#!/usr/bin/env bash
# CI gate: tier-1 tests + smoke benchmarks.
#
#   scripts/ci.sh            # whole gate
#   scripts/ci.sh tests      # tests only
#   scripts/ci.sh bench      # smoke benchmarks only
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
what="${1:-all}"

if [[ "$what" == "all" || "$what" == "tests" ]]; then
    echo "== tier-1 tests =="
    python -m pytest -q
fi

if [[ "$what" == "all" || "$what" == "bench" ]]; then
    echo "== smoke benchmarks (incl. HLO overlap + arena + sharded gates) =="
    # the smoke set contains three HLO gates: "overlap" compiles one fused
    # COVAP step on an 8-worker CPU mesh and FAILS unless the compiled
    # module schedules bucket collectives inside the backward pass;
    # "arena" lowers the covap/topk execute paths arena-off vs arena-on
    # and FAILS unless the arena build issues fewer data-movement ops
    # (and zero per-segment update-slice chains); "sharded" compiles one
    # sharded step and FAILS unless reduce-scatters precede the final
    # gradient fusion with the deferred param all-gathers at the step
    # head, and exposed wire bytes <= 0.6x all-reduce.  "hier" compiles
    # one hierarchical sharded step on a (pod=2, data=4) mesh
    # (benchmarks/hier_check.py) and FAILS unless the CommSchedule's
    # per-link byte accounting — intra-pod reduce-scatters + deferred
    # head all-gather on the ICI, owned-shard cross-pod exchanges on the
    # DCN — matches the compiled HLO's replica-group-classified
    # collective bytes; its hier_exposed_dcn_ratio lands in
    # BENCH_<n>.json under the trajectory gate.  "serve" runs a
    # short QPS sweep through the paged-KV continuous-batching engine and
    # FAILS on lost requests, invalid finish reasons, or prefill
    # degenerating to one dispatch per token.  "obs" is the telemetry
    # gate (benchmarks/obs_check.py): an instrumented fused-overlap run
    # must stream schema-valid events.jsonl (every line validated against
    # repro/obs/event_schema.json) and export a Chrome trace with one
    # named planned issue span per bucket; an instrumented serve run must
    # land per-request spans for all three stages; and the instrumented
    # step wall must stay within 3% of the uninstrumented one
    # (REPRO_OBS_NO_OVERHEAD_GATE=1 skips only the 3% check).  A
    # BENCH_<n>.json perf snapshot (interleaved min-of-trials step walls,
    # bytes/worker, overlap frac, pack-kernel µs, sharded exposed ratio,
    # serving stage unit costs + p50/p99/ttft/tokens-per-sec), built from
    # a repro.obs MetricsRegistry snapshot since schema 3, is written to
    # the repo root on every smoke run, and the run FAILS if any stable
    # key regressed >25% vs the previous snapshot
    # (REPRO_BENCH_NO_TRAJECTORY_GATE=1 records without gating; the gate
    # also auto-skips with a notice when the two snapshots' "workload"
    # fields differ — cross-workload numbers are not comparable).
    # "chaos" is the resilience gate (benchmarks/chaos_check.py): an
    # 8-worker mesh run under injected NaN grads, an EF blow-up, a
    # persistent Inf and a mid-run kill must heal through all three
    # recovery rungs (skip-step / ef-flush / checkpoint rewind), resume
    # from the guard-owned checkpoint, end with finite loss, and surface
    # every trip as schema-valid telemetry; a guarded step must stay
    # within 3% of an unguarded one, recorded as guard_overhead_frac in
    # BENCH_<n>.json (REPRO_CHAOS_NO_OVERHEAD_GATE=1 skips only the 3%
    # check).
    python -m benchmarks.run --smoke > /dev/null
    echo "smoke benchmarks OK"
fi
