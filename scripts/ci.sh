#!/usr/bin/env bash
# CI gate: tier-1 tests + smoke benchmarks.
#
#   scripts/ci.sh            # whole gate
#   scripts/ci.sh tests      # tests only
#   scripts/ci.sh bench      # smoke benchmarks only
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
what="${1:-all}"

if [[ "$what" == "all" || "$what" == "tests" ]]; then
    echo "== tier-1 tests =="
    python -m pytest -q
fi

if [[ "$what" == "all" || "$what" == "bench" ]]; then
    echo "== smoke benchmarks (incl. HLO overlap-interleaving gate) =="
    # the smoke set contains the "overlap" module: it compiles one fused
    # COVAP step on an 8-worker CPU mesh and FAILS the gate unless the
    # compiled HLO schedules bucket collectives inside the backward pass
    python -m benchmarks.run --smoke > /dev/null
    echo "smoke benchmarks OK"
fi
