"""The measured CCR profiler (paper §III.B): ``measure_ccr`` sub-program
timing and ``align_comm_times`` distributed-timeline alignment — including
on a real (fake-device) CPU mesh, where the full step carries genuine
shard_map collectives."""
import os
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

from repro.core.ccr import align_comm_times, measure_ccr, select_interval

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


# ---------------------------------------------------------------------------
# align_comm_times: pure arithmetic
# ---------------------------------------------------------------------------

def test_align_excludes_rendezvous_wait():
    # worker 0 reaches the collective early and waits; the true transfer
    # only starts when worker 1 (the straggler) arrives
    starts = np.array([[0.0], [3.0]])
    ends = np.array([[5.0], [5.0]])
    assert align_comm_times(starts, ends) == pytest.approx([2.0])


def test_align_multiple_ops_uses_last_start_first_end():
    starts = np.array([[0.0, 10.0], [1.0, 12.0], [0.5, 11.0]])
    ends = np.array([[4.0, 15.0], [4.5, 14.0], [4.0, 15.5]])
    got = align_comm_times(starts, ends)
    assert got == pytest.approx([4.0 - 1.0, 14.0 - 12.0])


def test_align_single_worker_is_plain_duration():
    starts = np.array([[1.0, 2.0]])
    ends = np.array([[1.5, 4.0]])
    assert align_comm_times(starts, ends) == pytest.approx([0.5, 2.0])


# ---------------------------------------------------------------------------
# measure_ccr: sub-program timing
# ---------------------------------------------------------------------------

def test_measure_ccr_with_synthetic_sleeps():
    full = lambda: time.sleep(0.012)
    comp = lambda: time.sleep(0.004)
    res = measure_ccr(full, comp, warmup=1, iters=3)
    assert res["t_full"] > res["t_comp"] > 0
    # t_comm ~ 8ms, t_comp ~ 4ms -> CCR ~ 2 (generous CI tolerance)
    assert 0.8 < res["ccr"] < 5.0
    assert select_interval(res["ccr"]) >= 1


def test_measure_ccr_comm_only_crosscheck_takes_max():
    # overlap makes (t_full - t_comp) undershoot; the direct schedule-only
    # timing must win when it is larger
    full = lambda: time.sleep(0.004)
    comp = lambda: time.sleep(0.004)
    comm = lambda: time.sleep(0.008)
    res = measure_ccr(full, comp, step_comm_only=comm, warmup=0, iters=2)
    assert "t_comm_direct" in res
    assert res["t_comm"] >= res["t_comm_direct"] * 0.8
    assert res["ccr"] > 1.0


def test_measure_ccr_comm_free_workload():
    fn = lambda: sum(range(2000))
    res = measure_ccr(fn, fn, warmup=1, iters=3)
    assert res["t_comm"] < res["t_comp"] + 1e-3
    # tiny jitter only: the derived interval should stay minimal
    assert select_interval(res["ccr"]) <= 2


# ---------------------------------------------------------------------------
# on a CPU mesh (8 fake devices, subprocess so the device count cannot
# leak into other tests)
# ---------------------------------------------------------------------------

def run_sub(code: str, timeout=560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    return r.stdout


def test_measure_ccr_on_cpu_mesh():
    """Full step = compute + psum over a 'data' mesh; compute-only elides
    the collective.  The profiler must produce a finite decomposition with
    t_full >= t_comp (within timing noise)."""
    out = run_sub("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.core.ccr import measure_ccr
from repro.train.trainer import shard_map_compat

mesh = Mesh(np.array(jax.devices()[:8]), ("data",))
x = jnp.arange(8 * 4096, dtype=jnp.float32).reshape(8, 4096)

def full_worker(x):
    y = jnp.tanh(x) @ jnp.ones((x.shape[-1], 64))
    return jax.lax.psum(y, "data")

def comp_worker(x):
    return jnp.tanh(x) @ jnp.ones((x.shape[-1], 64))

full = jax.jit(shard_map_compat(full_worker, mesh, (P("data"),), P(), ("data",)))
comp = jax.jit(shard_map_compat(comp_worker, mesh, (P("data"),), P("data"), ("data",)))

res = measure_ccr(
    lambda: jax.block_until_ready(full(x)),
    lambda: jax.block_until_ready(comp(x)),
    warmup=2, iters=5,
)
assert res["t_full"] > 0 and res["t_comp"] > 0
assert np.isfinite(res["ccr"]) and res["ccr"] >= 0.0
print("ccr=%.4f" % res["ccr"])
""")
    assert "ccr=" in out


def test_schedule_only_program_on_cpu_mesh():
    """runtime's schedule-only sub-program: replays exactly the planned
    collectives of a COVAP phase on a mesh and is timeable."""
    out = run_sub("""
import time
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.core import build_plan, get_compressor
from repro.runtime import build_schedule_only_fn

mesh = Mesh(np.array(jax.devices()[:8]), ("data",))
params = {"w": jnp.zeros((64, 16)), "b": jnp.zeros((16,))}
plan = build_plan(params, bucket_bytes=512, max_buckets=8, interval=4)
comp = get_compressor("covap", interval=4)
sched = comp.plan_phase(plan, 0, world=8)
fn = build_schedule_only_fn(sched, mesh=mesh, dp_axes=("data",))
fn()  # compile
t0 = time.perf_counter(); fn(); dt = time.perf_counter() - t0
assert dt >= 0.0
print("sched_only_ok %d calls" % len(sched.calls))
""")
    assert "sched_only_ok" in out
