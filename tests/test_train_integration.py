"""End-to-end training on learnable synthetic data: COVAP must converge
like the uncompressed baseline (the paper's central accuracy claim)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.data import DataConfig, make_loader
from repro.models import build_model
from repro.optim import adamw
from repro.train.trainer import TrainConfig, Trainer


def run_training(compressor, steps=30, interval=2, **copts):
    cfg = get_reduced("gpt2-paper").with_(vocab_size=256)
    model = build_model(cfg)
    tc = TrainConfig(
        compressor=compressor, compressor_options=copts, interval=interval,
        bucket_bytes=1 << 14, max_buckets=32, log_every=1000,
    )
    tr = Trainer(model, adamw(3e-3), tc)
    state = tr.init_state(jax.random.PRNGKey(0))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8,
                    corpus_tokens=1 << 14)
    loader = iter(make_loader(dc))
    losses = []
    for _ in range(steps):
        batch = next(loader)
        phase = state["step"] % tr.num_phases
        fn = tr._phase_fn(phase)
        p, o, c, m = fn(state["params"], state["opt"], state["comp"], batch,
                        jnp.int32(state["step"]))
        state = {"params": p, "opt": o, "comp": c, "step": state["step"] + 1}
        losses.append(float(m["loss"]))
    return losses


def test_baseline_converges():
    losses = run_training("none")
    assert losses[-1] < losses[0] * 0.8


def test_covap_converges_close_to_baseline():
    base = run_training("none")
    cov = run_training("covap", interval=2)
    assert cov[-1] < cov[0] * 0.85
    # within a modest factor of the baseline at equal step count
    assert cov[-1] < base[-1] * 1.6 + 0.3


def test_covap_without_ef_worse_or_equal():
    with_ef = run_training("covap", interval=4)
    without = run_training("covap", interval=4, ef=False)
    # EF should not hurt; usually helps (allow small noise margin)
    assert with_ef[-1] <= without[-1] * 1.15


def test_fp16_converges():
    losses = run_training("fp16")
    assert losses[-1] < losses[0] * 0.8


def test_trainer_run_loop_and_history():
    cfg = get_reduced("qwen1.5-0.5b").with_(vocab_size=256)
    model = build_model(cfg)
    tc = TrainConfig(compressor="covap", interval=2, bucket_bytes=1 << 14,
                     max_buckets=16, log_every=2, steps=4)
    tr = Trainer(model, adamw(1e-3), tc)
    state = tr.init_state(jax.random.PRNGKey(1))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4,
                    corpus_tokens=1 << 12)
    state = tr.run(state, iter(make_loader(dc)), steps=4, log=None)
    assert state["step"] == 4
    assert len(tr.history) >= 2
