"""Property tests for the bucket plan (coverage, sharding balance,
gather/scatter roundtrip)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import build_plan
from repro.core import bucketing as bk


def make_tree(shapes):
    return {
        f"leaf{i}": jnp.zeros(s, jnp.float32) for i, s in enumerate(shapes)
    }


shape_strategy = st.lists(
    st.one_of(
        st.tuples(st.integers(1, 40)),
        st.tuples(st.integers(1, 12), st.integers(1, 64)),
        st.tuples(st.integers(1, 6), st.integers(1, 16), st.integers(1, 32)),
    ),
    min_size=1,
    max_size=8,
)


@settings(max_examples=40, deadline=None)
@given(shapes=shape_strategy, interval=st.integers(1, 6),
       bucket_kb=st.sampled_from([1, 4, 16]))
def test_plan_covers_every_element_exactly_once(shapes, interval, bucket_kb):
    tree = make_tree(shapes)
    plan = build_plan(tree, bucket_bytes=bucket_kb * 1024, max_buckets=64,
                      interval=interval)
    total = sum(int(np.prod(s)) for s in shapes)
    assert plan.total_numel() == total
    # exact coverage: mark every element via scatter of ones
    leaves = [jnp.zeros(s, jnp.float32) for s in plan.leaf_shapes]
    for b in plan.buckets:
        ones = jnp.ones((b.numel,), jnp.float32)
        leaves = bk.scatter_bucket(plan, leaves, b, ones)
    for leaf in leaves:
        np.testing.assert_array_equal(np.asarray(leaf), 1.0)


@settings(max_examples=25, deadline=None)
@given(shapes=shape_strategy, interval=st.integers(1, 6))
def test_gather_scatter_roundtrip(shapes, interval):
    tree = make_tree(shapes)
    plan = build_plan(tree, bucket_bytes=2048, max_buckets=64, interval=interval)
    key = jax.random.PRNGKey(0)
    vals = [
        jax.random.normal(jax.random.fold_in(key, i), s)
        for i, s in enumerate(plan.leaf_shapes)
    ]
    rebuilt = [jnp.zeros(s, jnp.float32) for s in plan.leaf_shapes]
    for b in plan.buckets:
        flat = bk.gather_bucket(plan, vals, b)
        assert flat.shape == (b.numel,)
        rebuilt = bk.scatter_bucket(plan, rebuilt, b, flat)
    for a, c in zip(vals, rebuilt):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c))


def test_tensor_sharding_splits_oversized_bucket():
    """Paper SS III.C: a VGG-FC1-like oversized *layer* (one row bigger than
    the bucket target) must be sliced into min(numel//median, I) parts."""
    tree = {
        "convs": jnp.zeros((64, 64, 64)),       # many small rows (16 KiB each)
        "fc1": jnp.zeros((2, 1024, 1024)),      # 4 MiB rows >> 64 KiB target
    }
    plan = build_plan(tree, bucket_bytes=64 * 1024, max_buckets=512, interval=4)
    numels = plan.bucket_numels()
    med = np.median(numels)
    origins = {}
    for b in plan.buckets:
        origins.setdefault(b.origin, 0)
        origins[b.origin] += 1
    assert max(origins.values()) > 1, "expected at least one split bucket"
    # split count capped by the interval I=4
    assert max(origins.values()) <= 4
    # each oversized row was reduced 4x
    assert max(numels) == 1024 * 1024 // 4


def test_interval_caps_split_count():
    tree = {"big": jnp.zeros((4096, 512)), "small": jnp.zeros((4, 128))}
    for interval in (2, 3):
        plan = build_plan(tree, bucket_bytes=16 * 1024 * 1024,
                          max_buckets=256, interval=interval)
        origins = {}
        for b in plan.buckets:
            origins.setdefault(b.origin, 0)
            origins[b.origin] += 1
        assert max(origins.values()) <= max(interval, 1)


def test_plan_deterministic():
    tree = make_tree([(8, 32), (100,), (3, 5, 7)])
    p1 = build_plan(tree, bucket_bytes=1024, interval=4)
    p2 = build_plan(tree, bucket_bytes=1024, interval=4)
    assert p1.buckets == p2.buckets


def test_sub_axis_avoids_sharded_axis():
    from jax.sharding import PartitionSpec as P

    tree = {"w": jnp.zeros((1, 256, 512))}
    specs = {"w": P(None, None, "model")}
    plan = build_plan(tree, bucket_bytes=1024, max_buckets=4, interval=4,
                      param_specs=specs)
    for b in plan.buckets:
        for seg in b.segments:
            assert seg.sub_axis != 2, "split must avoid the sharded axis"
