"""Optimizers, schedules, data pipeline, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.data import DataConfig, make_loader, markov_corpus
from repro.optim import (
    adamw,
    apply_updates,
    clip_by_global_norm,
    cosine_warmup,
    global_norm,
    linear_warmup,
    sgd,
)


# ---- optimizers -------------------------------------------------------------

@pytest.mark.parametrize("opt_name", ["sgd", "adamw"])
def test_optimizer_minimises_quadratic(opt_name):
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"x": jnp.zeros(3)}
    opt = sgd(0.1, momentum=0.9) if opt_name == "sgd" else adamw(0.1)
    state = opt.init(params)
    for _ in range(200):
        grads = {"x": 2 * (params["x"] - target)}
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    np.testing.assert_allclose(np.asarray(params["x"]), np.asarray(target),
                               atol=1e-2)


def test_adamw_bf16_moments():
    params = {"x": jnp.zeros(8, jnp.float32)}
    opt = adamw(0.01, moment_dtype="bfloat16")
    state = opt.init(params)
    assert state["m"]["x"].dtype == jnp.bfloat16
    grads = {"x": jnp.ones(8)}
    updates, state = opt.update(grads, state, params)
    assert bool(jnp.all(jnp.isfinite(updates["x"])))


def test_weight_decay_shrinks():
    params = {"x": jnp.full(4, 10.0)}
    opt = adamw(0.1, weight_decay=0.1)
    state = opt.init(params)
    for _ in range(50):
        updates, state = opt.update({"x": jnp.zeros(4)}, state, params)
        params = apply_updates(params, updates)
    assert float(jnp.abs(params["x"]).max()) < 10.0


def test_schedules():
    lw = linear_warmup(1.0, 10)
    assert float(lw(0)) == 0.0
    assert abs(float(lw(5)) - 0.5) < 1e-6
    assert float(lw(100)) == 1.0
    cw = cosine_warmup(1.0, 10, 100, min_ratio=0.1)
    assert float(cw(100)) <= 0.11
    assert float(cw(10)) > 0.9


def test_clip_by_global_norm():
    tree = {"a": jnp.ones(100) * 10}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 99


# ---- data -------------------------------------------------------------

def test_markov_corpus_learnable():
    c = markov_corpus(0, 5000, 64)
    assert c.min() >= 0 and c.max() < 64
    # successor entropy must be far below uniform (learnable structure)
    pair_counts = {}
    for a, b in zip(c[:-1], c[1:]):
        pair_counts.setdefault(int(a), []).append(int(b))
    top_frac = np.mean(
        [
            max(np.bincount(v).max() / len(v), 0)
            for v in pair_counts.values()
            if len(v) >= 10
        ]
    )
    assert top_frac > 0.3


def test_loader_sharded_and_deterministic():
    dc = DataConfig(vocab_size=64, seq_len=16, global_batch=8,
                    corpus_tokens=1 << 12)
    l0 = make_loader(dc, num_workers=2, worker=0)
    l1 = make_loader(dc, num_workers=2, worker=1)
    b0 = l0._make(0)
    b1 = l1._make(0)
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(np.asarray(b0["tokens"]), np.asarray(b1["tokens"]))
    # labels are next-token shifted
    np.testing.assert_array_equal(
        np.asarray(b0["tokens"][:, 1:]), np.asarray(b0["labels"][:, :-1])
    )
    # deterministic
    again = make_loader(dc, num_workers=2, worker=0)._make(0)
    np.testing.assert_array_equal(np.asarray(b0["tokens"]),
                                  np.asarray(again["tokens"]))


def test_loader_iterator_prefetch():
    dc = DataConfig(vocab_size=64, seq_len=8, global_batch=2,
                    corpus_tokens=1 << 10)
    it = iter(make_loader(dc))
    b1, b2 = next(it), next(it)
    assert b1["tokens"].shape == (2, 8)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))


# ---- checkpoint -------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones(5, jnp.bfloat16), "c": jnp.int32(7)},
    }
    checkpoint.save(str(tmp_path), 3, tree)
    assert checkpoint.latest_step(str(tmp_path)) == 3
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    restored = checkpoint.restore(str(tmp_path), 3, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )


def test_checkpoint_shape_mismatch_raises(tmp_path):
    checkpoint.save(str(tmp_path), 0, {"a": jnp.zeros(4)})
    with pytest.raises(ValueError):
        checkpoint.restore(str(tmp_path), 0, {"a": jnp.zeros(5)})


def test_checkpoint_trainer_state_roundtrip(tmp_path):
    from repro.configs import get_reduced
    from repro.models import build_model
    from repro.optim import adamw as mk_adam
    from repro.train.trainer import TrainConfig, Trainer

    cfg = get_reduced("gpt2-paper").with_(vocab_size=64)
    model = build_model(cfg)
    tr = Trainer(model, mk_adam(1e-3),
                 TrainConfig(compressor="covap", interval=2,
                             bucket_bytes=1 << 12, max_buckets=8))
    state = tr.init_state(jax.random.PRNGKey(0))
    checkpoint.save(str(tmp_path), 0, state["params"])
    restored = checkpoint.restore(str(tmp_path), 0, state["params"])
    for a, b in zip(jax.tree.leaves(state["params"]), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
