"""Multi-worker correctness: compressors + trainer under shard_map on 8
fake CPU devices.  Runs in a subprocess because the device count must be
set before jax initialises (and must NOT leak into other tests)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_sub(code: str, timeout=560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    return r.stdout


PRELUDE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.core import build_plan, get_compressor
from repro.train.trainer import shard_map_compat

mesh = Mesh(np.array(jax.devices()[:8]), ("data",))
params = {"w": jnp.zeros((64, 16)), "b": jnp.zeros((16,))}
plan = build_plan(params, bucket_bytes=512, max_buckets=8, interval=4)
key = jax.random.PRNGKey(0)
# per-worker distinct gradients: (8, ...) leading axis
gw = {k: jax.random.normal(jax.random.fold_in(key, i), (8,) + v.shape)
      for i, (k, v) in enumerate(params.items())}
"""


def test_compressor_psum_equals_mean():
    """For mean-exact schemes the multi-worker sync must equal the mean of
    per-worker gradients at communicated positions."""
    out = run_sub(PRELUDE + """
for name in ("none", "covap", "fp16", "randomk"):
    comp = get_compressor(name, **({"interval": 4} if name == "covap" else {}))
    state = comp.init_state(params, plan)

    # shard_map splits leading axis 8 -> per-worker (1, ...) ... need squeeze
    def sync_worker(g, s):
        g = {k: v[0] for k, v in g.items()}
        out, s2, _ = comp.sync(g, s, plan=plan, phase=0, step=0,
                               axis_names=("data",))
        return out
    f = jax.jit(shard_map_compat(sync_worker, mesh,
        (P("data"), P()), P(), ("data",)))
    got = f(gw, state)
    mean = {k: v.mean(axis=0) for k, v in gw.items()}
    # compare only where the scheme communicated (out != 0)
    for k in mean:
        g_np, m_np = np.asarray(got[k]), np.asarray(mean[k])
        mask = g_np != 0
        if name in ("none", "fp16"):
            mask = np.ones_like(g_np, bool)
        tol = 2e-2 if name == "fp16" else 1e-5
        np.testing.assert_allclose(g_np[mask], m_np[mask], rtol=tol, atol=tol)
    print(name, "OK")
""")
    assert out.count("OK") == 4


def test_allgather_schemes_run_multiworker():
    out = run_sub(PRELUDE + """
for name in ("topk", "efsignsgd", "oktopk", "fp8wire"):
    comp = get_compressor(name)
    state = comp.init_state(params, plan)
    def sync_worker(g, s):
        g = {k: v[0] for k, v in g.items()}
        out, s2, _ = comp.sync(g, s, plan=plan, phase=0, step=0,
                               axis_names=("data",))
        return out
    f = jax.jit(shard_map_compat(sync_worker, mesh,
        (P("data"), P()), P(), ("data",)))
    got = f(gw, state)
    for k in got:
        assert bool(jnp.all(jnp.isfinite(got[k]))), name
    print(name, "OK")
""")
    assert out.count("OK") == 4


def test_trainer_covap_multiworker_loss_decreases():
    out = run_sub("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs import get_reduced
from repro.models import build_model
from repro.optim import adamw
from repro.train.trainer import TrainConfig, Trainer

mesh = Mesh(np.array(jax.devices()[:8]).reshape(4, 2), ("data", "model"))
cfg = get_reduced("gpt2-paper")
model = build_model(cfg)
tc = TrainConfig(compressor="covap", interval=2, bucket_bytes=1 << 14,
                 max_buckets=32, log_every=100)
tr = Trainer(model, adamw(3e-3), tc, mesh=mesh, dp_axes=("data",))
state = tr.init_state(jax.random.PRNGKey(0))

from repro.data import DataConfig, make_loader
dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8,
                corpus_tokens=1 << 14)
loader = iter(make_loader(dc))
first = None
losses = []
for i in range(12):
    batch = next(loader)
    phase = state["step"] % tr.num_phases
    fn = tr._phase_fn(phase)
    p, o, c, m = fn(state["params"], state["opt"], state["comp"], batch,
                    jnp.int32(state["step"]))
    state = {"params": p, "opt": o, "comp": c, "step": state["step"] + 1}
    losses.append(float(m["loss"]))
assert losses[-1] < losses[0], losses
print("loss", losses[0], "->", losses[-1], "OK")
""")
    assert "OK" in out
