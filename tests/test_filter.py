"""The coarse-grained filter schedule (paper SS III.A)."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.filter import (
    compression_ratio,
    is_selected,
    schedule_table,
    selected_buckets,
)


@settings(max_examples=50, deadline=None)
@given(n=st.integers(1, 64), interval=st.integers(1, 8))
def test_every_bucket_exactly_once_per_period(n, interval):
    table = schedule_table(n, interval, interval)
    counts = np.zeros(n, int)
    for sel in table:
        for b in sel:
            counts[b] += 1
    assert (counts == 1).all()


@settings(max_examples=50, deadline=None)
@given(n=st.integers(1, 64), interval=st.integers(2, 8), step=st.integers(0, 100))
def test_phase_specialisation_matches_paper_rule(n, interval, step):
    """Static per-phase selection (XLA adaptation) == the paper's runtime
    modulo rule for every step."""
    phase = step % interval
    assert selected_buckets(n, phase, interval) == tuple(
        b for b in range(n) if is_selected(b, step, interval)
    )


@settings(max_examples=30, deadline=None)
@given(interval=st.integers(1, 8), mult=st.integers(1, 8))
def test_volume_compression_equals_interval_when_divisible(interval, mult):
    import jax.numpy as jnp

    from repro.core import build_plan

    tree = {"w": jnp.zeros((interval * mult * 64,))}
    plan = build_plan(tree, bucket_bytes=256, max_buckets=interval * mult,
                      interval=interval)
    if plan.num_buckets % interval == 0:
        assert abs(compression_ratio(plan, interval) - interval) < 1e-9


def test_per_step_selection_size_balanced():
    for n, interval in [(16, 4), (17, 4), (5, 2), (64, 8)]:
        sizes = [len(s) for s in schedule_table(n, interval, interval)]
        assert max(sizes) - min(sizes) <= 1
