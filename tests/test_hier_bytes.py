"""Two-level hierarchical sync suite (DESIGN.md §17): per-link byte
accounting and pod-mesh conformance.

* hypothesis properties for the two-level wire model: for arbitrary
  (n_pods, intra_world, numel, dtype) the intra RS + cross-pod AR +
  intra AG wire bytes equal the flat RS+AG (== ring all-reduce) wire
  bytes at equal bandwidth; ``plan_pod_schedule`` prices exactly the
  owned-shard DCN bytes; W-aligned slot shard decomposition round-trips
  unchanged;
* ``CommSchedule`` per-link accessors and ``perfmodel`` per-link
  bandwidths on the merged hierarchical schedules;
* the 2x4-pod CPU-mesh conformance pin: hierarchical ``sync="sharded"``
  == hierarchical ``sync="allreduce"`` bit-for-bit (params, EF
  residuals, optimizer moments) through ``Trainer.flush_sync``;
* the compiled per-link gate (``repro.launch.hier_gate``): schedule
  bytes vs HLO replica-group-classified bytes on both links;
* the bf16 promotion-guard regression under hierarchical sharded sync.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import arena as ar
from repro.core import build_plan, get_compressor
from repro.core.schedule import CollectiveCall
from repro.train.trainer import plan_pod_schedule

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


# ---------------------------------------------------------------------------
# wire-model properties
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(n_pods=st.integers(2, 16), intra=st.integers(2, 32),
       m=st.integers(1, 200),
       dtype=st.sampled_from(["float32", "bfloat16", "float16"]))
def test_two_level_wire_equals_flat_at_equal_bandwidth(n_pods, intra, m, dtype):
    """The hierarchical ring identity: reduce-scatter inside the pod
    (k workers), all-reduce the owned 1/k shard across p pods, all-gather
    inside the pod == one flat ring all-reduce over p*k workers — and the
    flat sharded decomposition (RS + deferred AG at p*k) prices the same,
    so at equal per-link bandwidth the two-level plan moves exactly the
    flat plan's bytes."""
    k, p = intra, n_pods
    numel = m * k * p          # divisible by both worlds: no padding terms
    it = np.dtype(dtype).itemsize
    B = numel * it
    rs = CollectiveCall("b:0", "reduce_scatter", dtype, B, link="ici",
                        world=k)
    xp = CollectiveCall("pod-bucket:0", "all_reduce", dtype, B // k,
                        link="dcn", world=p)
    ag = CollectiveCall("pod-ag:0", "all_gather", dtype, B // k, link="ici",
                        world=k)
    two_level = rs.wire_bytes(0) + xp.wire_bytes(0) + ag.wire_bytes(0)
    W = p * k
    flat_rs = CollectiveCall("b:0", "reduce_scatter", dtype, B, world=W)
    flat_ag = CollectiveCall("p:0", "all_gather", dtype, B // W, world=W)
    flat = flat_rs.wire_bytes(0) + flat_ag.wire_bytes(0)
    assert two_level == pytest.approx(flat, rel=1e-12)
    # both equal the ring all-reduce closed form 2(W-1)/W * B
    assert flat == pytest.approx(2 * (W - 1) / W * B, rel=1e-12)


@settings(max_examples=30, deadline=None)
@given(numel=st.integers(1, 5000), intra=st.sampled_from([2, 4, 8]),
       n_pods=st.integers(2, 8), pod_interval=st.integers(1, 4))
def test_pod_schedule_exact_per_link_bytes(numel, intra, n_pods,
                                           pod_interval):
    """``plan_pod_schedule``'s per-link injected bytes, exactly: one DCN
    all-reduce of the W-aligned owned shard per selected bucket; under
    allreduce sync additionally one same-sized ICI all-gather; under
    sharded sync no ICI call at all."""
    tree = {"w": jax.ShapeDtypeStruct((numel,), np.float32)}
    plan = build_plan(tree, bucket_bytes=1 << 30, max_buckets=1, interval=1)
    assert plan.num_buckets == 1
    shard_bytes = (ar.aligned_numel(numel, intra) // intra) * 4
    for sync in ("allreduce", "sharded"):
        sched = plan_pod_schedule(
            plan, pod_phase=0, pod_interval=pod_interval, sync=sync,
            intra_world=intra, n_pods=n_pods,
        )
        if 0 not in sched.selected:
            assert not sched.calls
            continue
        by_link = sched.exposed_bytes_by_link()
        assert by_link.get("dcn") == shard_bytes
        if sync == "allreduce":
            assert by_link.get("ici") == shard_bytes
            assert sched.links == ("ici", "dcn")
        else:
            assert "ici" not in by_link
            assert sched.links == ("dcn",)
        # wire amplification uses each call's OWN world, independent of
        # the caller-supplied schedule world
        wire = sched.exposed_wire_bytes_by_link(1)
        assert wire["dcn"] == pytest.approx(
            2 * (n_pods - 1) / n_pods * shard_bytes
        )
        if sync == "allreduce":
            assert wire["ici"] == pytest.approx((intra - 1) * shard_bytes)


@settings(max_examples=30, deadline=None)
@given(numel=st.integers(1, 3000), intra=st.sampled_from([2, 4, 8]),
       n_pods=st.sampled_from([2, 4]))
def test_aligned_shard_exchange_roundtrip_unchanged(numel, intra, n_pods):
    """The W-aligned slot's owned-shard decomposition round-trips
    unchanged: slicing the slot into W contiguous shards (what
    ``pod_reconcile`` hands each worker), reassembling them, and
    unpacking rebuilds the original leaf bitwise — and the zero pad tail
    the alignment added stays exactly zero through a cross-pod mean of
    arbitrary per-pod values (zeros on every pod average to zero), so
    padding never leaks into real elements across the exchange."""
    rng = np.random.RandomState(numel)
    x = rng.randn(numel).astype(np.float32)
    tree = {"w": jax.ShapeDtypeStruct((numel,), np.float32)}
    plan = build_plan(tree, bucket_bytes=1 << 30, max_buckets=1, interval=1)
    layout = ar.build_layout(plan, (0,), align=intra)
    planes = ar.pack_leaves(layout, [x])
    view = np.asarray(layout.bucket_view(planes, 0))
    S = view.shape[0] // intra
    assert view.shape[0] == ar.aligned_numel(numel, intra)
    # shard decomposition covers the slot exactly, once
    out = np.concatenate(
        [view[w * S:(w + 1) * S] for w in range(intra)]
    )
    np.testing.assert_array_equal(out, view)
    (piece,) = layout.unpack_bucket(0, out)
    np.testing.assert_array_equal(np.asarray(piece), x)
    # pad tail: zeros on every pod -> exactly zero after the mean, for
    # any per-pod payload in the real region
    pods = np.stack([
        ar.pack_leaves(layout, [rng.randn(numel).astype(np.float32)])
        for _ in range(n_pods)
    ])
    mean = np.asarray(
        layout.bucket_view(pods.sum(axis=0) / n_pods, 0)
    )
    np.testing.assert_array_equal(mean[numel:], 0.0)


# ---------------------------------------------------------------------------
# CommSchedule per-link accessors + perfmodel per-link bandwidths
# ---------------------------------------------------------------------------

def _hier_trainer(sync="sharded", n_pods=2, data=4):
    from jax.sharding import Mesh

    from repro.configs import get_reduced
    from repro.models import build_model
    from repro.optim import adamw
    from repro.train.trainer import TrainConfig, Trainer

    class _FakeMesh:
        """Shape-only stand-in: schedules() and the perf model read only
        ``mesh.shape``, so no real devices are needed."""
        shape = {"pod": n_pods, "data": data}

    cfg = get_reduced("gpt2-paper").with_(vocab_size=256)
    tc = TrainConfig(compressor="covap", interval=4, bucket_bytes=1 << 14,
                     max_buckets=32, log_every=10 ** 9, sync=sync,
                     pod_interval=2)
    return Trainer(build_model(cfg), adamw(1e-3), tc, mesh=_FakeMesh(),
                   dp_axes=("pod", "data"))


def test_merged_hier_schedules_carry_per_link_accounting():
    tr = _hier_trainer()
    scheds = tr.schedules()
    assert len(scheds) == 4          # lcm(4, 2)
    for s in scheds:
        assert s.links == ("ici", "dcn")
        by = s.exposed_bytes_by_link()
        assert by["ici"] > 0 and by["dcn"] > 0
        # the DCN carries only owned shards: every dcn call is 1/W of its
        # bucket's aligned slot
        for c in s.calls:
            if c.link == "dcn":
                assert c.world == 2 and c.target.startswith("pod-bucket:")
        # per-link injected bytes partition the total
        assert sum(by.values()) == pytest.approx(s.exposed_bytes_per_worker)
        summ = s.summary()
        assert summ["links"] == ["ici", "dcn"] or \
            summ["links"] == ("ici", "dcn")
        assert summ["exposed_bytes_by_link"]["dcn"] == pytest.approx(
            by["dcn"]
        )


def test_perfmodel_per_link_bandwidths():
    """schedule_comm_times / simulate_schedule price each call on its own
    link: an infinitely fast DCN removes exactly the DCN share, and a
    Mapping link_bw with only one link raises a KeyError naming it."""
    from repro.core.perfmodel import schedule_comm_times, simulate_schedule

    tr = _hier_trainer()
    s = tr.schedules()[0]
    W = tr.dp_world
    both = schedule_comm_times(s, world=W, link_bw={"ici": 1e9, "dcn": 1e9})
    flat = schedule_comm_times(s, world=W, link_bw=1e9)
    assert sum(both) == pytest.approx(sum(flat))
    fast_dcn = schedule_comm_times(
        s, world=W, link_bw={"ici": 1e9, "dcn": 1e18}
    )
    dcn_share = sum(
        c.wire_bytes(W) for c in s.calls if c.link == "dcn"
    ) / 1e9
    assert sum(flat) - sum(fast_dcn) == pytest.approx(dcn_share, rel=1e-6)
    with pytest.raises(KeyError, match="dcn"):
        schedule_comm_times(s, world=W, link_bw={"ici": 1e9})
    r = simulate_schedule(1e-3, 1e-3, s, world=W,
                          link_bw={"ici": 1e9, "dcn": 1e8})
    assert r["comm_total"] > 0


def test_exposed_comm_scale_reads_slowest_link():
    """The controller's exposed scale derives from per-link exposed
    bytes: flat sharded sits at ~0.5 (RS half deferred), hierarchical
    sharded sits strictly above it (the DCN exchange is exposed and slow)
    but below 1."""
    from repro.runtime import exposed_comm_scale

    class _FlatMesh:
        shape = {"data": 8}

    from repro.configs import get_reduced
    from repro.models import build_model
    from repro.optim import adamw
    from repro.train.trainer import TrainConfig, Trainer

    cfg = get_reduced("gpt2-paper").with_(vocab_size=256)
    tc = TrainConfig(compressor="covap", interval=4, bucket_bytes=1 << 14,
                     max_buckets=32, log_every=10 ** 9, sync="sharded")
    tr_flat = Trainer(build_model(cfg), adamw(1e-3), tc, mesh=_FlatMesh(),
                      dp_axes=("data",))
    s_flat = exposed_comm_scale(tr_flat)
    assert s_flat == pytest.approx(0.5, abs=0.05)
    s_hier = exposed_comm_scale(_hier_trainer())
    assert 0.5 < s_hier <= 1.0


# ---------------------------------------------------------------------------
# pod-mesh conformance: hierarchical sharded == hierarchical allreduce
# ---------------------------------------------------------------------------

_HIER_PARITY_SUB = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs import get_reduced
from repro.data import DataConfig, make_loader
from repro.models import build_model
from repro.optim import adamw
from repro.train.trainer import TrainConfig, Trainer

mesh = Mesh(np.array(jax.devices()[:8]).reshape(2, 4), ("pod", "data"))
cfg = get_reduced("gpt2-paper").with_(vocab_size=256)
model = build_model(cfg)

def run(sync, steps=5):
    # clip_norm stays 0: the sharded path's grad-norm psum sums in a
    # different order than the allreduce path's single-array norm, so
    # clipping would break the bitwise pin (DESIGN.md §13) — norms agree
    # to ~ulp only.
    tc = TrainConfig(compressor="covap", interval=4, bucket_bytes=1 << 14,
                     max_buckets=32, log_every=10 ** 9, sync=sync,
                     pod_interval=2)
    tr = Trainer(model, adamw(3e-3), tc, mesh=mesh, dp_axes=("pod", "data"))
    state = tr.init_state(jax.random.PRNGKey(0))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8,
                    corpus_tokens=1 << 14)
    # Trainer.run: the real loop incl. the end-of-run flush_sync of the
    # last step's deferred param all-gather
    return tr.run(state, iter(make_loader(dc)), steps=steps, log=None)

base = run("allreduce")
got = run("sharded")
# params, EF residuals AND optimizer moments, on BOTH pod blocks: the
# two sync modes share one two-level pod_reconcile, so the drift each
# pod carries between reconciliations is bitwise identical too
for x, y in zip(
    jax.tree.leaves((base["params"], base["comp"], base["opt"])),
    jax.tree.leaves((got["params"], got["comp"], got["opt"])),
):
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
print("HIER PARITY EQUAL")
"""


def _run_sub(body: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(body)],
        capture_output=True, text=True, timeout=560, env=env,
    )
    assert r.returncode == 0, (
        f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    )
    return r.stdout


def test_hier_sharded_equals_hier_allreduce_on_pod_mesh():
    """The acceptance criterion: on an 8-worker (pod=2, data=4) CPU mesh,
    hierarchical ``sync="sharded"`` == hierarchical ``sync="allreduce"``
    bit-for-bit — params, EF residuals, optimizer moments — over a full
    lcm(interval, pod_interval) cycle + 1, through ``Trainer.flush_sync``."""
    out = _run_sub(_HIER_PARITY_SUB)
    assert "HIER PARITY EQUAL" in out


def test_hier_gate_per_link_bytes_match_hlo():
    """The compiled gate: per-link CommSchedule bytes == the HLO's
    replica-group-classified collective bytes, and the DCN plan is
    non-empty."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.hier_gate"],
        capture_output=True, text=True, timeout=560, env=env,
    )
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr[-3000:]}"
    line = next(l for l in r.stdout.splitlines() if l.startswith("HIER"))
    kv = dict(p.split("=") for p in line.split()[1:])
    assert kv["match"] == "1"
    assert float(kv["dcn_schedule"]) > 0
    assert 0.0 < float(kv["hier_exposed_dcn_ratio"]) < 1.0


_BF16_HIER_SUB = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs import get_reduced
from repro.data import DataConfig, make_loader
from repro.models import build_model
from repro.optim import sgd
from repro.train.trainer import TrainConfig, Trainer

mesh = Mesh(np.array(jax.devices()[:8]).reshape(2, 4), ("pod", "data"))
cfg = get_reduced("gpt2-paper").with_(vocab_size=256,
                                      param_dtype="bfloat16")
model = build_model(cfg)
tc = TrainConfig(compressor="covap", interval=2, bucket_bytes=1 << 14,
                 max_buckets=16, log_every=10 ** 9, sync="sharded",
                 pod_interval=2)
tr = Trainer(model, sgd(1e-3), tc, mesh=mesh, dp_axes=("pod", "data"))
state = tr.init_state(jax.random.PRNGKey(0))
dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8)
state = tr.run(state, iter(make_loader(dc)), steps=2, log=None)
assert all(jnp.isfinite(x).all() for x in jax.tree.leaves(state["params"])
           if jnp.issubdtype(x.dtype, jnp.floating))
print("BF16 HIER SHARDED OK")
"""


def test_bf16_params_compile_under_hierarchical_sharded_sync():
    """Regression for the REPRO_PSUM_PROMOTE_BF16 guard on the cross-pod
    exchange: a bf16-param arch must compile and step on the CPU dry-run
    backend under hierarchical sync="sharded" — the DCN shard exchange
    routes through comm.pmean, so the same f32 promotion that protects
    the intra-pod reduce-scatter wraps the pod all-reduce (XLA CPU
    CHECK-fails on raw bf16 all-reduces)."""
    out = _run_sub(_BF16_HIER_SUB)
    assert "BF16 HIER SHARDED OK" in out
