"""Sharded-sync conformance suite (DESIGN.md §13): the cross-path pin that
``sync="sharded"`` (reduce-scatter over the arena slots + deferred param
all-gather) is observationally identical to ``sync="allreduce"``.

* sharded == allreduce parity — params AND EF residuals — for
  covap/none/fp16 over a full phase cycle, single-process and on an
  8-worker CPU mesh, post AND fused overlap, arena on/off (mirroring the
  ``test_arena.py`` pinning style);
* hypothesis property tests for the W-aligned layout math and the RS+AG
  byte accounting (RS half + AG half == the all-reduce wire bytes, exact
  ``bytes_per_worker`` for arbitrary (W, bucket, dtype) draws);
* the schedule-level acceptance gate: exposed wire bytes per worker under
  ``sync="sharded"`` at W=8 <= 0.6x the all-reduce path;
* compiled-HLO placement (reduce-scatters inside the backward pass, param
  all-gathers at the step head) via ``repro.launch.sharded_gate``;
* the ``REPRO_PSUM_PROMOTE_BF16`` guard regression: a bf16-param arch
  compiles on the CPU dry-run backend under ``sync="sharded"``.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import arena as ar
from repro.core import build_plan, get_compressor
from repro.core.schedule import CollectiveCall

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def make_tree(shapes, dtypes=None):
    dtypes = dtypes or [jnp.float32] * len(shapes)
    key = jax.random.PRNGKey(11)
    return {
        f"leaf{i}": jax.random.normal(
            jax.random.fold_in(key, i), s, jnp.float32
        ).astype(d)
        for i, (s, d) in enumerate(zip(shapes, dtypes))
    }


shape_strategy = st.lists(
    st.one_of(
        st.tuples(st.integers(1, 40)),
        st.tuples(st.integers(1, 12), st.integers(1, 64)),
        st.tuples(st.integers(1, 6), st.integers(1, 16), st.integers(1, 32)),
    ),
    min_size=1,
    max_size=8,
)


# ---------------------------------------------------------------------------
# schedule structure + byte accounting
# ---------------------------------------------------------------------------

def test_sharded_schedule_structure():
    """A sharded covap phase: one reduce-scatter per SELECTED bucket (same
    selection as the allreduce plan), one deferred param all-gather per
    PLAN bucket, and the sync tag on the schedule."""
    tree = make_tree([(16, 8), (32, 4), (5,)])
    plan = build_plan(tree, bucket_bytes=256, max_buckets=8, interval=2)
    W = 8
    cs = get_compressor("covap", interval=2, sync="sharded")
    ca = get_compressor("covap", interval=2)
    for phase in range(2):
        ss = cs.plan_phase(plan, phase, world=W)
        sa = ca.plan_phase(plan, phase, world=W)
        assert ss.sync == "sharded" and sa.sync == "allreduce"
        assert ss.selected == sa.selected
        assert all(c.op == "reduce_scatter" for c in ss.calls)
        assert len(ss.deferred_calls) == plan.num_buckets
        assert all(
            c.op == "all_gather" and c.deferred for c in ss.deferred_calls
        )
        # exposed == calls, deferred == AG half, total == both
        assert ss.exposed_bytes_per_worker == ss.bytes_per_worker
        assert ss.total_bytes_per_worker == (
            ss.bytes_per_worker + ss.deferred_bytes_per_worker
        )
        assert ss.summary()["sync"] == "sharded"


@settings(max_examples=40, deadline=None)
@given(numel=st.integers(1, 10_000), world=st.integers(1, 64),
       wire=st.sampled_from(["float32", "bfloat16", "float16"]),
       param=st.sampled_from(["float32", "bfloat16"]))
def test_rs_ag_bytes_exact_and_sum_to_allreduce(numel, world, wire, param):
    """For arbitrary (W, bucket numel, dtypes): the planned RS payload is
    the W-aligned buffer at the wire dtype, the AG payload the 1/W param
    shard, and — at matching dtypes — RS wire + AG wire equals exactly the
    ring all-reduce wire bytes of the padded buffer."""
    padded = ar.aligned_numel(numel, world)
    assert padded % world == 0 and 0 <= padded - numel < world
    wi = np.dtype(wire).itemsize
    pi = np.dtype(param).itemsize
    rs = CollectiveCall("bucket:0", "reduce_scatter", wire, padded * wi)
    ag = CollectiveCall("param-bucket:0", "all_gather", param,
                        (padded // world) * pi, deferred=True)
    assert rs.bytes_per_worker == padded * wi
    assert ag.bytes_per_worker == padded // world * pi
    # wire model: RS moves (W-1)/W of its buffer, AG re-sends the shard
    # (W-1) times -> (W-1)/W of the full buffer
    assert rs.wire_bytes(world) == pytest.approx(
        (world - 1) / world * padded * wi if world > 1 else 0.0
    )
    assert ag.wire_bytes(world) == pytest.approx(
        (world - 1) / world * padded * pi if world > 1 else 0.0
    )
    if wire == param:
        arr = CollectiveCall("bucket:0", "all_reduce", wire, padded * wi)
        assert rs.wire_bytes(world) + ag.wire_bytes(world) == pytest.approx(
            arr.wire_bytes(world)
        )


@settings(max_examples=30, deadline=None)
@given(shapes=shape_strategy, world=st.sampled_from([1, 2, 4, 8, 16]),
       interval=st.integers(1, 4))
def test_planned_bytes_match_layout_extents(shapes, world, interval):
    """The sharded schedule's per-call bytes are exactly the W-aligned
    layout's slot extents — planner and executor agree on every pad."""
    tree = make_tree(shapes)
    plan = build_plan(tree, bucket_bytes=2048, max_buckets=16,
                      interval=interval)
    comp = get_compressor("none", sync="sharded")
    sched = comp.plan_phase(plan, 0, world=world)
    layout = ar.build_layout(plan, align=world)
    for b, call in zip(sched.selected, sched.calls):
        _, _, extent = layout.slot(b)
        dt = np.dtype(call.wire_dtype)
        assert call.payload_bytes == extent * dt.itemsize
    for b, call in enumerate(sched.deferred_calls):
        _, _, extent = layout.slot(b)
        dt = np.dtype(call.wire_dtype)
        assert call.payload_bytes == extent // max(world, 1) * dt.itemsize


# ---------------------------------------------------------------------------
# W-aligned layout properties
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(shapes=shape_strategy, world=st.sampled_from([1, 2, 3, 8, 16]),
       interval=st.integers(1, 4))
def test_aligned_layout_roundtrip_unchanged(shapes, world, interval):
    """W-aligned padding never changes pack -> view -> unpack ->
    gather_leaves round-trips: every slot extent is W-divisible, the real
    elements sit exactly where the unaligned layout puts them, and leaves
    rebuild bitwise."""
    tree = make_tree(shapes)
    plan = build_plan(tree, bucket_bytes=1024, max_buckets=32,
                      interval=interval)
    leaves = jax.tree_util.tree_leaves(tree)
    layout = ar.build_layout(plan, align=world)
    base = ar.build_layout(plan)
    planes = ar.pack_leaves(layout, leaves)
    pieces = {}
    for b, bucket in enumerate(plan.buckets):
        _, _, extent = layout.slot(b)
        assert extent % max(world, 1) == 0
        assert extent == ar.aligned_numel(bucket.numel, world)
        view = layout.bucket_view(planes, b)
        assert view.shape[0] == extent
        # real payload is bitwise the unaligned view; the tail is zeros
        ref = ar.build_layout(plan, (b,))
        ref_view = ref.bucket_view(
            ar.pack_leaves(ref, leaves), b
        )
        np.testing.assert_array_equal(
            np.asarray(view[: bucket.numel]), np.asarray(ref_view)
        )
        np.testing.assert_array_equal(
            np.asarray(view[bucket.numel:]), 0.0
        )
        got = layout.unpack_bucket(b, view)
        want = base.unpack_bucket(b, ref_view)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
        pieces[b] = got
    rebuilt = ar.gather_leaves(
        plan, lambda b, si, seg: pieces[b][si], leaves
    )
    for got, want in zip(rebuilt, leaves):
        assert got.dtype == want.dtype
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# execute parity, single-process (W=1: RS/AG degrade to identities but the
# sharded code path — pack, aligned layout, scatter — still runs)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,opts", [
    ("covap", {"interval": 2}),
    ("none", {}),
    ("fp16", {}),
    ("covap", {"interval": 2, "wire_dtype": "bfloat16"}),
])
def test_sharded_execute_parity_single_process(name, opts):
    tree = make_tree([(16, 8), (32, 4), (5,), ()])
    grads = jax.tree.map(lambda x: x * 0.1, tree)
    plan = build_plan(tree, bucket_bytes=256, max_buckets=8, interval=2)
    for arena_on in (False, True):
        cs = get_compressor(name, **opts, sync="sharded",
                            use_arena=arena_on)
        cb = get_compressor(name, **opts)
        sa, sb = cs.init_state(tree, plan), cb.init_state(tree, plan)
        for step in range(3):
            outa, sa, stats = cs.execute(
                cs.plan_phase(plan, step % 2), grads, sa, step=step
            )
            outb, sb, _ = cb.execute(
                cb.plan_phase(plan, step % 2), grads, sb, step=step
            )
            for x, y in zip(jax.tree.leaves((outa, sa)),
                            jax.tree.leaves((outb, sb))):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_sharded_rejects_flat_and_leaf_pipelines():
    with pytest.raises(ValueError, match="segmented bucket pipeline"):
        get_compressor("topk", ratio=0.1, sync="sharded")
    with pytest.raises(ValueError, match="segmented bucket pipeline"):
        get_compressor("powersgd", rank=2, sync="sharded")
    with pytest.raises(ValueError, match="sync must be"):
        get_compressor("none", sync="bogus")


def test_supports_sharded_sync_matches_constructor_validation():
    """The public eligibility predicate and the constructor's validation
    are one rule: for every registered compressor,
    ``overlap.supports_sharded_sync`` is True exactly when constructing it
    with ``sync="sharded"`` succeeds."""
    from repro.core.compressors import available
    from repro.core.overlap import supports_sharded_sync

    opts = {"covap": {"interval": 2}, "topk": {"ratio": 0.2},
            "randomk": {"ratio": 0.2}, "oktopk": {"ratio": 0.2},
            "dgc": {}, "powersgd": {"rank": 2}}
    for name in available():
        base = get_compressor(name, **opts.get(name, {}))
        try:
            get_compressor(name, **opts.get(name, {}), sync="sharded")
            constructible = True
        except ValueError:
            constructible = False
        assert supports_sharded_sync(base) == constructible, name


def test_sharded_composes_with_hierarchical_pods():
    """Sharded sync COMPOSES with hierarchical pods (DESIGN.md §17; the
    pre-§17 guard raised here): the step builds, and its cross-pod plan
    carries only owned-shard-sized DCN calls — no intra all-gather
    rebuild (the deferred head AG covers the non-owner shards)."""
    from repro.optim import sgd
    from repro.train.trainer import build_step_fn, plan_pod_schedule

    tree = make_tree([(8, 4)])
    plan = build_plan(tree, bucket_bytes=1 << 20, max_buckets=4, interval=1)
    comp = get_compressor("none", sync="sharded")
    fn = build_step_fn(
        None, sgd(1e-3), comp, plan, phase=0,
        dp_axes=("pod", "data"), pod_interval=2, dp_world=4, n_pods=2,
    )
    pod = fn.pod_schedule
    assert pod is not None and pod.calls
    assert all(c.link == "dcn" and c.op == "all_reduce" for c in pod.calls)
    full = ar.aligned_numel(plan.buckets[0].numel, 4) * 4
    assert all(c.payload_bytes == full // 4 for c in pod.calls)
    # the allreduce-sync plan for the same phase additionally rebuilds the
    # full slot on the fast link
    pod_ar = plan_pod_schedule(
        plan, pod_phase=0, pod_interval=2, sync="allreduce",
        intra_world=4, n_pods=2,
    )
    assert {c.link for c in pod_ar.calls} == {"ici", "dcn"}
    assert any(c.op == "all_gather" and c.link == "ici" for c in pod_ar.calls)


# ---------------------------------------------------------------------------
# schedule-level acceptance: exposed bytes <= 0.6x all-reduce at W=8
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,opts", [
    ("covap", {"interval": 4}),
    ("none", {}),
    ("fp16", {}),
])
def test_exposed_wire_bytes_at_most_06x_allreduce(name, opts):
    from repro.configs import get_reduced
    from repro.models import build_model

    cfg = get_reduced("gpt2-paper").with_(vocab_size=256)
    shapes = jax.eval_shape(build_model(cfg).init, jax.random.PRNGKey(0))
    plan = build_plan(shapes, bucket_bytes=1 << 14, max_buckets=32,
                      interval=4)
    W = 8
    cs = get_compressor(name, **opts, sync="sharded")
    cb = get_compressor(name, **opts)
    n = max(cs.num_phases(4), 1)
    exposed = sum(
        cs.plan_phase(plan, p, world=W).exposed_wire_bytes(W)
        for p in range(n)
    )
    dense = sum(
        cb.plan_phase(plan, p, world=W).wire_bytes(W) for p in range(n)
    )
    assert exposed <= 0.6 * dense, (name, exposed / dense)
    # the RS half is exactly half the all-reduce's ring traffic, plus
    # W-alignment padding epsilon
    assert exposed / dense == pytest.approx(0.5, rel=0.02)


# ---------------------------------------------------------------------------
# trainer parity: full phase cycle on an 8-worker CPU mesh
# ---------------------------------------------------------------------------

_MESH_SUB = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs import get_reduced
from repro.data import DataConfig, make_loader
from repro.models import build_model
from repro.optim import adamw
from repro.train.trainer import TrainConfig, Trainer

mesh = Mesh(np.array(jax.devices()[:8]), ("data",))
cfg = get_reduced("gpt2-paper").with_(vocab_size=256)
model = build_model(cfg)

def run(sync, overlap="post", arena=False, steps=5):
    tc = TrainConfig(compressor=COMPRESSOR, interval=4, bucket_bytes=1 << 14,
                     max_buckets=32, log_every=10 ** 9, overlap=overlap,
                     arena=arena, sync=sync)
    tr = Trainer(model, adamw(3e-3), tc, mesh=mesh, dp_axes=("data",))
    state = tr.init_state(jax.random.PRNGKey(0))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8,
                    corpus_tokens=1 << 14)
    # Trainer.run: the real loop incl. the end-of-run flush of the last
    # step's deferred param all-gather
    return tr.run(state, iter(make_loader(dc)), steps=steps, log=None)

base = run("allreduce")
for overlap, arena in COMBOS:
    got = run("sharded", overlap, arena)
    # params, EF residuals AND optimizer moments: flush_sync gathers the
    # owner shards of m/v too, so the handed-back state is bitwise the
    # allreduce path's (checkpoint-portable under any sync mode)
    for x, y in zip(
        jax.tree.leaves((base["params"], base["comp"], base["opt"])),
        jax.tree.leaves((got["params"], got["comp"], got["opt"])),
    ):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    print(COMPRESSOR, overlap, "arena" if arena else "plain", "EQUAL")
"""


def _run_mesh_parity(compressor: str, combos) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    body = (
        f"COMPRESSOR = {compressor!r}\nCOMBOS = {combos!r}\n"
        + textwrap.dedent(_MESH_SUB)
    )
    r = subprocess.run(
        [sys.executable, "-c", body],
        capture_output=True, text=True, timeout=560, env=env,
    )
    assert r.returncode == 0, (
        f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    )
    return r.stdout


def test_sharded_equals_allreduce_on_cpu_mesh_covap():
    """The acceptance criterion, full grid for covap: sharded == allreduce
    bit-for-bit (params AND EF residuals) over a full phase cycle + 1 on an
    8-worker CPU mesh, post AND fused overlap, arena on AND off."""
    combos = [("post", False), ("post", True), ("fused", False),
              ("fused", True)]
    out = _run_mesh_parity("covap", combos)
    assert out.count("EQUAL") == 4


@pytest.mark.parametrize("compressor", ["none", "fp16"])
def test_sharded_equals_allreduce_on_cpu_mesh_baselines(compressor):
    """none/fp16: both overlap modes, arena exercised on the fused leg."""
    combos = [("post", False), ("fused", True)]
    out = _run_mesh_parity(compressor, combos)
    assert out.count("EQUAL") == 2


# ---------------------------------------------------------------------------
# compiled placement + bf16 promotion-guard regression
# ---------------------------------------------------------------------------

def test_compiled_placement_rs_in_backward_ag_at_head():
    """The sharded gate: the compiled fused sharded step must reduce-
    scatter before the final gradient fusion and place every deferred
    param all-gather ahead of the first reduce-scatter (the forward pass
    they overlap sits between the two)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.sharded_gate"],
        capture_output=True, text=True, timeout=560, env=env,
    )
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr[-3000:]}"
    line = next(l for l in r.stdout.splitlines() if l.startswith("SHARDED"))
    kv = dict(p.split("=") for p in line.split()[1:])
    assert kv["placed"] == "True"
    assert float(kv["exposed_ratio"]) <= 0.6


_BF16_SUB = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs import get_reduced
from repro.data import DataConfig, make_loader
from repro.models import build_model
from repro.optim import sgd
from repro.train.trainer import TrainConfig, Trainer

mesh = Mesh(np.array(jax.devices()[:8]), ("data",))
cfg = get_reduced("gpt2-paper").with_(vocab_size=256,
                                      param_dtype="bfloat16")
model = build_model(cfg)
tc = TrainConfig(compressor="covap", interval=2, bucket_bytes=1 << 14,
                 max_buckets=16, log_every=10 ** 9, sync="sharded")
tr = Trainer(model, sgd(1e-3), tc, mesh=mesh, dp_axes=("data",))
state = tr.init_state(jax.random.PRNGKey(0))
dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8)
state = tr.run(state, iter(make_loader(dc)), steps=2, log=None)
assert all(jnp.isfinite(x).all() for x in jax.tree.leaves(state["params"])
           if jnp.issubdtype(x.dtype, jnp.floating))
print("BF16 SHARDED OK")
"""


def test_bf16_params_compile_under_sharded_sync():
    """Regression for the REPRO_PSUM_PROMOTE_BF16 guard on the new
    collectives: a bf16-param arch must compile and step on the CPU
    dry-run backend under sync="sharded" (the bf16 reduce-scatter is
    promoted to f32 around the collective exactly like the pmean path;
    the param all-gather carries bf16 untouched — pure data movement)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_BF16_SUB)],
        capture_output=True, text=True, timeout=560, env=env,
    )
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr[-4000:]}"
    assert "BF16 SHARDED OK" in r.stdout


# ---------------------------------------------------------------------------
# perf-model integration
# ---------------------------------------------------------------------------

def test_simulate_schedule_defers_ag_under_forward():
    """The timeline model: a sharded schedule's AG half rides the next
    forward pass — with t_before large enough it adds NOTHING to the step,
    and the exposed comm matches the RS-only timeline; with t_before=0 the
    whole deferred volume surfaces as exposed."""
    from repro.core.perfmodel import simulate_schedule

    tree = make_tree([(64, 32), (32, 16)])
    plan = build_plan(tree, bucket_bytes=2048, max_buckets=8, interval=1)
    W, bw = 8, 1e9
    cs = get_compressor("none", sync="sharded")
    sched = cs.plan_phase(plan, 0, world=W)
    t_def = sched.deferred_wire_bytes(W) / bw
    assert t_def > 0
    covered = simulate_schedule(
        10 * t_def, 1e-3, sched, world=W, link_bw=bw
    )
    bare = simulate_schedule(0.0, 1e-3, sched, world=W, link_bw=bw)
    assert covered["deferred_comm"] == pytest.approx(t_def)
    assert bare["exposed_comm"] >= covered["exposed_comm"] + t_def * 0.99
    assert covered["comm_total"] == pytest.approx(
        sched.exposed_wire_bytes(W) / bw + t_def
    )


def test_replan_controller_exposed_scale():
    """Sharded sync halves the exposed comm, so the controller's interval
    rule applies to measured_ccr * 0.5: a CCR of 6 that would pick I=6
    under allreduce picks I=3 under sharded."""
    from repro.runtime import AutotuneConfig, ReplanController

    cfg = AutotuneConfig(patience=1, cooldown_steps=0)
    full = ReplanController(cfg, interval=1)
    half = ReplanController(cfg, interval=1, exposed_scale=0.5)
    assert full.observe(100, 6.0).interval == 6
    assert half.observe(100, 6.0).interval == 3


def test_adaptive_replan_under_sharded_sync():
    """The adaptive runtime composes with sharded sync: a synthetic probe
    forces a re-plan mid-run; the trainer flushes the pending deferred
    gather before swapping plans, the new interval's schedules stay
    sharded, and the run completes with finite params."""
    from repro.configs import get_reduced
    from repro.data import DataConfig, make_loader
    from repro.models import build_model
    from repro.optim import adamw
    from repro.runtime import AutotuneConfig, exposed_comm_scale, synthetic_probe
    from repro.train.trainer import TrainConfig, Trainer

    cfg = get_reduced("gpt2-paper").with_(vocab_size=256)
    model = build_model(cfg)
    tc = TrainConfig(compressor="covap", interval=2, bucket_bytes=1 << 14,
                     max_buckets=16, log_every=10 ** 9, sync="sharded")
    tr = Trainer(model, adamw(3e-3), tc)
    assert exposed_comm_scale(tr) == 1.0  # single worker: nothing to halve
    state = tr.init_state(jax.random.PRNGKey(0))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4,
                    corpus_tokens=1 << 13)
    ac = AutotuneConfig(
        measure_every=2, warmup_steps=1, window=1, patience=1,
        cooldown_steps=0, probe=synthetic_probe(0.01, 6.0),
    )
    state = tr.run(state, iter(make_loader(dc)), steps=10, log=None,
                   autotune=ac)
    assert tr.runtime.controller.replans >= 1
    assert tr.tc.sync == "sharded"
    assert tr.compressor.sync_mode == "sharded"
    assert all(s.sync == "sharded" for s in tr.schedules())
    assert all(
        bool(jnp.isfinite(x).all())
        for x in jax.tree.leaves(state["params"])
    )
