"""Paged KV arena: layout planning, page-pool invariants, gather/scatter
round-trips, and the isolation properties continuous batching relies on
(unrelated slots' pages untouched; slot reuse cannot leak stale state)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.serve import KVArena, PagePool, gather_caches, plan_kv_layout, scatter_step
from repro.serve.kv_arena import build_insert_fn

# synthetic cache families: stacked attn-style (paged), recurrent state
# (resident), and an int8 leaf (second plane) — same structural variety as
# the real models, without a model build
def spec_fn(batch, max_len):
    f32, i8 = jnp.float32, jnp.int8
    S = jax.ShapeDtypeStruct
    return {
        "blocks": {
            "k": S((2, batch, max_len, 3, 4), f32),
            "v": S((2, batch, max_len, 3, 4), f32),
            "k8": S((batch, max_len, 6), i8),
        },
        "state": {
            "h": S((batch, 5, 7), f32),
            "conv": S((batch, 4), f32),
        },
    }


PS = 4          # page_size
MAXLEN = 16     # -> 4 pages per slot


@pytest.fixture(scope="module")
def layout():
    return plan_kv_layout(spec_fn, MAXLEN, PS)


def _rand_caches(rng, tokens):
    specs = spec_fn(1, tokens)
    return jax.tree.map(
        lambda s: jnp.asarray(
            rng.integers(-3, 4, size=s.shape).astype(s.dtype)
        ),
        specs,
    )


def _slot_view(layout, caches, slot):
    """Per-slot (batch axis dropped) leaves of a gathered batched cache."""
    vals = jax.tree_util.tree_leaves(caches)
    return [
        np.asarray(jnp.moveaxis(v, lf.batch_axis, 0)[slot])
        for lf, v in zip(layout.leaves, vals)
    ]


# ---------------------------------------------------------------------------
# layout planning
# ---------------------------------------------------------------------------


def test_layout_classification(layout):
    by_name = {l.name: l for l in layout.leaves}
    assert by_name["blocks/k"].paged and by_name["blocks/k"].time_axis == 1
    assert by_name["blocks/k8"].paged and by_name["blocks/k8"].time_axis == 0
    assert not by_name["state/h"].paged
    assert not by_name["state/conv"].paged
    assert layout.plane_dtypes == ("float32", "int8")
    assert layout.tokens == MAXLEN and layout.pages_per_slot == 4
    # f32 token page: two (2,ps,3,4) chunks = 192 elems; resident 35+4=39
    assert layout.plane_elems[0] == max(2 * 2 * PS * 3 * 4, 5 * 7 + 4)
    assert layout.plane_elems[1] == PS * 6
    # offsets are sequential and non-overlapping within each role
    # (flatten order sorts dict keys: k < v, conv < h)
    assert by_name["blocks/v"].offset == by_name["blocks/k"].numel
    assert by_name["state/h"].offset == by_name["state/conv"].numel


def test_layout_rounds_max_len_up():
    lay = plan_kv_layout(spec_fn, 13, PS)
    assert lay.tokens == 16 and lay.pages_per_slot == 4


def test_layout_real_models():
    """Classification on real cache_specs: attention KV pages, recurrent
    state stays resident, hybrids mix, rolling windows saturate to
    resident."""
    from repro.configs import get_reduced
    from repro.models import build_model

    def fams(arch, **kw):
        m = build_model(get_reduced(arch).with_(**kw))
        lay = plan_kv_layout(m.cache_specs, 64, 16)
        return (sum(l.paged for l in lay.leaves),
                sum(not l.paged for l in lay.leaves), lay)

    p, r, _ = fams("gpt2-paper")
    assert p > 0 and r == 0
    p, r, _ = fams("xlstm-125m")
    assert p == 0 and r > 0
    p, r, _ = fams("zamba2-2.7b")
    assert p > 0 and r > 0
    # gemma2 alternates local(window=16)/global: window caches saturate
    p, r, _ = fams("gemma2-27b")
    assert p > 0 and r > 0
    # int8 KV adds planes (int8 payload + scale dtype)
    _, _, lay = fams("gpt2-paper", kv_cache_dtype="int8")
    assert "int8" in lay.plane_dtypes and len(lay.plane_dtypes) >= 2


# ---------------------------------------------------------------------------
# page pool
# ---------------------------------------------------------------------------


@settings(max_examples=30)
@given(ops=st.lists(st.tuples(st.integers(0, 1), st.integers(0, 6)),
                    min_size=1, max_size=40))
def test_page_pool_invariants(ops):
    pool = PagePool(8)
    held: list[list[int]] = []
    for kind, n in ops:
        if kind == 0:
            before = pool.available
            got = pool.alloc(n)
            if n > before:
                assert got is None and pool.available == before
            else:
                assert got is not None and len(got) == n
                held.append(got)
        elif held:
            pool.free(held.pop(n % len(held)))
        # invariants: no page is both free and held, accounting exact
        out = [p for h in held for p in h]
        assert len(out) == len(set(out)), "double allocation"
        assert pool.available + len(out) == 8
        assert set(out).isdisjoint(set(pool._free))


def test_page_pool_rejects_double_free():
    pool = PagePool(4)
    pages = pool.alloc(2)
    pool.free(pages)
    with pytest.raises(ValueError):
        pool.free(pages)


# ---------------------------------------------------------------------------
# gather / insert / scatter round-trips
# ---------------------------------------------------------------------------


def test_insert_gather_round_trip(layout):
    rng = np.random.default_rng(0)
    arena = KVArena(layout, num_pages=16, num_slots=3)
    insert = build_insert_fn(layout)
    src = {}
    for slot in (0, 2):
        assert arena.acquire_slot(slot, MAXLEN)  # all pages
        src[slot] = _rand_caches(rng, layout.tokens)
        ids, rid = arena.insert_ids(slot)
        arena.planes = insert(arena.planes, src[slot], ids, rid)

    pt, rt = arena.device_tables()
    got = gather_caches(layout, arena.planes, pt, rt)
    for slot in (0, 2):
        want = _slot_view(layout, src[slot], 0)
        have = _slot_view(layout, got, slot)
        for lf, w, h in zip(layout.leaves, want, have):
            np.testing.assert_array_equal(w, h, err_msg=lf.name)
    # slot 1 was never allocated: gathers exact zeros
    for lf, h in zip(layout.leaves, _slot_view(layout, got, 1)):
        assert not np.any(h), lf.name


def test_partial_pages_gather_zero_tail(layout):
    """A request holding ceil(L/ps) pages gathers its own rows and exact
    zeros beyond its last page — unallocated table entries never alias
    another request's pages."""
    rng = np.random.default_rng(1)
    arena = KVArena(layout, num_pages=16, num_slots=2)
    insert = build_insert_fn(layout)
    L = 6  # -> 2 of 4 pages
    assert arena.acquire_slot(0, L)
    src = _rand_caches(rng, layout.tokens)
    ids, rid = arena.insert_ids(0)
    arena.planes = insert(arena.planes, src, ids, rid)

    pt, rt = arena.device_tables()
    got = gather_caches(layout, arena.planes, pt, rt)
    want = _slot_view(layout, src, 0)
    have = _slot_view(layout, got, 0)
    n_rows = 2 * PS
    for lf, w, h in zip(layout.leaves, want, have):
        if lf.paged:
            w = np.moveaxis(w, lf.time_axis, 0)
            h = np.moveaxis(h, lf.time_axis, 0)
            np.testing.assert_array_equal(w[:n_rows], h[:n_rows], err_msg=lf.name)
            assert not np.any(h[n_rows:]), lf.name
        else:
            np.testing.assert_array_equal(w, h, err_msg=lf.name)


def test_scatter_step_writes_one_row_and_residents(layout):
    rng = np.random.default_rng(2)
    arena = KVArena(layout, num_pages=16, num_slots=2)
    assert arena.acquire_slot(0, MAXLEN)
    pos_val = 9
    caches = _rand_caches(rng, layout.tokens)
    # batch the per-slot cache up to 2 slots (slot 1 inactive)
    batched = jax.tree_util.tree_unflatten(layout.treedef, [
        jnp.concatenate([v, jnp.zeros_like(v)], axis=lf.batch_axis)
        for lf, v in zip(layout.leaves, jax.tree_util.tree_leaves(caches))
    ])
    pt, rt = arena.device_tables()
    pos = jnp.asarray([pos_val, 0], jnp.int32)
    arena.planes = scatter_step(layout, arena.planes, pt, rt, batched, pos)

    got = gather_caches(layout, arena.planes, pt, rt)
    want = _slot_view(layout, caches, 0)
    have = _slot_view(layout, got, 0)
    for lf, w, h in zip(layout.leaves, want, have):
        if lf.paged:
            w = np.moveaxis(w, lf.time_axis, 0)
            h = np.moveaxis(h, lf.time_axis, 0)
            np.testing.assert_array_equal(w[pos_val], h[pos_val], err_msg=lf.name)
            mask = np.ones(layout.tokens, bool)
            mask[pos_val] = False
            assert not np.any(h[mask]), f"{lf.name}: wrote outside pos row"
        else:
            np.testing.assert_array_equal(w, h, err_msg=lf.name)
    # slot 1 had null tables: nothing written anywhere for it
    for lf, h in zip(layout.leaves, _slot_view(layout, got, 1)):
        assert not np.any(h), lf.name


# ---------------------------------------------------------------------------
# isolation properties (the continuous-batching contract)
# ---------------------------------------------------------------------------


@settings(max_examples=8)
@given(seed=st.integers(0, 10_000),
       n_ops=st.integers(2, 6))
def test_allocate_free_reuse_leaves_unrelated_slots_untouched(seed, n_ops):
    """Random allocate/insert/free churn on other slots must not perturb a
    live slot's gathered cache — bit-for-bit."""
    layout = plan_kv_layout(spec_fn, MAXLEN, PS)
    rng = np.random.default_rng(seed)
    arena = KVArena(layout, num_pages=12, num_slots=3)
    insert = build_insert_fn(layout)

    # pin slot 0 with known content
    assert arena.acquire_slot(0, 5)
    pinned = _rand_caches(rng, layout.tokens)
    ids, rid = arena.insert_ids(0)
    arena.planes = insert(arena.planes, pinned, ids, rid)
    pt, rt = arena.device_tables()
    baseline = _slot_view(
        layout, gather_caches(layout, arena.planes, pt, rt), 0
    )

    live = set()
    for _ in range(n_ops):
        slot = int(rng.integers(1, 3))
        if slot in live:
            arena.release_slot(slot)
            live.discard(slot)
        elif arena.acquire_slot(slot, int(rng.integers(1, MAXLEN + 1))):
            ids, rid = arena.insert_ids(slot)
            arena.planes = insert(
                arena.planes, _rand_caches(rng, layout.tokens), ids, rid
            )
            live.add(slot)

    pt, rt = arena.device_tables()
    after = _slot_view(layout, gather_caches(layout, arena.planes, pt, rt), 0)
    for lf, a, b in zip(layout.leaves, baseline, after):
        np.testing.assert_array_equal(a, b, err_msg=lf.name)


def test_slot_reuse_clears_stale_state(layout):
    """Insert rebuilds whole page rows from zeros: reusing a slot (and its
    recycled physical pages) for a shorter request must not expose the
    previous request's rows."""
    rng = np.random.default_rng(3)
    arena = KVArena(layout, num_pages=8, num_slots=1)
    insert = build_insert_fn(layout)

    assert arena.acquire_slot(0, MAXLEN)  # long request, all pages
    ids, rid = arena.insert_ids(0)
    arena.planes = insert(arena.planes, _rand_caches(rng, layout.tokens), ids, rid)
    arena.release_slot(0)

    def full_time_axis(lf):
        # lf.time_axis indexes the batch-stripped shape; recover the axis
        # in the full (batched) leaf
        return lf.time_axis + (1 if lf.batch_axis <= lf.time_axis else 0)

    short = _rand_caches(rng, layout.tokens)
    # zero the tail beyond the short prompt, as a real prefill would
    short = jax.tree_util.tree_unflatten(layout.treedef, [
        v if lf.time_axis is None else jnp.moveaxis(
            jnp.moveaxis(v, full_time_axis(lf), 0).at[3:].set(0),
            0, full_time_axis(lf),
        )
        for lf, v in zip(layout.leaves, jax.tree_util.tree_leaves(short))
    ])
    assert arena.acquire_slot(0, 3)  # one page
    ids, rid = arena.insert_ids(0)
    arena.planes = insert(arena.planes, short, ids, rid)

    pt, rt = arena.device_tables()
    got = _slot_view(layout, gather_caches(layout, arena.planes, pt, rt), 0)
    want = _slot_view(layout, short, 0)
    for lf, w, h in zip(layout.leaves, want, got):
        np.testing.assert_array_equal(w, h, err_msg=lf.name)
        if lf.paged:
            h_t = np.moveaxis(h, lf.time_axis, 0)
            assert not np.any(h_t[3:]), f"{lf.name}: stale rows visible"
