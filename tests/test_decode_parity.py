"""Prefill/decode parity: running the cache-based decode path token-by-token
must reproduce the teacher-forced (train-path) logits.  This cross-validates
the KV cache, the rolling window, the SSD chunked scan vs recurrence, and
the xLSTM scan vs cell recurrences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import build_model

# one representative per family + the windowed variant
PARITY_ARCHS = [
    "gpt2-paper",        # dense full attention
    "gemma2-27b",        # local/global alternation + softcaps
    "deepseek-moe-16b",  # MoE
    "xlstm-125m",        # mLSTM + sLSTM
    "zamba2-2.7b",       # mamba2 + shared attn block
    "seamless-m4t-medium",  # enc-dec with cross-attention
]


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_decode_matches_prefill(arch):
    cfg = get_reduced(arch)
    if cfg.num_experts > 0:
        # MoE capacity dropping is BATCH-SIZE dependent: prefill routes
        # B*S tokens competing for C = ceil(N*k/E * cf) slots per expert
        # while decode routes B tokens per call, so with a tight capacity
        # factor prefill drops assignments decode keeps (~11% of logits
        # off by O(1) at the seed's cf=1.25) — an inherent property of
        # GShard/Switch semantics, not a cache bug.  Parity is exact
        # whenever nothing is dropped, so this test pins the cache/scan
        # machinery under the drop-free capacity cf = E (worst case: all
        # N*k assignments land on one expert).
        cfg = cfg.with_(moe_capacity_factor=float(cfg.num_experts))
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    B, S = 2, 24
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": tokens}
    if cfg.family == "vlm":
        batch["patch_embeds"] = 0.02 * jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model), jnp.float32
        )
    frames = None
    if cfg.is_encdec:
        frames = 0.02 * jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model), jnp.float32
        )
        batch["frames"] = frames

    ref_logits = model.prefill(params, batch)      # (B, <=S, V) last chunk
    c = ref_logits.shape[1]

    caches = model.init_caches(B, S + 4)
    if cfg.is_encdec:
        # populate the cross-attention memory like a served request would
        from repro.models import encdec as ed
        memory = ed.encode(params["encdec"], frames, cfg)
        mks, mvs = ed.precompute_memory_kv(params["encdec"], memory, cfg)
        caches = dict(caches)
        caches["mem_k"] = mks
        caches["mem_v"] = mvs

    step = jax.jit(model.decode_step)
    got = []
    for t in range(S):
        b = {"tokens": tokens[:, t : t + 1],
             "pos": jnp.full((B,), t, jnp.int32)}
        logits, caches = step(params, caches, b)
        got.append(logits[:, 0])
    got = jnp.stack(got, axis=1)  # (B, S, V)

    np.testing.assert_allclose(
        np.asarray(got[:, -c:]), np.asarray(ref_logits), rtol=2e-2, atol=2e-2
    )


def test_int8_kv_cache_close_to_bf16():
    """Quantized KV cache (SSPerf memory lever) must track the fp cache."""
    cfg = get_reduced("gpt2-paper")
    m_ref = build_model(cfg)
    m_q = build_model(cfg.with_(kv_cache_dtype="int8"))
    key = jax.random.PRNGKey(0)
    params = m_ref.init(key)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)

    def decode_all(model):
        caches = model.init_caches(B, 32)
        outs = []
        step = jax.jit(model.decode_step)
        for t in range(S):
            b = {"tokens": toks[:, t : t + 1],
                 "pos": jnp.full((B,), t, jnp.int32)}
            lo, caches = step(params, caches, b)
            outs.append(lo[:, 0])
        return jnp.stack(outs, 1)

    err = float(jnp.max(jnp.abs(decode_all(m_q) - decode_all(m_ref))))
    assert err < 0.2, err
