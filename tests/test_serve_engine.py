"""Serving engine: batching invariance, slot reuse, determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import build_model
from repro.serve import Engine, ServeConfig


@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_reduced("gpt2-paper").with_(vocab_size=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(42))
    return model, params


def test_single_request_greedy(model_and_params):
    model, params = model_and_params
    eng = Engine(model, params, ServeConfig(batch_slots=2, max_len=64,
                                            max_new_tokens=8))
    rid = eng.submit([5, 17, 3])
    results = eng.run_until_done()
    assert rid in results
    assert len(results[rid]) == 8
    assert all(0 <= t < 128 for t in results[rid])


def test_batching_invariance(model_and_params):
    """A request's output must not depend on batch neighbours."""
    model, params = model_and_params
    prompt = [5, 17, 3, 9]

    eng1 = Engine(model, params, ServeConfig(batch_slots=1, max_len=64,
                                             max_new_tokens=6))
    r1 = eng1.submit(prompt)
    out1 = eng1.run_until_done()[r1]

    eng2 = Engine(model, params, ServeConfig(batch_slots=3, max_len=64,
                                             max_new_tokens=6))
    r2 = eng2.submit(prompt)
    eng2.submit([88, 2])
    eng2.submit([1, 1, 1, 1, 1])
    out2 = eng2.run_until_done()[r2]
    assert out1 == out2


def test_slot_reuse_does_not_leak_state(model_and_params):
    model, params = model_and_params
    prompt = [7, 7, 7]
    eng = Engine(model, params, ServeConfig(batch_slots=1, max_len=64,
                                            max_new_tokens=5))
    ra = eng.submit(prompt)
    rb = eng.submit(prompt)  # will reuse slot 0 after ra finishes
    res = eng.run_until_done()
    assert res[ra] == res[rb]


def test_many_requests_complete(model_and_params):
    model, params = model_and_params
    eng = Engine(model, params, ServeConfig(batch_slots=3, max_len=64,
                                            max_new_tokens=4))
    rids = [eng.submit([i + 1, i + 2]) for i in range(7)]
    res = eng.run_until_done()
    assert set(rids) <= set(res)
    assert all(len(res[r]) == 4 for r in rids)
