"""Serving engine: batching invariance, slot reuse, finish reasons,
chunked-prefill call counting, determinism."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import build_model
from repro.serve import Engine, ServeConfig


@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_reduced("gpt2-paper").with_(vocab_size=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(42))
    return model, params


def test_single_request_greedy(model_and_params):
    model, params = model_and_params
    eng = Engine(model, params, ServeConfig(batch_slots=2, max_len=64,
                                            max_new_tokens=8))
    rid = eng.submit([5, 17, 3])
    results = eng.run_until_done()
    assert rid in results
    comp = results[rid]
    assert len(comp.tokens) == 8
    assert comp.finish_reason == "length"
    assert all(0 <= t < 128 for t in comp.tokens)
    assert comp.finish_s >= comp.first_token_s >= comp.submit_s


def test_batching_invariance(model_and_params):
    """A request's output must not depend on batch neighbours."""
    model, params = model_and_params
    prompt = [5, 17, 3, 9]

    eng1 = Engine(model, params, ServeConfig(batch_slots=1, max_len=64,
                                             max_new_tokens=6))
    r1 = eng1.submit(prompt)
    out1 = eng1.run_until_done()[r1].tokens

    eng2 = Engine(model, params, ServeConfig(batch_slots=3, max_len=64,
                                             max_new_tokens=6))
    r2 = eng2.submit(prompt)
    eng2.submit([88, 2])
    eng2.submit([1, 1, 1, 1, 1])
    out2 = eng2.run_until_done()[r2].tokens
    assert out1 == out2


def test_slot_reuse_does_not_leak_state(model_and_params):
    model, params = model_and_params
    prompt = [7, 7, 7]
    eng = Engine(model, params, ServeConfig(batch_slots=1, max_len=64,
                                            max_new_tokens=5))
    ra = eng.submit(prompt)
    rb = eng.submit(prompt)  # will reuse slot 0 (and recycled pages)
    res = eng.run_until_done()
    assert res[ra].tokens == res[rb].tokens


def test_many_requests_complete(model_and_params):
    model, params = model_and_params
    eng = Engine(model, params, ServeConfig(batch_slots=3, max_len=64,
                                            max_new_tokens=4))
    rids = [eng.submit([i + 1, i + 2]) for i in range(7)]
    res = eng.run_until_done()
    assert set(rids) <= set(res)
    assert all(len(res[r].tokens) == 4 for r in rids)
    assert all(res[r].finish_reason == "length" for r in rids)


# ---------------------------------------------------------------------------
# finish reasons (the old engine silently truncated at max_len-1)
# ---------------------------------------------------------------------------


def test_finish_reason_eos(model_and_params):
    model, params = model_and_params
    prompt = [5, 17, 3]
    # learn what greedy produces, then rerun with that token as eos
    eng = Engine(model, params, ServeConfig(batch_slots=1, max_len=64,
                                            max_new_tokens=4))
    r = eng.submit(prompt)
    first = eng.run_until_done()[r].tokens[0]

    eng2 = Engine(model, params, ServeConfig(batch_slots=1, max_len=64,
                                             max_new_tokens=4,
                                             eos_token=first))
    r2 = eng2.submit(prompt)
    comp = eng2.run_until_done()[r2]
    assert comp.finish_reason == "eos"
    assert comp.tokens == [first]


def test_finish_reason_truncated_at_context(model_and_params):
    """Context fills before max_new_tokens: the completion must say so
    instead of masquerading as a normal finish."""
    model, params = model_and_params
    prompt = [5, 17, 3, 9]
    eng = Engine(model, params, ServeConfig(batch_slots=1, max_len=8,
                                            max_new_tokens=32, page_size=4))
    r = eng.submit(prompt)
    comp = eng.run_until_done()[r]
    assert comp.finish_reason == "truncated"
    # positions 0..7 all consumed (prompt at 0-3, generated fed at 4-7);
    # the final position's logits still yield one last token
    assert len(comp.tokens) == 8 - len(prompt) + 1


def test_finish_reason_truncated_prompt_too_long(model_and_params):
    model, params = model_and_params
    eng = Engine(model, params, ServeConfig(batch_slots=1, max_len=8,
                                            max_new_tokens=4, page_size=4))
    r = eng.submit(list(range(1, 13)))  # 12 > max_len-1
    comp = eng.run_until_done()[r]
    assert comp.finish_reason == "truncated"
    assert comp.tokens == []


def test_finish_reason_truncated_on_page_exhaustion(model_and_params):
    """An explicitly undersized page pool must truncate loudly, not wedge
    or corrupt neighbours."""
    model, params = model_and_params
    eng = Engine(model, params, ServeConfig(batch_slots=2, max_len=64,
                                            max_new_tokens=40, page_size=8,
                                            num_pages=3))
    ra = eng.submit([1, 2, 3])   # 1 page now, more as it generates
    rb = eng.submit([4, 5, 6])
    res = eng.run_until_done()
    assert res[ra].finish_reason == "truncated"
    assert res[rb].finish_reason == "truncated"
    assert len(res[ra].tokens) > 0


# ---------------------------------------------------------------------------
# chunked prefill: O(L/chunk) compiled calls, not O(L)
# ---------------------------------------------------------------------------


def test_prefill_call_count(model_and_params):
    model, params = model_and_params
    L, chunk = 11, 4
    eng = Engine(model, params, ServeConfig(batch_slots=1, max_len=64,
                                            max_new_tokens=2,
                                            prefill_chunk=chunk))
    eng.submit(list(range(1, L + 1)))
    eng.run_until_done()
    assert eng.stats["prefill_tokens"] == L
    assert eng.stats["prefill_calls"] == math.ceil(L / chunk)  # 3, not 11


def test_prefill_chunk_size_does_not_change_output(model_and_params):
    model, params = model_and_params
    prompt = list(range(1, 14))
    outs = []
    for chunk in (1, 5, 16):
        eng = Engine(model, params, ServeConfig(batch_slots=1, max_len=64,
                                                max_new_tokens=5,
                                                prefill_chunk=chunk))
        r = eng.submit(prompt)
        outs.append(eng.run_until_done()[r].tokens)
    assert outs[0] == outs[1] == outs[2]


# ---------------------------------------------------------------------------
# misc engine surface
# ---------------------------------------------------------------------------


def test_engine_reset_reuses_compilations(model_and_params):
    model, params = model_and_params
    eng = Engine(model, params, ServeConfig(batch_slots=2, max_len=64,
                                            max_new_tokens=4))
    r1 = eng.submit([5, 17, 3])
    out1 = eng.run_until_done()[r1].tokens
    eng.reset()
    assert not eng.busy and eng.results == {}
    r2 = eng.submit([5, 17, 3])
    out2 = eng.run_until_done()[r2].tokens
    assert out1 == out2


def test_stage_metrics_populated(model_and_params):
    model, params = model_and_params
    eng = Engine(model, params, ServeConfig(batch_slots=2, max_len=64,
                                            max_new_tokens=4))
    eng.submit([5, 17, 3])
    eng.run_until_done()
    m = eng.metrics()
    assert m["prefill_tok_us"] > 0
    assert m["generate_tok_us"] > 0
    assert m["insert_us"] > 0


def test_int8_kv_engine(model_and_params):
    """Quantized KV serves out of multi-dtype planes (int8 payload + fp
    scales) with the same batching-invariance contract."""
    cfg = get_reduced("gpt2-paper").with_(vocab_size=128,
                                          kv_cache_dtype="int8")
    model = build_model(cfg)
    params = model_and_params[1]
    sc = dict(max_len=64, max_new_tokens=4)
    e1 = Engine(model, params, ServeConfig(batch_slots=1, **sc))
    r1 = e1.submit([5, 17, 3, 9])
    out1 = e1.run_until_done()[r1].tokens
    e2 = Engine(model, params, ServeConfig(batch_slots=2, **sc))
    r2 = e2.submit([5, 17, 3, 9])
    e2.submit([88, 2])
    out2 = e2.run_until_done()[r2].tokens
    assert out1 == out2
    assert len(e2.layout.plane_dtypes) >= 2


# ---------------------------------------------------------------------------
# overload: load shedding keeps the engine honest past capacity
# (DESIGN.md §16 — rejected is terminal, retryable, and never silent)
# ---------------------------------------------------------------------------


def test_overload_door_shedding_no_request_lost(model_and_params):
    """A burst past ``max_queue`` sheds at the door: every rid still
    resolves, shed requests are ``rejected`` (zero tokens), and admitted
    ones run to completion untouched."""
    model, params = model_and_params
    eng = Engine(model, params, ServeConfig(batch_slots=2, max_len=32,
                                            max_new_tokens=4, max_queue=2))
    rids = [eng.submit([i + 1, i + 2, i + 3]) for i in range(8)]
    res = eng.run_until_done()
    assert set(rids) == set(res)                       # nothing lost
    reasons = [res[r].finish_reason for r in rids]
    # the burst lands before any engine tick, so exactly max_queue survive
    # the door; the rest shed immediately
    assert reasons.count("rejected") == 8 - 2
    for r in rids:
        comp = res[r]
        if comp.finish_reason == "rejected":
            assert comp.tokens == []                   # safe to retry
            assert comp.finish_s >= comp.submit_s
        else:
            assert comp.finish_reason == "length"
            assert len(comp.tokens) == 4


def test_overload_starvation_shedding(model_and_params):
    """With every page held (resilience ``page_starve`` fault) a queued
    request must be shed after ``starve_patience`` ticks instead of
    wedging the engine forever."""
    from repro.resilience import release_pages, starve_pages

    model, params = model_and_params
    eng = Engine(model, params, ServeConfig(batch_slots=2, max_len=32,
                                            max_new_tokens=4, page_size=8,
                                            starve_patience=3))
    held = starve_pages(eng.arena.pool)
    assert eng.arena.pool.available == 0
    rid = eng.submit([1, 2, 3])
    res = eng.run_until_done()
    assert res[rid].finish_reason == "rejected"
    assert eng.stats["starved_shed"] >= 1
    # end the fault: the engine serves normally again
    release_pages(eng.arena.pool, held)
    rid2 = eng.submit([1, 2, 3])
    res = eng.run_until_done()
    assert res[rid2].finish_reason == "length"


def test_overload_qps_sweep_p99_of_admitted_bounded(model_and_params):
    """QPS sweep past capacity: shedding converts overload into
    ``rejected`` completions (never bogus ``length`` ones), loses no
    request, and keeps the p99 latency of ADMITTED requests bounded by a
    fat multiple of the isolated per-request service time — instead of
    growing with the backlog as an unbounded queue would."""
    from repro.serve import TrafficConfig, sweep

    model, params = model_and_params
    eng = Engine(model, params, ServeConfig(batch_slots=2, max_len=32,
                                            max_new_tokens=4, page_size=8,
                                            max_queue=2))
    # isolated service time (compile already warm from other tests; one
    # more warm-up request makes this robust when run standalone)
    eng.submit([1, 2, 3])
    eng.run_until_done()
    eng.reset()
    import time as _time
    t0 = _time.perf_counter()
    eng.submit([1, 2, 3])
    eng.run_until_done()
    service_s = _time.perf_counter() - t0
    eng.reset()

    base = TrafficConfig(num_requests=16, prompt_len=(3, 6), vocab_size=128,
                         seed=7)
    reports = sweep(eng, [20.0, 2000.0], base)
    shed_total = 0
    for rep in reports:
        assert sum(rep.finish_reasons.values()) == 16   # nothing lost
        shed_total += rep.finish_reasons.get("rejected", 0)
        assert rep.finish_reasons.get("truncated", 0) == 0
    # far past capacity the door must actually shed
    assert reports[-1].finish_reasons.get("rejected", 0) > 0
    assert shed_total < 2 * 16                          # not shedding everyone

    # p99 of ADMITTED requests: bounded queue => bounded wait.  Recompute
    # from the engine's ledger of the final (overloaded) rate.
    admitted = [c for c in eng.results.values()
                if c.finish_reason != "rejected"]
    assert admitted
    lat = sorted(c.latency_s for c in admitted)
    p99 = lat[min(len(lat) - 1, int(0.99 * len(lat)))]
    # <= (queue + slots) requests ahead, 2 slots wide, fat 25x margin for
    # CI timer noise
    assert p99 < 25.0 * max(service_s, 1e-3) * (2 + 2), (p99, service_s)


def test_overload_retry_with_backoff_resolves(model_and_params):
    """The client half: rejected submissions retried with backoff all
    reach a terminal state, retries are counted, and latency is measured
    from the ORIGINAL arrival (retried requests pay their wait)."""
    from repro.serve import TrafficConfig, run_traffic

    model, params = model_and_params
    eng = Engine(model, params, ServeConfig(batch_slots=2, max_len=32,
                                            max_new_tokens=4, max_queue=2))
    cfg = TrafficConfig(qps=500.0, num_requests=12, prompt_len=(3, 6),
                        vocab_size=128, seed=3, max_retries=4,
                        retry_backoff_s=0.01)
    rep = run_traffic(eng, cfg)
    assert sum(rep.finish_reasons.values()) == 12
    assert rep.retries > 0
    # with a generous retry budget at this scale everyone eventually runs
    assert rep.finish_reasons.get("length", 0) >= 10
