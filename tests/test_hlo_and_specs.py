"""HLO collective parsing + tensor-parallel param-spec rules + the
plan/execute byte contract: every compressor's static
``CommSchedule.bytes_per_worker`` must equal both the executed
``SyncStats.bytes_per_worker`` and the collective bytes parsed from the
compiled HLO."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_reduced, list_archs
from repro.core import build_plan, get_compressor
from repro.core.compressors import available
from repro.launch.hlo_analysis import (
    collective_bytes_per_worker,
    collective_summary,
    parse_collectives,
    roofline_terms,
)
from repro.models import build_model, build_param_specs

FAKE_HLO = """
HloModule test
ENTRY main {
  %p0 = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%p0), replica_groups={}, to_apply=%add
  %ag = (f32[256]{0}, f32[256]{0}) all-gather-start(%p0), dimensions={0}
  %agd = f32[2048]{0} all-gather-done(%ag)
  %a2a = bf16[64,32]{1,0} all-to-all(%p0), dimensions={0}
  %cp = f32[16]{0} collective-permute(%p0), source_target_pairs={{0,1}}
  %rs = f32[128]{0} reduce-scatter(%p0), dimensions={0}, to_apply=%add
}
"""


def test_parse_collectives_counts_and_bytes():
    ops = parse_collectives(FAKE_HLO)
    kinds = sorted(o.kind for o in ops)
    assert kinds == sorted([
        "all-reduce", "all-gather", "all-to-all", "collective-permute",
        "reduce-scatter",
    ])
    by = {o.kind: o.result_bytes for o in ops}
    assert by["all-reduce"] == 4096
    assert by["all-gather"] == 2048  # start tuple counted once, done skipped
    assert by["all-to-all"] == 64 * 32 * 2
    assert by["reduce-scatter"] == 512


def test_collective_summary_wire_factor():
    s = collective_summary(FAKE_HLO)
    raw = s["buffer_bytes"]
    assert s["wire_bytes_est"] == raw + 4096  # all-reduce double-counted


def test_roofline_terms_dominance():
    t = roofline_terms(flops_per_device=197e12, hbm_bytes_per_device=0,
                       wire_bytes_per_device=0)
    assert t.dominant == "compute" and abs(t.compute_s - 1.0) < 1e-9
    t = roofline_terms(flops_per_device=0, hbm_bytes_per_device=819e9,
                       wire_bytes_per_device=100)
    assert t.dominant == "memory"


def test_parse_collectives_fp8_dtypes():
    hlo = """
    HloModule fp8
    ENTRY main {
      %q = f8e4m3fn[8,4096]{1,0} all-gather(%p0), dimensions={0}
      %s = f32[8,1]{1,0} all-gather(%p1), dimensions={0}
    }
    """
    ops = parse_collectives(hlo)
    by = sorted(o.result_bytes for o in ops)
    assert by == [32, 8 * 4096]  # 1 byte/elem fp8 payload + fp32 scales
    assert collective_bytes_per_worker(hlo, 8) == 4096 + 4


# ---- plan/execute byte contract ---------------------------------------------

def _tiny_setup():
    params = {
        "emb": jnp.zeros((128, 16)),
        "w1": jnp.zeros((4, 16, 32)),
        "b1": jnp.zeros((4, 32)),
    }
    plan = build_plan(params, bucket_bytes=2048, max_buckets=16, interval=4)
    key = jax.random.PRNGKey(0)
    grads = {
        k: jax.random.normal(jax.random.fold_in(key, i), v.shape)
        for i, (k, v) in enumerate(params.items())
    }
    return params, plan, grads


@pytest.mark.parametrize("name", available())
def test_schedule_bytes_match_executed_stats(name):
    """For every registered compressor and every phase: plan_phase yields
    a well-formed schedule and execute() reports its bytes.  (SyncStats is
    built *from* the schedule by construction — the independent check that
    planned bytes equal the real collectives is the HLO-parse test below.)
    """
    params, plan, grads = _tiny_setup()
    opts = {"interval": 4} if name == "covap" else {}
    comp = get_compressor(name, **opts)
    state = comp.init_state(params, plan)
    for phase in range(comp.num_phases(4)):
        sched = comp.plan_phase(plan, phase)
        assert sched.phase == phase
        assert sched.bytes_per_worker == sum(
            c.bytes_per_worker for c in sched.calls
        )
        _, _, stats = comp.execute(
            sched, grads, state, step=phase, axis_names=()
        )
        assert stats.bytes_per_worker == sched.bytes_per_worker
        assert stats.dense_bytes == sched.dense_bytes


_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

_HLO_MATCH_SUB = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.core import build_plan, get_compressor
from repro.launch.hlo_analysis import collective_bytes_per_worker
from repro.train.trainer import shard_map_compat

W = 8
mesh = Mesh(np.array(jax.devices()[:W]), ("data",))
params = {"w": jnp.zeros((64, 16)), "b": jnp.zeros((16,))}
plan = build_plan(params, bucket_bytes=512, max_buckets=8, interval=4)
key = jax.random.PRNGKey(0)
gw = {k: jax.random.normal(jax.random.fold_in(key, i), (W,) + v.shape)
      for i, (k, v) in enumerate(params.items())}

CASES = [
    ("none", {}, 0),
    ("fp16", {}, 0),
    ("covap", {"interval": 4}, 0),
    ("covap", {"interval": 4}, 1),
    ("covap", {"interval": 4, "wire_dtype": "bfloat16"}, 0),
    ("topk", {"ratio": 0.05}, 0),
    ("dgc", {"ratio": 0.05}, 0),
    ("randomk", {"ratio": 0.05}, 0),
    ("efsignsgd", {}, 0),
    ("fp8wire", {}, 0),
    ("oktopk", {"ratio": 0.05}, 0),
    ("powersgd", {"rank": 2}, 0),
]
for name, opts, phase in CASES:
    comp = get_compressor(name, **opts)
    state = comp.init_state(params, plan)
    sched = comp.plan_phase(plan, phase, world=W)

    def run(g, s):
        g = {k: v[0] for k, v in g.items()}
        out, s2, _ = comp.execute(sched, g, s, step=0, axis_names=("data",))
        return out, s2

    f = jax.jit(shard_map_compat(
        run, mesh, (P("data"), P()), (P(), P()), ("data",)))
    hlo = f.lower(gw, state).compile().as_text()
    got = collective_bytes_per_worker(hlo, W)
    # The CPU backend widens narrow wire formats inside collectives
    # (AllReducePromotion: bf16 all-reduce -> f32; fp8 all-gathers go out
    # as f16), so a planned narrow wire physically moves 2x the bytes on
    # CPU — noted in repro.core.comm._promote_bf16.  On TPU the planned
    # wire dtype goes out as-is and expected == planned exactly.
    def expected_bytes(c):
        if c.wire_dtype == "bfloat16" and c.op == "all_reduce":
            return c.payload_bytes * 2 + c.index_bytes
        if c.wire_dtype.startswith("float8") and c.op == "all_gather":
            return c.payload_bytes * 2 + c.index_bytes
        return c.bytes_per_worker

    expected = sum(expected_bytes(c) for c in sched.calls)
    assert int(got) == expected, (name, phase, int(got), expected)
    print(name, phase, "OK", int(got))
"""


def test_schedule_bytes_match_hlo_collectives():
    """The planned bytes ARE the compiled collectives: for every compressor,
    ``CommSchedule.bytes_per_worker`` equals the per-worker collective bytes
    parsed from the optimized HLO of ``execute`` under an 8-way shard_map."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_HLO_MATCH_SUB)],
        capture_output=True, text=True, timeout=560, env=env,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    assert r.stdout.count("OK") == 12


_SHARDED_HLO_SUB = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.core import build_plan, get_compressor
from repro.core.overlap import sharded_param_allgather
from repro.launch.hlo_analysis import collective_bytes_per_worker, parse_collectives
from repro.train.trainer import shard_map_compat

W = 8
mesh = Mesh(np.array(jax.devices()[:W]), ("data",))
params = {"w": jnp.zeros((64, 16)), "b": jnp.zeros((16,))}
plan = build_plan(params, bucket_bytes=512, max_buckets=8, interval=4)
key = jax.random.PRNGKey(0)
gw = {k: jax.random.normal(jax.random.fold_in(key, i), (W,) + v.shape)
      for i, (k, v) in enumerate(params.items())}

CASES = [
    ("none", {}, 0),
    ("fp16", {}, 0),
    ("covap", {"interval": 4}, 0),
    ("covap", {"interval": 4}, 1),
]
for name, opts, phase in CASES:
    comp = get_compressor(name, **opts, sync="sharded")
    state = comp.init_state(params, plan)
    sched = comp.plan_phase(plan, phase, world=W)

    # ---- the RS half: execute()'s compiled collectives ------------------
    def run(g, s):
        g = {k: v[0] for k, v in g.items()}
        out, s2, _ = comp.execute(sched, g, s, step=0, axis_names=("data",))
        return out, s2

    f = jax.jit(shard_map_compat(
        run, mesh, (P("data"), P()), (P(), P()), ("data",)))
    hlo = f.lower(gw, state).compile().as_text()
    got = collective_bytes_per_worker(hlo, W)
    kinds = {o.kind for o in parse_collectives(hlo)}
    assert kinds <= {"reduce-scatter"}, kinds
    # CPU backend promotes narrow reduction operands (the same
    # AllReducePromotion note as the all-reduce cases): a planned bf16
    # reduce-scatter physically moves f32 on the dry-run backend
    def expected_bytes(c):
        if c.wire_dtype == "bfloat16" and c.op == "reduce_scatter":
            return c.payload_bytes * 2 + c.index_bytes
        return c.bytes_per_worker

    expected = sum(expected_bytes(c) for c in sched.calls)
    assert int(got) == expected, (name, phase, int(got), expected)

    # ---- the AG half: the head/flush program's compiled collectives -----
    def head(p):
        return sharded_param_allgather(comp, sched, p, axis_names=("data",))

    fh = jax.jit(shard_map_compat(head, mesh, (P(),), P(), ("data",)))
    hlo_h = fh.lower(params).compile().as_text()
    got_h = collective_bytes_per_worker(hlo_h, W)
    kinds_h = {o.kind for o in parse_collectives(hlo_h)}
    assert kinds_h <= {"all-gather"}, kinds_h
    expected_h = sum(c.bytes_per_worker for c in sched.deferred_calls)
    assert int(got_h) == expected_h, (name, phase, int(got_h), expected_h)
    print(name, phase, "SHARDED-OK", int(got), int(got_h))
"""


def test_sharded_schedule_bytes_match_hlo_collectives():
    """Sharded sync's two halves cross-checked against compiled HLO: the
    RS bytes of ``execute`` equal ``schedule.bytes_per_worker`` and the AG
    bytes of the head/flush program equal
    ``schedule.deferred_bytes_per_worker`` — per-worker-normalised by the
    reduce-scatter/all-gather rules of ``collective_bytes_per_worker``."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_SHARDED_HLO_SUB)],
        capture_output=True, text=True, timeout=560, env=env,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    assert r.stdout.count("SHARDED-OK") == 4


# ---- param specs -------------------------------------------------------------

@pytest.mark.parametrize("arch", list_archs())
def test_param_specs_cover_all_leaves(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = build_param_specs(cfg, model.init, 2, "model")
    s_leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    p_leaves = jax.tree.leaves(shapes)
    assert len(s_leaves) == len(p_leaves)
    for spec, leaf in zip(s_leaves, p_leaves):
        assert isinstance(spec, P)
        # divisibility respected
        for ax, name in enumerate(spec):
            if name is not None and ax < len(leaf.shape):
                assert leaf.shape[ax] % 2 == 0


def test_param_specs_shard_big_matrices_full_config():
    cfg = get_config("mistral-large-123b")
    model = build_model(cfg)
    specs = build_param_specs(cfg, model.init, 16, "model")
    flat = {
        "/".join(str(getattr(k, "key", k)) for k in path): s
        for path, s in jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, P)
        )[0]
    }
    sharded = [k for k, s in flat.items() if any(a is not None for a in s)]
    assert any("wq" in k for k in sharded)
    assert any("w_down" in k for k in sharded)
    assert any("head" in k for k in sharded)


def test_moe_expert_parallel_spec():
    cfg = get_config("deepseek-moe-16b")  # 64 experts % 16 == 0
    model = build_model(cfg)
    specs = build_param_specs(cfg, model.init, 16, "model")
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P)
    )[0]
    moe_specs = [
        s for p, s in flat
        if "moe" in (jp := "/".join(str(getattr(k, "key", k)) for k in p))
        and "w_gate" in jp and "shared" not in jp
    ]
    assert moe_specs, "expected MoE expert leaves"
    for s in moe_specs:
        # stacked (n_super, E, d, ff): expert axis sharded
        assert s[-3] == "model"
