"""HLO collective parsing + tensor-parallel param-spec rules."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_reduced, list_archs
from repro.launch.hlo_analysis import (
    collective_summary,
    parse_collectives,
    roofline_terms,
)
from repro.models import build_model, build_param_specs

FAKE_HLO = """
HloModule test
ENTRY main {
  %p0 = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%p0), replica_groups={}, to_apply=%add
  %ag = (f32[256]{0}, f32[256]{0}) all-gather-start(%p0), dimensions={0}
  %agd = f32[2048]{0} all-gather-done(%ag)
  %a2a = bf16[64,32]{1,0} all-to-all(%p0), dimensions={0}
  %cp = f32[16]{0} collective-permute(%p0), source_target_pairs={{0,1}}
  %rs = f32[128]{0} reduce-scatter(%p0), dimensions={0}, to_apply=%add
}
"""


def test_parse_collectives_counts_and_bytes():
    ops = parse_collectives(FAKE_HLO)
    kinds = sorted(o.kind for o in ops)
    assert kinds == sorted([
        "all-reduce", "all-gather", "all-to-all", "collective-permute",
        "reduce-scatter",
    ])
    by = {o.kind: o.result_bytes for o in ops}
    assert by["all-reduce"] == 4096
    assert by["all-gather"] == 2048  # start tuple counted once, done skipped
    assert by["all-to-all"] == 64 * 32 * 2
    assert by["reduce-scatter"] == 512


def test_collective_summary_wire_factor():
    s = collective_summary(FAKE_HLO)
    raw = s["buffer_bytes"]
    assert s["wire_bytes_est"] == raw + 4096  # all-reduce double-counted


def test_roofline_terms_dominance():
    t = roofline_terms(flops_per_device=197e12, hbm_bytes_per_device=0,
                       wire_bytes_per_device=0)
    assert t.dominant == "compute" and abs(t.compute_s - 1.0) < 1e-9
    t = roofline_terms(flops_per_device=0, hbm_bytes_per_device=819e9,
                       wire_bytes_per_device=100)
    assert t.dominant == "memory"


# ---- param specs -------------------------------------------------------------

@pytest.mark.parametrize("arch", list_archs())
def test_param_specs_cover_all_leaves(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = build_param_specs(cfg, model.init, 2, "model")
    s_leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    p_leaves = jax.tree.leaves(shapes)
    assert len(s_leaves) == len(p_leaves)
    for spec, leaf in zip(s_leaves, p_leaves):
        assert isinstance(spec, P)
        # divisibility respected
        for ax, name in enumerate(spec):
            if name is not None and ax < len(leaf.shape):
                assert leaf.shape[ax] % 2 == 0


def test_param_specs_shard_big_matrices_full_config():
    cfg = get_config("mistral-large-123b")
    model = build_model(cfg)
    specs = build_param_specs(cfg, model.init, 16, "model")
    flat = {
        "/".join(str(getattr(k, "key", k)) for k in path): s
        for path, s in jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, P)
        )[0]
    }
    sharded = [k for k, s in flat.items() if any(a is not None for a in s)]
    assert any("wq" in k for k in sharded)
    assert any("w_down" in k for k in sharded)
    assert any("head" in k for k in sharded)


def test_moe_expert_parallel_spec():
    cfg = get_config("deepseek-moe-16b")  # 64 experts % 16 == 0
    model = build_model(cfg)
    specs = build_param_specs(cfg, model.init, 16, "model")
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P)
    )[0]
    moe_specs = [
        s for p, s in flat
        if "moe" in (jp := "/".join(str(getattr(k, "key", k)) for k in p))
        and "w_gate" in jp and "shared" not in jp
    ]
    assert moe_specs, "expected MoE expert leaves"
    for s in moe_specs:
        # stacked (n_super, E, d, ff): expert axis sharded
        assert s[-3] == "model"
