"""Error-feedback invariants (paper SS III.D, Algorithm 1)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import build_plan, get_compressor
from repro.core.error_feedback import EFSchedule


def test_coefficient_schedule_matches_paper_formula():
    s = EFSchedule(init_value=0.3, ascend_steps=200, ascend_range=0.1)
    for step in [0, 1, 199, 200, 399, 400, 1399, 1400, 10_000]:
        expected = min(0.3 + (step // 200) * 0.1, 1.0)
        assert abs(float(s.coefficient(step)) - expected) < 1e-6


def test_coefficient_caps_at_one():
    s = EFSchedule(0.5, 10, 0.25)
    assert float(s.coefficient(10_000)) == 1.0


@settings(max_examples=20, deadline=None)
@given(
    sizes=st.lists(st.integers(4, 64), min_size=1, max_size=4),
    phase=st.integers(0, 3),
    step_val=st.integers(0, 500),
)
def test_covap_conservation(sizes, phase, step_val):
    """t = g + coeff*r is exactly partitioned between the communicated part
    and the new residual: out + r' == t (single worker => pmean identity)."""
    params = {f"p{i}": jnp.zeros((n,)) for i, n in enumerate(sizes)}
    plan = build_plan(params, bucket_bytes=64, max_buckets=16, interval=4)
    comp = get_compressor("covap", interval=4)
    key = jax.random.PRNGKey(step_val)
    grads = {
        k: jax.random.normal(jax.random.fold_in(key, i), v.shape)
        for i, (k, v) in enumerate(params.items())
    }
    residual = {
        k: jax.random.normal(jax.random.fold_in(key, 100 + i), v.shape)
        for i, (k, v) in enumerate(params.items())
    }
    out, new_r, stats = comp.sync(
        grads, residual, plan=plan, phase=phase, step=step_val, axis_names=()
    )
    coeff = comp.schedule.coefficient(step_val)
    for k in grads:
        t = grads[k] + coeff * residual[k]
        np.testing.assert_allclose(
            np.asarray(out[k] + new_r[k]), np.asarray(t), rtol=1e-5, atol=1e-6
        )
        # disjointness: out and r' never overlap
        np.testing.assert_array_equal(
            np.asarray(out[k] * new_r[k]), 0.0
        )


@settings(max_examples=10, deadline=None)
@given(n=st.integers(32, 256), step_val=st.integers(0, 100))
def test_bucket_ef_conservation_topk(n, step_val):
    """Classic EF (Algorithm 1): sent_local + residual' == g + residual."""
    params = {"w": jnp.zeros((n,))}
    plan = build_plan(params, bucket_bytes=64, max_buckets=8, interval=4)
    comp = get_compressor("topk", ratio=0.1)
    key = jax.random.PRNGKey(step_val)
    g = {"w": jax.random.normal(key, (n,))}
    r = {"w": jax.random.normal(jax.random.fold_in(key, 1), (n,))}
    out, new_r, _ = comp.sync(g, r, plan=plan, phase=0, step=step_val,
                              axis_names=())
    # single worker: out == sent_local
    np.testing.assert_allclose(
        np.asarray(out["w"] + new_r["w"]),
        np.asarray(g["w"] + r["w"]),
        rtol=1e-5, atol=1e-6,
    )
