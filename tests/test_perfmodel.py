"""The paper's performance model (eqs 1-6) + CCR estimation properties."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import perfmodel as pm
from repro.core.ccr import (
    HardwareSpec,
    align_comm_times,
    allreduce_bytes_on_wire,
    analytic_times,
    select_interval,
)

pos = st.floats(0.001, 10.0)


@settings(max_examples=50, deadline=None)
@given(P=st.integers(2, 512), tb=pos, tc=pos, tm=pos)
def test_speedup_dp_bounded_by_linear_scaling(P, tb, tc, tm):
    s = pm.speedup_dp(P, tb, tc, tm)
    assert 0 < s <= P + 1e-9


@settings(max_examples=50, deadline=None)
@given(tb=pos, comp=st.lists(pos, min_size=1, max_size=10),
       comm=st.lists(pos, min_size=1, max_size=10))
def test_overlap_simulator_bounds(tb, comp, comm):
    n = min(len(comp), len(comm))
    comp, comm = comp[:n], comm[:n]
    r = pm.simulate_overlap(tb, comp, comm)
    lo = tb + max(sum(comp), sum(comm))
    hi = tb + sum(comp) + sum(comm)
    assert lo - 1e-9 <= r["total"] <= hi + 1e-9


@settings(max_examples=30, deadline=None)
@given(tb=pos, tc=pos, tm=pos, tcomp=pos)
def test_data_dependency_never_faster(tb, tc, tm, tcomp):
    with_dep = pm.t_gc_ovlp(tb, tc, tm, tcomp, data_dependency=True)
    without = pm.t_gc_ovlp(tb, tc, tm, tcomp, data_dependency=False)
    assert with_dep >= without - 1e-9


def test_full_overlap_when_ccr_below_one():
    """Paper claim: compressing to CCR<=1 hides all communication."""
    tb, tc = 0.1, 0.2
    t = pm.t_gc_ovlp(tb, tc, tc * 0.9, 0.0, n_buckets=16)
    assert t < (tb + tc) * 1.1


def test_table_iii_reproduction():
    """Table III: ResNet-101 CCR 2.1 -> GC+ovlp near linear scaling."""
    tb, tc = 0.055, 0.135
    tm = 2.1 * tc
    s_plain = pm.speedup_dp(64, tb, tc, tm)
    s_gc_ovlp = pm.speedup_gc_ovlp(64, tb, tc, tm, volume_ratio=2.1)
    s_ls = 64.0
    assert s_plain < s_gc_ovlp <= s_ls
    assert s_gc_ovlp > 0.85 * s_ls


# ---- ccr --------------------------------------------------------------------

def test_align_comm_times_removes_rendezvous_wait():
    # worker 0 arrives early (waits), worker 1 late; true transfer = 2
    starts = np.array([[0.0], [3.0]])
    ends = np.array([[5.0], [5.0]])
    out = align_comm_times(starts, ends)
    np.testing.assert_allclose(out, [2.0])


def test_select_interval_is_ceil():
    assert select_interval(0.1) == 1
    assert select_interval(1.0) == 1
    assert select_interval(2.1) == 3
    assert select_interval(4.0) == 4
    assert select_interval(1e9) == 64  # capped


def test_allreduce_wire_bytes():
    assert allreduce_bytes_on_wire(100.0, 1) == 0
    assert abs(allreduce_bytes_on_wire(100.0, 2) - 100.0) < 1e-9
    assert allreduce_bytes_on_wire(100.0, 64) < 200.0


def test_analytic_times_paper_environment():
    """In the paper's 30Gbps/V100 environment, VGG-19-like models must show
    CCR > 1 (the communication bottleneck the paper attacks)."""
    hw = HardwareSpec.cloud_v100_30gbps()
    # VGG-19: 143.6M params fp32, ~20 GFLOPs/sample * 32 batch
    r = analytic_times(
        step_flops_per_chip=3 * 20e9 * 32,
        grad_bytes=143.6e6 * 4,
        dp_world=64,
        hw=hw,
    )
    assert r["ccr"] > 1.0
    assert select_interval(r["ccr"]) >= 2
