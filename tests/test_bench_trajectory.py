"""Unit tests for the BENCH trajectory gate (benchmarks/run.py): the
regression comparator and the workload-mismatch skip path — snapshots
measuring different workloads must not be diffed against each other."""
import pytest

from benchmarks.run import (
    TRAJECTORY_TOLERANCE,
    gate_against_prev,
    trajectory_regressions,
)

BASE = {
    "workload": "gpt2-paper/reduced covap I=4 seq32 gb8",
    "step_wall_s": 1.0,
    "serve_p99_ms": 20.0,
    "serve_tokens_per_s": 1000.0,
    "hier_exposed_dcn_ratio": 0.4,
}


def test_trajectory_detects_regressions_both_directions():
    worse = dict(BASE, step_wall_s=1.0 * TRAJECTORY_TOLERANCE * 1.01,
                 serve_tokens_per_s=1000.0 / TRAJECTORY_TOLERANCE / 1.01)
    got = trajectory_regressions(BASE, worse)
    keys = {k for k, _, _ in got}
    assert keys == {"step_wall_s", "serve_tokens_per_s"}
    # inside tolerance: clean
    ok = dict(BASE, step_wall_s=1.2, serve_tokens_per_s=900.0)
    assert trajectory_regressions(BASE, ok) == []
    # improvements never flag
    better = dict(BASE, step_wall_s=0.1, serve_tokens_per_s=9000.0)
    assert trajectory_regressions(BASE, better) == []


def test_trajectory_skips_missing_and_null_keys():
    prev = dict(BASE)
    prev.pop("serve_p99_ms")
    new = dict(BASE, serve_p99_ms=100.0, step_wall_s=None)
    assert trajectory_regressions(prev, new) == []


def test_hier_dcn_ratio_is_gated():
    worse = dict(BASE, hier_exposed_dcn_ratio=0.4 * TRAJECTORY_TOLERANCE * 1.01)
    got = trajectory_regressions(BASE, worse)
    assert [k for k, _, _ in got] == ["hier_exposed_dcn_ratio"]


def test_gate_skips_on_workload_mismatch(capsys):
    """BENCH_<n> recorded under a different workload than BENCH_<n-1>
    (e.g. the smoke geometry changed): every gated number measures a
    different thing, so the gate must SKIP with a printed notice instead
    of flagging phantom regressions."""
    new = dict(BASE, workload="gpt2-paper/reduced covap I=8 seq64 gb16",
               step_wall_s=10.0)
    assert trajectory_regressions(BASE, new)   # raw compare WOULD flag
    assert gate_against_prev(BASE, new) == []  # the gate skips instead
    err = capsys.readouterr().err
    assert "SKIPPED" in err and "workload" in err


def test_gate_compares_when_workloads_match(capsys):
    worse = dict(BASE, step_wall_s=2.0)
    got = gate_against_prev(BASE, worse)
    assert [k for k, _, _ in got] == ["step_wall_s"]
    assert "SKIPPED" not in capsys.readouterr().err
