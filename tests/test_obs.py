"""Tests for the unified telemetry subsystem (``repro.obs``, DESIGN.md §15):
registry semantics, event-log schema enforcement, plan digests, the
Telemetry bundle's artifacts, and the train / serve / adaptive-runtime
integrations."""
from __future__ import annotations

import json
import os

import jax
import pytest

from repro.configs import get_reduced
from repro.data import DataConfig, make_loader
from repro.models import build_model
from repro.obs import (
    NULL_INSTRUMENT,
    NULL_REGISTRY,
    NULL_TELEMETRY,
    EventLog,
    MetricsRegistry,
    Telemetry,
    as_telemetry,
    load_schema,
    plan_digest,
    validate_event,
)
from repro.optim import sgd
from repro.train.trainer import TrainConfig, Trainer


def make_trainer(interval=2, bucket_bytes=1 << 14):
    cfg = get_reduced("gpt2-paper").with_(vocab_size=256)
    model = build_model(cfg)
    tc = TrainConfig(
        compressor="covap", interval=interval,
        bucket_bytes=bucket_bytes, max_buckets=32, log_every=1,
    )
    return Trainer(model, sgd(1e-3), tc)


def loader():
    dc = DataConfig(vocab_size=256, seq_len=16, global_batch=4)
    return iter(make_loader(dc))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_get_or_create_identity():
    r = MetricsRegistry()
    assert r.counter("a") is r.counter("a")
    assert r.gauge("g", x="1") is not r.gauge("g", x="2")
    # label order is irrelevant to identity
    assert r.gauge("g2", a="1", b="2") is r.gauge("g2", b="2", a="1")


def test_registry_kind_conflict_raises():
    r = MetricsRegistry()
    r.counter("n")
    with pytest.raises(ValueError, match="already registered"):
        r.gauge("n")


def test_disabled_registry_is_null_and_empty():
    assert NULL_REGISTRY.counter("x") is NULL_INSTRUMENT
    assert NULL_REGISTRY.gauge("y") is NULL_INSTRUMENT
    assert NULL_REGISTRY.histogram("z") is NULL_INSTRUMENT
    # mutators are no-ops, nothing lands in the snapshot
    NULL_REGISTRY.counter("x").inc()
    NULL_REGISTRY.gauge("y").set(3.0)
    NULL_REGISTRY.histogram("z").observe(1.0)
    assert NULL_REGISTRY.snapshot() == {}


def test_histogram_percentiles_and_window():
    r = MetricsRegistry(hist_window=4)
    h = r.histogram("lat")
    for v in range(1, 101):
        h.observe(float(v))
    st = h.stats()
    # count/sum/min/max are exact over the full life of the instrument...
    assert st["count"] == 100 and st["min"] == 1.0 and st["max"] == 100.0
    assert st["sum"] == pytest.approx(5050.0)
    # ...percentiles stream over the retained window (last 4: 97..100)
    assert st["p50"] == 98.0
    assert st["p99"] == 100.0


def test_snapshot_keys_and_histogram_expansion():
    r = MetricsRegistry()
    r.counter("steps").inc(3)
    r.gauge("loss").set(1.25)
    r.gauge("stage_ms", stage="prefill").set(7.0)
    r.gauge("never_measured")     # stays None
    h = r.histogram("lat")
    h.observe(2.0)
    h.observe(4.0)
    snap = r.snapshot()
    assert snap["steps"] == 3.0
    assert snap["loss"] == 1.25
    assert snap['stage_ms{stage="prefill"}'] == 7.0
    assert snap["never_measured"] is None
    assert snap["lat_count"] == 2 and snap["lat_sum"] == 6.0
    assert snap["lat_min"] == 2.0 and snap["lat_max"] == 4.0
    assert snap["lat_p50"] == 2.0 and snap["lat_p99"] == 4.0


def test_prometheus_text_exposition():
    r = MetricsRegistry()
    r.counter("req_total", "requests", reason="eos").inc(2)
    r.gauge("depth", "queue depth").set(5)
    r.gauge("unset")              # None -> omitted from exposition
    h = r.histogram("lat_ms", "latency")
    h.observe(10.0)
    text = r.to_prometheus_text()
    assert "# TYPE req_total counter" in text
    assert 'req_total{reason="eos"} 2' in text
    assert "# HELP depth queue depth" in text
    assert "depth 5" in text
    assert "# TYPE lat_ms summary" in text
    assert 'lat_ms{quantile="0.5"} 10' in text
    assert "lat_ms_count 1" in text
    # None-valued gauge: TYPE header only, no sample line
    assert not any(l.startswith("unset ") for l in text.splitlines())


# ---------------------------------------------------------------------------
# event log + schema
# ---------------------------------------------------------------------------

def test_emit_stamps_and_records():
    log = EventLog(clock=lambda: 123.5)
    ev = log.emit("note")
    assert ev["ts"] == 123.5 and ev["kind"] == "note"
    assert ev["run_id"] == log.run_id
    assert log.records == [ev]


def test_emit_validates_required_fields():
    log = EventLog()
    with pytest.raises(ValueError, match="missing required"):
        log.emit("step", step=1, loss=0.5)      # no wall_s
    with pytest.raises(ValueError, match="unknown event kind"):
        log.emit("no_such_kind")
    with pytest.raises(ValueError, match="is not"):
        log.emit("step", step="one", loss=0.5, wall_s=0.1)


def test_schema_optional_nullable_fields():
    # trailing "?" in the schema admits null: a probe before any full-step
    # wall exists has achieved_overlap=None
    errs = validate_event({
        "ts": 0.0, "kind": "probe", "run_id": "r",
        "step": 4, "phase": 0, "t_comp": 0.1, "t_comm": 0.2, "ccr": 2.0,
        "achieved_overlap": None,
    })
    assert errs == []
    # ...but a wrongly-typed optional still fails
    errs = validate_event({
        "ts": 0.0, "kind": "probe", "run_id": "r",
        "step": 4, "phase": 0, "t_comp": 0.1, "t_comm": 0.2, "ccr": 2.0,
        "achieved_overlap": "high",
    })
    assert errs and "achieved_overlap" in errs[0]


def test_every_schema_kind_is_well_formed():
    schema = load_schema()
    assert schema["version"] == 1
    for kind, spec in schema["kinds"].items():
        for field, typ in {**spec.get("required", {}),
                           **spec.get("optional", {})}.items():
            base = typ[:-1] if typ.endswith("?") else typ
            assert base in ("number", "integer", "string", "boolean",
                            "object", "array", "null"), (kind, field, typ)


def test_event_log_streams_jsonl(tmp_path):
    path = os.path.join(tmp_path, "events.jsonl")
    schema = load_schema()
    with EventLog(path) as log:
        log.emit("note")
        log.emit("flush", step=3, reason="test")
    with open(path) as f:
        lines = [json.loads(l) for l in f]
    assert [e["kind"] for e in lines] == ["note", "flush"]
    for ev in lines:
        assert validate_event(ev, schema) == []


def test_event_log_bounds_memory():
    log = EventLog(max_records=5)
    for i in range(12):
        log.emit("note")
    assert len(log.records) == 5


def test_disabled_event_log_is_free():
    log = EventLog(enabled=False)
    assert log.emit("no_such_kind_even") is None
    assert log.records == []


# ---------------------------------------------------------------------------
# plan digest
# ---------------------------------------------------------------------------

def test_plan_digest_stable_and_structure_sensitive():
    a = make_trainer(bucket_bytes=1 << 14)
    b = make_trainer(bucket_bytes=1 << 14)
    c = make_trainer(bucket_bytes=1 << 16)
    assert plan_digest(a.plan) == plan_digest(b.plan)
    assert plan_digest(a.plan) != plan_digest(c.plan)
    assert len(plan_digest(a.plan)) == 16


# ---------------------------------------------------------------------------
# Telemetry bundle
# ---------------------------------------------------------------------------

def test_as_telemetry_coercions(tmp_path):
    assert as_telemetry(None) is NULL_TELEMETRY
    tel = Telemetry()
    assert as_telemetry(tel) is tel
    d = os.path.join(tmp_path, "t")
    from_path = as_telemetry(d)
    assert from_path.enabled and from_path.directory == d
    from_path.close()
    with pytest.raises(TypeError):
        as_telemetry(42)


def test_null_telemetry_is_inert(tmp_path):
    assert not NULL_TELEMETRY.enabled
    assert NULL_TELEMETRY.manifest_once(role="train") is False
    assert NULL_TELEMETRY.save(str(tmp_path)) is None
    assert NULL_TELEMETRY.events.emit("note") is None


def test_telemetry_save_artifacts(tmp_path):
    d = os.path.join(tmp_path, "tel")
    with Telemetry(d) as tel:
        assert tel.manifest_once(config={}, plan={}, world=1) is True
        assert tel.manifest_once(config={}, plan={}, world=1) is False
        tel.registry.gauge("g").set(1.0)
        tel.tracer.record_step(0, 0, 0.01)
        paths = tel.save()
    for key in ("prom", "snapshot", "trace", "events"):
        assert os.path.exists(paths[key]), key
    with open(paths["snapshot"]) as f:
        assert json.load(f)["g"] == 1.0
    with open(paths["trace"]) as f:
        assert any(e.get("ph") == "X" for e in json.load(f)["traceEvents"])
    with open(paths["events"]) as f:
        (manifest,) = [json.loads(l) for l in f]
    assert manifest["kind"] == "manifest"


def test_memory_backed_telemetry_exports_events(tmp_path):
    tel = Telemetry()         # no directory: events buffer in memory
    tel.events.emit("note")
    paths = tel.save(str(tmp_path))
    with open(paths["events"]) as f:
        assert json.loads(f.readline())["kind"] == "note"
    tel.close()


# ---------------------------------------------------------------------------
# integrations
# ---------------------------------------------------------------------------

def test_trainer_run_emits_manifest_and_steps():
    tr = make_trainer(interval=2)
    state = tr.init_state(jax.random.PRNGKey(0))
    tel = Telemetry()
    tr.run(state, loader(), steps=3, log=None, telemetry=tel)
    kinds = [e["kind"] for e in tel.events.records]
    assert kinds[0] == "manifest"
    assert kinds.count("step") == 3
    schema = load_schema()
    for ev in tel.events.records:
        assert validate_event(ev, schema) == []
    manifest = tel.events.records[0]
    assert manifest["plan"]["digest"] == plan_digest(tr.plan)
    assert manifest["plan"]["num_buckets"] == tr.plan.num_buckets
    snap = tel.registry.snapshot()
    assert snap["train_steps_total"] == 3.0
    assert isinstance(snap["train_loss"], float)
    tel.close()


def test_adaptive_runtime_replan_audit_trail():
    from repro.runtime import AutotuneConfig
    from repro.runtime.monitor import synthetic_probe

    tr = make_trainer(interval=2)
    state = tr.init_state(jax.random.PRNGKey(0))
    tel = Telemetry()
    cfg = AutotuneConfig(
        measure_every=2, warmup_steps=2, window=2, patience=1,
        cooldown_steps=2, probe=synthetic_probe(0.01, 6.0),
    )
    tr.run(state, loader(), steps=12, log=None, autotune=cfg, telemetry=tel)
    kinds = [e["kind"] for e in tel.events.records]
    assert "probe" in kinds and "replan_decision" in kinds
    assert "replan" in kinds   # injected CCR=6 forces an interval switch
    schema = load_schema()
    for ev in tel.events.records:
        assert validate_event(ev, schema) == []
    rp = next(e for e in tel.events.records if e["kind"] == "replan")
    assert rp["old_interval"] == 2 and rp["new_interval"] != 2
    decisions = [e for e in tel.events.records
                 if e["kind"] == "replan_decision"]
    assert any(d["replan"] for d in decisions)
    # the runtime's spans land in the bundle's shared tracer
    assert any("replan" in e.get("cat", "") for e in tel.tracer.events)
    tel.close()


def test_serve_engine_records_requests():
    from repro.serve import Engine, ServeConfig

    cfg = get_reduced("qwen1.5-0.5b").with_(vocab_size=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tel = Telemetry()
    eng = Engine(
        model, params,
        ServeConfig(batch_slots=2, max_len=32, max_new_tokens=4,
                    page_size=8, prefill_chunk=8),
        telemetry=tel,
    )
    eng.submit([1, 2, 3])
    eng.submit([4, 5, 6, 7])
    eng.run_until_done()
    reqs = [e for e in tel.events.records if e["kind"] == "serve_request"]
    assert len(reqs) == 2
    schema = load_schema()
    for ev in tel.events.records:
        assert validate_event(ev, schema) == []
    cats = {e.get("cat") for e in tel.tracer.events}
    for stage in ("queued", "prefill", "insert", "decode"):
        assert f"serve,{stage}" in cats
    snap = tel.registry.snapshot()
    assert snap['serve_requests_total{reason="length"}'] == 2.0
    assert snap['serve_stage_ms{stage="prefill"}_count'] >= 1
    tel.close()
