"""Per-architecture smoke tests (deliverable f): REDUCED variant of every
assigned config runs one forward/train step + one decode step on CPU with
shape and finiteness assertions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_reduced, list_archs
from repro.models import build_model, padded_vocab

ARCHS = list_archs()


def make_batch(cfg, B=2, S=32, key=jax.random.PRNGKey(0)):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = 0.02 * jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model), jnp.float32
        )
    if cfg.is_encdec:
        batch["frames"] = 0.02 * jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step(arch):
    cfg = get_reduced(arch)
    assert cfg.d_model <= 512 and cfg.num_layers <= 2
    if cfg.is_moe:
        assert cfg.num_experts <= 4
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(
        model.loss_fn, has_aux=True
    )(params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf)))


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_prefill_shapes(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = make_batch(cfg, B, S)
    batch.pop("labels")
    logits = model.prefill(params, batch)
    V = padded_vocab(cfg)
    assert logits.shape[0] == B and logits.shape[-1] == V
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_decode_step(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B = 2
    caches = model.init_caches(B, 64)
    batch = {
        "tokens": jnp.ones((B, 1), jnp.int32),
        "pos": jnp.zeros((B,), jnp.int32),
    }
    logits, caches2 = model.decode_step(params, caches, batch)
    assert logits.shape == (B, 1, padded_vocab(cfg))
    assert bool(jnp.all(jnp.isfinite(logits)))
    # cache must actually change
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(caches), jax.tree.leaves(caches2))
    )
    assert changed


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_dims_cited(arch):
    cfg = get_config(arch)
    assert cfg.source, "every config must cite its source"
    assert cfg.vocab_size > 1000
    assert cfg.num_heads % cfg.num_kv_heads == 0


def test_assigned_pool_complete():
    assigned = set(list_archs(assigned_only=True))
    assert assigned == {
        "pixtral-12b", "deepseek-moe-16b", "gemma-2b", "grok-1-314b",
        "qwen1.5-0.5b", "mistral-large-123b", "xlstm-125m",
        "seamless-m4t-medium", "gemma2-27b", "zamba2-2.7b",
    }
