"""Resilience subsystem: deterministic faults, guard trips, the recovery
ladder, crash-safe checkpoints, and the controller circuit breaker
(DESIGN.md §16).

The multi-worker chaos test runs in a subprocess (8 fake CPU devices must
be configured before jax initialises); everything else is in-process on
the tiny reduced configs.
"""
import json
import math
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import checkpoint
from repro.checkpoint import CheckpointCorruptError
from repro.configs import get_reduced
from repro.data import DataConfig, make_loader
from repro.models import build_model
from repro.obs import Telemetry, validate_event
from repro.optim import adamw
from repro.resilience import (
    FaultEvent,
    FaultInjector,
    FaultPlan,
    GuardConfig,
    Guards,
    InjectedCrash,
    RecoveryError,
    blowup_residual,
    corrupt_planes,
    corrupt_tree,
    parse_fault_spec,
    plane_nonfinite_counts,
)
from repro.runtime.controller import AutotuneConfig, ReplanController
from repro.train.trainer import TrainConfig, Trainer

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


# ---------------------------------------------------------------------------
# faults: deterministic, reproducible corruption
# ---------------------------------------------------------------------------

def test_parse_fault_spec_grammar():
    plan = parse_fault_spec("grad_nan@6, ef_blowup@12*1e9, grad_inf@18x4")
    kinds = [(e.kind, e.step, e.times) for e in plan.events]
    assert kinds == [("grad_nan", 6, 1), ("ef_blowup", 12, 1),
                     ("grad_inf", 18, 4)]
    assert plan.events[1].scale == 1e9
    with pytest.raises(ValueError):
        parse_fault_spec("grad_nan")           # missing @step
    with pytest.raises(ValueError):
        parse_fault_spec("not_a_fault@3")      # unknown kind


def test_corrupt_tree_is_deterministic_and_minimal():
    tree = {"a": jnp.ones((8, 8)), "b": jnp.ones((32,))}
    out1, sites1 = corrupt_tree(tree, "grad_nan", seed=7, step=11, count=3)
    out2, sites2 = corrupt_tree(tree, "grad_nan", seed=7, step=11, count=3)
    assert sites1 == sites2
    n_bad = sum(int(jnp.sum(~jnp.isfinite(x))) for x in jax.tree.leaves(out1))
    assert n_bad == 3
    # different step -> different sites (the schedule, not the call count,
    # drives site selection)
    _, sites3 = corrupt_tree(tree, "grad_nan", seed=7, step=12, count=3)
    assert sites3 != sites1


def test_corrupt_planes_and_plane_guard():
    """The one-reduction-per-plane guard sees exactly the injected
    corruption on packed arena planes."""
    planes = [jnp.zeros(64), jnp.zeros(128), jnp.zeros(16)]
    assert plane_nonfinite_counts(planes) == [0, 0, 0]
    bad, sites = corrupt_planes(planes, "grad_inf", seed=0, step=3, count=4)
    counts = plane_nonfinite_counts(bad)
    assert sum(counts) == 4
    for li, _ in sites:
        assert counts[li] > 0


def test_bitflip_is_a_blowup_not_a_wiggle():
    tree = {"w": jnp.ones((64,))}
    out, sites = corrupt_tree(tree, "grad_bitflip", seed=1, step=5)
    (_, fi), = sites
    v = float(out["w"][fi])
    # a high-exponent-bit flip moves the value by many orders of magnitude
    # (up or down, depending on whether the bit was set) — never a wiggle
    assert not math.isfinite(v) or v == 0.0 or abs(math.log10(abs(v))) > 3


def test_blowup_residual_scales_floating_leaves():
    comp = {"r": jnp.full((4,), 2.0), "i": jnp.arange(3)}
    out = blowup_residual(comp, 1e10)
    assert float(out["r"][0]) == pytest.approx(2e10)
    assert out["i"].dtype == comp["i"].dtype          # ints untouched


def test_kill_fault_raises_injected_crash():
    inj = FaultInjector(FaultPlan(events=(FaultEvent(step=4, kind="kill"),)))
    state = {"params": {"w": jnp.ones(2)}, "comp": (), "step": 4}
    with pytest.raises(InjectedCrash):
        inj.pre_step(state, None, 4)
    # exhausted: the restart that resumes past step 4 is not re-killed
    state2, _ = inj.pre_step(state, None, 4)
    assert state2 is state


def test_fault_firing_budget_times():
    ev = FaultEvent(step=2, kind="grad_nan", times=2)
    inj = FaultInjector(FaultPlan(events=(ev,)))
    state = {"params": {"w": jnp.ones(4)}, "comp": (), "step": 2}
    for expect_poison in (True, True, False):
        out, _ = inj.pre_step(state, None, 2)
        poisoned = bool(jnp.any(~jnp.isfinite(out["params"]["w"])))
        assert poisoned == expect_poison


# ---------------------------------------------------------------------------
# guards
# ---------------------------------------------------------------------------

def test_guard_nonfinite_and_window_hygiene():
    g = Guards(GuardConfig())
    assert g.check(0, {"total_loss": 1.0, "grad_norm": 1.0}) == []
    trips = g.check(1, {"total_loss": float("inf"), "grad_norm": 1.0})
    assert [t.guard for t in trips] == ["nonfinite"]
    # the tripped loss must NOT enter the spike window
    assert all(math.isfinite(x) for x in g._losses)
    trips = g.check(2, {"total_loss": 1.0, "grad_norm": float("nan")})
    assert [t.guard for t in trips] == ["nonfinite"]


def test_guard_loss_spike_median_window():
    g = Guards(GuardConfig(loss_spike_min_steps=4, loss_spike_factor=10.0))
    for i in range(6):
        assert g.check(i, {"total_loss": 2.0 + 0.01 * i}) == []
    trips = g.check(6, {"total_loss": 50.0})
    assert [t.guard for t in trips] == ["loss_spike"]
    # not armed before min_steps
    g2 = Guards(GuardConfig(loss_spike_min_steps=4, loss_spike_factor=10.0))
    g2.check(0, {"total_loss": 1.0})
    assert g2.check(1, {"total_loss": 1000.0}) == []


def test_guard_residual_watchdog_cadence():
    cfg = GuardConfig(residual_check_every=4, residual_abs_max=1e6)
    g = Guards(cfg)
    comp = {"r": jnp.full((8,), 1e5)}     # norm ~2.8e5: under the limit
    assert g.check(4, {"total_loss": 1.0}, comp) == []
    hot = blowup_residual(comp, 1e8)
    # off-cadence step: watchdog silent even though the residual is hot
    assert g.check(5, {"total_loss": 1.0}, hot) == []
    trips = g.check(8, {"total_loss": 1.0}, hot)
    assert [t.guard for t in trips] == ["residual"]


# ---------------------------------------------------------------------------
# the recovery ladder end-to-end (single process)
# ---------------------------------------------------------------------------

def _tiny_trainer(steps_cfg=24, interval=2):
    cfg = get_reduced("gpt2-paper").with_(vocab_size=256)
    model = build_model(cfg)
    tc = TrainConfig(compressor="covap", interval=interval,
                     bucket_bytes=1 << 14, max_buckets=16,
                     log_every=1000, steps=steps_cfg)
    tr = Trainer(model, adamw(3e-3), tc)
    state = tr.init_state(jax.random.PRNGKey(0))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4,
                    corpus_tokens=1 << 12)
    return model, tr, state, iter(make_loader(dc))


def test_ladder_all_rungs_with_schema_valid_telemetry(tmp_path):
    """Every injected fault must surface as schema-valid guard_trip /
    recovery / fault_injected events with matching counter increments, and
    the run must end with finite loss."""
    model, tr, state, loader = _tiny_trainer()
    tel = Telemetry(str(tmp_path / "tel"))
    g = GuardConfig(ckpt_dir=str(tmp_path / "ck"), ckpt_every=6,
                    residual_check_every=2, max_skips=1, max_flushes=1,
                    sync_every=1)   # strict lag-one: step-exact schedule
    # 40 loop iterations: every fault firing costs two (the poisoned step
    # plus the lag-one detection step) and the rewind replays from the
    # step-12 checkpoint, so 24 nominal steps of progress need headroom
    state = tr.run(state, loader, steps=40, log=None, telemetry=tel,
                   guards=g, faults="grad_nan@8,ef_blowup@12,grad_inf@16x3")
    loss = float(model.loss_fn(state["params"], next(loader))[0])
    assert math.isfinite(loss)

    s = tr.resilience.summary()
    # all three rungs exercised by this schedule (1 skip budget + 1 flush
    # budget per incident, grad_inf fires 3x -> forced up to a rewind)
    assert set(s["actions_by_rung"]) == {"skip_step", "ef_flush", "rewind"}
    assert s["faults"]["fired"] >= 4

    tel.save()
    tel.close()
    by_kind: dict[str, list[dict]] = {}
    with open(tmp_path / "tel" / "events.jsonl") as f:
        for line in f:
            ev = json.loads(line)
            by_kind.setdefault(ev["kind"], []).append(ev)
            validate_event(ev)     # schema-valid on disk, not just at emit
    # every trip / action / firing visible in telemetry, 1:1 with counters
    snap = tel.registry.snapshot()
    n_trips = sum(v for k, v in snap.items()
                  if k.startswith("guard_trips_total"))
    n_actions = sum(v for k, v in snap.items()
                    if k.startswith("recovery_actions_total"))
    n_faults = sum(v for k, v in snap.items()
                   if k.startswith("faults_injected_total"))
    assert len(by_kind["guard_trip"]) == n_trips == s["trips"]
    assert len(by_kind["recovery"]) == n_actions == s["actions"]
    assert len(by_kind["fault_injected"]) == n_faults == s["faults"]["fired"]
    rungs = {e["action"] for e in by_kind["recovery"]}
    assert rungs == {"skip_step", "ef_flush", "rewind"}
    assert any("rewind_to" in e for e in by_kind["recovery"])


def test_skip_step_restores_pre_fault_state():
    """One transient NaN: the recovered run's state at the re-run step must
    be bit-identical to an unfaulted run fed the same batches (skip-step
    restores the pre-corruption snapshot; the poisoned batch AND the
    lag-one detection step's batch are consumed)."""
    model, tr, state, _ = _tiny_trainer()
    dc = DataConfig(vocab_size=256, seq_len=16, global_batch=4,
                    corpus_tokens=1 << 12)
    batches = list(b for b, _ in zip(make_loader(dc), range(16)))

    def run(faults):
        m, t, s, _ = _tiny_trainer()
        # faulted runs burn two batches on a skipped incident: give both
        # the same stream and compare at equal STEP, not equal batch count
        # (sync_every=1 pins the strict lag-one check so the batch
        # arithmetic below is exact; also exercises the dict-override path)
        s = t.run(s, iter(batches), steps=12, log=None,
                  guards={"sync_every": 1}, faults=faults)
        return s

    clean = run(None)
    healed = run("grad_nan@5")
    # fault at step 5 (batch 5), detected at the lag-one check during
    # step 6 (batch 6) -> both discarded, 10 real steps in 12 iterations
    assert int(healed["step"]) == 10
    m, t, s, _ = _tiny_trainer()
    replay_batches = batches[:5] + batches[7:12]
    replayed = t.run(s, iter(replay_batches), steps=10, log=None)
    for a, b in zip(jax.tree.leaves(healed["params"]),
                    jax.tree.leaves(replayed["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_batched_sync_detection_and_recovery():
    """Default ``sync_every=4`` batches the deferred checks: detection is
    late (up to a full batch window of work is discarded) but still
    deterministic — flushes are counted in steps, not wall time — and
    every step is still checked.  One transient NaN at step 5: the batch
    [4..7] flushes at iteration 8, trips on 5, and skip-step rolls back
    to the window-start snapshot (step 4), discarding the poisoned step,
    its clean neighbours 4/6/7 and the in-flight step 8 — 5 of 16
    iterations, netting exactly 11 committed steps."""
    model, tr, state, loader = _tiny_trainer()
    state = tr.run(state, loader, steps=16, log=None, guards=True,
                   faults="grad_nan@5")
    assert int(state["step"]) == 11
    s = tr.resilience.summary()
    assert s["actions_by_rung"] == {"skip_step": 1}
    assert s["trips_by_guard"] == {"nonfinite": 1}
    # the trip is attributed to the step that ran, not the flush point
    assert tr.resilience.guards.trips[0].step == 5
    loss = float(model.loss_fn(state["params"], next(loader))[0])
    assert math.isfinite(loss)


def test_guard_config_validates_sync_every():
    with pytest.raises(ValueError, match="sync_every"):
        GuardConfig(sync_every=0)


def test_ladder_exhaustion_raises_recovery_error():
    model, tr, state, loader = _tiny_trainer()
    g = GuardConfig(max_skips=1, max_flushes=0, max_rewinds=0)
    with pytest.raises(RecoveryError) as ei:
        tr.run(state, loader, steps=12, log=None, guards=g,
               faults="grad_nan@4x8")
    assert ei.value.trips      # the trip history rides the exception


def test_rewind_without_ckpt_dir_raises():
    model, tr, state, loader = _tiny_trainer()
    g = GuardConfig(max_skips=0, max_flushes=0, max_rewinds=2)  # rewind-only
    with pytest.raises(RecoveryError, match="ckpt_dir"):
        tr.run(state, loader, steps=8, log=None, guards=g,
               faults="grad_nan@3")


def test_guards_off_path_bit_identical():
    """guards=None must leave the training trajectory untouched."""
    def run(**kw):
        m, t, s, _ = _tiny_trainer()
        dc = DataConfig(vocab_size=256, seq_len=16, global_batch=4,
                        corpus_tokens=1 << 12)
        return t.run(s, iter(make_loader(dc)), steps=6, log=None, **kw)

    a = run()
    b = run(guards=True)
    for x, y in zip(jax.tree.leaves(a["params"]), jax.tree.leaves(b["params"])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_ccr_skew_inflates_probe():
    from repro.runtime.monitor import PhaseSample

    def probe(state, batch, phase):
        return PhaseSample(t_comp=1.0, t_comm=0.5, phase=phase, step=0)

    inj = FaultInjector(FaultPlan(events=(
        FaultEvent(step=1, kind="ccr_skew", times=2, scale=3.0),
    )))
    wrapped = inj.wrap_probe(probe)
    s0 = wrapped(None, None, 0)     # probe call 0: before the event
    s1 = wrapped(None, None, 0)     # probe calls 1,2: skewed
    s2 = wrapped(None, None, 0)
    s3 = wrapped(None, None, 0)     # budget exhausted
    assert s0.t_comm == 0.5 and s3.t_comm == 0.5
    assert s1.t_comm == pytest.approx(3.5) and s2.t_comm == pytest.approx(3.5)


# ---------------------------------------------------------------------------
# crash-safe checkpoint store
# ---------------------------------------------------------------------------

def _state():
    return {"params": {"w": jnp.arange(12.0), "b": jnp.ones(3)},
            "opt": {"m": {"w": jnp.zeros(12), "b": jnp.zeros(3)}},
            "comp": {"w": jnp.zeros(12), "b": jnp.zeros(3)}, "step": 7}


def test_checkpoint_digest_roundtrip(tmp_path):
    d = str(tmp_path)
    p = checkpoint.save_train_state(d, _state(), interval=2)
    with open(os.path.join(p, "manifest.json")) as f:
        man = json.load(f)
    assert man["digest"].startswith("sha256:")
    assert checkpoint.verify(d, 7) == man["digest"]
    restored, extra = checkpoint.restore_train_state(d, _state())
    assert extra["interval"] == 2
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]), np.arange(12.0)
    )


def test_checkpoint_corruption_detected(tmp_path):
    d = str(tmp_path)
    p = checkpoint.save_train_state(d, _state(), interval=2)
    npz = os.path.join(p, "arrays.npz")
    data = bytearray(open(npz, "rb").read())
    data[len(data) // 2] ^= 0xFF
    open(npz, "wb").write(bytes(data))
    with pytest.raises(CheckpointCorruptError):
        checkpoint.restore_train_state(d, _state())
    # the comp-drift fallback must NOT swallow corruption either
    with pytest.raises(CheckpointCorruptError):
        checkpoint.restore(d, 7, {"params": _state()["params"]})


def test_checkpoint_partial_write_detected(tmp_path):
    d = str(tmp_path)
    p = checkpoint.save_train_state(d, _state(), interval=2)
    os.remove(os.path.join(p, "arrays.npz"))
    with pytest.raises(CheckpointCorruptError, match="no arrays.npz"):
        checkpoint.restore_train_state(d, _state())


def test_checkpoint_save_is_atomic_and_overwrites(tmp_path):
    d = str(tmp_path)
    checkpoint.save_train_state(d, _state(), interval=2)
    # temp staging dirs are invisible to latest_step's scan
    assert checkpoint.latest_step(d) == 7
    assert not any(n.startswith(".tmp") for n in os.listdir(d))
    # re-save at the same step (e.g. rewind then re-checkpoint): replaced
    # atomically, still restorable
    s2 = _state()
    s2["params"] = {"w": jnp.full(12, 9.0), "b": jnp.ones(3)}
    checkpoint.save_train_state(d, s2, interval=4)
    restored, extra = checkpoint.restore_train_state(d, _state())
    assert float(restored["params"]["w"][0]) == 9.0 and extra["interval"] == 4


def test_pre_digest_checkpoints_still_restore(tmp_path):
    """Backward compat: a manifest without a digest restores (nothing to
    verify) rather than failing the new check."""
    d = str(tmp_path)
    p = checkpoint.save_train_state(d, _state(), interval=2)
    mpath = os.path.join(p, "manifest.json")
    man = json.load(open(mpath))
    del man["digest"]
    json.dump(man, open(mpath, "w"))
    restored, _ = checkpoint.restore_train_state(d, _state())
    assert int(restored["step"]) == 7


# ---------------------------------------------------------------------------
# controller: oscillation property + circuit breaker
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    lo=st.floats(min_value=0.5, max_value=3.0),
    hi=st.floats(min_value=4.0, max_value=32.0),
    period=st.integers(min_value=1, max_value=5),
    cooldown=st.integers(min_value=1, max_value=64),
    patience=st.integers(min_value=1, max_value=3),
    max_replans=st.integers(min_value=1, max_value=8),
)
def test_adversarial_ccr_trace_bounded_by_max_replans(
    lo, hi, period, cooldown, patience, max_replans,
):
    """PROPERTY: no alternating-CCR trace can trigger more than
    max_replans replans, breaker or no breaker."""
    cfg = AutotuneConfig(
        patience=patience, cooldown_steps=cooldown, max_replans=max_replans,
        breaker_replans=0,       # breaker off: max_replans alone must hold
    )
    ctl = ReplanController(cfg, interval=2)
    for i in range(400):
        ccr = lo if (i // period) % 2 == 0 else hi
        ctl.observe(i, ccr)
    assert ctl.replans <= max_replans
    assert len(ctl.replan_steps) == ctl.replans


@settings(max_examples=25, deadline=None)
@given(
    hi=st.floats(min_value=6.0, max_value=40.0),
    breaker=st.integers(min_value=2, max_value=5),
)
def test_breaker_latches_on_thrash_and_freezes(hi, breaker):
    """PROPERTY: under a worst-case flapping trace the breaker latches
    after exactly breaker_replans replans in its window, and no replan
    ever lands afterwards."""
    cfg = AutotuneConfig(
        patience=1, cooldown_steps=1, max_replans=10 ** 6,
        breaker_replans=breaker, breaker_window_steps=10 ** 6,
    )
    ctl = ReplanController(cfg, interval=2)
    for i in range(300):
        ccr = 1.0 if i % 2 == 0 else hi
        ctl.observe(i, ccr)
    assert ctl.frozen
    assert ctl.replans == breaker
    replans_at_latch = ctl.replans
    for i in range(300, 340):
        d = ctl.observe(i, hi if i % 2 else 1.0)
        assert not d.replan
        assert d.reason.startswith("circuit-open:")
    assert ctl.replans == replans_at_latch


def test_breaker_window_expiry_and_reset():
    cfg = AutotuneConfig(
        patience=1, cooldown_steps=1, max_replans=10 ** 6,
        breaker_replans=3, breaker_window_steps=10,
    )
    ctl = ReplanController(cfg, interval=2)
    # two replans, then a long quiet gap: the window forgets them
    ctl.observe(0, 8.0)
    ctl.observe(100, 1.0)
    assert ctl.replans == 2 and not ctl.frozen
    ctl.observe(300, 8.0)
    assert ctl.replans == 3 and not ctl.frozen   # only 1 in-window replan
    # three rapid replans latch it
    ctl.observe(301, 1.0)
    ctl.observe(302, 8.0)
    assert ctl.frozen
    ctl.reset_breaker()
    assert not ctl.frozen and ctl.replan_steps == []


# ---------------------------------------------------------------------------
# 8-worker mesh chaos: finite loss + bit-for-bit restorable state
# ---------------------------------------------------------------------------

def run_sub(code: str, timeout=560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    return r.stdout


def test_mesh_chaos_run_finite_and_restorable(tmp_path):
    out = run_sub(f"""
    import json, math, os, tempfile
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro import checkpoint
    from repro.configs import get_reduced
    from repro.data import DataConfig, make_loader
    from repro.models import build_model
    from repro.optim import adamw
    from repro.resilience import GuardConfig
    from repro.train.trainer import TrainConfig, Trainer

    td = {str(tmp_path)!r}
    mesh = Mesh(np.array(jax.devices()[:8]), ("data",))
    cfg = get_reduced("gpt2-paper").with_(vocab_size=256)
    model = build_model(cfg)
    tc = TrainConfig(compressor="covap", interval=2, bucket_bytes=1 << 14,
                     max_buckets=16, log_every=1000)
    tr = Trainer(model, adamw(3e-3), tc, mesh=mesh, dp_axes=("data",))
    state = tr.init_state(jax.random.PRNGKey(0))
    dc = DataConfig(vocab_size=256, seq_len=16, global_batch=8,
                    corpus_tokens=1 << 12)
    g = GuardConfig(ckpt_dir=os.path.join(td, "ck"), ckpt_every=6,
                    residual_check_every=2, max_skips=1, max_flushes=1)
    loader = iter(make_loader(dc))
    state = tr.run(state, loader, steps=20, log=None,
                   guards=g, faults="grad_nan@7,ef_blowup@11")
    s = tr.resilience.summary()
    assert s["actions"] >= 2, s

    # bit-for-bit restorable: save the final (flushed) state, restore into
    # a fresh trainer, compare every leaf exactly
    p = checkpoint.save_train_state(os.path.join(td, "final"), state,
                                    interval=tr.tc.interval)
    tr2 = Trainer(model, adamw(3e-3), tc, mesh=mesh, dp_axes=("data",))
    like = tr2.init_state(jax.random.PRNGKey(1))
    restored, extra = checkpoint.restore_train_state(os.path.join(td, "final"), like)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # finite loss after the chaos: one more compiled step reads the metric
    # through the trainer's own sharding-aware executable
    fn = tr._phase_fn(int(state["step"]) % tr.num_phases)
    _, _, _, m = fn(state["params"], state["opt"], state["comp"],
                    next(loader), jnp.asarray(state["step"], jnp.int32))
    loss = float(m["total_loss"])
    assert math.isfinite(loss), loss
    print("MESHCHAOS ok loss=%.4f actions=%d" % (loss, s["actions"]))
    """)
    assert "MESHCHAOS ok" in out
