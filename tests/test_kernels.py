"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode on CPU; the kernels target TPU BlockSpecs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SIZES = [1, 127, 4096, 33333, 100_000]
DTYPES = [jnp.float32, jnp.bfloat16]


def rnd(n, dtype, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (n,)).astype(dtype)


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("selected", [True, False])
def test_ef_update(n, dtype, selected):
    g, r = rnd(n, dtype, 0), rnd(n, dtype, 1)
    s1, r1 = ops.ef_update(g, r, 0.7, selected=selected, block=4096)
    s2, r2 = ref.ef_update_ref(g, r, 0.7, selected=selected)
    np.testing.assert_allclose(
        np.asarray(s1, np.float32), np.asarray(s2, np.float32), rtol=1e-2
    )
    np.testing.assert_allclose(
        np.asarray(r1, np.float32), np.asarray(r2, np.float32), rtol=1e-2
    )


@pytest.mark.parametrize("n", SIZES)
def test_quantize_roundtrip(n):
    x = rnd(n, jnp.float32)
    q, s = ops.quantize_fp8(x, block=2048)
    q2, s2 = ref.quantize_fp8_ref(x, block=2048)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s2), rtol=1e-6)
    np.testing.assert_array_equal(
        np.asarray(q, np.float32), np.asarray(q2, np.float32)
    )
    xd = ops.dequantize_fp8(q, s, block=2048)
    # fp8 e4m3 relative error ~2^-3
    np.testing.assert_allclose(np.asarray(xd), np.asarray(x), atol=0.2, rtol=0.13)


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_sign_compress(n, dtype):
    x = rnd(n, dtype)
    s1, sc1 = ops.sign_compress(x, block=4096)
    s2, sc2 = ref.sign_compress_ref(x)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_allclose(float(sc1), float(sc2), rtol=1e-3)


@pytest.mark.parametrize("n", SIZES)
def test_threshold_filter(n):
    x = rnd(n, jnp.float32)
    t = ops.sample_threshold(x, 0.05)
    y1, c1 = ops.threshold_filter(x, t, block=4096)
    y2, c2 = ref.threshold_filter_ref(x, t, block=4096)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2))
    assert int(c1.sum()) == int(c2.sum())


@pytest.mark.parametrize(
    "m,k,n", [(8, 8, 8), (128, 128, 128), (300, 257, 2), (64, 1000, 4)]
)
@pytest.mark.parametrize("dtype", DTYPES)
def test_matmul(m, k, n, dtype):
    a = jax.random.normal(jax.random.PRNGKey(0), (m, k)).astype(dtype)
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n)).astype(dtype)
    c1 = ops.matmul(a, b, bm=128, bn=128, bk=128)
    c2 = ref.matmul_ref(a, b)
    tol = 2e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=tol, atol=tol)


def test_sample_threshold_keeps_roughly_ratio():
    x = rnd(100_000, jnp.float32)
    for ratio in (0.01, 0.1):
        t = ops.sample_threshold(x, ratio)
        kept = float(jnp.mean(jnp.abs(x) >= t))
        assert 0.3 * ratio < kept < 3.0 * ratio
