"""Plan/execute split: CommSchedule invariants, stage composition, the
legacy-COVAP bit-for-bit equivalence, and the repro.api facade."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build_plan, get_compressor
from repro.core import bucketing as bk
from repro.core.ccr import (
    HardwareSpec,
    analytic_ccr,
    compressed_ccr,
    select_interval,
)
from repro.core.comm import pmean
from repro.core.error_feedback import EFSchedule, compensate
from repro.core.filter import selected_buckets
from repro.core.perfmodel import simulate_schedule
from repro.core.schedule import plan_all_phases
from repro.core.stages import (
    CoarseFilter,
    ErrorFeedback,
    FP8Block,
    SyncPipeline,
    WireCast,
)


@pytest.fixture(scope="module")
def setup():
    params = {
        "emb": jnp.zeros((128, 16)),
        "w1": jnp.zeros((4, 16, 32)),
        "b1": jnp.zeros((4, 32)),
    }
    plan = build_plan(params, bucket_bytes=2048, max_buckets=16, interval=4)
    key = jax.random.PRNGKey(0)
    grads = {
        k: jax.random.normal(jax.random.fold_in(key, i), v.shape)
        for i, (k, v) in enumerate(params.items())
    }
    residual = {
        k: jax.random.normal(jax.random.fold_in(key, 100 + i), v.shape)
        for i, (k, v) in enumerate(params.items())
    }
    return params, plan, grads, residual


# ---- legacy COVAP reference (the pre-split implementation, verbatim) --------

def legacy_covap_sync(grads, state, *, plan, phase, step, interval,
                      schedule: EFSchedule, wire_dtype=None, axis_names=()):
    ef_on = state != ()
    if ef_on:
        coeff = schedule.coefficient(step)
        t = compensate(grads, state, coeff)
    else:
        t = grads
    treedef = jax.tree_util.tree_structure(t)
    leaves = jax.tree_util.tree_leaves(t)
    out_leaves = [jnp.zeros(l.shape, l.dtype) for l in leaves]
    resid_leaves = list(leaves) if ef_on else None
    for b in selected_buckets(plan.num_buckets, phase, interval):
        bucket = plan.buckets[b]
        for seg in bucket.segments:
            li = seg.leaf_idx
            x = bk._slice_segment(leaves[li], seg)
            if wire_dtype is not None and x.dtype != wire_dtype:
                xw = x.astype(wire_dtype)
                xm = pmean(xw, axis_names).astype(x.dtype)
                if ef_on:
                    resid_leaves[li] = bk._update_segment(
                        resid_leaves[li], seg, x - xw.astype(x.dtype)
                    )
            else:
                xm = pmean(x, axis_names)
                if ef_on:
                    resid_leaves[li] = bk._update_segment(
                        resid_leaves[li], seg, jnp.zeros_like(x)
                    )
            out_leaves[li] = bk._update_segment(out_leaves[li], seg, xm)
    out = jax.tree_util.tree_unflatten(treedef, out_leaves)
    new_state = (
        jax.tree_util.tree_unflatten(treedef, resid_leaves) if ef_on else state
    )
    return out, new_state


@pytest.mark.parametrize("wire", ["", "bfloat16"])
def test_coarse_filter_ef_pipeline_reproduces_legacy_covap(setup, wire):
    """CoarseFilter ∘ ErrorFeedback ∘ WireCast == the legacy monolithic
    COVAP, bit for bit, across every phase of the cycle."""
    params, plan, grads, residual = setup
    comp = get_compressor("covap", interval=4, wire_dtype=wire)
    state = residual
    for step in range(8):
        phase = step % 4
        out, new_state, _ = comp.sync(
            grads, state, plan=plan, phase=phase, step=step, axis_names=()
        )
        ref_out, ref_state = legacy_covap_sync(
            grads, state, plan=plan, phase=phase, step=step, interval=4,
            schedule=comp.schedule,
            wire_dtype=jnp.dtype(wire) if wire else None,
        )
        for k in grads:
            np.testing.assert_array_equal(
                np.asarray(out[k]), np.asarray(ref_out[k])
            )
            np.testing.assert_array_equal(
                np.asarray(new_state[k]), np.asarray(ref_state[k])
            )
        state = new_state


def test_covap_is_a_stage_composition(setup):
    params, plan, grads, _ = setup
    comp = get_compressor("covap", interval=4)
    kinds = [type(s) for s in comp.stages]
    assert kinds == [CoarseFilter, ErrorFeedback, WireCast]
    assert comp.filter.interval == 4
    assert comp.num_phases(4) == 4


def test_hybrid_pipeline_one_liner(setup):
    """Beyond-paper hybrid: coarse filter + fp8 wire + EF, one line."""
    params, plan, grads, _ = setup
    comp = SyncPipeline.of(CoarseFilter(4), ErrorFeedback(), FP8Block())
    state = comp.init_state(params, plan)
    scheds = plan_all_phases(comp, plan)
    assert len(scheds) == 4
    # filter (4x on average) composes with fp8 (~4x): cycle mean well under
    # a quarter of dense
    mean_bytes = sum(s.bytes_per_worker for s in scheds) / 4
    assert mean_bytes < scheds[0].dense_bytes / 8
    out, state2, stats = comp.execute(
        scheds[0], grads, state, step=0, axis_names=()
    )
    assert stats.bytes_per_worker == scheds[0].bytes_per_worker
    for k in grads:
        assert bool(jnp.all(jnp.isfinite(out[k])))


def test_schedule_summary_and_wire_bytes(setup):
    params, plan, grads, _ = setup
    comp = get_compressor("covap", interval=4)
    sched = comp.plan_phase(plan, 0, world=8)
    s = sched.summary()
    assert s["bytes_per_worker"] == sched.bytes_per_worker
    assert s["selected"] == list(
        selected_buckets(plan.num_buckets, 0, 4)
    )
    # ring all-reduce wire factor 2(W-1)/W
    assert sched.wire_bytes(8) == pytest.approx(
        2 * 7 / 8 * sched.bytes_per_worker
    )
    assert sched.wire_bytes(1) == 0.0


def test_phase_cycle_covers_every_bucket_once(setup):
    params, plan, grads, _ = setup
    comp = get_compressor("covap", interval=4)
    seen = []
    for s in plan_all_phases(comp, plan):
        seen.extend(s.selected)
    assert sorted(seen) == list(range(plan.num_buckets))


def test_pod_schedule_follows_filter_rule(setup):
    from repro.train.trainer import plan_pod_schedule

    params, plan, grads, _ = setup
    sched = plan_pod_schedule(plan, pod_phase=1, pod_interval=4)
    assert sched.selected == selected_buckets(plan.num_buckets, 1, 4)
    assert sched.bytes_per_worker == sum(
        plan.buckets[b].numel * 4 for b in sched.selected
    )


def test_simulate_schedule_hides_compressed_comm(setup):
    """With the coarse filter the planned comm fits under the backward
    pass; the dense plan of 'none' leaves communication exposed."""
    params, plan, grads, _ = setup
    hw = HardwareSpec.cloud_v100_30gbps()
    t_before, t_comp = 0.05, 0.1
    covap = get_compressor("covap", interval=8).plan_phase(plan, 1, world=64)
    dense = get_compressor("none").plan_phase(plan, 0, world=64)
    # scale the link so dense comm is ~2x the backward pass
    bw = dense.wire_bytes(64) / (2 * t_comp)
    r_dense = simulate_schedule(
        t_before, t_comp, dense, world=64, link_bw=bw
    )
    r_covap = simulate_schedule(
        t_before, t_comp, covap, world=64, link_bw=bw
    )
    assert r_covap["total"] < r_dense["total"]
    assert r_covap["exposed_comm"] < r_dense["exposed_comm"]
    assert r_covap["total"] >= t_before + t_comp - 1e-12


def test_compressed_ccr_below_dense(setup):
    params, plan, grads, _ = setup
    comp = get_compressor("covap", interval=8)
    scheds = plan_all_phases(comp, plan, world=64)
    dense = plan_all_phases(get_compressor("none"), plan, world=64)
    c_covap = compressed_ccr(scheds, t_comp=1e-4, world=64, link_bw=1e9)
    c_dense = compressed_ccr(dense, t_comp=1e-4, world=64, link_bw=1e9)
    assert c_covap < c_dense / 4  # ~8x filter on average


# ---- the repro.api facade ---------------------------------------------------

def test_resolve_interval_auto_is_ceil_of_analytic_ccr():
    import repro.api as api

    from repro.configs import get_reduced

    cfg = get_reduced("gpt2-paper")
    hw = HardwareSpec.cloud_v100_30gbps()
    choice = api.resolve_interval(
        "auto", cfg, global_batch=8, seq_len=64, dp_world=8, hw=hw
    )
    assert choice.auto and choice.ccr is not None
    expected = analytic_ccr(
        step_flops_per_chip=choice.step_flops_per_chip,
        grad_bytes=choice.grad_bytes,
        dp_world=8,
        hw=hw,
    )
    assert choice.ccr == pytest.approx(expected)
    assert choice.interval == select_interval(expected)
    assert choice.interval == min(64, math.ceil(expected))

    explicit = api.resolve_interval(
        6, cfg, global_batch=8, seq_len=64, dp_world=8, hw=hw
    )
    assert explicit.interval == 6 and not explicit.auto


def test_api_fit_auto_interval_end_to_end():
    """Acceptance: repro.api.fit(..., interval='auto') selects
    I = ceil(analytic_ccr) end-to-end on a CPU dry-run config."""
    import repro.api as api

    r = api.fit(
        "gpt2-paper", reduced=True, interval="auto", steps=3,
        vocab_size=128, seq_len=16, global_batch=4, dp_workers=8,
        log_every=1,
    )
    assert r.ccr is not None
    assert r.interval == select_interval(r.ccr)
    assert r.trainer.compressor.interval == r.interval
    assert len(r.history) >= 1 and r.final_loss is not None
    assert len(r.schedules) == r.trainer.compressor.num_phases(r.interval)
    # the static plan is what the trainer reports
    rep = r.trainer.schedule_report()
    assert rep["bytes_per_worker_per_phase"] == [
        s.bytes_per_worker for s in r.schedules
    ]


def test_api_plan_report_and_tune():
    import repro.api as api

    rep = api.plan_report(
        "gpt2-paper", reduced=True, interval="auto", dp_workers=8
    )
    assert rep["interval_auto"]
    assert rep["residual_ccr"] < rep["dense_ccr"]
    assert len(rep["phases"]) == rep["interval"] or rep["interval"] == 1

    rows = api.tune(
        "gpt2-paper", reduced=True, dp_workers=16,
        candidates=(("covap", {}), ("none", {}), ("oktopk", {})),
    )
    assert rows and all(
        set(r) >= {"compressor", "speedup", "volume_ratio"} for r in rows
    )
    by_name = {r["compressor"]: r for r in rows}
    assert by_name["oktopk"]["data_dependency"]
    assert not by_name["covap"]["data_dependency"]
    # covap must beat the uncompressed baseline under the timeline model
    assert by_name["covap"]["speedup"] >= by_name["none"]["speedup"]
