"""``hypothesis`` shim: real library when installed, otherwise a tiny
deterministic fallback sampler so the property tests still *run* (rather
than fail collection) on a clean environment.

Usage in test modules::

    from _hypothesis_compat import given, settings, st

The fallback implements just the strategy surface this repo uses —
``integers``, ``floats``, ``lists``, ``tuples``, ``one_of``,
``sampled_from`` — and a ``@given`` that draws ``max_examples`` samples
from a seeded ``random.Random`` (seeded per test name, so failures are
reproducible).  It does no shrinking and no coverage-guided search; it is
a sampler, not a property-testing engine.  Install ``hypothesis`` (the
``dev`` extra in pyproject.toml) for the real thing.
"""
from __future__ import annotations

import random

try:  # pragma: no cover - exercised implicitly by either branch
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng: random.Random):
            return self._draw(rng)

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

        @staticmethod
        def one_of(*strategies):
            return _Strategy(
                lambda rng: strategies[rng.randrange(len(strategies))].draw(rng)
            )

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.draw(rng) for _ in range(n)]

            return _Strategy(draw)

        @staticmethod
        def tuples(*strategies):
            return _Strategy(
                lambda rng: tuple(s.draw(rng) for s in strategies)
            )

    st = _St()

    def settings(max_examples: int = 20, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            # no functools.wraps: copying fn's signature would make pytest
            # resolve the drawn parameters as fixtures
            def wrapper(*args, **kwargs):
                # @settings is applied outside @given, so read the budget
                # off the wrapper at call time
                max_examples = getattr(wrapper, "_max_examples", 20)
                rng = random.Random(fn.__name__)  # reproducible per test
                for _ in range(max_examples):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco
