"""Beyond-paper features: hierarchical multi-pod COVAP + bf16-wire option."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build_plan, get_compressor

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def test_covap_bf16_wire_conservation():
    """out + r' == t still holds with the bf16 wire (single worker)."""
    params = {"w": jnp.zeros((256,))}
    plan = build_plan(params, bucket_bytes=256, max_buckets=8, interval=4)
    comp = get_compressor("covap", interval=4, wire_dtype="bfloat16")
    key = jax.random.PRNGKey(0)
    g = {"w": jax.random.normal(key, (256,))}
    r = {"w": jax.random.normal(jax.random.fold_in(key, 1), (256,))}
    out, new_r, stats = comp.sync(g, r, plan=plan, phase=0, step=0,
                                  axis_names=())
    coeff = comp.schedule.coefficient(0)
    t = g["w"] + coeff * r["w"]
    np.testing.assert_allclose(
        np.asarray(out["w"] + new_r["w"]), np.asarray(t), rtol=1e-5, atol=1e-6
    )
    # wire bytes: selected ~1/4 of buckets at 2 bytes/elem
    dense = stats.dense_bytes
    assert stats.bytes_per_worker < dense / 4 * 0.6  # ~ dense/8


def test_covap_bf16_wire_volume_ratio():
    params = {"w": jnp.zeros((4096,))}
    plan = build_plan(params, bucket_bytes=1024, max_buckets=16, interval=4)
    comp = get_compressor("covap", interval=4, wire_dtype="bfloat16")
    st = comp.init_state(params, plan)
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (4096,))}
    _, _, stats = comp.sync(g, st, plan=plan, phase=0, step=0, axis_names=())
    assert stats.volume_ratio > 7.0  # I=4 x fp32->bf16 2x


def test_hierarchical_trainer_subprocess():
    code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs import get_reduced
from repro.models import build_model
from repro.optim import adamw
from repro.train.trainer import TrainConfig, Trainer
from repro.data import DataConfig, make_loader

mesh = Mesh(np.array(jax.devices()[:8]).reshape(2, 2, 2),
            ("pod", "data", "model"))
cfg = get_reduced("gpt2-paper").with_(vocab_size=128)
model = build_model(cfg)
tc = TrainConfig(compressor="covap", interval=2, pod_interval=4,
                 bucket_bytes=1 << 13, max_buckets=16, log_every=100)
tr = Trainer(model, adamw(3e-3), tc, mesh=mesh, dp_axes=("pod", "data"))
assert tr.hierarchical and tr.num_phases == 4
state = tr.init_state(jax.random.PRNGKey(0))
assert jax.tree.leaves(state["params"])[0].shape[0] == 2  # per-pod axis

dc = DataConfig(vocab_size=128, seq_len=24, global_batch=8,
                corpus_tokens=1 << 12)
loader = iter(make_loader(dc))
losses = []
for i in range(8):
    batch = next(loader)
    phase = state["step"] % tr.num_phases
    p, o, c, m = tr._phase_fn(phase)(
        state["params"], state["opt"], state["comp"], batch,
        jnp.int32(state["step"]))
    state = {"params": p, "opt": o, "comp": c, "step": state["step"] + 1}
    losses.append(float(m["loss"]))
assert losses[-1] < losses[0], losses
pv = jax.tree.leaves(state["params"])[0]
drift = float(jnp.max(jnp.abs(pv[0] - pv[1])))
assert drift < 1.0, drift          # bounded local-SGD drift
assert drift > 0.0                 # pods genuinely independent between syncs
print("OK drift", drift)
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=560, env=env,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "OK drift" in r.stdout
