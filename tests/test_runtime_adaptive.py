"""Adaptive runtime subsystem: monitor -> controller -> transitions -> trace.

Acceptance invariants pinned here:

* with an injected comm slowdown the controller converges the interval to
  within ±1 of ``ceil(measured CCR)`` in a bounded number of re-plans;
* EF residual norms are preserved across every carry transition;
* with autotune off, ``Trainer.run`` outputs are bit-for-bit identical to
  the static PR-1 loop;
* checkpoints round-trip the EF residual (it survives restarts);
* the Chrome-trace export round-trips into ``perfmodel.calibrate_from_trace``.
"""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import build_plan, get_compressor
from repro.core.perfmodel import calibrate_from_trace
from repro.data import DataConfig, make_loader
from repro.models import build_model
from repro.optim import adamw
from repro.runtime import (
    AutotuneConfig,
    CCRMonitor,
    PhaseProbe,
    PhaseSample,
    ReplanController,
    TimelineTracer,
    carry_comp_state,
    residual_norm,
    synthetic_probe,
)
from repro.train.trainer import TrainConfig, Trainer
from repro import checkpoint


def make_trainer(compressor="covap", interval=2, **copts):
    cfg = get_reduced("gpt2-paper").with_(vocab_size=256)
    model = build_model(cfg)
    tc = TrainConfig(
        compressor=compressor, compressor_options=copts, interval=interval,
        bucket_bytes=1 << 14, max_buckets=32, log_every=10 ** 9,
    )
    return Trainer(model, adamw(3e-3), tc)


def loader(n=64):
    dc = DataConfig(vocab_size=256, seq_len=32, global_batch=8)
    return iter(make_loader(dc))


# ---------------------------------------------------------------------------
# monitor
# ---------------------------------------------------------------------------

def test_monitor_ring_buffer_and_running_ccr():
    mon = CCRMonitor(window=4)
    for s in range(10):
        mon.record_step(s, s % 2, 0.1)
    assert mon.mean_step_time() == pytest.approx(0.1)
    # window=4: only the last 4 samples count
    for i, c in enumerate([9.0, 9.0, 2.0, 2.0, 2.0, 2.0]):
        mon.record_sample(PhaseSample(phase=0, t_comp=1.0, t_comm=c, step=i))
    assert mon.num_samples == 4
    assert mon.measured_ccr() == pytest.approx(2.0)
    assert mon.measured_ccr(phase=1) is None
    s = mon.summary()
    assert s["probe_samples"] == 4 and s["measured_ccr"] == pytest.approx(2.0)


def test_monitor_per_phase_decomposition():
    mon = CCRMonitor(window=8)
    mon.record_sample(PhaseSample(phase=0, t_comp=1.0, t_comm=4.0))
    mon.record_sample(PhaseSample(phase=1, t_comp=1.0, t_comm=1.0))
    assert mon.measured_ccr(phase=0) == pytest.approx(4.0)
    assert mon.measured_ccr(phase=1) == pytest.approx(1.0)
    assert mon.measured_ccr() == pytest.approx(2.5)


# ---------------------------------------------------------------------------
# controller policy
# ---------------------------------------------------------------------------

def test_controller_hysteresis_band():
    cfg = AutotuneConfig(hysteresis=0.25, patience=1, cooldown_steps=0)
    ctrl = ReplanController(cfg, interval=4)
    # in (3 - 0.25, 4 + 0.25]: consistent, no replan
    for ccr in (2.8, 3.0, 4.0, 4.2):
        assert not ctrl.observe(0, ccr).replan
    assert ctrl.interval == 4


def test_controller_patience_and_cooldown():
    cfg = AutotuneConfig(hysteresis=0.1, patience=3, cooldown_steps=100)
    ctrl = ReplanController(cfg, interval=2)
    assert not ctrl.observe(0, 8.0).replan      # pending 1/3
    assert not ctrl.observe(4, 8.0).replan      # pending 2/3
    d = ctrl.observe(8, 8.0)                    # pending 3/3 -> replan
    assert d.replan and d.interval == 8
    # cooldown: immediately drifting again must NOT replan
    for step in (12, 16, 20):
        assert not ctrl.observe(step, 30.0).replan
    assert ctrl.observe(8 + 100, 30.0).replan


def test_controller_max_replans_bounds_switching():
    cfg = AutotuneConfig(patience=1, cooldown_steps=0, max_replans=2)
    ctrl = ReplanController(cfg, interval=1)
    flip = [10.0, 1.0]
    n = sum(
        ctrl.observe(s, flip[s % 2]).replan for s in range(50)
    )
    assert n == 2


def test_controller_converges_within_one_of_ceil():
    """Pure-policy convergence: any persistent measured CCR pulls the
    interval to within ±1 of its ceil in <= 2 re-plans."""
    for ccr in (0.3, 1.7, 3.2, 5.5, 12.9, 40.0):
        cfg = AutotuneConfig(patience=2, cooldown_steps=0)
        ctrl = ReplanController(cfg, interval=4)
        for step in range(0, 64, 4):
            ctrl.observe(step, ccr)
        assert abs(ctrl.interval - max(1, math.ceil(ccr))) <= 1
        assert ctrl.replans <= 2


# ---------------------------------------------------------------------------
# transitions
# ---------------------------------------------------------------------------

def _ef_setup(old_i=2, new_i=4):
    params = {"w": jnp.ones((8, 4)), "b": jnp.ones((4,))}
    residual = {"w": jnp.full((8, 4), 0.5), "b": jnp.full((4,), -0.25)}
    new_comp = get_compressor("covap", interval=new_i)
    new_plan = build_plan(params, bucket_bytes=64, max_buckets=8,
                          interval=new_i)
    return params, residual, new_comp, new_plan


def test_transition_carry_preserves_norm_bitforbit():
    params, residual, comp, plan = _ef_setup()
    before = residual_norm(residual)
    new_state, rep = carry_comp_state(
        residual, new_compressor=comp, new_plan=plan, params_like=params,
        old_interval=2, new_interval=4, policy="carry",
    )
    assert rep.policy == "carry"
    assert rep.norm_before == rep.norm_after == before
    for k in residual:
        np.testing.assert_array_equal(np.asarray(new_state[k]),
                                      np.asarray(residual[k]))


def test_transition_flush_zeroes_and_reports_drop():
    params, residual, comp, plan = _ef_setup()
    new_state, rep = carry_comp_state(
        residual, new_compressor=comp, new_plan=plan, params_like=params,
        old_interval=2, new_interval=4, policy="flush",
    )
    assert rep.policy == "flush"
    assert rep.norm_after == 0.0
    assert rep.norm_dropped == pytest.approx(rep.norm_before)
    assert residual_norm(new_state) == 0.0


def test_transition_rescale_shrinking_cadence():
    params, residual, comp, plan = _ef_setup(old_i=8, new_i=2)
    new_state, rep = carry_comp_state(
        residual, new_compressor=comp, new_plan=plan, params_like=params,
        old_interval=8, new_interval=2, policy="rescale",
    )
    assert rep.policy == "rescale"
    assert rep.norm_after == pytest.approx(rep.norm_before * 2 / 8, rel=1e-6)
    # growing cadence: rescale degrades to carry
    _, rep2 = carry_comp_state(
        residual, new_compressor=comp, new_plan=plan, params_like=params,
        old_interval=2, new_interval=8, policy="rescale",
    )
    assert rep2.policy == "carry"
    assert rep2.norm_after == rep2.norm_before


def test_transition_reinit_when_structure_changes():
    """I -> 1 drops the EF stage (state () instead of a residual pytree):
    no carry exists, the dropped norm must be surfaced."""
    params, residual, _, _ = _ef_setup()
    comp1 = get_compressor("covap", interval=1)
    plan1 = build_plan(params, bucket_bytes=64, max_buckets=8, interval=1)
    new_state, rep = carry_comp_state(
        residual, new_compressor=comp1, new_plan=plan1, params_like=params,
        old_interval=4, new_interval=1, policy="carry",
    )
    assert rep.policy == "reinit"
    assert new_state == ()
    assert rep.norm_dropped == pytest.approx(rep.norm_before)


# ---------------------------------------------------------------------------
# end-to-end: trainer + injected comm slowdown (the acceptance scenario)
# ---------------------------------------------------------------------------

def test_injected_slowdown_converges_and_preserves_residual():
    tr = make_trainer(interval=2)
    state = tr.init_state(jax.random.PRNGKey(0))
    injected_ccr = 5.4
    cfg = AutotuneConfig(
        measure_every=2, warmup_steps=2, window=2, patience=2,
        cooldown_steps=4, probe=synthetic_probe(0.01, injected_ccr),
    )
    state = tr.run(state, loader(), steps=24, log=None, autotune=cfg)
    target = math.ceil(injected_ccr)
    assert abs(tr.tc.interval - target) <= 1
    assert 1 <= tr.runtime.controller.replans <= cfg.max_replans
    assert tr.transitions, "a re-plan must have crossed a transition"
    for rep in tr.transitions:
        if rep.policy == "carry":
            assert rep.norm_before == rep.norm_after
    # training continued sanely after the switch
    assert state["step"] == 24
    assert tr.num_phases == tr.tc.interval


def test_injected_drift_replans_back_down():
    """CCR drops mid-run (link recovers): the controller must follow."""
    tr = make_trainer(interval=6)
    state = tr.init_state(jax.random.PRNGKey(0))
    ccr_of_step = lambda step: 6.0 if step < 10 else 1.5
    cfg = AutotuneConfig(
        measure_every=2, warmup_steps=0, window=1, patience=2,
        cooldown_steps=2, probe=synthetic_probe(0.01, ccr_of_step),
    )
    state = tr.run(state, loader(), steps=30, log=None, autotune=cfg)
    assert abs(tr.tc.interval - 2) <= 1
    assert tr.runtime.controller.replans <= cfg.max_replans


def test_autotune_off_is_bitforbit_static():
    """PR-1 invariant: autotune=None must not perturb a single bit."""
    def run_once(use_run):
        tr = make_trainer(interval=2)
        state = tr.init_state(jax.random.PRNGKey(0))
        dc = DataConfig(vocab_size=256, seq_len=32, global_batch=8)
        it = iter(make_loader(dc))
        if use_run:
            state = tr.run(state, it, steps=6, log=None, autotune=None)
        else:
            for _ in range(6):  # the PR-1 static loop, verbatim
                batch = next(it)
                phase = state["step"] % tr.num_phases
                fn = tr._phase_fn(phase)
                p, o, c, m = fn(state["params"], state["opt"], state["comp"],
                                batch, jnp.asarray(state["step"], jnp.int32))
                state = {"params": p, "opt": o, "comp": c,
                         "step": state["step"] + 1}
        return state

    a = run_once(True)
    b = run_once(False)
    for la, lb in zip(jax.tree_util.tree_leaves(a["params"]),
                      jax.tree_util.tree_leaves(b["params"])):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    for la, lb in zip(jax.tree_util.tree_leaves(a["comp"]),
                      jax.tree_util.tree_leaves(b["comp"])):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_real_phase_probe_produces_finite_sample():
    tr = make_trainer(interval=2)
    state = tr.init_state(jax.random.PRNGKey(0))
    it = loader()
    batch = next(it)
    state = tr.run(state, iter([batch] * 2), steps=2, log=None)
    probe = PhaseProbe(tr, warmup=1, iters=1)
    sample = probe(state, batch, phase=state["step"] % tr.num_phases)
    assert sample.t_comp > 0
    assert sample.t_comm >= 0
    assert np.isfinite(sample.ccr)


# ---------------------------------------------------------------------------
# trace export + perfmodel calibration round trip
# ---------------------------------------------------------------------------

def test_trace_chrome_export_and_calibration(tmp_path):
    tracer = TimelineTracer()
    for s in range(4):
        tracer.record_step(s, s % 2, 0.12)
        tracer.record_sample(
            PhaseSample(phase=s % 2, t_comp=0.10, t_comm=0.02, step=s),
            bytes_on_wire=1_000_000,
        )
    tracer.record_replan(3, 2, 4, "test")
    path = str(tmp_path / "trace.json")
    tracer.save(path)

    with open(path) as f:
        trace = json.load(f)
    evs = trace["traceEvents"]
    assert any(e.get("ph") == "M" for e in evs)          # process names
    assert any(e.get("ph") == "i" for e in evs)          # replan marker
    assert all("ts" in e for e in evs if e.get("ph") == "X")

    cal = calibrate_from_trace(trace)
    assert cal["t_comp"] == pytest.approx(0.10, rel=1e-6)
    assert cal["t_comm"] == pytest.approx(0.02, rel=1e-6)
    assert cal["ccr"] == pytest.approx(0.2, rel=1e-6)
    assert cal["mean_step_s"] == pytest.approx(0.12, rel=1e-6)
    # effective link bandwidth: 1 MB / 20 ms = 50 MB/s
    assert cal["link_bw"] == pytest.approx(1_000_000 / 0.02, rel=1e-6)


def test_adaptive_run_emits_planned_and_measured_views(tmp_path):
    path = str(tmp_path / "run_trace.json")
    tr = make_trainer(interval=2)
    state = tr.init_state(jax.random.PRNGKey(0))
    cfg = AutotuneConfig(
        measure_every=2, warmup_steps=1, window=2, patience=1,
        cooldown_steps=2, probe=synthetic_probe(0.01, 3.3), trace_path=path,
    )
    tr.run(state, loader(), steps=10, log=None, autotune=cfg)
    with open(path) as f:
        trace = json.load(f)
    cats = {c for e in trace["traceEvents"]
            for c in e.get("cat", "").split(",") if c}
    assert "measured" in cats and "planned" in cats and "control" in cats
    cal = calibrate_from_trace(trace)
    assert cal["ccr"] == pytest.approx(3.3, rel=1e-3)


# ---------------------------------------------------------------------------
# checkpointing: EF residual survives restarts (satellite)
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrips_ef_residual(tmp_path):
    tr = make_trainer(interval=2)
    state = tr.init_state(jax.random.PRNGKey(0))
    state = tr.run(state, loader(), steps=3, log=None)
    norm = residual_norm(state["comp"])
    assert norm > 0, "EF must have accumulated a residual"

    checkpoint.save_train_state(str(tmp_path), state, interval=tr.tc.interval)
    extra = checkpoint.load_extra(str(tmp_path), state["step"])
    assert extra["interval"] == 2 and extra["has_comp_state"]

    tr2 = make_trainer(interval=2)
    like = tr2.init_state(jax.random.PRNGKey(1))
    restored, extra2 = checkpoint.restore_train_state(str(tmp_path), like)
    assert restored["step"] == state["step"]
    assert extra2["interval"] == 2
    for la, lb in zip(jax.tree_util.tree_leaves(restored["comp"]),
                      jax.tree_util.tree_leaves(state["comp"])):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    # training resumes from the restored residual without error
    tr2.run(restored, loader(), steps=2, log=None)


def test_checkpoint_restore_into_replanned_interval(tmp_path):
    """Restart with a different interval: the saved residual crosses the
    boundary through Trainer.replan, norm preserved by the carry."""
    tr = make_trainer(interval=2)
    state = tr.init_state(jax.random.PRNGKey(0))
    state = tr.run(state, loader(), steps=3, log=None)
    checkpoint.save_train_state(str(tmp_path), state, interval=2)

    tr2 = make_trainer(interval=2)
    like = tr2.init_state(jax.random.PRNGKey(1))
    restored, extra = checkpoint.restore_train_state(str(tmp_path), like)
    norm = residual_norm(restored["comp"])
    restored, rep = tr2.replan(4, restored, step=restored["step"])
    assert tr2.tc.interval == 4 and tr2.num_phases == 4
    assert rep.policy == "carry"
    assert rep.norm_before == pytest.approx(norm)
    assert rep.norm_after == pytest.approx(norm)
    tr2.run(restored, loader(), steps=2, log=None)


def test_checkpoint_restore_across_ef_boundary(tmp_path):
    """Saved with EF residuals (I=2), restored into a no-EF config (I=1)
    and vice versa: params/opt restore, the incompatible compressor state
    falls back to fresh init, and ``comp_restored`` flags the drop."""
    tr = make_trainer(interval=2)
    state = tr.init_state(jax.random.PRNGKey(0))
    state = tr.run(state, loader(), steps=3, log=None)
    checkpoint.save_train_state(str(tmp_path), state, interval=2)

    tr1 = make_trainer(interval=1)          # COVAP I=1: comp state is ()
    like = tr1.init_state(jax.random.PRNGKey(1))
    restored, extra = checkpoint.restore_train_state(str(tmp_path), like)
    assert extra["comp_restored"] is False
    assert restored["comp"] == ()
    for la, lb in zip(jax.tree_util.tree_leaves(restored["params"]),
                      jax.tree_util.tree_leaves(state["params"])):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))

    # reverse: saved without EF state, restored into an EF config
    d2 = tmp_path / "rev"
    state1 = tr1.init_state(jax.random.PRNGKey(0))
    state1 = tr1.run(state1, loader(), steps=2, log=None)
    checkpoint.save_train_state(str(d2), state1, interval=1)
    tr2 = make_trainer(interval=2)
    like2 = tr2.init_state(jax.random.PRNGKey(1))
    restored2, extra2 = checkpoint.restore_train_state(str(d2), like2)
    assert extra2["comp_restored"] is False
    assert residual_norm(restored2["comp"]) == 0.0   # fresh zeros
    tr2.run(restored2, loader(), steps=2, log=None)  # trains fine


def test_chunked_runs_share_adaptive_runtime():
    """A live AdaptiveRuntime passed to run() keeps controller state
    across chunks (the checkpoint-every loop), so patience accumulates
    instead of resetting."""
    from repro.runtime import AdaptiveRuntime

    tr = make_trainer(interval=2)
    state = tr.init_state(jax.random.PRNGKey(0))
    cfg = AutotuneConfig(
        measure_every=2, warmup_steps=0, window=2, patience=4,
        cooldown_steps=0, probe=synthetic_probe(0.01, 5.4),
    )
    rt = AdaptiveRuntime(tr, cfg)
    it = loader()
    # 4 chunks x 2 steps = 1 probe decision per chunk; patience=4 only
    # trips if pending survives chunk boundaries
    for _ in range(4):
        state = tr.run(state, it, steps=2, log=None, autotune=rt)
    assert tr.runtime is rt
    assert rt.controller.replans == 1
    assert tr.tc.interval == 6


# ---------------------------------------------------------------------------
# api surface
# ---------------------------------------------------------------------------

def test_fit_interval_adaptive_smoke():
    import repro.api as api

    r = api.fit(
        "gpt2-paper", reduced=True, interval="adaptive", steps=8, log=None,
        autotune=AutotuneConfig(
            measure_every=2, warmup_steps=1, window=2, patience=1,
            cooldown_steps=2, probe=synthetic_probe(0.01, 2.5),
        ),
    )
    assert r.autotune is not None
    assert r.autotune["measured_ccr"] == pytest.approx(2.5)
    assert r.final_interval == 3          # ceil(2.5)
    assert r.trainer.runtime.controller.replans >= 1


def test_fit_static_has_no_runtime():
    import repro.api as api

    r = api.fit("gpt2-paper", reduced=True, interval=2, steps=2, log=None)
    assert r.autotune is None
    assert r.final_interval == r.interval == 2


def test_tune_measured_reports_ccr_columns():
    import repro.api as api

    rows = api.tune(
        "gpt2-paper", dp_workers=8, measured=True, measure_steps=1,
        candidates=(("covap", {}), ("none", {})),
    )
    assert all("measured_ccr" in r and "analytic_ccr" in r for r in rows)
    assert all(np.isfinite(r["measured_ccr"]) for r in rows)
    assert all(r["measured_interval"] >= 1 for r in rows)
