"""Overlap execution engine: ReadyOrder properties, fused==post bit-for-bit
equivalence (single-process and 8-worker CPU mesh), the HLO interleaving
checker, and the fused EF kernel's wiring into the segmented execute path."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import build_plan, build_ready_order, get_compressor
from repro.core import perfmodel as pm
from repro.core.overlap import (
    overlapped_loss_and_grads,
    supports_fused_overlap,
)
from repro.data import DataConfig, make_loader
from repro.launch.hlo_analysis import check_interleaving
from repro.models import build_model
from repro.optim import adamw
from repro.train.trainer import (
    TrainConfig,
    Trainer,
    strip_pod_block,
)

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


# ---------------------------------------------------------------------------
# ReadyOrder: reverse-topological readiness properties
# ---------------------------------------------------------------------------

def _arch_plan(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return build_plan(shapes, bucket_bytes=1 << 13, max_buckets=64, interval=4)


@pytest.mark.parametrize(
    "arch", ["gpt2-paper", "deepseek-moe-16b", "seamless-m4t-medium"]
)
def test_ready_order_is_reverse_layer_permutation(arch):
    """For transformer, MoE and enc-dec stacks: ReadyOrder is a permutation
    of the buckets, monotone in reverse layer order (deeper layer -> lower
    rank), with head buckets first and embedding buckets last."""
    plan = _arch_plan(arch)
    ready = build_ready_order(plan)
    nb = plan.num_buckets

    # a permutation of the buckets
    assert sorted(ready.ranks) == list(range(nb))
    assert sorted(ready.order) == list(range(nb))
    assert len(ready.bucket_layer) == nb

    # strictly consistent with reverse layer order: a bucket whose last
    # gradient comes from a deeper layer is issued strictly earlier
    for a in range(nb):
        for b in range(nb):
            if ready.bucket_layer[a] > ready.bucket_layer[b]:
                assert ready.ranks[a] < ready.ranks[b]

    def buckets_only_in(marker):
        # buckets ALL of whose segments belong to `marker` leaves (a DDP
        # packer may straddle the embed/head boundary in one bucket; such
        # a bucket is ready only with its shallowest member)
        out = set()
        for bi, bucket in enumerate(plan.buckets):
            if all(
                marker in plan.leaf_paths[seg.leaf_idx]
                for seg in bucket.segments
            ):
                out.add(bi)
        return out

    head = buckets_only_in("head")
    embed = buckets_only_in("embed")
    assert head and embed
    # the head's VJP runs first in the backward pass; the embedding's last
    assert max(ready.ranks[b] for b in head) < min(
        ready.ranks[b] for b in embed
    )


def test_ready_order_stacked_rows_reverse():
    """Within a scan-stacked leaf, higher rows (later layers) are ready
    earlier."""
    plan = _arch_plan("gpt2-paper")
    ready = build_ready_order(plan)
    # collect (row, rank) for single-leaf block buckets
    rows = {}
    for bi, bucket in enumerate(plan.buckets):
        segs = bucket.segments
        if any("blocks" not in plan.leaf_paths[s.leaf_idx] for s in segs):
            continue
        rows.setdefault(min(s.row_lo for s in segs), []).append(
            ready.ranks[bi]
        )
    keys = sorted(rows)
    assert len(keys) >= 2
    for lo, hi in zip(keys, keys[1:]):
        # every bucket of row `hi` issues before every bucket of row `lo`
        assert max(rows[hi]) < min(rows[lo])


def test_ready_order_toy_tree_is_reverse_param_order():
    params = {"a": jnp.zeros((8, 4)), "b": jnp.zeros((8, 4)),
              "c": jnp.zeros((4,))}
    plan = build_plan(params, bucket_bytes=64, max_buckets=16, interval=2)
    ready = build_ready_order(plan)
    assert sorted(ready.ranks) == list(range(plan.num_buckets))
    # unknown paths: one depth slot per leaf, so readiness is reverse
    # parameter order — the last leaf's bucket issues first
    first = ready.order[0]
    last = ready.order[-1]
    assert plan.buckets[first].segments[0].leaf_idx >= \
        plan.buckets[last].segments[0].leaf_idx


def test_schedule_carries_ready_ranks():
    params = {"w": jnp.zeros((64, 16)), "b": jnp.zeros((16,))}
    plan = build_plan(params, bucket_bytes=512, max_buckets=8, interval=4)
    comp = get_compressor("covap", interval=4)
    sched = comp.plan_phase(plan, 0)
    assert len(sched.ready_ranks) == len(sched.calls)
    order = sched.issue_order()
    ranks = [sched.ready_ranks[i] for i in order]
    assert ranks == sorted(ranks)
    # dense plan: every bucket, ranks are exactly the ReadyOrder ranks
    dense = get_compressor("none").plan_phase(plan, 0)
    ready = build_ready_order(plan)
    assert dense.ready_ranks == tuple(
        ready.rank_of(b) for b in dense.selected
    )


# ---------------------------------------------------------------------------
# fused == post (single process)
# ---------------------------------------------------------------------------

def _train(compressor, overlap, steps, **copts):
    cfg = get_reduced("gpt2-paper").with_(vocab_size=256)
    model = build_model(cfg)
    tc = TrainConfig(
        compressor=compressor, compressor_options=copts, interval=4,
        bucket_bytes=1 << 14, max_buckets=32, log_every=10 ** 9,
        overlap=overlap,
    )
    tr = Trainer(model, adamw(3e-3), tc)
    state = tr.init_state(jax.random.PRNGKey(0))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8,
                    corpus_tokens=1 << 14)
    loader = iter(make_loader(dc))
    for _ in range(steps):
        batch = next(loader)
        fn = tr._phase_fn(state["step"] % tr.num_phases)
        p, o, c, m = fn(state["params"], state["opt"], state["comp"], batch,
                        jnp.int32(state["step"]))
        state = {"params": p, "opt": o, "comp": c, "step": state["step"] + 1}
    return state


def _assert_tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("compressor", ["covap", "none", "fp16"])
def test_fused_equals_post_single_process(compressor):
    """A full phase cycle + one: params AND EF residuals bit-for-bit."""
    steps = 5  # full covap cycle (4 phases) + 1
    post = _train(compressor, "post", steps)
    fused = _train(compressor, "fused", steps)
    _assert_tree_equal(post["params"], fused["params"])
    _assert_tree_equal(post["comp"], fused["comp"])


def test_fused_rejects_flat_and_leaf_pipelines():
    cfg = get_reduced("gpt2-paper").with_(vocab_size=256)
    model = build_model(cfg)
    for name in ("topk", "powersgd"):
        comp = get_compressor(name)
        assert not supports_fused_overlap(comp)
        tc = TrainConfig(compressor=name, interval=4, bucket_bytes=1 << 14,
                         max_buckets=16, overlap="fused")
        tr = Trainer(model, adamw(1e-3), tc)
        with pytest.raises(ValueError, match="overlap"):
            tr._phase_fn(0)


# ---------------------------------------------------------------------------
# fused == post on an 8-worker CPU mesh (the acceptance criterion) + the
# compiled-HLO interleaving check.  Subprocess: the fake device count must
# be set before jax initialises.
# ---------------------------------------------------------------------------

_MESH_SUB = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs import get_reduced
from repro.data import DataConfig, make_loader
from repro.launch.hlo_analysis import check_interleaving
from repro.models import build_model
from repro.optim import adamw
from repro.train.trainer import TrainConfig, Trainer

mesh = Mesh(np.array(jax.devices()[:8]), ("data",))
cfg = get_reduced("gpt2-paper").with_(vocab_size=256)
model = build_model(cfg)

def run(overlap, compressor, steps=5):
    tc = TrainConfig(compressor=compressor, interval=4, bucket_bytes=1 << 14,
                     max_buckets=32, log_every=10 ** 9, overlap=overlap)
    tr = Trainer(model, adamw(3e-3), tc, mesh=mesh, dp_axes=("data",))
    state = tr.init_state(jax.random.PRNGKey(0))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8,
                    corpus_tokens=1 << 14)
    loader = iter(make_loader(dc))
    for _ in range(steps):
        batch = next(loader)
        fn = tr._phase_fn(state["step"] % tr.num_phases)
        p, o, c, m = fn(state["params"], state["opt"], state["comp"], batch,
                        jnp.int32(state["step"]))
        state = {"params": p, "opt": o, "comp": c,
                 "step": state["step"] + 1}
    return tr, state, batch

for compressor in ("covap", "none"):
    tr_p, post, batch = run("post", compressor)
    tr_f, fused, _ = run("fused", compressor)
    for x, y in zip(jax.tree.leaves(post["params"]),
                    jax.tree.leaves(fused["params"])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(jax.tree.leaves(post["comp"]),
                    jax.tree.leaves(fused["comp"])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    print(compressor, "EQUAL")

    # interleaving: the fused module schedules at least one bucket
    # collective before the final gradient-producing fusion (shared
    # harness with the benchmarks.run --smoke "overlap" gate)
    from repro.launch.overlap_gate import compile_and_check
    r = compile_and_check(tr_f, fused, batch)
    assert r.num_collectives > 0, r
    assert r.interleaved, r
    print(compressor, "INTERLEAVED", r.before_final_grad)

# hierarchical pods: fused == post numerically (XLA fusion choices may
# differ at the ulp level between the two programs; bitwise pinning is a
# pure-DP-mesh property)
from repro.launch.mesh import make_mesh_compat
hmesh = make_mesh_compat((2, 4), ("pod", "data"))

def run_hier(overlap, steps=4):
    tc = TrainConfig(compressor="covap", interval=2, pod_interval=2,
                     bucket_bytes=1 << 14, max_buckets=16,
                     log_every=10 ** 9, overlap=overlap)
    tr = Trainer(model, adamw(3e-3), tc, mesh=hmesh,
                 dp_axes=("pod", "data"))
    state = tr.init_state(jax.random.PRNGKey(0))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8,
                    corpus_tokens=1 << 13)
    loader = iter(make_loader(dc))
    for _ in range(steps):
        b = next(loader)
        fn = tr._phase_fn(state["step"] % tr.num_phases)
        p, o, c, m = fn(state["params"], state["opt"], state["comp"], b,
                        jnp.int32(state["step"]))
        state = {"params": p, "opt": o, "comp": c,
                 "step": state["step"] + 1}
    return state

hp, hf = run_hier("post"), run_hier("fused")
for x, y in zip(jax.tree.leaves(hp["params"]), jax.tree.leaves(hf["params"])):
    np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                               rtol=1e-5, atol=1e-6)
for x, y in zip(jax.tree.leaves(hp["comp"]), jax.tree.leaves(hf["comp"])):
    np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                               rtol=1e-5, atol=1e-6)
print("HIER_CLOSE")
"""


def test_fused_equals_post_on_cpu_mesh():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_MESH_SUB)],
        capture_output=True, text=True, timeout=560, env=env,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    assert r.stdout.count("EQUAL") == 2
    assert r.stdout.count("INTERLEAVED") == 2
    assert "HIER_CLOSE" in r.stdout


# ---------------------------------------------------------------------------
# interleaving checker unit tests (synthetic HLO)
# ---------------------------------------------------------------------------

_HLO_INTERLEAVED = """
HloModule m
ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  %g1 = f32[1024]{0} fusion(f32[1024]{0} %p0), kind=kLoop, calls=%fc.1
  %ar1 = f32[1024]{0} all-reduce(f32[1024]{0} %g1), to_apply=%add
  %g2 = f32[1024]{0} fusion(f32[1024]{0} %p0), kind=kLoop, calls=%fc.2
  %ar2 = f32[1024]{0} all-reduce(f32[1024]{0} %g2), to_apply=%add
  %out = f32[1024]{0} add(f32[1024]{0} %ar1, f32[1024]{0} %ar2)
}
"""

_HLO_SERIAL = """
HloModule m
ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  %g1 = f32[1024]{0} fusion(f32[1024]{0} %p0), kind=kLoop, calls=%fc.1
  %g2 = f32[1024]{0} fusion(f32[1024]{0} %g1), kind=kLoop, calls=%fc.2
  %ar1 = f32[1024]{0} all-reduce(f32[1024]{0} %g1), to_apply=%add
  %ar2 = f32[1024]{0} all-reduce(f32[1024]{0} %g2), to_apply=%add
  %out = f32[1024]{0} add(f32[1024]{0} %ar1, f32[1024]{0} %ar2)
}
"""


def test_check_interleaving_synthetic():
    r = check_interleaving(_HLO_INTERLEAVED)
    assert r.num_collectives == 2
    # ar1 is scheduled before g2 (the final grad-producing fusion) and is
    # structurally independent of it
    assert r.interleaved and r.before_final_grad == 1
    assert r.independent >= 1

    r = check_interleaving(_HLO_SERIAL)
    assert r.num_collectives == 2
    assert not r.interleaved and r.before_final_grad == 0


def test_check_interleaving_ignores_scalar_psums():
    hlo = """
HloModule m
ENTRY %main (p0: f32[]) -> f32[] {
  %p0 = f32[] parameter(0)
  %loss = f32[] all-reduce(f32[] %p0), to_apply=%add
  %g = f32[] fusion(f32[] %loss), kind=kLoop, calls=%fc
}
"""
    r = check_interleaving(hlo)
    assert r.num_collectives == 0 and not r.interleaved


# ---------------------------------------------------------------------------
# overlap fraction accounting (predicted vs achieved)
# ---------------------------------------------------------------------------

def test_overlap_fraction_bounds():
    # fully hidden: comm fits entirely under remaining compute
    sim = pm.simulate_overlap(0.1, [0.2] * 4, [0.01] * 4)
    assert pm.overlap_fraction(sim) > 0.7
    # fully exposed: all comm after the last bucket's compute
    sim = pm.simulate_overlap(0.0, [0.0] * 4, [0.1] * 4)
    assert pm.overlap_fraction(sim) == 0.0
    assert pm.overlap_fraction({"comm_total": 0.0}) == 1.0

    assert pm.achieved_overlap_fraction(1.0, 0.5, 1.0) == 1.0
    assert pm.achieved_overlap_fraction(1.0, 0.5, 1.5) == 0.0
    assert abs(pm.achieved_overlap_fraction(1.0, 0.5, 1.25) - 0.5) < 1e-9
    assert pm.achieved_overlap_fraction(1.0, 0.0, 2.0) == 1.0


def test_simulate_schedule_ready_order():
    # unequal leaf sizes -> unequal per-bucket comm times, so a regression
    # that permutes comp but not comm (or neither) changes the timeline
    params = {"embed": {"table": jnp.zeros((64, 16))},
              "head": {"w": jnp.zeros((16, 100))}}
    plan = build_plan(params, bucket_bytes=1024, max_buckets=16, interval=2)
    sched = get_compressor("none").plan_phase(plan, 0, world=8)
    a = pm.simulate_schedule(0.1, 1.0, sched, world=8, link_bw=1e6)
    b = pm.simulate_schedule(0.1, 1.0, sched, world=8, link_bw=1e6,
                             ready_order=True)
    # same work either way, just a different timeline layout
    assert abs(a["comm_total"] - b["comm_total"]) < 1e-12
    # the ready_order branch must lay the timeline out exactly as
    # simulate_overlap over the (comp, comm) lists permuted by ReadyOrder
    order = build_ready_order(plan).order
    numels = plan.bucket_numels()
    total = sum(numels)
    comp = [1.0 * n / total for n in numels]
    comm = pm.schedule_comm_times(sched, world=8, link_bw=1e6)
    expect = pm.simulate_overlap(
        0.1, [comp[i] for i in order], [comm[i] for i in order]
    )
    assert b == expect
    # and the permutation is non-trivial for this embed+head tree (head
    # buckets issue first)
    assert tuple(order) != tuple(range(len(order)))
    assert [comm[i] for i in order] != comm


def test_monitor_reports_achieved_overlap():
    from repro.runtime.monitor import CCRMonitor, PhaseSample

    mon = CCRMonitor()
    mon.record_sample(PhaseSample(phase=0, t_comp=1.0, t_comm=0.5,
                                  t_full=1.25))
    mt = mon.measured_times()
    assert abs(mt["achieved_overlap"] - 0.5) < 1e-9
    assert abs(mon.summary()["achieved_overlap"] - 0.5) < 1e-9
    # synthetic samples (no wall time) stay None
    mon2 = CCRMonitor()
    mon2.record_sample(PhaseSample(phase=0, t_comp=1.0, t_comm=0.5))
    assert "achieved_overlap" not in (mon2.measured_times() or {})
    assert mon2.summary()["achieved_overlap"] is None


# ---------------------------------------------------------------------------
# fused EF kernel wiring (satellite): segmented COVAP path
# ---------------------------------------------------------------------------

def _covap_setup(use_kernel, **opts):
    params = {"w": jnp.zeros((64, 16), jnp.float32),
              "b": jnp.zeros((16,), jnp.float32)}
    plan = build_plan(params, bucket_bytes=512, max_buckets=8, interval=4)
    comp = get_compressor("covap", interval=4, use_ef_kernel=use_kernel,
                          **opts)
    return params, plan, comp


def test_covap_ef_kernel_exact_parity_on_exact_inputs():
    """Bit-for-bit parity of the kernel-wired segmented path against the
    jnp reference across selected/unselected phases, on inputs whose
    products are exact (residuals = powers of two, coefficient 0.5): this
    isolates wiring bugs from the kernel's FMA rounding, which is the only
    permitted difference (see kernels/ef_covap.py)."""
    exact = dict(ef_init=0.5, ef_ascend_steps=10 ** 9, ef_ascend_range=0.0)
    params, plan, comp_k = _covap_setup(True, **exact)
    _, _, comp_r = _covap_setup(False, **exact)
    key = jax.random.PRNGKey(0)
    grads = {
        k: jax.random.normal(jax.random.fold_in(key, i), v.shape)
        for i, (k, v) in enumerate(params.items())
    }
    # exact products: r in {2^k}, coefficient pinned at 0.5 — c*r is exact,
    # so FMA (one rounding) == mul+add (two roundings) bit-for-bit
    resid = {
        k: jnp.exp2(
            jax.random.randint(jax.random.fold_in(key, 7 + i), v.shape, -3, 3)
            .astype(jnp.float32)
        )
        for i, (k, v) in enumerate(params.items())
    }
    state_k, state_r = dict(resid), dict(resid)
    for step in range(8):  # two full cycles: every bucket selected twice
        phase = step % 4
        sk = comp_k.plan_phase(plan, phase)
        sr = comp_r.plan_phase(plan, phase)
        out_k, state_k, _ = comp_k.execute(sk, grads, state_k, step=step)
        out_r, state_r, _ = comp_r.execute(sr, grads, state_r, step=step)
        for k in grads:
            np.testing.assert_array_equal(np.asarray(out_k[k]),
                                          np.asarray(out_r[k]))
            np.testing.assert_array_equal(np.asarray(state_k[k]),
                                          np.asarray(state_r[k]))


def test_covap_ef_kernel_close_on_random_inputs():
    """On arbitrary inputs the kernel may differ from the 2-op reference by
    FMA rounding only (~1 ulp)."""
    params, plan, comp_k = _covap_setup(True)
    _, _, comp_r = _covap_setup(False)
    key = jax.random.PRNGKey(1)
    grads = {
        k: jax.random.normal(jax.random.fold_in(key, i), v.shape)
        for i, (k, v) in enumerate(params.items())
    }
    state_k = comp_k.init_state(params, plan)
    state_r = comp_r.init_state(params, plan)
    state_k = jax.tree.map(lambda a: a + 0.3, state_k)
    state_r = jax.tree.map(lambda a: a + 0.3, state_r)
    for step in range(4):
        sk = comp_k.plan_phase(plan, step % 4)
        out_k, state_k, _ = comp_k.execute(sk, grads, state_k, step=step)
        out_r, state_r, _ = comp_r.execute(sk, grads, state_r, step=step)
        for k in grads:
            np.testing.assert_allclose(
                np.asarray(out_k[k]), np.asarray(out_r[k]),
                rtol=1e-6, atol=1e-6,
            )
            np.testing.assert_allclose(
                np.asarray(state_k[k]), np.asarray(state_r[k]),
                rtol=1e-6, atol=1e-6,
            )


def test_fused_overlap_with_ef_kernel_matches_post():
    """overlap='fused' and overlap='post' share execute_bucket, so they
    agree bit-for-bit with the kernel engaged too."""
    post = _train("covap", "post", 5, use_ef_kernel=True)
    fused = _train("covap", "fused", 5, use_ef_kernel=True)
    _assert_tree_equal(post["params"], fused["params"])
    _assert_tree_equal(post["comp"], fused["comp"])


# ---------------------------------------------------------------------------
# pod-block helpers (satellite)
# ---------------------------------------------------------------------------

def test_strip_pod_block_asserts_local_block():
    good = {"w": jnp.zeros((1, 4, 4))}
    out = strip_pod_block(good)
    assert jax.tree.leaves(out)[0].shape == (4, 4)
    bad = {"w": jnp.zeros((2, 4, 4))}
    with pytest.raises(ValueError, match="pod block"):
        strip_pod_block(bad)
    # host-side use: peel pod 0 off a full state
    out = strip_pod_block(bad, expect_local=False)
    assert jax.tree.leaves(out)[0].shape == (4, 4)
