"""Zero-copy gradient arena (core/arena.py, DESIGN.md §12): layout
properties, pack→unpack bit-for-bit round-trips vs the concat/_split_like
reference, arena-on == arena-off execute parity for every registered
compressor, full-phase-cycle trainer parity (single-process and 8-worker
CPU mesh), the fused pack kernel, and the HLO copy-count gate."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import arena as ar
from repro.core import bucketing as bk
from repro.core import build_plan, get_compressor
from repro.core.compressors import available
from repro.core.stages import _bucket_dtype, _split_like
from repro.kernels import ref as kref
from repro.kernels.pack_ef_cast import pack_ef_cast

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def make_tree(shapes, dtypes=None):
    dtypes = dtypes or [jnp.float32] * len(shapes)
    key = jax.random.PRNGKey(7)
    return {
        f"leaf{i}": jax.random.normal(
            jax.random.fold_in(key, i), s, jnp.float32
        ).astype(d)
        for i, (s, d) in enumerate(zip(shapes, dtypes))
    }


shape_strategy = st.lists(
    st.one_of(
        st.tuples(st.integers(1, 40)),
        st.tuples(st.integers(1, 12), st.integers(1, 64)),
        st.tuples(st.integers(1, 6), st.integers(1, 16), st.integers(1, 32)),
    ),
    min_size=1,
    max_size=8,
)


# ---------------------------------------------------------------------------
# layout properties
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(shapes=shape_strategy, interval=st.integers(1, 6),
       bucket_kb=st.sampled_from([1, 4, 16]))
def test_offsets_exactly_partition_buckets(shapes, interval, bucket_kb):
    """Per bucket: segment offsets are ascending, back-to-back, and their
    extents sum to the bucket's numel; per plane: bucket slots tile the
    plane exactly (no gaps, no overlap)."""
    tree = make_tree(shapes)
    plan = build_plan(tree, bucket_bytes=bucket_kb * 1024, max_buckets=64,
                      interval=interval)
    layout = ar.build_layout(plan)
    plane_cursor = [0] * len(layout.plane_dtypes)
    for b in layout.buckets:
        i = layout.index_of(b)
        p, off, n = layout.slot(b)
        assert off == plane_cursor[p], "bucket slots must tile the plane"
        plane_cursor[p] += n
        bucket = plan.buckets[b]
        assert n == bucket.numel
        cur = off
        for seg, so in zip(bucket.segments, layout.seg_offsets[i]):
            assert so == cur, "segments must be back-to-back"
            cur += seg.numel(plan.leaf_shapes[seg.leaf_idx])
        assert cur == off + n
    assert plane_cursor == list(layout.plane_sizes)
    assert layout.total_elements() == plan.total_numel()


def test_dtype_promotion_matches_bucket_dtype():
    """A mixed bf16+f32 bucket's plane dtype is exactly ``_bucket_dtype``'s
    promotion (f32), and a pinned wire dtype overrides it."""
    tree = make_tree(
        [(8, 4), (8, 4), (6,)],
        [jnp.bfloat16, jnp.float32, jnp.bfloat16],
    )
    plan = build_plan(tree, bucket_bytes=1 << 20, max_buckets=4, interval=1)
    layout = ar.build_layout(plan)
    for b in layout.buckets:
        i = layout.index_of(b)
        want = _bucket_dtype(plan, plan.buckets[b])
        got = np.dtype(layout.plane_dtypes[layout.bucket_plane[i]])
        assert got == want, (b, got, want)
    pinned = ar.build_layout(plan, wire_dtype=jnp.bfloat16)
    assert set(pinned.plane_dtypes) == {"bfloat16"}


@settings(max_examples=20, deadline=None)
@given(shapes=shape_strategy, interval=st.integers(1, 6))
def test_pack_unpack_roundtrip_vs_concat_reference(shapes, interval):
    """``pack_leaves`` + ``bucket_view`` is bitwise ``gather_bucket``;
    ``unpack_bucket`` is bitwise ``_split_like``; ``gather_leaves`` of the
    pieces reconstructs the exact leaves."""
    tree = make_tree(shapes)
    plan = build_plan(tree, bucket_bytes=2048, max_buckets=32,
                      interval=interval)
    leaves = jax.tree_util.tree_leaves(tree)
    layout = ar.build_layout(plan)
    planes = ar.pack_leaves(layout, leaves)
    pieces = {}
    for b, bucket in enumerate(plan.buckets):
        flat_ref = bk.gather_bucket(plan, leaves, bucket)
        view = layout.bucket_view(planes, b)
        np.testing.assert_array_equal(np.asarray(view), np.asarray(flat_ref))
        slices = [x for _, x in bk.segment_slices(plan, leaves, bucket)]
        ref_pieces = _split_like(slices, flat_ref)
        got_pieces = layout.unpack_bucket(b, view)
        for gp, rp in zip(got_pieces, ref_pieces):
            np.testing.assert_array_equal(np.asarray(gp), np.asarray(rp))
        pieces[b] = got_pieces
    rebuilt = ar.gather_leaves(
        plan, lambda b, si, seg: pieces[b][si], leaves
    )
    for got, want in zip(rebuilt, leaves):
        assert got.dtype == want.dtype
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_gather_leaves_fallback_on_noncontiguous_cover():
    """A plan whose segment order breaks the ascending tiling must route
    through the scatter fallback — including a wire-dtype (bf16) piece
    cast back into an f32 leaf (``_update_segment`` casts)."""
    import dataclasses

    tree = {"a": jnp.ones((8, 4), jnp.float32)}
    plan = build_plan(tree, bucket_bytes=64, max_buckets=8, interval=1)
    assert plan.num_buckets >= 2
    b = list(plan.buckets)
    b[0], b[1] = (dataclasses.replace(b[1], index=0),
                  dataclasses.replace(b[0], index=1))
    plan2 = dataclasses.replace(plan, buckets=tuple(b))
    assert ar.leaf_cover(plan2)[0] is None
    leaves = [jnp.zeros((8, 4), jnp.float32)]
    pieces = {
        bi: [jnp.ones(ar.segment_shape(plan2, s), jnp.bfloat16)
             for s in bkt.segments]
        for bi, bkt in enumerate(plan2.buckets)
    }
    out = ar.gather_leaves(plan2, lambda b_, si, seg: pieces[b_][si], leaves)
    assert out[0].dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(out[0]), 1.0)


def test_leaf_cover_contiguous_for_arch_plans():
    from repro.configs import get_reduced
    from repro.models import build_model

    for arch in ("gpt2-paper", "deepseek-moe-16b"):
        cfg = get_reduced(arch)
        shapes = jax.eval_shape(build_model(cfg).init, jax.random.PRNGKey(0))
        plan = build_plan(shapes, bucket_bytes=1 << 13, max_buckets=64,
                          interval=4)
        cover = ar.leaf_cover(plan)
        assert all(c is not None for c in cover), arch


# ---------------------------------------------------------------------------
# execute parity: arena-on == arena-off for all registered compressors
# ---------------------------------------------------------------------------

_COMP_OPTS = {
    "covap": {"interval": 2},
    "topk": {"ratio": 0.2},
    "dgc": {},
    "randomk": {"ratio": 0.2},
    "oktopk": {"ratio": 0.2},
    "fp8wire": {"block": 64},
}


@pytest.mark.parametrize("name", available())
def test_arena_execute_parity_all_compressors(name):
    """Two steps (residual feedback exercised) of every registered scheme:
    synced gradients AND compressor state bit-for-bit arena-on vs off."""
    opts = _COMP_OPTS.get(name, {})
    tree = make_tree([(16, 8), (32, 4), (5,), ()])
    grads = jax.tree.map(lambda x: x * 0.1, tree)
    plan = build_plan(tree, bucket_bytes=256, max_buckets=8, interval=2)
    ca = get_compressor(name, **opts, use_arena=True)
    cb = get_compressor(name, **opts)
    sa, sb = ca.init_state(tree, plan), cb.init_state(tree, plan)
    for step in range(2):
        outa, sa, _ = ca.execute(ca.plan_phase(plan, step % 2), grads, sa,
                                 step=step)
        outb, sb, _ = cb.execute(cb.plan_phase(plan, step % 2), grads, sb,
                                 step=step)
        for x, y in zip(jax.tree.leaves((outa, sa)),
                        jax.tree.leaves((outb, sb))):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_arena_execute_parity_wire_cast():
    """The bf16 wire-cast path: quantisation-error residual bit-for-bit."""
    tree = make_tree([(16, 8), (32, 4)])
    grads = jax.tree.map(lambda x: x * 0.1, tree)
    plan = build_plan(tree, bucket_bytes=256, max_buckets=8, interval=2)
    for name, opts in (("fp16", {}),
                       ("covap", {"interval": 2, "wire_dtype": "bfloat16"})):
        ca = get_compressor(name, **opts, use_arena=True)
        cb = get_compressor(name, **opts)
        sa, sb = ca.init_state(tree, plan), cb.init_state(tree, plan)
        for step in range(3):
            outa, sa, _ = ca.execute(ca.plan_phase(plan, step % 2), grads,
                                     sa, step=step)
            outb, sb, _ = cb.execute(cb.plan_phase(plan, step % 2), grads,
                                     sb, step=step)
            for x, y in zip(jax.tree.leaves((outa, sa)),
                            jax.tree.leaves((outb, sb))):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# trainer parity: full phase cycle, post and fused overlap
# ---------------------------------------------------------------------------

def _train(compressor, overlap, arena, steps=5):
    from repro.configs import get_reduced
    from repro.data import DataConfig, make_loader
    from repro.models import build_model
    from repro.optim import adamw
    from repro.train.trainer import TrainConfig, Trainer

    cfg = get_reduced("gpt2-paper").with_(vocab_size=256)
    model = build_model(cfg)
    tc = TrainConfig(
        compressor=compressor, interval=4, bucket_bytes=1 << 14,
        max_buckets=32, log_every=10 ** 9, overlap=overlap, arena=arena,
    )
    tr = Trainer(model, adamw(3e-3), tc)
    state = tr.init_state(jax.random.PRNGKey(0))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8,
                    corpus_tokens=1 << 14)
    loader = iter(make_loader(dc))
    for _ in range(steps):
        batch = next(loader)
        fn = tr._phase_fn(state["step"] % tr.num_phases)
        p, o, c, m = fn(state["params"], state["opt"], state["comp"], batch,
                        jnp.int32(state["step"]))
        state = {"params": p, "opt": o, "comp": c, "step": state["step"] + 1}
    return state


def _assert_tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("compressor", ["covap", "none", "fp16"])
@pytest.mark.parametrize("overlap", ["post", "fused"])
def test_arena_equals_legacy_full_cycle(compressor, overlap):
    """Full covap cycle (4 phases) + 1: params AND EF residuals bit-for-bit
    arena-on vs arena-off, on both overlap paths."""
    base = _train(compressor, "post", arena=False)
    got = _train(compressor, overlap, arena=True)
    _assert_tree_equal(base["params"], got["params"])
    _assert_tree_equal(base["comp"], got["comp"])


_MESH_SUB = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs import get_reduced
from repro.data import DataConfig, make_loader
from repro.models import build_model
from repro.optim import adamw
from repro.train.trainer import TrainConfig, Trainer

mesh = Mesh(np.array(jax.devices()[:8]), ("data",))
cfg = get_reduced("gpt2-paper").with_(vocab_size=256)
model = build_model(cfg)

def run(overlap, arena, compressor, steps=5):
    tc = TrainConfig(compressor=compressor, interval=4, bucket_bytes=1 << 14,
                     max_buckets=32, log_every=10 ** 9, overlap=overlap,
                     arena=arena)
    tr = Trainer(model, adamw(3e-3), tc, mesh=mesh, dp_axes=("data",))
    state = tr.init_state(jax.random.PRNGKey(0))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8,
                    corpus_tokens=1 << 14)
    loader = iter(make_loader(dc))
    for _ in range(steps):
        batch = next(loader)
        fn = tr._phase_fn(state["step"] % tr.num_phases)
        p, o, c, m = fn(state["params"], state["opt"], state["comp"], batch,
                        jnp.int32(state["step"]))
        state = {"params": p, "opt": o, "comp": c,
                 "step": state["step"] + 1}
    return state

for compressor in ("covap", "none", "fp16"):
    base = run("post", False, compressor)
    for overlap in ("post", "fused"):
        got = run(overlap, True, compressor)
        for x, y in zip(jax.tree.leaves((base["params"], base["comp"])),
                        jax.tree.leaves((got["params"], got["comp"]))):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    print(compressor, "EQUAL")
"""


def test_arena_equals_legacy_on_cpu_mesh():
    """The acceptance criterion: arena-on == arena-off bit-for-bit (params
    AND EF residuals) over a full phase cycle on an 8-worker CPU mesh, for
    covap/none/fp16, post and fused."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_MESH_SUB)],
        capture_output=True, text=True, timeout=560, env=env,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    assert r.stdout.count("EQUAL") == 3


# ---------------------------------------------------------------------------
# fused pack kernel
# ---------------------------------------------------------------------------

def test_pack_kernel_matches_ref():
    key = jax.random.PRNGKey(3)
    g = jax.random.normal(key, (1000,), jnp.float32)
    r = jax.random.normal(jax.random.fold_in(key, 1), (1000,), jnp.float32)
    coeff = jnp.float32(0.7)
    for selected in (True, False):
        for wd in (None, "bfloat16", "float16"):
            w, rn = pack_ef_cast(g, r, coeff, selected=selected,
                                 wire_dtype=wd, block=256)
            wr, rr = kref.pack_ef_cast_ref(g, r, coeff, selected=selected,
                                           wire_dtype=wd)
            assert w.dtype == wr.dtype
            np.testing.assert_allclose(
                np.asarray(w, np.float32), np.asarray(wr, np.float32),
                rtol=1e-6, atol=1e-6,
            )
            np.testing.assert_allclose(np.asarray(rn), np.asarray(rr),
                                       rtol=1e-5, atol=1e-5)


def test_pack_kernel_bitwise_on_exact_products():
    """Where c*r is exactly representable the FMA and the 2-op form agree
    bitwise (same convention as the ef_covap kernel)."""
    g = jnp.arange(512, dtype=jnp.float32)
    r = jnp.full((512,), 0.5, jnp.float32)
    for wd in (None, "bfloat16"):
        w, rn = pack_ef_cast(g, r, jnp.float32(1.0), selected=True,
                             wire_dtype=wd, block=128)
        wr, rr = kref.pack_ef_cast_ref(g, r, jnp.float32(1.0), selected=True,
                                       wire_dtype=wd)
        np.testing.assert_array_equal(np.asarray(w, np.float32),
                                      np.asarray(wr, np.float32))
        np.testing.assert_array_equal(np.asarray(rn), np.asarray(rr))


def test_pack_ref_matches_legacy_segment_ops():
    """The ref pack IS the legacy ``_ef_segment`` + ``execute_segment``
    op sequence: compensate, cast, quantisation-error residual."""
    key = jax.random.PRNGKey(9)
    g = jax.random.normal(key, (257,), jnp.float32)
    r = jax.random.normal(jax.random.fold_in(key, 1), (257,), jnp.float32)
    coeff = jnp.float32(0.3)
    t = g + coeff * r
    # no cast, selected: wire = t, residual = 0
    w, rn = kref.pack_ef_cast_ref(g, r, coeff, selected=True, wire_dtype=None)
    np.testing.assert_array_equal(np.asarray(w), np.asarray(t))
    np.testing.assert_array_equal(np.asarray(rn), 0.0)
    # bf16 cast, selected: wire = t.astype(bf16), residual = t - wire
    w, rn = kref.pack_ef_cast_ref(g, r, coeff, selected=True,
                                  wire_dtype=jnp.bfloat16)
    xw = t.astype(jnp.bfloat16)
    np.testing.assert_array_equal(np.asarray(w, np.float32),
                                  np.asarray(xw, np.float32))
    np.testing.assert_array_equal(np.asarray(rn),
                                  np.asarray(t - xw.astype(t.dtype)))
    # unselected: residual carries the whole compensated gradient
    _, rn = kref.pack_ef_cast_ref(g, r, coeff, selected=False, wire_dtype=None)
    np.testing.assert_array_equal(np.asarray(rn), np.asarray(t))


def test_pack_fused_speedup_gate():
    """kernel_bench's pack case: the fused single-pass pack must beat the
    unfused triple-materialisation path by >= 1.5x on CPU (measured ~4x;
    best-of-two to absorb CI jitter)."""
    from benchmarks.kernel_bench import run as kb_run

    def speedup():
        rows = {name: derived for name, _, derived in kb_run(smoke=True)}
        d = rows["kernel/pack_unfused"]
        return float(d.split("speedup_fused=")[1])

    s = speedup()
    if s < 1.5:
        s = max(s, speedup())
    assert s >= 1.5, f"fused pack speedup {s:.2f}x < 1.5x"


# ---------------------------------------------------------------------------
# HLO copy-count gate
# ---------------------------------------------------------------------------

def test_hlo_gate_fewer_copies_than_concat_path():
    """The arena build of one execute phase must issue strictly fewer
    data-movement ops than the legacy path, with the per-segment
    dynamic-update-slice chains gone entirely (pre-optimisation HLO —
    what the traced program asks of the compiler)."""
    from repro.launch.hlo_analysis import count_data_movement

    tree = make_tree([(24, 16), (24, 16), (16, 8), (40,)])
    grads = jax.tree.map(lambda x: x * 0.1, tree)
    plan = build_plan(tree, bucket_bytes=1024, max_buckets=16, interval=2)

    def lowered(name, use_arena, **opts):
        comp = get_compressor(name, **opts, use_arena=use_arena)
        state = comp.init_state(tree, plan)
        sched = comp.plan_phase(plan, 0)

        def f(g, s):
            out, ns, _ = comp.execute(sched, g, s, step=1)
            return out, ns

        return jax.jit(f).lower(grads, state).as_text(dialect="hlo")

    for name, opts in (("covap", {"interval": 2}), ("topk", {"ratio": 0.1})):
        off = count_data_movement(lowered(name, False, **opts))
        on = count_data_movement(lowered(name, True, **opts))
        assert on["total"] < off["total"], (name, off, on)
        assert on["dynamic-update-slice"] == 0, (name, on)
