"""``runtime/trace.py`` unit tests: ring-buffer bounds, the
calibrate-from-trace round-trip, per-bucket planned issue spans, and
per-request serve spans (DESIGN.md §15)."""
from __future__ import annotations

import pytest

from repro.configs import get_reduced
from repro.core.perfmodel import calibrate_from_trace
from repro.models import build_model
from repro.optim import sgd
from repro.runtime.monitor import PhaseSample
from repro.runtime.trace import (
    PID_PLANNED,
    PID_SERVE,
    TimelineTracer,
)
from repro.serve.scheduler import Completion
from repro.train.trainer import TrainConfig, Trainer


def make_trainer(interval=2):
    cfg = get_reduced("gpt2-paper").with_(vocab_size=256)
    model = build_model(cfg)
    tc = TrainConfig(
        compressor="covap", interval=interval,
        bucket_bytes=1 << 14, max_buckets=32, log_every=10 ** 9,
    )
    return Trainer(model, sgd(1e-3), tc)


# ---------------------------------------------------------------------------
# ring buffer
# ---------------------------------------------------------------------------

def test_ring_buffer_evicts_oldest_at_max_events():
    tr = TimelineTracer(max_events=8)
    for step in range(20):
        tr.record_step(step, phase=0, wall_s=0.01)
    assert len(tr.events) == 8
    names = [e["name"] for e in tr.events]
    assert names == [f"step {s}" for s in range(12, 20)]
    # the synthetic cursor keeps advancing even as old spans fall off
    assert tr._cursor_s == pytest.approx(0.2)


def test_ring_buffer_export_survives_eviction():
    tr = TimelineTracer(max_events=4)
    for step in range(10):
        tr.record_step(step, phase=step % 2, wall_s=0.5)
    trace = tr.to_chrome_trace()
    spans = [e for e in trace["traceEvents"] if e.get("ph") == "X"]
    assert len(spans) == 4
    # metadata rows are re-emitted in full regardless of eviction
    meta = [e for e in trace["traceEvents"] if e.get("ph") == "M"]
    assert len(meta) == 4  # planned / measured / control / serve


# ---------------------------------------------------------------------------
# calibration round-trip
# ---------------------------------------------------------------------------

def test_calibrate_from_trace_round_trip():
    """Known t_comp / t_comm / bytes through the tracer must come back out
    of ``calibrate_from_trace`` — the measured timeline feeds the same
    model that planned it."""
    tr = TimelineTracer()
    t_comp, t_comm, wire = 0.02, 0.06, 6_000_000
    for step in range(5):
        tr.record_step(step, phase=0, wall_s=t_comp + t_comm)
        tr.record_sample(
            PhaseSample(phase=0, t_comp=t_comp, t_comm=t_comm, step=step),
            bytes_on_wire=wire,
        )
    cal = calibrate_from_trace(tr.to_chrome_trace())
    assert cal["t_comp"] == pytest.approx(t_comp, rel=1e-9)
    assert cal["t_comm"] == pytest.approx(t_comm, rel=1e-9)
    assert cal["ccr"] == pytest.approx(t_comm / t_comp, rel=1e-9)
    assert cal["mean_step_s"] == pytest.approx(t_comp + t_comm, rel=1e-9)
    assert cal["num_samples"] == 5
    assert cal["link_bw"] == pytest.approx(wire / t_comm, rel=1e-9)


def test_calibrate_accepts_bare_event_list():
    tr = TimelineTracer()
    tr.record_sample(PhaseSample(phase=0, t_comp=0.1, t_comm=0.3, step=0))
    cal = calibrate_from_trace(list(tr.events))
    assert cal["ccr"] == pytest.approx(3.0, rel=1e-9)
    assert "link_bw" not in cal  # no bytes arg -> no bandwidth estimate


# ---------------------------------------------------------------------------
# planned per-bucket issue spans
# ---------------------------------------------------------------------------

def test_planned_bucket_spans_cover_the_plan():
    """One named span per collective issue, phases together covering every
    bucket of the plan exactly once per interval cycle — the property the
    obs_check smoke gate asserts on the exported trace."""
    trainer = make_trainer(interval=2)
    tracer = TimelineTracer()
    scheds = trainer.schedules()
    for s in scheds:
        tracer.record_planned_buckets(s, world=8, link_bw=1e9)

    spans = [e for e in tracer.events if e.get("cat") == "planned,issue"]
    assert len(spans) == sum(len(s.calls) for s in scheds)
    assert all(e["pid"] == PID_PLANNED for e in spans)
    assert all(e["name"].startswith("issue bucket") for e in spans)
    covered = {e["args"]["bucket"] for e in spans}
    assert covered == set(range(trainer.plan.num_buckets))
    assert all(e["args"]["bytes"] > 0 for e in spans)
    assert all(e["dur"] > 0 for e in spans)


def test_planned_bucket_spans_follow_issue_order():
    trainer = make_trainer(interval=2)
    s = trainer.schedules()[0]
    tracer = TimelineTracer()
    tracer.record_planned_buckets(s, world=8)
    spans = [e for e in tracer.events if e.get("cat") == "planned,issue"]
    want = [int(s.selected[i]) for i in s.issue_order()]
    assert [e["args"]["bucket"] for e in spans] == want
    assert [e["args"]["rank"] for e in spans] == list(range(len(want)))
    # back-to-back layout: starts are non-decreasing
    ts = [e["ts"] for e in spans]
    assert ts == sorted(ts)


# ---------------------------------------------------------------------------
# serve spans
# ---------------------------------------------------------------------------

def _completion(rid=3, **over):
    base = dict(
        rid=rid, prompt_len=5, tokens=[7, 8], finish_reason="length",
        submit_s=10.0, admit_s=10.1, prefill_end_s=10.2,
        first_token_s=10.25, finish_s=10.4,
    )
    base.update(over)
    return Completion(**base)


def test_record_request_emits_all_stages():
    tracer = TimelineTracer()
    tracer.record_request(_completion(), t0=10.0)
    spans = {e["cat"]: e for e in tracer.events}
    assert set(spans) == {
        "serve,queued", "serve,prefill", "serve,insert", "serve,decode",
    }
    assert all(e["pid"] == PID_SERVE and e["tid"] == 3
               for e in spans.values())
    # stages tile the lifecycle end-to-end (µs timestamps, rebased to t0)
    assert spans["serve,queued"]["ts"] == pytest.approx(0.0, abs=1e-6)
    assert spans["serve,queued"]["dur"] == pytest.approx(0.1e6, rel=1e-9)
    assert spans["serve,prefill"]["dur"] == pytest.approx(0.1e6, rel=1e-9)
    assert spans["serve,insert"]["dur"] == pytest.approx(0.05e6, rel=1e-9)
    assert spans["serve,decode"]["dur"] == pytest.approx(0.15e6, rel=1e-9)
    for e in tracer.events:
        assert e["args"]["rid"] == 3
        assert e["args"]["finish_reason"] == "length"


def test_record_request_truncated_gets_only_queued_span():
    tracer = TimelineTracer()
    tracer.record_request(
        _completion(tokens=[], finish_reason="truncated",
                    admit_s=None, prefill_end_s=None,
                    first_token_s=None, finish_s=10.3),
    )
    assert len(tracer.events) == 1
    (ev,) = tracer.events
    assert ev["cat"] == "serve,queued"
    assert ev["dur"] >= 0


def test_record_counter_emits_counter_samples():
    tracer = TimelineTracer()
    tracer.record_counter("occupancy", 1.5, {"queue_depth": 3, "free": 7})
    (ev,) = tracer.events
    assert ev["ph"] == "C" and ev["pid"] == PID_SERVE
    assert ev["args"] == {"queue_depth": 3.0, "free": 7.0}
