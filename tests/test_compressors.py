"""Single-worker behaviour of every registered GC scheme."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build_plan, get_compressor
from repro.core.compressors import available, dense_bytes


@pytest.fixture(scope="module")
def setup():
    params = {
        "emb": jnp.zeros((128, 16)),
        "w1": jnp.zeros((4, 16, 32)),
        "b1": jnp.zeros((4, 32)),
        "scalar": jnp.zeros(()),
    }
    plan = build_plan(params, bucket_bytes=2048, max_buckets=16, interval=4)
    key = jax.random.PRNGKey(0)
    grads = {
        k: jax.random.normal(jax.random.fold_in(key, i), v.shape)
        for i, (k, v) in enumerate(params.items())
    }
    return params, plan, grads


@pytest.mark.parametrize("name", available())
def test_sync_preserves_structure_and_is_finite(name, setup):
    params, plan, grads = setup
    comp = get_compressor(name)
    state = comp.init_state(params, plan)
    out, state2, stats = comp.sync(
        grads, state, plan=plan, phase=0, step=0, axis_names=()
    )
    assert jax.tree_util.tree_structure(out) == jax.tree_util.tree_structure(grads)
    for k in grads:
        assert out[k].shape == grads[k].shape
        assert out[k].dtype == grads[k].dtype
        assert bool(jnp.all(jnp.isfinite(out[k])))
    assert stats.bytes_per_worker <= stats.dense_bytes
    assert stats.dense_bytes == dense_bytes(plan)


@pytest.mark.parametrize("name", available())
def test_sync_is_jittable(name, setup):
    params, plan, grads = setup
    comp = get_compressor(name)
    state = comp.init_state(params, plan)

    @jax.jit
    def f(g, s, step):
        out, s2, _ = comp.sync(g, s, plan=plan, phase=0, step=step,
                               axis_names=())
        return out, s2

    out, _ = f(grads, state, jnp.int32(3))
    assert out["emb"].shape == grads["emb"].shape


def test_none_is_identity_single_worker(setup):
    params, plan, grads = setup
    comp = get_compressor("none")
    out, _, stats = comp.sync(grads, (), plan=plan, phase=0, step=0,
                              axis_names=())
    for k in grads:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(grads[k]))
    assert stats.volume_ratio == 1.0


def test_fp16_close_to_identity(setup):
    params, plan, grads = setup
    comp = get_compressor("fp16")
    out, _, stats = comp.sync(grads, (), plan=plan, phase=0, step=0,
                              axis_names=())
    for k in grads:
        np.testing.assert_allclose(
            np.asarray(out[k]), np.asarray(grads[k]), rtol=2e-2, atol=2e-2
        )
    assert 1.9 < stats.volume_ratio < 2.1


def test_fp8wire_better_than_sign(setup):
    params, plan, grads = setup
    fp8 = get_compressor("fp8wire", ef=False)
    sgn = get_compressor("efsignsgd", ef=False)
    out8, _, s8 = fp8.sync(grads, (), plan=plan, phase=0, step=0, axis_names=())
    outs, _, ss = sgn.sync(grads, (), plan=plan, phase=0, step=0, axis_names=())

    def err(a):
        return sum(
            float(jnp.sum((a[k] - grads[k]) ** 2)) for k in grads
        )

    assert err(out8) < err(outs)
    assert s8.volume_ratio > 3.5  # ~4x


def test_covap_phase_volume(setup):
    params, plan, grads = setup
    comp = get_compressor("covap", interval=4)
    state = comp.init_state(params, plan)
    ratios = []
    for phase in range(4):
        _, _, stats = comp.sync(grads, state, plan=plan, phase=phase, step=phase,
                                axis_names=())
        ratios.append(stats.dense_bytes / max(stats.bytes_per_worker, 1))
    avg = len(ratios) / sum(1 / r for r in ratios)
    assert 3.0 < avg < 5.5  # ~interval on average


def test_powersgd_reduces_error_with_rank(setup):
    params, plan, grads = setup
    errs = []
    for rank in (1, 4):
        comp = get_compressor("powersgd", rank=rank, ef=False)
        state = comp.init_state(params, plan)
        # a few warm-start iterations improve the subspace
        for step in range(3):
            out, state, _ = comp.sync(grads, state, plan=plan, phase=0,
                                      step=step, axis_names=())
        errs.append(
            sum(float(jnp.sum((out[k] - grads[k]) ** 2)) for k in grads)
        )
    assert errs[1] < errs[0]


def test_randomk_same_seed_is_deterministic(setup):
    params, plan, grads = setup
    comp = get_compressor("randomk", ratio=0.05)
    st1 = comp.init_state(params, plan)
    o1, _, _ = comp.sync(grads, st1, plan=plan, phase=0, step=7, axis_names=())
    o2, _, _ = comp.sync(grads, st1, plan=plan, phase=0, step=7, axis_names=())
    for k in grads:
        np.testing.assert_array_equal(np.asarray(o1[k]), np.asarray(o2[k]))
