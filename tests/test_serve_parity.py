"""Serving parity: continuous batching over the paged KV arena must be
BIT-FOR-BIT equal to sequential one-request-at-a-time decode for every
model family — attention (dense, windowed, softcapped), MoE (at the
drop-free capacity cf=E; capacity is batch-size dependent otherwise, see
test_decode_parity), SSM, xLSTM, enc-dec cross-attention, VLM.

Exactness is the point: both sides prefill at batch=1 through the same
scan, gather through page tables into dense caches of the same logical
length (identical reduction orders), and sample greedily — any divergence
means the arena aliased, leaked, or mislaid a page."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced, list_archs
from repro.models import build_model
from repro.serve import Engine, ServeConfig

PROMPTS = [[5, 17, 3, 9], [88, 2], [1, 1, 1, 1, 1, 1, 1], [4, 40, 14]]
SC = dict(max_len=48, max_new_tokens=4, page_size=8, prefill_chunk=4)


def _build(arch):
    cfg = get_reduced(arch)
    if cfg.num_experts > 0:
        # drop-free capacity: MoE token dropping depends on how many
        # tokens route together, i.e. on batch composition
        cfg = cfg.with_(moe_capacity_factor=float(cfg.num_experts))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    frames = None
    if cfg.is_encdec:
        frames = 0.02 * np.random.default_rng(0).standard_normal(
            (1, cfg.frontend_tokens, cfg.d_model)
        ).astype(np.float32)
    return model, params, frames


@pytest.mark.parametrize("arch", list_archs())
def test_batched_equals_sequential(arch):
    model, params, frames = _build(arch)

    eng_seq = Engine(model, params, ServeConfig(batch_slots=1, **SC))
    seq = []
    for p in PROMPTS:
        r = eng_seq.submit(p, frames=frames)
        eng_seq.run_until_done()
        seq.append(eng_seq.results[r])

    eng_bat = Engine(model, params, ServeConfig(batch_slots=3, **SC))
    rids = [eng_bat.submit(p, frames=frames) for p in PROMPTS]
    res = eng_bat.run_until_done()

    for p, r, s in zip(PROMPTS, rids, seq):
        assert res[r].tokens == s.tokens, f"{arch}: prompt {p} diverged"
        assert res[r].finish_reason == s.finish_reason


def test_engine_matches_raw_dense_decode():
    """Anchor the whole paged path against a reference that uses no arena
    at all: a hand-rolled token-by-token decode over a dense cache."""
    cfg = get_reduced("gpt2-paper").with_(vocab_size=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(42))
    prompt, max_new = [5, 17, 3, 9], 6

    eng = Engine(model, params, ServeConfig(batch_slots=2, max_len=64,
                                            max_new_tokens=max_new,
                                            page_size=8, prefill_chunk=4))
    r = eng.submit(prompt)
    got = eng.run_until_done()[r].tokens

    step = jax.jit(model.decode_step)
    caches = model.init_caches(1, eng.layout.tokens)
    logits = None
    for pos, t in enumerate(prompt):
        b = {"tokens": jnp.asarray([[t]], jnp.int32),
             "pos": jnp.full((1,), pos, jnp.int32)}
        logits, caches = step(params, caches, b)
    ref, pos = [], len(prompt)
    while True:
        t = int(jnp.argmax(logits[:, 0, :], axis=-1)[0])
        ref.append(t)
        if len(ref) >= max_new:
            break
        b = {"tokens": jnp.asarray([[t]], jnp.int32),
             "pos": jnp.full((1,), pos, jnp.int32)}
        logits, caches = step(params, caches, b)
        pos += 1

    assert got == ref
