"""Serving smoke gate: QPS sweep on the reduced qwen1.5-0.5b config.

Runs the continuous-batching engine (paged KV arena, chunked prefill ->
insert -> generate) under synthetic Poisson traffic at a few arrival
rates and emits both the per-stage unit costs and the latency/throughput
digest the snapshot records (``prefill_tok_us``, ``generate_tok_us``,
``insert_us``, ``serve_p50_ms``, ``serve_p99_ms``, ``serve_ttft_ms``,
``serve_tokens_per_s``).

The gate FAILS (raises) if any request goes unanswered, if a finish
reason is invalid, or if chunked prefill degenerated to one call per
token — the structural properties; absolute numbers are tracked
relatively PR-over-PR by the trajectory gate in ``benchmarks.run``.
"""
from __future__ import annotations

import dataclasses

import jax

from .common import row

ARCH = "qwen1.5-0.5b"
VALID_REASONS = {"eos", "length", "truncated"}


def run(smoke: bool = False):
    from repro.configs import get_reduced
    from repro.models import build_model
    from repro.serve import Engine, ServeConfig, TrafficConfig, run_traffic

    cfg = get_reduced(ARCH).with_(vocab_size=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sc = ServeConfig(
        batch_slots=4, max_len=64, max_new_tokens=8,
        page_size=8, prefill_chunk=8,
    )
    engine = Engine(model, params, sc)

    rates = (4.0, 32.0) if smoke else (2.0, 8.0, 32.0, 128.0)
    n_req = 8 if smoke else 24
    lo, hi = 4, 12
    base = TrafficConfig(
        num_requests=n_req, prompt_len=(lo, hi),
        vocab_size=cfg.vocab_size, seed=0,
    )

    # warmup: one prompt per length in [lo, hi] compiles every prefill
    # remainder program plus insert/generate, so the measured sweep sees
    # steady-state latencies instead of charging XLA compiles to the first
    # arrival-rate's p50
    for n in range(lo, hi + 1):
        engine.submit(list(range(1, n + 1)))
    engine.run_until_done()
    engine.reset()

    reports = []
    for r in rates:
        engine.reset()
        reports.append(run_traffic(
            engine, dataclasses.replace(base, qps=float(r))
        ))

    # ---- structural gate ------------------------------------------------
    for rep in reports:
        if rep.num_requests != n_req or sum(rep.finish_reasons.values()) != n_req:
            raise AssertionError(f"serve gate: lost requests at qps={rep.qps}: {rep}")
        bad = set(rep.finish_reasons) - VALID_REASONS
        if bad:
            raise AssertionError(f"serve gate: invalid finish reasons {bad}")
        if not (0 < rep.p50_ms <= rep.p99_ms):
            raise AssertionError(f"serve gate: broken percentiles {rep}")
    st = engine.stats  # stats of the LAST (highest-qps) sweep point
    if st["prefill_calls"] >= st["prefill_tokens"] and st["prefill_tokens"] > n_req:
        raise AssertionError(
            "serve gate: prefill degenerated to one call per token "
            f"({st['prefill_calls']} calls / {st['prefill_tokens']} tokens)"
        )

    # ---- unit costs ------------------------------------------------------
    # The snapshot's gated stage unit costs (prefill/generate/insert µs)
    # come from identical deterministic batch-mode episodes, min over
    # episode means — the kernel_bench discipline.  A single sweep
    # point's mean covers only ~8 insert calls, noisy enough on a
    # time-shared box that the reading drifted past the trajectory
    # gate's 25% band on unchanged code.
    unit = None
    for _ in range(3):
        engine.reset()
        for n in range(lo, hi + 1):
            engine.submit(list(range(1, n + 1)))
        engine.run_until_done()
        em = engine.metrics()
        unit = em if unit is None else {k: min(unit[k], em[k]) for k in em}

    # ---- rows ------------------------------------------------------------
    m = unit
    est = engine.stats   # stats of the last unit-cost episode
    heavy = reports[-1]  # highest arrival rate = the "heavy traffic" point
    rows = [
        row("serve/prefill_tok_us", m["prefill_tok_us"] / 1e6,
            f"tokens={est['prefill_tokens']} calls={est['prefill_calls']}"),
        row("serve/generate_tok_us", m["generate_tok_us"] / 1e6,
            f"tokens={est['generate_tokens']} calls={est['generate_calls']}"),
        row("serve/insert_us", m["insert_us"] / 1e6,
            f"calls={est['insert_calls']} pages={engine.arena.num_pages} "
            f"page_bytes={engine.layout.page_bytes()}"),
        row("serve/p50_ms", heavy.p50_ms / 1e3,
            f"qps={heavy.qps} n={heavy.num_requests}"),
        row("serve/p99_ms", heavy.p99_ms / 1e3,
            f"qps={heavy.qps} ttft_p50_ms={heavy.ttft_p50_ms:.1f}"),
        row("serve/ttft_ms", heavy.ttft_p50_ms / 1e3,
            f"qps={heavy.qps} n={heavy.num_requests}"),
        row("serve/tokens_per_s", 1.0 / max(heavy.tokens_per_s, 1e-9),
            f"tokens_per_s={heavy.tokens_per_s:.1f} "
            f"makespan_s={heavy.makespan_s:.2f}"),
    ]
    for rep in reports:
        rows.append(row(
            f"serve/sweep_qps{rep.qps:g}", rep.p50_ms / 1e3,
            f"p99_ms={rep.p99_ms:.1f} tok_s={rep.tokens_per_s:.1f} "
            f"reasons={rep.finish_reasons}",
        ))
    return rows
