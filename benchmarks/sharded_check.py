"""CI gate for sharded sync (DESIGN.md §13).

Runs ``repro.launch.sharded_gate`` in a subprocess (the fake 8-device
count must be set before jax imports): it compiles one fused sharded COVAP
train step and FAILS unless the compiled module reduce-scatters gradient
buckets before the final gradient-producing fusion AND schedules the
deferred param all-gathers at the step's head (where they overlap the
forward pass), and unless the schedule-level exposed wire bytes per worker
are <= 0.6x the all-reduce path's.
"""
from __future__ import annotations

import os
import subprocess
import sys

from .common import row

_SRC = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "src")
)


def run(smoke: bool = False):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.sharded_gate"],
        capture_output=True, text=True, timeout=560, env=env,
    )
    line = next(
        (l for l in r.stdout.splitlines() if l.startswith("SHARDED ")),
        "SHARDED <missing>",
    )
    if r.returncode != 0:
        raise AssertionError(
            f"sharded placement gate failed: {line}\n{r.stderr[-2000:]}"
        )
    kv = dict(p.split("=") for p in line.split()[1:])
    return [
        row(
            "sharded/placement", 0.0,
            f"rs={kv['num_reduce_scatter']};ag={kv['num_all_gather']};"
            f"rs_before_final_grad={kv['rs_before_final_grad']};"
            f"ag_before_first_rs={kv['ag_before_first_rs']}",
        ),
        row("sharded/exposed_ratio", 0.0,
            f"ratio={kv['exposed_ratio']}"),
    ]
