"""Paper Fig 11: scalability across cluster sizes (8/16/32/64 ... 512).

Per GC scheme: modelled speedup at each cluster size with (a) comm time
scaling as ring-allreduce 2(W-1)/W, (b) AllGather-based schemes degrading
~W/ring (the paper's Random-k/EFsignSGD cliff), (c) measured compression
overheads from table2.  Reproduces: COVAP near-linear at every size,
AllGather schemes flattening out."""
from __future__ import annotations

from repro.core import perfmodel as pm
from repro.core.ccr import allreduce_bytes_on_wire, select_interval

from .common import row

SIZES = [8, 16, 32, 64, 128, 256, 512]

# (scheme, volume_ratio(P), compress_frac, allgather_based, data_dependency)
SCHEMES = [
    ("ddp_ovlp", lambda ccr: 1.0, 0.0, False, False),
    ("covap", lambda ccr: float(select_interval(ccr)), 0.001, False, False),
    ("fp16", lambda ccr: 2.0, 0.01, False, False),
    ("powersgd", lambda ccr: 50.0, 0.15, False, False),
    ("topk", lambda ccr: 100.0, 2.7, True, False),
    ("randomk", lambda ccr: 100.0, 1.5, True, False),
    ("efsignsgd", lambda ccr: 4.0, 0.15, True, False),
    ("oktopk", lambda ccr: 100.0, 0.3, False, True),
]

# VGG-19 profile at 8 workers in the paper's network; comm grows with ring factor
TB, TC = 0.105, 0.210
COMM_64 = 0.842


def comm_at(P):
    ring64 = 2 * (64 - 1) / 64
    ringP = 2 * (P - 1) / P
    return COMM_64 * ringP / ring64


def run():
    rows = []
    for name, vol_fn, cfrac, allgather, dep in SCHEMES:
        speeds = []
        for P in SIZES:
            tm = comm_at(P)
            ccr = tm / TC
            vol = vol_fn(ccr)
            if allgather:
                ring = 2 * (P - 1) / P
                tm = tm * (P / ring)  # allgather wire volume penalty
            s = pm.speedup_gc_ovlp(
                P, TB, TC, tm, volume_ratio=vol,
                t_compress=cfrac * TC, data_dependency=dep,
            )
            speeds.append(s / P)  # fraction of linear scaling
        detail = ";".join(
            f"P{P}={f:.2f}" for P, f in zip(SIZES, speeds)
        )
        rows.append(row(
            f"fig11/{name}", 0.0,
            f"frac_of_linear:{detail}",
        ))
    return rows
