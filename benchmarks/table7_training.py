"""Paper Table VII / Fig 6: time-to-solution and accuracy per GC scheme.

Real CPU training runs (reduced GPT-2, learnable Markov data): wall time for
N steps + final loss per compressor.  The paper's qualitative result to
reproduce: COVAP/FP16 match the DDP baseline loss while sparsifiers with
aggressive ratios lag at equal step count.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.data import DataConfig, make_loader
from repro.models import build_model
from repro.optim import adamw
from repro.train.trainer import TrainConfig, Trainer

from .common import row

SCHEMES = [
    ("none", {}),          # DDPovlp baseline
    ("covap", {}),
    ("fp16", {}),
    ("fp8wire", {}),
    ("topk", {"ratio": 0.01}),
    ("dgc", {"ratio": 0.001}),
    ("randomk", {"ratio": 0.01}),
    ("efsignsgd", {}),
    ("powersgd", {"rank": 2}),
]

STEPS = 25


def run():
    cfg = get_reduced("gpt2-paper").with_(vocab_size=256)
    model = build_model(cfg)
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=48, global_batch=8)
    rows = []
    for name, opts in SCHEMES:
        tc = TrainConfig(compressor=name, compressor_options=opts, interval=4,
                         bucket_bytes=1 << 14, max_buckets=32,
                         log_every=10 ** 9)
        tr = Trainer(model, adamw(3e-3), tc)
        state = tr.init_state(jax.random.PRNGKey(0))
        loader = iter(make_loader(data))
        # compile all phases first
        warm = next(loader)
        for ph in range(tr.num_phases):
            tr._phase_fn(ph)(state["params"], state["opt"], state["comp"],
                             warm, jnp.int32(ph))
        t0 = time.perf_counter()
        losses = []
        for _ in range(STEPS):
            batch = next(loader)
            phase = state["step"] % tr.num_phases
            p, o, c, m = tr._phase_fn(phase)(
                state["params"], state["opt"], state["comp"], batch,
                jnp.int32(state["step"]))
            state = {"params": p, "opt": o, "comp": c,
                     "step": state["step"] + 1}
            losses.append(float(m["loss"]))
        wall = time.perf_counter() - t0
        rows.append(row(
            f"table7/{name}", wall / STEPS,
            f"final_loss={losses[-1]:.4f};steps={STEPS}",
        ))
    return rows
