"""Resilience smoke gate (DESIGN.md §16): the guardrails must *work* and
be *cheap*.

Two checks:

1. **Chaos recovery** — ``repro.launch.chaos_gate`` in a subprocess (the
   fake 8-device count must be set before jax imports): a reduced covap
   run on an 8-worker CPU mesh under ``grad_nan`` + ``ef_blowup`` + a
   persistent ``grad_inf`` + a mid-run ``kill`` must heal through all
   three ladder rungs (skip-step / ef-flush / rewind), survive the
   kill via checkpoint restore + resume, end with a finite loss, and
   surface every trip/action/firing as schema-valid telemetry events
   matching the counters 1:1.
2. **Overhead** — a guarded step (``guards=True``: nonfinite + loss-spike
   + residual watchdog at their default cadences, no checkpointing) must
   cost within 3% of an unguarded one on the same precompiled trainer
   (interleaved min-of-trials, the kernel_bench/obs_check discipline).
   The µs column of the ``chaos/guard_overhead_frac`` row carries the
   dimensionless fraction (``frac/1e6`` — ``row()`` scales by 1e6);
   ``benchmarks.run`` lifts it into the ``guard_overhead_frac`` gauge of
   ``BENCH_<n>.json``.  Set ``REPRO_CHAOS_NO_OVERHEAD_GATE=1`` to record
   without gating on a hopelessly noisy box.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

import jax

from .common import row

OVERHEAD_BUDGET = 1.03   # guarded step wall <= 3% over unguarded

_SRC = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "src")
)


def _chaos_gate() -> tuple[float, dict]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    t0 = time.perf_counter()
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.chaos_gate"],
        capture_output=True, text=True, timeout=560, env=env,
    )
    wall = time.perf_counter() - t0
    line = next(
        (l for l in r.stdout.splitlines() if l.startswith("CHAOS ")),
        "CHAOS <missing>",
    )
    if r.returncode != 0:
        raise AssertionError(
            f"chaos recovery gate failed: {line}\n{r.stderr[-2000:]}"
        )
    kv = dict(p.split("=", 1) for p in line.split()[1:])
    return wall, kv


def _overhead_gate(smoke: bool) -> tuple:
    """Interleaved min-of-trials guarded-vs-bare step wall on ONE
    precompiled trainer: both arms replay the identical step sequence
    from the same initial state, so the only delta is the guard work —
    the per-step host materialisation of ``total_loss``/``grad_norm``
    plus the cadenced residual-norm reduction."""
    from repro.configs import get_reduced
    from repro.data import DataConfig, make_loader
    from repro.models import build_model
    from repro.optim import sgd
    from repro.train.trainer import TrainConfig, Trainer

    cfg = get_reduced("gpt2-paper").with_(vocab_size=256)
    model = build_model(cfg)
    tc = TrainConfig(compressor="covap", interval=2, log_every=1000,
                     steps=64)
    tr = Trainer(model, sgd(1e-3, momentum=0.9), tc)
    state = tr.init_state(jax.random.PRNGKey(0))
    loader = iter(make_loader(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=16, global_batch=4,
    )))

    steps = 8 if smoke else 12
    trials = 7 if smoke else 11
    tr.run(state, loader, steps=2, log=None)      # compile both phases
    tr.run(state, loader, steps=2, log=None, guards=True)   # + guard jits

    def timed(guards) -> float:
        t0 = time.perf_counter()
        out = tr.run(state, loader, steps=steps, log=None, guards=guards)
        # settle async dispatch: without this the bare arm measures only
        # the host loop, and the guarded arm's per-step sync looks like a
        # 200% "overhead" that is really the compute wall itself
        jax.block_until_ready(out["params"])
        return (time.perf_counter() - t0) / steps

    def measure() -> tuple:
        import gc

        gc.collect()    # don't let earlier modules' garbage bill a trial
        on, off = [], []
        for k in range(trials):
            # alternate pair order so a systematic second-position penalty
            # (frequency scaling, GC debt) is not charged to one arm
            if k % 2 == 0:
                off.append(timed(None))
                on.append(timed(True))
            else:
                on.append(timed(True))
                off.append(timed(None))
        min_on, min_off = min(on), min(off)
        return min_on / max(min_off, 1e-12) - 1.0, min_on, min_off

    # the ~3% budget sits below this box's trial-to-trial scheduler noise,
    # so re-measure up to 3 rounds and gate on the best: a structural
    # regression is over budget in EVERY round, a noise spike is not
    frac, min_on, min_off = measure()
    for _ in range(2):
        if frac <= OVERHEAD_BUDGET - 1.0:
            break
        frac, min_on, min_off = min(
            (frac, min_on, min_off), measure()
        )
    if (frac > OVERHEAD_BUDGET - 1.0
            and not os.environ.get("REPRO_CHAOS_NO_OVERHEAD_GATE")):
        raise AssertionError(
            f"chaos gate: guarded step {min_on*1e3:.2f} ms is "
            f"{frac*100:.1f}% over bare {min_off*1e3:.2f} ms "
            f"(budget {OVERHEAD_BUDGET - 1:.0%}; "
            f"REPRO_CHAOS_NO_OVERHEAD_GATE=1 to record anyway)"
        )
    return frac, min_on, min_off, trials


def run(smoke: bool = False):
    rows = []
    wall, kv = _chaos_gate()
    rows.append(row(
        "chaos/recovery_gate", wall,
        f"loss={kv.get('loss')} resumed_from={kv.get('resumed_from')} "
        f"trips={kv.get('trips')} actions={kv.get('actions')} "
        f"rungs={kv.get('rungs')}",
    ))
    frac, min_on, min_off, trials = _overhead_gate(smoke)
    # the µs column carries the dimensionless overhead fraction
    # (row() scales by 1e6, hence the /1e6) — build_snapshot lifts it
    # into the guard_overhead_frac gauge
    rows.append(row(
        "chaos/guard_overhead_frac", frac / 1e6,
        f"on={min_on*1e3:.2f}ms off={min_off*1e3:.2f}ms "
        f"trials={trials} budget={OVERHEAD_BUDGET - 1:.0%}",
    ))
    return rows
