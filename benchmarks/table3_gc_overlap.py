"""Paper Table III + SS II analysis: GC alone vs GC+Overlapping.

Uses the paper's ResNet-101 numbers (T_before=55ms, T_comp=135ms, CCR=2.1)
and the timeline simulator to reproduce S_GC vs S_GC-ovlp vs S_LS, showing
that compressing CCR to ~1 under overlap reaches near-linear scaling."""
from __future__ import annotations

from repro.core import perfmodel as pm

from .common import row

CASES = [
    # (scheme, volume_ratio, compress_frac_of_comp, data_dependency)
    ("ddp_ovlp", 1.0, 0.0, False),
    ("randomk", 2.0, 0.05, False),
    ("fp16", 2.0, 0.01, False),
    ("covap_I3", 3.0, 0.001, False),
    ("topk", 100.0, 2.7, False),       # huge T_compress (Table II: 370ms/135ms)
    ("oktopk", 100.0, 0.3, True),      # data dependency kills overlap
]


def run():
    P = 64
    tb, tc = 0.055, 0.135
    tm = 2.1 * tc
    n_buckets = 8
    ls = P
    rows = [row("table3/linear_scaling", tb + tc, f"speedup={ls:.2f}")]
    s_dp = pm.speedup_dp(P, tb, tc, tm)
    rows.append(row("table3/dp_no_overlap", tb + tc + tm, f"speedup={s_dp:.2f}"))
    for name, vol, cfrac, dep in CASES:
        s = pm.speedup_gc_ovlp(
            P, tb, tc, tm,
            volume_ratio=vol, t_compress=cfrac * tc, data_dependency=dep,
        )
        t = pm.t_gc_ovlp(tb, tc, tm / vol, cfrac * tc, data_dependency=dep)
        # achieved-overlap fraction of the bucketed timeline next to the
        # modeled speedup: what share of the scheme's wire time the engine
        # hides under backward compute (0 when data dependency serialises)
        if dep:
            ovlp = 0.0
        else:
            per = lambda x: [x / n_buckets] * n_buckets
            sim = pm.simulate_overlap(
                tb, per(tc + cfrac * tc), per(tm / vol)
            )
            ovlp = pm.overlap_fraction(sim)
        rows.append(row(
            f"table3/{name}", t,
            f"speedup={s:.2f};of_linear={s/ls:.1%};overlap_frac={ovlp:.3f}",
        ))
    return rows
