"""Paper Table I: computation vs communication times and CCR.

Two parts: (a) the paper's own DNNs with its measured times — validates the
analytic model against the paper's CCRs (2.1 / 4.0 / 3.1); (b) the assigned
architectures' analytic CCR on the v5e production mesh (the numbers that
drive COVAP's adaptive interval in the dry-run).
"""
from __future__ import annotations

from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.core.ccr import HardwareSpec, analytic_times, select_interval
from repro.models import count_params

from .common import PAPER_DNNS, row


def run():
    rows = []
    # (a) paper environment: reproduce Table I CCRs from its own T_comp/T_comm
    for name, params, tb, tc, tm in PAPER_DNNS:
        ccr = tm / tc
        interval = select_interval(ccr)
        rows.append(row(
            f"table1/paper/{name}", tm,
            f"ccr={ccr:.2f};interval={interval}",
        ))
    # (b) assigned archs on the production mesh (train_4k, 256 chips DP=16)
    hw = HardwareSpec.v5e()
    shape = INPUT_SHAPES["train_4k"]
    for arch in list_archs():
        cfg = get_config(arch)
        n_active = count_params(cfg, active_only=True)
        tokens = shape.global_batch * shape.seq_len
        r = analytic_times(
            step_flops_per_chip=6.0 * n_active * tokens / 256,
            grad_bytes=count_params(cfg) * 4 / 16,  # per model shard
            dp_world=16,
            hw=hw,
        )
        rows.append(row(
            f"table1/v5e/{arch}", r["t_comm"],
            f"ccr={r['ccr']:.3f};interval={select_interval(r['ccr'])};"
            f"t_comp={r['t_comp']*1e3:.1f}ms",
        ))
    # (c) same archs in the paper's 30Gbps cloud environment
    hw_cloud = HardwareSpec.cloud_v100_30gbps()
    for arch in list_archs():
        cfg = get_config(arch)
        n_active = count_params(cfg, active_only=True)
        # per-worker micro-batch of 2x512 tokens (paper-scale local batches)
        r = analytic_times(
            step_flops_per_chip=6.0 * n_active * 2 * 512,
            grad_bytes=count_params(cfg) * 4,
            dp_world=64,
            hw=hw_cloud,
        )
        rows.append(row(
            f"table1/cloud30g/{arch}", r["t_comm"],
            f"ccr={r['ccr']:.2f};interval={select_interval(r['ccr'])}",
        ))
    return rows
