"""CI gate for the zero-copy gradient arena (DESIGN.md §12).

Lowers one phase of the COVAP (segmented) and top-k (flat/concat) execute
paths with the arena off and on, and counts data-movement opcodes
(copy / concatenate / dynamic-slice / dynamic-update-slice) in the
**pre-optimisation** HLO — the ops the traced program *issues*, which is
what grows with bucket count and what the arena eliminates by
construction.  (Post-optimisation, XLA's simplifier + all-reduce combiner
can converge toy-scale programs — that convergence is itself evidence the
arena is pure data-movement restructuring; the gate pins the structural
claim.)  FAILS unless arena-on issues strictly fewer ops than arena-off
and the per-segment ``dynamic-update-slice`` scatter chains are gone
entirely.

Fast: lowering only, no XLA compile, no devices.
"""
from __future__ import annotations

import jax

from repro.configs import get_reduced
from repro.core import build_plan, get_compressor
from repro.launch.hlo_analysis import count_data_movement
from repro.models import build_model

from .common import row


def _lowered_hlo(params, grads, plan, name, use_arena, **opts):
    comp = get_compressor(name, **opts, use_arena=use_arena)
    state = comp.init_state(params, plan)
    sched = comp.plan_phase(plan, 0)

    def f(g, s):
        out, ns, _ = comp.execute(sched, g, s, step=1)
        return out, ns

    return jax.jit(f).lower(grads, state).as_text(dialect="hlo")


def run(smoke: bool = False):
    cfg = get_reduced("gpt2-paper").with_(vocab_size=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    plan = build_plan(params, bucket_bytes=1 << 14, max_buckets=32, interval=4)
    grads = jax.tree.map(lambda x: x * 0.1, params)

    rows = []
    for name, opts in (("covap", {"interval": 4}), ("topk", {"ratio": 0.05})):
        off = count_data_movement(
            _lowered_hlo(params, grads, plan, name, False, **opts)
        )
        on = count_data_movement(
            _lowered_hlo(params, grads, plan, name, True, **opts)
        )
        if not on["total"] < off["total"]:
            raise AssertionError(
                f"arena gate [{name}]: expected fewer data-movement ops "
                f"with the arena on; off={off} on={on}"
            )
        if on["dynamic-update-slice"] != 0:
            raise AssertionError(
                f"arena gate [{name}]: per-segment update-slice chains "
                f"survived: {on}"
            )
        rows.append(row(
            f"arena/{name}_copy_ops", 0.0,
            f"off={off['total']};on={on['total']};"
            f"dus_off={off['dynamic-update-slice']};dus_on=0",
        ))
    return rows
