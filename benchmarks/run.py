"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table2,fig5] [--smoke]

Prints ``name,us_per_call,derived`` CSV (one line per measurement).
``--smoke`` runs only the fast analytic/plan-level modules (sub-second
each, no training, no heavy jit) — the CI gate used by scripts/ci.sh.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

import inspect

from . import (
    adaptive_runtime,
    fig5_ratio_sweep,
    fig11_scaling,
    kernel_bench,
    overlap_check,
    table1_ccr,
    table2_overhead,
    table3_gc_overlap,
    table5_sharding,
    table7_training,
)
from .common import emit

MODULES = {
    "table1": table1_ccr,
    "table2": table2_overhead,
    "table3": table3_gc_overlap,
    "table5": table5_sharding,
    "table7": table7_training,
    "fig5": fig5_ratio_sweep,
    "fig11": fig11_scaling,
    "kernels": kernel_bench,
    "adaptive": adaptive_runtime,
    "overlap": overlap_check,
}

# fast modules only: no training loops, no heavy jit — the CI smoke gate.
# "kernels" runs here in its reduced --smoke size so scripts/ci.sh bench
# exercises the Pallas kernel reference path on every run; "overlap" is the
# HLO interleaving gate (compiles ONE fused step on an 8-worker CPU mesh
# and fails unless collectives are scheduled inside the backward pass).
SMOKE_MODULES = ("table1", "table3", "table5", "fig5", "fig11", "kernels",
                 "adaptive", "overlap")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--smoke", action="store_true",
                    help="fast analytic subset for CI")
    args = ap.parse_args()
    if args.only:
        names = args.only.split(",")
    elif args.smoke:
        names = list(SMOKE_MODULES)
    else:
        names = list(MODULES)

    print("name,us_per_call,derived")
    ok = True
    for name in names:
        mod = MODULES[name]
        t0 = time.perf_counter()
        try:
            kw = {}
            if args.smoke and "smoke" in inspect.signature(mod.run).parameters:
                kw["smoke"] = True
            rows = mod.run(**kw)
            emit(rows)
            print(f"# {name}: {len(rows)} rows in "
                  f"{time.perf_counter()-t0:.1f}s", file=sys.stderr)
        except Exception:
            ok = False
            print(f"# {name}: FAILED", file=sys.stderr)
            traceback.print_exc()
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
