"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table2,fig5] [--smoke]

Prints ``name,us_per_call,derived`` CSV (one line per measurement).
``--smoke`` runs the fast CI subset used by scripts/ci.sh: mostly
analytic/plan-level modules plus two compiled-HLO gates ("overlap",
"arena"), then records a standardized ``BENCH_<n>.json`` snapshot (step
wall time from a small measured covap run — the one genuinely trained
piece, ~15 s — bytes/worker, modeled overlap fraction, pack-kernel µs)
so the perf trajectory of the repo is tracked PR over PR.  The snapshot
is written only for the full smoke set (not with ``--only``); the
``BENCH_*.json`` pattern is gitignored — ``git add -f`` the snapshot a
PR means to record.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys
import time
import traceback

import inspect

from . import (
    adaptive_runtime,
    arena_check,
    fig5_ratio_sweep,
    fig11_scaling,
    kernel_bench,
    overlap_check,
    sharded_check,
    table1_ccr,
    table2_overhead,
    table3_gc_overlap,
    table5_sharding,
    table7_training,
)
from .common import emit

MODULES = {
    "table1": table1_ccr,
    "table2": table2_overhead,
    "table3": table3_gc_overlap,
    "table5": table5_sharding,
    "table7": table7_training,
    "fig5": fig5_ratio_sweep,
    "fig11": fig11_scaling,
    "kernels": kernel_bench,
    "adaptive": adaptive_runtime,
    "overlap": overlap_check,
    "arena": arena_check,
    "sharded": sharded_check,
}

# fast modules only: no training loops, no heavy jit — the CI smoke gate.
# "kernels" runs here in its reduced --smoke size so scripts/ci.sh bench
# exercises the Pallas kernel reference path on every run; "overlap" is the
# HLO interleaving gate (compiles ONE fused step on an 8-worker CPU mesh
# and fails unless collectives are scheduled inside the backward pass);
# "arena" is the zero-copy gate (fails unless the arena build issues fewer
# data-movement ops than the concat path); "sharded" is the sharded-sync
# placement gate (fails unless the compiled sharded step reduce-scatters
# before the final gradient fusion with the deferred param all-gathers at
# the step head, and the exposed wire bytes are <= 0.6x all-reduce).
SMOKE_MODULES = ("table1", "table3", "table5", "fig5", "fig11", "kernels",
                 "adaptive", "overlap", "arena", "sharded")

_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def build_snapshot(all_rows: list[tuple]) -> dict:
    """The standardized perf digest recorded per PR: a tiny measured covap
    run (per-step wall time, arena off/on), the static plan's byte and
    overlap accounting, and the pack-kernel microbenchmark."""
    import repro.api as api

    def measured_step(arena: bool) -> float:
        t0 = time.perf_counter()
        r = api.fit(
            "gpt2-paper", reduced=True, vocab_size=256, interval=4,
            steps=8, seq_len=32, global_batch=8, arena=arena,
        )
        # amortised per-step wall (includes the 4 phase compiles — a
        # stable smoke-sized proxy, tracked relative over PRs)
        return (time.perf_counter() - t0) / 8, r

    wall_off, fit = measured_step(False)
    wall_on, _ = measured_step(True)
    report = fit.trainer.schedule_report()
    # same configuration as the measured run above (interval=4, same
    # bucketing) so the modeled and measured columns describe ONE workload
    tune_row = api.tune(
        "gpt2-paper", dp_workers=8, candidates=(("covap", {}),),
        interval=4, bucket_bytes=1 << 14, max_buckets=32,
    )[0]
    kernel_rows = {name: (us, derived) for name, us, derived in all_rows
                   if name.startswith("kernel/pack")}
    pack_us = kernel_rows.get("kernel/pack_fused", (None, ""))[0]
    m = re.search(r"speedup_fused=([\d.]+)",
                  kernel_rows.get("kernel/pack_unfused", (0, ""))[1])
    # sharded-sync gate results (benchmarks/sharded_check.py): the
    # schedule-level exposed-bytes ratio vs all-reduce and the compiled
    # placement counts, recorded alongside the existing fields
    sharded_rows = {name: derived for name, _, derived in all_rows
                    if name.startswith("sharded/")}
    ms = re.search(r"ratio=([\d.]+)",
                   sharded_rows.get("sharded/exposed_ratio", ""))
    mp = re.search(r"rs_before_final_grad=(\d+)",
                   sharded_rows.get("sharded/placement", ""))
    return {
        "schema": 1,
        "unix_time": int(time.time()),
        "workload": "gpt2-paper/reduced covap I=4 seq32 gb8",
        "step_wall_s": wall_off,
        "step_wall_s_arena": wall_on,
        "bytes_per_worker_per_step": report["mean_bytes_per_step"],
        "volume_ratio": report["volume_ratio"],
        "overlap_frac_modeled": tune_row["overlap_frac_modeled"],
        "pack_overhead_us_modeled": tune_row["pack_overhead_us"],
        "pack_kernel_us": pack_us,
        "pack_fused_speedup": float(m.group(1)) if m else None,
        "sharded_exposed_ratio": float(ms.group(1)) if ms else None,
        "sharded_rs_before_final_grad": int(mp.group(1)) if mp else None,
    }


def write_snapshot(all_rows: list[tuple]) -> str:
    existing = glob.glob(os.path.join(_REPO_ROOT, "BENCH_*.json"))
    nums = [
        int(m.group(1))
        for p in existing
        if (m := re.match(r"BENCH_(\d+)\.json$", os.path.basename(p)))
    ]
    path = os.path.join(_REPO_ROOT, f"BENCH_{max(nums, default=-1) + 1}.json")
    with open(path, "w") as f:
        json.dump(build_snapshot(all_rows), f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--smoke", action="store_true",
                    help="fast analytic subset for CI")
    args = ap.parse_args()
    if args.only:
        names = args.only.split(",")
    elif args.smoke:
        names = list(SMOKE_MODULES)
    else:
        names = list(MODULES)

    print("name,us_per_call,derived")
    ok = True
    all_rows: list[tuple] = []
    for name in names:
        mod = MODULES[name]
        t0 = time.perf_counter()
        try:
            kw = {}
            if args.smoke and "smoke" in inspect.signature(mod.run).parameters:
                kw["smoke"] = True
            rows = mod.run(**kw)
            emit(rows)
            all_rows += rows
            print(f"# {name}: {len(rows)} rows in "
                  f"{time.perf_counter()-t0:.1f}s", file=sys.stderr)
        except Exception:
            ok = False
            print(f"# {name}: FAILED", file=sys.stderr)
            traceback.print_exc()
    if ok and args.smoke and not args.only:
        path = write_snapshot(all_rows)
        print(f"# snapshot: {path}", file=sys.stderr)
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
