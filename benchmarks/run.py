"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table2,fig5] [--smoke]

Prints ``name,us_per_call,derived`` CSV (one line per measurement).
``--smoke`` runs the fast CI subset used by scripts/ci.sh: mostly
analytic/plan-level modules plus two compiled-HLO gates ("overlap",
"arena"), then records a standardized ``BENCH_<n>.json`` snapshot (step
wall time from a small measured covap run — the one genuinely trained
piece, ~15 s — bytes/worker, modeled overlap fraction, pack-kernel µs)
so the perf trajectory of the repo is tracked PR over PR.  The snapshot
is written only for the full smoke set (not with ``--only``); the
``BENCH_*.json`` pattern is gitignored — ``git add -f`` the snapshot a
PR means to record.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys
import time
import traceback

import inspect

from . import (
    adaptive_runtime,
    arena_check,
    chaos_check,
    fig5_ratio_sweep,
    fig11_scaling,
    hier_check,
    kernel_bench,
    obs_check,
    overlap_check,
    serve_bench,
    sharded_check,
    table1_ccr,
    table2_overhead,
    table3_gc_overlap,
    table5_sharding,
    table7_training,
)
from .common import emit

MODULES = {
    "table1": table1_ccr,
    "table2": table2_overhead,
    "table3": table3_gc_overlap,
    "table5": table5_sharding,
    "table7": table7_training,
    "fig5": fig5_ratio_sweep,
    "fig11": fig11_scaling,
    "kernels": kernel_bench,
    "adaptive": adaptive_runtime,
    "overlap": overlap_check,
    "arena": arena_check,
    "sharded": sharded_check,
    "hier": hier_check,
    "serve": serve_bench,
    "obs": obs_check,
    "chaos": chaos_check,
}

# fast modules only: no training loops, no heavy jit — the CI smoke gate.
# "kernels" runs here in its reduced --smoke size so scripts/ci.sh bench
# exercises the Pallas kernel reference path on every run; "overlap" is the
# HLO interleaving gate (compiles ONE fused step on an 8-worker CPU mesh
# and fails unless collectives are scheduled inside the backward pass);
# "arena" is the zero-copy gate (fails unless the arena build issues fewer
# data-movement ops than the concat path); "sharded" is the sharded-sync
# placement gate (fails unless the compiled sharded step reduce-scatters
# before the final gradient fusion with the deferred param all-gathers at
# the step head, and the exposed wire bytes are <= 0.6x all-reduce);
# "hier" is the two-level hierarchical gate (benchmarks/hier_check.py:
# compiles one sharded step on a (pod=2, data=4) mesh and fails unless the
# CommSchedule's per-link byte accounting — intra-pod RS + deferred AG on
# the ICI, owned-shard exchanges on the DCN — matches the compiled HLO's
# replica-group-classified collective bytes); "serve" is the serving gate (short QPS sweep through the paged-KV
# continuous-batching engine; fails on lost requests, invalid finish
# reasons, or prefill degenerating to one call per token); "obs" is the
# telemetry gate (benchmarks/obs_check.py: an instrumented run must emit
# schema-valid JSONL + a Chrome trace with one named planned span per
# bucket + per-request serve spans, and the instrumented step wall must
# stay within 3% of the uninstrumented one); "chaos" is the resilience
# gate (benchmarks/chaos_check.py: an 8-worker mesh run under injected
# NaN grads + EF blow-up + a mid-run kill must heal through all three
# recovery rungs with every trip in telemetry, and a guarded step must
# stay within 3% of an unguarded one — recorded as guard_overhead_frac).
SMOKE_MODULES = ("table1", "table3", "table5", "fig5", "fig11", "kernels",
                 "adaptive", "overlap", "arena", "sharded", "hier", "serve",
                 "obs", "chaos")

_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def build_snapshot(all_rows: list[tuple]) -> dict:
    """The standardized perf digest recorded per PR: a tiny measured covap
    run (per-step wall time, arena off/on), the static plan's byte and
    overlap accounting, the pack-kernel microbenchmark, and the serving
    gate's stage/latency numbers.

    Since schema 3 every value flows through a ``repro.obs``
    :class:`MetricsRegistry` — the snapshot body IS ``registry.snapshot()``
    (DESIGN.md §15): a perf key exists in ``BENCH_<n>.json`` iff a gauge
    recorded it, so the BENCH schema and the telemetry schema cannot
    drift apart."""
    import repro.api as api
    from repro.obs import MetricsRegistry

    def measured_step(arena: bool):
        t0 = time.perf_counter()
        r = api.fit(
            "gpt2-paper", reduced=True, vocab_size=256, interval=4,
            steps=8, seq_len=32, global_batch=8, arena=arena,
        )
        # amortised per-step wall (includes the 4 phase compiles — a
        # stable smoke-sized proxy, tracked relative over PRs)
        return (time.perf_counter() - t0) / 8, r

    # interleaved min-of-trials (the kernel_bench discipline): alternating
    # off/on trials share whatever transient load the host is under, and
    # min-of-3 discards scheduler noise — step_wall_s moved 1.00->1.74 s
    # between BENCH_0/1 on an unchanged workload with the single-shot
    # measurement this replaces.
    walls_off, walls_on = [], []
    fit = None
    for _ in range(3):
        w_off, r = measured_step(False)
        walls_off.append(w_off)
        if fit is None:
            fit = r
        w_on, _ = measured_step(True)
        walls_on.append(w_on)
    wall_off, wall_on = min(walls_off), min(walls_on)
    report = fit.trainer.schedule_report()
    # the modeled overlap column prices the PAPER's workload — full
    # gpt2-paper at seq 1024 / global batch 512 over 64 workers, the
    # regime where CCR ≈ 3 and COVAP's I=4 hides ~94% of the comm.
    # Through BENCH_2 this row was priced on the SMOKE workload above
    # (256 tokens/step on the 30 Gbps V100 model -> CCR ≈ 638, so
    # overlap_frac_modeled pinned at ~0.006 — arithmetically correct,
    # diagnostically useless; see DESIGN.md §15).  The smoke fit keeps
    # its tiny geometry for wall-time stability; the model is priced at
    # paper scale because it costs nothing (static planning, no tracing).
    tune_row = api.tune(
        "gpt2-paper", reduced=False, dp_workers=64,
        candidates=(("covap", {}),), interval=4,
        seq_len=1024, global_batch=512,
        bucket_bytes=25 * 1024 * 1024, max_buckets=128,
    )[0]
    kernel_rows = {name: (us, derived) for name, us, derived in all_rows
                   if name.startswith("kernel/pack")}
    pack_us = kernel_rows.get("kernel/pack_fused", (None, ""))[0]
    m = re.search(r"speedup_fused=([\d.]+)",
                  kernel_rows.get("kernel/pack_unfused", (0, ""))[1])
    # sharded-sync gate results (benchmarks/sharded_check.py): the
    # schedule-level exposed-bytes ratio vs all-reduce and the compiled
    # placement counts, recorded alongside the existing fields
    sharded_rows = {name: derived for name, _, derived in all_rows
                    if name.startswith("sharded/")}
    ms = re.search(r"ratio=([\d.]+)",
                   sharded_rows.get("sharded/exposed_ratio", ""))
    mp = re.search(r"rs_before_final_grad=(\d+)",
                   sharded_rows.get("sharded/placement", ""))
    # hierarchical gate (benchmarks/hier_check.py): the DCN share of the
    # exposed wire bytes over one full phase cycle of the two-level plan
    hier_rows = {name: derived for name, _, derived in all_rows
                 if name.startswith("hier/")}
    mh = re.search(r"ratio=([\d.]+)",
                   hier_rows.get("hier/exposed_dcn_ratio", ""))
    # serving gate (benchmarks/serve_bench.py): per-stage unit costs and
    # the latency/throughput digest at the sweep's heaviest arrival rate
    serve_us = {name: us for name, us, _ in all_rows
                if name.startswith("serve/")}
    serve_derived = {name: derived for name, _, derived in all_rows
                     if name.startswith("serve/")}
    mt = re.search(r"tokens_per_s=([\d.]+)",
                   serve_derived.get("serve/tokens_per_s", ""))
    # telemetry-overhead gate result (benchmarks/obs_check.py)
    obs_us = {name: us for name, us, _ in all_rows
              if name.startswith("obs/")}
    # guard-overhead gate result (benchmarks/chaos_check.py)
    chaos_us = {name: us for name, us, _ in all_rows
                if name.startswith("chaos/")}

    def _serve(key, scale=1.0):
        v = serve_us.get(key)
        return v * scale if v is not None else None

    reg = MetricsRegistry()

    def g(name, value, help=""):
        reg.gauge(name, help).set(value)

    g("step_wall_s", wall_off, "min-of-3 amortised step wall, arena off")
    g("step_wall_s_arena", wall_on, "min-of-3 amortised step wall, arena on")
    g("bytes_per_worker_per_step", report["mean_bytes_per_step"],
      "static plan: mean collective bytes per worker per step")
    g("volume_ratio", report["volume_ratio"],
      "dense bytes / compressed bytes (static plan)")
    g("overlap_frac_modeled", tune_row["overlap_frac_modeled"],
      "eq-(6) overlap fraction at paper scale (seq1024 gb512 W=64)")
    g("pack_overhead_us_modeled", tune_row["pack_overhead_us"],
      "modeled arena pack-pass cost per phase, paper scale")
    g("pack_kernel_us", pack_us, "measured fused pack/EF/cast kernel wall")
    g("pack_fused_speedup", float(m.group(1)) if m else None,
      "fused pack kernel speedup over the 3-op unfused reference")
    g("sharded_exposed_ratio", float(ms.group(1)) if ms else None,
      "sharded-sync exposed wire bytes / all-reduce wire bytes")
    g("sharded_rs_before_final_grad",
      int(mp.group(1)) if mp else None,
      "compiled reduce-scatters placed before the final grad fusion")
    g("hier_exposed_dcn_ratio", float(mh.group(1)) if mh else None,
      "DCN share of exposed wire bytes in the two-level hierarchical plan")
    g("prefill_tok_us", _serve("serve/prefill_tok_us"),
      "serving prefill unit cost")
    g("generate_tok_us", _serve("serve/generate_tok_us"),
      "serving decode unit cost")
    g("insert_us", _serve("serve/insert_us"), "serving KV-insert unit cost")
    g("serve_p50_ms", _serve("serve/p50_ms", 1e-3),
      "traffic p50 latency at the heaviest swept rate")
    g("serve_p99_ms", _serve("serve/p99_ms", 1e-3),
      "traffic p99 latency at the heaviest swept rate")
    g("serve_ttft_ms", _serve("serve/ttft_ms", 1e-3),
      "traffic p50 time-to-first-token at the heaviest swept rate")
    g("serve_tokens_per_s", float(mt.group(1)) if mt else None,
      "sustained generated tokens/s at the heaviest swept rate")
    g("telemetry_overhead_frac", obs_us.get("obs/overhead_frac"),
      "instrumented/uninstrumented step-wall delta (obs_check gate)")
    g("guard_overhead_frac", chaos_us.get("chaos/guard_overhead_frac"),
      "guarded/unguarded step-wall delta (chaos_check gate)")
    return {
        "schema": 3,
        "unix_time": int(time.time()),
        "workload": "gpt2-paper/reduced covap I=4 seq32 gb8",
        **reg.snapshot(),
    }


# keys the trajectory gate watches: stable-by-construction measurements
# (min-of-trials walls, per-stage serving unit costs, latencies).  Modeled
# /analytic keys (bytes, ratios) change only when the code means them to,
# so a drift there should fail loudly too — but they are exact, not noisy,
# and are covered by their own module gates.  pack_kernel_us graduated to
# gated once kernel_bench moved to min-of-21 interleaved trials: the
# single-shot number drifted 166->205->269 across snapshots on unchanged
# kernel code, but the deep-min is reproducible well inside the 25%
# tolerance.  serve_ttft_ms is gated from the first snapshot that records
# it (keys absent from the previous snapshot are skipped, so its first
# appearance does not trip the gate).  Direction says which way is a
# regression.
TRAJECTORY_KEYS = {
    "step_wall_s": "lower",
    "step_wall_s_arena": "lower",
    "pack_kernel_us": "lower",
    "prefill_tok_us": "lower",
    "generate_tok_us": "lower",
    "insert_us": "lower",
    "serve_p50_ms": "lower",
    "serve_p99_ms": "lower",
    "serve_ttft_ms": "lower",
    "serve_tokens_per_s": "higher",
    "hier_exposed_dcn_ratio": "lower",
}
TRAJECTORY_TOLERANCE = 1.25  # >25% the wrong way = regression


def trajectory_regressions(prev: dict, new: dict) -> list[tuple]:
    """Compare two snapshots on the stable keys; returns the regressions
    as (key, prev, new) tuples.  Keys absent from either side are skipped
    (older snapshots predate the serving metrics)."""
    out = []
    for key, direction in TRAJECTORY_KEYS.items():
        a, b = prev.get(key), new.get(key)
        if not isinstance(a, (int, float)) or not isinstance(b, (int, float)):
            continue
        if a <= 0 or b <= 0:
            continue
        ratio = (b / a) if direction == "lower" else (a / b)
        if ratio > TRAJECTORY_TOLERANCE:
            out.append((key, a, b))
    return out


def gate_against_prev(prev: dict, new: dict) -> list[tuple]:
    """Trajectory gate entry point: compares like-for-like only.  When the
    ``workload`` field differs between the snapshots every gated number
    measures a different thing — comparing them would flag phantom
    regressions (or mask real ones) — so the gate SKIPS with a printed
    notice instead of diffing apples against oranges."""
    pw, nw = prev.get("workload"), new.get("workload")
    if pw != nw:
        print(
            f"# trajectory gate SKIPPED: workload changed "
            f"({pw!r} -> {nw!r}); snapshots are not comparable",
            file=sys.stderr,
        )
        return []
    return trajectory_regressions(prev, new)


def write_snapshot(all_rows: list[tuple]) -> tuple[str, list[tuple]]:
    """Write BENCH_<n>.json and gate it against BENCH_<n-1>.  Returns the
    path and any trajectory regressions (caller decides to fail).  Set
    REPRO_BENCH_NO_TRAJECTORY_GATE=1 to record without gating (e.g. when a
    regression is understood and accepted)."""
    existing = glob.glob(os.path.join(_REPO_ROOT, "BENCH_*.json"))
    nums = sorted(
        int(m.group(1))
        for p in existing
        if (m := re.match(r"BENCH_(\d+)\.json$", os.path.basename(p)))
    )
    snap = build_snapshot(all_rows)
    path = os.path.join(_REPO_ROOT, f"BENCH_{(nums[-1] if nums else -1) + 1}.json")
    with open(path, "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True)
        f.write("\n")
    regressions: list[tuple] = []
    if nums and not os.environ.get("REPRO_BENCH_NO_TRAJECTORY_GATE"):
        prev_path = os.path.join(_REPO_ROOT, f"BENCH_{nums[-1]}.json")
        with open(prev_path) as f:
            prev = json.load(f)
        regressions = gate_against_prev(prev, snap)
    return path, regressions


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--smoke", action="store_true",
                    help="fast analytic subset for CI")
    args = ap.parse_args()
    if args.only:
        names = args.only.split(",")
    elif args.smoke:
        names = list(SMOKE_MODULES)
    else:
        names = list(MODULES)

    print("name,us_per_call,derived")
    ok = True
    all_rows: list[tuple] = []
    for name in names:
        mod = MODULES[name]
        t0 = time.perf_counter()
        try:
            kw = {}
            if args.smoke and "smoke" in inspect.signature(mod.run).parameters:
                kw["smoke"] = True
            rows = mod.run(**kw)
            emit(rows)
            all_rows += rows
            print(f"# {name}: {len(rows)} rows in "
                  f"{time.perf_counter()-t0:.1f}s", file=sys.stderr)
        except Exception:
            ok = False
            print(f"# {name}: FAILED", file=sys.stderr)
            traceback.print_exc()
    if ok and args.smoke and not args.only:
        path, regressions = write_snapshot(all_rows)
        print(f"# snapshot: {path}", file=sys.stderr)
        for key, prev, new in regressions:
            print(f"# TRAJECTORY REGRESSION {key}: {prev:.6g} -> {new:.6g} "
                  f"(>{(TRAJECTORY_TOLERANCE - 1) * 100:.0f}%)",
                  file=sys.stderr)
        if regressions:
            ok = False
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
