"""CI gate for the overlap execution engine (DESIGN.md §11).

Runs ``repro.launch.overlap_gate`` in a subprocess (the fake 8-device
count must be set before jax imports): it compiles one fused-overlap COVAP
train step and FAILS unless at least one bucket collective-start is
scheduled before the final gradient-producing fusion — i.e. unless the
compiled module really issues collectives inside the backward pass.
"""
from __future__ import annotations

import os
import subprocess
import sys

from .common import row

_SRC = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "src")
)


def run(smoke: bool = False):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.overlap_gate"],
        capture_output=True, text=True, timeout=560, env=env,
    )
    line = next(
        (l for l in r.stdout.splitlines() if l.startswith("OVERLAP ")),
        "OVERLAP <missing>",
    )
    if r.returncode != 0:
        raise AssertionError(
            f"overlap interleaving gate failed: {line}\n{r.stderr[-2000:]}"
        )
    kv = dict(p.split("=") for p in line.split()[1:])
    return [
        row("overlap/collectives", 0.0, f"n={kv['num_collectives']}"),
        row(
            "overlap/before_final_grad", 0.0,
            f"n={kv['before_final_grad']};independent={kv['independent']}",
        ),
    ]
