"""Adaptive runtime convergence: re-plans + probes until the interval
tracks an injected comm slowdown.

For each slowdown factor the controller starts at the analytically-planned
interval and receives synthetic probe samples whose measured CCR is
``base_ccr * slowdown``; the derived columns report how many re-plans and
probe decisions it takes to land within ±1 of ``ceil(measured CCR)`` —
the bounded-convergence property the acceptance tests pin down.  Pure
policy arithmetic (no training, no jit): cheap enough for ``--smoke``.
"""
from __future__ import annotations

import math
import time

from repro.runtime import AutotuneConfig, ReplanController

from .common import row

BASE_CCR = 2.4
SLOWDOWNS = (0.25, 0.5, 1.0, 2.0, 4.0, 8.0)


def run(smoke: bool = False):
    rows = []
    for slow in SLOWDOWNS:
        ccr = BASE_CCR * slow
        cfg = AutotuneConfig(
            measure_every=1, warmup_steps=0, window=4,
            patience=2, cooldown_steps=4, max_replans=8,
        )
        ctrl = ReplanController(cfg, interval=math.ceil(BASE_CCR))
        target = max(1, math.ceil(ccr))
        decisions = 0
        t0 = time.perf_counter()
        for step in range(0, 256, 4):
            decisions += 1
            ctrl.observe(step, ccr)
            if abs(ctrl.interval - target) <= 1:
                break
        dt = (time.perf_counter() - t0) / max(decisions, 1)
        rows.append(row(
            f"adaptive/slowdown_{slow:g}x", dt,
            f"ccr={ccr:.2f};target_I={target};final_I={ctrl.interval};"
            f"replans={ctrl.replans};decisions={decisions};"
            f"converged={int(abs(ctrl.interval - target) <= 1)}",
        ))
    return rows
