"""CI gate for two-level hierarchical sharded sync (DESIGN.md §17).

Runs ``repro.launch.hier_gate`` in a subprocess (the fake 8-device count
must be set before jax imports): it compiles one hierarchical sharded
COVAP train step on a (pod=2, data=4) mesh and FAILS unless the per-link
bytes of the statically planned ``CommSchedule`` (intra-pod gradient
reduce-scatters + deferred head all-gather on the ICI, owned-shard
cross-pod exchanges on the DCN) match the compiled HLO's replica-group-
classified collective bytes.  The reported ``hier_exposed_dcn_ratio``
lands in the BENCH snapshot under the trajectory gate.
"""
from __future__ import annotations

import os
import subprocess
import sys

from .common import row

_SRC = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "src")
)


def run(smoke: bool = False):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.hier_gate"],
        capture_output=True, text=True, timeout=560, env=env,
    )
    line = next(
        (l for l in r.stdout.splitlines() if l.startswith("HIER ")),
        "HIER <missing>",
    )
    if r.returncode != 0:
        raise AssertionError(
            f"hierarchical per-link byte gate failed: {line}\n{r.stderr[-2000:]}"
        )
    kv = dict(p.split("=") for p in line.split()[1:])
    return [
        row(
            "hier/bytes_by_link", 0.0,
            f"ici_schedule={kv['ici_schedule']};ici_hlo={kv['ici_hlo']};"
            f"dcn_schedule={kv['dcn_schedule']};dcn_hlo={kv['dcn_hlo']};"
            f"match={kv['match']}",
        ),
        row("hier/exposed_dcn_ratio", 0.0,
            f"ratio={kv['hier_exposed_dcn_ratio']}"),
    ]
