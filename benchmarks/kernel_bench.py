"""SS III.A "near-zero overhead" kernels: per-kernel us/call.

On CPU the Pallas kernels run in interpret mode (Python — not a timing
target), so wall time is measured on the mathematically-identical jnp
reference path that production uses off-TPU, plus the analytic VMEM-roofline
time the fused TPU kernel would take (bytes moved / HBM bandwidth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.ccr import HardwareSpec
from repro.kernels import ref

from .common import row, timeit

N = 4_000_000  # one 16 MB fp32 bucket
SMOKE_N = 262_144  # 1 MB bucket: same kernels, CI-sized (--smoke)
HW = HardwareSpec.v5e()


def run(smoke: bool = False):
    n = SMOKE_N if smoke else N
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (n,), jnp.float32)
    r = jax.random.normal(jax.random.fold_in(key, 1), (n,), jnp.float32)
    rows = []

    side = int(n ** 0.5)  # n is a perfect square for both sizes
    cases = {
        "ef_update": (
            jax.jit(lambda g, r: ref.ef_update_ref(g, r, 0.5, selected=True)),
            (g, r), 3 * n * 4,  # read g,r write send (r'=0 folded)
        ),
        "quantize_fp8": (
            jax.jit(lambda x: ref.quantize_fp8_ref(x)), (g,), n * 5,
        ),
        "sign_compress": (
            jax.jit(lambda x: ref.sign_compress_ref(x)), (g,), n * 5,
        ),
        "threshold_filter": (
            jax.jit(lambda x: ref.threshold_filter_ref(x, 1.5)), (g,), n * 8,
        ),
        "lowrank_matmul": (
            jax.jit(lambda a, b: ref.matmul_ref(a, b)),
            (g.reshape(side, side), r.reshape(side, side)[:, :128]),
            (side * side + side * 128 + side * 128) * 4,
        ),
    }
    for name, (fn, args, bytes_moved) in cases.items():
        t = timeit(fn, *args, warmup=1, iters=3)
        tpu_us = bytes_moved / HW.hbm_bw * 1e6
        rows.append(row(
            f"kernel/{name}", t,
            f"bytes={bytes_moved};tpu_roofline_us={tpu_us:.1f}",
        ))
    rows += _pack_case(g, r, n)
    return rows


def _pack_case(g, r, n):
    """The arena pack pass, fused vs unfused (DESIGN.md §12).

    One bucket through both builds of the compensate → cast → residual
    sequence.  Fused: ONE jitted ``pack_ef_cast_ref`` call — the arena
    formulation XLA compiles to a single fusion (one read of g,r, one
    write of wire,r').  Unfused: the same math as op-at-a-time eager jnp
    — compensate, cast, residual each dispatching and materialising a
    bucket-sized vector (what "unfused" means: no cross-op fusion).  The
    CI gate asserts fused >= 1.5x (tests/test_arena.py; ~2-3x measured,
    interleaved min-of-trials so a time-shared CI box can't skew either
    side), the structural version of the paper's "near-zero compression
    overhead" claim.
    """
    import time

    coeff = jnp.float32(0.5)
    fused = jax.jit(
        lambda g, r: ref.pack_ef_cast_ref(
            g, r, coeff, selected=True, wire_dtype=jnp.bfloat16
        )
    )

    def unfused(g, r):
        t = g + coeff * r
        w = t.astype(jnp.bfloat16)
        return w, t - w.astype(t.dtype)

    def once(fn):
        t0 = time.perf_counter()
        out = fn(g, r)
        jax.block_until_ready(out)
        return time.perf_counter() - t0

    # interleaved min-of-trials: on a time-shared CI box both sides must
    # see the same noise regime, and min (not median of separate batches)
    # is the robust per-side estimator.  21 trials (up from 9) because
    # pack_kernel_us is now gated by the snapshot trajectory check in
    # ``benchmarks.run`` — the single-shot value drifted 166->205->269 µs
    # across snapshots on unchanged kernel code, while the deep min is
    # reproducible well inside the gate's 25% tolerance
    for _ in range(3):
        once(fused), once(unfused)  # warmup / compile
    tf, tu = [], []
    for k in range(21):
        # alternate order so a systematic second-position penalty can't
        # charge one side
        if k % 2 == 0:
            tf.append(once(fused))
            tu.append(once(unfused))
        else:
            tu.append(once(unfused))
            tf.append(once(fused))
    t_fused, t_unfused = min(tf), min(tu)
    speedup = t_unfused / max(t_fused, 1e-12)
    bytes_fused = n * (4 + 4 + 2 + 4)      # read g,r; write bf16 wire + r'
    tpu_us = bytes_fused / HW.hbm_bw * 1e6
    return [
        row("kernel/pack_fused", t_fused,
            f"bytes={bytes_fused};tpu_roofline_us={tpu_us:.1f}"),
        row("kernel/pack_unfused", t_unfused,
            f"speedup_fused={speedup:.2f}"),
    ]
