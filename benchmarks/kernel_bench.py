"""SS III.A "near-zero overhead" kernels: per-kernel us/call.

On CPU the Pallas kernels run in interpret mode (Python — not a timing
target), so wall time is measured on the mathematically-identical jnp
reference path that production uses off-TPU, plus the analytic VMEM-roofline
time the fused TPU kernel would take (bytes moved / HBM bandwidth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.ccr import HardwareSpec
from repro.kernels import ref

from .common import row, timeit

N = 4_000_000  # one 16 MB fp32 bucket
SMOKE_N = 262_144  # 1 MB bucket: same kernels, CI-sized (--smoke)
HW = HardwareSpec.v5e()


def run(smoke: bool = False):
    n = SMOKE_N if smoke else N
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (n,), jnp.float32)
    r = jax.random.normal(jax.random.fold_in(key, 1), (n,), jnp.float32)
    rows = []

    side = int(n ** 0.5)  # n is a perfect square for both sizes
    cases = {
        "ef_update": (
            jax.jit(lambda g, r: ref.ef_update_ref(g, r, 0.5, selected=True)),
            (g, r), 3 * n * 4,  # read g,r write send (r'=0 folded)
        ),
        "quantize_fp8": (
            jax.jit(lambda x: ref.quantize_fp8_ref(x)), (g,), n * 5,
        ),
        "sign_compress": (
            jax.jit(lambda x: ref.sign_compress_ref(x)), (g,), n * 5,
        ),
        "threshold_filter": (
            jax.jit(lambda x: ref.threshold_filter_ref(x, 1.5)), (g,), n * 8,
        ),
        "lowrank_matmul": (
            jax.jit(lambda a, b: ref.matmul_ref(a, b)),
            (g.reshape(side, side), r.reshape(side, side)[:, :128]),
            (side * side + side * 128 + side * 128) * 4,
        ),
    }
    for name, (fn, args, bytes_moved) in cases.items():
        t = timeit(fn, *args, warmup=1, iters=3)
        tpu_us = bytes_moved / HW.hbm_bw * 1e6
        rows.append(row(
            f"kernel/{name}", t,
            f"bytes={bytes_moved};tpu_roofline_us={tpu_us:.1f}",
        ))
    return rows
