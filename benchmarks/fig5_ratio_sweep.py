"""Paper Fig 5: speedup vs compression ratio.

For each paper workload, sweep the interval I and report the modelled
speedup on 64 workers.  The claim to reproduce: speedup saturates at
I = ceil(CCR) (compressing harder than the CCR buys nothing once the
residual communication already hides under compute)."""
from __future__ import annotations

from repro.core import perfmodel as pm
from repro.core.ccr import select_interval

from .common import PAPER_DNNS, row

RATIOS = [1, 2, 3, 4, 8, 16]


def run():
    P = 64
    rows = []
    for name, _, tb, tc, tm in PAPER_DNNS:
        ccr = tm / tc
        chosen = select_interval(ccr)
        speeds = {}
        for i in RATIOS:
            speeds[i] = pm.speedup_gc_ovlp(
                P, tb, tc, tm, volume_ratio=float(i), t_compress=0.0,
            )
        knee = speeds[min(RATIOS, key=lambda i: abs(i - chosen))]
        best = max(speeds.values())
        detail = ";".join(f"I{i}={s:.1f}" for i, s in speeds.items())
        rows.append(row(
            f"fig5/{name}", tm / chosen,
            f"chosen_I={chosen};knee_speedup={knee:.1f};max={best:.1f};{detail}",
        ))
    return rows
