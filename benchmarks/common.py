"""Benchmark helpers: timing + CSV emission.

Every benchmark module exposes ``run() -> list[tuple[name, us_per_call,
derived]]``; ``benchmarks.run`` prints the combined CSV.
"""
from __future__ import annotations

import time
from typing import Callable

import jax

# the paper's four workloads (Table I/VI): (name, params, T_before s,
# T_comp s, T_comm s on 64 GPUs @30Gbps) — T_* from the paper's Table I.
PAPER_DNNS = [
    ("ResNet-101", 44_654_504, 0.055, 0.135, 0.280),
    ("VGG-19", 143_652_544, 0.105, 0.210, 0.842),
    ("Bert", 102_267_648, 0.080, 0.170, 0.520),
    ("GPT-2", 81_894_144, 0.080, 0.170, 0.595),  # CCR~3.5 per SS IV.C.4
]


def timeit(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time per call in seconds (blocks on jax arrays)."""

    def call():
        out = fn(*args)
        for leaf in jax.tree.leaves(out):
            if hasattr(leaf, "block_until_ready"):
                leaf.block_until_ready()
        return out

    for _ in range(warmup):
        call()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        call()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def row(name: str, seconds: float, derived: str = "") -> tuple:
    return (name, seconds * 1e6, derived)


def emit(rows) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")
