"""Paper Tables IV/V + Fig 4: tensor-sharding balance.

Builds the bucket plan for a VGG-19-shaped model (FC1 = 71.5% of all
parameters, the paper's oversized-tensor example) and for the assigned
archs, and reports the max/median bucket imbalance before and after COVAP's
tensor sharding — the quantity that produces the 72.67%-of-comm-time single
tensor in the paper's Table V.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import build_plan
from repro.models import build_model

from .common import row

# exact VGG-19 feature/classifier shapes (paper Table IV)
VGG19 = {
    "conv1_1": (64, 3, 3, 3), "conv1_2": (64, 64, 3, 3),
    "conv2_1": (128, 64, 3, 3), "conv2_2": (128, 128, 3, 3),
    "conv3_1": (256, 128, 3, 3), "conv3_2": (256, 256, 3, 3),
    "conv3_3": (256, 256, 3, 3), "conv3_4": (256, 256, 3, 3),
    "conv4_1": (512, 256, 3, 3), "conv4_2": (512, 512, 3, 3),
    "conv4_3": (512, 512, 3, 3), "conv4_4": (512, 512, 3, 3),
    "conv5_1": (512, 512, 3, 3), "conv5_2": (512, 512, 3, 3),
    "conv5_3": (512, 512, 3, 3), "conv5_4": (512, 512, 3, 3),
    "fc1": (1, 25088, 4096),   # 102.76M = 71.53% (oversized single layer)
    "fc2": (1, 4096, 4096),
    "fc3": (1, 4096, 1000),
}


def imbalance(numels):
    med = max(np.median(numels), 1)
    return max(numels) / med


def run():
    rows = []
    shapes = {k: jnp.zeros(s, jnp.float32) for k, s in VGG19.items()}
    total = sum(int(v.size) for v in shapes.values())
    fc1_frac = int(np.prod(VGG19["fc1"])) / total
    # "before": DDP packing with sharding disabled (threshold -> infinity)
    before = build_plan(shapes, interval=4, shard_threshold=1e18)
    after = build_plan(shapes, interval=4)
    rows.append(row(
        "table5/vgg19_before", 0.0,
        f"buckets={before.num_buckets};imbalance={imbalance(before.bucket_numels()):.1f}x"
        f";fc1_frac={fc1_frac:.1%}",
    ))
    rows.append(row(
        "table5/vgg19_after", 0.0,
        f"buckets={after.num_buckets};imbalance={imbalance(after.bucket_numels()):.1f}x",
    ))

    for arch in ("gemma-2b", "deepseek-moe-16b", "zamba2-2.7b"):
        cfg = get_config(arch)
        model = build_model(cfg)
        sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        b = build_plan(sds, interval=4, shard_threshold=1e18)
        a = build_plan(sds, interval=4)
        rows.append(row(
            f"table5/{arch}", 0.0,
            f"imbalance_before={imbalance(b.bucket_numels()):.1f}x;"
            f"imbalance_after={imbalance(a.bucket_numels()):.1f}x;"
            f"buckets={a.num_buckets}",
        ))
    return rows
