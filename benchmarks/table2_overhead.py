"""Paper Table II: compression overhead of every GC scheme.

Measures single-worker ``compress`` wall time (the T_compress term — no
collectives) on a VGG-19-shaped gradient pytree, scaled to 1/8 size on CPU
with the scale factor reported (the paper's ordering is what matters:
Top-k >> DGC/PowerSGD/EFsignSGD >> FP16 > COVAP ~ 0).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import build_plan, get_compressor

from .common import row, timeit

SCALE = 8  # measure at 1/SCALE of VGG-19's 143.6M params

# VGG-19 layer shapes (paper Table IV), divided by SCALE on the FC dims
VGG_LIKE = {
    "conv1_1": (64, 3, 3, 3),
    "conv_mid": (24, 256, 256, 3),      # the conv bulk, stacked
    "fc1": (25088, 4096 // SCALE),
    "fc2": (4096, 4096 // SCALE),
    "fc3": (4096, 1000 // SCALE),
}

SCHEMES = [
    ("covap", {"interval": 4}),
    ("none", {}),
    ("fp16", {}),
    ("topk", {"ratio": 0.01}),
    ("dgc", {"ratio": 0.001}),
    ("randomk", {"ratio": 0.01}),
    ("efsignsgd", {}),
    ("powersgd", {"rank": 2}),
    ("oktopk", {"ratio": 0.01}),
    ("fp8wire", {}),
]


def run():
    params = {k: jnp.zeros(s, jnp.float32) for k, s in VGG_LIKE.items()}
    total = sum(int(v.size) for v in params.values())
    plan = build_plan(params, interval=4)
    key = jax.random.PRNGKey(0)
    grads = {
        k: jax.random.normal(jax.random.fold_in(key, i), v.shape)
        for i, (k, v) in enumerate(params.items())
    }
    rows = []
    for name, opts in SCHEMES:
        comp = get_compressor(name, **opts)
        state = comp.init_state(params, plan)

        @jax.jit
        def compress(g, s):
            out, s2, _ = comp.sync(g, s, plan=plan, phase=0, step=0,
                                   axis_names=())
            return out, s2

        t = timeit(compress, grads, state, warmup=1, iters=3)
        _, _, stats = comp.sync(grads, state, plan=plan, phase=0, step=0,
                                axis_names=())
        rows.append(row(
            f"table2/{name}", t,
            f"params={total};scale=1/{SCALE};volume_ratio={stats.volume_ratio:.1f}",
        ))
    return rows
