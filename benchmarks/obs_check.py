"""Telemetry smoke gate (DESIGN.md §15): the observability subsystem must
be *correct* and *free*.

Three checks, all structural (absolute numbers ride the trajectory gate):

1. **Schema** — a short instrumented fused-overlap fit streams
   ``events.jsonl``; every line read back from disk must validate against
   the checked-in ``repro/obs/event_schema.json``, and the run must have
   produced a manifest and step records.
2. **Trace** — the same run's Chrome trace must contain one named planned
   issue span per bucket (distinct ``args["bucket"]`` count equals
   ``plan.num_buckets``), and a tiny serve run must emit per-request spans
   covering all three stages (prefill / insert / decode) for every request.
3. **Overhead** — an instrumented step must cost within 3% of an
   uninstrumented one on the same precompiled trainer (interleaved
   min-of-trials, the kernel_bench discipline).  This is the "near-zero
   overhead when disabled... and cheap when enabled" budget; set
   ``REPRO_OBS_NO_OVERHEAD_GATE=1`` to record without gating on a
   hopelessly noisy box.
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import jax

from .common import row

OVERHEAD_BUDGET = 1.03   # instrumented step wall <= 3% over uninstrumented
SERVE_ARCH = "qwen1.5-0.5b"
SERVE_STAGES = ("prefill", "insert", "decode")


def _validate_jsonl(path: str, schema) -> dict:
    """Parse + validate every line of an events file; returns kind counts.
    Raises on the first invalid record — the gate wants the line number."""
    from repro.obs import validate_event

    kinds: dict[str, int] = {}
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            ev = json.loads(line)
            errs = validate_event(ev, schema)
            if errs:
                raise AssertionError(
                    f"obs gate: {path}:{lineno} invalid "
                    f"{ev.get('kind')!r} event: {errs}"
                )
            kinds[ev["kind"]] = kinds.get(ev["kind"], 0) + 1
    return kinds


def _train_gate(td: str, schema, smoke: bool) -> tuple:
    """Instrumented fused-overlap fit: schema-valid JSONL + one planned
    issue span per bucket in the exported Chrome trace."""
    import repro.api as api
    from repro.obs import Telemetry
    from repro.runtime import AutotuneConfig

    tel = Telemetry(os.path.join(td, "train"))
    t0 = time.perf_counter()
    fit = api.fit(
        "gpt2-paper", reduced=True, vocab_size=256,
        compressor="covap", interval=2, overlap="fused",
        steps=6, seq_len=16, global_batch=4, log_every=1,
        # probe early and often so the audit trail (probe/replan_decision
        # events) exists within a smoke-sized run
        autotune=AutotuneConfig(measure_every=2, warmup_steps=1),
        telemetry=tel,
    )
    if fit.trainer.runtime is not None:
        fit.trainer.runtime.finish()   # planned per-bucket spans -> tracer
    wall = time.perf_counter() - t0
    paths = tel.save()
    tel.close()

    kinds = _validate_jsonl(paths["events"], schema)
    for required in ("manifest", "step", "probe", "replan_decision"):
        if not kinds.get(required):
            raise AssertionError(
                f"obs gate: instrumented fit emitted no {required!r} "
                f"events (got {kinds})"
            )

    with open(paths["trace"]) as f:
        trace = json.load(f)
    buckets = {
        ev["args"]["bucket"]
        for ev in trace["traceEvents"]
        if ev.get("cat") == "planned,issue" and "bucket" in ev.get("args", {})
    }
    want = set(range(fit.trainer.plan.num_buckets))
    if buckets != want:
        raise AssertionError(
            f"obs gate: planned issue spans cover buckets "
            f"{sorted(buckets)} != plan's {sorted(want)}"
        )
    return wall, kinds, len(want)


def _serve_gate(td: str, schema, smoke: bool) -> tuple:
    """Tiny serve run: every request must land all three stage spans (plus
    its queued span) in the shared trace, and the request/report events
    must validate."""
    from repro.configs import get_reduced
    from repro.models import build_model
    from repro.obs import Telemetry
    from repro.serve import Engine, ServeConfig, TrafficConfig, run_traffic

    cfg = get_reduced(SERVE_ARCH).with_(vocab_size=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tel = Telemetry(os.path.join(td, "serve"))
    eng = Engine(
        model, params,
        ServeConfig(batch_slots=2, max_len=32, max_new_tokens=4,
                    page_size=8, prefill_chunk=8),
        telemetry=tel,
    )
    n_req = 4
    t0 = time.perf_counter()
    run_traffic(eng, TrafficConfig(
        qps=32.0, num_requests=n_req, prompt_len=(2, 6),
        vocab_size=cfg.vocab_size, seed=0,
    ))
    wall = time.perf_counter() - t0
    paths = tel.save()
    tel.close()

    kinds = _validate_jsonl(paths["events"], schema)
    if kinds.get("serve_request") != n_req or not kinds.get("serve_report"):
        raise AssertionError(
            f"obs gate: serve run emitted {kinds} for {n_req} requests"
        )

    with open(paths["trace"]) as f:
        trace = json.load(f)
    per_stage: dict[str, set] = {s: set() for s in SERVE_STAGES}
    for ev in trace["traceEvents"]:
        cat = ev.get("cat", "")
        if cat.startswith("serve,"):
            stage = cat.split(",", 1)[1]
            if stage in per_stage:
                per_stage[stage].add(ev["args"]["rid"])
    for stage, rids in per_stage.items():
        if len(rids) != n_req:
            raise AssertionError(
                f"obs gate: stage {stage!r} spans for requests "
                f"{sorted(rids)}, expected all {n_req}"
            )
    return wall, {s: len(r) for s, r in per_stage.items()}


def _overhead_gate(td: str, smoke: bool) -> tuple:
    """Interleaved min-of-trials instrumented-vs-bare step wall on ONE
    precompiled trainer: both arms replay the identical step sequence from
    the same initial state (the jitted path is functional), so the only
    delta is the telemetry work — per-step counter incs, per-log-cadence
    gauge sets + one streamed JSONL record (log_every=1 here: the
    *maximally* instrumented cadence)."""
    from repro.data import DataConfig, make_loader
    from repro.configs import get_reduced
    from repro.models import build_model
    from repro.obs import NULL_TELEMETRY, Telemetry
    from repro.optim import sgd
    from repro.train.trainer import TrainConfig, Trainer

    cfg = get_reduced("gpt2-paper").with_(vocab_size=256)
    model = build_model(cfg)
    tc = TrainConfig(compressor="covap", interval=2, log_every=1, steps=64)
    tr = Trainer(model, sgd(1e-3, momentum=0.9), tc)
    state = tr.init_state(jax.random.PRNGKey(0))
    loader = iter(make_loader(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=16, global_batch=4,
    )))

    def silent(*_a, **_k):
        pass

    # the real per-step delta is ~25 µs (one streamed JSONL record + a few
    # gauge sets at log cadence, one counter inc per step) on a ~8 ms step
    # — ~0.3%, an order under budget — so the gate's enemy is host noise,
    # and the estimator needs depth: many short interleaved trials, min
    # per side (both sides see the same noise regime; min discards it)
    steps = 4 if smoke else 8
    trials = 5 if smoke else 9
    tr.run(state, loader, steps=2, log=silent)   # compile both phases
    tel = Telemetry(os.path.join(td, "overhead"))

    def timed(telemetry) -> float:
        t0 = time.perf_counter()
        tr.run(state, loader, steps=steps, log=silent, telemetry=telemetry)
        return (time.perf_counter() - t0) / steps

    def measure() -> tuple:
        import gc

        gc.collect()    # don't let earlier modules' garbage bill a trial
        on, off = [], []
        for k in range(trials):
            tr.telemetry = NULL_TELEMETRY  # un-stick the previous on-trial
            # alternate pair order: a fixed off-then-on order would charge
            # any systematic second-position penalty (frequency scaling,
            # GC debt from the first run) entirely to the instrumented arm
            if k % 2 == 0:
                off.append(timed(None))
                on.append(timed(tel))
            else:
                on.append(timed(tel))
                tr.telemetry = NULL_TELEMETRY
                off.append(timed(None))
        min_on, min_off = min(on), min(off)
        return min_on / max(min_off, 1e-12) - 1.0, min_on, min_off

    # the 3% budget sits below this box's trial-to-trial scheduler noise,
    # so re-measure up to 3 rounds and gate on the best: a structural
    # regression is over budget in EVERY round, a noise spike is not
    frac, min_on, min_off = measure()
    for _ in range(2):
        if frac <= OVERHEAD_BUDGET - 1.0:
            break
        frac, min_on, min_off = min((frac, min_on, min_off), measure())
    tel.close()
    if (frac > OVERHEAD_BUDGET - 1.0
            and not os.environ.get("REPRO_OBS_NO_OVERHEAD_GATE")):
        raise AssertionError(
            f"obs gate: instrumented step {min_on*1e3:.2f} ms is "
            f"{frac*100:.1f}% over bare {min_off*1e3:.2f} ms "
            f"(budget {OVERHEAD_BUDGET - 1:.0%}; "
            f"REPRO_OBS_NO_OVERHEAD_GATE=1 to record anyway)"
        )
    return frac, min_on, min_off, trials


def run(smoke: bool = False):
    from repro.obs import load_schema

    schema = load_schema()
    rows = []
    with tempfile.TemporaryDirectory() as td:
        train_wall, kinds, n_buckets = _train_gate(td, schema, smoke)
        rows.append(row(
            "obs/train_gate", train_wall,
            f"buckets={n_buckets}/{n_buckets} "
            f"events={sum(kinds.values())} kinds={len(kinds)}",
        ))
        serve_wall, stages = _serve_gate(td, schema, smoke)
        rows.append(row(
            "obs/serve_gate", serve_wall,
            "spans=" + ",".join(f"{s}:{n}" for s, n in stages.items()),
        ))
        frac, min_on, min_off, trials = _overhead_gate(td, smoke)
        # the µs column carries the dimensionless overhead fraction
        # (row() scales by 1e6, hence the /1e6) — build_snapshot lifts it
        # into the telemetry_overhead_frac gauge
        rows.append(row(
            "obs/overhead_frac", frac / 1e6,
            f"on={min_on*1e3:.2f}ms off={min_off*1e3:.2f}ms "
            f"trials={trials} budget={OVERHEAD_BUDGET - 1:.0%}",
        ))
    return rows
