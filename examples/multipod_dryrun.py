"""Example: lower + compile one architecture against the production meshes
and print its roofline terms (the programmatic face of launch/dryrun.py).

    PYTHONPATH=src python examples/multipod_dryrun.py --arch gemma-2b
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse

from repro.launch.dryrun import run_one

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="gemma-2b")
ap.add_argument("--shape", default="train_4k")
args = ap.parse_args()

for multi_pod in (False, True):
    rec = run_one(args.arch, args.shape, multi_pod)
    mesh = rec["mesh"]
    if rec["status"] != "ok":
        print(f"{mesh}: FAILED {rec['error']}")
        continue
    r = rec["roofline"]
    print(f"{mesh}: dominant={r['dominant']} "
          f"compute={r['compute_s']*1e3:.2f}ms "
          f"memory={r['memory_s']*1e3:.2f}ms "
          f"collective={r['collective_s']*1e3:.2f}ms "
          f"(I={rec.get('interval')}, buckets={rec.get('plan_buckets')})")
