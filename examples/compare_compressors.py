"""Table-VII-style comparison: train the same model with every GC scheme and
report wall time + final loss (the paper's time-to-solution experiment at
laptop scale).

    PYTHONPATH=src python examples/compare_compressors.py [--steps 30]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.data import DataConfig, make_loader
from repro.models import build_model
from repro.optim import adamw
from repro.train.trainer import TrainConfig, Trainer

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=30)
ap.add_argument("--schemes", default="none,covap,fp16,topk,randomk,efsignsgd,powersgd,fp8wire")
args = ap.parse_args()

cfg = get_reduced("gpt2-paper").with_(vocab_size=256)
model = build_model(cfg)
data = DataConfig(vocab_size=cfg.vocab_size, seq_len=48, global_batch=8)

print(f"{'scheme':12s} {'wall_s':>8s} {'final_loss':>11s} {'sent_ratio':>10s}")
for scheme in args.schemes.split(","):
    tc = TrainConfig(compressor=scheme, interval=4, bucket_bytes=1 << 14,
                     max_buckets=32, log_every=10**9)
    tr = Trainer(model, adamw(3e-3), tc)
    state = tr.init_state(jax.random.PRNGKey(0))
    loader = iter(make_loader(data))
    # warm-up/compile every phase executable outside the timed region
    batch = next(loader)
    for ph in range(tr.num_phases):
        tr._phase_fn(ph)(state["params"], state["opt"], state["comp"],
                         batch, jnp.int32(ph))
    t0 = time.perf_counter()
    losses = []
    for i in range(args.steps):
        batch = next(loader)
        phase = state["step"] % tr.num_phases
        p, o, c, m = tr._phase_fn(phase)(
            state["params"], state["opt"], state["comp"], batch,
            jnp.int32(state["step"]))
        state = {"params": p, "opt": o, "comp": c, "step": state["step"] + 1}
        losses.append(float(m["loss"]))
    wall = time.perf_counter() - t0
    # volume ratio from the compressor's static accounting
    from repro.core import get_compressor
    comp = tr.compressor
    _, _, stats = comp.sync(
        jax.tree.map(jnp.zeros_like, state["params"]),
        comp.init_state(state["params"], tr.plan),
        plan=tr.plan, phase=0, step=0, axis_names=())
    print(f"{scheme:12s} {wall:8.2f} {losses[-1]:11.4f} "
          f"{stats.volume_ratio:9.1f}x")
