"""Batched serving demo: continuous batching over the paged KV arena.

    PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.models import build_model
from repro.serve import Engine, ServeConfig

cfg = get_reduced("qwen1.5-0.5b").with_(vocab_size=256)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(7))

eng = Engine(model, params,
             ServeConfig(batch_slots=4, max_len=96, max_new_tokens=12,
                         page_size=16, prefill_chunk=16))
print(f"arena: {eng.arena.num_pages} pages x {eng.layout.page_bytes()} B "
      f"({eng.arena.nbytes() / 1e6:.1f} MB)")
rng = np.random.default_rng(0)
rids = [eng.submit(rng.integers(0, 256, size=5).tolist()) for _ in range(10)]

t0 = time.perf_counter()
results = eng.run_until_done()
wall = time.perf_counter() - t0
toks = sum(len(c.tokens) for c in results.values())
m = eng.metrics()
print(f"completed {len(results)} requests, {toks} tokens in {wall:.2f}s")
print(f"stages: prefill={m['prefill_tok_us']:.0f}us/tok "
      f"generate={m['generate_tok_us']:.0f}us/tok insert={m['insert_us']:.0f}us")
for rid in rids[:3]:
    c = results[rid]
    print(f"  request {rid} -> {c.tokens} [{c.finish_reason}]")
