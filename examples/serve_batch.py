"""Batched serving demo: continuous batching through the slot engine.

    PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.models import build_model
from repro.serve import Engine, ServeConfig

cfg = get_reduced("qwen1.5-0.5b").with_(vocab_size=256)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(7))

eng = Engine(model, params,
             ServeConfig(batch_slots=4, max_len=96, max_new_tokens=12))
rng = np.random.default_rng(0)
rids = [eng.submit(rng.integers(0, 256, size=5).tolist()) for _ in range(10)]

t0 = time.perf_counter()
results = eng.run_until_done()
wall = time.perf_counter() - t0
toks = sum(len(v) for v in results.values())
print(f"completed {len(results)} requests, {toks} tokens in {wall:.2f}s")
for rid in rids[:3]:
    print(f"  request {rid} -> {results[rid]}")
