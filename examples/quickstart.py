"""Quickstart: train a small LM with COVAP data-parallel gradient compression.

    PYTHONPATH=src python examples/quickstart.py

Shows the full public API surface: config -> model -> trainer (bucket plan,
coarse filter, error feedback) -> training on learnable synthetic data.
"""
import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.data import DataConfig, make_loader
from repro.models import build_model
from repro.optim import adamw
from repro.train.trainer import TrainConfig, Trainer

cfg = get_reduced("gpt2-paper").with_(vocab_size=256)
model = build_model(cfg)

tc = TrainConfig(
    compressor="covap",      # the paper's scheme; try "topk", "powersgd", ...
    interval=4,              # I = ceil(CCR); COVAP compresses volume by ~I
    bucket_bytes=1 << 14,
    max_buckets=32,
    log_every=5,
)
trainer = Trainer(model, adamw(3e-3), tc)
print(f"bucket plan: {trainer.plan.num_buckets} buckets, "
      f"{trainer.num_phases} phase-specialised executables")

state = trainer.init_state(jax.random.PRNGKey(0))
data = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
state = trainer.run(state, iter(make_loader(data)), steps=40)
print(f"final loss: {trainer.history[-1]['loss']:.4f}")

# --- adaptive mode: the interval tracks the *measured* CCR online --------
# The analytic profiler picks the initial I; the runtime then probes the
# compute-only / schedule-only sub-programs, and a hysteresis controller
# re-plans the interval when the measured CCR drifts (EF residuals are
# carried across each switch).  On a single process the honest measured
# CCR is ~0, so expect it to settle at I=1 here.
import repro.api as api
from repro.runtime import AutotuneConfig

result = api.fit(
    "gpt2-paper", reduced=True, vocab_size=256, interval="adaptive",
    steps=30, seq_len=64, global_batch=8,
    autotune=AutotuneConfig(measure_every=8, warmup_steps=4,
                            cooldown_steps=8),
)
print(f"adaptive: initial I={result.interval} "
      f"-> final I={result.final_interval}, "
      f"measured CCR={result.autotune['measured_ccr']:.3f}, "
      f"{result.autotune['replans']} re-plan(s)")

# --- overlap execution engine -------------------------------------------
# overlap="fused" issues each bucket's all-reduce INSIDE the backward pass
# (gradient-ready hooks; bit-for-bit equal to the default post-hoc path),
# and api.tune reports the overlap headroom per scheme: how much of each
# scheme's wire time the engine can hide under backward compute.
result = api.fit(
    "gpt2-paper", reduced=True, vocab_size=256, interval=4,
    steps=10, seq_len=64, global_batch=8, overlap="fused",
)
print(f"fused overlap: final loss {result.final_loss:.4f}")

for row in api.tune("gpt2-paper", dp_workers=64,
                    candidates=(("covap", {}), ("none", {}),
                                ("oktopk", {"ratio": 0.01}))):
    print(f"  {row['compressor']:>8s}  speedup {row['speedup']:5.1f}  "
          f"overlap modeled {row['overlap_frac_modeled']:.2f}  "
          f"pack {row['pack_overhead_us']:.1f}us")
# COVAP keeps ~all of its (tiny) wire time hidden; ok-topk's data-dependent
# all-to-all forfeits overlap entirely (paper Fig. 1e) — the report makes
# the difference visible without compiling anything.

# --- zero-copy gradient arena -------------------------------------------
# arena=True packs the step's gradient ONCE into statically-planned flat
# bucket buffers (fused compensate+cast+pack pass) so every bucket's
# payload is a static slice view — bitwise-identical results, with the
# per-bucket gather/scatter copies gone.  Measure the per-step saving:
import time

def _wall(arena: bool, steps: int = 12) -> float:
    t0 = time.perf_counter()
    api.fit("gpt2-paper", reduced=True, vocab_size=256, interval=4,
            steps=steps, seq_len=64, global_batch=8, arena=arena)
    return (time.perf_counter() - t0) / steps

off_s, on_s = _wall(False), _wall(True)
print(f"arena off {off_s*1e3:.1f} ms/step -> on {on_s*1e3:.1f} ms/step "
      f"({(off_s - on_s)*1e6:+.0f} us/step packed away; includes compile, "
      f"CPU-scale noise — the structural win is the HLO copy-count gate)")
