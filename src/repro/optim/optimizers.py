"""Pytree optimizers.  ``Optimizer`` is an (init, update) pair:

    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

Moment dtype is configurable (bf16 moments for the 100B+ archs, DESIGN SS8).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]


def _sched(lr):
    return lr if callable(lr) else (lambda step: jnp.asarray(lr, jnp.float32))


def sgd(lr, momentum: float = 0.9, nesterov: bool = False) -> Optimizer:
    lr_fn = _sched(lr)

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(jnp.zeros_like, params) if momentum else (),
        }

    def update(grads, state, params=None):
        step = state["step"] + 1
        if momentum:
            mu = jax.tree.map(
                lambda m, g: momentum * m + g.astype(m.dtype), state["mu"], grads
            )
            if nesterov:
                upd = jax.tree.map(lambda m, g: momentum * m + g.astype(m.dtype), mu, grads)
            else:
                upd = mu
            new_state = {"step": step, "mu": mu}
        else:
            upd = grads
            new_state = {"step": step, "mu": ()}
        lr = lr_fn(step)
        upd = jax.tree.map(lambda u: (-lr * u.astype(jnp.float32)), upd)
        return upd, new_state

    return Optimizer(init, update)


def adamw(
    lr,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    moment_dtype: str | None = None,
) -> Optimizer:
    lr_fn = _sched(lr)

    def _mdtype(p):
        return jnp.dtype(moment_dtype) if moment_dtype else p.dtype

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, _mdtype(p)), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, _mdtype(p)), params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        m = jax.tree.map(
            lambda m, g: (b1 * m.astype(jnp.float32) + (1 - b1) * g.astype(jnp.float32)).astype(m.dtype),
            state["m"], grads,
        )
        v = jax.tree.map(
            lambda v, g: (b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g.astype(jnp.float32))).astype(v.dtype),
            state["v"], grads,
        )
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t
        lr = lr_fn(step)

        def upd(m, v, p):
            mh = m.astype(jnp.float32) / bc1
            vh = v.astype(jnp.float32) / bc2
            u = mh / (jnp.sqrt(vh) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return -lr * u

        updates = jax.tree.map(upd, m, v, params)
        return updates, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params, updates
    )
