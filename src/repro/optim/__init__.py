"""Optimizers (pure-pytree, no optax): SGD+momentum, Adam/AdamW, LR
schedules, global-norm clipping.  Matches the paper's setups: SGD(1e-3) for
CV models, Adam(5e-5 / 1.5e-4) for Bert/GPT-2.
"""
from .optimizers import Optimizer, adamw, apply_updates, sgd
from .schedules import constant, cosine_warmup, linear_warmup
from .clip import clip_by_global_norm, global_norm

__all__ = [
    "Optimizer",
    "adamw",
    "sgd",
    "apply_updates",
    "constant",
    "cosine_warmup",
    "linear_warmup",
    "clip_by_global_norm",
    "global_norm",
]
