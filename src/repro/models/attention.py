"""Attention: MHA/GQA/MQA with RoPE, q-chunked streaming softmax (bounded
memory at 32k prefill), sliding-window and softcap variants, and a KV-cache
decode path (rolling cache for windowed layers -> O(window) state at 500k).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from .layers import linear_init, rope, softcap, truncated_normal_init

NEG_INF = -2.0e38


def attn_init(key, cfg, dtype) -> dict:
    d, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": truncated_normal_init(ks[0], (d, H * hd), dtype),
        "wk": truncated_normal_init(ks[1], (d, K * hd), dtype),
        "wv": truncated_normal_init(ks[2], (d, K * hd), dtype),
        "wo": truncated_normal_init(ks[3], (H * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((K * hd,), dtype)
        p["bv"] = jnp.zeros((K * hd,), dtype)
    return p


def _qkv(params, x, cfg):
    cd = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cd)
    B, S, _ = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", xc, params["wq"].astype(cd))
    k = jnp.einsum("bsd,dh->bsh", xc, params["wk"].astype(cd))
    v = jnp.einsum("bsd,dh->bsh", xc, params["wv"].astype(cd))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(cd)
        k = k + params["bk"].astype(cd)
        v = v + params["bv"].astype(cd)
    return (
        q.reshape(B, S, H, hd),
        k.reshape(B, S, K, hd),
        v.reshape(B, S, K, hd),
    )


def _scores_softmax_value(q, k, v, mask, cfg):
    """q: (B,Sq,K,G,hd)  k/v: (B,T,K,hd)  mask: (B,1,1,Sq,T) or (1,1,1,Sq,T).

    Returns (B,Sq,K,G,hd).  fp32 softmax."""
    scale = cfg.head_dim ** -0.5
    s = jnp.einsum("bqkgh,btkh->bkgqt", q, k).astype(jnp.float32) * scale
    if cfg.attn_softcap > 0:
        s = cfg.attn_softcap * jnp.tanh(s / cfg.attn_softcap)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgqt,btkh->bqkgh", p, v)


def attn_train(params, x, cfg, *, window: int = 0) -> jax.Array:
    """Causal self-attention over a full sequence, q-chunked.

    ``window > 0`` restricts to a sliding window (j in (i-window, i])."""
    B, S, d = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // K
    q, k, v = _qkv(params, x, cfg)
    positions = jnp.arange(S)[None, :]
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = q.reshape(B, S, K, G, hd)

    chunk = min(cfg.attn_chunk, S)
    if S % chunk != 0:
        chunk = S  # fall back to unchunked for odd smoke shapes
    n_chunks = S // chunk
    t_idx = jnp.arange(S)

    def body(carry, qc_and_off):
        qc, off = qc_and_off
        q_idx = off * chunk + jnp.arange(chunk)
        m = t_idx[None, :] <= q_idx[:, None]
        if window > 0:
            m &= t_idx[None, :] > (q_idx[:, None] - window)
        m = m[None, None, None]  # (1,1,1,chunk,T)
        out = _scores_softmax_value(qc, k, v, m, cfg)
        return carry, out

    q_chunks = q.reshape(B, n_chunks, chunk, K, G, hd).transpose(1, 0, 2, 3, 4, 5)
    _, outs = lax.scan(body, (), (q_chunks, jnp.arange(n_chunks)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H * hd)
    cd = jnp.dtype(cfg.compute_dtype)
    return jnp.einsum("bsh,hd->bsd", out, params["wo"].astype(cd))


# ---------------------------------------------------------------------------
# KV cache (decode)
# ---------------------------------------------------------------------------

def _cache_dtype(cfg):
    if cfg.kv_cache_dtype == "int8":
        return jnp.int8
    return jnp.dtype(cfg.kv_cache_dtype or cfg.compute_dtype)


def init_cache(cfg, batch: int, max_len: int, *, window: int = 0) -> dict:
    """Rolling cache for windowed layers; linear cache otherwise.

    With ``kv_cache_dtype='int8'`` keys/values are stored quantised with a
    per-(slot, position, head) fp16-ish scale (SSPerf memory-term lever:
    halves decode HBM traffic vs bf16)."""
    K, hd = cfg.num_kv_heads, cfg.head_dim
    T = min(window, max_len) if window > 0 else max_len
    dt = _cache_dtype(cfg)
    c = {
        "k": jnp.zeros((batch, T, K, hd), dt),
        "v": jnp.zeros((batch, T, K, hd), dt),
    }
    if cfg.kv_cache_dtype == "int8":
        c["k_scale"] = jnp.zeros((batch, T, K), jnp.bfloat16)
        c["v_scale"] = jnp.zeros((batch, T, K), jnp.bfloat16)
    return c


def cache_specs(cfg, batch: int, max_len: int, *, window: int = 0) -> dict:
    K, hd = cfg.num_kv_heads, cfg.head_dim
    T = min(window, max_len) if window > 0 else max_len
    dt = _cache_dtype(cfg)
    c = {
        "k": jax.ShapeDtypeStruct((batch, T, K, hd), dt),
        "v": jax.ShapeDtypeStruct((batch, T, K, hd), dt),
    }
    if cfg.kv_cache_dtype == "int8":
        c["k_scale"] = jax.ShapeDtypeStruct((batch, T, K), jnp.bfloat16)
        c["v_scale"] = jax.ShapeDtypeStruct((batch, T, K), jnp.bfloat16)
    return c


def _quantize_kv(x):
    """x: (B, K, hd) -> (int8 payload, (B, K) scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127
    ).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def _dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)).astype(dtype)


def attn_decode(params, x, cache, pos, cfg, *, window: int = 0):
    """One decode step.

    x: (B, 1, d); pos: (B,) absolute position of the new token.
    Returns (y (B,1,d), new_cache)."""
    B = x.shape[0]
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // K
    T = cache["k"].shape[1]
    q, k, v = _qkv(params, x, cfg)
    q = rope(q, pos[:, None], cfg.rope_theta)
    k = rope(k, pos[:, None], cfg.rope_theta)

    slot = (pos % T) if window > 0 else pos  # rolling for windowed layers
    b_idx = jnp.arange(B)
    quantized = cfg.kv_cache_dtype == "int8"
    if quantized:
        qk, sk = _quantize_kv(k[:, 0])
        qv, sv = _quantize_kv(v[:, 0])
        new_cache = {
            "k": cache["k"].at[b_idx, slot].set(qk),
            "v": cache["v"].at[b_idx, slot].set(qv),
            "k_scale": cache["k_scale"].at[b_idx, slot].set(sk),
            "v_scale": cache["v_scale"].at[b_idx, slot].set(sv),
        }
        new_k = _dequantize_kv(new_cache["k"], new_cache["k_scale"], k.dtype)
        new_v = _dequantize_kv(new_cache["v"], new_cache["v_scale"], v.dtype)
    else:
        new_k = cache["k"].at[b_idx, slot].set(k[:, 0])
        new_v = cache["v"].at[b_idx, slot].set(v[:, 0])
        new_cache = {"k": new_k, "v": new_v}

    t_idx = jnp.arange(T)[None, :]
    if window > 0:
        valid = t_idx <= jnp.minimum(pos, T - 1)[:, None]
    else:
        valid = t_idx <= pos[:, None]
    mask = valid[:, None, None, None, :]  # (B,1,1,1,T)

    qh = q.reshape(B, 1, K, G, hd)
    out = _scores_softmax_value(qh, new_k, new_v, mask, cfg)
    out = out.reshape(B, 1, H * hd)
    cd = jnp.dtype(cfg.compute_dtype)
    y = jnp.einsum("bsh,hd->bsd", out, params["wo"].astype(cd))
    return y, new_cache
