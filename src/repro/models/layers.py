"""Shared model primitives: norms, linears, embeddings, RoPE, gated MLPs.

Conventions:
* params are nested dicts of ``jnp`` arrays;
* stacked-layer leaves carry a leading ``(n_superblocks,)`` axis and are
  consumed inside ``lax.scan`` bodies;
* matmul inputs are cast to ``compute_dtype``; accumulation is fp32 via
  ``preferred_element_type`` where it matters.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax


def truncated_normal_init(key, shape, dtype, scale: float = 1.0):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / math.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * std).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.zeros((d,), dtype)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * lax.rsqrt(var + eps)
    return (x * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


def layernorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * lax.rsqrt(var + eps)
    out = x * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# linear / embedding
# ---------------------------------------------------------------------------

def linear_init(key, d_in: int, d_out: int, dtype, bias: bool = False) -> dict:
    p = {"w": truncated_normal_init(key, (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(params: dict, x: jax.Array, compute_dtype=jnp.bfloat16) -> jax.Array:
    w = params["w"].astype(compute_dtype)
    y = jnp.einsum("...i,io->...o", x.astype(compute_dtype), w)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def embedding_init(key, vocab: int, d: int, dtype) -> dict:
    return {"table": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def embed(params: dict, tokens: jax.Array, compute_dtype=jnp.bfloat16) -> jax.Array:
    return params["table"].astype(compute_dtype)[tokens]


def unembed(params: dict, x: jax.Array, compute_dtype=jnp.bfloat16) -> jax.Array:
    """Tied unembedding: logits over the vocab."""
    t = params["table"].astype(compute_dtype)
    return jnp.einsum("...d,vd->...v", x.astype(compute_dtype), t)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(
        -jnp.arange(0, half, dtype=jnp.float32) * (math.log(theta) / half)
    )
    ang = positions[..., :, None].astype(jnp.float32) * freqs[None, :]
    cos = jnp.cos(ang)[..., :, None, :]  # (..., seq, 1, half)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# gated MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": truncated_normal_init(k1, (d, d_ff), dtype),
        "w_up": truncated_normal_init(k2, (d, d_ff), dtype),
        "w_down": truncated_normal_init(k3, (d_ff, d), dtype),
    }


def _act(name: str, x: jax.Array) -> jax.Array:
    if name == "swiglu":
        return jax.nn.silu(x)
    if name == "geglu":
        return jax.nn.gelu(x, approximate=True)
    if name == "gelu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(name)


def mlp(params: dict, x: jax.Array, act: str, compute_dtype=jnp.bfloat16) -> jax.Array:
    xc = x.astype(compute_dtype)
    g = jnp.einsum("...d,df->...f", xc, params["w_gate"].astype(compute_dtype))
    u = jnp.einsum("...d,df->...f", xc, params["w_up"].astype(compute_dtype))
    h = _act(act, g) * u
    return jnp.einsum("...f,fd->...d", h, params["w_down"].astype(compute_dtype))


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)
