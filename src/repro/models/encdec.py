"""Encoder-decoder transformer (seamless-m4t backbone).

Encoder: bidirectional self-attention over stub frame embeddings.
Decoder: causal self-attention + cross-attention to the encoder memory.
Decode path caches decoder self-attn KV plus the projected memory KV.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from . import attention as attn
from .attention import NEG_INF, _qkv, _scores_softmax_value
from .layers import mlp, mlp_init, rmsnorm, rmsnorm_init, rope, truncated_normal_init


# ---------------------------------------------------------------------------
# cross attention
# ---------------------------------------------------------------------------

def cross_attn_init(key, cfg, dtype):
    return attn.attn_init(key, cfg, dtype)


def _memory_kv(params, memory, cfg):
    cd = jnp.dtype(cfg.compute_dtype)
    B, T, _ = memory.shape
    K, hd = cfg.num_kv_heads, cfg.head_dim
    k = jnp.einsum("btd,dh->bth", memory.astype(cd), params["wk"].astype(cd))
    v = jnp.einsum("btd,dh->bth", memory.astype(cd), params["wv"].astype(cd))
    return k.reshape(B, T, K, hd), v.reshape(B, T, K, hd)


def cross_attn(params, x, mem_k, mem_v, cfg):
    """x: (B,S,d); mem_k/v: (B,T,K,hd).  No masking (full cross-attn)."""
    B, S, _ = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // K
    cd = jnp.dtype(cfg.compute_dtype)
    q = jnp.einsum("bsd,dh->bsh", x.astype(cd), params["wq"].astype(cd))
    q = q.reshape(B, S, K, G, hd)
    mask = jnp.ones((1, 1, 1, S, mem_k.shape[1]), bool)
    out = _scores_softmax_value(q, mem_k, mem_v, mask, cfg)
    out = out.reshape(B, S, H * hd)
    return jnp.einsum("bsh,hd->bsd", out, params["wo"].astype(cd))


def _bidir_attn(params, x, cfg):
    """Non-causal self-attention (encoder)."""
    B, S, _ = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // K
    cd = jnp.dtype(cfg.compute_dtype)
    q, k, v = _qkv(params, x, cfg)
    positions = jnp.arange(S)[None, :]
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    mask = jnp.ones((1, 1, 1, S, S), bool)
    out = _scores_softmax_value(q.reshape(B, S, K, G, hd), k, v, mask, cfg)
    out = out.reshape(B, S, H * hd)
    return jnp.einsum("bsh,hd->bsd", out, params["wo"].astype(cd))


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def enc_block_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn.attn_init(k1, cfg, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def dec_block_init(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn.attn_init(k1, cfg, dtype),
        "lnx": rmsnorm_init(cfg.d_model, dtype),
        "xattn": cross_attn_init(k2, cfg, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, dtype),
    }


def encdec_init(key, cfg) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kd = jax.random.split(key)
    enc_keys = jax.random.split(ke, cfg.encoder_layers)
    dec_keys = jax.random.split(kd, cfg.num_layers)
    return {
        "encoder": jax.vmap(lambda k: enc_block_init(k, cfg, dtype))(enc_keys),
        "enc_norm": rmsnorm_init(cfg.d_model, dtype),
        "decoder": jax.vmap(lambda k: dec_block_init(k, cfg, dtype))(dec_keys),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }


def encode(params, frames, cfg):
    """frames: (B, T_enc, d) stub frontend embeddings -> memory (B, T_enc, d)."""
    cd = jnp.dtype(cfg.compute_dtype)
    x = frames.astype(cd)

    def body(x, p):
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        x = x + _bidir_attn(p["attn"], h, cfg)
        h = rmsnorm(p["ln2"], x, cfg.norm_eps)
        x = x + mlp(p["mlp"], h, cfg.mlp_act, cd)
        return x, ()

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = lax.scan(body, x, params["encoder"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def decode_train(params, x, memory, cfg, *, window: int = 0):
    """Teacher-forced decoder pass.  x: (B,S,d) token embeddings."""
    cd = jnp.dtype(cfg.compute_dtype)

    def body(x, p):
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        x = x + attn.attn_train(p["attn"], h, cfg, window=window)
        h = rmsnorm(p["lnx"], x, cfg.norm_eps)
        mk, mv = _memory_kv(p["xattn"], memory, cfg)
        x = x + cross_attn(p["xattn"], h, mk, mv, cfg)
        h = rmsnorm(p["ln2"], x, cfg.norm_eps)
        x = x + mlp(p["mlp"], h, cfg.mlp_act, cd)
        return x, ()

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = lax.scan(body, x.astype(cd), params["decoder"])
    return rmsnorm(params["final_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# decode step
# ---------------------------------------------------------------------------

def dec_caches(params_or_cfg, cfg, batch, max_len, memory_len, *, window: int = 0,
               specs_only: bool = False):
    L = cfg.num_layers
    K, hd = cfg.num_kv_heads, cfg.head_dim
    cd = jnp.dtype(cfg.compute_dtype)
    self_c = attn.cache_specs(cfg, batch, max_len, window=window) if specs_only \
        else attn.init_cache(cfg, batch, max_len, window=window)

    def stack(leaf):
        if specs_only:
            return jax.ShapeDtypeStruct((L,) + leaf.shape, leaf.dtype)
        return jnp.zeros((L,) + leaf.shape, leaf.dtype)

    mem_kv_shape = (L, batch, memory_len, K, hd)
    mem_kv = (
        jax.ShapeDtypeStruct(mem_kv_shape, cd)
        if specs_only
        else jnp.zeros(mem_kv_shape, cd)
    )
    return {
        "self": jax.tree.map(stack, self_c),
        "mem_k": mem_kv,
        "mem_v": mem_kv if specs_only else jnp.zeros(mem_kv_shape, cd),
    }


def precompute_memory_kv(params, memory, cfg):
    """Project encoder memory into per-layer cross-attn KV once per request."""

    def body(_, p):
        mk, mv = _memory_kv(p["xattn"], memory, cfg)
        return (), (mk, mv)

    _, (mks, mvs) = lax.scan(body, (), params["decoder"])
    return mks, mvs  # (L, B, T, K, hd)


def decode_step(params, x, caches, pos, cfg, *, window: int = 0):
    """x: (B,1,d) -> (y (B,1,d), new_caches)."""
    cd = jnp.dtype(cfg.compute_dtype)

    def body(x, scanned):
        p, self_c, mk, mv = scanned
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        y, self_c = attn.attn_decode(p["attn"], h, self_c, pos, cfg, window=window)
        x = x + y
        h = rmsnorm(p["lnx"], x, cfg.norm_eps)
        x = x + cross_attn(p["xattn"], h, mk, mv, cfg)
        h = rmsnorm(p["ln2"], x, cfg.norm_eps)
        x = x + mlp(p["mlp"], h, cfg.mlp_act, cd)
        return x, self_c

    x, new_self = lax.scan(
        body, x.astype(cd),
        (params["decoder"], caches["self"], caches["mem_k"], caches["mem_v"]),
    )
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, {"self": new_self, "mem_k": caches["mem_k"], "mem_v": caches["mem_v"]}
