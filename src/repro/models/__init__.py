"""Model zoo substrate: every assigned architecture family in pure JAX."""
from .model import (
    Model,
    build_model,
    build_param_specs,
    count_params,
    long_context_variant,
    model_flops,
    padded_vocab,
)

__all__ = [
    "Model",
    "build_model",
    "build_param_specs",
    "count_params",
    "long_context_variant",
    "model_flops",
    "padded_vocab",
]
