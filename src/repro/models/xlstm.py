"""xLSTM blocks (Beck et al., arXiv:2405.04517): mLSTM (matrix memory,
parallelizable) and sLSTM (scalar memory, true recurrence with block-diagonal
recurrent weights).  Exponential gating with the max-stabilizer state m.

Train/prefill run a per-token ``lax.scan`` (compile-size O(1) in sequence
length); decode carries (C, n, m) / (c, n, m, h) states — O(1) per token,
which is why xlstm runs ``long_500k`` natively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .layers import rmsnorm, rmsnorm_init, truncated_normal_init


def _dims(cfg):
    d = cfg.d_model
    d_in = 2 * d               # mLSTM expansion 2 (paper)
    H = cfg.num_heads
    hd = d_in // H
    return d, d_in, H, hd


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg, dtype) -> dict:
    d, d_in, H, hd = _dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "norm": rmsnorm_init(d, dtype),
        "up_x": truncated_normal_init(ks[0], (d, d_in), dtype),
        "up_z": truncated_normal_init(ks[7], (d, d_in), dtype),
        "wq": truncated_normal_init(ks[1], (d_in, d_in), dtype),
        "wk": truncated_normal_init(ks[2], (d_in, d_in), dtype),
        "wv": truncated_normal_init(ks[3], (d_in, d_in), dtype),
        "wi": truncated_normal_init(ks[4], (d_in, H), jnp.float32, scale=0.1),
        "wf": truncated_normal_init(ks[5], (d_in, H), jnp.float32, scale=0.1),
        "bi": jnp.zeros((H,), jnp.float32),
        "bf": jnp.ones((H,), jnp.float32) * 3.0,  # open forget gates at init
        "out_norm": rmsnorm_init(d_in, dtype),
        "down": truncated_normal_init(ks[6], (d_in, d), dtype),
    }


def _mlstm_precompute(params, x, cfg):
    d, d_in, H, hd = _dims(cfg)
    cd = jnp.dtype(cfg.compute_dtype)
    xn = rmsnorm(params["norm"], x, cfg.norm_eps).astype(cd)
    xm = jnp.einsum("bsd,dk->bsk", xn, params["up_x"].astype(cd))
    z = jnp.einsum("bsd,dk->bsk", xn, params["up_z"].astype(cd))
    q = jnp.einsum("bsk,kj->bsj", xm, params["wq"].astype(cd))
    k = jnp.einsum("bsk,kj->bsj", xm, params["wk"].astype(cd))
    v = jnp.einsum("bsk,kj->bsj", xm, params["wv"].astype(cd))
    B, S = x.shape[:2]
    q = q.reshape(B, S, H, hd).astype(jnp.float32)
    k = k.reshape(B, S, H, hd).astype(jnp.float32) * (hd ** -0.5)
    v = v.reshape(B, S, H, hd).astype(jnp.float32)
    ig = jnp.einsum("bsk,kh->bsh", xm.astype(jnp.float32), params["wi"]) + params["bi"]
    fg = jnp.einsum("bsk,kh->bsh", xm.astype(jnp.float32), params["wf"]) + params["bf"]
    return q, k, v, ig, fg, z


def _mlstm_cell(state, qkvif):
    """One token of the stabilized mLSTM recurrence."""
    C, n, m = state                       # (B,H,hd,hd), (B,H,hd), (B,H)
    q, k, v, ig, fg = qkvif               # (B,H,hd) x3, (B,H) x2
    m_new = jnp.maximum(fg + m, ig)
    fp = jnp.exp(fg + m - m_new)[..., None]
    ip = jnp.exp(ig - m_new)[..., None]
    C_new = fp[..., None] * C + ip[..., None] * (v[..., :, None] * k[..., None, :])
    n_new = fp * n + ip * k
    num = jnp.einsum("bhij,bhj->bhi", C_new, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n_new, q)), 1.0)
    h = num / den[..., None]
    return (C_new, n_new, m_new), h


def mlstm_train(params, x, cfg) -> jax.Array:
    d, d_in, H, hd = _dims(cfg)
    cd = jnp.dtype(cfg.compute_dtype)
    B, S = x.shape[:2]
    q, k, v, ig, fg, z = _mlstm_precompute(params, x, cfg)

    def body(state, inp):
        return _mlstm_cell(state, inp)

    init = (
        jnp.zeros((B, H, hd, hd), jnp.float32),
        jnp.zeros((B, H, hd), jnp.float32),
        jnp.zeros((B, H), jnp.float32),
    )
    seq = (
        q.transpose(1, 0, 2, 3),
        k.transpose(1, 0, 2, 3),
        v.transpose(1, 0, 2, 3),
        ig.transpose(1, 0, 2),
        fg.transpose(1, 0, 2),
    )
    _, hs = lax.scan(body, init, seq)
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, d_in).astype(cd)
    h = rmsnorm(params["out_norm"], h, cfg.norm_eps)
    h = h * jax.nn.silu(z)
    return jnp.einsum("bsk,kd->bsd", h, params["down"].astype(cd))


def mlstm_state_init(cfg, batch):
    d, d_in, H, hd = _dims(cfg)
    return {
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.zeros((batch, H), jnp.float32),
    }


def mlstm_decode(params, x, state, cfg):
    d, d_in, H, hd = _dims(cfg)
    cd = jnp.dtype(cfg.compute_dtype)
    B = x.shape[0]
    q, k, v, ig, fg, z = _mlstm_precompute(params, x, cfg)
    st = (state["C"], state["n"], state["m"])
    st, h = _mlstm_cell(st, (q[:, 0], k[:, 0], v[:, 0], ig[:, 0], fg[:, 0]))
    h = h.reshape(B, 1, d_in).astype(cd)
    h = rmsnorm(params["out_norm"], h, cfg.norm_eps)
    h = h * jax.nn.silu(z)
    y = jnp.einsum("bsk,kd->bsd", h, params["down"].astype(cd))
    return y, {"C": st[0], "n": st[1], "m": st[2]}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg, dtype) -> dict:
    d = cfg.d_model
    H = cfg.num_heads
    hd = d // H
    ks = jax.random.split(key, 9)
    p = {"norm": rmsnorm_init(d, dtype)}
    for i, g in enumerate(("i", "f", "z", "o")):
        p[f"w{g}"] = truncated_normal_init(ks[i], (d, d), dtype)
        p[f"r{g}"] = truncated_normal_init(ks[4 + i], (H, hd, hd), dtype, scale=0.5)
        p[f"b{g}"] = (
            jnp.ones((d,), jnp.float32) * 3.0 if g == "f" else jnp.zeros((d,), jnp.float32)
        )
    p["down"] = truncated_normal_init(ks[8], (d, d), dtype)
    return p


def _slstm_cell(params, state, xg, cfg):
    """xg: dict of per-token gate pre-activations from the input side."""
    H = cfg.num_heads
    c, n, m, h = state                    # (B,H,hd) x2, (B,H,hd), (B,H,hd)

    def rec(g):
        r = params[f"r{g}"].astype(jnp.float32)
        return xg[g] + jnp.einsum("bhi,hij->bhj", h, r)

    it, ft = rec("i"), rec("f")
    zt = jnp.tanh(rec("z"))
    ot = jax.nn.sigmoid(rec("o"))
    m_new = jnp.maximum(ft + m, it)
    ip = jnp.exp(it - m_new)
    fp = jnp.exp(ft + m - m_new)
    c_new = fp * c + ip * zt
    n_new = fp * n + ip
    h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, m_new, h_new), h_new


def _slstm_inputs(params, x, cfg):
    d = cfg.d_model
    H = cfg.num_heads
    hd = d // H
    cd = jnp.dtype(cfg.compute_dtype)
    B, S = x.shape[:2]
    xn = rmsnorm(params["norm"], x, cfg.norm_eps).astype(cd)
    out = {}
    for g in ("i", "f", "z", "o"):
        v = jnp.einsum("bsd,dk->bsk", xn, params[f"w{g}"].astype(cd))
        v = v.astype(jnp.float32) + params[f"b{g}"]
        out[g] = v.reshape(B, S, H, hd)
    return out


def slstm_train(params, x, cfg) -> jax.Array:
    d = cfg.d_model
    H = cfg.num_heads
    hd = d // H
    cd = jnp.dtype(cfg.compute_dtype)
    B, S = x.shape[:2]
    xg = _slstm_inputs(params, x, cfg)

    def body(state, tok):
        return _slstm_cell(params, state, tok, cfg)

    init = tuple(jnp.zeros((B, H, hd), jnp.float32) for _ in range(4))
    seq = {g: xg[g].transpose(1, 0, 2, 3) for g in xg}
    _, hs = lax.scan(body, init, seq)
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, d).astype(cd)
    return jnp.einsum("bsd,dk->bsk", h, params["down"].astype(cd))


def slstm_state_init(cfg, batch):
    H = cfg.num_heads
    hd = cfg.d_model // H
    z = lambda: jnp.zeros((batch, H, hd), jnp.float32)
    return {"c": z(), "n": z(), "m": z(), "h": z()}


def slstm_decode(params, x, state, cfg):
    cd = jnp.dtype(cfg.compute_dtype)
    B = x.shape[0]
    xg = _slstm_inputs(params, x, cfg)
    tok = {g: xg[g][:, 0] for g in xg}
    st = (state["c"], state["n"], state["m"], state["h"])
    st, h = _slstm_cell(params, st, tok, cfg)
    h = h.reshape(B, 1, cfg.d_model).astype(cd)
    y = jnp.einsum("bsd,dk->bsk", h, params["down"].astype(cd))
    return y, {"c": st[0], "n": st[1], "m": st[2], "h": st[3]}
