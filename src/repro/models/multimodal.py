"""Modality frontend STUBS — the one allowed carve-out (DESIGN.md SS5).

VLM (pixtral):  ``input_specs`` provides precomputed ViT patch embeddings
``(B, n_patches, d_model)``; the backbone prepends them to the text-token
embeddings.  Audio (seamless): precomputed mel+conv frame embeddings
``(B, n_frames, d_model)`` feed the encoder.

A tiny learned projection is still applied (as real VLM projectors are), so
the frontend embeddings participate in training and gradient sync.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import truncated_normal_init


def projector_init(key, d_in: int, d_model: int, dtype) -> dict:
    return {"w": truncated_normal_init(key, (d_in, d_model), dtype)}


def project(params: dict, embeds: jax.Array, compute_dtype) -> jax.Array:
    return jnp.einsum(
        "bpd,dk->bpk", embeds.astype(compute_dtype), params["w"].astype(compute_dtype)
    )


def frontend_embed_specs(cfg, batch: int) -> jax.ShapeDtypeStruct:
    """ShapeDtypeStruct stand-in for the stub frontend output."""
    n = cfg.frontend_tokens
    return jax.ShapeDtypeStruct((batch, n, cfg.d_model), jnp.dtype(cfg.compute_dtype))


def synth_frontend_embeds(key, cfg, batch: int) -> jax.Array:
    n = cfg.frontend_tokens
    return jax.random.normal(
        key, (batch, n, cfg.d_model), jnp.dtype(cfg.compute_dtype)
    ) * 0.02
