"""Unified model API over every assigned architecture family.

    model = build_model(cfg)
    params = model.init(key)
    loss, metrics = model.loss_fn(params, batch)              # train_4k
    logits = model.prefill(params, batch)                     # prefill_32k
    logits, caches = model.decode_step(params, caches, batch) # decode_*

plus ``param_specs`` (tensor-parallel PartitionSpecs over the 'model' mesh
axis) and ``input_specs`` (ShapeDtypeStruct stand-ins for the dry-run).

Sharding deviations from the reference checkpoints (DESIGN.md SS8):
embeddings are untied and the input table is sharded on d_model (cheap row
gather) while the output head is sharded on the vocab (keeps logits
vocab-sharded through the chunked softmax-xent); vocab sizes are padded to a
multiple of 128 for even sharding.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape
from . import encdec as encdec_mod
from . import multimodal, transformer
from .layers import embedding_init, rmsnorm, softcap, truncated_normal_init

LONG_CONTEXT_WINDOW = 8192  # sliding-window variant used for long_500k


def padded_vocab(cfg: ArchConfig) -> int:
    return int(math.ceil(cfg.vocab_size / 128) * 128)


def long_context_variant(cfg: ArchConfig) -> ArchConfig:
    """The sliding-window variant that makes full-attention archs runnable at
    500k decode (DESIGN.md SS4)."""
    if cfg.family in ("ssm", "hybrid"):
        return cfg  # O(1)/O(window) state already
    if cfg.local_global:
        # gemma2: local layers keep their window; global layers get 32k
        return cfg.with_(sliding_window=cfg.sliding_window or 4096)
    return cfg.with_(sliding_window=LONG_CONTEXT_WINDOW)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable
    loss_fn: Callable          # (params, batch) -> (loss, metrics)
    prefill: Callable          # (params, batch) -> logits (B, S, V) last-chunk
    decode_step: Callable      # (params, caches, batch) -> (logits, caches)
    init_caches: Callable      # (batch, max_len) -> cache pytree
    cache_specs: Callable      # (batch, max_len) -> ShapeDtypeStruct pytree
    param_specs: Callable      # (model_axis_size) -> pytree of PartitionSpec
    input_specs: Callable      # (InputShape) -> batch of ShapeDtypeStruct


# ---------------------------------------------------------------------------
# loss (chunked softmax-xent, vocab-sharded logits)
# ---------------------------------------------------------------------------

def _xent_chunked(head_w, x, labels, cfg):
    """x: (B,S,d) hidden; labels: (B,S) int32, -1 = ignore.

    Computes softmax-xent in sequence chunks so the (B,c,V) logits buffer is
    bounded (DESIGN.md SS7)."""
    B, S, d = x.shape
    c = min(cfg.xent_chunk, S)
    if S % c != 0:
        c = S
    n = S // c
    cd = jnp.dtype(cfg.compute_dtype)

    xc = x.reshape(B, n, c, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, c).transpose(1, 0, 2)

    def body(acc, inp):
        xk, lk = inp
        logits = jnp.einsum("bcd,dv->bcv", xk.astype(cd), head_w.astype(cd))
        logits = logits.astype(jnp.float32)
        if cfg.logit_softcap > 0:
            logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.clip(lk, 0)[..., None], axis=-1
        )[..., 0]
        mask = (lk >= 0).astype(jnp.float32)
        loss_sum, count = acc
        return (loss_sum + jnp.sum((lse - ll) * mask), count + jnp.sum(mask)), ()

    (loss_sum, count), _ = lax.scan(body, (jnp.float32(0), jnp.float32(0)), (xc, lc))
    return loss_sum / jnp.maximum(count, 1.0)


def _logits(head_w, x, cfg):
    cd = jnp.dtype(cfg.compute_dtype)
    logits = jnp.einsum("bsd,dv->bsv", x.astype(cd), head_w.astype(cd))
    logits = logits.astype(jnp.float32)
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits


# ---------------------------------------------------------------------------
# decoder-only families (dense / moe / ssm / hybrid / vlm)
# ---------------------------------------------------------------------------

def _decoder_model(cfg: ArchConfig) -> Model:
    V = padded_vocab(cfg)
    is_vlm = cfg.family == "vlm"

    def init(key):
        dtype = jnp.dtype(cfg.param_dtype)
        k1, k2, k3, k4 = jax.random.split(key, 4)
        params = {
            "embed": embedding_init(k1, V, cfg.d_model, dtype),
            "stack": transformer.stack_init(k2, cfg),
            "head": {"w": truncated_normal_init(k3, (cfg.d_model, V), dtype)},
        }
        if is_vlm:
            params["projector"] = multimodal.projector_init(
                k4, cfg.d_model, cfg.d_model, dtype
            )
        return params

    def _embed_inputs(params, batch):
        cd = jnp.dtype(cfg.compute_dtype)
        x = params["embed"]["table"].astype(cd)[batch["tokens"]]
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cd)
        if is_vlm and "patch_embeds" in batch:
            pe = multimodal.project(params["projector"], batch["patch_embeds"], cd)
            x = jnp.concatenate([pe, x], axis=1)
        return x

    def loss_fn(params, batch):
        x = _embed_inputs(params, batch)
        x, aux = transformer.stack_train(params["stack"], x, cfg)
        labels = batch["labels"]
        if is_vlm and "patch_embeds" in batch:
            npatch = batch["patch_embeds"].shape[1]
            pad = jnp.full(labels.shape[:1] + (npatch,), -1, labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        loss = _xent_chunked(params["head"]["w"], x, labels, cfg)
        total = loss + aux
        return total, {"loss": loss, "aux_loss": aux}

    def prefill(params, batch):
        x = _embed_inputs(params, batch)
        x, _ = transformer.stack_train(params["stack"], x, cfg)
        # return logits of the last xent_chunk only (bounded output)
        c = min(cfg.xent_chunk, x.shape[1])
        return _logits(params["head"]["w"], x[:, -c:], cfg)

    def decode_step(params, caches, batch):
        cd = jnp.dtype(cfg.compute_dtype)
        tok, pos = batch["tokens"], batch["pos"]  # (B,1), (B,)
        x = params["embed"]["table"].astype(cd)[tok]
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cd)
        x, caches = transformer.stack_decode(params["stack"], x, caches, pos, cfg)
        return _logits(params["head"]["w"], x, cfg), caches

    def init_caches(batch, max_len):
        return transformer.init_caches(cfg, batch, max_len)

    def cache_specs(batch, max_len):
        return transformer.init_caches(cfg, batch, max_len, specs_only=True)

    def input_specs(shape: InputShape):
        return _decoder_input_specs(cfg, shape, is_vlm)

    return Model(
        cfg=cfg,
        init=init,
        loss_fn=loss_fn,
        prefill=prefill,
        decode_step=decode_step,
        init_caches=init_caches,
        cache_specs=cache_specs,
        param_specs=lambda model_axis=16, axis_name="model": build_param_specs(
            cfg, init, model_axis, axis_name
        ),
        input_specs=input_specs,
    )


def _decoder_input_specs(cfg, shape: InputShape, is_vlm):
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        text = S - cfg.frontend_tokens if is_vlm else S
        batch = {
            "tokens": jax.ShapeDtypeStruct((B, text), i32),
            "labels": jax.ShapeDtypeStruct((B, text), i32),
        }
        if is_vlm:
            batch["patch_embeds"] = multimodal.frontend_embed_specs(cfg, B)
        return batch
    if shape.kind == "prefill":
        text = S - cfg.frontend_tokens if is_vlm else S
        batch = {"tokens": jax.ShapeDtypeStruct((B, text), i32)}
        if is_vlm:
            batch["patch_embeds"] = multimodal.frontend_embed_specs(cfg, B)
        return batch
    # decode: one new token against a cache of S
    return {
        "tokens": jax.ShapeDtypeStruct((B, 1), i32),
        "pos": jax.ShapeDtypeStruct((B,), i32),
    }


# ---------------------------------------------------------------------------
# encoder-decoder (audio / seamless)
# ---------------------------------------------------------------------------

def _encdec_model(cfg: ArchConfig) -> Model:
    V = padded_vocab(cfg)

    def init(key):
        dtype = jnp.dtype(cfg.param_dtype)
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "embed": embedding_init(k1, V, cfg.d_model, dtype),
            "encdec": encdec_mod.encdec_init(k2, cfg),
            "head": {"w": truncated_normal_init(k3, (cfg.d_model, V), dtype)},
        }

    def _tok_embed(params, tok):
        cd = jnp.dtype(cfg.compute_dtype)
        x = params["embed"]["table"].astype(cd)[tok]
        return x * jnp.asarray(math.sqrt(cfg.d_model), cd)

    def loss_fn(params, batch):
        memory = encdec_mod.encode(params["encdec"], batch["frames"], cfg)
        x = _tok_embed(params, batch["tokens"])
        x = encdec_mod.decode_train(params["encdec"], x, memory, cfg)
        loss = _xent_chunked(params["head"]["w"], x, batch["labels"], cfg)
        return loss, {"loss": loss, "aux_loss": jnp.float32(0)}

    def prefill(params, batch):
        memory = encdec_mod.encode(params["encdec"], batch["frames"], cfg)
        x = _tok_embed(params, batch["tokens"])
        x = encdec_mod.decode_train(params["encdec"], x, memory, cfg)
        c = min(cfg.xent_chunk, x.shape[1])
        return _logits(params["head"]["w"], x[:, -c:], cfg)

    def decode_step(params, caches, batch):
        x = _tok_embed(params, batch["tokens"])
        win = cfg.sliding_window
        x, caches = encdec_mod.decode_step(
            params["encdec"], x, caches, batch["pos"], cfg, window=win
        )
        return _logits(params["head"]["w"], x, cfg), caches

    def init_caches(batch, max_len):
        return encdec_mod.dec_caches(
            None, cfg, batch, max_len, cfg.frontend_tokens,
            window=cfg.sliding_window,
        )

    def cache_specs(batch, max_len):
        return encdec_mod.dec_caches(
            None, cfg, batch, max_len, cfg.frontend_tokens,
            window=cfg.sliding_window, specs_only=True,
        )

    def input_specs(shape: InputShape):
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind in ("train", "prefill"):
            batch = {
                "frames": multimodal.frontend_embed_specs(cfg, B),
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
            }
            if shape.kind == "train":
                batch["labels"] = jax.ShapeDtypeStruct((B, S), i32)
            return batch
        return {
            "tokens": jax.ShapeDtypeStruct((B, 1), i32),
            "pos": jax.ShapeDtypeStruct((B,), i32),
        }

    return Model(
        cfg=cfg,
        init=init,
        loss_fn=loss_fn,
        prefill=prefill,
        decode_step=decode_step,
        init_caches=init_caches,
        cache_specs=cache_specs,
        param_specs=lambda model_axis=16, axis_name="model": build_param_specs(
            cfg, init, model_axis, axis_name
        ),
        input_specs=input_specs,
    )


def build_model(cfg: ArchConfig) -> Model:
    if cfg.is_encdec:
        return _encdec_model(cfg)
    return _decoder_model(cfg)


# ---------------------------------------------------------------------------
# tensor-parallel PartitionSpecs (name-based rules, divisibility-checked)
# ---------------------------------------------------------------------------

_SHARD_LAST = {
    "wq", "wk", "wv", "w_gate", "w_up", "wz", "wx", "up_x", "up_z",
    "conv_x", "head_w",
}
_SHARD_IN = {"wo", "w_down", "down", "out_proj"}


def _leaf_spec(path: tuple[str, ...], shape, model_axis: int, axis_name: str,
               axis_sizes: tuple[int, ...] = ()):
    name = path[-1]
    joined = "/".join(path)
    ndim = len(shape)

    def spec_with(axis_from_end: int):
        ax = ndim - axis_from_end
        if ax < 0 or shape[ax] % model_axis != 0:
            return P()
        s = [None] * ndim
        s[ax] = axis_name
        return P(*s)

    if "moe" in joined and name in ("w_gate", "w_up", "w_down") and ndim >= 3:
        e_ax = ndim - 3
        # multi-axis serve sharding: E over axis 0, ff over the rest
        # (E and ff are rarely divisible by the combined 256-way product)
        if (
            isinstance(axis_name, tuple)
            and len(axis_name) >= 2
            and len(axis_sizes) == len(axis_name)
        ):
            ff_ax = ndim - 1 if name in ("w_gate", "w_up") else ndim - 2
            rest = 1
            for sz in axis_sizes[1:]:
                rest *= sz
            if shape[e_ax] % axis_sizes[0] == 0 and shape[ff_ax] % rest == 0:
                s = [None] * ndim
                s[e_ax] = axis_name[0]
                s[ff_ax] = axis_name[1:] if len(axis_name) > 2 else axis_name[1]
                return P(*s)
        # expert-parallel on E when divisible, else shard the ff dim
        if shape[e_ax] % model_axis == 0:
            s = [None] * ndim
            s[e_ax] = axis_name
            return P(*s)
        return spec_with(1) if name in ("w_gate", "w_up") else spec_with(2)
    if name == "table":  # input embedding: shard d_model
        return spec_with(1)
    if path[-2:] == ("head", "w") or (len(path) >= 2 and path[-2] == "head"):
        return spec_with(1)  # vocab-sharded output head
    if name in _SHARD_LAST:
        return spec_with(1)
    if name in _SHARD_IN:
        return spec_with(2)
    return P()


def _path_names(path) -> tuple[str, ...]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return tuple(out)


def build_param_specs(cfg, init_fn, model_axis: int, axis_name: str,
                      axis_sizes: tuple[int, ...] = ()):
    shapes = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
    leaves, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    specs = [
        _leaf_spec(_path_names(p), l.shape, model_axis, axis_name, axis_sizes)
        for p, l in leaves
    ]
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# parameter counting (roofline MODEL_FLOPS)
# ---------------------------------------------------------------------------

def count_params(cfg: ArchConfig, active_only: bool = False) -> int:
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    total = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(shapes):
        n = int(np.prod(leaf.shape, dtype=np.int64))
        joined = "/".join(_path_names(path))
        if (
            active_only
            and cfg.is_moe
            and "moe" in joined
            and any(k in joined for k in ("w_gate", "w_up", "w_down"))
            and "shared" not in joined
        ):
            n = int(n * cfg.experts_per_token / cfg.num_experts)
        total += n
    return total


def model_flops(cfg: ArchConfig, tokens: int, kind: str = "train") -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active params."""
    n = count_params(cfg, active_only=True)
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens
