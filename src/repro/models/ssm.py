"""Mamba2 (SSD) block — chunked parallel scan for train/prefill, O(1)-state
recurrence for decode.  Used by zamba2 (hybrid) and available standalone.

State space (per head h, scalar decay a_t = exp(dt_t * A_h)):

    H_t = a_t * H_{t-1} + dt_t * x_t (x) B_t        H: (hd, ds)
    y_t = C_t . H_t + D * x_t

Train uses the standard SSD chunk decomposition: intra-chunk attention-like
term through the decay matrix L, inter-chunk through the carried state.

TP note: the reference fused ``in_proj`` emits a mixed [z|x|B|C|dt] layout
that cannot be sharded cleanly on the 'model' axis; we split it into
separate projections (wz/wx/wB/wC/wdt) and give each channel group its own
depthwise conv — mathematically identical (depthwise convs don't mix
channels) and cleanly shardable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .layers import rmsnorm, rmsnorm_init, truncated_normal_init


def ssm_dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    ds = cfg.ssm_state
    return d_in, H, ds


def ssm_init(key, cfg, dtype) -> dict:
    d = cfg.d_model
    d_in, H, ds = ssm_dims(cfg)
    k = cfg.ssm_conv
    ks = jax.random.split(key, 7)
    conv = lambda kk, ch: (jax.random.normal(kk, (k, ch)) * 0.1).astype(dtype)
    return {
        "wz": truncated_normal_init(ks[0], (d, d_in), dtype),
        "wx": truncated_normal_init(ks[1], (d, d_in), dtype),
        "wB": truncated_normal_init(ks[2], (d, ds), dtype),
        "wC": truncated_normal_init(ks[3], (d, ds), dtype),
        "wdt": truncated_normal_init(ks[4], (d, H), jnp.float32, scale=0.1),
        "conv_x": conv(ks[5], d_in),
        "conv_x_b": jnp.zeros((d_in,), dtype),
        "conv_B": conv(ks[6], ds),
        "conv_B_b": jnp.zeros((ds,), dtype),
        "conv_C": conv(jax.random.fold_in(key, 7), ds),
        "conv_C_b": jnp.zeros((ds,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": rmsnorm_init(d_in, dtype),
        "out_proj": truncated_normal_init(
            jax.random.fold_in(key, 8), (d_in, d), dtype
        ),
    }


def _causal_conv(x, w, b):
    """x: (B,S,ch) depthwise causal conv, width k."""
    k, ch = w.shape
    kernel = w.astype(x.dtype).reshape(k, 1, ch)
    y = lax.conv_general_dilated(
        x,
        kernel,
        window_strides=(1,),
        padding=[(k - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=ch,
    )
    return y + b.astype(x.dtype)


def _proj(params, name, x, cd):
    return jnp.einsum("bsd,dk->bsk", x.astype(cd), params[name].astype(cd))


def ssm_train(params, x, cfg, *, chunk: int = 128) -> jax.Array:
    B, S, d = x.shape
    d_in, H, ds = ssm_dims(cfg)
    hd = cfg.ssm_head_dim
    cd = jnp.dtype(cfg.compute_dtype)

    z = _proj(params, "wz", x, cd)
    xs = jax.nn.silu(
        _causal_conv(_proj(params, "wx", x, cd), params["conv_x"], params["conv_x_b"])
    )
    Bv = jax.nn.silu(
        _causal_conv(_proj(params, "wB", x, cd), params["conv_B"], params["conv_B_b"])
    ).astype(jnp.float32)
    Cv = jax.nn.silu(
        _causal_conv(_proj(params, "wC", x, cd), params["conv_C"], params["conv_C_b"])
    ).astype(jnp.float32)
    dt = _proj(params, "wdt", x, jnp.float32)

    xs = xs.reshape(B, S, H, hd)
    dt = jax.nn.softplus(dt + params["dt_bias"])       # (B,S,H)
    A = -jnp.exp(params["A_log"])                      # (H,)
    dA = dt * A[None, None, :]                         # log-decay

    c = min(chunk, S)
    if S % c != 0:
        c = S
    n = S // c

    def chop(t):
        return t.reshape((B, n, c) + t.shape[2:]).transpose(
            (1, 0, 2) + tuple(range(3, t.ndim + 1))
        )

    xs_c, B_c, C_c, dt_c, dA_c = map(
        chop, (xs.astype(jnp.float32), Bv, Cv, dt, dA)
    )

    def body(h, inp):
        xsk, Bk, Ck, dtk, dAk = inp                 # (B,c,...)
        cum = jnp.cumsum(dAk, axis=1)               # (B,c,H)
        # intra-chunk: L_ij = exp(cum_i - cum_j), i >= j
        diff = cum[:, :, None, :] - cum[:, None, :, :]       # (B,c,c,H)
        mask = jnp.tril(jnp.ones((c, c), bool))
        L = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)
        G = jnp.einsum("bin,bjn->bij", Ck, Bk)               # (B,c,c)
        dtx = dtk[..., None] * xsk                           # (B,c,H,hd)
        y_intra = jnp.einsum("bij,bijh,bjhp->bihp", G, L, dtx)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bin,bhpn->bihp", Ck, h) * jnp.exp(cum)[..., None]
        # state update
        tail = jnp.exp(cum[:, -1:, :] - cum)                 # (B,c,H)
        h_new = (
            jnp.exp(cum[:, -1])[:, :, None, None] * h
            + jnp.einsum("bjhp,bjn,bjh->bhpn", dtx, Bk, tail)
        )
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((B, H, hd, ds), jnp.float32)
    _, ys = lax.scan(body, h0, (xs_c, B_c, C_c, dt_c, dA_c))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)
    y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, S, d_in).astype(cd)

    y = y * jax.nn.silu(z)
    y = rmsnorm(params["norm"], y, cfg.norm_eps)
    return jnp.einsum("bsk,kd->bsd", y, params["out_proj"].astype(cd))


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def ssm_state_init(cfg, batch: int, specs_only: bool = False) -> dict:
    d_in, H, ds = ssm_dims(cfg)
    k = cfg.ssm_conv
    mk = (
        (lambda s, d: jax.ShapeDtypeStruct(s, d))
        if specs_only
        else (lambda s, d: jnp.zeros(s, d))
    )
    return {
        "h": mk((batch, H, cfg.ssm_head_dim, ds), jnp.float32),
        "conv_x": mk((batch, k - 1, d_in), jnp.float32),
        "conv_B": mk((batch, k - 1, ds), jnp.float32),
        "conv_C": mk((batch, k - 1, ds), jnp.float32),
    }


def ssm_state_specs(cfg, batch: int) -> dict:
    return ssm_state_init(cfg, batch, specs_only=True)


def _conv_step(state_buf, new, w, b):
    """state_buf: (B,k-1,ch); new: (B,ch) -> (out (B,ch), new_buf)."""
    window = jnp.concatenate([state_buf, new[:, None, :]], axis=1)  # (B,k,ch)
    out = jnp.einsum("bkc,kc->bc", window, w.astype(jnp.float32)) + b.astype(
        jnp.float32
    )
    return out, window[:, 1:, :]


def ssm_decode(params, x, state, cfg):
    """x: (B,1,d) -> (y (B,1,d), new_state)."""
    B = x.shape[0]
    d_in, H, ds = ssm_dims(cfg)
    hd = cfg.ssm_head_dim
    cd = jnp.dtype(cfg.compute_dtype)

    z = _proj(params, "wz", x, cd)
    x_new = _proj(params, "wx", x, jnp.float32)[:, 0]
    B_new = _proj(params, "wB", x, jnp.float32)[:, 0]
    C_new = _proj(params, "wC", x, jnp.float32)[:, 0]
    dt = _proj(params, "wdt", x, jnp.float32)[:, 0]

    xo, conv_x = _conv_step(state["conv_x"], x_new, params["conv_x"], params["conv_x_b"])
    Bo, conv_B = _conv_step(state["conv_B"], B_new, params["conv_B"], params["conv_B_b"])
    Co, conv_C = _conv_step(state["conv_C"], C_new, params["conv_C"], params["conv_C_b"])
    xs = jax.nn.silu(xo).reshape(B, H, hd)
    Bv = jax.nn.silu(Bo)
    Cv = jax.nn.silu(Co)

    dtv = jax.nn.softplus(dt + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dtv * A[None, :])                             # (B,H)

    h_new = a[:, :, None, None] * state["h"] + jnp.einsum(
        "bh,bhp,bn->bhpn", dtv, xs, Bv
    )
    y = jnp.einsum("bn,bhpn->bhp", Cv, h_new)
    y = y + params["D"][None, :, None] * xs
    y = y.reshape(B, 1, d_in).astype(cd)
    y = y * jax.nn.silu(z)
    y = rmsnorm(params["norm"], y, cfg.norm_eps)
    y = jnp.einsum("bsk,kd->bsd", y, params["out_proj"].astype(cd))
    return y, {"h": h_new, "conv_x": conv_x, "conv_B": conv_B, "conv_C": conv_C}
