"""Mixture-of-Experts FFN: top-k router + shared experts (DeepSeekMoE-style
fine-grained experts; also covers Grok-1's 8e top-2).

Dispatch is capacity-based gather/scatter (TPU-friendly: static shapes,
expert-parallel shardable on the expert axis):

    tokens -> router top-k -> position-in-expert via cumsum ->
    scatter into (E, C, d) buffers -> batched expert matmuls ->
    gather back weighted by router probs.

Overflow beyond capacity C = ceil(N*k/E * capacity_factor) is dropped
(standard Switch/GShard semantics); the aux load-balance loss keeps the
router near-uniform so drops stay rare.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import mlp, mlp_init, truncated_normal_init


def moe_init(key, cfg, dtype) -> dict:
    E, d, ff = cfg.num_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": truncated_normal_init(ks[0], (d, E), jnp.float32, scale=0.1),
        "w_gate": truncated_normal_init(ks[1], (E, d, ff), dtype),
        "w_up": truncated_normal_init(ks[2], (E, d, ff), dtype),
        "w_down": truncated_normal_init(ks[3], (E, ff, d), dtype),
    }
    if cfg.num_shared_experts > 0:
        p["shared"] = mlp_init(ks[4], d, ff * cfg.num_shared_experts, dtype)
    return p


def moe_apply(params, x, cfg):
    """x: (B, S, d) -> (y, aux_loss)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    cd = jnp.dtype(cfg.compute_dtype)
    N = B * S
    xt = x.reshape(N, d)

    logits = jnp.einsum(
        "nd,de->ne", xt.astype(jnp.float32), params["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)  # (N, E)
    top_p, top_e = jax.lax.top_k(probs, k)   # (N, k)
    top_p = top_p / (jnp.sum(top_p, axis=-1, keepdims=True) + 1e-9)

    # load-balance aux loss (Switch-style)
    me = jnp.mean(probs, axis=0)                                  # (E,)
    ce = jnp.mean(
        jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), axis=0
    )
    aux = cfg.aux_loss_coef * E * jnp.sum(me * ce)

    C = int(math.ceil(N * k / E * cfg.moe_capacity_factor))
    eid = top_e.reshape(-1)                                        # (N*k,)
    w = top_p.reshape(-1).astype(cd)
    # position of each assignment within its expert
    onehot = jax.nn.one_hot(eid, E, dtype=jnp.int32)               # (N*k, E)
    pos = (jnp.cumsum(onehot, axis=0) - 1)[jnp.arange(N * k), eid]
    keep = pos < C
    slot = jnp.where(keep, eid * C + pos, E * C)                   # OOB -> drop

    tok = jnp.repeat(jnp.arange(N), k)
    buf = jnp.zeros((E * C, d), cd).at[slot].set(
        xt.astype(cd)[tok], mode="drop"
    )
    buf = buf.reshape(E, C, d)

    # batched expert MLP (E-parallel)
    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(cd))
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(cd))
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(cd))
    out_buf = out_buf.reshape(E * C, d)

    gathered = jnp.take(out_buf, jnp.where(keep, slot, E * C - 1), axis=0)
    gathered = gathered * keep[:, None].astype(cd) * w[:, None]
    y = jnp.zeros((N, d), cd).at[tok].add(gathered)

    if cfg.num_shared_experts > 0:
        y = y + mlp(params["shared"], xt, cfg.mlp_act, cd)
    return y.reshape(B, S, d), aux


def moe_flops_per_token(cfg) -> int:
    """Active FLOPs per token in the MoE FFN (for MODEL_FLOPS)."""
    per_expert = 6 * cfg.d_model * cfg.d_ff  # 3 matmuls, fwd only (x2 for mults/adds)
    routed = cfg.experts_per_token * per_expert
    shared = cfg.num_shared_experts * per_expert
    return routed + shared + 2 * cfg.d_model * cfg.num_experts
