"""Decoder-only stack: superblock scan over heterogeneous block patterns.

A *superblock* is the repeating unit of the architecture (1 block for plain
dense/MoE; a (local, global) pair for gemma2; (k x mamba) + shared-attn for
zamba2; (k x mLSTM) + sLSTM for xlstm).  Parameters are stacked over
superblocks and consumed by ``lax.scan`` so HLO size is O(superblock) even
for 88-layer models (DESIGN.md SS7).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from . import xlstm as xlstm_mod
from .layers import mlp, mlp_init, rmsnorm, rmsnorm_init


# ---------------------------------------------------------------------------
# superblock structure
# ---------------------------------------------------------------------------

def superblock_kinds(cfg) -> list[tuple[str, int]]:
    """[(kind, window)] per block inside one superblock."""
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        if cfg.local_global:
            local_w = cfg.sliding_window or 4096
            return [("attn", local_w), ("attn", 0)]
        return [("attn", cfg.sliding_window)]
    if fam == "ssm":  # xlstm
        if cfg.slstm_every and cfg.slstm_every > 1:
            return [("mlstm", 0)] * (cfg.slstm_every - 1) + [("slstm", 0)]
        return [("mlstm", 0)]
    if fam == "hybrid":  # zamba2: k mamba blocks + one shared attn block
        k = cfg.attn_every or 6
        return [("mamba", 0)] * k
    raise ValueError(fam)


def num_superblocks(cfg) -> int:
    kinds = superblock_kinds(cfg)
    n, r = divmod(cfg.num_layers, len(kinds))
    if r:
        raise ValueError(
            f"{cfg.name}: num_layers={cfg.num_layers} not divisible by "
            f"superblock size {len(kinds)}"
        )
    return n


def has_shared_block(cfg) -> bool:
    return cfg.family == "hybrid" and (cfg.attn_every or 0) > 0


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _attn_block_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn.attn_init(k1, cfg, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
    }
    if cfg.is_moe:
        p["moe"] = moe_mod.moe_init(k2, cfg, dtype)
    else:
        p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def _block_init(key, cfg, kind, dtype):
    if kind == "attn":
        return _attn_block_init(key, cfg, dtype)
    if kind == "mamba":
        return {
            "ln": rmsnorm_init(cfg.d_model, dtype),
            "ssm": ssm_mod.ssm_init(key, cfg, dtype),
        }
    if kind == "mlstm":
        return xlstm_mod.mlstm_init(key, cfg, dtype)
    if kind == "slstm":
        return xlstm_mod.slstm_init(key, cfg, dtype)
    raise ValueError(kind)


def superblock_init(key, cfg, dtype):
    kinds = superblock_kinds(cfg)
    keys = jax.random.split(key, len(kinds))
    return {
        f"b{j}": _block_init(k, cfg, kind, dtype)
        for j, (k, (kind, _)) in enumerate(zip(keys, kinds))
    }


def _shared_sub_cfg(cfg):
    d_ff = cfg.d_ff if cfg.d_ff > 0 else 4 * cfg.d_model
    return cfg.with_(num_experts=0, d_ff=d_ff)


def shared_block_init(key, cfg, dtype):
    """zamba2's weight-shared full transformer block (attn + MLP).

    Adaptation note: the reference model concatenates the original embedding
    into the shared block input; we use a standard residual block with shared
    weights (same compute/communication shape, simpler composition)."""
    sub = _shared_sub_cfg(cfg)
    return _attn_block_init(key, sub, dtype), sub


def stack_init(key, cfg) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    n = num_superblocks(cfg)
    k_blocks, k_shared, k_final = jax.random.split(key, 3)
    keys = jax.random.split(k_blocks, n)
    blocks = jax.vmap(lambda k: superblock_init(k, cfg, dtype))(keys)
    params = {"blocks": blocks, "final_norm": rmsnorm_init(cfg.d_model, dtype)}
    if has_shared_block(cfg):
        shared, _ = shared_block_init(k_shared, cfg, dtype)
        params["shared"] = shared
    return params


# ---------------------------------------------------------------------------
# train / prefill apply
# ---------------------------------------------------------------------------

def _attn_block_train(p, x, cfg, window):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    x = x + attn.attn_train(p["attn"], h, cfg, window=window)
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.is_moe:
        y, aux = moe_mod.moe_apply(p["moe"], h, cfg)
        return x + y, aux
    return x + mlp(p["mlp"], h, cfg.mlp_act, jnp.dtype(cfg.compute_dtype)), 0.0


def _block_train(p, x, cfg, kind, window):
    if kind == "attn":
        return _attn_block_train(p, x, cfg, window)
    if kind == "mamba":
        h = rmsnorm(p["ln"], x, cfg.norm_eps)
        return x + ssm_mod.ssm_train(p["ssm"], h, cfg), 0.0
    if kind == "mlstm":
        return x + xlstm_mod.mlstm_train(p, x, cfg), 0.0
    if kind == "slstm":
        return x + xlstm_mod.slstm_train(p, x, cfg), 0.0
    raise ValueError(kind)


def stack_train(params, x, cfg):
    """x: (B, S, d) -> (y, aux_loss)."""
    kinds = superblock_kinds(cfg)
    shared = params.get("shared")
    sub_cfg = _shared_sub_cfg(cfg) if shared is not None else None

    def body(carry, block_params):
        x, aux = carry
        for j, (kind, window) in enumerate(kinds):
            x, a = _block_train(block_params[f"b{j}"], x, cfg, kind, window)
            aux = aux + a
        if shared is not None:
            x, a = _attn_block_train(shared, x, sub_cfg, 0)
            aux = aux + a
        return (x, aux), ()

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = lax.scan(body, (x, jnp.float32(0.0)), params["blocks"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux


# ---------------------------------------------------------------------------
# decode apply (one token, stacked caches scanned alongside params)
# ---------------------------------------------------------------------------

def _cache_one(cfg, kind, window, batch, max_len, specs_only):
    if kind == "attn":
        fn = attn.cache_specs if specs_only else attn.init_cache
        return fn(cfg, batch, max_len, window=window)
    if kind == "mamba":
        fn = ssm_mod.ssm_state_specs if specs_only else ssm_mod.ssm_state_init
        return fn(cfg, batch)
    if kind == "mlstm":
        st = xlstm_mod.mlstm_state_init(cfg, batch)
    elif kind == "slstm":
        st = xlstm_mod.slstm_state_init(cfg, batch)
    else:
        raise ValueError(kind)
    if specs_only:
        return jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), st)
    return st


def init_caches(cfg, batch: int, max_len: int, specs_only: bool = False):
    """Stacked-over-superblocks cache pytree (+ shared-block cache)."""
    n = num_superblocks(cfg)
    kinds = superblock_kinds(cfg)

    def stack_leaf(leaf):
        if specs_only:
            return jax.ShapeDtypeStruct((n,) + leaf.shape, leaf.dtype)
        return jnp.zeros((n,) + leaf.shape, leaf.dtype)

    caches = {
        f"b{j}": jax.tree.map(
            stack_leaf, _cache_one(cfg, kind, window, batch, max_len, specs_only)
        )
        for j, (kind, window) in enumerate(kinds)
    }
    out = {"blocks": caches}
    if has_shared_block(cfg):
        # weight-shared block, but one KV cache per application (per superblock)
        out["shared"] = jax.tree.map(
            stack_leaf, _cache_one(cfg, "attn", 0, batch, max_len, specs_only)
        )
    return out


def _block_decode(p, x, cache, pos, cfg, kind, window):
    if kind == "attn":
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        y, cache = attn.attn_decode(p["attn"], h, cache, pos, cfg, window=window)
        x = x + y
        h = rmsnorm(p["ln2"], x, cfg.norm_eps)
        if cfg.is_moe:
            y, _ = moe_mod.moe_apply(p["moe"], h, cfg)
        else:
            y = mlp(p["mlp"], h, cfg.mlp_act, jnp.dtype(cfg.compute_dtype))
        return x + y, cache
    if kind == "mamba":
        h = rmsnorm(p["ln"], x, cfg.norm_eps)
        y, cache = ssm_mod.ssm_decode(p["ssm"], h, cache, cfg)
        return x + y, cache
    if kind == "mlstm":
        y, cache = xlstm_mod.mlstm_decode(p, x, cache, cfg)
        return x + y, cache
    if kind == "slstm":
        y, cache = xlstm_mod.slstm_decode(p, x, cache, cfg)
        return x + y, cache
    raise ValueError(kind)


def stack_decode(params, x, caches, pos, cfg):
    """x: (B, 1, d); pos: (B,).  Returns (y, new_caches)."""
    kinds = superblock_kinds(cfg)
    shared = params.get("shared")
    sub_cfg = _shared_sub_cfg(cfg) if shared is not None else None

    def body(x, scanned):
        if shared is not None:
            block_params, block_caches, shared_cache = scanned
        else:
            block_params, block_caches = scanned
        new_caches = {}
        for j, (kind, window) in enumerate(kinds):
            x, c = _block_decode(
                block_params[f"b{j}"], x, block_caches[f"b{j}"], pos, cfg, kind, window
            )
            new_caches[f"b{j}"] = c
        if shared is not None:
            h = rmsnorm(shared["ln1"], x, cfg.norm_eps)
            y, sc = attn.attn_decode(shared["attn"], h, shared_cache, pos, sub_cfg)
            x = x + y
            h = rmsnorm(shared["ln2"], x, cfg.norm_eps)
            x = x + mlp(shared["mlp"], h, sub_cfg.mlp_act, jnp.dtype(cfg.compute_dtype))
            return x, (new_caches, sc)
        return x, new_caches

    if shared is not None:
        x, (new_block_caches, new_shared) = lax.scan(
            body, x, (params["blocks"], caches["blocks"], caches["shared"])
        )
        out_caches = {"blocks": new_block_caches, "shared": new_shared}
    else:
        x, new_block_caches = lax.scan(body, x, (params["blocks"], caches["blocks"]))
        out_caches = {"blocks": new_block_caches}
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, out_caches
