"""Compressor interface + registry, built on the plan/execute split.

Every GC scheme from the paper's Table II is a ``Compressor`` with two
halves (DESIGN.md SS3):

    schedule = comp.plan_phase(plan, phase)          # static, no tracing
    synced, new_state, stats = comp.execute(
        schedule, grads, state, step=step, axis_names=('data',))

``plan_phase`` emits a :class:`~repro.core.schedule.CommSchedule` — the
exact per-phase communication contract (selected buckets, collective op,
wire dtype, bytes per worker) — computable before any XLA graph exists.
``execute`` is a pure function of the schedule that runs inside
``shard_map``.  The legacy one-call ``sync`` remains as a thin wrapper.

``axis_names`` are the *manual* mesh axes of the enclosing ``shard_map`` over
which gradients are reduced (the data-parallel axes).  With
``axis_names=()`` the compressor runs in single-worker mode (unit tests,
compression-overhead benchmarks) — all collectives become identities.

``stats.bytes_per_worker`` always equals ``schedule.bytes_per_worker`` — the
statically-known number of bytes each worker injects into the interconnect
per call; tests cross-check it against the collective bytes parsed from
compiled HLO.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from .bucketing import BucketPlan


@dataclasses.dataclass(frozen=True)
class SyncStats:
    bytes_per_worker: int
    dense_bytes: int

    @property
    def volume_ratio(self) -> float:
        return self.dense_bytes / max(self.bytes_per_worker, 1)


def _promote_bf16() -> bool:
    """XLA's CPU AllReducePromotion pass CHECK-fails on bf16 all-reduce
    (hlo_instruction.cc 'Invalid binary instruction opcode copy').  On the
    CPU dry-run backend we promote bf16 collectives to f32; on TPU (the
    target) bf16 goes on the wire directly.  Collective-byte accounting in
    the dry-run notes the 2x inflation for bf16-param archs."""
    mode = os.environ.get("REPRO_PSUM_PROMOTE_BF16", "auto")
    if mode == "never":
        return False
    if mode == "always":
        return True
    return jax.default_backend() == "cpu"


def _reduce(op, x: jax.Array, axis_names: Sequence[str]) -> jax.Array:
    if not axis_names:
        return x
    if x.dtype == jnp.bfloat16 and _promote_bf16():
        return op(x.astype(jnp.float32), tuple(axis_names)).astype(jnp.bfloat16)
    return op(x, tuple(axis_names))


def pmean(x: jax.Array, axis_names: Sequence[str]) -> jax.Array:
    return _reduce(lax.pmean, x, axis_names)


def psum(x: jax.Array, axis_names: Sequence[str]) -> jax.Array:
    return _reduce(lax.psum, x, axis_names)


def world_size(axis_names: Sequence[str]) -> int | jax.Array:
    if not axis_names:
        return 1
    return lax.psum(1, tuple(axis_names))


def axis_size(axis_name) -> int:
    """Static size of a manual mesh axis inside shard_map — via
    ``lax.axis_size`` where available, ``jax.core.axis_frame`` on older
    releases."""
    if hasattr(lax, "axis_size"):
        return int(lax.axis_size(axis_name))
    import jax.core as _jc

    return int(_jc.axis_frame(axis_name))


def all_gather(x: jax.Array, axis_names: Sequence[str]) -> jax.Array:
    """Gather along a new leading axis; identity (adds axis of 1) if local."""
    if not axis_names:
        return x[None]
    g = x
    for ax in reversed(tuple(axis_names)):
        g = lax.all_gather(g, ax)
        g = g.reshape((-1,) + x.shape)
    return g


def flat_axis_index(axis_names: Sequence[str]):
    """Row-major flat worker index over (possibly multiple) named axes —
    the shard-ownership index of the sharded sync path (worker ``w`` owns
    shard ``w`` of every bucket slot)."""
    idx = lax.axis_index(axis_names[0])
    for ax in axis_names[1:]:
        idx = idx * axis_size(ax) + lax.axis_index(ax)
    return idx


def reduce_scatter(
    x: jax.Array, axis_names: Sequence[str], *, mean: bool = True
) -> jax.Array:
    """Reduce-scatter a flat vector over the DP axes: worker ``w`` receives
    the reduced shard ``x[w*S:(w+1)*S]`` (``S = len(x) // W``; the caller
    pads to a W-divisible length — ``arena.build_layout(align=W)``).

    The mean divides the summed shard by ``W`` *after* the collective —
    elementwise the exact op order of ``pmean`` (sum, then divide), so the
    owned shard is bitwise what the all-reduce path computes.  The same
    ``REPRO_PSUM_PROMOTE_BF16`` guard applies: XLA's CPU backend mishandles
    narrow-dtype reduction computations, so bf16 operands are promoted to
    f32 around the collective on the dry-run backend (TPU keeps bf16 on
    the wire).  With no axes this is the identity (single-worker mode).
    """
    if not axis_names:
        return x
    axes = tuple(axis_names)

    W = 1
    for a in axes:
        W *= axis_size(a)

    def op(v, names):
        s = lax.psum_scatter(v, names, scatter_dimension=0, tiled=True)
        if mean:
            s = s / jnp.asarray(W, v.dtype)
        return s

    if x.dtype == jnp.bfloat16 and _promote_bf16():
        return op(x.astype(jnp.float32), axes).astype(jnp.bfloat16)
    return op(x, axes)


def pod_shard_exchange(x: jax.Array, pod_axes: Sequence[str]) -> jax.Array:
    """Cross-pod mean of an owned shard — the DCN half of the two-level
    hierarchical sync (DESIGN.md §17).  ``x`` is the 1/W_intra shard this
    worker owns after the intra-pod reduce-scatter (or the exact slice of
    an intra-pod-replicated bucket); the exchange averages it with the
    same shard held by the peer workers in every other pod.

    Routed through :func:`pmean` so the ``REPRO_PSUM_PROMOTE_BF16`` guard
    applies exactly as it does to the intra-pod reduce-scatter: bf16
    shards are promoted to f32 around the collective on the CPU dry-run
    backend (XLA's CPU AllReducePromotion pass CHECK-fails on bf16
    all-reduce) and stay bf16 on the TPU wire.  Identity with no axes.
    """
    if not pod_axes:
        return x
    return pmean(x, tuple(pod_axes))


def all_gather_tiled(x: jax.Array, axis_names: Sequence[str]) -> jax.Array:
    """Concatenating all-gather of per-worker shards along axis 0 — the
    inverse of :func:`reduce_scatter`'s scatter (worker order matches
    :func:`flat_axis_index`).  Pure data movement, so no dtype promotion is
    needed (the bf16 CPU guard exists for *reduction* computations only).
    Identity with no axes."""
    if not axis_names:
        return x
    g = x
    for ax in reversed(tuple(axis_names)):
        g = lax.all_gather(g, ax, tiled=True)
    return g


class Compressor:
    """Base class.  Subclasses set ``name`` and implement the plan/execute
    pair (``plan_phase`` + ``execute``); ``sync`` composes the two."""

    name: str = "base"

    def __init__(self, **kw):
        self.options = dict(kw)

    # ---- lifecycle -------------------------------------------------------
    def init_state(self, params_like: Any, plan: BucketPlan) -> Any:
        return ()

    def num_phases(self, interval: int) -> int:
        """How many step-specialised executables the trainer must build."""
        return 1

    # ---- plan: static, computable without tracing -------------------------
    def plan_phase(self, plan: BucketPlan, phase: int, *, world: int = 1):
        """Static communication plan for one phase -> ``CommSchedule``."""
        raise NotImplementedError

    # ---- execute: pure, runs inside shard_map -----------------------------
    def execute(
        self,
        schedule,
        grads: Any,
        state: Any,
        *,
        step=0,
        axis_names: Sequence[str] = (),
    ) -> tuple[Any, Any, SyncStats]:
        raise NotImplementedError

    # ---- legacy one-call wrapper ------------------------------------------
    def sync(
        self,
        grads: Any,
        state: Any,
        *,
        plan: BucketPlan,
        phase: int,
        step,
        axis_names: Sequence[str] = (),
    ) -> tuple[Any, Any, SyncStats]:
        # inside a shard_map trace the axis sizes are static, so the plan
        # can be built for the real world size (world-dependent planners
        # like oktopk report wrong bytes otherwise)
        world = 1
        for a in axis_names:
            try:
                world *= axis_size(a)
            except Exception:  # not inside a mapping over `a`
                world = 1
                break
        schedule = self.plan_phase(plan, phase, world=world)
        return self.execute(
            schedule, grads, state, step=step, axis_names=axis_names
        )

    def __repr__(self):
        opts = ", ".join(f"{k}={v}" for k, v in self.options.items())
        return f"{type(self).__name__}({opts})"


_REGISTRY: dict[str, Callable[..., Compressor]] = {}


def register(name: str):
    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def get_compressor(name: str, **kw) -> Compressor:
    if name not in _REGISTRY:
        raise KeyError(f"unknown compressor {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](**kw)


def available() -> list[str]:
    return sorted(_REGISTRY)


def dense_bytes(plan: BucketPlan) -> int:
    return sum(b.nbytes for b in plan.buckets)
