"""Static communication schedules: the *plan* half of the plan/execute split.

The paper's coarse filter works because bucket selection is a **static**
function of ``(phase, interval)`` — nothing about a step's communication
depends on gradient values.  ``CommSchedule`` makes that property a
first-class artifact: for one compressor phase it records which buckets are
communicated, with which collective op, at which wire dtype, and exactly how
many bytes each worker injects — all computable **without tracing** a single
XLA graph (DESIGN.md SS3).

Consumers:

* ``train.trainer`` builds one schedule per phase and passes it to the pure
  ``Compressor.execute`` that runs inside ``shard_map``;
* ``core.ccr`` / ``core.perfmodel`` read ``bytes_per_worker`` /
  ``wire_bytes`` for CCR estimation and overlap simulation;
* ``launch.dryrun`` cross-checks the planned bytes against the collective
  bytes parsed from compiled HLO — the plan is the spec, the HLO is the
  proof.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

from .bucketing import BucketPlan, Segment


@dataclasses.dataclass(frozen=True)
class CollectiveCall:
    """One planned collective: what a single bucket (or leaf) puts on the
    wire during this phase.

    ``payload_bytes`` is the per-worker value traffic; ``index_bytes`` is
    the sideband (sparse indices, block scales, routing masks).  Both count
    bytes *injected by one worker once* — ring/gather wire amplification is
    applied separately by :meth:`wire_bytes` so the raw numbers stay
    comparable with single-participant HLO.
    """

    target: str                # "bucket:3" | "leaf:2" | "pod-bucket:1"
    op: str                    # "all_reduce" | "reduce_scatter" | "all_gather" | "all_to_all"
    wire_dtype: str            # numpy dtype name of the wire payload
    payload_bytes: int
    index_bytes: int = 0
    # a deferred call is planned in this phase but issued at the HEAD of the
    # next step so it overlaps the forward pass (sharded sync's param
    # all-gather, DESIGN.md §13) — it never contributes to the phase's
    # *exposed* communication behind the backward pass.
    deferred: bool = False
    # which physical link this call crosses in a two-level hierarchy
    # (DESIGN.md §17): "ici" for intra-pod collectives on the fast mesh
    # axis, "dcn" for the cross-pod exchange.  Flat (single-pod) plans
    # leave everything on "ici".
    link: str = "ici"
    # participant count of THIS call's collective group when it differs
    # from the schedule-level world (hierarchical plans: the intra-pod RS
    # runs over W_intra workers while the cross-pod exchange runs over
    # n_pods).  0 means "use the world the caller passes to wire_bytes".
    world: int = 0

    @property
    def bytes_per_worker(self) -> int:
        return self.payload_bytes + self.index_bytes

    def wire_bytes(self, world: int) -> float:
        """Bytes one worker actually moves for this call under the standard
        ring algorithms (paper SS II): all-reduce moves ``2(W-1)/W`` of the
        buffer, a reduce-scatter moves ``(W-1)/W`` of the buffer it feeds
        in, an all-gather re-sends the local shard ``W-1`` times, an
        all-to-all keeps ``1/W`` local.

        Note the conventions per op: ``payload_bytes`` of a reduce-scatter
        is the FULL per-worker input buffer (of which the worker keeps
        ``1/W``), while an all-gather's is the LOCAL shard the worker
        contributes — matching the per-worker *injected* bytes the HLO
        parser reproduces (``launch.hlo_analysis``).  A call with its own
        ``world`` (hierarchical plans) ignores the argument — its group
        size is a property of the plan, not of the schedule."""
        if self.world:
            world = self.world
        if world <= 1:
            return 0.0
        b = float(self.bytes_per_worker)
        if self.op == "all_reduce":
            return 2.0 * (world - 1) / world * b
        if self.op == "reduce_scatter":
            return (world - 1) / world * b
        if self.op == "all_gather":
            return (world - 1) * b
        if self.op == "all_to_all":
            return (world - 1) / world * b
        return b


@dataclasses.dataclass(frozen=True)
class CommSchedule:
    """Per-phase static communication plan of one compressor.

    ``selected`` are bucket indices (``granularity == "bucket"``) or leaf
    indices (``granularity == "leaf"``), aligned 1:1 with ``calls``.  The
    originating :class:`BucketPlan` rides along so the pure ``execute`` can
    slice segments without re-deriving anything.
    """

    compressor: str
    phase: int
    num_phases: int
    granularity: str                     # "bucket" | "leaf"
    selected: tuple[int, ...]
    calls: tuple[CollectiveCall, ...]
    dense_bytes: int
    world: int = 1
    plan: BucketPlan | None = None
    # per-call readiness rank (overlap engine): position of each call in the
    # backward-pass issue order derived from ``bucketing.ReadyOrder`` — rank
    # 0 is the first collective whose operand gradient lands.  Empty for
    # planners that predate the overlap engine (treated as plan order).
    ready_ranks: tuple[int, ...] = ()
    # collective decomposition: "allreduce" (one all-reduce per bucket) or
    # "sharded" (reduce-scatter the gradient, optimizer on the local shard,
    # deferred all-gather of updated params at the next step's head —
    # DESIGN.md §13).
    sync: str = "allreduce"
    # the deferred half of sharded sync: the param all-gathers issued at
    # the HEAD of the next step, where they overlap the forward pass
    # instead of extending this phase's sync tail.  They cover EVERY plan
    # bucket, not just this phase's selected ones: any bucket that was ever
    # selected keeps moving under the optimizer's moment decay, and only
    # the shard owner holds its authoritative values.  Kept separate from
    # ``calls`` so ``bytes_per_worker`` remains exactly what ``execute``'s
    # compiled HLO shows (the RS half); the AG half cross-checks against
    # the head/flush program.
    deferred_calls: tuple[CollectiveCall, ...] = ()

    # ---- byte accounting --------------------------------------------------
    @property
    def bytes_per_worker(self) -> int:
        """Exact bytes each worker injects inside ``execute`` this phase —
        the number the HLO collective parser must reproduce
        (tests/test_hlo_and_specs.py).  Excludes ``deferred_calls`` (issued
        by the trainer at the next step's head)."""
        return sum(c.bytes_per_worker for c in self.calls)

    @property
    def exposed_bytes_per_worker(self) -> int:
        """Bytes whose collective must complete before the optimizer can
        step — the RS half under ``sync="sharded"``, everything under
        ``"allreduce"``."""
        return self.bytes_per_worker

    @property
    def deferred_bytes_per_worker(self) -> int:
        """Bytes of the deferred param all-gathers (sharded sync) — they
        ride the next step's forward pass instead of this phase's tail."""
        return sum(c.bytes_per_worker for c in self.deferred_calls)

    @property
    def total_bytes_per_worker(self) -> int:
        return self.bytes_per_worker + self.deferred_bytes_per_worker

    def exposed_wire_bytes(self, world: int | None = None) -> float:
        """Ring-amplified wire bytes of the exposed calls only — the
        number the 0.6x sharded-vs-allreduce acceptance gate compares
        (tests/test_sharded_sync.py)."""
        w = self.world if world is None else world
        return sum(c.wire_bytes(w) for c in self.calls)

    def deferred_wire_bytes(self, world: int | None = None) -> float:
        w = self.world if world is None else world
        return sum(c.wire_bytes(w) for c in self.deferred_calls)

    @property
    def volume_ratio(self) -> float:
        return self.dense_bytes / max(self.bytes_per_worker, 1)

    def wire_bytes(self, world: int | None = None) -> float:
        w = self.world if world is None else world
        return sum(c.wire_bytes(w) for c in self.calls)

    # ---- per-link accounting (two-level hierarchy, DESIGN.md §17) ---------
    @property
    def links(self) -> tuple[str, ...]:
        """Distinct links this phase touches, "ici" first."""
        seen = {c.link for c in self.calls} | {
            c.link for c in self.deferred_calls
        }
        return tuple(sorted(seen, key=lambda l: (l != "ici", l)))

    def exposed_bytes_by_link(self) -> dict[str, int]:
        """Per-link injected bytes of the exposed calls — what the HLO
        cross-check (``launch.hlo_analysis.collective_bytes_by_link``)
        must reproduce for the execute half of a hierarchical step."""
        out: dict[str, int] = {}
        for c in self.calls:
            out[c.link] = out.get(c.link, 0) + c.bytes_per_worker
        return out

    def deferred_bytes_by_link(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for c in self.deferred_calls:
            out[c.link] = out.get(c.link, 0) + c.bytes_per_worker
        return out

    def exposed_wire_bytes_by_link(
        self, world: int | None = None
    ) -> dict[str, float]:
        """Ring-amplified wire bytes of the exposed calls split by link —
        the per-link numerators from which the adaptive controller derives
        ``exposed_scale`` (slowest-link time, ``runtime.controller``)."""
        w = self.world if world is None else world
        out: dict[str, float] = {}
        for c in self.calls:
            out[c.link] = out.get(c.link, 0.0) + c.wire_bytes(w)
        return out

    def deferred_wire_bytes_by_link(
        self, world: int | None = None
    ) -> dict[str, float]:
        w = self.world if world is None else world
        out: dict[str, float] = {}
        for c in self.deferred_calls:
            out[c.link] = out.get(c.link, 0.0) + c.wire_bytes(w)
        return out

    # ---- structure accessors ---------------------------------------------
    def issue_order(self) -> tuple[int, ...]:
        """Indices into ``calls`` sorted by backward readiness — the order
        the overlap engine issues this phase's collectives.  Falls back to
        plan order when the planner recorded no ranks."""
        if len(self.ready_ranks) != len(self.calls):
            return tuple(range(len(self.calls)))
        return tuple(
            sorted(range(len(self.calls)), key=lambda i: self.ready_ranks[i])
        )

    def segments(self, index: int) -> tuple[Segment, ...]:
        """Segments of selected entry ``index`` (bucket granularity only)."""
        if self.plan is None or self.granularity != "bucket":
            raise ValueError("schedule has no bucket-plan segments")
        return self.plan.buckets[self.selected[index]].segments

    def summary(self) -> dict:
        """JSON-serialisable digest for dry-run reports and logs."""
        ops: dict[str, int] = {}
        for c in self.calls:
            ops[c.op] = ops.get(c.op, 0) + c.bytes_per_worker
        out = {
            "compressor": self.compressor,
            "phase": self.phase,
            "num_phases": self.num_phases,
            "granularity": self.granularity,
            "selected": list(self.selected),
            "num_calls": len(self.calls),
            "bytes_per_worker": self.bytes_per_worker,
            "dense_bytes": self.dense_bytes,
            "volume_ratio": round(self.volume_ratio, 3),
            "bytes_by_op": ops,
            "sync": self.sync,
        }
        if self.sync != "allreduce":
            out["exposed_bytes_per_worker"] = self.exposed_bytes_per_worker
            out["deferred_bytes_per_worker"] = self.deferred_bytes_per_worker
            out["total_bytes_per_worker"] = self.total_bytes_per_worker
        if self.links != ("ici",) and self.links != ():
            out["links"] = list(self.links)
            out["exposed_bytes_by_link"] = self.exposed_bytes_by_link()
            out["deferred_bytes_by_link"] = self.deferred_bytes_by_link()
        return out


def plan_all_phases(
    compressor, plan: BucketPlan, *, world: int = 1
) -> tuple[CommSchedule, ...]:
    """Every phase's schedule — the complete static comm description of one
    training cycle (period = num_phases steps)."""
    n = max(compressor.num_phases(plan.interval_hint), 1)
    return tuple(
        compressor.plan_phase(plan, p, world=world) for p in range(n)
    )


def cycle_bytes_per_worker(schedules: Iterable[CommSchedule]) -> int:
    return sum(s.bytes_per_worker for s in schedules)


def mean_bytes_per_step(schedules: Sequence[CommSchedule]) -> float:
    schedules = tuple(schedules)
    if not schedules:
        return 0.0
    return cycle_bytes_per_worker(schedules) / len(schedules)
