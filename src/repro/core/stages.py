"""Reusable gradient-sync stages + the ``SyncPipeline`` combinator.

Every GC scheme in this repo decomposes into at most three orthogonal
stages (DESIGN.md SS4):

* an optional :class:`ErrorFeedback` stage (compensate before, keep the
  un-sent part as the residual after);
* an optional :class:`CoarseFilter` (the paper's static bucket selection —
  the only stage that makes a schedule phase-dependent);
* exactly one *wire stage* that defines how a selected bucket (or leaf)
  crosses the interconnect: :class:`WireCast` (dense, optionally
  dtype-cast, segment-wise all-reduce), :class:`TopK`, :class:`RandomK`,
  :class:`SignCompress`, :class:`FP8Block`, :class:`OkTopKRoute`
  (bucket granularity) or :class:`LowRank` (leaf granularity, PowerSGD).

``SyncPipeline`` composes them and implements the plan/execute split:
``plan_phase`` emits a static :class:`CommSchedule` (no tracing), and
``execute`` is a pure function of ``(schedule, grads, state)`` that runs
inside ``shard_map``.  COVAP is literally::

    SyncPipeline(filter=CoarseFilter(I), ef=ErrorFeedback(EFSchedule(...)),
                 wire=WireCast())

and beyond-paper hybrids (filter + fp8 wire + EF, GraVAC-style) are
one-liners: ``SyncPipeline.of(CoarseFilter(8), ErrorFeedback(), FP8Block())``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import arena as ar
from . import bucketing as bk
from .bucketing import Bucket, BucketPlan, build_ready_order
from .error_feedback import EFSchedule, compensate, init_residual
from .filter import selected_buckets
from .schedule import CollectiveCall, CommSchedule
from .comm import (
    Compressor,
    SyncStats,
    all_gather,
    axis_size,
    dense_bytes,
    flat_axis_index,
    pmean,
    reduce_scatter,
)


def _bucket_dtype(plan: BucketPlan, bucket: Bucket) -> np.dtype:
    """Dtype of the flattened bucket vector (mixed buckets promote) —
    canonical definition lives in :func:`repro.core.arena.bucket_dtype`."""
    return ar.bucket_dtype(plan, bucket)


# ---------------------------------------------------------------------------
# filter + error-feedback stages
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CoarseFilter:
    """The paper's coarse-grained filter (SS III.A): bucket ``b`` is
    communicated in phase ``p`` iff ``(b + p) % interval == 0``."""

    interval: int = 4

    def num_phases(self) -> int:
        return max(int(self.interval), 1)

    def select(self, plan: BucketPlan, phase: int) -> tuple[int, ...]:
        return selected_buckets(plan.num_buckets, phase, self.interval)


@dataclasses.dataclass(frozen=True)
class ErrorFeedback:
    """Compensation + residual stage (SS III.D).  ``schedule=None`` is the
    classic EF of the baselines (coefficient 1); COVAP passes its ascending
    :class:`EFSchedule`."""

    schedule: EFSchedule | None = None

    def compensated(self, grads: Any, residual: Any, step) -> Any:
        if self.schedule is None:
            return jax.tree.map(
                lambda g, r: g + r.astype(g.dtype), grads, residual
            )
        return compensate(grads, residual, self.schedule.coefficient(step))


# ---------------------------------------------------------------------------
# wire stages (bucket granularity)
# ---------------------------------------------------------------------------

class WireStage:
    """How one selected bucket crosses the network.

    ``plan_bucket`` is the static half (exact per-worker bytes, collective
    op, wire dtype); ``execute_bucket`` / ``execute_segment`` the traced
    half.  ``segmented=True`` stages work on sharding-preserving segment
    slices (no gather/scatter copies); the rest see the flat bucket vector.
    """

    op: str = "all_reduce"
    segmented: bool = False

    def plan_bucket(
        self, plan: BucketPlan, bucket: Bucket, world: int = 1
    ) -> CollectiveCall:
        raise NotImplementedError

    def execute_bucket(self, flat, key, axis_names):
        """-> (synced_flat, local_sent_flat)"""
        raise NotImplementedError

    def __repr__(self):
        return f"{type(self).__name__}()"


class WireCast(WireStage):
    """Dense segment-wise all-reduce, optionally dtype-cast on the wire.

    ``WireCast(None)`` is the DDP baseline (one psum per bucket segment);
    ``WireCast('bfloat16')`` halves the wire volume, with the quantisation
    error landing in the EF residual when an :class:`ErrorFeedback` stage is
    present (beyond-paper COVAP x2 composition).
    """

    segmented = True

    def __init__(self, wire_dtype: str | None = None):
        self.wire_dtype = jnp.dtype(wire_dtype) if wire_dtype else None

    def plan_bucket(self, plan, bucket, world=1):
        if self.wire_dtype is not None:
            payload = bucket.numel * self.wire_dtype.itemsize
            name = self.wire_dtype.name
        else:
            payload = bucket.nbytes
            name = _bucket_dtype(plan, bucket).name
        return CollectiveCall(
            f"bucket:{bucket.index}", "all_reduce", name, payload
        )

    def execute_segment(self, x, axis_names):
        """-> (synced_segment, residual_segment)."""
        if self.wire_dtype is not None and x.dtype != self.wire_dtype:
            xw = x.astype(self.wire_dtype)
            xm = pmean(xw, axis_names).astype(x.dtype)
            return xm, x - xw.astype(x.dtype)
        return pmean(x, axis_names), jnp.zeros_like(x)

    def __repr__(self):
        return f"WireCast({self.wire_dtype})"


class TopK(WireStage):
    """Aji & Heafield top-|g| selection; worker index sets differ, so the
    exchange is an all-gather of (values, int32 indices).  ``clip_norm``
    adds DGC's local gradient clipping before selection."""

    op = "all_gather"

    def __init__(self, ratio: float = 0.01, clip_norm: float = 0.0):
        self.ratio = float(ratio)
        self.clip_norm = float(clip_norm)

    def _k(self, n: int) -> int:
        return max(1, int(math.ceil(n * self.ratio)))

    def plan_bucket(self, plan, bucket, world=1):
        dt = _bucket_dtype(plan, bucket)
        m = self._k(bucket.numel)
        return CollectiveCall(
            f"bucket:{bucket.index}", "all_gather", dt.name,
            m * dt.itemsize, m * 4,
        )

    def execute_bucket(self, flat, key, axis_names):
        if self.clip_norm > 0:
            norm = jnp.linalg.norm(flat) + 1e-12
            flat = flat * jnp.minimum(1.0, self.clip_norm / norm)
        n = flat.shape[0]
        m = self._k(n)
        _, idx = lax.top_k(jnp.abs(flat), m)
        vals = flat[idx]
        vals_all = all_gather(vals, axis_names)  # (W, m)
        idx_all = all_gather(idx, axis_names)
        W = vals_all.shape[0]
        out = jnp.zeros(n, flat.dtype)
        out = out.at[idx_all.reshape(-1)].add(vals_all.reshape(-1)) / W
        local_sent = jnp.zeros(n, flat.dtype).at[idx].set(vals)
        return out, local_sent


class RandomK(WireStage):
    """Stich et al. sparsified SGD: the index set comes from a PRNG key
    shared by construction (seed, step, bucket), so the exchange is a dense
    psum over the selected values only — no index traffic."""

    op = "all_reduce"

    def __init__(self, ratio: float = 0.01):
        self.ratio = float(ratio)

    def plan_bucket(self, plan, bucket, world=1):
        dt = _bucket_dtype(plan, bucket)
        m = max(1, int(math.ceil(bucket.numel * self.ratio)))
        return CollectiveCall(
            f"bucket:{bucket.index}", "all_reduce", dt.name, m * dt.itemsize
        )

    def execute_bucket(self, flat, key, axis_names):
        n = flat.shape[0]
        m = max(1, int(math.ceil(n * self.ratio)))
        idx = jax.random.randint(key, (m,), 0, n)
        vals = flat[idx]
        synced = pmean(vals, axis_names)
        out = jnp.zeros(n, flat.dtype).at[idx].set(synced)
        local_sent = jnp.zeros(n, flat.dtype).at[idx].set(vals)
        return out, local_sent


class SignCompress(WireStage):
    """EFsignSGD wire format: int8 signs (1 byte/elem) + one fp32 scale
    = mean(|t|); AllGather-based (scales worse with W — Fig. 11)."""

    op = "all_gather"

    def plan_bucket(self, plan, bucket, world=1):
        return CollectiveCall(
            f"bucket:{bucket.index}", "all_gather", "int8",
            bucket.numel * 1, 4,
        )

    def execute_bucket(self, flat, key, axis_names):
        scale = jnp.mean(jnp.abs(flat))
        signs = jnp.where(flat >= 0, 1, -1).astype(jnp.int8)
        signs_all = all_gather(signs, axis_names)          # (W, n) int8
        scales_all = all_gather(scale[None], axis_names)   # (W, 1)
        decoded = (
            signs_all.astype(flat.dtype) * scales_all.astype(flat.dtype)
        ).mean(axis=0)
        local_sent = scale * signs.astype(flat.dtype)
        return decoded, local_sent


class FP8Block(WireStage):
    """Block-scaled FP8 wire (4x vs fp32): fp8 payload + fp32 per-block
    amax scales, exchanged by all-gather (payloads differ per worker)."""

    op = "all_gather"

    def __init__(self, block: int = 8192):
        self.block = int(block)

    def plan_bucket(self, plan, bucket, world=1):
        nb = max(1, -(-bucket.numel // self.block))
        return CollectiveCall(
            f"bucket:{bucket.index}", "all_gather", "float8_e4m3fn",
            bucket.numel * 1, nb * 4,
        )

    def execute_bucket(self, flat, key, axis_names):
        from ..kernels import ref as kref

        q, scales = kref.quantize_fp8_ref(flat, block=self.block)
        q_all = all_gather(q, axis_names)            # (W, n) fp8
        s_all = all_gather(scales, axis_names)       # (W, nb)
        W = q_all.shape[0]
        dec = jnp.stack(
            [
                kref.dequantize_fp8_ref(q_all[w], s_all[w], block=self.block)
                for w in range(W)
            ]
        ).mean(axis=0).astype(flat.dtype)
        local_sent = kref.dequantize_fp8_ref(
            q, scales, block=self.block
        ).astype(flat.dtype)
        return dec, local_sent


def _all_to_all(x, axis_names):
    """all-to-all over (possibly multiple) named axes; x: (W, ...)."""
    if len(axis_names) == 1:
        return lax.all_to_all(x, axis_names[0], split_axis=0, concat_axis=0)
    return lax.all_to_all(x, tuple(axis_names), split_axis=0, concat_axis=0)


class OkTopKRoute(WireStage):
    """Ok-topk's region-routed sparse exchange (all-to-all with fixed
    capacity + regional top-(k/W) + all-gather of survivors) — the
    data-dependent multi-stage pattern the paper identifies as hostile to
    overlapping (SS I, Fig. 1e)."""

    op = "all_to_all"

    def __init__(self, ratio: float = 0.01):
        self.ratio = float(ratio)

    @staticmethod
    def _geometry(n: int, ratio: float, W: int):
        m = max(W, int(math.ceil(n * ratio)))
        m = int(math.ceil(m / W) * W)
        region_size = int(math.ceil(n / W))
        cap = min(2 * m // W + 1, region_size)
        return m, region_size, cap

    def plan_bucket(self, plan, bucket, world=1):
        dt = _bucket_dtype(plan, bucket)
        W = max(int(world), 1)
        m, _, cap = self._geometry(bucket.numel, self.ratio, W)
        k_r = m // W
        # two physically different exchanges, priced separately so the
        # wire model amplifies each correctly: the routed all-to-all
        # ((vals, int32 idx, mask-at-wire-dtype) x W capacity windows) and
        # the survivor all-gather ((vals, int32 global idx) x k_r)
        return (
            CollectiveCall(
                f"bucket:{bucket.index}", "all_to_all", dt.name,
                W * cap * dt.itemsize, W * cap * (4 + dt.itemsize),
            ),
            CollectiveCall(
                f"bucket:{bucket.index}:survivors", "all_gather", dt.name,
                k_r * dt.itemsize, k_r * 4,
            ),
        )

    def execute_bucket(self, flat, key, axis_names):
        n = flat.shape[0]
        if not axis_names:
            # single worker: reduces to local top-k
            m = max(1, int(math.ceil(n * self.ratio)))
            _, idx = lax.top_k(jnp.abs(flat), m)
            vals = flat[idx]
            out = jnp.zeros(n, flat.dtype).at[idx].set(vals)
            return out, out

        W = axis_size(axis_names[0])
        for ax in axis_names[1:]:
            W *= axis_size(ax)
        m, region_size, cap = self._geometry(n, self.ratio, W)
        n_pad = region_size * W

        _, idx = lax.top_k(jnp.abs(flat), m)
        vals = flat[idx]
        region = idx // region_size  # (m,) destination worker

        # position of each entry within its destination's capacity window
        onehot = (region[:, None] == jnp.arange(W)[None, :]).astype(jnp.int32)
        pos = (jnp.cumsum(onehot, axis=0) - 1)[jnp.arange(m), region]

        send_vals = jnp.zeros((W, cap), flat.dtype).at[region, pos].set(
            vals, mode="drop"
        )
        send_idx = jnp.zeros((W, cap), jnp.int32).at[region, pos].set(
            (idx - region * region_size).astype(jnp.int32), mode="drop"
        )
        send_mask = jnp.zeros((W, cap), flat.dtype).at[region, pos].set(
            1.0, mode="drop"
        )

        recv_vals = _all_to_all(send_vals, axis_names)
        recv_idx = _all_to_all(send_idx, axis_names)
        recv_mask = _all_to_all(send_mask, axis_names)

        dense = jnp.zeros(region_size, flat.dtype).at[
            recv_idx.reshape(-1)
        ].add((recv_vals * recv_mask).reshape(-1))
        k_r = m // W
        _, ridx = lax.top_k(jnp.abs(dense), k_r)
        rvals = dense[ridx]
        offset = flat_axis_index(tuple(axis_names)) * region_size
        gidx = ridx + offset

        vals_all = all_gather(rvals, axis_names).reshape(-1)
        gidx_all = all_gather(gidx, axis_names).reshape(-1)
        out = jnp.zeros(n_pad, flat.dtype).at[gidx_all].set(vals_all) / W
        out = out[:n]

        kept = pos < cap
        local_sent = jnp.zeros(n, flat.dtype).at[idx].set(
            jnp.where(kept, vals, 0.0)
        )
        return out, local_sent


# ---------------------------------------------------------------------------
# leaf-granularity wire stage (PowerSGD)
# ---------------------------------------------------------------------------

def _as_batched_matrix(x: jax.Array) -> jax.Array:
    if x.ndim == 2:
        return x[None]
    return x.reshape((-1,) + x.shape[-2:])


class LowRank:
    """PowerSGD's rank-r factorised all-reduce, per >=2-D leaf (batched over
    leading stack axes).  Communication per matrix: (a + b) * r words via
    AllReduce — scales well but pays two matmuls + QR per step."""

    granularity = "leaf"
    op = "all_reduce"

    def __init__(self, rank: int = 2, seed: int = 0):
        self.rank = int(rank)
        self.seed = int(seed)

    def init_state(self, params_like: Any, plan: BucketPlan, *, use_ef: bool):
        key = jax.random.PRNGKey(self.seed)
        qs, resid = [], []
        for i, leaf in enumerate(jax.tree_util.tree_leaves(params_like)):
            if leaf.ndim >= 2:
                m = _as_batched_matrix(jnp.zeros(leaf.shape, leaf.dtype))
                b = m.shape[-1]
                k = jax.random.fold_in(key, i)
                qs.append(
                    jax.random.normal(k, (m.shape[0], b, self.rank), leaf.dtype)
                )
            else:
                qs.append(None)
            resid.append(
                jnp.zeros(leaf.shape, leaf.dtype) if use_ef else None
            )
        return {"q": qs, "residual": resid}

    def plan_leaf(
        self, leaf_idx: int, shape: tuple[int, ...], dtype
    ) -> CollectiveCall:
        dt = np.dtype(dtype)
        if len(shape) >= 2:
            lead = shape[:-2]
            B = int(np.prod(lead, dtype=np.int64)) if lead else 1
            a, b = shape[-2], shape[-1]
            payload = B * (a + b) * self.rank * dt.itemsize
        else:
            n = int(np.prod(shape, dtype=np.int64)) if shape else 1
            payload = n * dt.itemsize
        return CollectiveCall(f"leaf:{leaf_idx}", "all_reduce", dt.name, payload)

    def execute_leaf(self, t, q, axis_names):
        """-> (approx, new_q); dense pmean for <2-D leaves (q is None)."""
        if q is None:
            return pmean(t, axis_names), None
        m = _as_batched_matrix(t)
        p = pmean(jnp.einsum("bij,bjk->bik", m, q), axis_names)
        p, _ = jnp.linalg.qr(p)  # orthonormalize columns
        qn = pmean(jnp.einsum("bij,bik->bjk", m, p), axis_names)
        approx = jnp.einsum("bik,bjk->bij", p, qn).reshape(t.shape)
        return approx, qn

    def __repr__(self):
        return f"LowRank(rank={self.rank})"


# ---------------------------------------------------------------------------
# the combinator
# ---------------------------------------------------------------------------

def _state_present(state: Any) -> bool:
    return state is not None and state != ()


def _split_like(slices: Sequence[jax.Array], flat: jax.Array) -> list[jax.Array]:
    """Split a flat bucket vector back into pieces shaped like ``slices``."""
    out, off = [], 0
    for x in slices:
        n = int(x.size)
        out.append(lax.dynamic_slice_in_dim(flat, off, n).reshape(x.shape))
        off += n
    return out


class SyncPipeline(Compressor):
    """filter ∘ error-feedback ∘ wire, with the plan/execute split.

    ``plan_phase(plan, phase)`` -> :class:`CommSchedule` (static, no
    tracing); ``execute(schedule, grads, state)`` -> (synced, state', stats)
    (pure, shard_map-safe).  ``sync`` remains as the legacy one-call wrapper.
    """

    name = "pipeline"

    def __init__(
        self,
        *,
        wire,
        filter: CoarseFilter | None = None,
        ef: ErrorFeedback | None = None,
        seed: int = 0,
        **opts,
    ):
        super().__init__(**opts)
        self.wire = wire
        self.filter = filter
        self.ef = ef
        self.seed = int(seed)
        if self.granularity == "leaf" and filter is not None:
            raise ValueError("CoarseFilter requires bucket granularity")
        sync = self.options.get("sync", "allreduce") or "allreduce"
        if sync not in ("allreduce", "sharded"):
            raise ValueError(
                f"sync must be 'allreduce' or 'sharded', got {sync!r}"
            )
        if sync == "sharded" and not (
            self.granularity == "bucket"
            and getattr(self.wire, "segmented", False)
        ):
            raise ValueError(
                "sync='sharded' requires a segmented bucket pipeline "
                f"(covap / none / fp16); {self.wire!r} must use "
                "sync='allreduce'"
            )

    # ---- composition sugar ------------------------------------------------
    @classmethod
    def of(cls, *stages, seed: int = 0, **opts) -> "SyncPipeline":
        """Build a pipeline from an unordered stage list, e.g.
        ``SyncPipeline.of(CoarseFilter(8), ErrorFeedback(), FP8Block())``."""
        filt, ef, wire = None, None, None
        for s in stages:
            if isinstance(s, CoarseFilter):
                filt = s
            elif isinstance(s, ErrorFeedback):
                ef = s
            elif isinstance(s, (WireStage, LowRank)):
                if wire is not None:
                    raise ValueError("exactly one wire stage per pipeline")
                wire = s
            else:
                raise TypeError(f"not a pipeline stage: {s!r}")
        if wire is None:
            wire = WireCast(None)
        return cls(wire=wire, filter=filt, ef=ef, seed=seed, **opts)

    @property
    def granularity(self) -> str:
        return getattr(self.wire, "granularity", "bucket")

    @property
    def sync_mode(self) -> str:
        """Collective decomposition: ``"allreduce"`` (one all-reduce per
        selected bucket — the classic path) or ``"sharded"`` (reduce-scatter
        the compressed gradient, optimizer on the local shard, deferred
        param all-gather at the next step's head — DESIGN.md §13)."""
        return self.options.get("sync", "allreduce") or "allreduce"

    @property
    def stages(self) -> tuple:
        out = []
        if self.filter is not None:
            out.append(self.filter)
        if self.ef is not None:
            out.append(self.ef)
        out.append(self.wire)
        return tuple(out)

    def __repr__(self):
        inner = " ∘ ".join(repr(s) for s in self.stages)
        return f"{type(self).__name__}[{inner}]"

    # ---- lifecycle --------------------------------------------------------
    def num_phases(self, interval: int | None = None) -> int:
        return self.filter.num_phases() if self.filter is not None else 1

    def init_state(self, params_like: Any, plan: BucketPlan) -> Any:
        if self.granularity == "leaf":
            return self.wire.init_state(
                params_like, plan, use_ef=self.ef is not None
            )
        if self.ef is None:
            return ()
        return init_residual(params_like)

    # ---- plan -------------------------------------------------------------
    def _plan_bucket_sharded(
        self, plan: BucketPlan, bucket: Bucket, world: int
    ) -> CollectiveCall:
        """The exposed half of one bucket's sharded sync (DESIGN.md §13): a
        reduce-scatter of the W-aligned wire slot.  ``payload_bytes`` is
        the full padded input buffer at the wire dtype — the per-worker
        *injected* bytes the HLO parser normalises a reduce-scatter result
        to (``launch.hlo_analysis.collective_bytes_per_worker``)."""
        W = max(int(world), 1)
        padded = ar.aligned_numel(bucket.numel, W)
        wd = _bucket_dtype(plan, bucket)
        if isinstance(self.wire, WireCast) and self.wire.wire_dtype is not None:
            wd = np.dtype(self.wire.wire_dtype)
        return CollectiveCall(
            f"bucket:{bucket.index}", "reduce_scatter", np.dtype(wd).name,
            padded * np.dtype(wd).itemsize,
        )

    def _plan_deferred_allgather(
        self, plan: BucketPlan, world: int
    ) -> tuple[CollectiveCall, ...]:
        """The deferred half of sharded sync: the param all-gathers the
        trainer issues at the next step's head.  One call per plan bucket —
        EVERY bucket, not just this phase's selected ones: once a bucket
        has been selected its optimizer moments are nonzero, so its params
        keep moving every step (Adam decay) and only the shard owner holds
        authoritative values.  Payload is the LOCAL shard each worker
        contributes, at the promoted PARAM dtype (updated parameters go on
        the wire uncompressed — compression applies to gradients only)."""
        W = max(int(world), 1)
        calls = []
        for bucket in plan.buckets:
            padded = ar.aligned_numel(bucket.numel, W)
            pd = _bucket_dtype(plan, bucket)
            calls.append(
                CollectiveCall(
                    f"param-bucket:{bucket.index}", "all_gather",
                    np.dtype(pd).name,
                    (padded // W) * np.dtype(pd).itemsize, deferred=True,
                )
            )
        return tuple(calls)

    def plan_phase(
        self, plan: BucketPlan, phase: int, *, world: int = 1
    ) -> CommSchedule:
        n = self.num_phases()
        ph = int(phase) % max(n, 1)
        ready_ranks: tuple[int, ...] = ()
        sharded = self.sync_mode == "sharded"
        if self.granularity == "leaf":
            selected = tuple(range(len(plan.leaf_shapes)))
            calls = tuple(
                self.wire.plan_leaf(i, plan.leaf_shapes[i], plan.leaf_dtypes[i])
                for i in selected
            )
        else:
            sel = (
                self.filter.select(plan, ph)
                if self.filter is not None
                else tuple(range(plan.num_buckets))
            )
            # a wire stage may plan several collectives per bucket
            # (e.g. OkTopKRoute's route + survivor exchange); `selected`
            # repeats the bucket index so it stays aligned with `calls`
            ready = build_ready_order(plan)
            selected, calls, ranks = [], [], []
            for b in sel:
                planned = (
                    self._plan_bucket_sharded(plan, plan.buckets[b], world)
                    if sharded
                    else self.wire.plan_bucket(plan, plan.buckets[b], world)
                )
                for call in planned if isinstance(planned, tuple) else (planned,):
                    selected.append(b)
                    calls.append(call)
                    ranks.append(ready.rank_of(b))
            selected, calls = tuple(selected), tuple(calls)
            ready_ranks = tuple(ranks)
        return CommSchedule(
            compressor=self.name,
            phase=ph,
            num_phases=max(n, 1),
            granularity=self.granularity,
            selected=selected,
            calls=calls,
            dense_bytes=dense_bytes(plan),
            world=world,
            plan=plan,
            ready_ranks=ready_ranks,
            sync="sharded" if sharded else "allreduce",
            deferred_calls=(
                self._plan_deferred_allgather(plan, world) if sharded else ()
            ),
        )

    # ---- execute ----------------------------------------------------------
    def execute(
        self,
        schedule: CommSchedule,
        grads: Any,
        state: Any,
        *,
        step=0,
        axis_names: Sequence[str] = (),
    ):
        stats = SyncStats(schedule.bytes_per_worker, schedule.dense_bytes)
        if self.granularity == "leaf":
            out, new_state = self._execute_leaf(grads, state, axis_names)
        elif getattr(self.wire, "segmented", False):
            out, new_state = self._execute_segmented(
                schedule, grads, state, step, axis_names
            )
        else:
            out, new_state = self._execute_flat(
                schedule, grads, state, step, axis_names
            )
        return out, new_state, stats

    # ---- granular per-bucket API (overlap engine entry points) ------------
    def ef_coefficient(self, step):
        """The EF compensation coefficient for ``step`` — ``None`` when the
        pipeline has no EF stage (classic EF without a schedule is exactly
        coefficient 1, which is bitwise-identical to the plain add)."""
        if self.ef is None:
            return None
        if self.ef.schedule is None:
            return jnp.float32(1.0)
        return self.ef.schedule.coefficient(step)

    def _use_ef_kernel(self, g, r, coeff) -> bool:
        """The fused Pallas EF-update (kernels/ef_covap.ef_update) replaces
        the 2-3-op jnp formulation on the dense segmented path: one
        streaming pass computes t = g + c*r and splits it into
        (send, residual').  Applicability: plain WireCast (no wire cast —
        the cast path keeps its quantisation-error residual) and f32
        operands.

        Engagement: on TPU by default; on CPU only with the explicit
        ``use_ef_kernel=True`` compressor option.  The fused kernel emits a
        single-rounding FMA for ``g + c*r`` while the jnp formulation
        rounds the product separately, so interpret mode cannot be
        bitwise-identical to the legacy path — CPU runs keep the reference
        formulation unless a test/benchmark opts in (both the post and the
        fused overlap path route through here, so they always agree with
        each other either way)."""
        if not (
            coeff is not None
            and r is not None
            and isinstance(self.wire, WireCast)
            and self.wire.wire_dtype is None
            and g.dtype == jnp.float32
            and r.dtype == jnp.float32
        ):
            return False
        use = self.options.get("use_ef_kernel")
        if use is None:
            from ..kernels.common import INTERPRET

            use = not INTERPRET
        return bool(use)

    # ---- zero-copy arena path (core/arena.py, DESIGN.md §12) --------------
    def _arena_on(self) -> bool:
        """The ``use_arena`` compressor option: bucket payloads live as
        static-offset views of per-phase flat planes instead of per-step
        ``concatenate`` / ``dynamic_slice`` rebuilds.  Off by default — the
        legacy op order stays pinned; arena-on is bitwise-equal for
        uniform-dtype models (mixed-dtype buckets promote per
        :func:`arena.bucket_dtype`, exactly as ``jnp.concatenate`` would,
        so the flat wires match there too)."""
        return bool(self.options.get("use_arena", False))

    def _use_pack_kernel(self, g, r, coeff) -> bool:
        """Fused Pallas pack kernel (kernels/pack_ef_cast.pack_ef_cast) on
        the arena pack pass: one streaming pass computes ``t = g + c*r``,
        the wire-dtype cast, and the residual split — replacing the
        flatten -> compensate -> cast triple materialisation.

        Applicability: EF present, ``WireCast`` wire (dense or bf16/f16
        cast), f32 operands.  Engagement mirrors ``_use_ef_kernel``: on by
        default on TPU, CPU opt-in via ``use_pack_kernel=True`` (interpret
        mode emits a single-rounding FMA for ``g + c*r``, so the CPU
        default stays on the bitwise-identical jnp reference)."""
        if not (
            coeff is not None
            and r is not None
            and isinstance(self.wire, WireCast)
            and g.dtype == jnp.float32
            and r.dtype == jnp.float32
        ):
            return False
        wd = self.wire.wire_dtype
        if wd is not None and wd not in (
            jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float16)
        ):
            return False
        use = self.options.get("use_pack_kernel")
        if use is None:
            from ..kernels.common import INTERPRET

            use = not INTERPRET
        return bool(use)

    def _pack_segment(self, g, r, coeff, *, selected: bool):
        """One segment through the fused pack + EF + cast pass.

        Returns ``(wire_flat, resid)``: the flat wire-dtype values destined
        for the segment's arena slot (zeros for an unselected bucket —
        never written) and the new residual in the segment's shape
        (``None`` when EF is off)."""
        gf = g.reshape(-1)
        wd = self.wire.wire_dtype if isinstance(self.wire, WireCast) else None
        if self._use_pack_kernel(g, r, coeff):
            from ..kernels.pack_ef_cast import pack_ef_cast

            w, rnew = pack_ef_cast(
                gf, r.reshape(-1).astype(g.dtype), coeff,
                selected=selected,
                wire_dtype=wd.name if wd is not None else None,
            )
        else:
            from ..kernels import ref as kref

            w, rnew = kref.pack_ef_cast_ref(
                gf,
                r.reshape(-1).astype(g.dtype) if r is not None else None,
                coeff, selected=selected, wire_dtype=wd,
            )
        if rnew is not None:
            rnew = rnew.reshape(g.shape)
        return w, (rnew if r is not None else None)

    def _execute_bucket_arena(
        self, schedule, b, g_slices, r_slices, *, coeff, axis_names
    ):
        """Arena form of one segmented bucket's sync: pack the segments
        into the bucket's contiguous slot (fused EF + cast, static
        offsets), ONE collective over the slot view, split the result with
        static slices.  vs. the legacy per-segment path: no per-segment
        collectives, no dynamic-slice chains — and bitwise-identical
        outputs for uniform-dtype buckets (elementwise ops and ``pmean``
        commute with layout).  A MIXED-dtype bucket reduces at the
        promoted plane dtype (legacy reduces each segment at its own
        dtype), so there the sum's bits — and the dense wire bytes vs the
        planned ``bucket.nbytes`` — legitimately differ; the pinned
        parity guarantee (TrainConfig.arena) is scoped to uniform-dtype
        models."""
        plan = schedule.plan
        selected = b in schedule.selected
        layout = ar.build_layout(
            plan, (b,),
            wire_dtype=(
                self.wire.wire_dtype
                if isinstance(self.wire, WireCast) else None
            ),
        )
        ef_on = r_slices is not None
        wires, resids = [], []
        for g, r in zip(
            g_slices, r_slices if ef_on else (None,) * len(g_slices)
        ):
            w, rnew = self._pack_segment(g, r, coeff, selected=selected)
            wires.append(w)
            resids.append(rnew)
        if not selected:
            return None, (resids if ef_on else None)
        planes = layout.assemble({b: wires})
        xm = pmean(layout.bucket_view(planes, b), axis_names)
        synced = [
            piece.astype(g.dtype)
            for piece, g in zip(layout.unpack_bucket(b, xm), g_slices)
        ]
        return synced, (resids if ef_on else None)

    # ---- sharded sync (reduce-scatter over the arena, DESIGN.md §13) ------
    def _reduce_scatter_slot(self, view, axis_names):
        """One W-aligned slot view through the sharded collective: a
        reduce-scatter (mean, same elementwise op order as ``pmean``) hands
        this worker its reduced shard; the shard is placed back at its
        owner offset in an otherwise-ZERO slot-sized vector.

        The zeros are the sharded contract: only the locally-owned 1/W of
        each bucket carries meaningful synced values — the optimizer's
        updates elsewhere are dead compute whose results are overwritten by
        the owner's shard when ``overlap.sharded_param_allgather`` runs at
        the next step's head.  Single-worker (no axes): identity.
        """
        if not axis_names:
            return reduce_scatter(view, axis_names)
        W = 1
        for a in axis_names:
            W *= axis_size(a)
        shard = reduce_scatter(view, axis_names)
        start = flat_axis_index(axis_names) * (view.shape[0] // W)
        return lax.dynamic_update_slice(
            jnp.zeros_like(view), shard, (start,)
        )

    def _execute_bucket_sharded(
        self, schedule, b, g_slices, r_slices, *, coeff, axis_names
    ):
        """Sharded form of one segmented bucket's sync: pack the segments
        into the bucket's W-aligned contiguous slot (same fused EF + cast
        pass as the arena path — ``pack_ef_cast_ref`` is op-for-op the
        legacy ``_ef_segment`` math), reduce-scatter the slot view, and
        return segment pieces that hold the reduced values at the
        locally-owned shard and zeros elsewhere.  EF residuals are computed
        locally BEFORE the collective, so they are bitwise the allreduce
        path's residuals regardless of the decomposition."""
        plan = schedule.plan
        selected = b in schedule.selected
        ef_on = r_slices is not None
        wires, resids = [], []
        for g, r in zip(
            g_slices, r_slices if ef_on else (None,) * len(g_slices)
        ):
            w, rnew = self._pack_segment(g, r, coeff, selected=selected)
            wires.append(w)
            resids.append(rnew)
        if not selected:
            return None, (resids if ef_on else None)
        W = 1
        for a in axis_names:
            W *= axis_size(a)
        layout = ar.build_layout(
            plan, (b,),
            wire_dtype=(
                self.wire.wire_dtype
                if isinstance(self.wire, WireCast) else None
            ),
            align=W,
        )
        planes = layout.assemble({b: wires})
        full = self._reduce_scatter_slot(
            layout.bucket_view(planes, b), axis_names
        )
        synced = [
            piece.astype(g.dtype)
            for piece, g in zip(layout.unpack_bucket(b, full), g_slices)
        ]
        return synced, (resids if ef_on else None)

    def _ef_segment(self, g, r, coeff, *, selected: bool, axis_names):
        """One segment slice through EF ∘ filter-decision ∘ wire.

        ``g`` is the raw gradient slice, ``r`` the residual slice (or
        ``None`` when the pipeline runs without EF), ``coeff`` the
        compensation coefficient from :meth:`ef_coefficient`.  Returns
        ``(synced, resid)``: the globally-synced value (``None`` for an
        unselected bucket — the caller's output stays zero there) and the
        new residual slice (``None`` when EF is off).
        """
        if self._use_ef_kernel(g, r, coeff):
            from ..kernels.ef_covap import ef_update

            send, rnew = ef_update(
                g.reshape(-1), r.reshape(-1).astype(g.dtype), coeff,
                selected=selected,
            )
            rnew = rnew.reshape(g.shape)
            if not selected:
                return None, rnew
            return pmean(send.reshape(g.shape), axis_names), rnew
        if r is None:
            t = g
        elif coeff is None:
            t = g + r.astype(g.dtype)
        else:
            t = g + coeff * r.astype(g.dtype)
        if not selected:
            return None, (t if r is not None else None)
        xm, resid = self.wire.execute_segment(t, axis_names)
        return xm, (resid if r is not None else None)

    def execute_bucket(
        self,
        schedule: CommSchedule,
        b: int,
        g_slices: Sequence[jax.Array],
        r_slices: Sequence[jax.Array] | None = None,
        *,
        coeff=None,
        key=None,
        axis_names: Sequence[str] = (),
    ):
        """Execute exactly ONE bucket's synchronisation — the granular unit
        the overlap engine's gradient-ready hooks call from inside the
        backward pass, and which :meth:`execute` loops over.

        ``g_slices`` are segment-aligned slices of bucket ``b``
        (``plan.buckets[b].segments`` order); ``r_slices`` the matching EF
        residual slices or ``None``.  Segmented wires take RAW gradient
        slices (EF compensation — fused kernel when applicable — happens in
        here, so the hook path and the post path share one implementation);
        flat wires take already-compensated slices (their classic EF
        residual ``t - sent`` is a whole-tree property handled by the
        caller).

        Returns ``(synced_slices, resid_slices)`` aligned with the bucket's
        segments; ``synced_slices`` is ``None`` for an unselected segmented
        bucket (nothing crosses the wire — output stays zero), and
        ``resid_slices`` is ``None`` when no EF state is threaded.  For
        flat wires ``resid_slices`` carries the *locally sent* values
        (classic EF subtracts them from ``t``).
        """
        plan = schedule.plan
        bucket = plan.buckets[b]
        if self.granularity == "leaf":
            raise ValueError("leaf-granularity pipelines have no buckets; "
                             "use execute_leaf_one")
        selected = b in schedule.selected
        if getattr(self.wire, "segmented", False):
            if schedule.sync == "sharded":
                return self._execute_bucket_sharded(
                    schedule, b, g_slices, r_slices,
                    coeff=coeff, axis_names=axis_names,
                )
            if self._arena_on():
                return self._execute_bucket_arena(
                    schedule, b, g_slices, r_slices,
                    coeff=coeff, axis_names=axis_names,
                )
            synced, resids = [], []
            for g, r in zip(
                g_slices,
                r_slices if r_slices is not None else (None,) * len(g_slices),
            ):
                xm, rr = self._ef_segment(
                    g, r, coeff, selected=selected, axis_names=axis_names
                )
                synced.append(xm)
                resids.append(rr)
            if not selected:
                return None, (resids if r_slices is not None else None)
            return synced, (resids if r_slices is not None else None)
        # flat wire: gather the (compensated) slices, one wire exchange,
        # split synced/sent back into segment-shaped pieces
        if not selected:
            return None, None
        flat = jnp.concatenate([x.reshape(-1) for x in g_slices])
        synced_flat, sent_flat = self.wire.execute_bucket(
            flat, key, axis_names
        )
        return (
            _split_like(g_slices, synced_flat),
            _split_like(g_slices, sent_flat),
        )

    def execute_leaf_one(self, leaf_idx: int, t, q, axis_names):
        """Granular leaf path (LowRank/PowerSGD): sync one compensated leaf
        -> ``(approx, new_q)``."""
        return self.wire.execute_leaf(t, q, axis_names)

    # ---- whole-tree execute paths, rebuilt on the granular API ------------
    def _execute_segmented_arena(self, schedule, grads, state, step, axis_names):
        """Arena form of :meth:`_execute_segmented`: ONE pack pass writes
        every selected bucket's compensated, wire-cast payload into its
        static slot (fused pack kernel where applicable), each bucket's
        collective runs over a contiguous slice view, and results scatter
        back through static-offset segment writes — no per-bucket
        ``concatenate`` rebuilds, no ``dynamic_slice_in_dim`` chains.
        Unselected buckets never touch the arena: their residual update is
        the same fused pack pass with the wire write elided."""
        plan = schedule.plan
        ef_on = self.ef is not None and _state_present(state)
        coeff = self.ef_coefficient(step) if ef_on else None

        treedef = jax.tree_util.tree_structure(grads)
        leaves = jax.tree_util.tree_leaves(grads)
        r_leaves = jax.tree_util.tree_leaves(state) if ef_on else None

        sel = dict.fromkeys(schedule.selected)  # unique, order kept
        wd = self.wire.wire_dtype if isinstance(self.wire, WireCast) else None
        sharded = schedule.sync == "sharded"
        W = 1
        if sharded:
            for a in axis_names:
                W *= axis_size(a)
        layout = ar.build_layout(plan, sel, wire_dtype=wd, align=W)

        # ---- pack pass: one streaming traversal of the gradient ----------
        wire_pieces: dict[int, list] = {}
        resid_pieces: dict[int, list] = {}
        todo = range(plan.num_buckets) if ef_on else sel
        for b in todo:
            selected = b in sel
            pieces, rps = [], []
            for seg in plan.buckets[b].segments:
                g = bk._slice_segment(leaves[seg.leaf_idx], seg)
                r = (
                    bk._slice_segment(r_leaves[seg.leaf_idx], seg)
                    if ef_on else None
                )
                w, rnew = self._pack_segment(g, r, coeff, selected=selected)
                pieces.append(w)
                rps.append(rnew)
            if selected:
                wire_pieces[b] = pieces
            if ef_on:
                resid_pieces[b] = rps
        planes = layout.assemble(wire_pieces)

        # ---- wire pass: one collective per bucket, over a slice view -----
        # (sharded: reduce-scatter the W-aligned slot instead of an
        # all-reduce; the unpacked pieces carry zeros off the owned shard)
        # named_scope per bucket: metadata-only labels so XLA/Perfetto
        # profiles attribute each slot collective to its bucket
        synced_pieces = {}
        for b in sel:
            with jax.named_scope(
                f"covap_arena_bucket_{b}/phase_{schedule.phase}"
            ):
                slot = layout.bucket_view(planes, b)
                wired = (
                    self._reduce_scatter_slot(slot, axis_names)
                    if sharded
                    else pmean(slot, axis_names)
                )
                synced_pieces[b] = layout.unpack_bucket(b, wired)

        # ---- reassembly: one concat per leaf, no update-slice chains -----
        out_leaves = ar.gather_leaves(
            plan,
            lambda b, si, seg: (
                synced_pieces[b][si] if b in synced_pieces else None
            ),
            leaves,
        )
        out = jax.tree_util.tree_unflatten(treedef, out_leaves)
        if ef_on:
            resid_leaves = ar.gather_leaves(
                plan, lambda b, si, seg: resid_pieces[b][si], leaves
            )
            new_state = jax.tree_util.tree_unflatten(treedef, resid_leaves)
        else:
            new_state = state
        return out, new_state

    def _execute_segmented(self, schedule, grads, state, step, axis_names):
        """Sharding-preserving path (COVAP / dense): per-segment slices,
        zero gather/scatter copies for the common whole-leaf case.  With EF
        on, every bucket (selected or not) flows through
        :meth:`execute_bucket` so the residual update fuses with the
        compensation (ef_covap kernel)."""
        if self._arena_on():
            return self._execute_segmented_arena(
                schedule, grads, state, step, axis_names
            )
        plan = schedule.plan
        ef_on = self.ef is not None and _state_present(state)
        coeff = self.ef_coefficient(step) if ef_on else None

        treedef = jax.tree_util.tree_structure(grads)
        leaves = jax.tree_util.tree_leaves(grads)
        r_leaves = jax.tree_util.tree_leaves(state) if ef_on else None
        out_leaves = [jnp.zeros(l.shape, l.dtype) for l in leaves]
        resid_leaves = (
            [jnp.zeros(l.shape, l.dtype) for l in leaves] if ef_on else None
        )

        todo = (
            range(plan.num_buckets) if ef_on
            else dict.fromkeys(schedule.selected)  # unique, order kept
        )
        for b in todo:
            segs = plan.buckets[b].segments
            g_slices = [
                bk._slice_segment(leaves[s.leaf_idx], s) for s in segs
            ]
            r_slices = (
                [bk._slice_segment(r_leaves[s.leaf_idx], s) for s in segs]
                if ef_on else None
            )
            with jax.named_scope(
                f"covap_bucket_{b}/phase_{schedule.phase}"
            ):
                synced, resids = self.execute_bucket(
                    schedule, b, g_slices, r_slices,
                    coeff=coeff, axis_names=axis_names,
                )
            if synced is not None:
                for seg, xm in zip(segs, synced):
                    out_leaves[seg.leaf_idx] = bk._update_segment(
                        out_leaves[seg.leaf_idx], seg, xm
                    )
            if ef_on and resids is not None:
                for seg, rr in zip(segs, resids):
                    resid_leaves[seg.leaf_idx] = bk._update_segment(
                        resid_leaves[seg.leaf_idx], seg, rr
                    )

        out = jax.tree_util.tree_unflatten(treedef, out_leaves)
        new_state = (
            jax.tree_util.tree_unflatten(treedef, resid_leaves)
            if ef_on
            else state
        )
        return out, new_state

    def _execute_flat_arena(self, schedule, grads, state, step, axis_names):
        """Arena form of :meth:`_execute_flat`: the compensated gradient is
        packed ONCE into per-dtype planes (static offsets, the exact
        element order ``gather_bucket`` produces), each selected bucket's
        wire stage consumes a static slice view, and synced/sent values
        return through static-slice unpacks — bitwise-identical to the
        concat/``_split_like`` path for every flat wire."""
        plan = schedule.plan
        ef_on = self.ef is not None and _state_present(state)
        t = self.ef.compensated(grads, state, step) if ef_on else grads

        treedef = jax.tree_util.tree_structure(t)
        leaves = jax.tree_util.tree_leaves(t)

        sel = dict.fromkeys(schedule.selected)  # unique, order kept
        layout = ar.build_layout(plan, sel)
        planes = ar.pack_leaves(layout, leaves)

        base_key = jax.random.PRNGKey(self.seed)
        base_key = jax.random.fold_in(base_key, jnp.asarray(step, jnp.int32))
        synced_pieces: dict[int, list] = {}
        sent_pieces: dict[int, list] = {}
        for b in sel:
            key = jax.random.fold_in(base_key, plan.buckets[b].index)
            synced_flat, sent_flat = self.wire.execute_bucket(
                layout.bucket_view(planes, b), key, axis_names
            )
            synced_pieces[b] = layout.unpack_bucket(b, synced_flat)
            if ef_on:
                sent_pieces[b] = layout.unpack_bucket(b, sent_flat)
        out_leaves = ar.gather_leaves(
            plan,
            lambda b, si, seg: (
                synced_pieces[b][si] if b in synced_pieces else None
            ),
            leaves,
        )
        out = jax.tree_util.tree_unflatten(treedef, out_leaves)
        if ef_on:
            sent_leaves = ar.gather_leaves(
                plan,
                lambda b, si, seg: (
                    sent_pieces[b][si] if b in sent_pieces else None
                ),
                leaves,
            )
            new_state = jax.tree.map(
                lambda a, b: a - b,
                jax.tree_util.tree_unflatten(treedef, leaves),
                jax.tree_util.tree_unflatten(treedef, sent_leaves),
            )
        else:
            new_state = state
        return out, new_state

    def _execute_flat(self, schedule, grads, state, step, axis_names):
        """Flat-bucket path (sparsifiers / sign / fp8): gather each selected
        bucket to a vector, run the wire stage, scatter back; classic EF
        residual' = t - sent_local."""
        if self._arena_on():
            return self._execute_flat_arena(
                schedule, grads, state, step, axis_names
            )
        plan = schedule.plan
        ef_on = self.ef is not None and _state_present(state)
        t = self.ef.compensated(grads, state, step) if ef_on else grads

        treedef = jax.tree_util.tree_structure(t)
        leaves = jax.tree_util.tree_leaves(t)
        out_leaves = [jnp.zeros(l.shape, l.dtype) for l in leaves]
        sent_leaves = [jnp.zeros(l.shape, l.dtype) for l in leaves]

        base_key = jax.random.PRNGKey(self.seed)
        base_key = jax.random.fold_in(base_key, jnp.asarray(step, jnp.int32))
        for b in dict.fromkeys(schedule.selected):  # unique, order kept
            bucket = plan.buckets[b]
            segs = bucket.segments
            g_slices = [
                bk._slice_segment(leaves[s.leaf_idx], s) for s in segs
            ]
            key = jax.random.fold_in(base_key, bucket.index)
            synced, sent = self.execute_bucket(
                schedule, b, g_slices,
                coeff=None, key=key, axis_names=axis_names,
            )
            for seg, xm, sv in zip(segs, synced, sent):
                out_leaves[seg.leaf_idx] = bk._update_segment(
                    out_leaves[seg.leaf_idx], seg, xm
                )
                if ef_on:
                    sent_leaves[seg.leaf_idx] = bk._update_segment(
                        sent_leaves[seg.leaf_idx], seg, sv
                    )
        out = jax.tree_util.tree_unflatten(treedef, out_leaves)
        if ef_on:
            new_state = jax.tree.map(
                lambda a, b: a - b,
                jax.tree_util.tree_unflatten(treedef, leaves),
                jax.tree_util.tree_unflatten(treedef, sent_leaves),
            )
        else:
            new_state = state
        return out, new_state

    def _execute_leaf(self, grads, state, axis_names):
        """Leaf-granularity path (LowRank/PowerSGD): EF folded into the
        per-leaf loop; residual' = t - global approximation."""
        treedef = jax.tree_util.tree_structure(grads)
        leaves = jax.tree_util.tree_leaves(grads)
        qs, resid = state["q"], state["residual"]
        out_leaves, new_qs, new_resid = [], [], []
        for li, (leaf, q, r) in enumerate(zip(leaves, qs, resid)):
            t = leaf + r.astype(leaf.dtype) if r is not None else leaf
            approx, qn = self.execute_leaf_one(li, t, q, axis_names)
            out_leaves.append(approx)
            new_qs.append(qn)
            if r is not None:
                new_resid.append(
                    jnp.zeros_like(t) if qn is None else t - approx
                )
            else:
                new_resid.append(None)
        out = jax.tree_util.tree_unflatten(treedef, out_leaves)
        return out, {"q": new_qs, "residual": new_resid}


__all__ = [
    "CoarseFilter",
    "ErrorFeedback",
    "WireStage",
    "WireCast",
    "TopK",
    "RandomK",
    "SignCompress",
    "FP8Block",
    "OkTopKRoute",
    "LowRank",
    "SyncPipeline",
]
