"""COVAP: the paper's contribution (SS III.A-D), as a stage composition.

COVAP is exactly ``CoarseFilter(I) ∘ ErrorFeedback(EFSchedule) ∘ WireCast``
under the :class:`~repro.core.stages.SyncPipeline` combinator.  Per step
with phase ``p = step % I``:

  1. ``t = g + coeff(step) * residual``           (error feedback, SS III.D)
  2. buckets with ``(b + p) % I == 0`` are all-reduced **segment-by-segment**
     (sharding-preserving slices, zero gather/scatter copies for the common
     whole-leaf case) — everything else is *not communicated at all*
  3. ``residual' = t`` at unselected positions, ``0`` at selected ones

The bucket selection is static per phase — ``plan_phase`` returns the full
``CommSchedule`` (selected buckets, wire dtype, exact bytes per worker)
without tracing, and the compiled executable for a phase contains only that
phase's collectives: the volume compression is visible in HLO, not
simulated.  Compression cost is the elementwise EF update only — the
"near-zero overhead" property.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..error_feedback import EFSchedule
from ..stages import CoarseFilter, ErrorFeedback, SyncPipeline, WireCast
from .base import register


@register("covap")
class COVAP(SyncPipeline):
    def __init__(
        self,
        interval: int = 4,
        ef: bool = True,
        ef_init: float = 0.3,
        ef_ascend_steps: int = 200,
        ef_ascend_range: float = 0.1,
        wire_dtype: str = "",
        use_ef_kernel: bool | None = None,
        **opts,
    ):
        """``wire_dtype='bfloat16'`` additionally halves the wire volume of
        the selected buckets (beyond-paper: composes 2x with the filter's
        Ix; quantisation error lands in the EF residual).

        ``use_ef_kernel`` selects the fused Pallas EF-update kernel on the
        segmented execute path (``None`` = auto: on for TPU, off for CPU
        interpret mode whose FMA rounding differs bitwise from the jnp
        reference — see ``SyncPipeline._use_ef_kernel``)."""
        interval = int(interval)
        schedule = EFSchedule(ef_init, ef_ascend_steps, ef_ascend_range)
        # interval <= 1 (CCR <= 1): no filter, no EF state — but an
        # explicitly requested wire cast is still honored
        filtered = interval > 1
        super().__init__(
            wire=WireCast(wire_dtype or None),
            filter=CoarseFilter(interval) if filtered else None,
            ef=ErrorFeedback(schedule) if (ef and filtered) else None,
            interval=interval,
            ef_flag=bool(ef),
            wire_dtype=wire_dtype,
            use_ef_kernel=use_ef_kernel,
            **opts,
        )
        self.interval = interval
        self.use_ef = bool(ef)
        self.wire_dtype = jnp.dtype(wire_dtype) if wire_dtype else None
        self.schedule = schedule
