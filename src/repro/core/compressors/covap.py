"""COVAP: the paper's contribution (SS III.A-D), as a composable compressor.

Per step with phase ``p = step % I``:

  1. ``t = g + coeff(step) * residual``           (error feedback, SS III.D)
  2. buckets with ``(b + p) % I == 0`` are all-reduced **segment-by-segment**
     (sharding-preserving slices, zero gather/scatter copies for the common
     whole-leaf case) — everything else is *not communicated at all*
  3. ``residual' = t`` at unselected positions, ``0`` at selected ones

The bucket selection is static per phase, so the compiled executable for a
phase contains only the collectives of that phase's buckets: the volume
compression is visible in HLO, not simulated.  Compression cost is the
elementwise EF update only — the "near-zero overhead" property.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from .. import bucketing as bk
from ..bucketing import BucketPlan
from ..error_feedback import EFSchedule, compensate, init_residual
from ..filter import selected_buckets
from .base import Compressor, SyncStats, dense_bytes, pmean, register


@register("covap")
class COVAP(Compressor):
    def __init__(
        self,
        interval: int = 4,
        ef: bool = True,
        ef_init: float = 0.3,
        ef_ascend_steps: int = 200,
        ef_ascend_range: float = 0.1,
        wire_dtype: str = "",
    ):
        """``wire_dtype='bfloat16'`` additionally halves the wire volume of
        the selected buckets (beyond-paper: composes 2x with the filter's
        Ix; quantisation error lands in the EF residual)."""
        super().__init__(interval=interval, ef=ef, wire_dtype=wire_dtype)
        self.interval = int(interval)
        self.use_ef = bool(ef)
        self.wire_dtype = jnp.dtype(wire_dtype) if wire_dtype else None
        self.schedule = EFSchedule(ef_init, ef_ascend_steps, ef_ascend_range)

    def num_phases(self, interval: int | None = None) -> int:
        return self.interval if self.interval > 1 else 1

    def init_state(self, params_like: Any, plan: BucketPlan) -> Any:
        if not self.use_ef or self.interval <= 1:
            return ()
        return init_residual(params_like)

    def sync(
        self,
        grads: Any,
        state: Any,
        *,
        plan: BucketPlan,
        phase: int,
        step,
        axis_names: Sequence[str] = (),
    ):
        interval = self.interval
        if interval <= 1:
            # degenerate case (CCR <= 1): plain per-bucket all-reduce
            leaves = jax.tree_util.tree_leaves(grads)
            out = [pmean(l, axis_names) for l in leaves]
            tree = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(grads), out
            )
            d = dense_bytes(plan)
            return tree, state, SyncStats(d, d)

        ef_on = self.use_ef and state != ()
        if ef_on:
            coeff = self.schedule.coefficient(step)
            t = compensate(grads, state, coeff)
        else:
            t = grads

        treedef = jax.tree_util.tree_structure(t)
        leaves = jax.tree_util.tree_leaves(t)
        out_leaves = [jnp.zeros(l.shape, l.dtype) for l in leaves]
        resid_leaves = list(leaves) if ef_on else None

        sel = selected_buckets(plan.num_buckets, phase, interval)
        sent_bytes = 0
        for b in sel:
            bucket = plan.buckets[b]
            for seg in bucket.segments:
                li = seg.leaf_idx
                x = bk._slice_segment(leaves[li], seg)
                if self.wire_dtype is not None and x.dtype != self.wire_dtype:
                    xw = x.astype(self.wire_dtype)
                    xm = pmean(xw, axis_names).astype(x.dtype)
                    sent_bytes += x.size * self.wire_dtype.itemsize
                    if ef_on:
                        # quantisation error stays in the residual
                        resid_leaves[li] = bk._update_segment(
                            resid_leaves[li], seg, x - xw.astype(x.dtype)
                        )
                else:
                    xm = pmean(x, axis_names)
                    sent_bytes += x.size * x.dtype.itemsize
                    if ef_on:
                        resid_leaves[li] = bk._update_segment(
                            resid_leaves[li], seg, jnp.zeros_like(x)
                        )
                out_leaves[li] = bk._update_segment(out_leaves[li], seg, xm)

        out = jax.tree_util.tree_unflatten(treedef, out_leaves)
        new_state = (
            jax.tree_util.tree_unflatten(treedef, resid_leaves) if ef_on else state
        )
        return out, new_state, SyncStats(sent_bytes, dense_bytes(plan))
