"""GC scheme registry: COVAP + every baseline from the paper's Table II."""
from .base import (
    Compressor,
    SyncStats,
    available,
    dense_bytes,
    get_compressor,
    register,
)
from .covap import COVAP
from .fp8wire import FP8Wire
from .oktopk import OkTopK
from .powersgd import PowerSGD
from .signsgd import EFSignSGD
from .simple import HalfPrecision, NoCompression
from .sparsify import DGC, RandomK, TopK

__all__ = [
    "Compressor",
    "SyncStats",
    "available",
    "dense_bytes",
    "get_compressor",
    "register",
    "COVAP",
    "NoCompression",
    "HalfPrecision",
    "TopK",
    "DGC",
    "RandomK",
    "EFSignSGD",
    "PowerSGD",
    "OkTopK",
]
