"""EFsignSGD (Karimireddy et al. [11]): sign compression with error feedback.

``SyncPipeline(ef=ErrorFeedback(), wire=SignCompress())``.  Wire format per
bucket: int8 signs (1 byte/elem, 4x vs fp32) + one fp32 scale = mean(|t|).
Workers' signs differ, so the exchange is an all-gather (the paper's Fig. 11
notes AllGather-based schemes scale worse — reproduced here structurally).
Decode: mean_w(scale_w * sign_w).
"""
from __future__ import annotations

from ..stages import ErrorFeedback, SignCompress, SyncPipeline
from .base import register


@register("efsignsgd")
class EFSignSGD(SyncPipeline):
    def __init__(self, seed: int = 0, ef: bool = True, **opts):
        super().__init__(
            wire=SignCompress(),
            ef=ErrorFeedback() if ef else None,
            seed=seed,
            **opts,
        )
        self.use_ef = ef
