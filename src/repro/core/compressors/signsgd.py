"""EFsignSGD (Karimireddy et al. [11]): sign compression with error feedback.

Wire format per bucket: int8 signs (1 byte/elem, 4x vs fp32) + one fp32
scale = mean(|t|).  Workers' signs differ, so the exchange is an all-gather
(the paper's Fig. 11 notes AllGather-based schemes scale worse — reproduced
here structurally).  Decode: mean_w(scale_w * sign_w).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import SyncStats, all_gather, register
from .sparsify import _BucketEFCompressor


@register("efsignsgd")
class EFSignSGD(_BucketEFCompressor):
    def __init__(self, seed: int = 0, ef: bool = True):
        super().__init__(seed=seed)
        self.use_ef = ef

    def _bucket_sync(self, flat, key, axis_names):
        n = flat.shape[0]
        scale = jnp.mean(jnp.abs(flat))
        signs = jnp.where(flat >= 0, 1, -1).astype(jnp.int8)
        signs_all = all_gather(signs, axis_names)          # (W, n) int8
        scales_all = all_gather(scale[None], axis_names)   # (W, 1)
        W = signs_all.shape[0]
        decoded = (
            signs_all.astype(flat.dtype) * scales_all.astype(flat.dtype)
        ).mean(axis=0)
        local_sent = scale * signs.astype(flat.dtype)
        return decoded, local_sent, n * 1 + 4
