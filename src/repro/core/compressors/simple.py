"""Baseline compressors: no-compression DDP and FP16/BF16 quantization.

``none``  — per-bucket dense all-reduce (= DDPovlp, the paper's baseline):
            ``SyncPipeline(wire=WireCast(None))``; one psum per bucket gives
            the latency-hiding scheduler the same overlap units DDP's bucket
            hooks give NCCL.
``fp16``  — cast-to-half on the wire, all-reduce, cast back (Table II row
            FP16): ``SyncPipeline(wire=WireCast('bfloat16'))``; on TPU
            ``bf16`` is the native half type; the wire format is selectable.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..stages import SyncPipeline, WireCast
from .base import register


@register("none")
class NoCompression(SyncPipeline):
    def __init__(self, per_bucket: bool = True, **opts):
        super().__init__(wire=WireCast(None), per_bucket=per_bucket, **opts)
        self.per_bucket = per_bucket


@register("fp16")
class HalfPrecision(SyncPipeline):
    def __init__(self, wire_dtype: str = "bfloat16", **opts):
        super().__init__(wire=WireCast(wire_dtype), wire_dtype=wire_dtype,
                         **opts)
        self.wire_dtype = jnp.dtype(wire_dtype)
