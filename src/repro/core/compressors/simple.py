"""Baseline compressors: no-compression DDP and FP16/BF16 quantization.

``none``  — per-bucket dense all-reduce (= DDPovlp, the paper's baseline);
            one psum per bucket gives the latency-hiding scheduler the same
            overlap units DDP's bucket hooks give NCCL.
``fp16``  — cast-to-half, all-reduce in half precision, cast back (Table II
            row FP16).  On TPU ``bf16`` is the native half type; the wire
            format is selectable.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from ..bucketing import BucketPlan
from .base import Compressor, SyncStats, dense_bytes, pmean, register


@register("none")
class NoCompression(Compressor):
    def __init__(self, per_bucket: bool = True):
        super().__init__(per_bucket=per_bucket)
        self.per_bucket = per_bucket

    def sync(self, grads, state, *, plan, phase, step, axis_names=()):
        leaves = jax.tree_util.tree_leaves(grads)
        out = [pmean(l, axis_names) for l in leaves]
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(grads), out
        )
        d = dense_bytes(plan)
        return tree, state, SyncStats(d, d)


@register("fp16")
class HalfPrecision(Compressor):
    def __init__(self, wire_dtype: str = "bfloat16"):
        super().__init__(wire_dtype=wire_dtype)
        self.wire_dtype = jnp.dtype(wire_dtype)

    def sync(self, grads, state, *, plan, phase, step, axis_names=()):
        def one(l):
            lo = l.astype(self.wire_dtype)
            lo = pmean(lo, axis_names)
            return lo.astype(l.dtype)

        out = jax.tree.map(one, grads)
        d = dense_bytes(plan)
        itemsize = jnp.dtype(self.wire_dtype).itemsize
        sent = sum(b.numel * itemsize for b in plan.buckets)
        return out, state, SyncStats(sent, d)
