"""Beyond-paper compressor: block-scaled FP8 gradient exchange.

4x wire compression (vs fp32) with per-8192-block amax scaling — far better
fidelity than naive fp16 casting at 2x the compression.  Workers' payloads
differ, so the exchange is an all-gather of (fp8 payload, fp32 scales),
decoded as the mean of the dequantised contributions.  With error feedback
the quantisation error is folded into the residual.

The hot path (amax + scale + cast in one HBM pass) is the
``kernels/quantize.py`` Pallas kernel; inside the traced train step the
mathematically-identical jnp formulation is used (kernels/ref.py), keeping
the compressor backend-agnostic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...kernels import ref as kref
from .base import SyncStats, all_gather, register
from .sparsify import _BucketEFCompressor


@register("fp8wire")
class FP8Wire(_BucketEFCompressor):
    def __init__(self, block: int = 8192, seed: int = 0, ef: bool = True):
        super().__init__(block=block, seed=seed)
        self.block = int(block)
        self.use_ef = ef

    def _bucket_sync(self, flat, key, axis_names):
        n = flat.shape[0]
        q, scales = kref.quantize_fp8_ref(flat, block=self.block)
        q_all = all_gather(q, axis_names)            # (W, n) fp8
        s_all = all_gather(scales, axis_names)       # (W, nb)
        W = q_all.shape[0]
        dec = jnp.stack(
            [
                kref.dequantize_fp8_ref(q_all[w], s_all[w], block=self.block)
                for w in range(W)
            ]
        ).mean(axis=0).astype(flat.dtype)
        local_sent = kref.dequantize_fp8_ref(q, scales, block=self.block).astype(
            flat.dtype
        )
        nbytes = n * 1 + scales.shape[0] * 4
        return dec, local_sent, nbytes
