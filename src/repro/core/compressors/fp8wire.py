"""Beyond-paper compressor: block-scaled FP8 gradient exchange.

``SyncPipeline(ef=ErrorFeedback(), wire=FP8Block(block))``: 4x wire
compression (vs fp32) with per-8192-block amax scaling — far better
fidelity than naive fp16 casting at 2x the compression.  Workers' payloads
differ, so the exchange is an all-gather of (fp8 payload, fp32 scales),
decoded as the mean of the dequantised contributions.  With error feedback
the quantisation error is folded into the residual.

The hot path (amax + scale + cast in one HBM pass) is the
``kernels/quantize.py`` Pallas kernel; inside the traced train step the
mathematically-identical jnp formulation is used (kernels/ref.py), keeping
the compressor backend-agnostic.
"""
from __future__ import annotations

from ..stages import ErrorFeedback, FP8Block, SyncPipeline
from .base import register


@register("fp8wire")
class FP8Wire(SyncPipeline):
    def __init__(self, block: int = 8192, seed: int = 0, ef: bool = True,
                 **opts):
        super().__init__(
            wire=FP8Block(block),
            ef=ErrorFeedback() if ef else None,
            seed=seed,
            block=block,
            **opts,
        )
        self.block = int(block)
        self.use_ef = ef
