"""Compressor interface + registry — re-exported from ``repro.core.comm``.

The primitives (``Compressor``, ``SyncStats``, the registry, and the
manual-collective helpers) live in :mod:`repro.core.comm` so that
:mod:`repro.core.stages` can build on them without a circular import
through this package.  This module keeps the historical import surface
(``repro.core.compressors.base``) stable.
"""
from __future__ import annotations

from ..comm import (  # noqa: F401
    Compressor,
    SyncStats,
    _promote_bf16,
    all_gather,
    available,
    dense_bytes,
    get_compressor,
    pmean,
    psum,
    register,
    world_size,
)

__all__ = [
    "Compressor",
    "SyncStats",
    "all_gather",
    "available",
    "dense_bytes",
    "get_compressor",
    "pmean",
    "psum",
    "register",
    "world_size",
]
