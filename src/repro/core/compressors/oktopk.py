"""Ok-topk (Li & Hoefler [13]): near-optimal sparse all-reduce, simplified.

``SyncPipeline(ef=ErrorFeedback(), wire=OkTopKRoute(ratio))``.  The
reference scheme partitions the index space into per-worker *regions*;
each worker (1) selects its local top-k, (2) routes entries to their region
owner via all-to-all with a fixed capacity, (3) the owner reduces and keeps
the regional top-(k/W), and (4) the survivors are all-gathered.  Traffic is
O(k) instead of Top-k's O(k * W) — but the exchange is synchronous and
multi-stage, which is exactly the *data dependency* the paper (SS I, Fig 1e)
identifies as hostile to overlapping.

Simplifications vs. the reference (noted for fidelity): fixed all-to-all
capacity 2k/W with magnitude-ordered overflow drop, and EF counts an entry
as "sent" once routed (region-level drops land in the error term rather
than the residual).  The planned byte accounting counts the routing mask at
its true wire width (the bucket dtype) so ``CommSchedule.bytes_per_worker``
matches the HLO collectives bit-for-bit.
"""
from __future__ import annotations

from ..stages import ErrorFeedback, OkTopKRoute, SyncPipeline
from .base import register


@register("oktopk")
class OkTopK(SyncPipeline):
    def __init__(self, ratio: float = 0.01, seed: int = 0, ef: bool = True,
                 **opts):
        super().__init__(
            wire=OkTopKRoute(ratio),
            ef=ErrorFeedback() if ef else None,
            seed=seed,
            ratio=ratio,
            **opts,
        )
        self.ratio = float(ratio)
        self.use_ef = ef
