"""Ok-topk (Li & Hoefler [13]): near-optimal sparse all-reduce, simplified.

The reference scheme partitions the index space into per-worker *regions*;
each worker (1) selects its local top-k, (2) routes entries to their region
owner via all-to-all with a fixed capacity, (3) the owner reduces and keeps
the regional top-(k/W), and (4) the survivors are all-gathered.  Traffic is
O(k) instead of Top-k's O(k * W) — but the exchange is synchronous and
multi-stage, which is exactly the *data dependency* the paper (SS I, Fig 1e)
identifies as hostile to overlapping.

Simplifications vs. the reference (noted for fidelity): fixed all-to-all
capacity 2k/W with magnitude-ordered overflow drop, and EF counts an entry
as "sent" once routed (region-level drops land in the error term rather
than the residual).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from .base import SyncStats, all_gather, register
from .sparsify import _BucketEFCompressor


def _flat_axis_index(axis_names):
    idx = lax.axis_index(axis_names[0])
    for ax in axis_names[1:]:
        idx = idx * lax.axis_size(ax) + lax.axis_index(ax)
    return idx


def _all_to_all(x, axis_names):
    """all-to-all over (possibly multiple) named axes; x: (W, ...)."""
    if len(axis_names) == 1:
        return lax.all_to_all(x, axis_names[0], split_axis=0, concat_axis=0)
    return lax.all_to_all(x, tuple(axis_names), split_axis=0, concat_axis=0)


@register("oktopk")
class OkTopK(_BucketEFCompressor):
    def __init__(self, ratio: float = 0.01, seed: int = 0, ef: bool = True):
        super().__init__(ratio=ratio, seed=seed)
        self.ratio = float(ratio)
        self.use_ef = ef

    def _bucket_sync(self, flat, key, axis_names):
        n = flat.shape[0]
        itemsize = jnp.dtype(flat.dtype).itemsize
        if not axis_names:
            # single worker: reduces to local top-k
            m = max(1, int(math.ceil(n * self.ratio)))
            _, idx = lax.top_k(jnp.abs(flat), m)
            vals = flat[idx]
            out = jnp.zeros(n, flat.dtype).at[idx].set(vals)
            return out, out, m * (itemsize + 4)

        W = int(lax.axis_size(axis_names[0]))
        for ax in axis_names[1:]:
            W *= int(lax.axis_size(ax))
        m = max(W, int(math.ceil(n * self.ratio)))
        m = int(math.ceil(m / W) * W)
        region_size = int(math.ceil(n / W))
        n_pad = region_size * W
        cap = min(2 * m // W + 1, region_size)

        _, idx = lax.top_k(jnp.abs(flat), m)
        vals = flat[idx]
        region = idx // region_size  # (m,) destination worker

        # position of each entry within its destination's capacity window
        onehot = (region[:, None] == jnp.arange(W)[None, :]).astype(jnp.int32)
        pos = (jnp.cumsum(onehot, axis=0) - 1)[jnp.arange(m), region]

        send_vals = jnp.zeros((W, cap), flat.dtype).at[region, pos].set(
            vals, mode="drop"
        )
        send_idx = jnp.zeros((W, cap), jnp.int32).at[region, pos].set(
            (idx - region * region_size).astype(jnp.int32), mode="drop"
        )
        send_mask = jnp.zeros((W, cap), flat.dtype).at[region, pos].set(
            1.0, mode="drop"
        )

        recv_vals = _all_to_all(send_vals, axis_names)
        recv_idx = _all_to_all(send_idx, axis_names)
        recv_mask = _all_to_all(send_mask, axis_names)

        dense = jnp.zeros(region_size, flat.dtype).at[recv_idx.reshape(-1)].add(
            (recv_vals * recv_mask).reshape(-1)
        )
        k_r = m // W
        _, ridx = lax.top_k(jnp.abs(dense), k_r)
        rvals = dense[ridx]
        offset = _flat_axis_index(tuple(axis_names)) * region_size
        gidx = ridx + offset

        vals_all = all_gather(rvals, axis_names).reshape(-1)
        gidx_all = all_gather(gidx, axis_names).reshape(-1)
        out = jnp.zeros(n_pad, flat.dtype).at[gidx_all].set(vals_all) / W
        out = out[:n]

        kept = pos < cap
        local_sent = jnp.zeros(n, flat.dtype).at[idx].set(
            jnp.where(kept, vals, 0.0)
        )
        nbytes = W * cap * (itemsize + 4 + 1) + k_r * (itemsize + 4)
        return out, local_sent, nbytes
