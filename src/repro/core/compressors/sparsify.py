"""Sparsification baselines: Top-k [3], Random-k [23], DGC [16].

All three operate per communication bucket on the flat gradient vector,
carry classic error feedback (residual accumulation, coefficient 1), and use
the collective pattern of their reference implementations:

* Top-k / DGC: worker-local indices differ -> all-gather of (values, indices).
* Random-k: the index set is derived from a PRNG key shared by construction
  (seed, step, bucket) -> identical on every worker -> a dense psum over the
  selected values only, no index exchange.
"""
from __future__ import annotations

import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from .. import bucketing as bk
from ..bucketing import BucketPlan
from .base import Compressor, SyncStats, all_gather, dense_bytes, pmean, register


class _BucketEFCompressor(Compressor):
    """Shared scaffolding: EF + per-bucket gather/compress/scatter."""

    use_ef = True

    def init_state(self, params_like: Any, plan: BucketPlan) -> Any:
        if not self.use_ef:
            return ()
        return jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params_like)

    def _bucket_sync(self, flat, key, axis_names):
        """-> (synced_flat, local_sent_flat, bytes_per_worker)"""
        raise NotImplementedError

    def sync(self, grads, state, *, plan, phase, step, axis_names=()):
        ef_on = self.use_ef and state != ()
        if ef_on:
            t = jax.tree.map(lambda g, r: g + r.astype(g.dtype), grads, state)
        else:
            t = grads
        treedef = jax.tree_util.tree_structure(t)
        leaves = jax.tree_util.tree_leaves(t)
        out_leaves = [jnp.zeros(l.shape, l.dtype) for l in leaves]
        sent_leaves = [jnp.zeros(l.shape, l.dtype) for l in leaves]

        base_key = jax.random.PRNGKey(self.options.get("seed", 0))
        base_key = jax.random.fold_in(base_key, jnp.asarray(step, jnp.int32))
        total_sent = 0
        for bucket in plan.buckets:
            flat = bk.gather_bucket(plan, leaves, bucket)
            key = jax.random.fold_in(base_key, bucket.index)
            synced, local_sent, nbytes = self._bucket_sync(flat, key, axis_names)
            total_sent += nbytes
            out_leaves = bk.scatter_bucket(plan, out_leaves, bucket, synced)
            if ef_on:
                sent_leaves = bk.scatter_bucket(
                    plan, sent_leaves, bucket, local_sent
                )
        out = jax.tree_util.tree_unflatten(treedef, out_leaves)
        if ef_on:
            new_state = jax.tree.map(
                lambda a, b: a - b,
                jax.tree_util.tree_unflatten(treedef, leaves),
                jax.tree_util.tree_unflatten(treedef, sent_leaves),
            )
        else:
            new_state = state
        return out, new_state, SyncStats(total_sent, dense_bytes(plan))


@register("topk")
class TopK(_BucketEFCompressor):
    """Aji & Heafield sparse communication: largest-|g| k fraction."""

    def __init__(self, ratio: float = 0.01, seed: int = 0, ef: bool = True):
        super().__init__(ratio=ratio, seed=seed)
        self.ratio = float(ratio)
        self.use_ef = ef

    def _select(self, flat):
        n = flat.shape[0]
        m = max(1, int(math.ceil(n * self.ratio)))
        _, idx = jax.lax.top_k(jnp.abs(flat), m)
        return idx, flat[idx]

    def _bucket_sync(self, flat, key, axis_names):
        n = flat.shape[0]
        idx, vals = self._select(flat)
        m = idx.shape[0]
        vals_all = all_gather(vals, axis_names)  # (W, m)
        idx_all = all_gather(idx, axis_names)
        W = vals_all.shape[0]
        out = jnp.zeros(n, flat.dtype)
        out = out.at[idx_all.reshape(-1)].add(vals_all.reshape(-1)) / W
        local_sent = jnp.zeros(n, flat.dtype).at[idx].set(vals)
        itemsize = jnp.dtype(flat.dtype).itemsize
        return out, local_sent, m * (itemsize + 4)


@register("dgc")
class DGC(TopK):
    """Deep Gradient Compression: aggressive ratio (0.1%) + local gradient
    clipping before selection (momentum correction folded into EF)."""

    def __init__(
        self, ratio: float = 0.001, clip_norm: float = 1.0, seed: int = 0
    ):
        super().__init__(ratio=ratio, seed=seed)
        self.clip_norm = float(clip_norm)
        self.options["clip_norm"] = clip_norm

    def _bucket_sync(self, flat, key, axis_names):
        norm = jnp.linalg.norm(flat) + 1e-12
        scale = jnp.minimum(1.0, self.clip_norm / norm)
        return super()._bucket_sync(flat * scale, key, axis_names)


@register("randomk")
class RandomK(_BucketEFCompressor):
    """Stich et al. sparsified SGD: k uniformly random coordinates, shared
    PRNG -> dense psum of the selected values (no index traffic)."""

    def __init__(self, ratio: float = 0.01, seed: int = 0, ef: bool = True):
        super().__init__(ratio=ratio, seed=seed)
        self.ratio = float(ratio)
        self.use_ef = ef

    def _bucket_sync(self, flat, key, axis_names):
        n = flat.shape[0]
        m = max(1, int(math.ceil(n * self.ratio)))
        idx = jax.random.randint(key, (m,), 0, n)
        vals = flat[idx]
        synced = pmean(vals, axis_names)
        out = jnp.zeros(n, flat.dtype).at[idx].set(synced)
        local_sent = jnp.zeros(n, flat.dtype).at[idx].set(vals)
        itemsize = jnp.dtype(flat.dtype).itemsize
        return out, local_sent, m * itemsize
