"""Sparsification baselines: Top-k [3], Random-k [23], DGC [16].

All three are ``SyncPipeline(ef=ErrorFeedback(), wire=<stage>)`` with a
per-bucket wire stage from :mod:`repro.core.stages`, the classic EF rule
(residual accumulation, coefficient 1), and the collective pattern of their
reference implementations:

* Top-k / DGC: worker-local indices differ -> all-gather of (values, indices).
* Random-k: the index set is derived from a PRNG key shared by construction
  (seed, step, bucket) -> identical on every worker -> a dense psum over the
  selected values only, no index exchange.
"""
from __future__ import annotations

from .. import stages
from ..stages import ErrorFeedback, SyncPipeline
from .base import register


@register("topk")
class TopK(SyncPipeline):
    """Aji & Heafield sparse communication: largest-|g| k fraction."""

    def __init__(self, ratio: float = 0.01, seed: int = 0, ef: bool = True,
                 **opts):
        super().__init__(
            wire=stages.TopK(ratio),
            ef=ErrorFeedback() if ef else None,
            seed=seed,
            ratio=ratio,
            **opts,
        )
        self.ratio = float(ratio)
        self.use_ef = ef


@register("dgc")
class DGC(SyncPipeline):
    """Deep Gradient Compression: aggressive ratio (0.1%) + local gradient
    clipping before selection (momentum correction folded into EF)."""

    def __init__(
        self, ratio: float = 0.001, clip_norm: float = 1.0, seed: int = 0,
        **opts,
    ):
        super().__init__(
            wire=stages.TopK(ratio, clip_norm=clip_norm),
            ef=ErrorFeedback(),
            seed=seed,
            ratio=ratio,
            clip_norm=clip_norm,
            **opts,
        )
        self.ratio = float(ratio)
        self.clip_norm = float(clip_norm)
        self.use_ef = True


@register("randomk")
class RandomK(SyncPipeline):
    """Stich et al. sparsified SGD: k uniformly random coordinates, shared
    PRNG -> dense psum of the selected values (no index traffic)."""

    def __init__(self, ratio: float = 0.01, seed: int = 0, ef: bool = True,
                 **opts):
        super().__init__(
            wire=stages.RandomK(ratio),
            ef=ErrorFeedback() if ef else None,
            seed=seed,
            ratio=ratio,
            **opts,
        )
        self.ratio = float(ratio)
        self.use_ef = ef
