"""PowerSGD (Vogels et al. [26]): rank-r low-rank gradient compression.

``SyncPipeline(ef=ErrorFeedback(), wire=LowRank(rank))`` — the one
leaf-granularity pipeline.  Per >=2-D leaf (batched over any leading
stack/layer axes):

    M  = t reshaped to (B, a, b)
    P  = M @ Q        ; all-reduce(P) ; P <- orthonormalize(P)
    Q' = M^T @ P      ; all-reduce(Q')
    t~ = P @ Q'^T     ; residual = t - t~

State carries Q between steps (warm start — the power iteration).  1-D
leaves (biases, norms) are all-reduced densely, as in the reference
implementation.  Communication per matrix: (a + b) * r words instead of
a * b — all via AllReduce, which is why PowerSGD scales well in the paper's
Fig. 11 yet still loses to COVAP on compression overhead (two matmuls + QR).
"""
from __future__ import annotations

from ..stages import ErrorFeedback, LowRank, SyncPipeline
from .base import register


@register("powersgd")
class PowerSGD(SyncPipeline):
    def __init__(self, rank: int = 2, seed: int = 0, ef: bool = True,
                 **opts):
        super().__init__(
            wire=LowRank(rank, seed=seed),
            ef=ErrorFeedback() if ef else None,
            seed=seed,
            rank=rank,
            **opts,
        )
        self.rank = int(rank)
        self.use_ef = bool(ef)
