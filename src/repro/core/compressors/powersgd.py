"""PowerSGD (Vogels et al. [26]): rank-r low-rank gradient compression.

Per >=2-D leaf (batched over any leading stack/layer axes):

    M  = t reshaped to (B, a, b)
    P  = M @ Q        ; all-reduce(P) ; P <- orthonormalize(P)
    Q' = M^T @ P      ; all-reduce(Q')
    t~ = P @ Q'^T     ; residual = t - t~

State carries Q between steps (warm start — the power iteration).  1-D
leaves (biases, norms) are all-reduced densely, as in the reference
implementation.  Communication per matrix: (a + b) * r words instead of
a * b — all via AllReduce, which is why PowerSGD scales well in the paper's
Fig. 11 yet still loses to COVAP on compression overhead (two matmuls + QR).
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from ..bucketing import BucketPlan
from .base import Compressor, SyncStats, dense_bytes, pmean, register


def _as_batched_matrix(x: jax.Array) -> jax.Array:
    if x.ndim == 2:
        return x[None]
    return x.reshape((-1,) + x.shape[-2:])


@register("powersgd")
class PowerSGD(Compressor):
    def __init__(self, rank: int = 2, seed: int = 0, ef: bool = True):
        super().__init__(rank=rank, seed=seed)
        self.rank = int(rank)
        self.use_ef = bool(ef)

    def init_state(self, params_like: Any, plan: BucketPlan) -> Any:
        key = jax.random.PRNGKey(self.options.get("seed", 0))
        qs, resid = [], []
        for i, leaf in enumerate(jax.tree_util.tree_leaves(params_like)):
            if leaf.ndim >= 2:
                m = _as_batched_matrix(jnp.zeros(leaf.shape, leaf.dtype))
                b = m.shape[-1]
                k = jax.random.fold_in(key, i)
                qs.append(
                    jax.random.normal(k, (m.shape[0], b, self.rank), leaf.dtype)
                )
            else:
                qs.append(None)
            resid.append(jnp.zeros(leaf.shape, leaf.dtype) if self.use_ef else None)
        return {"q": qs, "residual": resid}

    def sync(self, grads, state, *, plan, phase, step, axis_names=()):
        treedef = jax.tree_util.tree_structure(grads)
        leaves = jax.tree_util.tree_leaves(grads)
        qs, resid = state["q"], state["residual"]
        out_leaves, new_qs, new_resid = [], [], []
        sent = 0
        itemsize = 4
        for leaf, q, r in zip(leaves, qs, resid):
            t = leaf + r.astype(leaf.dtype) if r is not None else leaf
            if q is None:
                out = pmean(t, axis_names)
                out_leaves.append(out)
                new_qs.append(None)
                new_resid.append(jnp.zeros_like(t) if r is not None else None)
                sent += t.size * itemsize
                continue
            m = _as_batched_matrix(t)
            p = pmean(jnp.einsum("bij,bjk->bik", m, q), axis_names)
            p, _ = jnp.linalg.qr(p)  # orthonormalize columns
            qn = pmean(jnp.einsum("bij,bik->bjk", m, p), axis_names)
            approx = jnp.einsum("bik,bjk->bij", p, qn).reshape(leaf.shape)
            out_leaves.append(approx)
            new_qs.append(qn)
            new_resid.append(t - approx if r is not None else None)
            B, a, b = m.shape
            sent += B * (a + b) * self.rank * itemsize
        out = jax.tree_util.tree_unflatten(treedef, out_leaves)
        return (
            out,
            {"q": new_qs, "residual": new_resid},
            SyncStats(sent, dense_bytes(plan)),
        )
