"""COVAP coarse-grained gradient filter (paper SS III.A).

Bucket ``t`` is communicated at iteration ``num_steps`` iff
``(t + num_steps) % I == 0``.  Every bucket is therefore communicated exactly
once per ``I`` consecutive iterations, and ~``num_buckets / I`` buckets are
communicated per iteration — a compression ratio of ``I`` with O(num_buckets)
selection cost and **no data dependency**: every worker derives the same
selection from ``(step, I)`` locally, no index exchange required.

On TPU/XLA the selection must be static inside a compiled graph, so the train
step is specialised on ``phase = step % I`` (``I`` compiled executables); see
DESIGN.md SS8.  ``selected_buckets`` is the single source of truth used both by
the runtime and by the tests proving schedule equivalence with the paper's
modulo rule.
"""
from __future__ import annotations

from .bucketing import BucketPlan


def is_selected(bucket_idx: int, step: int, interval: int) -> bool:
    """The paper's selection rule, verbatim."""
    if interval <= 1:
        return True
    return (bucket_idx + step) % interval == 0


def selected_buckets(num_buckets: int, phase: int, interval: int) -> tuple[int, ...]:
    """Indices of buckets communicated at any step with ``step % I == phase``."""
    if interval <= 1:
        return tuple(range(num_buckets))
    return tuple(
        b for b in range(num_buckets) if (b + phase) % interval == 0
    )


def selected_numel(plan: BucketPlan, phase: int, interval: int) -> int:
    sel = selected_buckets(plan.num_buckets, phase, interval)
    return sum(plan.buckets[b].numel for b in sel)


def compression_ratio(plan: BucketPlan, interval: int) -> float:
    """Average achieved volume-compression ratio over one full period."""
    if interval <= 1:
        return 1.0
    total = plan.total_numel()
    per_step = [
        selected_numel(plan, phase, interval) for phase in range(interval)
    ]
    avg = sum(per_step) / interval
    return total / max(avg, 1)


def schedule_table(num_buckets: int, interval: int, steps: int) -> list[list[int]]:
    """For visualisation/tests: bucket selections for ``steps`` iterations."""
    return [
        [b for b in range(num_buckets) if is_selected(b, s, interval)]
        for s in range(steps)
    ]
