"""Gradient bucketing + COVAP tensor sharding (paper SS III.A / SS III.C).

A ``BucketPlan`` partitions a gradient pytree into communication buckets, the
granularity at which COVAP's coarse-grained filter selects / skips collectives.

Design notes (TPU adaptation, see DESIGN.md SS2):

* Leaves may be *stacked* over a layer axis (scan-over-layers models), so the
  packing granularity is a **row** = one slice along ``axis 0`` of a leaf
  (= one layer's tensor), mirroring DDP's "never split a variable" rule at
  layer granularity.
* Tensor sharding (SS III.C) splits oversized buckets.  Splits happen along a
  per-leaf ``sub_axis`` chosen to avoid tensor-parallel sharded axes so a
  segment slice never forces a resharding collective on the 'model' mesh axis.
* The DDP default bucket size is 25 MB (paper SS III.A).  On a 256-chip ICI
  domain the efficient message size is far larger than on 30 Gbps Ethernet,
  and HLO size grows with bucket count, so the plan additionally caps the
  number of buckets (``max_buckets``) by growing the target size; the 25 MB
  default is preserved for paper-scale models.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

DEFAULT_BUCKET_BYTES = 25 * 1024 * 1024  # PyTorch DDP default (paper SS III.A)
DEFAULT_MAX_BUCKETS = 128


@dataclasses.dataclass(frozen=True)
class Segment:
    """A contiguous slab of one leaf: rows [row_lo, row_hi) along axis 0,
    optionally restricted to [sub_lo, sub_hi) along ``sub_axis`` (only when the
    segment covers a single row that had to be split)."""

    leaf_idx: int
    row_lo: int
    row_hi: int
    sub_axis: int | None = None
    sub_lo: int = 0
    sub_hi: int = 0

    def numel(self, shape: tuple[int, ...]) -> int:
        if not shape:  # scalar leaf
            return 1
        row = int(np.prod(shape[1:], dtype=np.int64)) if len(shape) > 1 else 1
        n = (self.row_hi - self.row_lo) * row
        if self.sub_axis is not None:
            n = n * (self.sub_hi - self.sub_lo) // shape[self.sub_axis]
        return int(n)


@dataclasses.dataclass(frozen=True)
class Bucket:
    index: int
    segments: tuple[Segment, ...]
    numel: int
    nbytes: int
    origin: int  # index of the pre-sharding bucket this came from (SS III.C)


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    buckets: tuple[Bucket, ...]
    leaf_shapes: tuple[tuple[int, ...], ...]
    leaf_dtypes: tuple[Any, ...]
    leaf_paths: tuple[str, ...]
    treedef: Any
    bucket_bytes_target: int
    interval_hint: int

    @property
    def num_buckets(self) -> int:
        return len(self.buckets)

    def total_numel(self) -> int:
        return sum(b.numel for b in self.buckets)

    def bucket_numels(self) -> list[int]:
        return [b.numel for b in self.buckets]


def _leaf_path_str(path) -> str:
    return jax.tree_util.keystr(path)


def _row_count(shape: tuple[int, ...]) -> int:
    return shape[0] if shape else 1


def _row_numel(shape: tuple[int, ...]) -> int:
    if not shape:
        return 1
    return int(np.prod(shape[1:], dtype=np.int64)) if len(shape) > 1 else 1


def _pick_sub_axis(shape: tuple[int, ...], spec, avoid_axes: set[int]) -> int | None:
    """First axis >= 1 that is not tensor-parallel sharded and is divisible
    enough to slice.  ``spec`` is an optional PartitionSpec for the leaf."""
    if len(shape) < 2:
        return None
    sharded: set[int] = set(avoid_axes)
    if spec is not None:
        for ax, names in enumerate(spec):
            if names is not None and ax < len(shape):
                sharded.add(ax)
    for ax in range(1, len(shape)):
        if ax not in sharded and shape[ax] > 1:
            return ax
    return None


def build_plan(
    params_like: Any,
    *,
    bucket_bytes: int = DEFAULT_BUCKET_BYTES,
    max_buckets: int = DEFAULT_MAX_BUCKETS,
    interval: int = 4,
    param_specs: Any = None,
    shard_threshold: float = 2.0,
) -> BucketPlan:
    """Build the static bucket plan for a parameter/gradient pytree.

    Pass 1 (DDP-style packing): greedily pack rows into buckets of
    ``target`` bytes; a row larger than the target becomes its own bucket.

    Pass 2 (COVAP tensor sharding, SS III.C): find the median bucket numel;
    any bucket with ``numel >= shard_threshold * median`` is evenly sliced
    into ``min(numel // median, interval)`` pieces.
    """
    leaves_with_path = jax.tree_util.tree_leaves_with_path(params_like)
    treedef = jax.tree_util.tree_structure(params_like)
    shapes = tuple(tuple(l.shape) for _, l in leaves_with_path)
    dtypes = tuple(jnp.dtype(l.dtype) for _, l in leaves_with_path)
    paths = tuple(_leaf_path_str(p) for p, _ in leaves_with_path)

    spec_leaves = None
    if param_specs is not None:
        spec_leaves = jax.tree_util.tree_leaves(
            param_specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
        )

    total_bytes = sum(
        int(np.prod(s, dtype=np.int64)) * d.itemsize for s, d in zip(shapes, dtypes)
    )
    target = max(bucket_bytes, math.ceil(total_bytes / max_buckets))

    # ---- pass 1: DDP-style greedy packing at row granularity -------------
    raw: list[list[Segment]] = []
    raw_bytes: list[int] = []
    cur: list[Segment] = []
    cur_bytes = 0

    def flush():
        nonlocal cur, cur_bytes
        if cur:
            raw.append(cur)
            raw_bytes.append(cur_bytes)
            cur, cur_bytes = [], 0

    for li, (shape, dtype) in enumerate(zip(shapes, dtypes)):
        rows = _row_count(shape)
        rb = _row_numel(shape) * dtype.itemsize
        if rb >= target:
            # every row of this leaf is itself bucket-sized
            flush()
            for r in range(rows):
                raw.append([Segment(li, r, r + 1)])
                raw_bytes.append(rb)
            continue
        r = 0
        while r < rows:
            space = target - cur_bytes
            take = max(1, min(rows - r, space // rb if rb else rows - r))
            cur.append(Segment(li, r, r + take))
            cur_bytes += take * rb
            r += take
            if cur_bytes + rb > target:
                flush()
    flush()

    # ---- pass 2: COVAP tensor sharding (SS III.C) -------------------------
    numels = [sum(s.numel(shapes[s.leaf_idx]) for s in segs) for segs in raw]
    median = int(np.median(numels)) if numels else 0
    buckets: list[Bucket] = []
    for origin, (segs, numel, nbytes) in enumerate(zip(raw, numels, raw_bytes)):
        if median > 0 and numel >= shard_threshold * median and len(segs) >= 1:
            parts = min(numel // median, interval)
            parts = max(int(parts), 1)
        else:
            parts = 1
        if parts == 1:
            buckets.append(
                Bucket(len(buckets), tuple(segs), numel, nbytes, origin)
            )
            continue
        for piece in _split_segments(segs, parts, shapes, spec_leaves):
            pn = sum(s.numel(shapes[s.leaf_idx]) for s in piece)
            pb = sum(
                s.numel(shapes[s.leaf_idx]) * dtypes[s.leaf_idx].itemsize
                for s in piece
            )
            buckets.append(Bucket(len(buckets), tuple(piece), pn, pb, origin))

    return BucketPlan(
        buckets=tuple(buckets),
        leaf_shapes=shapes,
        leaf_dtypes=dtypes,
        leaf_paths=paths,
        treedef=treedef,
        bucket_bytes_target=target,
        interval_hint=interval,
    )


def _split_segments(segs, parts, shapes, spec_leaves):
    """Split a bucket's segments into ``parts`` roughly equal pieces."""
    if len(segs) == 1 and segs[0].row_hi - segs[0].row_lo == 1:
        # single row: split along a non-sharded sub axis (SS III.C oversized layer)
        s = segs[0]
        shape = shapes[s.leaf_idx]
        spec = spec_leaves[s.leaf_idx] if spec_leaves is not None else None
        ax = _pick_sub_axis(shape, spec, avoid_axes=set())
        if ax is None:
            return [[s]]  # cannot split safely; keep whole
        dim = shape[ax]
        parts = min(parts, dim)
        bounds = np.linspace(0, dim, parts + 1, dtype=np.int64)
        out = []
        for i in range(parts):
            lo, hi = int(bounds[i]), int(bounds[i + 1])
            if hi > lo:
                out.append(
                    [Segment(s.leaf_idx, s.row_lo, s.row_hi, ax, lo, hi)]
                )
        return out
    # multi-row bucket: split by rows, keeping segments intact where possible
    rows = []
    for s in segs:
        for r in range(s.row_lo, s.row_hi):
            rows.append(Segment(s.leaf_idx, r, r + 1))
    parts = min(parts, len(rows))
    bounds = np.linspace(0, len(rows), parts + 1, dtype=np.int64)
    out = []
    for i in range(parts):
        chunk = rows[int(bounds[i]) : int(bounds[i + 1])]
        out.append(_coalesce(chunk))
    return [c for c in out if c]


def _coalesce(row_segs: Sequence[Segment]) -> list[Segment]:
    out: list[Segment] = []
    for s in row_segs:
        if out and out[-1].leaf_idx == s.leaf_idx and out[-1].row_hi == s.row_lo:
            prev = out[-1]
            out[-1] = Segment(prev.leaf_idx, prev.row_lo, s.row_hi)
        else:
            out.append(s)
    return out


# ---------------------------------------------------------------------------
# runtime ops over a plan
# ---------------------------------------------------------------------------

def _slice_segment(leaf: jax.Array, seg: Segment) -> jax.Array:
    if leaf.ndim == 0:
        return leaf[None]
    x = lax.slice_in_dim(leaf, seg.row_lo, seg.row_hi, axis=0)
    if seg.sub_axis is not None:
        x = lax.slice_in_dim(x, seg.sub_lo, seg.sub_hi, axis=seg.sub_axis)
    return x


def _update_segment(leaf: jax.Array, seg: Segment, val: jax.Array) -> jax.Array:
    # mixed-dtype buckets (e.g. bf16 weights + f32 router in one bucket)
    # promote on gather; cast back on scatter
    val = val.astype(leaf.dtype)
    if leaf.ndim == 0:
        return val.reshape(())
    starts = [0] * leaf.ndim
    starts[0] = seg.row_lo
    if seg.sub_axis is not None:
        starts[seg.sub_axis] = seg.sub_lo
    return lax.dynamic_update_slice(leaf, val, tuple(starts))


def segment_slices(plan: BucketPlan, leaves: list[jax.Array], bucket: Bucket):
    """Yield (segment, sliced-array) pairs for a bucket (sharding-preserving)."""
    return [(seg, _slice_segment(leaves[seg.leaf_idx], seg)) for seg in bucket.segments]


def gather_bucket(plan: BucketPlan, leaves: list[jax.Array], bucket: Bucket) -> jax.Array:
    """Materialise a bucket as a flat 1-D vector (baseline-compressor path)."""
    parts = [
        _slice_segment(leaves[seg.leaf_idx], seg).reshape(-1)
        for seg in bucket.segments
    ]
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts)


def scatter_bucket(
    plan: BucketPlan, leaves: list[jax.Array], bucket: Bucket, flat: jax.Array
) -> list[jax.Array]:
    """Write a flat bucket vector back into the leaves (inverse of gather)."""
    leaves = list(leaves)
    off = 0
    for seg in bucket.segments:
        shape = plan.leaf_shapes[seg.leaf_idx]
        n = seg.numel(shape)
        val = lax.dynamic_slice_in_dim(flat, off, n)
        off += n
        leaf = leaves[seg.leaf_idx]
        if leaf.ndim == 0:
            leaves[seg.leaf_idx] = val.reshape(()).astype(leaf.dtype)
            continue
        seg_shape = list(shape)
        seg_shape[0] = seg.row_hi - seg.row_lo
        if seg.sub_axis is not None:
            seg_shape[seg.sub_axis] = seg.sub_hi - seg.sub_lo
        leaves[seg.leaf_idx] = _update_segment(leaf, seg, val.reshape(seg_shape))
    return leaves


# ---------------------------------------------------------------------------
# ReadyOrder: reverse-topological bucket readiness (overlap engine)
# ---------------------------------------------------------------------------
#
# The backward pass produces gradients in *reverse* forward order: the output
# head's VJP runs first, the embedding's last.  A bucket's collective may be
# issued the moment its LAST gradient is produced — i.e. when the VJP of the
# shallowest (smallest forward depth) layer it touches has run.  ``ReadyOrder``
# makes that readiness static metadata of a ``BucketPlan`` so the schedule can
# state the issue order and the perf model can lay out a faithful timeline.
#
# Forward depth is derived from leaf paths: the models in this repo stack
# per-layer parameters over axis 0 (scan-over-layers), so for leaves under a
# stacked stage (``blocks`` / ``encoder`` / ``decoder``) row ``r`` sits at
# depth ``stage_base + r``; everything else occupies one depth slot per stage
# (embed/projector -> encoder -> enc_norm -> decoder -> blocks -> shared ->
# final_norm -> head).  Unrecognised trees (toy tests) fall back to one slot
# per leaf in parameter order, which makes readiness = reverse leaf order.

# (stage id, path markers, stacked-over-rows)
_STAGE_MARKERS = (
    (0, ("embed", "projector"), False),
    (1, ("encoder",), True),
    (2, ("enc_norm",), False),
    (3, ("decoder",), True),
    (4, ("blocks",), True),
    # weight-shared block (zamba2): applied inside every scan iteration, so
    # its gradient completes with blocks row 0 — it shares the blocks base.
    (4, ("shared",), False),
    (7, ("final_norm",), False),
    (8, ("head",), False),
)
_UNKNOWN_STAGE = 6  # mid-network: between the stacks and final_norm


def _leaf_stage(path: str) -> tuple[int, bool]:
    for sid, markers, stacked in _STAGE_MARKERS:
        if any(m in path for m in markers):
            return sid, stacked
    return _UNKNOWN_STAGE, False


@dataclasses.dataclass(frozen=True)
class ReadyOrder:
    """Static backward-readiness of a plan's buckets.

    ``bucket_layer[b]`` is the forward depth of the layer whose VJP produces
    bucket ``b``'s *last* gradient; ``ranks[b]`` is the issue rank (0 =
    first bucket whose collective can start); ``order`` lists bucket indices
    in issue order.  ``num_layers`` is the total forward depth span.
    """

    bucket_layer: tuple[int, ...]
    ranks: tuple[int, ...]
    num_layers: int

    @property
    def order(self) -> tuple[int, ...]:
        out = sorted(range(len(self.ranks)), key=lambda b: self.ranks[b])
        return tuple(out)

    def rank_of(self, bucket: int) -> int:
        return self.ranks[bucket]


def leaf_row_depth(plan: BucketPlan) -> list[Any]:
    """Per-leaf forward depth: an ``int`` for whole-leaf stages or a
    callable ``row -> depth`` for stacked-over-layers leaves."""
    stages = [_leaf_stage(p) for p in plan.leaf_paths]
    known = any(sid != _UNKNOWN_STAGE for sid, _ in stages)

    # depth slots per stage id, in forward order
    slots: dict[int, int] = {}
    for li, (sid, stacked) in enumerate(stages):
        if not known:
            # toy tree: one slot per leaf, forward = parameter order
            slots[li] = 1
            continue
        rows = _row_count(plan.leaf_shapes[li]) if stacked else 1
        slots[sid] = max(slots.get(sid, 1), rows)
    base: dict[int, int] = {}
    off = 0
    for sid in sorted(slots):
        base[sid] = off
        off += slots[sid]

    depths: list[Any] = []
    for li, (sid, stacked) in enumerate(stages):
        key = li if not known else sid
        if stacked and known:
            b = base[key]
            depths.append(lambda r, _b=b: _b + r)
        else:
            depths.append(base[key])
    return depths


def build_ready_order(plan: BucketPlan) -> ReadyOrder:
    """Reverse-topological readiness of every bucket (see module notes).

    A bucket becomes ready when its shallowest segment's gradient lands, so
    buckets are ranked by descending minimum forward depth; ties (several
    buckets of one layer) break toward higher bucket index, matching the
    reverse of the plan's forward packing order.
    """
    depths = leaf_row_depth(plan)
    layer: list[int] = []
    for bucket in plan.buckets:
        d = None
        for seg in bucket.segments:
            dl = depths[seg.leaf_idx]
            v = dl(seg.row_lo) if callable(dl) else dl
            d = v if d is None else min(d, v)
        layer.append(int(d if d is not None else 0))
    order = sorted(range(len(layer)), key=lambda b: (-layer[b], -b))
    ranks = [0] * len(order)
    for rank, b in enumerate(order):
        ranks[b] = rank
    num_layers = max(layer) + 1 if layer else 0
    return ReadyOrder(tuple(layer), tuple(ranks), num_layers)


def zeros_like_leaves(plan: BucketPlan) -> list[jax.Array]:
    return [
        jnp.zeros(s, d) for s, d in zip(plan.leaf_shapes, plan.leaf_dtypes)
    ]


def leaves_of(plan: BucketPlan, tree: Any) -> list[jax.Array]:
    return jax.tree_util.tree_leaves(tree)


def tree_of(plan: BucketPlan, leaves: list[jax.Array]) -> Any:
    return jax.tree_util.tree_unflatten(plan.treedef, leaves)
