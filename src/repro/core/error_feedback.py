"""Error feedback with COVAP's compensation-coefficient scheduler (SS III.D).

Algorithm 1 of the paper, with the scheduler extension:

    t         = g + coeff(step) * residual      # compensation
    g'        = filter(t)                       # communicated part
    residual' = t - g'                          # kept locally

    coeff(step) = min(init + floor(step / ascend_steps) * ascend_range, 1)

The residual lives as a pytree with the *same structure and sharding* as the
gradients, so it adds exactly one parameter-sized buffer per worker and never
forces a resharding collective.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class EFSchedule:
    init_value: float = 0.3
    ascend_steps: int = 200
    ascend_range: float = 0.1

    def coefficient(self, step) -> jax.Array:
        """Traceable: ``step`` may be a python int or a jnp scalar."""
        step = jnp.asarray(step, jnp.float32)
        c = self.init_value + jnp.floor(step / self.ascend_steps) * self.ascend_range
        return jnp.minimum(c, 1.0)


def init_residual(params_like: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params_like)


def compensate(grads: Any, residual: Any, coeff) -> Any:
    """t = g + coeff * r (line 2 of Algorithm 1 with the scheduler)."""
    return jax.tree.map(lambda g, r: g + coeff * r.astype(g.dtype), grads, residual)


def residual_update(t: Any, sent: Any) -> Any:
    """residual' = t - g' (line 4 of Algorithm 1).

    ``sent`` must be the *local pre-reduction* contribution at the positions
    that were communicated and zero elsewhere.
    """
    return jax.tree.map(lambda a, b: a - b, t, sent)
