"""COVAP core: the paper's contribution as composable JAX modules."""
from . import bucketing, ccr, compressors, error_feedback, filter, perfmodel
from .bucketing import BucketPlan, build_plan
from .ccr import HardwareSpec, analytic_times, select_interval
from .compressors import available, get_compressor
from .error_feedback import EFSchedule
from .filter import compression_ratio, selected_buckets

__all__ = [
    "bucketing",
    "ccr",
    "compressors",
    "error_feedback",
    "filter",
    "perfmodel",
    "BucketPlan",
    "build_plan",
    "HardwareSpec",
    "analytic_times",
    "select_interval",
    "available",
    "get_compressor",
    "EFSchedule",
    "compression_ratio",
    "selected_buckets",
]
