"""COVAP core: the paper's contribution as composable JAX modules.

The compressor subsystem is organised around the plan/execute split:
``schedule.CommSchedule`` (static per-phase comm plans), ``stages``
(reusable sync stages + the ``SyncPipeline`` combinator) and ``comm``
(the ``Compressor`` contract, registry, and manual-collective helpers).
"""
from . import (
    arena,
    bucketing,
    ccr,
    comm,
    compressors,
    error_feedback,
    filter,
    overlap,
    perfmodel,
    schedule,
    stages,
)
from .arena import ArenaLayout, build_layout
from .bucketing import BucketPlan, ReadyOrder, build_plan, build_ready_order
from .ccr import HardwareSpec, analytic_ccr, analytic_times, select_interval
from .comm import Compressor, SyncStats
from .compressors import available, get_compressor
from .error_feedback import EFSchedule
from .filter import compression_ratio, selected_buckets
from .schedule import CollectiveCall, CommSchedule, plan_all_phases
from .stages import SyncPipeline

__all__ = [
    "arena",
    "bucketing",
    "ccr",
    "comm",
    "compressors",
    "error_feedback",
    "filter",
    "overlap",
    "perfmodel",
    "schedule",
    "stages",
    "ArenaLayout",
    "build_layout",
    "BucketPlan",
    "ReadyOrder",
    "build_plan",
    "build_ready_order",
    "HardwareSpec",
    "analytic_ccr",
    "analytic_times",
    "select_interval",
    "Compressor",
    "SyncStats",
    "available",
    "get_compressor",
    "EFSchedule",
    "compression_ratio",
    "selected_buckets",
    "CollectiveCall",
    "CommSchedule",
    "plan_all_phases",
    "SyncPipeline",
]
