"""Zero-copy gradient arena: statically-planned flat bucket buffers.

The plan/execute split makes *which* bytes cross the wire a static property
of ``(plan, phase)`` — this module makes *where they live* static too.  An
:class:`ArenaLayout` assigns every covered bucket a contiguous slot inside
one flat per-dtype buffer (a *plane*), with per-segment offsets computed
once from the :class:`~repro.core.bucketing.BucketPlan`.  At execute time
the gradient is packed into the arena **once per step** and every bucket's
wire payload is a static-offset slice view — no per-bucket
``jnp.concatenate`` rebuilds, no ``lax.dynamic_slice_in_dim`` chains on the
way back (the gather/scatter data-movement tax Agarwal et al. identify as
the reason GC schemes lose their paper speedups).

Layout rules
------------

* Buckets are laid out in **plan order** (ascending bucket index), one
  slot per bucket, segments packed back-to-back inside the slot in segment
  order — exactly the element order ``bucketing.gather_bucket`` produces,
  so packed views are interchangeable with the legacy flat vectors.
* A bucket's element dtype is its **promoted** dtype
  (:func:`bucket_dtype` = ``np.result_type`` over its segments — the same
  promotion ``jnp.concatenate`` applies on the legacy path), unless the
  caller pins a wire dtype (``WireCast('bfloat16')``).
* Buckets of different dtypes land in different *planes* (one flat buffer
  per dtype); models with uniform parameter dtype get exactly one plane.
* The layout covers a caller-chosen bucket subset — per phase, the
  selected buckets of that phase's ``CommSchedule`` — so an unselected
  bucket (which never crosses the wire) occupies no arena space.

Note the arena order is NOT the issue order: the overlap engine's
``bucketing.ReadyOrder`` ranks buckets by backward readiness (head first,
embedding last) while the arena keeps plan order so that offsets stay
monotone in bucket index (DESIGN.md §12 has the picture).  The two are
orthogonal: readiness decides *when* a bucket's collective is issued,
the layout decides *where* its payload lives.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import bucketing as bk
from .bucketing import Bucket, BucketPlan


def bucket_dtype(plan: BucketPlan, bucket: Bucket) -> np.dtype:
    """Promoted dtype of a flattened bucket (mixed buckets promote via
    ``np.result_type`` — the same rule ``jnp.concatenate`` applies)."""
    return np.result_type(
        *[plan.leaf_dtypes[s.leaf_idx] for s in bucket.segments]
    )


def segment_shape(plan: BucketPlan, seg: bk.Segment) -> tuple[int, ...]:
    """Shape of one segment's slice of its leaf (scalars -> ``(1,)``)."""
    shape = plan.leaf_shapes[seg.leaf_idx]
    if not shape:
        return (1,)
    out = list(shape)
    out[0] = seg.row_hi - seg.row_lo
    if seg.sub_axis is not None:
        out[seg.sub_axis] = seg.sub_hi - seg.sub_lo
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class ArenaLayout:
    """Static flat-buffer layout for a subset of a plan's buckets.

    ``buckets[i]`` is covered bucket *i* (plan order); parallel tuples give
    its plane, offset (elements, within the plane), and extent.
    ``seg_offsets[i]`` holds the absolute plane offset of each of its
    segments.  ``plane_dtypes`` / ``plane_sizes`` describe the flat
    buffers themselves.
    """

    plan: BucketPlan
    buckets: tuple[int, ...]
    plane_dtypes: tuple[str, ...]
    plane_sizes: tuple[int, ...]
    bucket_plane: tuple[int, ...]
    bucket_offsets: tuple[int, ...]
    bucket_numels: tuple[int, ...]
    seg_offsets: tuple[tuple[int, ...], ...]
    # slot alignment (sharded sync, DESIGN.md §13): every bucket slot's
    # extent is rounded up to a multiple of ``align`` so the slot view is
    # evenly partitionable into W = align worker shards for a
    # reduce-scatter.  ``bucket_numels`` holds the PADDED extents; the
    # zero-filled tail of a slot (extent - bucket.numel elements) is packed
    # by ``assemble``, reduced like real payload, and ignored by
    # ``unpack_bucket`` (segment offsets address only real elements).
    align: int = 1

    def __post_init__(self):
        object.__setattr__(
            self, "_pos", {b: i for i, b in enumerate(self.buckets)}
        )

    # ---- lookups ----------------------------------------------------------
    def index_of(self, b: int) -> int:
        return self._pos[b]

    def covers(self, b: int) -> bool:
        return b in self._pos

    def slot(self, b: int) -> tuple[int, int, int]:
        """-> (plane index, element offset, extent) of bucket ``b``."""
        i = self._pos[b]
        return self.bucket_plane[i], self.bucket_offsets[i], self.bucket_numels[i]

    def total_elements(self) -> int:
        return sum(self.plane_sizes)

    def nbytes(self) -> int:
        return sum(
            n * np.dtype(d).itemsize
            for n, d in zip(self.plane_sizes, self.plane_dtypes)
        )

    # ---- buffers ----------------------------------------------------------
    def bucket_view(self, planes: Sequence[jax.Array], b: int) -> jax.Array:
        """Bucket ``b``'s payload — a static-offset slice, not a copy."""
        p, off, n = self.slot(b)
        return planes[p][off : off + n]

    def assemble(self, pieces: dict[int, Sequence[jax.Array]]) -> list[jax.Array]:
        """Build the arena planes from per-bucket segment pieces — ONE
        fused op per plane.

        ``pieces[b]`` holds bucket ``b``'s per-segment values (any shape;
        flattened and cast to the plane dtype here).  Because the layout
        places buckets and segments back-to-back in plan order,
        concatenating the pieces in that order IS the packed plane: the
        whole pack pass lowers to a single HLO concatenate per plane
        instead of a per-bucket rebuild or a dynamic-update-slice chain.
        Buckets the layout doesn't cover are ignored; every covered bucket
        must be present.
        """
        per_plane: list[list[jax.Array]] = [[] for _ in self.plane_dtypes]
        for b in self.buckets:
            i = self._pos[b]
            p = self.bucket_plane[i]
            dt = np.dtype(self.plane_dtypes[p])
            vals = pieces[b]
            segs = self.plan.buckets[b].segments
            if len(vals) != len(segs):
                raise ValueError(
                    f"bucket {b}: {len(vals)} pieces for {len(segs)} segments"
                )
            per_plane[p].extend(v.reshape(-1).astype(dt) for v in vals)
            pad = self.bucket_numels[i] - self.plan.buckets[b].numel
            if pad:
                per_plane[p].append(jnp.zeros(pad, dt))
        return [
            jnp.concatenate(vs)
            if vs else jnp.zeros(0, np.dtype(self.plane_dtypes[p]))
            for p, vs in enumerate(per_plane)
        ]

    def unpack_bucket(self, b: int, flat: jax.Array) -> list[jax.Array]:
        """Split a bucket-sized flat vector back into segment-shaped pieces
        using static slices (the zero-copy replacement for
        ``stages._split_like`` / ``bucketing.scatter_bucket``)."""
        i = self._pos[b]
        plan = self.plan
        bucket = plan.buckets[b]
        base = self.bucket_offsets[i]
        out = []
        for seg, off in zip(bucket.segments, self.seg_offsets[i]):
            shape = segment_shape(plan, seg)
            n = int(np.prod(shape, dtype=np.int64))
            rel = off - base
            out.append(flat[rel : rel + n].reshape(shape))
        return out


def aligned_numel(numel: int, align: int) -> int:
    """Slot extent of a bucket under W-aligned padding — the element count
    that actually crosses the wire on the sharded path (planner-side
    counterpart of ``build_layout(align=)``)."""
    align = max(int(align), 1)
    return -(-int(numel) // align) * align


def build_layout(
    plan: BucketPlan,
    selected: Iterable[int] | None = None,
    *,
    wire_dtype: Any = None,
    align: int = 1,
) -> ArenaLayout:
    """Compute the static arena layout for ``selected`` buckets (default:
    every bucket) — pure Python over plan metadata, no tracing.

    ``wire_dtype`` pins every bucket's element type (the ``WireCast`` cast
    path); otherwise each bucket uses its promoted :func:`bucket_dtype`.
    ``align`` (sharded sync) rounds every slot's extent up to a multiple —
    pass the DP world size so each slot partitions evenly into worker
    shards for a reduce-scatter; the padding is zero-filled tail elements
    that never map to a segment.
    """
    if selected is None:
        covered = list(range(plan.num_buckets))
    else:
        covered = sorted(dict.fromkeys(int(b) for b in selected))
    wd = np.dtype(wire_dtype) if wire_dtype is not None else None
    align = max(int(align), 1)

    plane_of: dict[str, int] = {}
    plane_dtypes: list[str] = []
    plane_sizes: list[int] = []
    bucket_plane: list[int] = []
    bucket_offsets: list[int] = []
    bucket_numels: list[int] = []
    seg_offsets: list[tuple[int, ...]] = []

    for b in covered:
        bucket = plan.buckets[b]
        dt = wd if wd is not None else bucket_dtype(plan, bucket)
        name = np.dtype(dt).name
        if name not in plane_of:
            plane_of[name] = len(plane_dtypes)
            plane_dtypes.append(name)
            plane_sizes.append(0)
        p = plane_of[name]
        off = plane_sizes[p]
        offs = []
        cur = off
        for seg in bucket.segments:
            offs.append(cur)
            cur += seg.numel(plan.leaf_shapes[seg.leaf_idx])
        extent = cur - off
        assert extent == bucket.numel, (extent, bucket.numel)
        extent = -(-extent // align) * align  # W-aligned slot (zero tail)
        bucket_plane.append(p)
        bucket_offsets.append(off)
        bucket_numels.append(extent)
        seg_offsets.append(tuple(offs))
        plane_sizes[p] = off + extent

    return ArenaLayout(
        plan=plan,
        buckets=tuple(covered),
        plane_dtypes=tuple(plane_dtypes),
        plane_sizes=tuple(plane_sizes),
        bucket_plane=tuple(bucket_plane),
        bucket_offsets=tuple(bucket_offsets),
        bucket_numels=tuple(bucket_numels),
        seg_offsets=tuple(seg_offsets),
        align=align,
    )


def pack_leaves(
    layout: ArenaLayout, leaves: Sequence[jax.Array]
) -> list[jax.Array]:
    """Pack leaf arrays into arena planes — one fused op per plane.

    Pure data movement (plus the plane-dtype promotion ``jnp.concatenate``
    would apply on the legacy path): every covered bucket's segment slices
    land at their planned offsets, so the result's ``bucket_view`` is
    bitwise what ``bucketing.gather_bucket`` returns — but the whole step
    packs once instead of once per bucket.
    """
    pieces = {
        b: [
            bk._slice_segment(leaves[seg.leaf_idx], seg)
            for seg in layout.plan.buckets[b].segments
        ]
        for b in layout.buckets
    }
    return layout.assemble(pieces)


def leaf_cover(plan: BucketPlan) -> list[list[tuple[int, int, bk.Segment]] | None]:
    """Per-leaf ordered ``(bucket, seg_pos, Segment)`` coverage.

    ``build_plan`` tiles every leaf with ascending contiguous row (and
    sub-axis) ranges, in bucket order — which makes leaf *reassembly* a
    single concatenate instead of a per-segment update-slice chain
    (:func:`gather_leaves`).  Entries are validated; a leaf whose coverage
    is not a contiguous ascending tiling yields ``None`` (callers fall
    back to the scatter path)."""
    cover: list[list[tuple[int, int, bk.Segment]]] = [
        [] for _ in plan.leaf_shapes
    ]
    for b, bucket in enumerate(plan.buckets):
        for si, seg in enumerate(bucket.segments):
            cover[seg.leaf_idx].append((b, si, seg))
    out: list[list[tuple[int, int, bk.Segment]] | None] = []
    for li, entries in enumerate(cover):
        shape = plan.leaf_shapes[li]
        rows = shape[0] if shape else 1
        ok = bool(entries)
        r = 0
        i = 0
        while ok and i < len(entries):
            seg = entries[i][2]
            if seg.row_lo != r:
                ok = False
                break
            if seg.sub_axis is None:
                r = seg.row_hi
                i += 1
                continue
            # a run of sub-axis splits of one row block must tile the axis
            dim = shape[seg.sub_axis]
            c = 0
            while i < len(entries):
                s2 = entries[i][2]
                if (
                    s2.row_lo != seg.row_lo
                    or s2.sub_axis != seg.sub_axis
                    or s2.sub_lo != c
                ):
                    break
                c = s2.sub_hi
                i += 1
            if c != dim:
                ok = False
            r = seg.row_hi
        out.append(entries if ok and r == rows else None)
    return out


def gather_leaves(
    plan: BucketPlan,
    piece: Any,
    like: Sequence[jax.Array],
) -> list[jax.Array]:
    """Reassemble full leaves from per-segment pieces — the zero-copy
    inverse of :func:`pack_leaves`.

    ``piece(b, si, seg)`` returns the segment-shaped value for segment
    ``si`` of bucket ``b`` (or ``None`` for "zero": an unselected bucket's
    contribution).  Each leaf is rebuilt with at most one concatenate per
    split axis — replacing the legacy per-segment
    ``dynamic_update_slice`` chain — and cast to ``like``'s dtype.  Leaves
    whose coverage :func:`leaf_cover` rejects fall back to the scatter
    path.
    """
    cover = leaf_cover(plan)
    out: list[jax.Array] = []
    for li, entries in enumerate(cover):
        ref = like[li]
        shape = plan.leaf_shapes[li]
        if entries is None:  # defensive: non-contiguous coverage
            leaf = jnp.zeros(ref.shape, ref.dtype)
            for b, bucket in enumerate(plan.buckets):
                for si, seg in enumerate(bucket.segments):
                    if seg.leaf_idx != li:
                        continue
                    v = piece(b, si, seg)
                    if v is not None:
                        leaf = bk._update_segment(leaf, seg, v)
            out.append(leaf)
            continue

        def val(b, si, seg):
            v = piece(b, si, seg)
            if v is None:
                return jnp.zeros(segment_shape(plan, seg), ref.dtype)
            return v.astype(ref.dtype)

        blocks: list[jax.Array] = []
        i = 0
        while i < len(entries):
            b, si, seg = entries[i]
            if seg.sub_axis is None:
                blocks.append(val(b, si, seg))
                i += 1
                continue
            parts = []
            while i < len(entries) and entries[i][2].row_lo == seg.row_lo:
                b2, s2, seg2 = entries[i]
                parts.append(val(b2, s2, seg2))
                i += 1
            blocks.append(
                parts[0] if len(parts) == 1
                else jnp.concatenate(parts, axis=seg.sub_axis)
            )
        leaf = blocks[0] if len(blocks) == 1 else jnp.concatenate(blocks, axis=0)
        out.append(leaf.reshape(ref.shape).astype(ref.dtype))
    return out


__all__ = [
    "ArenaLayout",
    "aligned_numel",
    "bucket_dtype",
    "build_layout",
    "gather_leaves",
    "leaf_cover",
    "pack_leaves",
    "segment_shape",
]
