"""CCR (communication-to-computation ratio) estimation + interval selection
(paper SS III.B).

Two estimators, per DESIGN.md SS2:

* ``analytic_ccr`` — the TPU-native profiler: XLA graphs are static, so
  communication volume and FLOPs are exact properties of the compiled
  artifact (or of the config, pre-compile).  This replaces CUDA-event
  tracing for the production path.
* ``measure_ccr`` / ``align_comm_times`` — the paper's measured profiler,
  including the *distributed timeline alignment*: a worker that reaches the
  collective early observes transfer + rendezvous-wait; the true transfer
  starts when the **last** worker arrives, so per-op comm time is
  ``end - max_w(start_w)``.  Used by the CPU benchmarks and tests.

The adaptive rule is the paper's: ``I = ceil(CCR)`` (a little more
compression than strictly needed, so the remaining communication always
fits under the backward pass).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """TPU v5e defaults (per chip)."""

    peak_flops: float = 197e12          # bf16 FLOP/s
    hbm_bw: float = 819e9               # bytes/s
    ici_bw: float = 50e9                # bytes/s per link
    dcn_bw: float = 6.25e9              # bytes/s per chip across pods (DCN)
    mfu: float = 0.4                    # assumed model-FLOPs utilisation

    @staticmethod
    def v5e() -> "HardwareSpec":
        return HardwareSpec()

    @staticmethod
    def cloud_v100_30gbps() -> "HardwareSpec":
        """The paper's environment: V100 + 30 Gbps Ethernet."""
        return HardwareSpec(
            peak_flops=125e12, hbm_bw=900e9, ici_bw=30e9 / 8, mfu=0.35
        )


def allreduce_bytes_on_wire(payload_bytes: float, world: int) -> float:
    """Ring all-reduce: each worker moves 2*(W-1)/W * payload."""
    if world <= 1:
        return 0.0
    return 2.0 * (world - 1) / world * payload_bytes


def analytic_times(
    *,
    step_flops_per_chip: float,
    grad_bytes: float,
    dp_world: int,
    hw: HardwareSpec,
    fwd_fraction: float = 1.0 / 3.0,
) -> dict:
    """Analytic T_before / T_comp / T_comm for one DP step (paper Table I).

    ``step_flops_per_chip`` is fwd+bwd model FLOPs per chip;
    the backward pass is ~2/3 of it; T_before ~ forward third.
    """
    t_total_compute = step_flops_per_chip / (hw.peak_flops * hw.mfu)
    t_before = t_total_compute * fwd_fraction
    t_comp = t_total_compute * (1.0 - fwd_fraction)
    wire = allreduce_bytes_on_wire(grad_bytes, dp_world)
    t_comm = wire / hw.ici_bw
    ccr = t_comm / max(t_comp, 1e-12)
    return {
        "t_before": t_before,
        "t_comp": t_comp,
        "t_comm": t_comm,
        "ccr": ccr,
    }


def analytic_ccr(
    *,
    step_flops_per_chip: float,
    grad_bytes: float,
    dp_world: int,
    hw: HardwareSpec | None = None,
    fwd_fraction: float = 1.0 / 3.0,
) -> float:
    """The analytic profiler's CCR (paper SS III.B) — ``repro.api``'s
    ``interval='auto'`` rule is ``I = ceil(analytic_ccr(...))``."""
    hw = hw or HardwareSpec.v5e()
    return analytic_times(
        step_flops_per_chip=step_flops_per_chip,
        grad_bytes=grad_bytes,
        dp_world=dp_world,
        hw=hw,
        fwd_fraction=fwd_fraction,
    )["ccr"]


def select_interval(ccr: float, max_interval: int = 64) -> int:
    """The paper's adaptive compression ratio: I = ceil(CCR), floored at 1."""
    return int(min(max(1, math.ceil(ccr)), max_interval))


# ---------------------------------------------------------------------------
# schedule-aware accounting (plan/execute split: no tracing required)
# ---------------------------------------------------------------------------

def schedule_comm_seconds(
    schedules: Sequence, *, world: int, hw: HardwareSpec | None = None,
    link_bw: float | None = None,
) -> float:
    """Mean per-step communication time of a compressor's phase cycle,
    straight from its static ``CommSchedule``s — the executed-volume
    counterpart of ``analytic_times``'s dense estimate."""
    hw = hw or HardwareSpec.v5e()
    bw = link_bw or hw.ici_bw
    schedules = tuple(schedules)
    if not schedules:
        return 0.0
    wire = sum(s.wire_bytes(world) for s in schedules) / len(schedules)
    return wire / bw


def compressed_ccr(
    schedules: Sequence,
    *,
    t_comp: float,
    world: int,
    hw: HardwareSpec | None = None,
    link_bw: float | None = None,
) -> float:
    """Residual CCR after compression: planned wire seconds / backward-pass
    seconds.  COVAP targets < 1 (communication fully hidden)."""
    t_comm = schedule_comm_seconds(
        schedules, world=world, hw=hw, link_bw=link_bw
    )
    return t_comm / max(t_comp, 1e-12)


# ---------------------------------------------------------------------------
# measured profiler (CPU benchmarks / tests)
# ---------------------------------------------------------------------------

def align_comm_times(
    starts: np.ndarray, ends: np.ndarray
) -> np.ndarray:
    """Distributed-profiler alignment (paper SS III.B, Fig. 3).

    ``starts``/``ends``: (workers, ops) wall-clock times of each collective.
    Returns (ops,) true transfer times: ``min_w(end) - max_w(start)`` — wait
    time spent by early workers at the rendezvous is excluded.
    """
    starts = np.asarray(starts, dtype=np.float64)
    ends = np.asarray(ends, dtype=np.float64)
    return ends.min(axis=0) - starts.max(axis=0)


def measure_ccr(
    step_full: Callable[[], None],
    step_compute_only: Callable[[], None],
    *,
    step_comm_only: Callable[[], None] | None = None,
    warmup: int = 2,
    iters: int = 5,
) -> dict:
    """Measured profiler: times a full DP step vs. a communication-free
    step and derives CCR = (T_full - T_comp) / T_comp.

    ``step_comm_only`` (the schedule-only sub-program: just the phase's
    planned collectives on dummy buffers) adds a ``t_comm_direct``
    cross-check — under full overlap ``t_full - t_comp`` undershoots the
    wire time, so the reported ``t_comm`` is the larger of the two.
    Consumed per-phase by ``repro.runtime.monitor.PhaseProbe``.
    """

    def timed(fn):
        for _ in range(warmup):
            fn()
        t0 = time.perf_counter()
        for _ in range(iters):
            fn()
        return (time.perf_counter() - t0) / iters

    t_full = timed(step_full)
    t_comp = timed(step_compute_only)
    t_comm = max(t_full - t_comp, 0.0)
    out = {"t_full": t_full, "t_comp": t_comp}
    if step_comm_only is not None:
        t_direct = timed(step_comm_only)
        out["t_comm_direct"] = t_direct
        t_comm = max(t_comm, t_direct)
    out["t_comm"] = t_comm
    out["ccr"] = t_comm / max(t_comp, 1e-12)
    return out
