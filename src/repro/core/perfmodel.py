"""The paper's analytical performance model — equations (1)-(6) — plus the
bucket-timeline simulator used for Figs. 1/4/5/11.

All times in seconds; all speedups relative to single-worker linear scaling
(upper limit = P, the number of workers).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence, Union


# ---- eq (1)/(2): plain DP ---------------------------------------------------

def t_dp(t_before: float, t_comp: float, t_comm: float) -> float:
    return t_before + t_comp + t_comm


def speedup_dp(P: int, t_before: float, t_comp: float, t_comm: float) -> float:
    """Eq (2): P * k / (k + CCR), k = T_before/T_comp + 1."""
    k = t_before / t_comp + 1.0
    ccr = t_comm / t_comp
    return P * k / (k + ccr)


# ---- eq (3): tensor-based overlapping timeline ------------------------------

def simulate_overlap(
    t_before: float,
    comp_times: Sequence[float],
    comm_times: Sequence[float],
) -> dict:
    """Simulate one iteration of bucketed overlapped DP (Fig. 1(b)/(d)).

    Bucket i's communication may start once (a) its gradients are computed
    and (b) the previous bucket's communication finished (collectives are
    ordered on the interconnect).  Returns total time + bubble accounting
    (the idle interconnect slots of eq (3))."""
    assert len(comp_times) == len(comm_times)
    t = t_before
    comm_free = t_before
    bubbles = 0.0
    for comp, comm in zip(comp_times, comm_times):
        t += comp  # gradient of this bucket ready
        start = max(t, comm_free)
        if comm > 0 and start > comm_free and comm_free > t_before:
            bubbles += start - comm_free
        comm_free = start + comm
    total = max(t, comm_free)
    return {
        "total": total,
        "compute_end": t,
        "comm_end": comm_free,
        "bubbles": bubbles,
        "exposed_comm": max(0.0, comm_free - t),
        "comm_total": float(sum(comm_times)),
    }


def overlap_fraction(sim: dict) -> float:
    """Fraction of a timeline's communication hidden under compute:
    ``1 - exposed/total`` (1.0 when the phase moves no bytes).  Works on
    any :func:`simulate_overlap` / :func:`simulate_schedule` result."""
    comm = sim.get("comm_total", 0.0)
    if comm <= 0.0:
        return 1.0
    return max(0.0, 1.0 - sim.get("exposed_comm", 0.0) / comm)


def achieved_overlap_fraction(
    t_comp: float, t_comm: float, t_step: float
) -> float:
    """Measured counterpart of :func:`overlap_fraction`: with compute time
    ``t_comp`` (collective-free sub-program), wire time ``t_comm``
    (schedule-only sub-program) and the full step's wall time, the hidden
    communication is ``t_comp + t_comm - t_step`` — clamped to [0, 1] of
    ``t_comm``.  This is the number the overlap engine is judged by:
    predicted (:func:`overlap_fraction` on the planned timeline) vs
    achieved (this, from ``runtime.monitor`` probes)."""
    if t_comm <= 0.0:
        return 1.0
    hidden = t_comp + t_comm - t_step
    return max(0.0, min(1.0, hidden / t_comm))


def t_ovlp(t_before: float, t_comp: float, t_comm: float, n_buckets: int = 8) -> float:
    """Eq (4) via the simulator with uniform buckets."""
    comp = [t_comp / n_buckets] * n_buckets
    comm = [t_comm / n_buckets] * n_buckets
    return simulate_overlap(t_before, comp, comm)["total"]


def speedup_ovlp(P: int, t_before: float, t_comp: float, t_comm: float) -> float:
    ls = t_before + t_comp
    return P * ls / t_ovlp(t_before, t_comp, t_comm)


# ---- eq (5)/(6): GC and GC+overlap ------------------------------------------

def t_gc(
    t_before: float, t_comp: float, t_comm_gc: float, t_compress: float
) -> float:
    """Eq (5): compression is serial between compute and communication."""
    return t_before + t_comp + t_compress + t_comm_gc


def t_gc_ovlp(
    t_before: float,
    t_comp: float,
    t_comm_gc: float,
    t_compress: float,
    n_buckets: int = 8,
    data_dependency: bool = False,
) -> float:
    """Eq (6) via the simulator.  With ``data_dependency`` (Fig. 1(e)) the
    scheme's synchronous exchange serialises compression+communication after
    compute — overlap is lost (Ok-topk-style)."""
    if data_dependency:
        return t_before + t_comp + t_compress + t_comm_gc
    comp = [(t_comp + t_compress) / n_buckets] * n_buckets
    comm = [t_comm_gc / n_buckets] * n_buckets
    return simulate_overlap(t_before, comp, comm)["total"]


def speedup_gc_ovlp(
    P: int,
    t_before: float,
    t_comp: float,
    t_comm: float,
    *,
    volume_ratio: float,
    t_compress: float = 0.0,
    data_dependency: bool = False,
    n_buckets: int = 8,
) -> float:
    """Speedup of a GC scheme under overlapping; ``volume_ratio`` is the
    communication-volume compression factor (dense/sent)."""
    ls = t_before + t_comp
    total = t_gc_ovlp(
        t_before,
        t_comp,
        t_comm / max(volume_ratio, 1e-9),
        t_compress,
        n_buckets=n_buckets,
        data_dependency=data_dependency,
    )
    return P * ls / total


# ---- pack-overhead term (zero-copy arena, DESIGN.md §12) --------------------

def pack_overhead_s(schedule, *, hbm_bw: float, ef: bool = False) -> float:
    """HBM streaming seconds of one phase's arena pack pass.

    The fused ``pack_ef_cast`` pass reads each selected bucket's gradient
    once and writes its wire-dtype arena slot once; with error feedback it
    additionally reads the residual and writes the new one for EVERY
    bucket (unselected buckets update their residual too, and their
    gradient is read for the compensation).  Keeping this term explicit is
    what keeps modeled vs achieved overlap honest: the paper's "near-zero
    compression overhead" is near-zero *because* it is one streaming pass,
    not because it is free.

    Returns 0.0 for leaf-granularity schedules (no arena path).
    """
    import numpy as np

    plan = schedule.plan
    if plan is None or schedule.granularity != "bucket":
        return 0.0
    total = 0
    seen: set[int] = set()
    for b, call in zip(schedule.selected, schedule.calls):
        if b in seen:
            continue
        seen.add(b)
        bucket = plan.buckets[b]
        total += bucket.nbytes  # read g
        total += bucket.numel * np.dtype(call.wire_dtype).itemsize  # write wire
    if ef:
        for b, bucket in enumerate(plan.buckets):
            total += 2 * bucket.nbytes  # read r, write r'
            if b not in seen:
                total += bucket.nbytes  # read g for the residual update
    return total / hbm_bw


# ---- schedule-driven timeline (plan/execute split) --------------------------

#: a single scalar bandwidth (every call shares one link — the flat-mesh
#: model) or a per-link mapping like ``{"ici": 50e9, "dcn": 6.25e9}``
#: matched against each ``CollectiveCall.link`` (two-level hierarchy,
#: DESIGN.md §17).
LinkBandwidth = Union[float, Mapping[str, float]]


def _bw_for(link_bw: LinkBandwidth, link: str) -> float:
    if isinstance(link_bw, Mapping):
        try:
            return link_bw[link]
        except KeyError:
            raise KeyError(
                f"link_bw mapping has no bandwidth for link {link!r} "
                f"(have {sorted(link_bw)})"
            ) from None
    return link_bw


def schedule_comm_times(
    schedule, *, world: int, link_bw: LinkBandwidth
) -> list[float]:
    """Per-bucket communication times of one phase, aligned with the
    bucket order of the schedule's plan (0.0 for unselected buckets) —
    straight from the static ``CommSchedule``, no tracing or measuring.

    ``link_bw`` may be a per-link mapping (see :data:`LinkBandwidth`);
    each call is then priced at its own link's bandwidth, so a bucket
    carrying both a DCN exchange and an ICI rebuild accumulates both
    terms."""
    plan = schedule.plan
    if plan is None:
        raise ValueError("schedule carries no BucketPlan")
    times = [0.0] * plan.num_buckets
    if schedule.granularity != "bucket":
        # leaf-granularity schemes have no bucket timeline; spread evenly
        total = sum(
            c.wire_bytes(world) / _bw_for(link_bw, c.link)
            for c in schedule.calls
        )
        return [total / plan.num_buckets] * plan.num_buckets
    if len(schedule.calls) == len(schedule.selected):
        pairs = list(zip(schedule.selected, schedule.calls))
    else:
        # merged hierarchical schedules carry extra pod-level calls beyond
        # the 1:1 selected alignment — recover each call's bucket from its
        # target ("bucket:3" / "pod-bucket:3" / "pod-ag:3")
        pairs = []
        for call in schedule.calls:
            _, _, idx = call.target.rpartition(":")
            pairs.append((int(idx), call))
    for b, call in pairs:
        # += : a bucket may carry several calls (e.g. oktopk route+gather)
        times[b] += call.wire_bytes(world) / _bw_for(link_bw, call.link)
    return times


def simulate_schedule(
    t_before: float,
    t_comp: float,
    schedule,
    *,
    world: int,
    link_bw: LinkBandwidth,
    t_compress: float = 0.0,
    t_pack: float = 0.0,
    data_dependency: bool = False,
    ready_order: bool = False,
) -> dict:
    """Eq (6) with *real* per-bucket volumes from a ``CommSchedule``:
    compute time is spread over buckets proportionally to their numel
    (backward-pass order), communication times come from the planned
    collective bytes.  This is how the trainer's overlap headroom is
    estimated without compiling a step.

    ``ready_order=True`` lays the timeline out in the overlap engine's
    actual issue order (``bucketing.ReadyOrder``: head buckets first,
    embedding last) instead of plan order — the faithful model of the
    fused execution path.

    ``t_pack`` is the arena pack pass (:func:`pack_overhead_s`): like
    ``t_compress`` it rides on the compute lane, spread over buckets
    proportionally — each bucket's slot is packed right before its
    collective can issue.

    Sharded schedules (``schedule.sync == "sharded"``): the per-bucket
    backward timeline carries only the reduce-scatter half
    (``schedule.calls``); the deferred param all-gathers ride the NEXT
    step's forward pass, so they are exposed only to the extent they
    exceed ``t_before`` — the result gains ``deferred_comm`` and folds the
    uncovered remainder into ``exposed_comm``/``total``."""
    plan = schedule.plan
    numels = plan.bucket_numels()
    total = sum(numels) or 1
    comp = [(t_comp + t_compress + t_pack) * n / total for n in numels]
    comm = schedule_comm_times(schedule, world=world, link_bw=link_bw)
    if ready_order and schedule.granularity == "bucket":
        from .bucketing import build_ready_order

        order = build_ready_order(plan).order
        comp = [comp[b] for b in order]
        comm = [comm[b] for b in order]
    if data_dependency:
        t = t_before + sum(comp) + sum(comm)
        sim = {
            "total": t,
            "compute_end": t_before + sum(comp),
            "comm_end": t,
            "bubbles": 0.0,
            "exposed_comm": sum(comm),
            "comm_total": float(sum(comm)),
        }
    else:
        sim = simulate_overlap(t_before, comp, comm)
    if isinstance(link_bw, Mapping):
        t_deferred = sum(
            c.wire_bytes(world) / _bw_for(link_bw, c.link)
            for c in getattr(schedule, "deferred_calls", ())
        )
    else:
        deferred = getattr(schedule, "deferred_wire_bytes", None)
        t_deferred = deferred(world) / link_bw if deferred is not None else 0.0
    if t_deferred > 0.0:
        # the AG half hides under the forward pass (t_before) of the next
        # step; only the uncovered remainder extends the step
        uncovered = max(0.0, t_deferred - t_before)
        sim = dict(sim)
        sim["deferred_comm"] = t_deferred
        sim["exposed_comm"] = sim["exposed_comm"] + uncovered
        sim["comm_total"] = sim["comm_total"] + t_deferred
        sim["total"] = sim["total"] + uncovered
    return sim


def cycle_speedup(
    P: int,
    t_before: float,
    t_comp: float,
    schedules,
    *,
    world: int | None = None,
    link_bw: float,
    t_compress: float = 0.0,
    data_dependency: bool = False,
) -> float:
    """Mean speedup over one full phase cycle (period = num_phases steps),
    each phase simulated with its own planned volumes."""
    schedules = tuple(schedules)
    ls = t_before + t_comp
    totals = [
        simulate_schedule(
            t_before, t_comp, s,
            world=world if world is not None else max(P, 1),
            link_bw=link_bw, t_compress=t_compress,
            data_dependency=data_dependency,
        )["total"]
        for s in schedules
    ]
    mean_total = sum(totals) / max(len(totals), 1)
    return P * ls / mean_total


# ---- measured-trace calibration (adaptive runtime round-trip) ---------------

def calibrate_from_trace(trace: dict) -> dict:
    """Recover the perf model's inputs from a Chrome-trace dict produced by
    ``repro.runtime.trace.TimelineTracer`` — the measured timeline feeding
    back into the same model that planned it.

    Returns mean measured ``t_comp`` / ``t_comm`` / ``ccr`` over the
    trace's probe samples, mean full-step wall time, and — when measured
    comm events carry a ``bytes`` arg — the *effective link bandwidth*
    (bytes moved / aligned seconds).  ``t_comp`` plugs straight into
    :func:`simulate_schedule`; ``link_bw`` replaces the HardwareSpec
    estimate in :func:`schedule_comm_times`.
    """
    if isinstance(trace, dict):
        events = trace.get("traceEvents", [])
    else:
        events = list(trace)   # a bare event list is accepted too

    def spans(kind: str):
        return [
            e for e in events
            if e.get("ph") == "X" and kind in e.get("cat", "").split(",")
        ]

    def mean_dur(evs):
        return sum(e["dur"] for e in evs) / len(evs) / 1e6 if evs else None

    measured = [e for e in spans("measured")]
    comp = [e for e in measured if "compute" in e["cat"].split(",")]
    comm = [e for e in measured if "comm" in e["cat"].split(",")]
    coll = [e for e in measured if "collective" in e["cat"].split(",")]
    steps = [e for e in measured if "step" in e["cat"].split(",")]

    t_comp = mean_dur(comp)
    t_comm = mean_dur(comm)
    out = {
        "t_comp": t_comp,
        "t_comm": t_comm,
        "ccr": (
            t_comm / max(t_comp, 1e-12)
            if t_comp is not None and t_comm is not None
            else None
        ),
        "mean_step_s": mean_dur(steps),
        "num_samples": len(comm),
    }
    with_bytes = [
        e for e in comm + coll
        if e.get("args", {}).get("bytes") and e["dur"] > 0
    ]
    if with_bytes:
        total_bytes = sum(e["args"]["bytes"] for e in with_bytes)
        total_s = sum(e["dur"] for e in with_bytes) / 1e6
        out["link_bw"] = total_bytes / max(total_s, 1e-12)
    return out


@dataclasses.dataclass(frozen=True)
class SchemeProfile:
    """What the timeline model needs to know about a GC scheme."""

    name: str
    volume_ratio: float          # dense bytes / sent bytes
    compress_overhead_frac: float  # T_compress / T_comp
    data_dependency: bool = False
    allgather_based: bool = False  # scales worse with W (Fig. 11)

    def comm_scale(self, world: int) -> float:
        """AllGather traffic grows ~W/(2(W-1)/W) vs ring all-reduce."""
        if not self.allgather_based or world <= 1:
            return 1.0
        ring = 2.0 * (world - 1) / world
        return world / ring
