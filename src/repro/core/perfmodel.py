"""The paper's analytical performance model — equations (1)-(6) — plus the
bucket-timeline simulator used for Figs. 1/4/5/11.

All times in seconds; all speedups relative to single-worker linear scaling
(upper limit = P, the number of workers).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence


# ---- eq (1)/(2): plain DP ---------------------------------------------------

def t_dp(t_before: float, t_comp: float, t_comm: float) -> float:
    return t_before + t_comp + t_comm


def speedup_dp(P: int, t_before: float, t_comp: float, t_comm: float) -> float:
    """Eq (2): P * k / (k + CCR), k = T_before/T_comp + 1."""
    k = t_before / t_comp + 1.0
    ccr = t_comm / t_comp
    return P * k / (k + ccr)


# ---- eq (3): tensor-based overlapping timeline ------------------------------

def simulate_overlap(
    t_before: float,
    comp_times: Sequence[float],
    comm_times: Sequence[float],
) -> dict:
    """Simulate one iteration of bucketed overlapped DP (Fig. 1(b)/(d)).

    Bucket i's communication may start once (a) its gradients are computed
    and (b) the previous bucket's communication finished (collectives are
    ordered on the interconnect).  Returns total time + bubble accounting
    (the idle interconnect slots of eq (3))."""
    assert len(comp_times) == len(comm_times)
    t = t_before
    comm_free = t_before
    bubbles = 0.0
    for comp, comm in zip(comp_times, comm_times):
        t += comp  # gradient of this bucket ready
        start = max(t, comm_free)
        if comm > 0 and start > comm_free and comm_free > t_before:
            bubbles += start - comm_free
        comm_free = start + comm
    total = max(t, comm_free)
    return {
        "total": total,
        "compute_end": t,
        "comm_end": comm_free,
        "bubbles": bubbles,
        "exposed_comm": max(0.0, comm_free - t),
    }


def t_ovlp(t_before: float, t_comp: float, t_comm: float, n_buckets: int = 8) -> float:
    """Eq (4) via the simulator with uniform buckets."""
    comp = [t_comp / n_buckets] * n_buckets
    comm = [t_comm / n_buckets] * n_buckets
    return simulate_overlap(t_before, comp, comm)["total"]


def speedup_ovlp(P: int, t_before: float, t_comp: float, t_comm: float) -> float:
    ls = t_before + t_comp
    return P * ls / t_ovlp(t_before, t_comp, t_comm)


# ---- eq (5)/(6): GC and GC+overlap ------------------------------------------

def t_gc(
    t_before: float, t_comp: float, t_comm_gc: float, t_compress: float
) -> float:
    """Eq (5): compression is serial between compute and communication."""
    return t_before + t_comp + t_compress + t_comm_gc


def t_gc_ovlp(
    t_before: float,
    t_comp: float,
    t_comm_gc: float,
    t_compress: float,
    n_buckets: int = 8,
    data_dependency: bool = False,
) -> float:
    """Eq (6) via the simulator.  With ``data_dependency`` (Fig. 1(e)) the
    scheme's synchronous exchange serialises compression+communication after
    compute — overlap is lost (Ok-topk-style)."""
    if data_dependency:
        return t_before + t_comp + t_compress + t_comm_gc
    comp = [(t_comp + t_compress) / n_buckets] * n_buckets
    comm = [t_comm_gc / n_buckets] * n_buckets
    return simulate_overlap(t_before, comp, comm)["total"]


def speedup_gc_ovlp(
    P: int,
    t_before: float,
    t_comp: float,
    t_comm: float,
    *,
    volume_ratio: float,
    t_compress: float = 0.0,
    data_dependency: bool = False,
    n_buckets: int = 8,
) -> float:
    """Speedup of a GC scheme under overlapping; ``volume_ratio`` is the
    communication-volume compression factor (dense/sent)."""
    ls = t_before + t_comp
    total = t_gc_ovlp(
        t_before,
        t_comp,
        t_comm / max(volume_ratio, 1e-9),
        t_compress,
        n_buckets=n_buckets,
        data_dependency=data_dependency,
    )
    return P * ls / total


@dataclasses.dataclass(frozen=True)
class SchemeProfile:
    """What the timeline model needs to know about a GC scheme."""

    name: str
    volume_ratio: float          # dense bytes / sent bytes
    compress_overhead_frac: float  # T_compress / T_comp
    data_dependency: bool = False
    allgather_based: bool = False  # scales worse with W (Fig. 11)

    def comm_scale(self, world: int) -> float:
        """AllGather traffic grows ~W/(2(W-1)/W) vs ring all-reduce."""
        if not self.allgather_based or world <= 1:
            return 1.0
        ring = 2.0 * (world - 1) / world
        return world / ring
