"""Overlap execution engine: per-bucket collectives issued *inside* the
backward pass (the paper's Fig. 1(d) mechanism, executed rather than
simulated).

The post-hoc path (``SyncPipeline.execute``) runs every collective after
``value_and_grad`` returns, so compiled HLO serialises the whole exchange
behind the whole backward pass and overlap exists only in the perf model's
analytic timeline.  This module closes that gap:

* every bucket's parameter segments are routed through a ``jax.custom_vjp``
  **identity hook** at the top of the forward graph;
* the hook's backward rule receives exactly that bucket's gradient slices —
  which happens at the point of the backward trace where the bucket's last
  gradient is produced (``bucketing.ReadyOrder``'s reverse-topological
  readiness, realised structurally) — and calls the pipeline's granular
  :meth:`~repro.core.stages.SyncPipeline.execute_bucket` there, so the
  bucket's all-reduce enters the graph *before* the remaining backward
  compute and XLA's latency-hiding scheduler is free to interleave them;
* error feedback stays correct under hook-order execution: the residual is
  threaded in as a *differentiated input* whose only use is the hooks, so
  the cotangent JAX accumulates for it IS the new residual (selected
  buckets contribute the wire residual, unselected buckets the compensated
  gradient ``t``), bit-for-bit what the post-hoc path computes.

``launch.hlo_analysis.check_interleaving`` proves the mechanism on compiled
modules: with the hooks, at least one bucket collective is structurally
independent of the backward scan's while loop; post-hoc, none is.

With the zero-copy arena on (``use_arena`` compressor option /
``TrainConfig.arena``, DESIGN.md §12), the hook's backward sources its
payload from the bucket's contiguous arena slot instead of per-segment
collectives: ``execute_bucket`` packs the slices with the fused
``pack_ef_cast`` pass (EF compensation + wire cast + placement in one
sweep), issues ONE collective over the static slot view, and splits the
result with static slices — same bits, fewer copies, one collective per
bucket.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from . import bucketing as bk
from .schedule import CommSchedule
from .stages import SyncPipeline, _state_present


def supports_fused_overlap(compressor) -> bool:
    """Fused overlap needs bucket granularity and a segmented wire stage
    (COVAP / dense / fp16-cast): the hook's backward must be able to sync a
    bucket from its raw gradient slices alone.  Flat sparsifiers
    (value+index exchanges) and leaf-granularity schemes stay on the
    post-hoc path."""
    return (
        isinstance(compressor, SyncPipeline)
        and getattr(compressor, "granularity", "bucket") == "bucket"
        and getattr(compressor.wire, "segmented", False)
    )


def supports_sharded_sync(compressor) -> bool:
    """Sharded sync (reduce-scatter + deferred param all-gather, DESIGN.md
    §13) has the same structural requirement as fused overlap: a segmented
    bucket pipeline whose wire payload is a dense slot view the collective
    can partition evenly.  Value+index exchanges (top-k / sign / fp8
    gathers) and leaf-granularity schemes have no W-divisible dense buffer
    to scatter and stay on ``sync="allreduce"``."""
    return supports_fused_overlap(compressor)


def sharded_param_allgather(
    pipeline: SyncPipeline,
    schedule: CommSchedule,
    params: Any,
    *,
    axis_names: Sequence[str] = (),
) -> Any:
    """The deferred half of sharded sync: freshen EVERY bucket's parameters
    from their owners' updated shards (``schedule.deferred_calls``).

    After a sharded step, worker ``w``'s parameters are authoritative only
    on the shards ``w`` owns: for buckets selected that phase the owner
    applied the reduce-scattered gradient, and for every other
    once-selected bucket the optimizer's moment decay still moved the
    params — correctly only where the moments themselves are
    authoritative, i.e. on the owned shard again.  So the gather covers
    the whole plan, exactly like ZeRO's per-step parameter all-gather,
    not just the previous phase's selected buckets.  (Before a bucket's
    first selection its moments are zero and every worker computes the
    identical zero update, which is why the full-coverage gather is
    correct from step 0 — it rebroadcasts values that already agree.)

    Each bucket's param segments are packed into its W-aligned slot
    (promoted bucket dtype — params go on the wire uncompressed), the
    locally-owned shard sliced out, the shards all-gathered
    (``comm.all_gather_tiled``), and the leaves rebuilt with
    ``arena.gather_leaves``.

    Issued at the HEAD of the step — before the forward pass touches any
    parameter — so XLA's latency-hiding scheduler can overlap the gathers
    with forward compute; that placement is what makes the AG half of the
    schedule's bytes *deferred* rather than exposed.  Identity with no
    axes (single worker).
    """
    from . import arena as ar
    from .comm import all_gather_tiled, axis_size, flat_axis_index

    if not axis_names or schedule.plan is None:
        return params
    plan = schedule.plan
    W = 1
    for a in axis_names:
        W *= axis_size(a)
    layout = ar.build_layout(plan, align=W)
    treedef = jax.tree_util.tree_structure(params)
    leaves = jax.tree_util.tree_leaves(params)
    planes = ar.pack_leaves(layout, leaves)
    w_idx = flat_axis_index(axis_names)
    fresh_pieces = {}
    for b in range(plan.num_buckets):
        with jax.named_scope(f"covap_param_ag_bucket_{b}"):
            view = layout.bucket_view(planes, b)
            S = view.shape[0] // W
            shard = jax.lax.dynamic_slice_in_dim(view, w_idx * S, S)
            full = all_gather_tiled(shard, axis_names)
            fresh_pieces[b] = layout.unpack_bucket(b, full)
    out_leaves = ar.gather_leaves(
        plan, lambda b, si, seg: fresh_pieces[b][si], leaves
    )
    return jax.tree_util.tree_unflatten(treedef, out_leaves)


def _assert_full_coverage(plan: bk.BucketPlan) -> None:
    """Every leaf element must be owned by exactly one bucket segment —
    otherwise some gradient would bypass the hooks unsynced."""
    covered = [0] * len(plan.leaf_shapes)
    for bucket in plan.buckets:
        for seg in bucket.segments:
            covered[seg.leaf_idx] += seg.numel(plan.leaf_shapes[seg.leaf_idx])
    for li, (shape, got) in enumerate(zip(plan.leaf_shapes, covered)):
        import numpy as np

        want = int(np.prod(shape, dtype=np.int64)) if shape else 1
        if got != want:
            raise ValueError(
                f"bucket plan covers {got}/{want} elements of leaf "
                f"{plan.leaf_paths[li]} — cannot install gradient hooks"
            )


def _make_bucket_hook(
    pipeline: SyncPipeline,
    schedule: CommSchedule,
    b: int,
    *,
    ef_on: bool,
    axis_names: Sequence[str],
):
    """A custom_vjp identity over one bucket's segment slices whose backward
    performs that bucket's synchronisation.

    Signature: ``hook(xs, rs, coeff) -> xs`` where ``xs`` are the param
    slices, ``rs`` the residual slices (``()`` without EF) and ``coeff`` the
    compensation coefficient (dummy scalar without EF).  The backward
    returns the globally-synced gradient as the cotangent of ``xs`` and the
    new residual as the cotangent of ``rs``.
    """

    @jax.custom_vjp
    def hook(xs, rs, coeff):
        return xs

    def fwd(xs, rs, coeff):
        return xs, (rs, coeff)

    def bwd(res, g_xs):
        rs, coeff = res
        # named_scope is metadata-only (no ops added, bits unchanged); it
        # labels this bucket's collective issue in XLA/Perfetto profiles
        # so comm attributes to buckets, not one anonymous backward blob.
        with jax.named_scope(f"covap_bucket_{b}/phase_{schedule.phase}"):
            synced, resids = pipeline.execute_bucket(
                schedule, b,
                list(g_xs),
                list(rs) if ef_on else None,
                coeff=coeff if ef_on else None,
                axis_names=axis_names,
            )
        if synced is None:  # unselected bucket: nothing crosses the wire
            g_cot = tuple(jnp.zeros_like(g) for g in g_xs)
        else:
            g_cot = tuple(
                x.astype(g.dtype) for x, g in zip(synced, g_xs)
            )
        if ef_on:
            r_cot = tuple(
                rr.astype(r.dtype) for rr, r in zip(resids, rs)
            )
        else:
            r_cot = ()
        return g_cot, r_cot, jnp.zeros_like(coeff)

    hook.defvjp(fwd, bwd)
    return hook


def install_hooks(
    pipeline: SyncPipeline,
    schedule: CommSchedule,
    params: Any,
    residual: Any,
    coeff,
    *,
    axis_names: Sequence[str] = (),
) -> Any:
    """Rebuild ``params`` with every bucket's segments routed through its
    gradient-ready hook.  Forward values are bitwise-identical (pure data
    movement); backward cotangents become the synced gradients."""
    plan = schedule.plan
    _assert_full_coverage(plan)
    ef_on = residual is not None
    treedef = jax.tree_util.tree_structure(params)
    leaves = jax.tree_util.tree_leaves(params)
    r_leaves = jax.tree_util.tree_leaves(residual) if ef_on else None
    coeff_arr = (
        jnp.asarray(coeff, jnp.float32) if ef_on else jnp.float32(0.0)
    )
    out = list(leaves)
    for bucket in plan.buckets:
        segs = bucket.segments
        xs = tuple(bk._slice_segment(leaves[s.leaf_idx], s) for s in segs)
        rs = (
            tuple(bk._slice_segment(r_leaves[s.leaf_idx], s) for s in segs)
            if ef_on else ()
        )
        hook = _make_bucket_hook(
            pipeline, schedule, bucket.index,
            ef_on=ef_on, axis_names=axis_names,
        )
        ys = hook(xs, rs, coeff_arr)
        for s, y in zip(segs, ys):
            out[s.leaf_idx] = bk._update_segment(out[s.leaf_idx], s, y)
    return jax.tree_util.tree_unflatten(treedef, out)


def overlapped_loss_and_grads(
    model,
    pipeline: SyncPipeline,
    schedule: CommSchedule,
    params: Any,
    comp_state: Any,
    batch: Any,
    step,
    *,
    axis_names: Sequence[str] = (),
):
    """The fused train-step core: one ``value_and_grad`` whose backward
    trace contains the phase's collectives at their readiness points.

    Returns ``(loss, metrics, synced_grads, new_comp_state)`` — the same
    contract as ``_loss_and_grads`` + ``pipeline.execute``, bit-for-bit.

    The EF residual rides along as a second differentiated argument: it
    never affects the loss (the hooks are identities on the params), so the
    gradient JAX computes for it is exactly the sum of the per-bucket
    residual cotangents — the new residual tree.
    """
    if not supports_fused_overlap(pipeline):
        raise ValueError(
            f"fused overlap supports segmented bucket pipelines "
            f"(COVAP/dense/wire-cast); got {pipeline!r} — use overlap='post'"
        )
    ef_on = pipeline.ef is not None and _state_present(comp_state)
    coeff = pipeline.ef_coefficient(step) if ef_on else None

    if ef_on:

        def lf(p, r):
            hooked = install_hooks(
                pipeline, schedule, p, r, coeff, axis_names=axis_names
            )
            return model.loss_fn(hooked, batch)

        (loss, metrics), (synced, new_r) = jax.value_and_grad(
            lf, argnums=(0, 1), has_aux=True
        )(params, comp_state)
        return loss, metrics, synced, new_r

    def lf0(p):
        hooked = install_hooks(
            pipeline, schedule, p, None, None, axis_names=axis_names
        )
        return model.loss_fn(hooked, batch)

    (loss, metrics), synced = jax.value_and_grad(lf0, has_aux=True)(params)
    return loss, metrics, synced, comp_state


__all__ = [
    "install_hooks",
    "overlapped_loss_and_grads",
    "sharded_param_allgather",
    "supports_fused_overlap",
    "supports_sharded_sync",
]
