"""The telemetry bundle: one handle tying the three views together.

A :class:`Telemetry` instance owns a :class:`~repro.obs.registry.MetricsRegistry`
(how much / how fast), an :class:`~repro.obs.events.EventLog` (why), and a
:class:`~repro.runtime.trace.TimelineTracer` (when) so train, serve, and the
adaptive runtime all write into the same sinks and ``save()`` drops one
coherent telemetry directory:

* ``metrics.prom``  — Prometheus textfile exposition of the registry;
* ``metrics.json``  — the flat ``snapshot()`` dict (BENCH-key shaped);
* ``events.jsonl``  — streamed as events happen (crash-safe), schema-valid;
* ``trace.json``    — Chrome trace with planned / measured / control /
  serve process rows, openable in Perfetto.

``as_telemetry`` is the coercion every entry point (``Trainer.run``,
``Engine``, ``api.fit``, the launchers) routes through: ``None`` → the
shared disabled singleton (near-zero overhead), a path string → a
directory-backed bundle, an existing bundle → itself.
"""
from __future__ import annotations

import json
import os

from repro.obs.events import NULL_EVENTS, EventLog
from repro.obs.registry import NULL_REGISTRY, MetricsRegistry
from repro.runtime.trace import TimelineTracer


class Telemetry:
    """Bundle of registry + event log + tracer sharing one run identity.

    ``directory=None`` keeps everything in memory (events buffer in
    ``events.records``; ``save(path)`` can still export later).  With a
    directory, events stream to ``events.jsonl`` immediately and
    ``save()`` writes the remaining artifacts there.
    """

    def __init__(
        self,
        directory: str | None = None,
        *,
        enabled: bool = True,
        run_id: str | None = None,
        max_trace_events: int = 100_000,
        hist_window: int = 1024,
    ):
        self.enabled = bool(enabled)
        self.directory = directory
        if self.enabled and directory is not None:
            os.makedirs(directory, exist_ok=True)
        events_path = (
            os.path.join(directory, "events.jsonl")
            if (self.enabled and directory is not None)
            else None
        )
        self.registry = MetricsRegistry(
            enabled=self.enabled, hist_window=hist_window
        )
        self.events = EventLog(
            events_path, run_id=run_id, enabled=self.enabled
        )
        self.tracer = TimelineTracer(max_events=max_trace_events)
        self._manifest_done = False

    # Manifest is once-per-bundle: chunked launcher loops call
    # ``Trainer.run`` repeatedly against the same telemetry handle.
    def manifest_once(self, **fields) -> bool:
        if not self.enabled or self._manifest_done:
            return False
        self.events.emit("manifest", **fields)
        self._manifest_done = True
        return True

    def save(self, directory: str | None = None) -> dict | None:
        """Write ``metrics.prom`` / ``metrics.json`` / ``trace.json`` (and,
        for memory-backed bundles, ``events.jsonl``) into ``directory``
        (default: the bundle's own).  Returns ``{artifact: path}``."""
        if not self.enabled:
            return None
        directory = directory or self.directory
        if directory is None:
            raise ValueError("telemetry has no directory; pass one to save()")
        os.makedirs(directory, exist_ok=True)
        paths = {}
        prom = os.path.join(directory, "metrics.prom")
        with open(prom, "w") as f:
            f.write(self.registry.to_prometheus_text())
        paths["prom"] = prom
        snap = os.path.join(directory, "metrics.json")
        with open(snap, "w") as f:
            json.dump(self.registry.snapshot(), f, indent=1, sort_keys=True)
        paths["snapshot"] = snap
        trace = os.path.join(directory, "trace.json")
        self.tracer.save(trace)
        paths["trace"] = trace
        events = os.path.join(directory, "events.jsonl")
        if self.events.path is None and self.events.records:
            with open(events, "w") as f:
                for rec in self.events.records:
                    f.write(json.dumps(rec) + "\n")
            paths["events"] = events
        elif self.events.path is not None:
            paths["events"] = self.events.path
        return paths

    def close(self) -> None:
        self.events.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


NULL_TELEMETRY = Telemetry(enabled=False)


def as_telemetry(obj) -> Telemetry:
    """Coerce the user-facing ``telemetry=`` argument to a bundle:
    ``None`` → shared disabled singleton, ``str`` path → directory-backed
    bundle, ``Telemetry`` → itself."""
    if obj is None:
        return NULL_TELEMETRY
    if isinstance(obj, Telemetry):
        return obj
    if isinstance(obj, str):
        return Telemetry(obj)
    raise TypeError(
        f"telemetry must be None, a directory path, or a Telemetry bundle; "
        f"got {type(obj).__name__}"
    )


__all__ = ["NULL_TELEMETRY", "Telemetry", "as_telemetry"]
