"""Metrics registry: labeled counters / gauges / histograms with a
Prometheus textfile exposition and a flat ``snapshot()`` digest.

Design constraints (DESIGN.md §15):

* **Near-zero overhead when disabled** — a disabled registry hands out one
  shared null instrument whose methods are no-ops; the hot path pays a
  dict lookup at *instrument creation* time only, never per observation.
  Callers hold the instrument, not the registry, so the per-step cost of
  ``counter.inc()`` on an enabled registry is one float add.
* **Host-side only** — instruments record Python floats.  Nothing here
  touches a jax trace; recording a device array forces a sync, so callers
  convert at points that already block (log cadence, probe steps).
* **The snapshot is the source of truth** — ``snapshot()`` flattens every
  instrument into ``{name_or_name{labels}: value}``; ``benchmarks.run``
  builds ``BENCH_<n>.json`` from exactly this dict, so a perf key exists
  in the snapshot iff some instrument recorded it.

Histograms keep a bounded window of recent observations (ring buffer, the
monitor's discipline) for streaming p50/p99, plus exact running
count/sum/min/max over the full life of the instrument.
"""
from __future__ import annotations

import collections
import math
from typing import Iterable


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


def _label_str(labels: tuple) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape(str(v))}"' for k, v in labels)
    return "{" + inner + "}"


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


class _NullInstrument:
    """Shared no-op instrument of a disabled registry: every mutator is a
    method on this one object, so the disabled path costs one attribute
    call and returns immediately."""

    __slots__ = ()

    def inc(self, value: float = 1.0) -> None:
        pass

    def set(self, value) -> None:
        pass

    def observe(self, value: float) -> None:
        pass


NULL_INSTRUMENT = _NullInstrument()


class Counter:
    __slots__ = ("value",)
    kind = "counter"

    def __init__(self):
        self.value = 0.0

    def inc(self, value: float = 1.0) -> None:
        self.value += value


class Gauge:
    """Last-write-wins scalar.  ``None`` is a legal value: a gauge that was
    planned but never measured stays in the snapshot as ``None`` (the
    BENCH trajectory gate skips non-numeric values)."""

    __slots__ = ("value",)
    kind = "gauge"

    def __init__(self):
        self.value = None

    def set(self, value) -> None:
        self.value = None if value is None else float(value)


class Histogram:
    """Streaming distribution: exact count/sum/min/max over everything
    observed, p50/p99 over the most recent ``window`` observations."""

    __slots__ = ("count", "sum", "min", "max", "_window")
    kind = "histogram"

    def __init__(self, window: int = 1024):
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._window: collections.deque = collections.deque(maxlen=int(window))

    def observe(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        self._window.append(v)

    def percentile(self, q: float) -> float | None:
        """q in [0, 100] over the retained window (nearest-rank)."""
        if not self._window:
            return None
        xs = sorted(self._window)
        rank = max(0, min(len(xs) - 1, math.ceil(q / 100.0 * len(xs)) - 1))
        return xs[rank]

    def stats(self) -> dict:
        if self.count == 0:
            return {"count": 0, "sum": 0.0, "min": None, "max": None,
                    "p50": None, "p99": None}
        return {
            "count": self.count,
            "sum": self.sum,
            "min": self.min,
            "max": self.max,
            "p50": self.percentile(50),
            "p99": self.percentile(99),
        }


class MetricsRegistry:
    """One namespace of instruments.  ``counter/gauge/histogram`` are
    get-or-create: the same (name, labels) always returns the same
    instrument, and re-registering a name as a different kind raises."""

    def __init__(self, enabled: bool = True, *, hist_window: int = 1024):
        self.enabled = bool(enabled)
        self.hist_window = int(hist_window)
        # name -> (kind, help, {label_key: instrument})
        self._families: dict[str, tuple[str, str, dict]] = {}

    # ---- instrument creation ---------------------------------------------
    def _get(self, cls, name: str, help: str, labels: dict):
        if not self.enabled:
            return NULL_INSTRUMENT
        kind = cls.kind
        fam = self._families.get(name)
        if fam is None:
            fam = (kind, help, {})
            self._families[name] = fam
        elif fam[0] != kind:
            raise ValueError(
                f"metric {name!r} already registered as {fam[0]}, "
                f"cannot re-register as {kind}"
            )
        key = _label_key(labels)
        inst = fam[2].get(key)
        if inst is None:
            inst = cls(self.hist_window) if cls is Histogram else cls()
            fam[2][key] = inst
        return inst

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "", **labels) -> Histogram:
        return self._get(Histogram, name, help, labels)

    # ---- read side --------------------------------------------------------
    def families(self) -> Iterable[tuple[str, str, str, dict]]:
        for name in sorted(self._families):
            kind, help, insts = self._families[name]
            yield name, kind, help, insts

    def snapshot(self) -> dict:
        """Flat ``{key: value}`` digest.  Un-labeled instruments use their
        bare name (this is what makes a registry gauge a ``BENCH_<n>.json``
        key); labeled ones append ``{k="v",...}``.  Histograms expand into
        ``_count/_sum/_min/_max/_p50/_p99`` sub-keys."""
        out: dict = {}
        for name, kind, _help, insts in self.families():
            for key, inst in sorted(insts.items()):
                full = name + _label_str(key)
                if kind == "histogram":
                    for stat, v in inst.stats().items():
                        out[f"{full}_{stat}"] = v
                else:
                    out[full] = inst.value
        return out

    def to_prometheus_text(self) -> str:
        """Prometheus textfile exposition (node-exporter textfile-collector
        compatible).  Histograms are exported as summaries (quantile
        labels) since the window percentiles are precomputed."""
        lines: list[str] = []
        for name, kind, help, insts in self.families():
            if help:
                lines.append(f"# HELP {name} {_escape(help)}")
            lines.append(
                f"# TYPE {name} {'summary' if kind == 'histogram' else kind}"
            )
            for key, inst in sorted(insts.items()):
                if kind == "histogram":
                    st = inst.stats()
                    for q, stat in (("0.5", "p50"), ("0.99", "p99")):
                        if st[stat] is not None:
                            qkey = key + (("quantile", q),)
                            lines.append(
                                f"{name}{_label_str(qkey)} {st[stat]:g}"
                            )
                    lines.append(f"{name}_sum{_label_str(key)} {st['sum']:g}")
                    lines.append(f"{name}_count{_label_str(key)} {st['count']}")
                elif inst.value is not None:
                    lines.append(f"{name}{_label_str(key)} {inst.value:g}")
        return "\n".join(lines) + "\n"


NULL_REGISTRY = MetricsRegistry(enabled=False)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_INSTRUMENT",
    "NULL_REGISTRY",
]
