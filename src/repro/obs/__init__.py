"""Unified telemetry: metrics registry, structured event log, span tracing.

Three complementary views of one run (DESIGN.md §15):

* :class:`MetricsRegistry` — labeled counters / gauges / histograms;
  ``snapshot()`` is the single source of ``BENCH_<n>.json`` keys and
  ``to_prometheus_text()`` the scrape-side exposition.
* :class:`EventLog` — append-only JSONL narrative (manifest, steps,
  probes, the replan decision audit trail), schema-validated at emit time
  against ``event_schema.json``.
* :class:`~repro.runtime.trace.TimelineTracer` — Chrome-trace spans:
  planned per-bucket timelines, measured decompositions, control marks,
  and per-request serve spans, all in one Perfetto-openable file.

:class:`Telemetry` bundles the three behind one handle; ``telemetry=``
arguments throughout the codebase accept ``None`` / a directory path /
a bundle via :func:`as_telemetry`.
"""
from repro.obs.events import (
    NULL_EVENTS,
    SCHEMA_PATH,
    EventLog,
    load_schema,
    plan_digest,
    validate_event,
)
from repro.obs.registry import (
    NULL_INSTRUMENT,
    NULL_REGISTRY,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.telemetry import NULL_TELEMETRY, Telemetry, as_telemetry

__all__ = [
    "Counter",
    "EventLog",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_EVENTS",
    "NULL_INSTRUMENT",
    "NULL_REGISTRY",
    "NULL_TELEMETRY",
    "SCHEMA_PATH",
    "Telemetry",
    "as_telemetry",
    "load_schema",
    "plan_digest",
    "validate_event",
]
