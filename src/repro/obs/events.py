"""Structured JSONL event log: the narrative half of telemetry.

The metrics registry answers "how much / how fast"; this log answers
*why* — it records the run manifest (config, mesh, plan digest), per-step
records, checkpoint/flush boundaries, and the :class:`ReplanController`'s
full decision audit trail (measured CCR, hysteresis state, chosen
interval), so every re-plan in a run is explainable after the fact instead
of reconstructed from prints.

Every line is one JSON object and validates against the checked-in schema
(``event_schema.json``, enforced at emit time and re-checked by the
``benchmarks/obs_check.py`` smoke gate).  The schema is deliberately a
small declarative format — required/optional field names with primitive
types per event kind — validated by :func:`validate_event` with no
third-party dependency.

With no ``path`` the log buffers in memory (``records``), which is what
``api.fit(telemetry=...)`` hands back for interactive inspection; with a
path each event is appended (and flushed) as it happens, so a crashed run
keeps everything up to the crash.
"""
from __future__ import annotations

import hashlib
import json
import os
import time
from typing import Any

SCHEMA_PATH = os.path.join(os.path.dirname(__file__), "event_schema.json")

_TYPE_CHECKS = {
    "number": lambda v: isinstance(v, (int, float)) and not isinstance(v, bool),
    "integer": lambda v: isinstance(v, int) and not isinstance(v, bool),
    "string": lambda v: isinstance(v, str),
    "boolean": lambda v: isinstance(v, bool),
    "object": lambda v: isinstance(v, dict),
    "array": lambda v: isinstance(v, list),
    "null": lambda v: v is None,
}

_schema_cache: dict | None = None


def load_schema() -> dict:
    global _schema_cache
    if _schema_cache is None:
        with open(SCHEMA_PATH) as f:
            _schema_cache = json.load(f)
    return _schema_cache


def _check_type(value: Any, typ: str) -> bool:
    if typ.endswith("?"):
        if value is None:
            return True
        typ = typ[:-1]
    return _TYPE_CHECKS[typ](value)


def validate_event(event: dict, schema: dict | None = None) -> list[str]:
    """Validate one event dict against the schema; returns a list of error
    strings (empty = valid).  Checks: base fields present and typed, kind
    known, per-kind required fields present and typed, optional fields
    typed when present.  Unknown extra fields are allowed (forward
    compatibility) — the schema pins what consumers may rely on."""
    schema = schema or load_schema()
    errors: list[str] = []
    if not isinstance(event, dict):
        return [f"event is {type(event).__name__}, expected object"]
    for field, typ in schema["base"].items():
        if field not in event:
            errors.append(f"missing base field {field!r}")
        elif not _check_type(event[field], typ):
            errors.append(f"base field {field!r} is not {typ}")
    kind = event.get("kind")
    if not isinstance(kind, str):
        return errors
    spec = schema["kinds"].get(kind)
    if spec is None:
        errors.append(f"unknown event kind {kind!r}")
        return errors
    for field, typ in spec.get("required", {}).items():
        if field not in event:
            errors.append(f"{kind}: missing required field {field!r}")
        elif not _check_type(event[field], typ):
            errors.append(f"{kind}: field {field!r} is not {typ}")
    for field, typ in spec.get("optional", {}).items():
        if field in event and not _check_type(event[field], typ):
            errors.append(f"{kind}: optional field {field!r} is not {typ}")
    return errors


def plan_digest(plan) -> str:
    """Stable short digest of a ``BucketPlan``'s structure — enough to tell
    after the fact whether two runs (or two sides of a re-plan) executed
    the same bucketing, without storing the whole plan."""
    h = hashlib.sha256()
    h.update(str(plan.interval_hint).encode())
    for bucket in plan.buckets:
        h.update(str(bucket.numel).encode())
        for seg in bucket.segments:
            h.update(
                f"{seg.leaf_idx}:{seg.row_lo}:{seg.row_hi}:"
                f"{seg.sub_axis}:{seg.sub_lo}:{seg.sub_hi}".encode()
            )
    return h.hexdigest()[:16]


def _jsonable(v):
    """Best-effort coercion of config-ish values to JSON."""
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


class EventLog:
    """Append-only JSONL event stream with emit-time schema validation.

    ``enabled=False`` (or the shared :data:`NULL_EVENTS`) turns ``emit``
    into an early-return — the disabled cost is one attribute check."""

    def __init__(
        self,
        path: str | None = None,
        *,
        run_id: str | None = None,
        enabled: bool = True,
        validate: bool = True,
        max_records: int = 100_000,
        clock=time.time,
    ):
        self.enabled = bool(enabled)
        self.path = path
        self.run_id = run_id or f"run-{os.getpid()}-{int(clock() * 1e3):x}"
        self.validate = bool(validate)
        self.clock = clock
        self.records: list[dict] = []      # in-memory tail (bounded ring)
        self._max_records = int(max_records)
        self._fh = None
        if self.enabled and path is not None:
            os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
            self._fh = open(path, "a")

    def emit(self, kind: str, **fields) -> dict | None:
        """Record one event; returns the event dict (or None when
        disabled).  Raises ``ValueError`` on schema violations when
        ``validate`` — a malformed event is a bug at the call site, not
        something to discover when the JSONL is consumed."""
        if not self.enabled:
            return None
        event = {"ts": float(self.clock()), "kind": kind,
                 "run_id": self.run_id}
        event.update({k: _jsonable(v) for k, v in fields.items()})
        if self.validate:
            errors = validate_event(event)
            if errors:
                raise ValueError(
                    f"invalid {kind!r} event: " + "; ".join(errors)
                )
        self.records.append(event)
        if len(self.records) > self._max_records:
            del self.records[: len(self.records) - self._max_records]
        if self._fh is not None:
            self._fh.write(json.dumps(event) + "\n")
            self._fh.flush()
        return event

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


NULL_EVENTS = EventLog(enabled=False)

__all__ = [
    "EventLog",
    "NULL_EVENTS",
    "SCHEMA_PATH",
    "load_schema",
    "plan_digest",
    "validate_event",
]
