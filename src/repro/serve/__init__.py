"""Serving substrate: batched KV-cache engine (prefill + decode steps)."""
from .engine import Engine, ServeConfig, greedy_sample

__all__ = ["Engine", "ServeConfig", "greedy_sample"]
