"""Serving subsystem: continuous batching over a statically-planned paged
KV arena, with chunked prefill -> insert -> generate stages and a
synthetic-traffic harness (see DESIGN.md §14)."""
from .engine import Engine, ServeConfig, build_generate_fn, greedy_sample
from .kv_arena import (
    KVArena,
    KVLayout,
    PagePool,
    build_insert_fn,
    gather_caches,
    plan_kv_layout,
    scatter_step,
)
from .prefill import ChunkedPrefill
from .scheduler import (
    FINISH_EOS,
    FINISH_LENGTH,
    FINISH_REJECTED,
    FINISH_TRUNCATED,
    Completion,
    Request,
    Scheduler,
)
from .traffic import TrafficConfig, TrafficReport, run_traffic, sweep

__all__ = [
    "ChunkedPrefill",
    "Completion",
    "Engine",
    "FINISH_EOS",
    "FINISH_LENGTH",
    "FINISH_REJECTED",
    "FINISH_TRUNCATED",
    "KVArena",
    "KVLayout",
    "PagePool",
    "Request",
    "Scheduler",
    "ServeConfig",
    "TrafficConfig",
    "TrafficReport",
    "build_generate_fn",
    "build_insert_fn",
    "gather_caches",
    "greedy_sample",
    "plan_kv_layout",
    "run_traffic",
    "scatter_step",
    "sweep",
]
