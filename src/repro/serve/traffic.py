"""Synthetic high-QPS traffic for the serving engine.

"Millions of users" needs a measurable proxy: this module generates
Poisson arrivals at a target rate, pumps them through an
:class:`~repro.serve.engine.Engine` on the wall clock, and aggregates each
request's :class:`~repro.serve.scheduler.Completion` ledger into the
latency numbers that matter for serving (p50/p99 end-to-end latency,
time-to-first-token, sustained tokens/sec).  ``sweep`` repeats the run
across arrival rates on one engine (reset between rates, compiled
executables reused) to expose the saturation knee.

Shed-and-retry (DESIGN.md §16): when the engine load-sheds
(``finish_reason="rejected"``, ``ServeConfig.max_queue``), the pump
resubmits up to ``max_retries`` times with exponential backoff
(``retry_backoff_s`` doubling per attempt) — the client half of graceful
degradation.  Latency is always measured from the ORIGINAL scheduled
arrival, so retries show up as honest tail latency, not as a reset clock.
With ``max_retries=0`` (default) a rejection is final and the pump
behaves exactly as before.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Callable

import numpy as np


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    qps: float = 8.0
    num_requests: int = 16
    prompt_len: tuple[int, int] = (4, 12)   # inclusive range
    vocab_size: int = 128
    seed: int = 0
    max_retries: int = 0           # resubmits per request after a rejection
    retry_backoff_s: float = 0.05  # first backoff; doubles per attempt


@dataclasses.dataclass(frozen=True)
class TrafficReport:
    qps: float
    num_requests: int
    generated_tokens: int
    makespan_s: float
    p50_ms: float
    p99_ms: float
    ttft_p50_ms: float
    tokens_per_s: float
    finish_reasons: dict[str, int]
    retries: int = 0               # total resubmissions across all requests

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def synth_requests(cfg: TrafficConfig) -> list[tuple[float, list[int]]]:
    """(arrival_offset_s, prompt) pairs with exponential inter-arrival
    gaps — a Poisson process at ``cfg.qps``."""
    rng = np.random.default_rng(cfg.seed)
    gaps = rng.exponential(1.0 / cfg.qps, size=cfg.num_requests)
    arrivals = np.cumsum(gaps)
    lo, hi = cfg.prompt_len
    out = []
    for a in arrivals:
        n = int(rng.integers(lo, hi + 1))
        prompt = rng.integers(1, cfg.vocab_size, size=n).tolist()
        out.append((float(a), [int(t) for t in prompt]))
    return out


def _percentile(xs: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


def run_traffic(engine, cfg: TrafficConfig) -> TrafficReport:
    """Open-loop pump: requests are submitted at their scheduled wall-clock
    arrival whether or not the engine has caught up (queueing delay is part
    of the measured latency, as it would be for real traffic).  Rejected
    submissions are resubmitted with exponential backoff up to
    ``cfg.max_retries`` times; the FINAL completion (retried or not) is
    what lands in the latency aggregate, timed from the original arrival.
    """
    plan = synth_requests(cfg)
    submitted = 0
    live: dict[int, int] = {}       # rid -> plan index, awaiting completion
    final: dict[int, object] = {}   # plan index -> terminal Completion
    attempts = [0] * len(plan)
    retry_heap: list[tuple[float, int]] = []   # (due rel-time, plan index)
    retries_total = 0
    t0 = time.perf_counter()
    while len(final) < len(plan):
        now = time.perf_counter() - t0
        while submitted < len(plan) and plan[submitted][0] <= now:
            live[engine.submit(plan[submitted][1])] = submitted
            submitted += 1
        while retry_heap and retry_heap[0][0] <= now:
            _, idx = heapq.heappop(retry_heap)
            live[engine.submit(plan[idx][1])] = idx
        if engine.busy:
            engine.step()
        # resolve: rejected -> maybe retry; anything else is terminal
        for rid in [r for r in live if r in engine.results]:
            comp = engine.results[rid]
            idx = live.pop(rid)
            if (
                comp.finish_reason == "rejected"
                and attempts[idx] < cfg.max_retries
            ):
                attempts[idx] += 1
                retries_total += 1
                due = (time.perf_counter() - t0) + cfg.retry_backoff_s * (
                    2 ** (attempts[idx] - 1)
                )
                heapq.heappush(retry_heap, (due, idx))
            else:
                final[idx] = comp
        if not engine.busy and len(final) < len(plan):
            waits = []
            if submitted < len(plan):
                waits.append(plan[submitted][0] - now)
            if retry_heap:
                waits.append(retry_heap[0][0] - now)
            if waits:
                time.sleep(min(0.05, max(0.0, min(waits))))
    t_end = time.perf_counter()

    lat, ttft, reasons = [], [], {}
    gen_tokens = 0
    for idx, (arr, _prompt) in enumerate(plan):
        comp = final[idx]
        sched_s = t0 + arr  # ORIGINAL scheduled arrival, not any resubmit
        lat.append(comp.finish_s - sched_s)
        ttft.append(comp.first_token_s - sched_s)
        gen_tokens += len(comp.tokens)
        reasons[comp.finish_reason] = reasons.get(comp.finish_reason, 0) + 1
    makespan = max(t_end - t0, 1e-9)
    report = TrafficReport(
        qps=cfg.qps,
        num_requests=len(plan),
        generated_tokens=gen_tokens,
        makespan_s=makespan,
        p50_ms=1e3 * _percentile(lat, 50),
        p99_ms=1e3 * _percentile(lat, 99),
        ttft_p50_ms=1e3 * _percentile(ttft, 50),
        tokens_per_s=gen_tokens / makespan,
        finish_reasons=reasons,
        retries=retries_total,
    )
    tel = getattr(engine, "telemetry", None)
    if tel is not None and tel.enabled:
        tel.events.emit("serve_report", **report.as_dict())
        for k in ("p50_ms", "p99_ms", "ttft_p50_ms", "tokens_per_s"):
            tel.registry.gauge(
                f"serve_traffic_{k}", "last traffic-run aggregate",
                qps=f"{cfg.qps:g}",
            ).set(getattr(report, k))
    return report


def sweep(engine, qps_rates, base: TrafficConfig) -> list[TrafficReport]:
    """Arrival-rate sweep on one engine (reset between rates — compiled
    executables are reused, only arena/queue state is rebuilt)."""
    reports = []
    for r in qps_rates:
        engine.reset()
        cfg = dataclasses.replace(base, qps=float(r))
        reports.append(run_traffic(engine, cfg))
    return reports


__all__ = ["TrafficConfig", "TrafficReport", "run_traffic", "sweep", "synth_requests"]
