"""Synthetic high-QPS traffic for the serving engine.

"Millions of users" needs a measurable proxy: this module generates
Poisson arrivals at a target rate, pumps them through an
:class:`~repro.serve.engine.Engine` on the wall clock, and aggregates each
request's :class:`~repro.serve.scheduler.Completion` ledger into the
latency numbers that matter for serving (p50/p99 end-to-end latency,
time-to-first-token, sustained tokens/sec).  ``sweep`` repeats the run
across arrival rates on one engine (reset between rates, compiled
executables reused) to expose the saturation knee.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    qps: float = 8.0
    num_requests: int = 16
    prompt_len: tuple[int, int] = (4, 12)   # inclusive range
    vocab_size: int = 128
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class TrafficReport:
    qps: float
    num_requests: int
    generated_tokens: int
    makespan_s: float
    p50_ms: float
    p99_ms: float
    ttft_p50_ms: float
    tokens_per_s: float
    finish_reasons: dict[str, int]

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def synth_requests(cfg: TrafficConfig) -> list[tuple[float, list[int]]]:
    """(arrival_offset_s, prompt) pairs with exponential inter-arrival
    gaps — a Poisson process at ``cfg.qps``."""
    rng = np.random.default_rng(cfg.seed)
    gaps = rng.exponential(1.0 / cfg.qps, size=cfg.num_requests)
    arrivals = np.cumsum(gaps)
    lo, hi = cfg.prompt_len
    out = []
    for a in arrivals:
        n = int(rng.integers(lo, hi + 1))
        prompt = rng.integers(1, cfg.vocab_size, size=n).tolist()
        out.append((float(a), [int(t) for t in prompt]))
    return out


def _percentile(xs: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


def run_traffic(engine, cfg: TrafficConfig) -> TrafficReport:
    """Open-loop pump: requests are submitted at their scheduled wall-clock
    arrival whether or not the engine has caught up (queueing delay is part
    of the measured latency, as it would be for real traffic)."""
    plan = synth_requests(cfg)
    submitted = 0
    rids = []
    t0 = time.perf_counter()
    while submitted < len(plan) or engine.busy:
        now = time.perf_counter() - t0
        while submitted < len(plan) and plan[submitted][0] <= now:
            rids.append(engine.submit(plan[submitted][1]))
            submitted += 1
        if engine.busy:
            engine.step()
        elif submitted < len(plan):
            time.sleep(min(0.05, max(0.0, plan[submitted][0] - now)))
    t_end = time.perf_counter()

    lat, ttft, reasons = [], [], {}
    gen_tokens = 0
    for (arr, _prompt), rid in zip(plan, rids):
        comp = engine.results[rid]
        sched_s = t0 + arr  # scheduled arrival, not actual submit call
        lat.append(comp.finish_s - sched_s)
        ttft.append(comp.first_token_s - sched_s)
        gen_tokens += len(comp.tokens)
        reasons[comp.finish_reason] = reasons.get(comp.finish_reason, 0) + 1
    makespan = max(t_end - t0, 1e-9)
    report = TrafficReport(
        qps=cfg.qps,
        num_requests=len(plan),
        generated_tokens=gen_tokens,
        makespan_s=makespan,
        p50_ms=1e3 * _percentile(lat, 50),
        p99_ms=1e3 * _percentile(lat, 99),
        ttft_p50_ms=1e3 * _percentile(ttft, 50),
        tokens_per_s=gen_tokens / makespan,
        finish_reasons=reasons,
    )
    tel = getattr(engine, "telemetry", None)
    if tel is not None and tel.enabled:
        tel.events.emit("serve_report", **report.as_dict())
        for k in ("p50_ms", "p99_ms", "ttft_p50_ms", "tokens_per_s"):
            tel.registry.gauge(
                f"serve_traffic_{k}", "last traffic-run aggregate",
                qps=f"{cfg.qps:g}",
            ).set(getattr(report, k))
    return report


def sweep(engine, qps_rates, base: TrafficConfig) -> list[TrafficReport]:
    """Arrival-rate sweep on one engine (reset between rates — compiled
    executables are reused, only arena/queue state is rebuilt)."""
    reports = []
    for r in qps_rates:
        engine.reset()
        cfg = dataclasses.replace(base, qps=float(r))
        reports.append(run_traffic(engine, cfg))
    return reports


__all__ = ["TrafficConfig", "TrafficReport", "run_traffic", "sweep", "synth_requests"]
