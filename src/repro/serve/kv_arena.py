"""Statically-planned paged KV arena for serving.

The training side solved "where do the bytes live" once, statically
(:mod:`repro.core.arena`): every bucket gets a fixed slot in a flat
per-dtype plane, and execute-time access is a static-offset slice view.
This module applies the same layout discipline to the *serving* caches.
Instead of one dense ``(batch_slots, max_len, ...)`` buffer per cache leaf
— which reserves ``max_len`` positions for every slot whether a request
uses 6 tokens or 600 — the arena stores fixed-size **pages** in flat
per-dtype planes and gives each decode slot a **page table**:

* A *plane* is one ``(num_pages, page_elems)`` buffer per dtype
  (bf16/f32 KV, int8 payloads and their bf16 scales land in separate
  planes automatically).
* A *page* is ``page_size`` tokens' worth of EVERY time-indexed cache
  leaf, packed back-to-back at static offsets inside the page row — the
  same ``build_layout``-style offset math as the gradient arena, with
  "segment" = one leaf's ``page_size``-token chunk.
* A single page id is meaningful in every plane at once (page ``p`` covers
  the same logical token range in the bf16 plane and the int8 plane), so
  one page table per slot serves all cache families together.

Cache leaves are classified by *probing* ``model.cache_specs`` — no
per-family knowledge is hard-coded:

* **paged** leaves grow linearly with ``max_len`` (attention K/V and their
  int8 scales, enc-dec self-attention): the axis whose extent tracks
  ``max_len`` is the time axis, paged in ``page_size``-token chunks.
* **resident** leaves do not grow with ``max_len`` (SSM recurrent state and
  conv tails, xLSTM cell states, rolling sliding-window KV, enc-dec
  cross-attention memory): the whole per-slot state is a *single-page
  resident* — one page allocated at admission, rewritten wholesale every
  step, freed on finish.  This is why SSM/xLSTM models serve out of the
  same arena as attention models: their O(1) state is just a page that
  never grows.

Allocation lives host-side in :class:`PagePool` (a free list — pure
Python, property-testable); device-side access is three pure functions
built per layout: :func:`gather_caches` (page table -> dense batched cache
pytree for the model's ``decode_step``), :func:`scatter_step` (persist the
one written token row per slot + residents), and :func:`build_insert_fn`
(copy a prefilled per-request cache into freshly allocated pages).
Unallocated page-table entries use the out-of-bounds sentinel
``num_pages``: gathers fill with exact zeros, scatters drop — a slot can
therefore never read or write another slot's pages.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def _leaf_name(path) -> str:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return "/".join(out)


@dataclasses.dataclass(frozen=True)
class CacheLeaf:
    """Static placement of one cache-pytree leaf in the arena.

    ``shape`` is the per-slot shape (batch axis removed) at the arena's
    logical length; ``time_axis`` indexes into ``shape`` (``None`` =
    resident).  ``offset``/``numel`` address the leaf's segment inside a
    page row of its plane: for paged leaves ``numel`` is one
    ``page_size``-token chunk, for residents the whole per-slot state.
    """

    name: str
    shape: tuple[int, ...]
    dtype: str
    batch_axis: int
    time_axis: int | None
    plane: int
    offset: int
    numel: int

    @property
    def paged(self) -> bool:
        return self.time_axis is not None


@dataclasses.dataclass(frozen=True)
class KVLayout:
    """Static page/plane layout for one model's serving caches.

    ``tokens`` is the arena's logical length (``max_len`` rounded up to a
    page multiple); every paged leaf's time axis has that extent.
    ``leaves`` parallels ``jax.tree_util.tree_flatten`` order of the cache
    pytree, so gather/scatter never re-derive structure at trace time.
    """

    page_size: int
    tokens: int
    pages_per_slot: int
    plane_dtypes: tuple[str, ...]
    plane_elems: tuple[int, ...]
    leaves: tuple[CacheLeaf, ...]
    treedef: Any

    @property
    def num_planes(self) -> int:
        return len(self.plane_dtypes)

    @property
    def has_paged(self) -> bool:
        return any(l.paged for l in self.leaves)

    @property
    def has_resident(self) -> bool:
        return any(not l.paged for l in self.leaves)

    def token_pages(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` cache rows (0 for pure-resident
        models, whose state never grows with the sequence)."""
        if not self.has_paged or n_tokens <= 0:
            return 0
        return -(-int(n_tokens) // self.page_size)

    def pages_per_request(self, n_tokens: int) -> int:
        """Total pages a request holding ``n_tokens`` occupies (token pages
        plus the single resident page, when the model has resident state)."""
        return self.token_pages(n_tokens) + (1 if self.has_resident else 0)

    def page_bytes(self) -> int:
        return sum(
            w * np.dtype(d).itemsize
            for w, d in zip(self.plane_elems, self.plane_dtypes)
        )


def plan_kv_layout(
    cache_spec_fn: Callable[[int, int], Any],
    max_len: int,
    page_size: int,
) -> KVLayout:
    """Probe ``cache_spec_fn(batch, max_len)`` and compute the static layout.

    Classification is structural, not name-based: the batch axis is the
    axis that moves when ``batch`` does, the time axis is the axis that
    grows by exactly one page when ``max_len`` grows by ``page_size``.
    Leaves with no such axis (recurrent state, rolling-window caches whose
    extent saturates at the window, cross-attn memory) become residents.
    """
    page_size = int(page_size)
    if page_size < 1:
        raise ValueError(f"page_size must be >= 1, got {page_size}")
    tokens = -(-int(max_len) // page_size) * page_size
    base, bdef = jax.tree_util.tree_flatten_with_path(cache_spec_fn(1, tokens))
    wide = jax.tree_util.tree_leaves(cache_spec_fn(2, tokens))
    long = jax.tree_util.tree_leaves(cache_spec_fn(1, tokens + page_size))

    plane_of: dict[str, int] = {}
    plane_dtypes: list[str] = []
    tok_elems: list[int] = []  # per-plane token-page row width
    res_elems: list[int] = []  # per-plane resident row width
    leaves: list[CacheLeaf] = []

    for (path, spec), w_spec, l_spec in zip(base, wide, long):
        name = _leaf_name(path)
        b_axes = [
            i for i, (a, b) in enumerate(zip(spec.shape, w_spec.shape)) if a != b
        ]
        if len(b_axes) != 1 or w_spec.shape[b_axes[0]] - spec.shape[b_axes[0]] != 1:
            raise ValueError(
                f"cache leaf {name}: cannot identify batch axis "
                f"({spec.shape} vs {w_spec.shape})"
            )
        batch_axis = b_axes[0]
        t_axes = [
            i for i, (a, b) in enumerate(zip(spec.shape, l_spec.shape)) if a != b
        ]
        if len(t_axes) > 1:
            raise ValueError(
                f"cache leaf {name}: multiple axes track max_len "
                f"({spec.shape} vs {l_spec.shape})"
            )
        shape = tuple(s for i, s in enumerate(spec.shape) if i != batch_axis)
        time_axis = None
        if t_axes and l_spec.shape[t_axes[0]] - spec.shape[t_axes[0]] == page_size:
            # grows one-row-per-token: genuinely time-indexed -> paged
            time_axis = t_axes[0] - (1 if batch_axis < t_axes[0] else 0)

        dt = np.dtype(spec.dtype).name
        if dt not in plane_of:
            plane_of[dt] = len(plane_dtypes)
            plane_dtypes.append(dt)
            tok_elems.append(0)
            res_elems.append(0)
        p = plane_of[dt]
        if time_axis is not None:
            chunk = list(shape)
            chunk[time_axis] = page_size
            numel = int(np.prod(chunk, dtype=np.int64))
            offset = tok_elems[p]
            tok_elems[p] += numel
        else:
            numel = int(np.prod(shape, dtype=np.int64)) if shape else 1
            offset = res_elems[p]
            res_elems[p] += numel
        leaves.append(CacheLeaf(
            name=name, shape=shape, dtype=dt, batch_axis=batch_axis,
            time_axis=time_axis, plane=p, offset=offset, numel=numel,
        ))

    plane_elems = tuple(max(t, r) for t, r in zip(tok_elems, res_elems))
    return KVLayout(
        page_size=page_size,
        tokens=tokens,
        pages_per_slot=tokens // page_size,
        plane_dtypes=tuple(plane_dtypes),
        plane_elems=plane_elems,
        leaves=tuple(leaves),
        treedef=bdef,
    )


# ---------------------------------------------------------------------------
# page allocation (host side, pure Python -> property-testable)
# ---------------------------------------------------------------------------


class PagePool:
    """Free-list page allocator.  Deterministic (LIFO reuse) so serving runs
    are reproducible; allocation is all-or-nothing per request."""

    def __init__(self, num_pages: int):
        if num_pages < 1:
            raise ValueError(f"num_pages must be >= 1, got {num_pages}")
        self.num_pages = int(num_pages)
        self._free: list[int] = list(range(self.num_pages - 1, -1, -1))
        self._used: set[int] = set()

    @property
    def available(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> list[int] | None:
        """Allocate ``n`` pages, or ``None`` (and no state change) if fewer
        than ``n`` are free."""
        if n < 0:
            raise ValueError(n)
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        self._used.update(out)
        return out

    def free(self, pages: Sequence[int]) -> None:
        for p in pages:
            if p not in self._used:
                raise ValueError(f"double free / foreign page {p}")
            self._used.remove(p)
            self._free.append(p)


# ---------------------------------------------------------------------------
# device-side access (pure functions over planes + tables)
# ---------------------------------------------------------------------------


def _plane_view(plane: jax.Array, leaf: CacheLeaf, inner: tuple[int, ...]):
    """Leaf segment of a plane as ``(num_pages, *inner)`` — a static slice
    plus reshape, the serving twin of ``ArenaLayout.bucket_view``."""
    return plane[:, leaf.offset : leaf.offset + leaf.numel].reshape(
        (plane.shape[0],) + inner
    )


def _chunk_shape(leaf: CacheLeaf, page_size: int) -> tuple[int, ...]:
    chunk = list(leaf.shape)
    chunk[leaf.time_axis] = page_size
    return tuple(chunk)


def gather_caches(
    layout: KVLayout,
    planes: Sequence[jax.Array],
    page_tbl: jax.Array,
    resident_tbl: jax.Array,
):
    """Materialise the dense batched cache pytree the model's
    ``decode_step`` expects, reading every slot's rows through its page
    table.  Unallocated entries (sentinel >= num_pages) gather exact zeros,
    which the decode masks discard — a slot sees only its own pages.

    ``page_tbl``: (slots, pages_per_slot) int32; ``resident_tbl``: (slots,).
    """
    S = page_tbl.shape[0]
    ps, P = layout.page_size, layout.pages_per_slot
    out = []
    for leaf in layout.leaves:
        plane = planes[leaf.plane]
        if not leaf.paged:
            rows = jnp.take(
                plane[:, leaf.offset : leaf.offset + leaf.numel],
                resident_tbl, axis=0, mode="fill", fill_value=0,
            )
            x = rows.reshape((S,) + leaf.shape)
        else:
            seg = _plane_view(plane, leaf, _chunk_shape(leaf, ps))
            rows = jnp.take(
                seg, page_tbl.reshape(-1), axis=0, mode="fill", fill_value=0
            )
            x = rows.reshape((S, P) + _chunk_shape(leaf, ps))
            x = jnp.moveaxis(x, 1, 1 + leaf.time_axis)
            x = x.reshape((S,) + leaf.shape)  # merge (P, ps) -> tokens
        out.append(jnp.moveaxis(x, 0, leaf.batch_axis))
    return jax.tree_util.tree_unflatten(layout.treedef, out)


def scatter_step(
    layout: KVLayout,
    planes: Sequence[jax.Array],
    page_tbl: jax.Array,
    resident_tbl: jax.Array,
    caches,
    pos: jax.Array,
):
    """Persist one decode step back into the arena: for each slot, the
    single token row written at ``pos`` (paged leaves, read-modify-write of
    the touched page row) plus the whole resident state (rewritten
    wholesale — SSM/xLSTM state is not position-masked, so partial writes
    would be wrong).  Slots whose table entry is the sentinel scatter
    nowhere (``mode='drop'``)."""
    S = page_tbl.shape[0]
    ps = layout.page_size
    vals = jax.tree_util.tree_leaves(caches)
    page_ids = jnp.take_along_axis(page_tbl, (pos // ps)[:, None], axis=1)[:, 0]
    within = pos % ps
    planes = list(planes)

    for p in range(layout.num_planes):
        paged = [
            (lf, v) for lf, v in zip(layout.leaves, vals)
            if lf.plane == p and lf.paged
        ]
        res = [
            (lf, v) for lf, v in zip(layout.leaves, vals)
            if lf.plane == p and not lf.paged
        ]
        W = layout.plane_elems[p]
        dt = planes[p].dtype
        if paged:
            rows = jnp.take(planes[p], page_ids, axis=0, mode="fill",
                            fill_value=0)
            for lf, v in paged:
                x = jnp.moveaxis(v, lf.batch_axis, 0)  # (S, *shape)
                y = jnp.moveaxis(x, 1 + lf.time_axis, 1)  # time -> axis 1
                idx = pos.reshape((S,) + (1,) * (y.ndim - 1))
                tok = jnp.take_along_axis(y, idx, axis=1)[:, 0]  # (S, *rest)
                chunk = _chunk_shape(lf, ps)
                seg = rows[:, lf.offset : lf.offset + lf.numel].reshape(
                    (S,) + chunk
                )
                ix = (jnp.arange(S),) + (slice(None),) * lf.time_axis + (within,)
                seg = seg.at[ix].set(tok.astype(dt))
                rows = rows.at[:, lf.offset : lf.offset + lf.numel].set(
                    seg.reshape(S, lf.numel)
                )
            planes[p] = planes[p].at[page_ids].set(rows, mode="drop")
        if res:
            rows = jnp.zeros((S, W), dt)
            for lf, v in res:
                x = jnp.moveaxis(v, lf.batch_axis, 0).reshape(S, lf.numel)
                rows = rows.at[:, lf.offset : lf.offset + lf.numel].set(
                    x.astype(dt)
                )
            planes[p] = planes[p].at[resident_tbl].set(rows, mode="drop")
    return planes


def build_insert_fn(layout: KVLayout):
    """Compile the insert stage: copy a prefilled per-request cache
    (batch=1, dense at the arena's logical length) into freshly allocated
    pages.  Whole page rows are rebuilt from zeros, so slot reuse can never
    leak a previous request's state.  ``page_ids`` is null-padded to
    ``pages_per_slot`` (fixed shape -> one compilation per model)."""
    ps, P = layout.page_size, layout.pages_per_slot

    def insert(planes, pcache, page_ids, resident_id):
        vals = jax.tree_util.tree_leaves(pcache)
        planes = list(planes)
        for p in range(layout.num_planes):
            W = layout.plane_elems[p]
            dt = planes[p].dtype
            paged = [
                (lf, v) for lf, v in zip(layout.leaves, vals)
                if lf.plane == p and lf.paged
            ]
            res = [
                (lf, v) for lf, v in zip(layout.leaves, vals)
                if lf.plane == p and not lf.paged
            ]
            if paged:
                rows = jnp.zeros((P, W), dt)
                for lf, v in paged:
                    x = jnp.moveaxis(v, lf.batch_axis, 0)[0]  # per-slot
                    shp = (
                        lf.shape[: lf.time_axis]
                        + (P, ps)
                        + lf.shape[lf.time_axis + 1 :]
                    )
                    x = jnp.moveaxis(x.reshape(shp), lf.time_axis, 0)
                    rows = rows.at[:, lf.offset : lf.offset + lf.numel].set(
                        x.reshape(P, lf.numel).astype(dt)
                    )
                planes[p] = planes[p].at[page_ids].set(rows, mode="drop")
            if res:
                row_ = jnp.zeros((1, W), dt)
                for lf, v in res:
                    x = jnp.moveaxis(v, lf.batch_axis, 0).reshape(1, lf.numel)
                    row_ = row_.at[:, lf.offset : lf.offset + lf.numel].set(
                        x.astype(dt)
                    )
                planes[p] = planes[p].at[resident_id].set(row_, mode="drop")
        return planes

    return jax.jit(insert, donate_argnums=(0,))


# ---------------------------------------------------------------------------
# the arena object (planes + tables + pool)
# ---------------------------------------------------------------------------


class KVArena:
    """Mutable serving arena: device planes, host page tables, page pool.

    The sentinel for "no page" is ``num_pages`` — deliberately
    out-of-bounds so device gathers fill zeros and device scatters drop
    (negative sentinels would wrap, silently aliasing the last page).
    """

    def __init__(self, layout: KVLayout, num_pages: int, num_slots: int):
        self.layout = layout
        self.num_pages = int(num_pages)
        self.num_slots = int(num_slots)
        self.null = self.num_pages
        self.pool = PagePool(num_pages)
        self.planes = [
            jnp.zeros((num_pages, w), np.dtype(d))
            for w, d in zip(layout.plane_elems, layout.plane_dtypes)
        ]
        self.page_tbl = np.full(
            (num_slots, layout.pages_per_slot), self.null, np.int32
        )
        self.resident_tbl = np.full((num_slots,), self.null, np.int32)
        self._slot_pages: list[list[int]] = [[] for _ in range(num_slots)]
        self._slot_resident: list[int | None] = [None] * num_slots

    @classmethod
    def auto_pages(cls, layout: KVLayout, num_slots: int) -> int:
        """Pool size at which admission can never starve: every slot can
        hold a full-length request simultaneously."""
        per_slot = layout.pages_per_slot * (1 if layout.has_paged else 0)
        per_slot += 1 if layout.has_resident else 0
        return max(1, num_slots * per_slot)

    def nbytes(self) -> int:
        return self.num_pages * self.layout.page_bytes()

    # ---- slot lifecycle ---------------------------------------------------
    def acquire_slot(self, slot: int, n_tokens: int) -> bool:
        """Allocate the pages a fresh request needs (token pages for the
        prompt + the resident page).  All-or-nothing; False = not enough
        free pages, nothing changed."""
        n_tok = self.layout.token_pages(n_tokens)
        n_res = 1 if self.layout.has_resident else 0
        pages = self.pool.alloc(n_tok + n_res)
        if pages is None:
            return False
        if n_res:
            self._slot_resident[slot] = pages[0]
            self.resident_tbl[slot] = pages[0]
        tok_pages = pages[n_res:]
        self._slot_pages[slot] = tok_pages
        self.page_tbl[slot, :] = self.null
        self.page_tbl[slot, : len(tok_pages)] = tok_pages
        return True

    def extend_slot(self, slot: int) -> bool:
        """Grow a slot by one token page (generate crossed a page
        boundary).  False = pool exhausted (caller truncates)."""
        got = self.pool.alloc(1)
        if got is None:
            return False
        i = len(self._slot_pages[slot])
        self._slot_pages[slot].append(got[0])
        self.page_tbl[slot, i] = got[0]
        return True

    def page_for(self, slot: int, pos: int) -> bool:
        """Ensure the page covering position ``pos`` exists (allocating at
        most one — positions advance a token at a time)."""
        if not self.layout.has_paged:
            return True
        idx = pos // self.layout.page_size
        if idx < len(self._slot_pages[slot]):
            return True
        if idx != len(self._slot_pages[slot]):
            raise AssertionError(
                f"slot {slot}: non-contiguous page demand {idx}"
            )
        return self.extend_slot(slot)

    def release_slot(self, slot: int) -> None:
        pages = list(self._slot_pages[slot])
        if self._slot_resident[slot] is not None:
            pages.append(self._slot_resident[slot])
        if pages:
            self.pool.free(pages)
        self._slot_pages[slot] = []
        self._slot_resident[slot] = None
        self.page_tbl[slot, :] = self.null
        self.resident_tbl[slot] = self.null

    # ---- device-table views -------------------------------------------
    def device_tables(self) -> tuple[jax.Array, jax.Array]:
        return jnp.asarray(self.page_tbl), jnp.asarray(self.resident_tbl)

    def insert_ids(self, slot: int) -> tuple[jax.Array, jax.Array]:
        """Null-padded page-id vector + resident id for the insert stage."""
        ids = np.full((self.layout.pages_per_slot,), self.null, np.int32)
        tok = self._slot_pages[slot]
        ids[: len(tok)] = tok
        rid = self._slot_resident[slot]
        res = np.full((1,), self.null if rid is None else rid, np.int32)
        return jnp.asarray(ids), jnp.asarray(res)


__all__ = [
    "CacheLeaf",
    "KVArena",
    "KVLayout",
    "PagePool",
    "build_insert_fn",
    "gather_caches",
    "plan_kv_layout",
    "scatter_step",
]
