"""Batched serving engine with slot-based continuous batching.

Fixed ``batch_slots`` decode slots; each slot holds one request at its own
position (the decode step takes a per-slot ``pos`` vector).  Prompts are
prefilled token-by-token through the decode path (exact cache semantics for
every family: attention KV, SSM state, xLSTM state, enc-dec cross-attn).
Finished slots are immediately refilled from the queue.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch_slots: int = 4
    max_len: int = 256
    max_new_tokens: int = 32
    eos_token: int = -1          # -1 = never stop on eos
    temperature: float = 0.0     # 0 = greedy


def greedy_sample(logits: jax.Array, key=None, temperature: float = 0.0):
    """logits: (B, 1, V) -> (B,) int32."""
    if temperature and temperature > 0:
        return jax.random.categorical(key, logits[:, 0, :] / temperature, axis=-1)
    return jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)


@dataclasses.dataclass
class _Slot:
    request_id: int | None = None
    prompt: list[int] | None = None
    generated: list[int] = dataclasses.field(default_factory=list)
    pos: int = 0
    prefill_cursor: int = 0

    @property
    def active(self) -> bool:
        return self.request_id is not None

    @property
    def prefilling(self) -> bool:
        return self.active and self.prefill_cursor < len(self.prompt)


class Engine:
    def __init__(self, model, params, sc: ServeConfig, *, sample=greedy_sample):
        self.model = model
        self.params = params
        self.sc = sc
        self.sample = sample
        B = sc.batch_slots
        self.caches = model.init_caches(B, sc.max_len)
        self.slots = [_Slot() for _ in range(B)]
        self.queue: deque = deque()
        self.results: dict[int, list[int]] = {}
        self._next_id = 0
        self._step_fn = jax.jit(model.decode_step)
        self._key = jax.random.PRNGKey(0)

    # ---- request API -------------------------------------------------------
    def submit(self, prompt_tokens: Sequence[int]) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append((rid, list(prompt_tokens)))
        return rid

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(s.active for s in self.slots)

    # ---- scheduling -------------------------------------------------------
    def _reset_slot_cache(self, i: int):
        """Zero slot i's cache rows (SSM/xLSTM states are not position-masked,
        so stale state from the previous request must be cleared)."""
        self.caches = jax.tree.map(
            lambda c: c.at[:, i].set(jnp.zeros_like(c[:, i])) if c.ndim >= 2 else c,
            self.caches,
        )

    def _fill_slots(self):
        for i, s in enumerate(self.slots):
            if not s.active and self.queue:
                rid, prompt = self.queue.popleft()
                s.request_id = rid
                s.prompt = prompt
                s.generated = []
                s.pos = 0
                s.prefill_cursor = 0
                self._reset_slot_cache(i)

    def step(self) -> int:
        """One engine iteration: every active slot advances one token
        (prefill consumes a prompt token; decode emits a new one).
        Returns the number of active slots."""
        self._fill_slots()
        B = self.sc.batch_slots
        tokens = np.zeros((B, 1), np.int32)
        pos = np.zeros((B,), np.int32)
        active = []
        for i, s in enumerate(self.slots):
            if not s.active:
                continue
            active.append(i)
            pos[i] = s.pos
            if s.prefilling:
                tokens[i, 0] = s.prompt[s.prefill_cursor]
            else:
                tokens[i, 0] = s.generated[-1]
        if not active:
            return 0

        batch = {"tokens": jnp.asarray(tokens), "pos": jnp.asarray(pos)}
        logits, self.caches = self._step_fn(self.params, self.caches, batch)
        self._key, sub = jax.random.split(self._key)
        next_tok = np.asarray(self.sample(logits, sub, self.sc.temperature))

        for i in active:
            s = self.slots[i]
            fed_last_prompt = (
                s.prefilling and s.prefill_cursor == len(s.prompt) - 1
            )
            was_decode = not s.prefilling
            s.pos += 1
            if s.prefilling:
                s.prefill_cursor += 1
            if fed_last_prompt or was_decode:
                # the logits of this step predict the next token
                t = int(next_tok[i])
                s.generated.append(t)
                done = (
                    len(s.generated) >= self.sc.max_new_tokens
                    or t == self.sc.eos_token
                    or s.pos >= self.sc.max_len - 1
                )
                if done:
                    self.results[s.request_id] = list(s.generated)
                    s.request_id = None
                    s.prompt = None
        return len(active)

    def run_until_done(self, max_steps: int = 100_000) -> dict[int, list[int]]:
        steps = 0
        while self.busy and steps < max_steps:
            self.step()
            steps += 1
        return self.results
