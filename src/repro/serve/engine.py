"""Continuous-batching serving engine over the paged KV arena.

The engine runs three separately-compiled, separately-timed stages
(MaxText/JetStream-style), replacing the old single loop that pushed every
prompt token through the batched decode step one jitted call at a time:

* **prefill** — :class:`~repro.serve.prefill.ChunkedPrefill` consumes the
  whole prompt at batch=1 through a ``lax.scan`` of the decode step: one
  compiled dispatch per ``prefill_chunk`` tokens instead of one per token,
  with bit-identical cache semantics for every family.
* **insert** — the prefilled dense cache is copied into freshly allocated
  arena pages (one compiled call, whole page rows rebuilt from zeros so
  slot reuse cannot leak state).
* **generate** — all active slots advance one token per call: gather the
  dense batched caches through the page tables, run ``decode_step``,
  scatter the written rows back.  Slots at different positions, admitted
  and evicted continuously, share the one compiled executable.

Requests finish with an explicit ``finish_reason`` (eos / length /
truncated) — the old engine silently dropped requests at ``max_len-1``.
``Engine.results`` maps request id to a :class:`~repro.serve.scheduler.Completion`
carrying tokens, the reason, and a wall-clock ledger for latency metrics.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .kv_arena import (
    KVArena,
    build_insert_fn,
    gather_caches,
    plan_kv_layout,
    scatter_step,
)
from .prefill import ChunkedPrefill
from .scheduler import (
    FINISH_EOS,
    FINISH_LENGTH,
    FINISH_TRUNCATED,
    Completion,
    Request,
    Scheduler,
    Slot,
)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch_slots: int = 4
    max_len: int = 256
    max_new_tokens: int = 32
    eos_token: int = -1          # -1 = never stop on eos
    temperature: float = 0.0     # 0 = greedy
    page_size: int = 16          # tokens per KV page
    num_pages: int = 0           # 0 = auto (every slot can run full-length)
    prefill_chunk: int = 16      # prompt tokens per compiled prefill call
    # load shedding (DESIGN.md §16).  max_queue bounds the admission
    # queue: a submit over the bound finishes immediately with
    # finish_reason="rejected" (no tokens consumed, safe to retry) instead
    # of queueing unboundedly — under an overload storm the queue stops
    # being a hidden latency reservoir and p99 of ADMITTED requests stays
    # bounded.  None keeps the legacy unbounded queue.
    max_queue: int | None = None
    # starvation shedding: if the queue head has waited starve_patience
    # consecutive engine ticks during which nothing could be admitted AND
    # no slot is active (so nothing will ever free pages — e.g. the page
    # pool is held externally), shed the head as rejected rather than
    # deadlock the episode.  0 disables (legacy behaviour).
    starve_patience: int = 0


def greedy_sample(logits: jax.Array, key=None, temperature: float = 0.0):
    """logits: (B, 1, V) -> (B,) int32."""
    if temperature and temperature > 0:
        return jax.random.categorical(key, logits[:, 0, :] / temperature, axis=-1)
    return jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)


def build_generate_fn(model, layout):
    """Compile the batched generate step: page tables -> dense caches ->
    decode_step -> scatter written rows back.  One executable serves every
    mix of active slots/positions (tables and pos are data, not shapes)."""

    def gen(params, planes, page_tbl, resident_tbl, tokens, pos):
        caches = gather_caches(layout, planes, page_tbl, resident_tbl)
        logits, caches = model.decode_step(
            params, caches, {"tokens": tokens, "pos": pos}
        )
        planes = scatter_step(
            layout, planes, page_tbl, resident_tbl, caches, pos
        )
        return logits, planes

    return jax.jit(gen, donate_argnums=(1,))


def _zero_stats() -> dict[str, float]:
    return {
        "requests": 0, "completed": 0, "starved_shed": 0,
        "prefill_calls": 0, "prefill_tokens": 0, "prefill_s": 0.0,
        "insert_calls": 0, "insert_s": 0.0,
        "generate_calls": 0, "generate_tokens": 0, "generate_s": 0.0,
    }


class Engine:
    def __init__(self, model, params, sc: ServeConfig, *, sample=greedy_sample,
                 telemetry=None):
        from repro.obs import as_telemetry

        self.model = model
        self.params = params
        self.sc = sc
        self.sample = sample
        # telemetry (repro.obs): per-request lifecycle spans + stage
        # histograms + queue/page-pool occupancy series.  Disabled bundle
        # (the default) makes every hook a no-op attribute check.
        self.telemetry = as_telemetry(telemetry)
        self.layout = plan_kv_layout(model.cache_specs, sc.max_len, sc.page_size)
        self._num_pages = sc.num_pages or KVArena.auto_pages(
            self.layout, sc.batch_slots
        )
        self.prefill = ChunkedPrefill(model, sc.prefill_chunk)
        self._generate = build_generate_fn(model, self.layout)
        self._insert = build_insert_fn(self.layout)
        self._encode = None
        if getattr(model.cfg, "is_encdec", False):
            from repro.models import encdec as ed

            def enc(params, frames):
                memory = ed.encode(params["encdec"], frames, model.cfg)
                return ed.precompute_memory_kv(
                    params["encdec"], memory, model.cfg
                )

            self._encode = jax.jit(enc)
        self._next_id = 0
        self.reset()

    def reset(self) -> None:
        """Fresh arena/queue/results/stats; compiled executables are kept,
        so QPS sweeps can reuse one engine without re-tracing."""
        self.arena = KVArena(self.layout, self._num_pages, self.sc.batch_slots)
        self.sched = Scheduler(self.sc.batch_slots)
        self.results: dict[int, Completion] = {}
        self.stats = _zero_stats()
        self._starved_ticks = 0
        self._key = jax.random.PRNGKey(0)
        # wall-clock origin of this serving episode: request spans in the
        # Chrome trace are rebased to it so traces start near t=0
        self._trace_t0 = time.perf_counter()

    # ---- request API -------------------------------------------------------
    def submit(self, prompt_tokens: Sequence[int], frames: Any = None) -> int:
        rid = self._next_id
        self._next_id += 1
        req = Request(
            rid=rid, prompt=list(prompt_tokens), frames=frames,
            submit_s=time.perf_counter(),
        )
        if (
            self.sc.max_queue is not None
            and self.sched.pending >= self.sc.max_queue
        ):
            # shed at the door: the request never queues, consumes no
            # tokens, and surfaces as finish_reason="rejected" — the
            # caller (traffic.py) may retry with backoff
            self.stats["requests"] += 1
            self._record_completion(
                self.sched.reject(req, time.perf_counter())
            )
            return rid
        self.sched.submit(req)
        return rid

    @property
    def busy(self) -> bool:
        return self.sched.busy

    def metrics(self) -> dict[str, float]:
        """Per-stage unit costs (µs), for the serve smoke gate."""
        st = self.stats
        return {
            "prefill_tok_us": 1e6 * st["prefill_s"] / max(1, st["prefill_tokens"]),
            "generate_tok_us": 1e6 * st["generate_s"] / max(1, st["generate_tokens"]),
            "insert_us": 1e6 * st["insert_s"] / max(1, st["insert_calls"]),
        }

    # ---- internals -----------------------------------------------------
    def _sample_host(self, logits) -> np.ndarray:
        self._key, sub = jax.random.split(self._key)
        return np.asarray(self.sample(logits, sub, self.sc.temperature))

    def _finish(self, slot: Slot, reason: str) -> None:
        comp = self.sched.finish(slot, reason, time.perf_counter())
        self.arena.release_slot(slot.index)
        self._record_completion(comp)

    def _record_completion(self, comp: Completion) -> None:
        """Terminal bookkeeping shared by slot finishes and slotless
        rejections: results map, stats, and the telemetry ledger."""
        self.results[comp.rid] = comp
        self.stats["completed"] += 1
        reason = comp.finish_reason
        tel = self.telemetry
        if tel.enabled:
            tel.tracer.record_request(comp, t0=self._trace_t0)
            tel.registry.counter(
                "serve_requests_total", "completed requests by finish reason",
                reason=reason,
            ).inc()
            tel.registry.histogram(
                "serve_request_latency_ms", "submit -> finish, per request"
            ).observe(comp.latency_s * 1e3)
            tel.registry.histogram(
                "serve_request_ttft_ms", "submit -> first token, per request"
            ).observe(comp.ttft_s * 1e3)
            tel.events.emit(
                "serve_request",
                rid=int(comp.rid),
                prompt_len=int(comp.prompt_len),
                new_tokens=len(comp.tokens),
                finish_reason=comp.finish_reason,
                ttft_ms=comp.ttft_s * 1e3,
                latency_ms=comp.latency_s * 1e3,
                queued_ms=max(comp.admit_s - comp.submit_s, 0.0) * 1e3,
            )

    def _admit(self) -> None:
        while True:
            na = self.sched.next_admission()
            if na is None:
                return
            slot, req = na
            L = len(req.prompt)
            if L > self.sc.max_len - 1:
                # no room to even feed the first generated token back in
                self.sched.admit(slot, time.perf_counter())
                self.stats["requests"] += 1
                self._finish(slot, FINISH_TRUNCATED)
                continue
            needed = self.layout.pages_per_request(L)
            if needed > self.arena.pool.available:
                if needed > self.arena.num_pages:
                    # could never fit even in an idle arena: reject now
                    # rather than deadlock the queue
                    self.sched.admit(slot, time.perf_counter())
                    self.stats["requests"] += 1
                    self._finish(slot, FINISH_TRUNCATED)
                    continue
                return  # wait for running requests to free pages
            self.sched.admit(slot, time.perf_counter())
            self.stats["requests"] += 1
            self._run_prefill(slot, req)

    def _run_prefill(self, slot: Slot, req: Request) -> None:
        if not self.arena.acquire_slot(slot.index, len(req.prompt)):
            raise AssertionError("admission checked pages but alloc failed")
        t0 = time.perf_counter()
        caches = self.model.init_caches(1, self.layout.tokens)
        if self._encode is not None:
            cfg = self.model.cfg
            frames = req.frames
            if frames is None:
                frames = np.zeros(
                    (1, cfg.frontend_tokens, cfg.d_model), np.float32
                )
            caches = dict(caches)
            mem_k, mem_v = self._encode(self.params, jnp.asarray(frames))
            caches["mem_k"] = mem_k
            caches["mem_v"] = mem_v
        logits, caches, calls = self.prefill(self.params, caches, req.prompt)
        first = int(self._sample_host(logits)[0])
        t1 = time.perf_counter()
        slot.prefill_end_s = t1
        self.stats["prefill_calls"] += calls
        self.stats["prefill_tokens"] += len(req.prompt)
        self.stats["prefill_s"] += t1 - t0

        page_ids, res_id = self.arena.insert_ids(slot.index)
        self.arena.planes = self._insert(
            self.arena.planes, caches, page_ids, res_id
        )
        jax.block_until_ready(self.arena.planes)
        t2 = time.perf_counter()
        self.stats["insert_calls"] += 1
        self.stats["insert_s"] += t2 - t1

        tel = self.telemetry
        if tel.enabled:
            tel.registry.histogram(
                "serve_stage_ms", "per-call stage wall", stage="prefill"
            ).observe((t1 - t0) * 1e3)
            tel.registry.histogram(
                "serve_stage_ms", "per-call stage wall", stage="insert"
            ).observe((t2 - t1) * 1e3)

        slot.tokens.append(first)
        slot.first_token_s = t2
        self._maybe_finish(slot, first)

    def _maybe_finish(self, slot: Slot, tok: int) -> None:
        """Terminal checks after a token lands.  ``slot.pos`` is the
        position the NEXT decode input would occupy; it must stay within
        the context for generation to continue."""
        if tok == self.sc.eos_token:
            self._finish(slot, FINISH_EOS)
        elif len(slot.tokens) >= self.sc.max_new_tokens:
            self._finish(slot, FINISH_LENGTH)
        elif slot.pos > self.sc.max_len - 1:
            self._finish(slot, FINISH_TRUNCATED)

    def step(self) -> int:
        """One engine iteration: admit (prefill+insert) what fits, then
        advance every active slot one generated token.  Returns the number
        of slots that decoded."""
        self._admit()
        for slot in self.sched.active_slots:
            if not self.arena.page_for(slot.index, slot.pos):
                self._finish(slot, FINISH_TRUNCATED)  # pool ran dry
        active = self.sched.active_slots
        if self.sc.starve_patience > 0:
            if self.sched.pending and not active:
                # queue is non-empty, nothing admitted, nothing running:
                # no slot will ever free the pages admission is waiting on
                # (e.g. the pool is held externally — a page_starve
                # fault).  After starve_patience ticks, shed the head per
                # tick instead of deadlocking the episode.
                self._starved_ticks += 1
                if self._starved_ticks > self.sc.starve_patience:
                    req = self.sched.queue.popleft()
                    self.stats["requests"] += 1
                    self.stats["starved_shed"] += 1
                    self._record_completion(
                        self.sched.reject(req, time.perf_counter())
                    )
            else:
                self._starved_ticks = 0
        tel = self.telemetry
        if tel.enabled:
            # occupancy series: one counter-track sample per engine tick
            # plus last-value gauges for the registry snapshot
            now = time.perf_counter() - self._trace_t0
            depth = self.sched.pending
            free = self.arena.pool.available
            tel.tracer.record_counter(
                "serve occupancy", now,
                {"queue_depth": depth, "active_slots": len(active),
                 "free_pages": free},
            )
            tel.registry.gauge(
                "serve_queue_depth", "requests waiting for admission"
            ).set(depth)
            tel.registry.gauge(
                "serve_active_slots", "slots decoding this tick"
            ).set(len(active))
            tel.registry.gauge(
                "serve_free_pages", "KV arena pages unallocated"
            ).set(free)
            tel.registry.histogram(
                "serve_page_occupancy", "fraction of KV pages in use, per tick"
            ).observe(1.0 - free / max(self.arena.num_pages, 1))
        if not active:
            return 0

        S = self.sc.batch_slots
        tokens = np.zeros((S, 1), np.int32)
        pos = np.zeros((S,), np.int32)
        for slot in active:
            tokens[slot.index, 0] = slot.tokens[-1]
            pos[slot.index] = slot.pos
        page_tbl, resident_tbl = self.arena.device_tables()

        t0 = time.perf_counter()
        logits, self.arena.planes = self._generate(
            self.params, self.arena.planes, page_tbl, resident_tbl,
            jnp.asarray(tokens), jnp.asarray(pos),
        )
        nxt = self._sample_host(logits)
        t1 = time.perf_counter()
        self.stats["generate_calls"] += 1
        self.stats["generate_tokens"] += len(active)
        self.stats["generate_s"] += t1 - t0
        if tel.enabled:
            tel.registry.histogram(
                "serve_stage_ms", "per-call stage wall", stage="generate"
            ).observe((t1 - t0) * 1e3)

        for slot in active:
            tok = int(nxt[slot.index])
            slot.tokens.append(tok)
            slot.pos += 1
            self._maybe_finish(slot, tok)
        return len(active)

    def run_until_done(self, max_steps: int = 100_000) -> dict[int, Completion]:
        steps = 0
        while self.busy and steps < max_steps:
            self.step()
            steps += 1
        return self.results
