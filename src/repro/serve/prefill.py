"""Chunked whole-prompt prefill.

The old engine prefilled prompts token-by-token through the jitted decode
step — one compiled-call dispatch per prompt token, L dispatches for an
L-token prompt.  This module compiles a ``lax.scan`` of ``decode_step``
over a whole chunk of prompt tokens instead: one dispatch per
``chunk_tokens`` (O(L/chunk) calls), while keeping *exact* decode-path
cache semantics for every family — the scan body is literally the decode
step, so attention KV, rolling windows, SSM recurrences, xLSTM cells and
enc-dec cross-attention all fill identically to sequential decode (this is
what makes continuous-batching output bit-for-bit checkable against
one-at-a-time decode).

One program is compiled per distinct chunk *length* (the full chunk plus
at most one remainder length per prompt); the start position is a traced
scalar, so serving many prompts reuses the same two executables.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


class ChunkedPrefill:
    """Callable prefill stage.  ``__call__`` consumes the whole prompt and
    returns the last-token logits (which predict the first generated
    token), the filled batch=1 cache, and the number of compiled-call
    dispatches it made (the counting test's ground truth)."""

    def __init__(self, model, chunk_tokens: int = 16):
        if chunk_tokens < 1:
            raise ValueError(f"chunk_tokens must be >= 1, got {chunk_tokens}")
        self.model = model
        self.chunk_tokens = int(chunk_tokens)
        self._fns: dict[int, Any] = {}

    def _fn(self, n: int):
        fn = self._fns.get(n)
        if fn is None:
            decode_step = self.model.decode_step

            def run(params, caches, tokens, pos0):
                # tokens: (n,) int32; pos0: traced scalar start position
                def body(carry, tok):
                    caches, pos = carry
                    logits, caches = decode_step(
                        params, caches,
                        {"tokens": tok[None, None], "pos": pos[None]},
                    )
                    return (caches, pos + 1), logits

                init = (caches, jnp.asarray(pos0, jnp.int32))
                (caches, _), ys = jax.lax.scan(body, init, tokens)
                return ys[-1], caches

            fn = jax.jit(run, donate_argnums=(1,))
            self._fns[n] = fn
        return fn

    def __call__(self, params, caches, prompt: list[int]):
        """Prefill ``prompt`` (positions 0..L-1) into ``caches`` (batch=1,
        donated).  Returns (last_logits, caches, n_calls)."""
        toks = np.asarray(prompt, np.int32)
        logits = None
        calls = 0
        for off in range(0, len(toks), self.chunk_tokens):
            chunk = jnp.asarray(toks[off : off + self.chunk_tokens])
            logits, caches = self._fn(chunk.shape[0])(
                params, caches, chunk, off
            )
            calls += 1
        return logits, caches, calls


__all__ = ["ChunkedPrefill"]
