"""Continuous-batching scheduler: request queue, slot states, admission.

Admission is FIFO and two-resource: the queue head is admitted when a
decode *slot* is free AND the page pool can cover the request
(``ceil(prompt_len / page_size)`` token pages plus the resident page for
models with recurrent state).  Head-of-line order is preserved on purpose
— requests never overtake each other, which keeps serving runs
deterministic and makes batched-vs-sequential parity testable.

Every request finishes with an explicit ``finish_reason``:

* ``"eos"`` — the model emitted the eos token;
* ``"length"`` — ``max_new_tokens`` generated;
* ``"truncated"`` — the context filled up (``max_len`` reached, the page
  pool ran dry mid-generation, or the prompt alone exceeds the context);
  previously this case was silently reported as a normal completion;
* ``"rejected"`` — load shedding (DESIGN.md §16): the engine refused the
  request *without running it* — the admission queue is over
  ``max_queue``, or the queue head starved with every slot/page
  exhausted.  Distinct from ``"truncated"`` on purpose: a rejected
  request produced no tokens and is safe to retry verbatim
  (``traffic.py`` does, with backoff), whereas a truncated one consumed
  budget.  Under an overload storm this is what keeps p99 of *admitted*
  requests bounded instead of silently degrading everyone.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

FINISH_EOS = "eos"
FINISH_LENGTH = "length"
FINISH_TRUNCATED = "truncated"
FINISH_REJECTED = "rejected"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    frames: Any = None          # enc-dec conditioning (1, F, d_model) or None
    submit_s: float = 0.0       # wall clock at submit()


@dataclasses.dataclass
class Completion:
    """Terminal record for one request — tokens plus the latency ledger the
    traffic harness aggregates into p50/p99."""

    rid: int
    prompt_len: int
    tokens: list[int]
    finish_reason: str
    submit_s: float = 0.0
    admit_s: float = 0.0        # prefill started
    prefill_end_s: float = 0.0  # prompt forward done, KV insert starts
    first_token_s: float = 0.0  # first generated token available
    finish_s: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.submit_s

    @property
    def ttft_s(self) -> float:
        return self.first_token_s - self.submit_s


@dataclasses.dataclass
class Slot:
    index: int
    request: Request | None = None
    tokens: list[int] = dataclasses.field(default_factory=list)
    pos: int = 0                # position the NEXT decode input occupies
    admit_s: float = 0.0
    prefill_end_s: float = 0.0
    first_token_s: float = 0.0

    @property
    def active(self) -> bool:
        return self.request is not None

    def clear(self) -> None:
        self.request = None
        self.tokens = []
        self.pos = 0


class Scheduler:
    """Owns the queue and the slot array; the engine owns the arena and
    asks ``next_admission`` whether the queue head fits."""

    def __init__(self, num_slots: int):
        self.slots = [Slot(i) for i in range(num_slots)]
        self.queue: deque[Request] = deque()

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    @property
    def pending(self) -> int:
        return len(self.queue)

    @property
    def active_slots(self) -> list[Slot]:
        return [s for s in self.slots if s.active]

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(s.active for s in self.slots)

    def free_slot(self) -> Slot | None:
        for s in self.slots:
            if not s.active:
                return s
        return None

    def next_admission(self) -> tuple[Slot, Request] | None:
        """Queue head + a free slot, if both exist.  Does NOT pop — the
        engine pops via ``admit`` only once the page pool also agrees."""
        if not self.queue:
            return None
        slot = self.free_slot()
        if slot is None:
            return None
        return slot, self.queue[0]

    def admit(self, slot: Slot, now: float) -> Request:
        req = self.queue.popleft()
        slot.request = req
        slot.tokens = []
        slot.pos = len(req.prompt)
        slot.admit_s = now
        slot.prefill_end_s = 0.0
        slot.first_token_s = 0.0
        return req

    def reject(self, req: Request, now: float) -> Completion:
        """Shed one request without a slot: a terminal Completion with no
        tokens, ``finish_reason="rejected"``, and the latency ledger
        collapsed to the decision instant (admit == finish == now, so a
        rejection's 'latency' is pure queueing time, never compute)."""
        return Completion(
            rid=req.rid,
            prompt_len=len(req.prompt),
            tokens=[],
            finish_reason=FINISH_REJECTED,
            submit_s=req.submit_s,
            admit_s=now,
            prefill_end_s=now,
            first_token_s=now,
            finish_s=now,
        )

    def finish(self, slot: Slot, reason: str, now: float) -> Completion:
        req = slot.request
        comp = Completion(
            rid=req.rid,
            prompt_len=len(req.prompt),
            tokens=list(slot.tokens),
            finish_reason=reason,
            submit_s=req.submit_s,
            admit_s=slot.admit_s,
            prefill_end_s=slot.prefill_end_s or now,
            first_token_s=slot.first_token_s or now,
            finish_s=now,
        )
        slot.clear()
        return comp


__all__ = [
    "Completion",
    "FINISH_EOS",
    "FINISH_LENGTH",
    "FINISH_REJECTED",
    "FINISH_TRUNCATED",
    "Request",
    "Scheduler",
    "Slot",
]
