"""COVAP reproduction: overlapping-aware gradient compression in JAX.

``repro.api`` is the front door (``fit`` / ``tune`` / ``plan_report``);
the subpackages are importable directly (``repro.core``, ``repro.train``,
``repro.launch``, ...).  Submodules are loaded lazily so ``import repro``
stays cheap.
"""
from __future__ import annotations

import importlib

__version__ = "0.1.0"

_SUBMODULES = (
    "api",
    "checkpoint",
    "configs",
    "core",
    "data",
    "kernels",
    "launch",
    "models",
    "optim",
    "resilience",
    "runtime",
    "serve",
    "train",
)


def __getattr__(name: str):
    if name in _SUBMODULES:
        return importlib.import_module(f"{__name__}.{name}")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_SUBMODULES))
