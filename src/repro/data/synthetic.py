"""Deterministic synthetic corpora.

``markov_corpus`` produces *learnable* token streams (a random sparse
first-order Markov chain): a model that trains correctly drives the loss
well below the unigram entropy, which is what the convergence benchmarks
(Table VII analogue) measure.  ``zipf_tokens`` gives heavy-tailed unigram
data for throughput-only runs.
"""
from __future__ import annotations

import numpy as np


def zipf_tokens(rng: np.random.Generator, n: int, vocab: int, a: float = 1.3):
    toks = rng.zipf(a, size=n).astype(np.int64)
    return (toks % vocab).astype(np.int32)


def markov_corpus(
    seed: int, length: int, vocab: int, branching: int = 4
) -> np.ndarray:
    """Each token deterministically prefers one of ``branching`` successors."""
    rng = np.random.default_rng(seed)
    succ = rng.integers(0, vocab, size=(vocab, branching), dtype=np.int32)
    probs = rng.dirichlet(np.ones(branching) * 0.5, size=vocab).astype(np.float32)
    out = np.empty(length, dtype=np.int32)
    t = int(rng.integers(0, vocab))
    # vectorised-ish generation in blocks
    u = rng.random(length, dtype=np.float32)
    explore = rng.random(length) < 0.05
    wild = rng.integers(0, vocab, size=length, dtype=np.int32)
    cum = np.cumsum(probs, axis=1)
    for i in range(length):
        if explore[i]:
            t = int(wild[i])
        else:
            j = int(np.searchsorted(cum[t], u[i]))
            t = int(succ[t, min(j, branching - 1)])
        out[i] = t
    return out
