"""Sharded host data loader.

Deterministic per-(epoch, step, worker) batches drawn from a synthetic
corpus; each data-parallel worker reads its own disjoint slice (the "each
worker iterates its own partition" premise of DP, paper SS II.A).  A
one-deep prefetch thread hides host-side generation, mirroring the
``T_before`` data-input term.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from .synthetic import markov_corpus


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    corpus_tokens: int = 1 << 18
    seed: int = 0


class ShardedLoader:
    def __init__(self, cfg: DataConfig, num_workers: int = 1, worker: int = 0,
                 prefetch: int = 2):
        self.cfg = cfg
        self.num_workers = num_workers
        self.worker = worker
        assert cfg.global_batch % num_workers == 0
        self.local_batch = cfg.global_batch // num_workers
        corpus = markov_corpus(cfg.seed, cfg.corpus_tokens, cfg.vocab_size)
        # disjoint per-worker partition
        per = len(corpus) // num_workers
        self.corpus = corpus[worker * per : (worker + 1) * per]
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._step = 0
        self._thread = None

    def _make(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.cfg.seed, step, self.worker, 0xC07A)
        )
        S = self.cfg.seq_len
        starts = rng.integers(0, len(self.corpus) - S - 1, size=self.local_batch)
        idx = starts[:, None] + np.arange(S + 1)[None, :]
        window = self.corpus[idx]
        return {
            "tokens": jnp.asarray(window[:, :-1]),
            "labels": jnp.asarray(window[:, 1:]),
        }

    def __iter__(self) -> Iterator[dict]:
        def produce():
            s = 0
            while True:
                self._q.put(self._make(s))
                s += 1

        if self._thread is None:
            self._thread = threading.Thread(target=produce, daemon=True)
            self._thread.start()
        while True:
            yield self._q.get()


def make_loader(cfg: DataConfig, num_workers: int = 1, worker: int = 0):
    return ShardedLoader(cfg, num_workers, worker)


def synth_batch(key, cfg, shape_kind: str, batch: int, seq: int) -> dict:
    """Random batch for smoke tests / dry-run value execution."""
    k1, k2 = jax.random.split(key)
    out = {
        "tokens": jax.random.randint(k1, (batch, seq), 0, cfg.vocab_size, jnp.int32)
    }
    if shape_kind == "train":
        out["labels"] = jax.random.randint(
            k2, (batch, seq), 0, cfg.vocab_size, jnp.int32
        )
    return out
