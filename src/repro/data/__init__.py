"""Data pipeline: deterministic synthetic corpora + sharded host loader."""
from .pipeline import DataConfig, ShardedLoader, make_loader, synth_batch
from .synthetic import markov_corpus, zipf_tokens

__all__ = [
    "DataConfig",
    "ShardedLoader",
    "make_loader",
    "synth_batch",
    "markov_corpus",
    "zipf_tokens",
]
