"""Timeline tracing: planned-vs-measured step timelines as Chrome trace JSON.

Open the dump in ``chrome://tracing`` / Perfetto: one process row per view —

* ``planned``  — per-bucket compute/comm spans from the static
  ``CommSchedule`` + the analytic step times (what the planner *promised*);
* ``measured`` — full-step wall times from the monitor's ring buffer and
  the probe's comm/compute decompositions (what the hardware *delivered*);
* ``control``  — instant events marking re-plans;
* ``serve``    — per-request serving spans (queued → prefill → insert →
  decode, one Chrome-trace thread per request) plus queue-depth /
  page-pool counter tracks from the engine.

The measured events carry enough in ``args`` (bytes, phase) that the trace
round-trips into the perf model: ``core.perfmodel.calibrate_from_trace``
recovers mean ``t_comp`` / ``t_comm`` / effective link bandwidth from a
trace dict, which plug straight into ``simulate_schedule`` — measurements
calibrate the same model that produced the plan.

Multi-worker timestamps go through ``core.ccr.align_comm_times`` before
becoming spans, so rendezvous wait is excluded exactly as in the paper's
distributed profiler (§III.B, Fig. 3).
"""
from __future__ import annotations

import json
from typing import Any, Sequence

import numpy as np

from repro.core.ccr import align_comm_times

# Chrome trace pids: one logical process per view
PID_PLANNED = 1
PID_MEASURED = 2
PID_CONTROL = 3
PID_SERVE = 4

_US = 1e6


class TimelineTracer:
    """Collects trace events; ``to_chrome_trace()`` / ``save()`` export.

    ``max_events`` bounds host memory on long runs (paper-scale training
    is O(10^5) steps): the buffer is a ring, oldest spans fall off first —
    the same windowing discipline as the monitor's ring buffers."""

    def __init__(self, max_events: int = 100_000):
        import collections

        self.events: "collections.deque[dict]" = collections.deque(
            maxlen=int(max_events)
        )
        self._cursor_s = 0.0       # synthetic wall clock of measured steps

    # ---- low-level --------------------------------------------------------
    def add_event(
        self, name: str, *, pid: int, tid: int, ts_s: float, dur_s: float,
        cat: str = "", args: dict | None = None, ph: str = "X",
    ) -> None:
        ev = {
            "name": name, "ph": ph, "pid": pid, "tid": tid,
            "ts": ts_s * _US, "cat": cat,
        }
        if ph == "X":
            ev["dur"] = dur_s * _US
        if args:
            ev["args"] = args
        self.events.append(ev)

    # ---- measured view ----------------------------------------------------
    def record_step(self, step: int, phase: int, wall_s: float) -> None:
        """One full training step (ring-buffer signal)."""
        self.add_event(
            f"step {step}", pid=PID_MEASURED, tid=0,
            ts_s=self._cursor_s, dur_s=wall_s, cat="measured,step",
            args={"step": step, "phase": phase},
        )
        self._cursor_s += wall_s

    def record_sample(self, sample, *, bytes_on_wire: int | None = None) -> None:
        """One probe decomposition: back-to-back compute + comm spans.
        ``bytes_on_wire`` (the phase schedule's planned wire bytes) makes
        the comm span calibratable into an effective link bandwidth."""
        t0 = self._cursor_s
        self.add_event(
            "compute", pid=PID_MEASURED, tid=1, ts_s=t0,
            dur_s=sample.t_comp, cat="measured,compute",
            args={"step": sample.step, "phase": sample.phase},
        )
        comm_args: dict[str, Any] = {"step": sample.step, "phase": sample.phase}
        if bytes_on_wire is not None:
            comm_args["bytes"] = int(bytes_on_wire)
        self.add_event(
            "comm", pid=PID_MEASURED, tid=1, ts_s=t0 + sample.t_comp,
            dur_s=sample.t_comm, cat="measured,comm", args=comm_args,
        )

    def record_aligned_collectives(
        self,
        step: int,
        names: Sequence[str],
        starts: np.ndarray,
        ends: np.ndarray,
        *,
        bytes_per_op: Sequence[int] | None = None,
    ) -> None:
        """Per-collective spans from (workers, ops) timestamp arrays, with
        the paper's alignment applied: span start is the **last** worker's
        arrival, duration the aligned transfer time."""
        starts = np.asarray(starts, np.float64)
        ends = np.asarray(ends, np.float64)
        durs = align_comm_times(starts, ends)
        t_start = starts.max(axis=0)
        for i, name in enumerate(names):
            args = {"step": step, "op": i}
            if bytes_per_op is not None:
                args["bytes"] = int(bytes_per_op[i])
            self.add_event(
                name, pid=PID_MEASURED, tid=2,
                ts_s=float(t_start[i]), dur_s=float(max(durs[i], 0.0)),
                cat="measured,collective", args=args,
            )

    # ---- planned view -----------------------------------------------------
    def record_planned_phase(
        self, schedule, *, t_before: float, t_comp: float,
        link_bw: float, world: int, at_s: float = 0.0,
    ) -> None:
        """The planner's promised timeline for one phase: the same
        simulation the perf model runs (``simulate_schedule``), emitted as
        spans instead of a scalar."""
        from repro.core.perfmodel import schedule_comm_times

        plan = schedule.plan
        numels = plan.bucket_numels()
        total = sum(numels) or 1
        comp = [t_comp * n / total for n in numels]
        comm = schedule_comm_times(schedule, world=world, link_bw=link_bw)

        self.add_event(
            "before", pid=PID_PLANNED, tid=0, ts_s=at_s, dur_s=t_before,
            cat="planned,compute", args={"phase": schedule.phase},
        )
        t = at_s + t_before
        comm_free = t
        for b, (c_comp, c_comm) in enumerate(zip(comp, comm)):
            self.add_event(
                f"bwd bucket {b}", pid=PID_PLANNED, tid=0, ts_s=t,
                dur_s=c_comp, cat="planned,compute",
                args={"phase": schedule.phase, "bucket": b},
            )
            t += c_comp
            if c_comm > 0:
                start = max(t, comm_free)
                # bytes = ring-amplified wire bytes, the same convention
                # the measured comm spans use, so planned and measured
                # rows divide to the same effective bandwidth.  `selected`
                # holds bucket ids only at bucket granularity; leaf-
                # granularity schedules spread their comm evenly over the
                # buckets (matching schedule_comm_times), so the bytes
                # spread the same way
                if schedule.granularity == "bucket":
                    span_bytes = sum(
                        call.wire_bytes(world)
                        for s, call in zip(schedule.selected, schedule.calls)
                        if s == b
                    )
                else:
                    span_bytes = schedule.wire_bytes(world) / max(
                        plan.num_buckets, 1
                    )
                self.add_event(
                    f"comm bucket {b}", pid=PID_PLANNED, tid=1, ts_s=start,
                    dur_s=c_comm, cat="planned,comm",
                    args={
                        "phase": schedule.phase, "bucket": b,
                        "bytes": int(round(span_bytes)),
                    },
                )
                comm_free = start + c_comm

    def record_planned_buckets(
        self, schedule, *, world: int | None = None,
        link_bw: float | None = None, at_s: float = 0.0,
    ) -> None:
        """One named span per collective issue of a phase, in the exact
        order the overlap engine fires them (``CommSchedule.issue_order()``)
        — the per-bucket resolution the phase-level planned view lacks.

        Spans are laid back-to-back on their own planned thread; with a
        ``link_bw`` each span's duration is the call's ring transfer time,
        otherwise spans get a nominal unit width (ordering and naming are
        the payload, not the absolute timescale).  ``args`` carry phase /
        bucket / op / bytes so the smoke gate (and Perfetto queries) can
        count distinct buckets against ``plan.num_buckets``."""
        w = world if world is not None else schedule.world
        t = at_s
        for rank, i in enumerate(schedule.issue_order()):
            call = schedule.calls[i]
            sel = int(schedule.selected[i])
            span_bytes = call.wire_bytes(w)
            dur = span_bytes / link_bw if link_bw else 1e-6
            label = "bucket" if schedule.granularity == "bucket" else "leaf"
            self.add_event(
                f"issue {label} {sel} ({call.op})",
                pid=PID_PLANNED, tid=2, ts_s=t, dur_s=dur,
                cat="planned,issue",
                args={
                    "phase": schedule.phase, label: sel, "op": call.op,
                    "bytes": int(round(span_bytes)), "rank": rank,
                },
            )
            t += dur

    # ---- control view -----------------------------------------------------
    def record_replan(
        self, step: int, old_interval: int, new_interval: int, reason: str
    ) -> None:
        self.add_event(
            f"replan I {old_interval}->{new_interval}",
            pid=PID_CONTROL, tid=0, ts_s=self._cursor_s, dur_s=0.0,
            cat="control,replan", ph="i",
            args={"step": step, "old": old_interval, "new": new_interval,
                  "reason": reason},
        )

    # ---- serve view -------------------------------------------------------
    def record_request(self, comp, *, t0: float = 0.0) -> None:
        """Per-request lifecycle spans from a serve ``Completion``: one
        Chrome-trace thread per request id, with ``queued`` (submit →
        admit), ``prefill`` (admit → prefill end), ``insert`` (prefill end
        → first token), and ``decode`` (first token → finish) laid
        end-to-end.  ``t0`` rebases wall-clock stamps so traces start near
        zero.  Requests truncated before prefill (no first token) get only
        their queued span — there are no stages to show."""
        tid = int(comp.rid)
        args = {
            "rid": int(comp.rid),
            "prompt_len": int(comp.prompt_len),
            "new_tokens": len(comp.tokens),
            "finish_reason": comp.finish_reason,
        }
        admit = comp.admit_s if comp.admit_s is not None else comp.finish_s
        self.add_event(
            f"queued r{comp.rid}", pid=PID_SERVE, tid=tid,
            ts_s=comp.submit_s - t0, dur_s=max(admit - comp.submit_s, 0.0),
            cat="serve,queued", args=args,
        )
        if comp.admit_s is None or comp.first_token_s is None:
            return
        prefill_end = (
            comp.prefill_end_s
            if getattr(comp, "prefill_end_s", None) is not None
            else comp.first_token_s
        )
        stages = (
            ("prefill", comp.admit_s, prefill_end),
            ("insert", prefill_end, comp.first_token_s),
            ("decode", comp.first_token_s, comp.finish_s),
        )
        for stage, start, end in stages:
            self.add_event(
                f"{stage} r{comp.rid}", pid=PID_SERVE, tid=tid,
                ts_s=start - t0, dur_s=max(end - start, 0.0),
                cat=f"serve,{stage}", args=args,
            )

    def record_counter(
        self, name: str, ts_s: float, values: dict, *, pid: int = PID_SERVE
    ) -> None:
        """Chrome counter sample (``ph: "C"``) — queue depth, page-pool
        occupancy, active slots render as stacked area tracks."""
        self.events.append({
            "name": name, "ph": "C", "pid": pid, "tid": 0,
            "ts": ts_s * _US,
            "args": {k: float(v) for k, v in values.items()},
        })

    # ---- export -----------------------------------------------------------
    def to_chrome_trace(self) -> dict:
        meta = [
            {"name": "process_name", "ph": "M", "pid": pid,
             "args": {"name": label}}
            for pid, label in (
                (PID_PLANNED, "planned"),
                (PID_MEASURED, "measured"),
                (PID_CONTROL, "control"),
                (PID_SERVE, "serve"),
            )
        ]
        return {
            "traceEvents": meta + list(self.events),
            "displayTimeUnit": "ms",
        }

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)
        return path


__all__ = [
    "TimelineTracer",
    "PID_PLANNED",
    "PID_MEASURED",
    "PID_CONTROL",
    "PID_SERVE",
]
