"""Timeline tracing: planned-vs-measured step timelines as Chrome trace JSON.

Open the dump in ``chrome://tracing`` / Perfetto: one process row per view —

* ``planned``  — per-bucket compute/comm spans from the static
  ``CommSchedule`` + the analytic step times (what the planner *promised*);
* ``measured`` — full-step wall times from the monitor's ring buffer and
  the probe's comm/compute decompositions (what the hardware *delivered*);
* ``control``  — instant events marking re-plans.

The measured events carry enough in ``args`` (bytes, phase) that the trace
round-trips into the perf model: ``core.perfmodel.calibrate_from_trace``
recovers mean ``t_comp`` / ``t_comm`` / effective link bandwidth from a
trace dict, which plug straight into ``simulate_schedule`` — measurements
calibrate the same model that produced the plan.

Multi-worker timestamps go through ``core.ccr.align_comm_times`` before
becoming spans, so rendezvous wait is excluded exactly as in the paper's
distributed profiler (§III.B, Fig. 3).
"""
from __future__ import annotations

import json
from typing import Any, Sequence

import numpy as np

from repro.core.ccr import align_comm_times

# Chrome trace pids: one logical process per view
PID_PLANNED = 1
PID_MEASURED = 2
PID_CONTROL = 3

_US = 1e6


class TimelineTracer:
    """Collects trace events; ``to_chrome_trace()`` / ``save()`` export.

    ``max_events`` bounds host memory on long runs (paper-scale training
    is O(10^5) steps): the buffer is a ring, oldest spans fall off first —
    the same windowing discipline as the monitor's ring buffers."""

    def __init__(self, max_events: int = 100_000):
        import collections

        self.events: "collections.deque[dict]" = collections.deque(
            maxlen=int(max_events)
        )
        self._cursor_s = 0.0       # synthetic wall clock of measured steps

    # ---- low-level --------------------------------------------------------
    def add_event(
        self, name: str, *, pid: int, tid: int, ts_s: float, dur_s: float,
        cat: str = "", args: dict | None = None, ph: str = "X",
    ) -> None:
        ev = {
            "name": name, "ph": ph, "pid": pid, "tid": tid,
            "ts": ts_s * _US, "cat": cat,
        }
        if ph == "X":
            ev["dur"] = dur_s * _US
        if args:
            ev["args"] = args
        self.events.append(ev)

    # ---- measured view ----------------------------------------------------
    def record_step(self, step: int, phase: int, wall_s: float) -> None:
        """One full training step (ring-buffer signal)."""
        self.add_event(
            f"step {step}", pid=PID_MEASURED, tid=0,
            ts_s=self._cursor_s, dur_s=wall_s, cat="measured,step",
            args={"step": step, "phase": phase},
        )
        self._cursor_s += wall_s

    def record_sample(self, sample, *, bytes_on_wire: int | None = None) -> None:
        """One probe decomposition: back-to-back compute + comm spans.
        ``bytes_on_wire`` (the phase schedule's planned wire bytes) makes
        the comm span calibratable into an effective link bandwidth."""
        t0 = self._cursor_s
        self.add_event(
            "compute", pid=PID_MEASURED, tid=1, ts_s=t0,
            dur_s=sample.t_comp, cat="measured,compute",
            args={"step": sample.step, "phase": sample.phase},
        )
        comm_args: dict[str, Any] = {"step": sample.step, "phase": sample.phase}
        if bytes_on_wire is not None:
            comm_args["bytes"] = int(bytes_on_wire)
        self.add_event(
            "comm", pid=PID_MEASURED, tid=1, ts_s=t0 + sample.t_comp,
            dur_s=sample.t_comm, cat="measured,comm", args=comm_args,
        )

    def record_aligned_collectives(
        self,
        step: int,
        names: Sequence[str],
        starts: np.ndarray,
        ends: np.ndarray,
        *,
        bytes_per_op: Sequence[int] | None = None,
    ) -> None:
        """Per-collective spans from (workers, ops) timestamp arrays, with
        the paper's alignment applied: span start is the **last** worker's
        arrival, duration the aligned transfer time."""
        starts = np.asarray(starts, np.float64)
        ends = np.asarray(ends, np.float64)
        durs = align_comm_times(starts, ends)
        t_start = starts.max(axis=0)
        for i, name in enumerate(names):
            args = {"step": step, "op": i}
            if bytes_per_op is not None:
                args["bytes"] = int(bytes_per_op[i])
            self.add_event(
                name, pid=PID_MEASURED, tid=2,
                ts_s=float(t_start[i]), dur_s=float(max(durs[i], 0.0)),
                cat="measured,collective", args=args,
            )

    # ---- planned view -----------------------------------------------------
    def record_planned_phase(
        self, schedule, *, t_before: float, t_comp: float,
        link_bw: float, world: int, at_s: float = 0.0,
    ) -> None:
        """The planner's promised timeline for one phase: the same
        simulation the perf model runs (``simulate_schedule``), emitted as
        spans instead of a scalar."""
        from repro.core.perfmodel import schedule_comm_times

        plan = schedule.plan
        numels = plan.bucket_numels()
        total = sum(numels) or 1
        comp = [t_comp * n / total for n in numels]
        comm = schedule_comm_times(schedule, world=world, link_bw=link_bw)

        self.add_event(
            "before", pid=PID_PLANNED, tid=0, ts_s=at_s, dur_s=t_before,
            cat="planned,compute", args={"phase": schedule.phase},
        )
        t = at_s + t_before
        comm_free = t
        for b, (c_comp, c_comm) in enumerate(zip(comp, comm)):
            self.add_event(
                f"bwd bucket {b}", pid=PID_PLANNED, tid=0, ts_s=t,
                dur_s=c_comp, cat="planned,compute",
                args={"phase": schedule.phase, "bucket": b},
            )
            t += c_comp
            if c_comm > 0:
                start = max(t, comm_free)
                # bytes = ring-amplified wire bytes, the same convention
                # the measured comm spans use, so planned and measured
                # rows divide to the same effective bandwidth.  `selected`
                # holds bucket ids only at bucket granularity; leaf-
                # granularity schedules spread their comm evenly over the
                # buckets (matching schedule_comm_times), so the bytes
                # spread the same way
                if schedule.granularity == "bucket":
                    span_bytes = sum(
                        call.wire_bytes(world)
                        for s, call in zip(schedule.selected, schedule.calls)
                        if s == b
                    )
                else:
                    span_bytes = schedule.wire_bytes(world) / max(
                        plan.num_buckets, 1
                    )
                self.add_event(
                    f"comm bucket {b}", pid=PID_PLANNED, tid=1, ts_s=start,
                    dur_s=c_comm, cat="planned,comm",
                    args={
                        "phase": schedule.phase, "bucket": b,
                        "bytes": int(round(span_bytes)),
                    },
                )
                comm_free = start + c_comm

    # ---- control view -----------------------------------------------------
    def record_replan(
        self, step: int, old_interval: int, new_interval: int, reason: str
    ) -> None:
        self.add_event(
            f"replan I {old_interval}->{new_interval}",
            pid=PID_CONTROL, tid=0, ts_s=self._cursor_s, dur_s=0.0,
            cat="control,replan", ph="i",
            args={"step": step, "old": old_interval, "new": new_interval,
                  "reason": reason},
        )

    # ---- export -----------------------------------------------------------
    def to_chrome_trace(self) -> dict:
        meta = [
            {"name": "process_name", "ph": "M", "pid": pid,
             "args": {"name": label}}
            for pid, label in (
                (PID_PLANNED, "planned"),
                (PID_MEASURED, "measured"),
                (PID_CONTROL, "control"),
            )
        ]
        return {
            "traceEvents": meta + list(self.events),
            "displayTimeUnit": "ms",
        }

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)
        return path


__all__ = ["TimelineTracer", "PID_PLANNED", "PID_MEASURED", "PID_CONTROL"]
