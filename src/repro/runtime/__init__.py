"""Adaptive runtime: the layer between planning and execution (DESIGN.md §10).

Closes the loop the paper's "adaptive compression" claim needs:

    monitor  (measured CCR, ring buffers + sub-program probes)
      -> controller  (hysteresis re-planning: I = ceil(measured CCR))
        -> transitions  (EF residuals carried safely across plan switches)
          -> trace  (planned-vs-measured Chrome-trace timelines)

Entry points: ``Trainer.run(..., autotune=AutotuneConfig())`` and
``repro.api.fit(..., interval="adaptive")``.
"""
from .controller import (
    AdaptiveRuntime,
    AutotuneConfig,
    ReplanController,
    ReplanDecision,
    as_autotune_config,
    exposed_comm_scale,
)
from .monitor import (
    CCRMonitor,
    PhaseProbe,
    PhaseSample,
    build_schedule_only_fn,
    measure_workload_ccr,
    synthetic_probe,
)
from .trace import TimelineTracer
from .transitions import TransitionReport, carry_comp_state, residual_norm

__all__ = [
    "AdaptiveRuntime",
    "AutotuneConfig",
    "CCRMonitor",
    "PhaseProbe",
    "PhaseSample",
    "ReplanController",
    "ReplanDecision",
    "TimelineTracer",
    "TransitionReport",
    "as_autotune_config",
    "build_schedule_only_fn",
    "carry_comp_state",
    "exposed_comm_scale",
    "measure_workload_ccr",
    "residual_norm",
    "synthetic_probe",
]
