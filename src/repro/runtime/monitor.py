"""Online CCR monitor: the measurement half of the adaptive runtime.

The planner picks ``I = ceil(CCR)`` from the *analytic* profiler before a
single step runs (``core.ccr.analytic_ccr``).  The paper's headline claim,
however, is *adaptive* compression — the interval must track the CCR the
hardware actually delivers, which drifts with stragglers, congested links
and evolving batch shapes.  This module closes the measurement side of
that loop (DESIGN.md §10):

* :class:`CCRMonitor` — a per-step ring buffer of wall times plus a
  per-phase ring buffer of comm/compute decompositions, yielding a
  *running measured CCR* (overall and per phase);
* :class:`PhaseProbe` — produces one decomposition sample by timing the
  **compute-only** sub-program (the same step math with every collective
  elided — ``build_train_step(mesh=None)``) and the **schedule-only**
  sub-program (exactly the phase's planned collectives on zero buffers)
  against the full phase executable, via ``core.ccr.measure_ccr``.

The probe is deliberately a plain callable ``(state, batch, phase) ->
PhaseSample`` so tests and benchmarks can inject synthetic comm slowdowns
without ever touching a clock.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core.ccr import measure_ccr


@dataclasses.dataclass(frozen=True)
class PhaseSample:
    """One measured comm/compute decomposition of a phase's step."""

    phase: int
    t_comp: float
    t_comm: float
    step: int = 0
    # wall time of the full step (collectives included); 0.0 on synthetic
    # probes.  t_comp + t_comm - t_full is the communication the overlap
    # engine actually hid this sample (perfmodel.achieved_overlap_fraction).
    t_full: float = 0.0

    @property
    def ccr(self) -> float:
        return self.t_comm / max(self.t_comp, 1e-12)

    @property
    def achieved_overlap(self) -> float | None:
        """Measured overlap fraction, or None when the probe recorded no
        full-step wall time (synthetic probes)."""
        if self.t_full <= 0.0:
            return None
        from repro.core.perfmodel import achieved_overlap_fraction

        return achieved_overlap_fraction(self.t_comp, self.t_comm, self.t_full)


class CCRMonitor:
    """Ring buffers of measured step times and CCR decompositions.

    ``record_step`` feeds the cheap always-on signal (full-step wall time,
    one entry per training step); ``record_sample`` feeds the expensive
    occasional signal (a :class:`PhaseSample` from a probe).  The running
    measured CCR is the mean over the most recent ``window`` samples —
    per phase when asked, pooled otherwise.
    """

    def __init__(self, window: int = 32):
        self.window = int(window)
        self._steps: collections.deque = collections.deque(maxlen=self.window)
        self._samples: collections.deque = collections.deque(maxlen=self.window)

    # ---- feeding ----------------------------------------------------------
    def record_step(self, step: int, phase: int, wall_s: float) -> None:
        self._steps.append((int(step), int(phase), float(wall_s)))

    def record_sample(self, sample: PhaseSample) -> None:
        self._samples.append(sample)

    def clear_samples(self) -> None:
        """Drop the decomposition window (measurements taken under a plan
        that no longer exists must not drive the next decision)."""
        self._samples.clear()

    # ---- reading ----------------------------------------------------------
    @property
    def num_samples(self) -> int:
        return len(self._samples)

    def samples(self, phase: int | None = None) -> list[PhaseSample]:
        if phase is None:
            return list(self._samples)
        return [s for s in self._samples if s.phase == phase]

    def mean_step_time(self, phase: int | None = None) -> float | None:
        ts = [w for (_, p, w) in self._steps if phase is None or p == phase]
        return sum(ts) / len(ts) if ts else None

    def measured_times(self, phase: int | None = None) -> dict | None:
        """Mean ``(t_comp, t_comm)`` over the sample window, or None when
        no probe has run yet.  Samples with a full-step wall time also
        yield ``achieved_overlap`` — the fraction of the wire time the
        executed step actually hid under compute (predicted-vs-achieved
        counterpart of ``perfmodel.overlap_fraction``)."""
        ss = self.samples(phase)
        if not ss:
            return None
        t_comp = sum(s.t_comp for s in ss) / len(ss)
        t_comm = sum(s.t_comm for s in ss) / len(ss)
        out = {"t_comp": t_comp, "t_comm": t_comm,
               "ccr": t_comm / max(t_comp, 1e-12), "n": len(ss)}
        timed = [s for s in ss if s.t_full > 0.0]
        if timed:
            from repro.core.perfmodel import achieved_overlap_fraction

            out["t_full"] = sum(s.t_full for s in timed) / len(timed)
            out["achieved_overlap"] = achieved_overlap_fraction(
                sum(s.t_comp for s in timed) / len(timed),
                sum(s.t_comm for s in timed) / len(timed),
                out["t_full"],
            )
        return out

    def measured_ccr(self, phase: int | None = None) -> float | None:
        mt = self.measured_times(phase)
        return None if mt is None else mt["ccr"]

    def summary(self) -> dict:
        """JSON-serialisable digest for logs / FitResult."""
        mt = self.measured_times()
        return {
            "steps_recorded": len(self._steps),
            "probe_samples": len(self._samples),
            "mean_step_s": self.mean_step_time(),
            "measured_ccr": None if mt is None else mt["ccr"],
            "t_comp": None if mt is None else mt["t_comp"],
            "t_comm": None if mt is None else mt["t_comm"],
            "achieved_overlap": (
                None if mt is None else mt.get("achieved_overlap")
            ),
        }


# ---------------------------------------------------------------------------
# the real probe: sub-program timing against the live trainer
# ---------------------------------------------------------------------------

def _blocked(fn: Callable, *args) -> Callable[[], None]:
    def run():
        jax.block_until_ready(fn(*args))

    return run


class PhaseProbe:
    """Measures one phase's comm/compute decomposition on live state.

    Three sub-programs, cached after first build:

    * **full** — the trainer's own phase executable (collectives included);
    * **compute-only** — the identical step built with ``mesh=None`` so
      every collective is elided (``core.comm`` reduces become identities);
    * **schedule-only** — the **dense** schedule's collectives replayed on
      zero buffers (every bucket, uncompressed wire).

    ``core.ccr.measure_ccr`` does the timing.  The comm term is the dense
    one deliberately: the paper's rule ``I = ceil(CCR)`` is defined on the
    *uncompressed* comm/compute balance.  Timing the live compressed
    executable's collectives instead would divide the measured comm by
    ~I — the controller would then see CCR ≈ dense/I, conclude ``I = 1``,
    re-plan, see the dense CCR again, and oscillate.  Measuring the dense
    schedule keeps the measured CCR a property of the *workload*, so the
    controller has a fixed point.
    """

    def __init__(self, trainer, *, warmup: int = 1, iters: int = 2):
        self.trainer = trainer
        self.warmup = int(warmup)
        self.iters = int(iters)
        self._compute_only: dict[int, Callable] = {}
        self._comm_only: dict[int, Callable] = {}

    def invalidate(self) -> None:
        """Drop cached sub-programs (after a re-plan)."""
        self._compute_only.clear()
        self._comm_only.clear()

    # ---- sub-program builders ---------------------------------------------
    def _compute_fn(self, phase: int) -> Callable:
        if phase not in self._compute_only:
            from repro.train.trainer import build_train_step

            tr = self.trainer
            self._compute_only[phase] = build_train_step(
                tr.model, tr.optimizer, tr.compressor, tr.plan,
                phase=phase, mesh=None, dp_axes=(),
                clip_norm=tr.tc.clip_norm, donate=False,
            )
        return self._compute_only[phase]

    def _comm_fn(self, phase: int) -> Callable:
        # keyed on 0: the dense schedule is phase-independent
        if 0 not in self._comm_only:
            from repro.core import get_compressor

            tr = self.trainer
            dense = get_compressor("none").plan_phase(
                tr.plan, 0, world=tr.dp_world
            )
            self._comm_only[0] = build_schedule_only_fn(
                dense, mesh=tr.mesh, dp_axes=tr.dp_axes
            )
        return self._comm_only[0]

    # ---- the probe call ---------------------------------------------------
    def __call__(self, state, batch, phase: int) -> PhaseSample:
        tr = self.trainer
        full = tr._phase_fn(phase)
        step = jnp.asarray(state["step"], jnp.int32)
        args = (state["params"], state["opt"], state["comp"], batch, step)
        if tr.hierarchical:
            # the compute-only program is per-pod: take pod 0's block of
            # the full (n_pods, ...) host-side state
            from repro.train.trainer import strip_pod_block

            flat = strip_pod_block(
                (args[0], args[1], args[2]), expect_local=False
            )
            comp_args = flat + (batch, step)
        else:
            comp_args = args
        res = measure_ccr(
            _blocked(full, *args),
            _blocked(self._compute_fn(phase), *comp_args),
            step_comm_only=_blocked(self._comm_fn(phase)),
            warmup=self.warmup,
            iters=self.iters,
        )
        return PhaseSample(
            phase=int(phase),
            t_comp=res["t_comp"],
            t_comm=res["t_comm"],
            step=int(state["step"]),
            t_full=res["t_full"],
        )


def build_schedule_only_fn(schedule, *, mesh=None, dp_axes: Sequence[str] = ()):
    """jit a program that performs exactly the collectives a
    ``CommSchedule`` plans — on zero buffers, one per planned call — so the
    wire cost of a phase can be timed in isolation.

    Single-process (``mesh=None``): the collectives are identities, so the
    measured time is the (near-zero) dispatch floor — the honest answer on
    one worker.
    """
    import numpy as np

    shapes = [
        (max(1, c.payload_bytes // max(np.dtype("float32").itemsize, 1)),)
        for c in schedule.calls
    ]

    def body(*bufs):
        from jax import lax

        out = []
        for b in bufs:
            if mesh is not None and dp_axes:
                out.append(lax.psum(b, tuple(dp_axes)))
            else:
                out.append(b + 0.0)
        return tuple(out)

    if mesh is not None and dp_axes:
        from jax.sharding import PartitionSpec as P

        from repro.train.trainer import shard_map_compat

        mapped = shard_map_compat(
            body, mesh,
            tuple(P() for _ in shapes), tuple(P() for _ in shapes),
            tuple(dp_axes),
        )
        jitted = jax.jit(mapped)
    else:
        jitted = jax.jit(body)

    bufs = tuple(jnp.zeros(s, jnp.float32) for s in shapes)

    def run():
        if bufs:
            jax.block_until_ready(jitted(*bufs))

    return run


# ---------------------------------------------------------------------------
# synthetic probes (tests / benchmarks) and one-off workload measurement
# ---------------------------------------------------------------------------

def synthetic_probe(
    t_comp: float, ccr: float | Callable[[int], float]
) -> Callable:
    """A probe that reports a prescribed CCR instead of touching a clock —
    the injected-comm-slowdown harness of the acceptance tests.  ``ccr``
    may be a float or a ``step -> ccr`` callable (drifting links)."""

    def probe(state, batch, phase) -> PhaseSample:
        step = int(state["step"]) if isinstance(state, dict) else 0
        c = ccr(step) if callable(ccr) else float(ccr)
        return PhaseSample(
            phase=int(phase), t_comp=float(t_comp),
            t_comm=float(t_comp) * c, step=step,
        )

    return probe


def measure_workload_ccr(
    trainer, state, batch, *, phases: Sequence[int] | None = None,
    warmup: int = 1, iters: int = 2,
) -> dict:
    """One-off measured CCR of a trainer's workload: probes each requested
    phase once and pools the decompositions.  This is what
    ``repro.api.tune(measured=True)`` reports alongside the analytic
    ranking."""
    probe = PhaseProbe(trainer, warmup=warmup, iters=iters)
    todo = list(phases) if phases is not None else list(range(trainer.num_phases))
    mon = CCRMonitor(window=max(len(todo), 8))
    for p in todo:
        st = dict(state)
        mon.record_sample(probe(st, batch, int(p)))
    out = mon.measured_times() or {"t_comp": 0.0, "t_comm": 0.0, "ccr": 0.0}
    out["per_phase"] = {
        s.phase: s.ccr for s in mon.samples()
    }
    return out


__all__ = [
    "CCRMonitor",
    "PhaseProbe",
    "PhaseSample",
    "build_schedule_only_fn",
    "measure_workload_ccr",
    "synthetic_probe",
]
