"""Safe re-plan boundaries: carrying compressor state across a plan switch.

A re-plan changes the interval ``I`` (and with it the bucket plan, since
COVAP's tensor sharding slices oversized buckets into ``min(., I)`` pieces).
The error-feedback residual, however, is **parameter-structured**, not
bucket-structured (``core.error_feedback``): it is exactly the gradient
mass not yet communicated.  The paper's accuracy argument (§III.D) only
needs that mass to be conserved — so the default transition policy is
``"carry"``: the residual pytree moves to the new plan untouched, and its
global norm is preserved bit-for-bit (the acceptance invariant).

Policies:

* ``"carry"``  — keep residuals verbatim (default; norm preserved);
* ``"rescale"`` — when the cadence *shortens* (``new_I < old_I``) scale
  residuals by ``new_I / old_I``: the compensation scheduler now drains
  the buffer over fewer steps, and the damping avoids a one-time
  over-compensation spike right after the switch;
* ``"flush"``  — zero the residuals (the conservative reset; the dropped
  norm is reported so callers can log the accuracy cost).

Structure changes (EF turning on/off at ``I = 1``, leaf-granularity
state such as PowerSGD's ``{q, residual}``) fall back to re-initialising
from the new compressor, with the dropped norm reported.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TransitionReport:
    """What happened to compressor state at one re-plan boundary."""

    step: int
    old_interval: int
    new_interval: int
    policy: str                 # "carry" | "rescale" | "flush" | "reinit"
    norm_before: float
    norm_after: float

    @property
    def norm_dropped(self) -> float:
        return max(0.0, self.norm_before - self.norm_after)

    def summary(self) -> dict:
        return dataclasses.asdict(self)


def residual_norm(comp_state: Any) -> float:
    """Global L2 norm of every floating leaf in a compressor state pytree.

    Handles the three state shapes in the repo: ``()`` (no EF), a
    parameter-structured residual pytree (COVAP & friends), and PowerSGD's
    ``{"q": [...], "residual": [...]}`` dict with ``None`` holes — only the
    ``residual`` half counts (``q`` is a sketch, not deferred gradient)."""
    if isinstance(comp_state, dict) and "residual" in comp_state:
        comp_state = comp_state["residual"]
    total = None
    for leaf in jax.tree_util.tree_leaves(comp_state):
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating):
            sq = jnp.sum(jnp.square(leaf.astype(jnp.float32)))
            total = sq if total is None else total + sq
    if total is None:
        return 0.0
    # one device sync for the whole tree, not one per leaf
    return math.sqrt(float(total))


def _same_structure(a: Any, b: Any) -> bool:
    return (
        jax.tree_util.tree_structure(a) == jax.tree_util.tree_structure(b)
        and all(
            getattr(x, "shape", None) == getattr(y, "shape", None)
            for x, y in zip(
                jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
            )
        )
    )


def carry_comp_state(
    comp_state: Any,
    *,
    new_compressor,
    new_plan,
    params_like: Any,
    step: int = 0,
    old_interval: int = 1,
    new_interval: int = 1,
    policy: str = "carry",
) -> tuple[Any, TransitionReport]:
    """Move compressor state across a re-plan boundary.

    Returns ``(new_state, report)``.  ``params_like`` is the *current*
    parameter pytree (hierarchical states keep their leading pod axis, so
    re-initialised residuals match whatever shape the carried params have).
    """
    if policy not in ("carry", "rescale", "flush"):
        raise ValueError(f"unknown transition policy {policy!r}")
    norm_before = residual_norm(comp_state)
    fresh = new_compressor.init_state(params_like, new_plan)

    def report(state, eff_policy):
        return state, TransitionReport(
            step=int(step),
            old_interval=int(old_interval),
            new_interval=int(new_interval),
            policy=eff_policy,
            norm_before=norm_before,
            norm_after=residual_norm(state),
        )

    if policy == "flush":
        return report(fresh, "flush")

    if not _same_structure(comp_state, fresh):
        # EF turned on/off, or the state family changed (e.g. leaf-
        # granularity PowerSGD): no meaningful carry exists — reinit, and
        # surface the dropped norm in the report.
        return report(fresh, "reinit")

    if policy == "rescale" and new_interval < old_interval:
        factor = float(new_interval) / float(max(old_interval, 1))
        scaled = jax.tree.map(
            lambda r: (r.astype(jnp.float32) * factor).astype(r.dtype)
            if hasattr(r, "dtype") and jnp.issubdtype(r.dtype, jnp.floating)
            else r,
            comp_state,
        )
        return report(scaled, "rescale")

    # "rescale" with a non-shrinking cadence is a plain carry (factor 1)
    return report(comp_state, "carry")


__all__ = ["TransitionReport", "carry_comp_state", "residual_norm"]
