"""Re-planning controller: measured CCR in, fresh plans out.

The decision rule is the paper's ``I = ceil(CCR)`` applied to the
*measured* CCR from :class:`~repro.runtime.monitor.CCRMonitor`, wrapped in
a hysteresis band so transient stragglers don't thrash the executable
cache:

* the current interval ``I`` is *consistent* with any measured CCR in
  ``(I - 1 - h, I + h]`` (``h`` = ``hysteresis``) — ``ceil`` would pick
  ``I`` for the un-widened band, and ``h`` widens it on both sides;
* a re-plan needs ``patience`` consecutive out-of-band decisions, at
  least ``cooldown_steps`` since the previous re-plan, and fewer than
  ``max_replans`` switches so far;
* the new interval is ``select_interval(measured_ccr)`` — one hop puts
  the interval within ±1 of ``ceil(measured CCR)``, so convergence is
  bounded by construction, not by luck.

:class:`AdaptiveRuntime` glues monitor → controller → transitions → trace
around a live :class:`~repro.train.trainer.Trainer`; the trainer calls
``after_step`` once per step and everything else is internal.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro.core.ccr import select_interval

from .monitor import CCRMonitor, PhaseProbe, PhaseSample
from .trace import TimelineTracer


@dataclasses.dataclass(frozen=True)
class AutotuneConfig:
    """Knobs of the adaptive runtime (``Trainer.run(autotune=...)``)."""

    measure_every: int = 16      # steps between probe measurements
    warmup_steps: int = 4        # steps before the first probe (compile noise)
    window: int = 8              # probe samples pooled per decision
    hysteresis: float = 0.25     # CCR deadband beyond the ceil boundaries
    patience: int = 2            # consecutive drifting decisions to re-plan
    cooldown_steps: int = 32     # min steps between re-plans
    max_replans: int = 8
    max_interval: int = 64
    # circuit breaker (repro.resilience, DESIGN.md §16): when the measured
    # CCR oscillates across a band boundary — straggler flapping, noisy
    # probes, or an injected ccr_skew fault — hysteresis+patience damp the
    # thrash but cannot stop a slow alternation that re-plans every
    # cooldown.  The breaker latches the controller OPEN (interval frozen,
    # decisions keep flowing with reason "circuit-open:...") after
    # breaker_replans re-plans land within any breaker_window_steps span.
    # 0 disables.  Latched is latched: only an explicit reset_breaker()
    # (an operator action) closes it again.
    breaker_replans: int = 4
    breaker_window_steps: int = 256
    transition_policy: str = "carry"   # "carry" | "rescale" | "flush"
    probe: Callable[..., PhaseSample] | None = None  # override (tests/bench)
    probe_warmup: int = 1
    probe_iters: int = 2
    trace_path: str | None = None      # Chrome-trace JSON dump on finish


@dataclasses.dataclass(frozen=True)
class ReplanDecision:
    replan: bool
    interval: int                # target interval (== current when not replan)
    measured_ccr: float | None
    reason: str


class ReplanController:
    """Hysteresis policy over the monitor's running measured CCR.

    ``exposed_scale`` re-prices the measured CCR for the sync mode's
    *exposed* communication (sharded sync, DESIGN.md §13): the probe's
    comm term reflects the dense all-reduce volume, but under
    ``sync="sharded"`` only the reduce-scatter half — ``(W-1)/W`` of the
    buffer vs the all-reduce's ``2(W-1)/W``, i.e. exactly half — must hide
    behind the backward pass (the param all-gather rides the next
    forward).  The interval rule ``I = ceil(CCR)`` therefore applies to
    ``measured_ccr * exposed_scale``; with the default 1.0 the behaviour
    is unchanged."""

    def __init__(
        self, config: AutotuneConfig, *, interval: int,
        exposed_scale: float = 1.0,
    ):
        self.config = config
        self.interval = int(interval)
        self.exposed_scale = float(exposed_scale)
        self.pending = 0
        self.replans = 0
        self.last_replan_step = -(10 ** 9)
        self.decisions: list[ReplanDecision] = []
        self.replan_steps: list[int] = []
        self.frozen = False
        self.freeze_reason: str | None = None

    # ---- circuit breaker --------------------------------------------------
    def freeze(self, reason: str) -> None:
        """Latch the breaker open: the interval is frozen and every
        subsequent decision is a no-replan with reason
        ``"circuit-open:<reason>"``."""
        self.frozen = True
        self.freeze_reason = reason

    def reset_breaker(self) -> None:
        """Close a latched breaker (operator action): re-plan history is
        kept, but the window that tripped it is cleared so the very next
        re-plan cannot instantly re-latch."""
        self.frozen = False
        self.freeze_reason = None
        self.replan_steps.clear()

    def _check_breaker(self, step: int) -> None:
        c = self.config
        if c.breaker_replans <= 0 or self.frozen:
            return
        recent = [
            s for s in self.replan_steps
            if step - s < c.breaker_window_steps
        ]
        if len(recent) >= c.breaker_replans:
            self.freeze(
                f"{len(recent)} replans in {c.breaker_window_steps} steps"
            )

    # ---- the band ---------------------------------------------------------
    def consistent(self, ccr: float) -> bool:
        """Is the current interval still the right pick for this
        (already exposure-scaled) CCR?"""
        h = self.config.hysteresis
        lo = self.interval - 1 - h
        hi = self.interval + h
        return lo < ccr <= hi

    # ---- one decision -----------------------------------------------------
    def observe(self, step: int, measured_ccr: float | None) -> ReplanDecision:
        c = self.config

        def out(replan, interval, reason):
            d = ReplanDecision(replan, interval, measured_ccr, reason)
            self.decisions.append(d)
            if replan:
                self.pending = 0
                self.replans += 1
                self.last_replan_step = int(step)
                self.interval = int(interval)
                self.replan_steps.append(int(step))
                # latch AFTER the commit: the replan that trips the
                # breaker still lands (so max_replans stays the hard
                # bound); everything later is frozen out
                self._check_breaker(int(step))
            return d

        if self.frozen:
            return out(False, self.interval,
                       f"circuit-open:{self.freeze_reason}")
        if measured_ccr is None:
            return out(False, self.interval, "no-measurement")
        effective_ccr = measured_ccr * self.exposed_scale
        if self.consistent(effective_ccr):
            self.pending = 0
            return out(False, self.interval, "in-band")
        target = select_interval(effective_ccr, c.max_interval)
        if target == self.interval:
            # out of the widened band but ceil still agrees (h < drift < 1)
            self.pending = 0
            return out(False, self.interval, "ceil-agrees")
        self.pending += 1
        if self.pending < c.patience:
            return out(False, self.interval, f"pending {self.pending}/{c.patience}")
        if step - self.last_replan_step < c.cooldown_steps:
            return out(False, self.interval, "cooldown")
        if self.replans >= c.max_replans:
            return out(False, self.interval, "max-replans")
        return out(True, target, f"ccr {effective_ccr:.2f} -> I {target}")


class AdaptiveRuntime:
    """monitor → controller → transitions → trace, around one Trainer.

    The trainer owns the loop; this object owns everything adaptive.  One
    call per step::

        state = runtime.after_step(state, batch, wall_s=dt)

    may mutate the trainer (new compressor / plan / executables) and
    returns the (possibly transitioned) train state.
    """

    def __init__(self, trainer, config: AutotuneConfig | None = None):
        self.trainer = trainer
        self.config = config or AutotuneConfig()
        self.monitor = CCRMonitor(window=self.config.window)
        self.controller = ReplanController(
            self.config, interval=trainer.tc.interval,
            exposed_scale=exposed_comm_scale(trainer),
        )
        self.tracer = TimelineTracer()
        self._default_probe = (
            None
            if self.config.probe is not None
            else PhaseProbe(
                trainer,
                warmup=self.config.probe_warmup,
                iters=self.config.probe_iters,
            )
        )
        self.transitions: list = []
        self._step_count = 0
        self._probe_count = 0
        self._planned_key = None
        self._events = None          # obs EventLog when telemetry is attached

    def attach_telemetry(self, telemetry) -> None:
        """Route this runtime through a :class:`repro.obs.Telemetry`
        bundle: planned/measured/control spans land in the bundle's shared
        tracer (one Chrome trace alongside serve spans), and every probe
        + controller decision is emitted to the structured event log — the
        re-plan audit trail that makes ``I`` switches explainable after
        the fact.  Existing tracer events are carried over so a
        mid-training attach loses nothing."""
        if not telemetry.enabled:
            return
        for ev in self.tracer.events:
            telemetry.tracer.events.append(ev)
        telemetry.tracer._cursor_s = max(
            telemetry.tracer._cursor_s, self.tracer._cursor_s
        )
        self.tracer = telemetry.tracer
        self._events = telemetry.events

    # ---- probing ----------------------------------------------------------
    def _probe(self, state, batch, phase: int) -> PhaseSample:
        if self.config.probe is not None:
            return self.config.probe(state, batch, phase)
        return self._default_probe(state, batch, phase)

    def _due(self, i: int) -> bool:
        c = self.config
        if i < c.warmup_steps:
            return False
        return (i - c.warmup_steps) % max(c.measure_every, 1) == 0

    def due_next(self) -> bool:
        """Will the NEXT ``after_step`` call probe?  The trainer blocks on
        device completion (for a meaningful wall time) only when it will —
        an always-on block would serialise host/device pipelining on every
        step to feed a diagnostic-only metric."""
        return self._due(self._step_count)

    # ---- the per-step hook -------------------------------------------------
    def after_step(self, state, batch, *, wall_s: float | None, log=None):
        tr = self.trainer
        step = int(state["step"]) - 1       # the step that just ran
        phase = step % tr.num_phases
        if wall_s is not None:
            self.monitor.record_step(step, phase, wall_s)
            self.tracer.record_step(step, phase, wall_s)
        i = self._step_count
        self._step_count += 1
        if not self._due(i):
            return state

        # probe phases round-robin rather than whatever phase the step
        # landed on: with num_phases | measure_every the step phase is
        # constant, and always sampling one phase (possibly a skip phase
        # with zero planned collectives) would bias the pooled CCR
        probe_phase = self._probe_count % max(tr.num_phases, 1)
        self._probe_count += 1
        sample = self._probe(state, batch, probe_phase)
        self.monitor.record_sample(sample)
        # the probe's comm term is the DENSE schedule's (see PhaseProbe),
        # so the calibration bytes are the dense ring-amplified wire bytes
        from repro.core.ccr import allreduce_bytes_on_wire
        from repro.core.comm import dense_bytes

        wire = allreduce_bytes_on_wire(dense_bytes(tr.plan), tr.dp_world)
        self.tracer.record_sample(sample, bytes_on_wire=int(round(wire)))
        measured = self.monitor.measured_ccr()
        decision = self.controller.observe(step, measured)
        if self._events is not None:
            self._events.emit(
                "probe",
                step=int(sample.step), phase=int(sample.phase),
                t_comp=float(sample.t_comp), t_comm=float(sample.t_comm),
                ccr=float(sample.ccr),
                achieved_overlap=(
                    float(sample.achieved_overlap)
                    if sample.achieved_overlap is not None else None
                ),
            )
            self._events.emit(
                "replan_decision",
                step=int(step),
                interval=int(decision.interval),
                replan=bool(decision.replan),
                reason=decision.reason,
                measured_ccr=(
                    float(measured) if measured is not None else None
                ),
                effective_ccr=(
                    float(measured * self.controller.exposed_scale)
                    if measured is not None else None
                ),
                exposed_scale=self.controller.exposed_scale,
                pending=int(self.controller.pending),
            )
        if not decision.replan:
            return state

        old_interval = tr.tc.interval
        state, report = tr.replan(
            decision.interval, state,
            policy=self.config.transition_policy, step=step,
        )
        self.transitions.append(report)
        # old-plan measurements must not drive new-plan decisions: drop
        # the sample window (and the compiled sub-programs) at the switch
        self.monitor.clear_samples()
        self._probe_count = 0
        if self._default_probe is not None:
            self._default_probe.invalidate()
        self.tracer.record_replan(
            step, old_interval, decision.interval, decision.reason
        )
        if self._events is not None:
            self._events.emit(
                "replan",
                step=int(step),
                old_interval=int(old_interval),
                new_interval=int(decision.interval),
                reason=decision.reason,
                policy=report.policy,
                residual_norm_before=float(report.norm_before),
                residual_norm_after=float(report.norm_after),
            )
        if log:
            log(
                f"[autotune] step {step}: measured CCR "
                f"{decision.measured_ccr:.2f} -> re-plan I={decision.interval}"
                f" (residual norm {report.norm_before:.3e} -> "
                f"{report.norm_after:.3e}, {report.policy})"
            )
        return state

    # ---- wrap-up -----------------------------------------------------------
    def _record_planned(self) -> None:
        """Emit the planner's promised timeline for the final plan, priced
        with the *measured* calibration (measured t_comp; effective link
        bandwidth = planned wire bytes / measured comm seconds) so the
        planned and measured rows of the trace are directly comparable."""
        mt = self.monitor.measured_times()
        if mt is None:
            return
        tr = self.trainer
        key = (tr.tc.interval, tr.num_phases)
        if self._planned_key == key:
            return     # chunked runs call finish() repeatedly: record once
        self._planned_key = key
        scheds = tr.schedules()
        mean_wire = sum(s.wire_bytes(tr.dp_world) for s in scheds) / max(
            len(scheds), 1
        )
        if mt["t_comm"] > 1e-9 and mean_wire > 0:
            link_bw = mean_wire / mt["t_comm"]
        else:
            from repro.core.ccr import HardwareSpec

            link_bw = HardwareSpec.v5e().ici_bw
        at = 0.0
        for s in scheds:
            self.tracer.record_planned_phase(
                s, t_before=mt["t_comp"] * 0.5, t_comp=mt["t_comp"],
                link_bw=link_bw, world=tr.dp_world, at_s=at,
            )
            # per-bucket issue-order spans (the resolution the phase view
            # lacks): one named span per collective issue of this phase
            self.tracer.record_planned_buckets(
                s, world=tr.dp_world, link_bw=link_bw, at_s=at,
            )
            at += mt["t_comp"] * 1.5 + s.wire_bytes(tr.dp_world) / link_bw

    def finish(self) -> dict:
        self._record_planned()
        if self.config.trace_path:
            self.tracer.save(self.config.trace_path)
        return self.summary()

    def summary(self) -> dict:
        return {
            "interval": self.controller.interval,
            "replans": self.controller.replans,
            "breaker_open": self.controller.frozen,
            "breaker_reason": self.controller.freeze_reason,
            "measured_ccr": self.monitor.measured_ccr(),
            "monitor": self.monitor.summary(),
            "transitions": [t.summary() for t in self.transitions],
            "trace_events": len(self.tracer.events),
        }


def exposed_comm_scale(trainer, hw=None) -> float:
    """Fraction of the probe's (dense all-reduce) comm term that stays
    *exposed* behind the backward pass under the trainer's sync mode —
    derived from the static per-link ``CommSchedule`` accounting instead
    of a hardcoded scalar.

    ``allreduce``: everything — 1.0.  ``sharded``: per phase, the exposed
    time is the SLOWEST link's exposed wire bytes over that link's
    bandwidth (the ICI reduce-scatters and — hierarchical pods — the DCN
    shard exchange run back-to-back per bucket but the slow link
    dominates); the baseline is the all-reduce-equivalent of the same
    payloads on the fast link, which is what the probe's dense comm term
    measures.  On a flat mesh this reduces to exactly 0.5: the RS half
    moves ``(W-1)/W`` of the buffer where the all-reduce moves
    ``2(W-1)/W``, and the param all-gather is deferred under the next
    forward pass.  A pod mesh raises it by the DCN exposure.
    Single-worker trainers keep 1.0: there is no collective to halve, and
    the measured comm floor is dispatch overhead either way.

    ``hw`` (default :meth:`HardwareSpec.v5e`) supplies the per-link
    bandwidths ``{"ici", "dcn"}``.
    """
    if getattr(trainer.tc, "sync", "allreduce") != "sharded":
        return 1.0
    if trainer.dp_world <= 1:
        return 1.0
    try:
        from repro.core.ccr import HardwareSpec

        if hw is None:
            hw = HardwareSpec.v5e()
        bw = {"ici": hw.ici_bw, "dcn": hw.dcn_bw}
        num = 0.0
        den = 0.0
        for s in trainer.schedules():
            by_link = s.exposed_wire_bytes_by_link(trainer.dp_world)
            num += max(
                (v / bw.get(l, hw.ici_bw) for l, v in by_link.items()),
                default=0.0,
            )
            for c in s.calls:
                wire = c.wire_bytes(trainer.dp_world)
                # AR-equivalent of this payload: an RS (or AG) half moves
                # exactly half of what the full ring all-reduce would
                if c.op in ("reduce_scatter", "all_gather"):
                    wire *= 2.0
                den += wire / hw.ici_bw
        if den <= 0.0:
            return 1.0
        return min(1.0, num / den)
    except Exception:
        return 0.5    # the flat-mesh closed form


def as_autotune_config(autotune) -> AutotuneConfig | None:
    """Normalise ``Trainer.run(autotune=...)``: None/False off, True ->
    defaults, an :class:`AutotuneConfig` passes through."""
    if autotune is None or autotune is False:
        return None
    if autotune is True:
        return AutotuneConfig()
    if isinstance(autotune, AutotuneConfig):
        return autotune
    raise TypeError(f"autotune must be None/bool/AutotuneConfig, got {autotune!r}")


__all__ = [
    "AdaptiveRuntime",
    "AutotuneConfig",
    "ReplanController",
    "ReplanDecision",
    "as_autotune_config",
    "exposed_comm_scale",
]
