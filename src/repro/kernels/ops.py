"""jit'd public wrappers for the Pallas kernels."""
from .ef_covap import ef_update
from .lowrank import matmul
from .quantize import dequantize_fp8, quantize_fp8
from .sign_compress import sign_compress, sign_decompress
from .topk_threshold import sample_threshold, threshold_filter

__all__ = [
    "ef_update",
    "matmul",
    "quantize_fp8",
    "dequantize_fp8",
    "sign_compress",
    "sign_decompress",
    "threshold_filter",
    "sample_threshold",
]
