"""Threshold-filter kernel for Top-k / DGC sparsification.

The DGC trick: estimate the k-th magnitude from a sample, then a single
streaming pass masks |x| < threshold and counts survivors per block (the
count feeding the variable-length pack).  This replaces the O(N log N)
sort that dominates Top-k's 1560 ms overhead in the paper's Table II.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import ELEMWISE_BLOCK, INTERPRET, pad_to_multiple, unpad


def _thresh_kernel(x_ref, t_ref, y_ref, c_ref):
    x = x_ref[...]
    keep = jnp.abs(x) >= t_ref[0]
    y_ref[...] = jnp.where(keep, x, jnp.zeros_like(x))
    c_ref[0] = jnp.sum(keep.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def threshold_filter(x: jax.Array, threshold: jax.Array, *,
                     block: int = ELEMWISE_BLOCK,
                     interpret: bool | None = None):
    """x: (N,) -> (masked (N,), counts (nblocks,) int32)."""
    interpret = INTERPRET if interpret is None else interpret
    xp, n = pad_to_multiple(x, block)
    nb = xp.shape[0] // block
    x2 = xp.reshape(nb, block)
    t = jnp.asarray(threshold, x.dtype).reshape(1)
    y, c = pl.pallas_call(
        _thresh_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x2.shape, x.dtype),
            jax.ShapeDtypeStruct((nb,), jnp.int32),
        ],
        interpret=interpret,
    )(x2, t)
    return unpad(y.reshape(-1), n), c


def sample_threshold(x: jax.Array, ratio: float, sample: int = 4096) -> jax.Array:
    """Estimate the (1-ratio) magnitude quantile from a strided sample."""
    n = x.shape[0]
    stride = max(n // sample, 1)
    s = jnp.abs(x[::stride])
    k = jnp.clip(jnp.int32(s.shape[0] * (1.0 - ratio)), 0, s.shape[0] - 1)
    return jnp.sort(s)[k]
