"""Block-scaled FP8 quantize/dequantize kernels.

Beyond-paper compressor substrate: per-block amax scaling into
float8_e4m3fn gives 4x wire compression with far better fidelity than
naive casting.  One fused pass computes the block amax (VPU reduction in
VMEM) and writes the scaled fp8 payload + per-block scale.

Block = one (8x128)-aligned tile row of ``block`` elements.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pad_to_multiple, unpad

FP8_MAX = 448.0  # float8_e4m3fn max finite


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax / FP8_MAX, 1e-12)
    q_ref[...] = (x / scale).astype(jnp.float8_e4m3fn)
    s_ref[0] = scale.astype(jnp.float32)


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[0]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def quantize_fp8(x: jax.Array, *, block: int = 8192, interpret: bool | None = None):
    """x: (N,) fp32/bf16 -> (q (N,) fp8, scales (nblocks,) fp32)."""
    interpret = INTERPRET if interpret is None else interpret
    xp, n = pad_to_multiple(x, block)
    nb = xp.shape[0] // block
    x2 = xp.reshape(nb, block)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x2.shape, jnp.float8_e4m3fn),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
        ],
        interpret=interpret,
    )(x2)
    return unpad(q.reshape(-1), n), s


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dequantize_fp8(q: jax.Array, scales: jax.Array, *, block: int = 8192,
                   interpret: bool | None = None) -> jax.Array:
    interpret = INTERPRET if interpret is None else interpret
    qp, n = pad_to_multiple(q, block)
    nb = qp.shape[0] // block
    q2 = qp.reshape(nb, block)
    x = pl.pallas_call(
        _dequant_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(q2.shape, jnp.float32),
        interpret=interpret,
    )(q2, scales)
    return unpad(x.reshape(-1), n)
