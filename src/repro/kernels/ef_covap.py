"""Fused COVAP error-feedback update kernel — the compression hot-spot.

One HBM pass computes, per bucket:

    t    = g + coeff * r
    send = t        if the bucket is selected this phase else 0
    r'   = 0        if selected                           else t

The reference path (core/compressors/covap.py) does this with 2-3 separate
elementwise ops (2-3 HBM round trips over the gradient); fusing makes
compression overhead a single streaming pass — the structural version of
the paper's "near-zero compression overhead" claim.

Layout: buckets are flat vectors, viewed as (blocks, 8, 128) tiles; grid is
1-D over blocks; ``selected`` is a *static* kernel specialisation (the
coarse filter is static per phase, SS III.A).

Rounding note: the fused single pass compiles ``g + c*r`` to an FMA (one
rounding) where the 2-op jnp reference rounds the product separately, so
results are ~1 ulp MORE accurate but not bitwise-identical to
``kernels.ref.ef_update_ref``.  The segmented execute path therefore
engages this kernel on TPU by default and on CPU only via the explicit
``use_ef_kernel=True`` compressor option (tests/benchmarks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import ELEMWISE_BLOCK, INTERPRET, pad_to_multiple, unpad


def _kernel_selected(g_ref, r_ref, coeff_ref, send_ref, rnew_ref):
    c = coeff_ref[0]
    t = g_ref[...] + c * r_ref[...]
    send_ref[...] = t
    rnew_ref[...] = jnp.zeros_like(t)


def _kernel_unselected(g_ref, r_ref, coeff_ref, send_ref, rnew_ref):
    c = coeff_ref[0]
    t = g_ref[...] + c * r_ref[...]
    send_ref[...] = jnp.zeros_like(t)
    rnew_ref[...] = t


@functools.partial(jax.jit, static_argnames=("selected", "block", "interpret"))
def ef_update(
    g: jax.Array,
    r: jax.Array,
    coeff: jax.Array,
    *,
    selected: bool,
    block: int = ELEMWISE_BLOCK,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """g, r: flat (N,) bucket; coeff: scalar.  Returns (send, r_new)."""
    interpret = INTERPRET if interpret is None else interpret
    assert g.ndim == 1 and g.shape == r.shape
    gp, n = pad_to_multiple(g, block)
    rp, _ = pad_to_multiple(r, block)
    nblocks = gp.shape[0] // block
    g2 = gp.reshape(nblocks, block)
    r2 = rp.reshape(nblocks, block)
    coeff_arr = jnp.asarray(coeff, g.dtype).reshape(1)

    kernel = _kernel_selected if selected else _kernel_unselected
    send, rnew = pl.pallas_call(
        kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(g2.shape, g.dtype),
            jax.ShapeDtypeStruct(r2.shape, r.dtype),
        ],
        interpret=interpret,
    )(g2, r2, coeff_arr)
    return unpad(send.reshape(-1), n), unpad(rnew.reshape(-1), n)
