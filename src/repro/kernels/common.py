"""Shared Pallas helpers: interpret-mode selection + padding utilities.

Kernels TARGET TPU (pl.pallas_call with explicit VMEM BlockSpecs, tile sizes
aligned to the 8x128 VPU lanes / 128x128 MXU); on this CPU container they
are VALIDATED with ``interpret=True`` which executes the kernel body in
Python.  ``INTERPRET`` auto-detects the backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

INTERPRET = jax.default_backend() != "tpu"

# default elementwise block: 8 sublanes x 128 lanes x 32 = 32k elems (128 KiB fp32)
ELEMWISE_BLOCK = 32768


def pad_to_multiple(x: jax.Array, multiple: int, axis: int = 0, value=0):
    n = x.shape[axis]
    rem = (-n) % multiple
    if rem == 0:
        return x, n
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad, constant_values=value), n


def unpad(x: jax.Array, n: int, axis: int = 0):
    return jax.lax.slice_in_dim(x, 0, n, axis=axis)
