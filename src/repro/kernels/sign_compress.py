"""EFsignSGD sign-compression kernel: int8 signs + per-block |x| partial
sums in one pass (the scale ``mean(|x|)`` is finished by a tiny jnp
reduction over the per-block partials)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import ELEMWISE_BLOCK, INTERPRET, pad_to_multiple, unpad


def _sign_kernel(x_ref, s_ref, a_ref):
    x = x_ref[...].astype(jnp.float32)
    s_ref[...] = jnp.where(x >= 0, 1, -1).astype(jnp.int8)
    a_ref[0] = jnp.sum(jnp.abs(x))


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def sign_compress(x: jax.Array, *, block: int = ELEMWISE_BLOCK,
                  interpret: bool | None = None):
    """x: (N,) -> (signs (N,) int8, scale () fp32 = mean|x|)."""
    interpret = INTERPRET if interpret is None else interpret
    xp, n = pad_to_multiple(x, block)
    nb = xp.shape[0] // block
    x2 = xp.reshape(nb, block)
    signs, partials = pl.pallas_call(
        _sign_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x2.shape, jnp.int8),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
        ],
        interpret=interpret,
    )(x2)
    scale = jnp.sum(partials) / jnp.float32(max(n, 1))
    return unpad(signs.reshape(-1), n), scale


def sign_decompress(signs: jax.Array, scale: jax.Array) -> jax.Array:
    return signs.astype(jnp.float32) * scale
