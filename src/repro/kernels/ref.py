"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

FP8_MAX = 448.0


def ef_update_ref(g, r, coeff, *, selected: bool):
    t = g + jnp.asarray(coeff, g.dtype) * r
    if selected:
        return t, jnp.zeros_like(t)
    return jnp.zeros_like(t), t


def pack_ef_cast_ref(g, r, coeff, *, selected: bool, wire_dtype=None):
    """Fused pack + error feedback + wire cast (arena pack pass).

    ``t = g + coeff * r`` (``r=None`` -> ``t = g``); for a *selected*
    bucket the wire value is ``t`` cast to ``wire_dtype`` (identity when
    ``None``) and the residual is the quantisation error ``t - cast(t)``
    (zero without a cast); an *unselected* bucket sends nothing and keeps
    the whole compensated gradient as its residual.

    Every expression matches the legacy segmented path
    (``stages.WireCast.execute_segment`` + ``stages.SyncPipeline._ef_segment``)
    op-for-op — including the ``coeff * r.astype(g.dtype)`` promotion and
    the ``coeff=None`` classic-EF plain add — so the jnp fallback is
    bitwise-identical to arena-off.  Returns ``(wire, r_new)``; ``r_new``
    is ``None`` when ``r`` is.
    """
    if r is None:
        t = g
    elif coeff is None:
        t = g + r.astype(g.dtype)
    else:
        t = g + coeff * r.astype(g.dtype)
    wd = jnp.dtype(wire_dtype) if wire_dtype is not None else None
    if not selected:
        zero = jnp.zeros_like(t if wd is None else t.astype(wd))
        return zero, (t if r is not None else None)
    if wd is None or t.dtype == wd:
        return t, (jnp.zeros_like(t) if r is not None else None)
    w = t.astype(wd)
    rnew = t - w.astype(t.dtype)
    return w, (rnew if r is not None else None)


def quantize_fp8_ref(x, *, block: int = 8192):
    n = x.shape[0]
    pad = (-n) % block
    xp = jnp.pad(x, (0, pad)).astype(jnp.float32)
    nb = xp.shape[0] // block
    x2 = xp.reshape(nb, block)
    amax = jnp.max(jnp.abs(x2), axis=1)
    scales = jnp.maximum(amax / FP8_MAX, 1e-12)
    q = (x2 / scales[:, None]).astype(jnp.float8_e4m3fn)
    return q.reshape(-1)[:n], scales


def dequantize_fp8_ref(q, scales, *, block: int = 8192):
    n = q.shape[0]
    pad = (-n) % block
    qp = jnp.pad(q, (0, pad))
    nb = qp.shape[0] // block
    x = qp.reshape(nb, block).astype(jnp.float32) * scales[:, None]
    return x.reshape(-1)[:n]


def sign_compress_ref(x):
    signs = jnp.where(x >= 0, 1, -1).astype(jnp.int8)
    scale = jnp.mean(jnp.abs(x.astype(jnp.float32)))
    return signs, scale


def threshold_filter_ref(x, threshold, *, block: int = 32768):
    keep = jnp.abs(x) >= threshold
    y = jnp.where(keep, x, jnp.zeros_like(x))
    n = x.shape[0]
    pad = (-n) % block
    kp = jnp.pad(keep, (0, pad))
    counts = kp.reshape(-1, block).sum(axis=1).astype(jnp.int32)
    return y, counts


def matmul_ref(a, b, out_dtype=jnp.float32):
    return jnp.dot(
        a.astype(jnp.float32), b.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(out_dtype)
