"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

FP8_MAX = 448.0


def ef_update_ref(g, r, coeff, *, selected: bool):
    t = g + jnp.asarray(coeff, g.dtype) * r
    if selected:
        return t, jnp.zeros_like(t)
    return jnp.zeros_like(t), t


def quantize_fp8_ref(x, *, block: int = 8192):
    n = x.shape[0]
    pad = (-n) % block
    xp = jnp.pad(x, (0, pad)).astype(jnp.float32)
    nb = xp.shape[0] // block
    x2 = xp.reshape(nb, block)
    amax = jnp.max(jnp.abs(x2), axis=1)
    scales = jnp.maximum(amax / FP8_MAX, 1e-12)
    q = (x2 / scales[:, None]).astype(jnp.float8_e4m3fn)
    return q.reshape(-1)[:n], scales


def dequantize_fp8_ref(q, scales, *, block: int = 8192):
    n = q.shape[0]
    pad = (-n) % block
    qp = jnp.pad(q, (0, pad))
    nb = qp.shape[0] // block
    x = qp.reshape(nb, block).astype(jnp.float32) * scales[:, None]
    return x.reshape(-1)[:n]


def sign_compress_ref(x):
    signs = jnp.where(x >= 0, 1, -1).astype(jnp.int8)
    scale = jnp.mean(jnp.abs(x.astype(jnp.float32)))
    return signs, scale


def threshold_filter_ref(x, threshold, *, block: int = 32768):
    keep = jnp.abs(x) >= threshold
    y = jnp.where(keep, x, jnp.zeros_like(x))
    n = x.shape[0]
    pad = (-n) % block
    kp = jnp.pad(keep, (0, pad))
    counts = kp.reshape(-1, block).sum(axis=1).astype(jnp.int32)
    return y, counts


def matmul_ref(a, b, out_dtype=jnp.float32):
    return jnp.dot(
        a.astype(jnp.float32), b.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(out_dtype)
