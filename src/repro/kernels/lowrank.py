"""MXU-aligned blocked matmul kernel — the PowerSGD P/Q projection hot-spot.

C (m, n) = A (m, k) @ B (k, n) with 128-aligned tiles, fp32 accumulation in
a VMEM scratch accumulator; grid (m/bm, n/bn, k/bk) with k innermost so the
accumulator lives across the k-loop (standard TPU matmul schedule).
PowerSGD calls this with n = rank (padded to 128) — a skinny matmul where
MXU alignment of the m/k tiles is what matters.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import INTERPRET


def _matmul_kernel(a_ref, b_ref, c_ref, acc_ref, *, k_steps):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.float32),
        b_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _store():
        c_ref[...] = acc_ref[...].astype(c_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret", "out_dtype")
)
def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    out_dtype=jnp.float32,
    interpret: bool | None = None,
) -> jax.Array:
    """Tiled matmul; dims are padded up to tile multiples."""
    interpret = INTERPRET if interpret is None else interpret
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    a = jnp.pad(a, ((0, pm), (0, pk)))
    b = jnp.pad(b, ((0, pk), (0, pn)))
    M, K = a.shape
    _, N = b.shape
    k_steps = K // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=(M // bm, N // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out[:m, :n]
