"""Fused arena pack + error feedback + wire cast — one streaming pass.

The zero-copy gradient arena (``core/arena.py``) turns every bucket into a
static-offset view of one flat buffer, so the only remaining per-step work
on the compression path is producing that buffer.  The legacy segmented
path materialises three arrays per bucket to do it (the flattened gather,
the compensated ``t = g + c*r``, and the wire-dtype cast); this kernel
fuses them into one HBM pass per segment:

    t    = g + coeff * r
    wire = cast(t)                  if the bucket is selected else 0
    r'   = t - cast(t).astype(f32)  if selected (0 when no cast) else t

``selected`` and the cast target are *static* kernel specialisations (the
coarse filter is static per phase, paper SS III.A), so each compiled phase
contains only the variant it needs.

Layout: flat vectors viewed as (blocks, ELEMWISE_BLOCK) rows = 8x128 VPU
tiles x 32; grid is 1-D over blocks.  Two outputs per block (wire value at
the wire dtype, residual at the gradient dtype) stream back to HBM once.

Rounding note (same as ``ef_covap.ef_update``): the fused pass compiles
``g + c*r`` to an FMA (single rounding) where the 2-op jnp reference rounds
the product separately, so interpret mode cannot be bitwise-identical to
``kernels.ref.pack_ef_cast_ref``.  The arena path therefore engages this
kernel on TPU by default and on CPU only via the explicit
``use_pack_kernel=True`` compressor option; the CPU default is the ref
formulation, which IS bitwise-identical to the arena-off legacy ops.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import ELEMWISE_BLOCK, INTERPRET, pad_to_multiple, unpad


def _kernel_selected_cast(wd):
    def kernel(g_ref, r_ref, coeff_ref, wire_ref, rnew_ref):
        c = coeff_ref[0]
        t = g_ref[...] + c * r_ref[...]
        w = t.astype(wd)
        wire_ref[...] = w
        rnew_ref[...] = t - w.astype(t.dtype)

    return kernel


def _kernel_selected(g_ref, r_ref, coeff_ref, wire_ref, rnew_ref):
    c = coeff_ref[0]
    t = g_ref[...] + c * r_ref[...]
    wire_ref[...] = t
    rnew_ref[...] = jnp.zeros_like(t)


def _kernel_unselected(g_ref, r_ref, coeff_ref, wire_ref, rnew_ref):
    c = coeff_ref[0]
    t = g_ref[...] + c * r_ref[...]
    wire_ref[...] = jnp.zeros_like(wire_ref[...])
    rnew_ref[...] = t


@functools.partial(
    jax.jit, static_argnames=("selected", "wire_dtype", "block", "interpret")
)
def pack_ef_cast(
    g: jax.Array,
    r: jax.Array,
    coeff: jax.Array,
    *,
    selected: bool,
    wire_dtype: str | None = None,
    block: int = ELEMWISE_BLOCK,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """g, r: flat (N,) segment; coeff: scalar.  Returns (wire, r_new) with
    ``wire`` at ``wire_dtype`` (or ``g.dtype`` when None) — the value the
    arena slot receives — and ``r_new`` at ``r``'s dtype."""
    interpret = INTERPRET if interpret is None else interpret
    assert g.ndim == 1 and g.shape == r.shape
    wd = jnp.dtype(wire_dtype) if wire_dtype is not None else jnp.dtype(g.dtype)
    cast = wd != g.dtype
    gp, n = pad_to_multiple(g, block)
    rp, _ = pad_to_multiple(r, block)
    nblocks = gp.shape[0] // block
    g2 = gp.reshape(nblocks, block)
    r2 = rp.reshape(nblocks, block)
    coeff_arr = jnp.asarray(coeff, g.dtype).reshape(1)

    if not selected:
        kernel = _kernel_unselected
    elif cast:
        kernel = _kernel_selected_cast(wd)
    else:
        kernel = _kernel_selected
    wire, rnew = pl.pallas_call(
        kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(g2.shape, wd),
            jax.ShapeDtypeStruct(r2.shape, r.dtype),
        ],
        interpret=interpret,
    )(g2, r2, coeff_arr)
    return unpad(wire.reshape(-1), n), unpad(rnew.reshape(-1), n)
