"""DP train-step builder: COVAP (or any registered GC scheme) wired into the
gradient synchronisation of a ``shard_map``-manual data-parallel step.

Key structural points (DESIGN.md SS2):

* ``shard_map`` is **manual over the DP axes** ('pod','data') so each
  worker's gradients exist un-reduced and the compressor controls exactly
  which bytes cross the interconnect (one ``psum`` per selected bucket);
  the 'model' axis stays **auto** so tensor-parallel sharding of the model
  math is compiler-managed.
* Plan/execute split (DESIGN.md SS3): each phase's ``CommSchedule`` is
  computed **outside** the traced function by ``Compressor.plan_phase`` —
  the trainer knows the exact planned collective bytes before (and without)
  compiling anything — and the pure ``Compressor.execute`` consumes it
  inside ``shard_map``.
* The coarse filter's bucket selection must be static in XLA, so the step
  is specialised per ``phase = step % I`` -> ``I`` executables, compiled
  lazily on first use.
* Loss/grad math is unchanged across compressors — swapping schemes swaps
  only the sync stage (the paper's DDP-communication-hook shape).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import build_plan, get_compressor
from repro.core.bucketing import BucketPlan
from repro.core.comm import Compressor, dense_bytes
from repro.core.filter import selected_buckets
from repro.core.schedule import CollectiveCall, CommSchedule, mean_bytes_per_step
from repro.optim import Optimizer, apply_updates, clip_by_global_norm, global_norm


def shard_map_compat(fn, mesh, in_specs, out_specs, axis_names):
    """``jax.shard_map`` appeared (with ``check_vma``) in newer jax; older
    releases ship ``jax.experimental.shard_map`` (with ``check_rep``).  The
    trainer supports both so CPU dry-runs work on either toolchain."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            axis_names=set(axis_names), check_vma=False,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    # NOTE: unlike jax.shard_map(axis_names=...), the experimental API
    # treats every mesh axis as manual here.  Passing auto= for the
    # non-DP axes would match the new API's manual/auto split, but
    # partial-manual shard_map CHECK-fails in the old XLA builds this
    # fallback targets (hlo_sharding_util: IsManualSubgroup) — so on old
    # jax the model axis runs replicated (correct numerics, no TP
    # sharding of the step's math).  The production TP path requires a
    # jax with jax.shard_map.
    return _shard_map(
        fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    compressor: str = "covap"
    compressor_options: dict = dataclasses.field(default_factory=dict)
    interval: int = 4                      # COVAP I = ceil(CCR); 1 = no filter
    pod_interval: int = 1                  # hierarchical COVAP across pods
    bucket_bytes: int = 25 * 1024 * 1024
    max_buckets: int = 128
    clip_norm: float = 0.0                 # 0 = off
    steps: int = 100
    log_every: int = 10
    # gradient-sync placement: "post" runs every collective after the full
    # backward pass (the classic path, pinned bit-for-bit); "fused" issues
    # each bucket's collective inside the backward trace via the overlap
    # engine's gradient-ready hooks (core/overlap.py) so XLA can interleave
    # comm with the remaining backward compute.  Segmented bucket pipelines
    # only (COVAP / none / fp16).
    overlap: str = "post"
    # zero-copy gradient arena (core/arena.py, DESIGN.md §12): bucket
    # payloads become static-offset views of per-phase flat planes — one
    # pack pass per step (fused EF + wire cast), one collective per bucket
    # over a contiguous slice, static-slice unpacks on the way back —
    # instead of per-bucket concatenate / dynamic_slice rebuilds.
    # Bitwise-equal to the default path for uniform-dtype models.
    arena: bool = False
    # collective decomposition (core/comm.py + DESIGN.md §13/§17):
    # "allreduce" all-reduces each selected bucket (the classic path,
    # pinned); "sharded" reduce-scatters the compressed slot view (each
    # worker keeps 1/W), lets the optimizer's meaningful updates land on
    # the local shard, and defers the all-gather of updated params to the
    # HEAD of the next step so it overlaps the forward pass — exposed wire
    # volume behind the backward pass drops to ~half of the all-reduce
    # path's.  Segmented bucket pipelines only (covap / none / fp16).
    # Composes with hierarchical pods (pod_interval > 1): the gradient RS
    # runs over the fast intra-pod axes, ``pod_reconcile`` exchanges only
    # the owned 1/W shard of each selected bucket across the DCN, and the
    # deferred head all-gather freshens non-owner shards from the pod's
    # owners (DESIGN.md §17).
    sync: str = "allreduce"


def make_compressor(tc: TrainConfig) -> Compressor:
    opts = dict(tc.compressor_options)
    if tc.compressor == "covap":
        opts.setdefault("interval", tc.interval)
    if tc.arena:
        opts.setdefault("use_arena", True)
    if tc.sync != "allreduce":
        opts.setdefault("sync", tc.sync)
    return get_compressor(tc.compressor, **opts)


def _loss_and_grads(model, params, batch):
    def lf(p):
        loss, metrics = model.loss_fn(p, batch)
        return loss, metrics

    (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
    return loss, metrics, grads


def strip_pod_block(tree, *, expect_local: bool = True):
    """Drop the leading per-pod block axis from every leaf of a
    hierarchical train state.

    Inside the shard_map the state is sharded ``P('pod')``, so the local
    block size must be exactly 1 — ``expect_local=True`` asserts that with
    a clear error instead of silently indexing.  Host-side callers (e.g.
    the CCR probe peeling pod 0 off a full ``(n_pods, ...)`` state) pass
    ``expect_local=False``.
    """

    def strip(a):
        if expect_local and a.shape[0] != 1:
            raise ValueError(
                f"hierarchical state leaf has local pod block size "
                f"{a.shape[0]}, expected 1 (shape {a.shape}); the state "
                f"must enter shard_map sharded P('pod')"
            )
        return a[0]

    return jax.tree.map(strip, tree)


def restore_pod_block(tree):
    """Re-attach the length-1 pod block axis removed by
    :func:`strip_pod_block` (inverse inside the shard_map body)."""
    return jax.tree.map(lambda a: a[None], tree)


def plan_pod_schedule(
    plan: BucketPlan, *, pod_phase: int, pod_interval: int,
    sync: str = "allreduce", intra_world: int = 1, n_pods: int = 1,
) -> CommSchedule:
    """Static cross-pod reconciliation plan (hierarchical COVAP, DESIGN
    SS7b + §17): the coarse filter's selection rule applied at the pod
    level.

    With ``intra_world <= 1`` (legacy flat accounting) each selected
    bucket is one f32 all-reduce of its full extent over the pod group.
    With ``intra_world = W > 1`` the plan is the two-level decomposition
    :func:`pod_reconcile` executes: per selected bucket a DCN all-reduce
    of only the owned ``1/W`` shard of the W-aligned slot (at the
    bucket's promoted dtype — what actually crosses the slow link), plus
    — under ``sync="allreduce"`` only — the intra-pod all-gather that
    rebuilds the full slot on the fast link.  Under ``sync="sharded"``
    the rebuild rides the next step's deferred head all-gather instead,
    so no ICI call is planned here."""
    from repro.core import arena as ar

    interval = max(int(pod_interval), 1)
    sel = selected_buckets(plan.num_buckets, pod_phase % interval, interval)
    W = max(int(intra_world), 1)
    pod_world = int(n_pods) if int(n_pods) > 1 else 0
    calls: list[CollectiveCall] = []
    if W <= 1:
        for b in sel:
            calls.append(CollectiveCall(
                f"pod-bucket:{b}", "all_reduce", "float32",
                plan.buckets[b].numel * 4, link="dcn", world=pod_world,
            ))
    else:
        for b in sel:
            bucket = plan.buckets[b]
            dt = ar.bucket_dtype(plan, bucket)
            shard_bytes = (
                ar.aligned_numel(bucket.numel, W) // W
            ) * dt.itemsize
            calls.append(CollectiveCall(
                f"pod-bucket:{b}", "all_reduce", dt.name, shard_bytes,
                link="dcn", world=pod_world,
            ))
            if sync == "allreduce":
                calls.append(CollectiveCall(
                    f"pod-ag:{b}", "all_gather", dt.name, shard_bytes,
                    link="ici", world=W,
                ))
    return CommSchedule(
        compressor="pod_reconcile",
        phase=pod_phase % interval,
        num_phases=interval,
        granularity="bucket",
        selected=sel,
        calls=tuple(calls),
        dense_bytes=sum(b.numel for b in plan.buckets) * 4,
        plan=plan,
    )


def pod_reconcile(params, schedule: CommSchedule, *,
                  pod_axes: Sequence[str],
                  reconcile_helper_axes: Sequence[str] = (),
                  owned_only: bool = False):
    """Hierarchical COVAP's cross-pod level (beyond-paper, DESIGN SS7b +
    §17): instead of sending every gradient across the slow DCN pod
    links, each step reconciles only the PARAMETER segments named by the
    static ``CommSchedule`` (buckets with ``(b + step) % I_pod == 0`` —
    the coarse filter applied at the pod level, where CCR > 1 genuinely
    holds).  Local-SGD-style drift between reconciliations, bounded to
    I_pod steps per bucket by the round-robin.

    The exchange is an EXPLICIT two-level decomposition over the
    ``reconcile_helper_axes`` (the intra-pod DP axes, W workers): each
    selected bucket is packed into its W-aligned arena slot, worker ``w``
    slices the shard ``[w*S, (w+1)*S)`` it owns — free, no collective;
    under allreduce sync params are intra-pod replicated so the slice is
    exact, under sharded sync it is precisely the shard the optimizer
    just updated — and :func:`~repro.core.comm.pod_shard_exchange`
    pmean-reconciles only that 1/W shard across the pods.  Only shard-
    sized payloads ever touch the DCN.  Then:

    * ``owned_only=False`` (allreduce sync): an intra-pod all-gather on
      the fast link rebuilds the full reconciled slot on every worker;
    * ``owned_only=True`` (sharded sync): the reconciled shard is written
      back to the owned region only — non-owner positions stay stale by
      contract and are freshened by the next step's deferred head
      all-gather, which always gathers from the shard owners.

    Returns (params, schedule.bytes_per_worker)."""
    from repro.core import arena as ar
    from repro.core import bucketing as bk
    from repro.core.comm import (
        all_gather_tiled, axis_size, flat_axis_index, pod_shard_exchange,
    )

    plan = schedule.plan
    treedef = jax.tree_util.tree_structure(params)
    leaves = jax.tree_util.tree_leaves(params)
    helper = tuple(reconcile_helper_axes)
    W = 1
    for a in helper:
        W *= axis_size(a)
    if not schedule.selected:
        return params, schedule.bytes_per_worker
    layout = ar.build_layout(plan, schedule.selected, align=W)
    planes = ar.pack_leaves(layout, leaves)
    for b in schedule.selected:
        view = layout.bucket_view(planes, b)
        if W > 1:
            S = view.shape[0] // W
            w = flat_axis_index(helper)
            shard = lax.dynamic_slice_in_dim(view, w * S, S)
            shard = pod_shard_exchange(shard, pod_axes)
            if owned_only:
                full = lax.dynamic_update_slice(view, shard, (w * S,))
            else:
                full = all_gather_tiled(shard, helper)
        else:
            full = pod_shard_exchange(view, pod_axes)
        for seg, piece in zip(
            plan.buckets[b].segments, layout.unpack_bucket(b, full)
        ):
            li = seg.leaf_idx
            leaves[li] = bk._update_segment(
                leaves[li], seg, piece.astype(leaves[li].dtype)
            )
    return (
        jax.tree_util.tree_unflatten(treedef, leaves),
        schedule.bytes_per_worker,
    )


def build_step_fn(
    model,
    optimizer: Optimizer,
    compressor: Compressor,
    plan: BucketPlan,
    *,
    phase: int,
    dp_axes: Sequence[str] = (),
    clip_norm: float = 0.0,
    pod_interval: int = 1,
    dp_world: int = 1,
    n_pods: int = 1,
) -> Callable:
    """The un-jitted per-phase step (runs inside shard_map when dp_axes).

    The phase's ``CommSchedule`` is planned here, statically — the traced
    body only ever sees ``compressor.execute(schedule, ...)``.

    With ``pod_interval > 1`` (hierarchical mode) gradient sync runs only
    over the intra-pod axes; the 'pod' axis is reconciled by
    ``pod_reconcile`` and the state carries a leading pod-block axis.

    Sharded sync compressors additionally issue the deferred param
    all-gather at the step's head (see :func:`_build_phase_step`)."""
    return _build_phase_step(
        model, optimizer, compressor, plan, phase=phase, dp_axes=dp_axes,
        clip_norm=clip_norm, pod_interval=pod_interval, dp_world=dp_world,
        fused=False, n_pods=n_pods,
    )


def build_overlapped_step(
    model,
    optimizer: Optimizer,
    compressor: Compressor,
    plan: BucketPlan,
    *,
    phase: int,
    dp_axes: Sequence[str] = (),
    clip_norm: float = 0.0,
    pod_interval: int = 1,
    dp_world: int = 1,
    n_pods: int = 1,
) -> Callable:
    """The fused-overlap per-phase step (``TrainConfig.overlap="fused"``).

    Identical contract to :func:`build_step_fn`, but gradient sync happens
    INSIDE the backward pass: every bucket's parameter segments are routed
    through a gradient-ready hook (``core.overlap``) whose backward rule
    issues that bucket's planned collective the moment its last gradient is
    produced — XLA's latency-hiding scheduler can then interleave each
    bucket's all-reduce with the remaining backward compute instead of
    serialising comm after compute.  Bit-for-bit equal to the post path
    (the hooks call the same granular ``execute_bucket``) on the pure-DP
    mesh; with hierarchical pods (``pod_interval > 1``) XLA's fusion
    choices may differ between the two compiled programs at the ulp level,
    so equivalence there is numerical (~1e-7), not bitwise.
    """
    from repro.core.overlap import supports_fused_overlap

    if not supports_fused_overlap(compressor):
        raise ValueError(
            f"overlap='fused' requires a segmented bucket pipeline "
            f"(covap / none / fp16); {compressor!r} must use overlap='post'"
        )
    return _build_phase_step(
        model, optimizer, compressor, plan, phase=phase, dp_axes=dp_axes,
        clip_norm=clip_norm, pod_interval=pod_interval, dp_world=dp_world,
        fused=True, n_pods=n_pods,
    )


def _sharded_grad_norm(synced, grad_axes):
    """Global gradient norm under sharded sync: each worker's ``synced``
    tree is zero off its owned shards, so the exact global square-sum is
    the psum of the local ones (summation order differs from the allreduce
    path's single-array norm, so the metric agrees to ~ulp, not bitwise)."""
    sq = sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(synced)
    )
    if grad_axes:
        sq = lax.psum(sq, tuple(grad_axes))
    return jnp.sqrt(sq)


def _build_phase_step(
    model, optimizer, compressor, plan, *, phase, dp_axes, clip_norm,
    pod_interval, dp_world, fused, n_pods=1,
) -> Callable:
    """Shared skeleton of :func:`build_step_fn` / :func:`build_overlapped_step`
    — only the loss/grads/sync block differs; each path keeps its exact
    traced op order (the post path is pinned bit-for-bit).

    Sharded sync (``compressor.sync_mode == "sharded"``): every step begins
    with the deferred param all-gather of the PREVIOUS step
    (``overlap.sharded_param_allgather``) — the previous optimizer step
    landed authoritative values only on locally-owned shards, and the head
    gather freshens all of them before the forward pass touches any
    parameter, so the AG overlaps forward compute instead of extending the
    previous step's sync tail.  The gather is phase-independent (it covers
    every bucket) and is an identity on already-fresh params, so it runs
    unconditionally (step 0 included)."""
    pod_axes = tuple(a for a in dp_axes if a == "pod") if pod_interval > 1 else ()
    grad_axes = tuple(a for a in dp_axes if a not in pod_axes)
    sharded = getattr(compressor, "sync_mode", "allreduce") == "sharded"

    comm_schedule = compressor.plan_phase(plan, phase, world=dp_world)
    prev_schedule = comm_schedule if sharded and grad_axes else None
    pod_schedule = (
        plan_pod_schedule(
            plan, pod_phase=phase % pod_interval, pod_interval=pod_interval,
            sync="sharded" if sharded else "allreduce",
            intra_world=dp_world, n_pods=n_pods,
        )
        if pod_axes
        else None
    )

    def pmean_metrics(loss, metrics):
        if not dp_axes:
            return loss, metrics
        return (
            lax.pmean(loss, tuple(dp_axes)),
            jax.tree.map(lambda m: lax.pmean(m, tuple(dp_axes)), metrics),
        )

    def step_fn(params, opt_state, comp_state, batch, step):
        hier = bool(pod_axes)
        if hier:
            params, opt_state, comp_state = strip_pod_block(
                (params, opt_state, comp_state)
            )
        if prev_schedule is not None:
            from repro.core.overlap import sharded_param_allgather

            params = sharded_param_allgather(
                compressor, prev_schedule, params, axis_names=grad_axes,
            )
        if fused:
            from repro.core.overlap import overlapped_loss_and_grads

            loss, metrics, synced, comp_state = overlapped_loss_and_grads(
                model, compressor, comm_schedule,
                params, comp_state, batch, step, axis_names=grad_axes,
            )
            loss, metrics = pmean_metrics(loss, metrics)
        else:
            loss, metrics, grads = _loss_and_grads(model, params, batch)
            loss, metrics = pmean_metrics(loss, metrics)
            synced, comp_state, _ = compressor.execute(
                comm_schedule, grads, comp_state,
                step=step, axis_names=grad_axes,
            )
        if sharded and grad_axes:
            gnorm = _sharded_grad_norm(synced, grad_axes)
            if clip_norm > 0:
                scale = jnp.minimum(1.0, clip_norm / (gnorm + 1e-12))
                synced = jax.tree.map(
                    lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype),
                    synced,
                )
        elif clip_norm > 0:
            synced, gnorm = clip_by_global_norm(synced, clip_norm)
        else:
            gnorm = global_norm(synced)
        updates, opt_state = optimizer.update(synced, opt_state, params)
        params = apply_updates(params, updates)
        if hier:
            params, _ = pod_reconcile(
                params, pod_schedule,
                pod_axes=pod_axes, reconcile_helper_axes=grad_axes,
                owned_only=sharded,
            )
            params, opt_state, comp_state = restore_pod_block(
                (params, opt_state, comp_state)
            )
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["total_loss"] = loss
        return params, opt_state, comp_state, metrics

    step_fn.comm_schedule = comm_schedule
    step_fn.prev_schedule = prev_schedule
    step_fn.pod_schedule = pod_schedule
    return step_fn


def build_train_step(
    model,
    optimizer: Optimizer,
    compressor: Compressor,
    plan: BucketPlan,
    *,
    phase: int,
    mesh=None,
    dp_axes: Sequence[str] = (),
    param_shardings=None,
    clip_norm: float = 0.0,
    donate: bool = True,
    pod_interval: int = 1,
    overlap: str = "post",
):
    """jit (+ shard_map over DP axes) the per-phase step.

    Single-process CPU path: ``mesh=None`` -> plain jit, no collectives.
    Production path: manual over ``dp_axes``, auto over everything else.
    Hierarchical mode (``pod_interval > 1``): state carries a leading
    per-pod axis (P('pod')) so pods may drift between reconciliations.
    ``overlap``: "post" (sync after the backward pass, the pinned default)
    or "fused" (:func:`build_overlapped_step`'s in-backward collectives).
    """
    if overlap not in ("post", "fused"):
        raise ValueError(f"overlap must be 'post' or 'fused', got {overlap!r}")
    hier = pod_interval > 1 and "pod" in dp_axes
    # the compressor's collectives run over the gradient-sync axes only:
    # in hierarchical mode the 'pod' axis is reconciled separately, so the
    # schedule must be planned for the intra-pod world
    sync_axes = tuple(a for a in dp_axes if a != "pod") if hier else tuple(dp_axes)
    dp_world = 1
    if mesh is not None:
        for a in sync_axes:
            dp_world *= mesh.shape[a]
    n_pods = mesh.shape["pod"] if hier and mesh is not None else 1
    builder = build_overlapped_step if overlap == "fused" else build_step_fn
    step_fn = builder(
        model, optimizer, compressor, plan,
        phase=phase, dp_axes=dp_axes if mesh is not None else (),
        clip_norm=clip_norm, pod_interval=pod_interval if hier else 1,
        dp_world=dp_world, n_pods=n_pods,
    )
    if mesh is None:
        jitted = jax.jit(step_fn, donate_argnums=(0, 1, 2) if donate else ())
        jitted.comm_schedule = step_fn.comm_schedule
        jitted.prev_schedule = step_fn.prev_schedule
        jitted.pod_schedule = step_fn.pod_schedule
        return jitted

    state_spec = P("pod") if hier else P()
    batch_spec = P(tuple(dp_axes))
    mapped = shard_map_compat(
        step_fn,
        mesh,
        (
            state_spec,                           # params
            state_spec,                           # opt_state
            state_spec,                           # comp_state (residuals)
            batch_spec,                           # batch (sharded on dim 0)
            P(),                                  # step
        ),
        (state_spec, state_spec, state_spec, P()),
        dp_axes,
    )
    kw = {}
    if param_shardings is not None:
        like = lambda tree: jax.tree.map(
            lambda s: NamedSharding(mesh, s), tree,
            is_leaf=lambda x: isinstance(x, P),
        )
        kw["in_shardings"] = (
            like(param_shardings["params"]),
            like(param_shardings["opt"]),
            like(param_shardings["comp"]),
            like(param_shardings["batch"]),
            NamedSharding(mesh, P()),
        )
    jitted = jax.jit(mapped, donate_argnums=(0, 1, 2) if donate else (), **kw)
    jitted.comm_schedule = step_fn.comm_schedule
    jitted.prev_schedule = step_fn.prev_schedule
    jitted.pod_schedule = step_fn.pod_schedule
    return jitted


def make_train_state(model, optimizer, compressor, plan, key):
    params = model.init(key)
    return {
        "params": params,
        "opt": optimizer.init(params),
        "comp": compressor.init_state(params, plan),
        "step": 0,
    }


class Trainer:
    """Host loop: lazily compiles one executable per COVAP phase, logs
    metrics, exposes measured step timing for the CCR profiler and the
    static per-phase ``CommSchedule``s for byte/overlap accounting."""

    def __init__(self, model, optimizer, tc: TrainConfig, *, mesh=None,
                 dp_axes: Sequence[str] = (), param_specs=None):
        self.model = model
        self.optimizer = optimizer
        self.tc = tc
        self.mesh = mesh
        self.dp_axes = tuple(dp_axes)
        self.compressor = make_compressor(tc)
        self._shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        self.plan = build_plan(
            self._shapes,
            bucket_bytes=tc.bucket_bytes,
            max_buckets=tc.max_buckets,
            interval=tc.interval,
        )
        self._steps: dict[int, Callable] = {}
        self.history: list[dict] = []
        self.runtime = None          # AdaptiveRuntime of the last run(), if any
        self.resilience = None       # ResilienceRuntime of the last run(), if any
        self.transitions: list = []  # TransitionReports from re-plans
        # telemetry bundle (repro.obs): registry + event log + tracer.
        # Defaults to the disabled singleton; run(telemetry=...) swaps in a
        # live bundle (the adaptive runtime and flush_sync write through it)
        from repro.obs import NULL_TELEMETRY

        self.telemetry = NULL_TELEMETRY
        # sharded sync (DESIGN.md §13): True while the last step's deferred
        # param all-gather has not been issued yet (the optimizer left
        # non-owner shards stale).  Each sharded step's head gather settles
        # it implicitly; flush_sync() settles it at run boundaries so the
        # state handed back always carries fresh full params.
        self._pending_sync: bool = False
        self._flush_fns: dict[int, Callable] = {}

    @property
    def num_phases(self) -> int:
        base = self.compressor.num_phases(self.tc.interval)
        if self.tc.pod_interval > 1 and "pod" in self.dp_axes:
            import math as _m
            return _m.lcm(base, self.tc.pod_interval)
        return base

    @property
    def dp_world(self) -> int:
        """World size of the compressor's collectives (excludes the 'pod'
        axis in hierarchical mode, where pods sync via pod_reconcile)."""
        axes = self.dp_axes
        if self.hierarchical:
            axes = tuple(a for a in axes if a != "pod")
        w = 1
        if self.mesh is not None:
            for a in axes:
                w *= self.mesh.shape[a]
        return w

    def schedules(self) -> list[CommSchedule]:
        """Static comm plan of every phase — available before (and without)
        compiling a single executable.

        Hierarchical mode: one schedule per phase of the FULL lcm cycle,
        each carrying the intra-pod gradient calls (link="ici") merged
        with that step's cross-pod reconciliation calls (link="dcn", plus
        the intra AG rebuild under allreduce sync) — the per-link byte
        accounting the adaptive controller and the HLO cross-check read."""
        n = max(self.compressor.num_phases(self.tc.interval), 1)
        base = [
            self.compressor.plan_phase(self.plan, p, world=self.dp_world)
            for p in range(n)
        ]
        if not self.hierarchical:
            return base
        n_pods = self.mesh.shape["pod"] if self.mesh is not None else 1
        out = []
        for p in range(self.num_phases):
            g = base[p % n]
            pod = plan_pod_schedule(
                self.plan,
                pod_phase=p % self.tc.pod_interval,
                pod_interval=self.tc.pod_interval,
                sync=self.tc.sync,
                intra_world=self.dp_world,
                n_pods=n_pods,
            )
            ranks = g.ready_ranks
            if ranks:
                # pod calls issue after every gradient collective
                ranks = ranks + tuple(
                    range(len(ranks), len(ranks) + len(pod.calls))
                )
            out.append(dataclasses.replace(
                g, phase=p, num_phases=self.num_phases,
                calls=g.calls + pod.calls, ready_ranks=ranks,
            ))
        return out

    def schedule_report(self) -> dict:
        scheds = self.schedules()
        mean = mean_bytes_per_step(scheds)
        out = {
            "compressor": self.tc.compressor,
            "num_phases": len(scheds),
            "bytes_per_worker_per_phase": [s.bytes_per_worker for s in scheds],
            "mean_bytes_per_step": mean,
            "dense_bytes": scheds[0].dense_bytes if scheds else 0,
            "volume_ratio": (
                scheds[0].dense_bytes / max(mean, 1) if scheds else 1.0
            ),
        }
        if self.sharded:
            n = max(len(scheds), 1)
            out["sync"] = self.tc.sync
            out["mean_exposed_wire_bytes_per_step"] = (
                sum(s.exposed_wire_bytes(self.dp_world) for s in scheds) / n
            )
            out["mean_deferred_bytes_per_step"] = (
                sum(s.deferred_bytes_per_worker for s in scheds) / n
            )
        return out

    def _phase_fn(self, phase: int) -> Callable:
        if phase not in self._steps:
            self._steps[phase] = build_train_step(
                self.model, self.optimizer, self.compressor, self.plan,
                phase=phase, mesh=self.mesh, dp_axes=self.dp_axes,
                clip_norm=self.tc.clip_norm, donate=False,
                pod_interval=self.tc.pod_interval,
                overlap=self.tc.overlap,
            )
        return self._steps[phase]

    @property
    def hierarchical(self) -> bool:
        return self.tc.pod_interval > 1 and "pod" in self.dp_axes

    @property
    def sharded(self) -> bool:
        return self.tc.sync == "sharded"

    # ---- sharded sync bookkeeping (DESIGN.md §13) -------------------------
    def _flush_fn(self) -> Callable:
        if 0 not in self._flush_fns:
            from repro.core.overlap import sharded_param_allgather

            # the gather covers every bucket, so any phase's schedule works
            schedule = self.compressor.plan_phase(
                self.plan, 0, world=self.dp_world
            )
            hier = self.hierarchical
            # hierarchical: each pod's shard owners hold that pod's
            # authoritative values, so the settling gather runs over the
            # intra-pod axes only — pods keep their (bounded) drift
            axes = (
                tuple(a for a in self.dp_axes if a != "pod")
                if hier else self.dp_axes
            )
            params_def = jax.tree_util.tree_structure(
                jax.tree.map(lambda _: 0, self._shapes)
            )

            def gather(tree):
                return sharded_param_allgather(
                    self.compressor, schedule, tree, axis_names=axes
                )

            def gather_like_params(tree):
                """Gather every params-shaped subtree (Adam's m/v, SGD's
                mu) — the shard owners hold the exact moments the
                allreduce path would have, so the gathered state is fully
                portable (checkpoint-restorable under any sync mode or
                world size)."""
                if (
                    jax.tree_util.tree_structure(
                        jax.tree.map(lambda _: 0, tree)
                    )
                    == params_def
                ):
                    return gather(tree)
                if isinstance(tree, dict):
                    return {
                        k: gather_like_params(v) for k, v in tree.items()
                    }
                return tree

            def flush(params, opt):
                if hier:
                    params, opt = strip_pod_block((params, opt))
                out = gather(params), gather_like_params(opt)
                if hier:
                    out = restore_pod_block(out)
                return out

            spec = P("pod") if hier else P()
            mapped = shard_map_compat(
                flush, self.mesh, (spec, spec), (spec, spec), self.dp_axes
            )
            self._flush_fns[0] = jax.jit(mapped)
        return self._flush_fns[0]

    def flush_sync(self, state):
        """Settle the pending deferred gathers (sharded sync): at run
        boundaries — end of ``run``, checkpoint saves, re-plans, state
        inspection — the last step's updated shards must be gathered so
        params AND optimizer moments are fully fresh on every worker
        (owner shards carry the exact allreduce-equivalent values, so the
        flushed state checkpoints/restores portably).  No-op for
        ``allreduce`` runs, single-process runs, and when nothing is
        pending."""
        if not self.sharded or not self._pending_sync:
            return state
        self._pending_sync = False
        if self.telemetry.enabled:
            self.telemetry.events.emit(
                "flush", step=int(state["step"]), reason="deferred-allgather"
            )
        if self.mesh is None or not self.dp_axes:
            return state      # single worker: shards ARE the full params
        params, opt = self._flush_fn()(state["params"], state["opt"])
        return {**state, "params": params, "opt": opt}

    def init_state(self, key):
        state = make_train_state(self.model, self.optimizer, self.compressor,
                                 self.plan, key)
        if self.hierarchical:
            n_pods = self.mesh.shape["pod"]
            for k in ("params", "opt", "comp"):
                state[k] = jax.tree.map(
                    lambda a: jnp.broadcast_to(a[None], (n_pods,) + a.shape),
                    state[k],
                )
        return state

    def replan(self, interval: int, state=None, *, policy: str = "carry",
               step: int = 0, old_interval: int | None = None):
        """Adopt a new COVAP interval at a safe boundary (between steps):
        new compressor + bucket plan + (lazily recompiled) phase
        executables, with the EF residual carried across the switch by
        ``runtime.transitions`` so its norm survives the transition.

        ``old_interval`` is the cadence the residual in ``state`` was
        accumulated under; it defaults to this trainer's current interval
        and must be given explicitly when the state came from elsewhere
        (e.g. a checkpoint saved under a different config).

        Returns ``(state, TransitionReport)`` — ``state`` unchanged (may be
        None) when the caller manages compressor state itself."""
        from repro.runtime.transitions import carry_comp_state

        if old_interval is None:
            old_interval = self.tc.interval
        if state is not None:
            # sharded sync: the pending deferred AG references the OLD
            # plan's schedules — settle it before the plan is replaced
            state = self.flush_sync(state)
        self.tc = dataclasses.replace(self.tc, interval=int(interval))
        self.compressor = make_compressor(self.tc)
        self.plan = build_plan(
            self._shapes,
            bucket_bytes=self.tc.bucket_bytes,
            max_buckets=self.tc.max_buckets,
            interval=self.tc.interval,
        )
        self._steps = {}   # stale executables: new phases compile lazily
        self._flush_fns = {}
        report = None
        if state is not None:
            comp, report = carry_comp_state(
                state["comp"],
                new_compressor=self.compressor,
                new_plan=self.plan,
                params_like=state["params"],
                step=step,
                old_interval=old_interval,
                new_interval=self.tc.interval,
                policy=policy,
            )
            state = {**state, "comp": comp}
            self.transitions.append(report)
        return state, report

    def run(self, state, batches, steps: int | None = None, log=print,
            autotune=None, telemetry=None, guards=None, faults=None):
        """Host loop.  ``autotune`` (None | True | AutotuneConfig | a live
        AdaptiveRuntime) arms the adaptive runtime: measured-CCR monitoring
        + hysteresis re-planning + timeline tracing (DESIGN.md §10).
        Passing an ``AdaptiveRuntime`` keeps its monitor/controller state
        across chunked ``run`` calls (checkpoint-every loops) instead of
        restarting the policy each chunk.  With ``autotune=None`` the loop
        is the PR-1 static path, bit-for-bit.

        ``telemetry`` (None | directory path | :class:`repro.obs.Telemetry`)
        arms the unified telemetry subsystem (DESIGN.md §15): a run
        manifest + step records into the JSONL event log, loss/grad-norm/
        step counters into the metrics registry, and — when the adaptive
        runtime is armed too — the runtime's planned/measured/control
        spans land in the bundle's shared tracer.  All recording happens
        at the existing log cadence (metrics are already host-side floats
        there), so the hot loop gains no extra device syncs; with
        ``telemetry=None`` every hook is a no-op on the shared disabled
        singleton.

        ``guards`` (None | True | GuardConfig | dict of overrides) arms
        the resilience runtime (DESIGN.md §16): numeric guardrails on
        each step's metrics plus the skip-step -> EF-flush -> checkpoint-
        rewind recovery ladder.  ``faults`` (None | spec string |
        FaultPlan | FaultInjector) arms deterministic fault injection for
        chaos runs; a live :class:`~repro.resilience.ResilienceRuntime`
        passed as ``guards`` keeps its ladder/injector state across
        chunked ``run`` calls.  With both None the loop is the prior
        path, bit-for-bit."""
        from repro.obs import as_telemetry
        from repro.obs.events import plan_digest

        steps = steps if steps is not None else self.tc.steps
        tel = as_telemetry(telemetry)
        if tel.enabled:
            self.telemetry = tel
            tel.manifest_once(
                role="train",
                config=dataclasses.asdict(self.tc),
                plan={
                    "digest": plan_digest(self.plan),
                    "num_buckets": self.plan.num_buckets,
                    "num_phases": self.num_phases,
                    "bucket_bytes_target": self.plan.bucket_bytes_target,
                },
                world=self.dp_world,
                mesh=(
                    {a: int(self.mesh.shape[a]) for a in self.mesh.shape}
                    if self.mesh is not None else None
                ),
            )
        rt = None
        if autotune is not None and autotune is not False:
            from repro.runtime import AdaptiveRuntime, as_autotune_config

            if isinstance(autotune, AdaptiveRuntime):
                rt = self.runtime = autotune
            else:
                rt = self.runtime = AdaptiveRuntime(
                    self, as_autotune_config(autotune)
                )
            if tel.enabled:
                rt.attach_telemetry(tel)
        res = None
        if guards is not None or faults is not None:
            from repro.resilience import ResilienceRuntime

            if isinstance(guards, ResilienceRuntime):
                res = self.resilience = guards
            else:
                res = self.resilience = ResilienceRuntime(
                    self, guards=guards, faults=faults,
                )
            res.attach_telemetry(tel)
            if res.injector is not None and rt is not None:
                # ccr_skew faults ride the probe path: wrap the runtime's
                # probe dispatch so due events inflate the measured comm
                # time (instance attribute shadows the class method)
                rt._probe = res.injector.wrap_probe(rt._probe)
        it = iter(batches)
        steps_c = tel.registry.counter(
            "train_steps_total", "optimizer steps completed"
        )
        loss_g = tel.registry.gauge("train_loss", "last logged total loss")
        gnorm_g = tel.registry.gauge(
            "train_grad_norm", "last logged global gradient norm"
        )
        t0 = time.perf_counter()
        for i in range(steps):
            batch = next(it)
            if res is not None:
                # snapshot (free: state dicts reference immutable arrays)
                # -> guard-owned checkpoint -> fault injection
                state, batch = res.pre_step(state, batch)
            phase = state["step"] % self.num_phases
            fn = self._phase_fn(phase)
            # block for a true wall time only on probe-due steps — an
            # every-step block would serialise async dispatch for the
            # whole run to feed a diagnostic metric
            timed = rt is not None and rt.due_next()
            t_step = time.perf_counter() if timed else 0.0
            params, opt, comp, metrics = fn(
                state["params"], state["opt"], state["comp"], batch,
                jnp.asarray(state["step"], jnp.int32),
            )
            state = {"params": params, "opt": opt, "comp": comp,
                     "step": state["step"] + 1}
            steps_c.inc()
            if self.sharded:
                self._pending_sync = True
            if res is not None:
                # guard check + recovery BEFORE the adaptive runtime sees
                # the state: a poisoned step must not feed the CCR probe
                # or cross a re-plan boundary
                state = res.post_step(state, metrics)
            if rt is not None:
                wall = None
                if timed:
                    jax.block_until_ready(params)
                    wall = time.perf_counter() - t_step
                state = rt.after_step(state, batch, wall_s=wall, log=log)
            if (i + 1) % self.tc.log_every == 0 or i == 0:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = state["step"]
                m["wall_s"] = time.perf_counter() - t0
                self.history.append(m)
                if tel.enabled:
                    loss_g.set(m["total_loss"])
                    gnorm_g.set(m["grad_norm"])
                    tel.events.emit(
                        "step",
                        step=int(state["step"]),
                        loss=m["total_loss"],
                        grad_norm=m["grad_norm"],
                        wall_s=m["wall_s"],
                        phase=int(phase),
                        metrics={
                            k: v for k, v in m.items()
                            if k not in ("step", "wall_s")
                        },
                    )
                if log:
                    # only total_loss/grad_norm are guaranteed — model
                    # metrics dicts need not include a 'loss' key
                    shown = m.get("loss", m["total_loss"])
                    log(
                        f"step {state['step']:>5d}  loss {shown:.4f}  "
                        f"gnorm {m['grad_norm']:.3f}  t {m['wall_s']:.1f}s"
                    )
        if res is not None:
            # drain the lag-one deferred guard check (may recover: the
            # returned state can sit behind the loop's nominal target)
            state = res.finalize(state)
        if rt is not None:
            rt.finish()
        # sharded sync: hand back fully-fresh params (the final step's
        # deferred AG has no next step to ride — settle it here)
        return self.flush_sync(state)
