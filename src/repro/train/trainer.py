"""DP train-step builder: COVAP (or any registered GC scheme) wired into the
gradient synchronisation of a ``shard_map``-manual data-parallel step.

Key structural points (DESIGN.md SS2):

* ``shard_map`` is **manual over the DP axes** ('pod','data') so each
  worker's gradients exist un-reduced and the compressor controls exactly
  which bytes cross the interconnect (one ``psum`` per selected bucket);
  the 'model' axis stays **auto** so tensor-parallel sharding of the model
  math is compiler-managed.
* The coarse filter's bucket selection must be static in XLA, so the step
  is specialised per ``phase = step % I`` -> ``I`` executables, compiled
  lazily on first use.
* Loss/grad math is unchanged across compressors — swapping schemes swaps
  only the sync stage (the paper's DDP-communication-hook shape).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import build_plan, get_compressor
from repro.core.bucketing import BucketPlan
from repro.core.compressors.base import Compressor, dense_bytes
from repro.optim import Optimizer, apply_updates, clip_by_global_norm, global_norm


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    compressor: str = "covap"
    compressor_options: dict = dataclasses.field(default_factory=dict)
    interval: int = 4                      # COVAP I = ceil(CCR); 1 = no filter
    pod_interval: int = 1                  # hierarchical COVAP across pods
    bucket_bytes: int = 25 * 1024 * 1024
    max_buckets: int = 128
    clip_norm: float = 0.0                 # 0 = off
    steps: int = 100
    log_every: int = 10


def make_compressor(tc: TrainConfig) -> Compressor:
    opts = dict(tc.compressor_options)
    if tc.compressor == "covap":
        opts.setdefault("interval", tc.interval)
    return get_compressor(tc.compressor, **opts)


def _loss_and_grads(model, params, batch):
    def lf(p):
        loss, metrics = model.loss_fn(p, batch)
        return loss, metrics

    (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
    return loss, metrics, grads


def pod_reconcile(params, plan: BucketPlan, *, pod_phase: int,
                  pod_interval: int, pod_axes: Sequence[str],
                  reconcile_helper_axes: Sequence[str] = ()):
    """Hierarchical COVAP's cross-pod level (beyond-paper, DESIGN SS7b):
    instead of sending every gradient across the slow DCN pod links, each
    step pmean-reconciles only the PARAMETER segments of the buckets with
    ``(b + step) % I_pod == 0`` — the coarse filter applied at the pod
    level, where CCR > 1 genuinely holds.  Local-SGD-style drift between
    reconciliations, bounded to I_pod steps per bucket by the round-robin.

    The pmean runs over the pod axis PLUS the intra-pod data axes: params
    are data-replicated so the result is identical, but XLA then lowers the
    collective hierarchically (reduce-scatter across the 16 data rows ->
    thin DCN crossing -> all-gather), cutting the cross-pod volume 16x vs a
    naive per-row pod exchange (EXPERIMENTS SSPerf Pair D follow-up).

    Returns (params, bytes_sent_across_pods)."""
    from repro.core import bucketing as bk
    from repro.core.filter import selected_buckets

    treedef = jax.tree_util.tree_structure(params)
    leaves = jax.tree_util.tree_leaves(params)
    sent = 0
    axes = tuple(pod_axes) + tuple(reconcile_helper_axes)
    for b in selected_buckets(plan.num_buckets, pod_phase, pod_interval):
        bucket = plan.buckets[b]
        for seg in bucket.segments:
            li = seg.leaf_idx
            x = bk._slice_segment(leaves[li], seg)
            xm = lax.pmean(x.astype(jnp.float32), axes).astype(x.dtype)
            leaves[li] = bk._update_segment(leaves[li], seg, xm)
            sent += x.size * x.dtype.itemsize
    return jax.tree_util.tree_unflatten(treedef, leaves), sent


def build_step_fn(
    model,
    optimizer: Optimizer,
    compressor: Compressor,
    plan: BucketPlan,
    *,
    phase: int,
    dp_axes: Sequence[str] = (),
    clip_norm: float = 0.0,
    pod_interval: int = 1,
) -> Callable:
    """The un-jitted per-phase step (runs inside shard_map when dp_axes).

    With ``pod_interval > 1`` (hierarchical mode) gradient sync runs only
    over the intra-pod axes; the 'pod' axis is reconciled by
    ``pod_reconcile`` and the state carries a leading pod-block axis."""
    pod_axes = tuple(a for a in dp_axes if a == "pod") if pod_interval > 1 else ()
    grad_axes = tuple(a for a in dp_axes if a not in pod_axes)

    def step_fn(params, opt_state, comp_state, batch, step):
        hier = bool(pod_axes)
        if hier:
            # strip the per-pod block axis (local block size 1)
            params, opt_state, comp_state = jax.tree.map(
                lambda a: a[0], (params, opt_state, comp_state)
            )
        loss, metrics, grads = _loss_and_grads(model, params, batch)
        if dp_axes:
            loss = lax.pmean(loss, tuple(dp_axes))
            metrics = jax.tree.map(
                lambda m: lax.pmean(m, tuple(dp_axes)), metrics
            )
        synced, comp_state, stats = compressor.sync(
            grads, comp_state,
            plan=plan, phase=phase % max(compressor.num_phases(0), 1),
            step=step, axis_names=grad_axes,
        )
        if clip_norm > 0:
            synced, gnorm = clip_by_global_norm(synced, clip_norm)
        else:
            gnorm = global_norm(synced)
        updates, opt_state = optimizer.update(synced, opt_state, params)
        params = apply_updates(params, updates)
        if hier:
            params, _ = pod_reconcile(
                params, plan, pod_phase=phase % pod_interval,
                pod_interval=pod_interval, pod_axes=pod_axes,
                reconcile_helper_axes=grad_axes,
            )
            params, opt_state, comp_state = jax.tree.map(
                lambda a: a[None], (params, opt_state, comp_state)
            )
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["total_loss"] = loss
        return params, opt_state, comp_state, metrics

    return step_fn


def build_train_step(
    model,
    optimizer: Optimizer,
    compressor: Compressor,
    plan: BucketPlan,
    *,
    phase: int,
    mesh=None,
    dp_axes: Sequence[str] = (),
    param_shardings=None,
    clip_norm: float = 0.0,
    donate: bool = True,
    pod_interval: int = 1,
):
    """jit (+ shard_map over DP axes) the per-phase step.

    Single-process CPU path: ``mesh=None`` -> plain jit, no collectives.
    Production path: manual over ``dp_axes``, auto over everything else.
    Hierarchical mode (``pod_interval > 1``): state carries a leading
    per-pod axis (P('pod')) so pods may drift between reconciliations.
    """
    hier = pod_interval > 1 and "pod" in dp_axes
    step_fn = build_step_fn(
        model, optimizer, compressor, plan,
        phase=phase, dp_axes=dp_axes if mesh is not None else (),
        clip_norm=clip_norm, pod_interval=pod_interval if hier else 1,
    )
    if mesh is None:
        return jax.jit(step_fn, donate_argnums=(0, 1, 2) if donate else ())

    state_spec = P("pod") if hier else P()
    batch_spec = P(tuple(dp_axes))
    mapped = jax.shard_map(
        step_fn,
        mesh=mesh,
        in_specs=(
            state_spec,                           # params
            state_spec,                           # opt_state
            state_spec,                           # comp_state (residuals)
            batch_spec,                           # batch (sharded on dim 0)
            P(),                                  # step
        ),
        out_specs=(state_spec, state_spec, state_spec, P()),
        axis_names=set(dp_axes),
        check_vma=False,
    )
    kw = {}
    if param_shardings is not None:
        like = lambda tree: jax.tree.map(
            lambda s: NamedSharding(mesh, s), tree,
            is_leaf=lambda x: isinstance(x, P),
        )
        kw["in_shardings"] = (
            like(param_shardings["params"]),
            like(param_shardings["opt"]),
            like(param_shardings["comp"]),
            like(param_shardings["batch"]),
            NamedSharding(mesh, P()),
        )
    return jax.jit(mapped, donate_argnums=(0, 1, 2) if donate else (), **kw)


def make_train_state(model, optimizer, compressor, plan, key):
    params = model.init(key)
    return {
        "params": params,
        "opt": optimizer.init(params),
        "comp": compressor.init_state(params, plan),
        "step": 0,
    }


class Trainer:
    """Host loop: lazily compiles one executable per COVAP phase, logs
    metrics, exposes measured step timing for the CCR profiler."""

    def __init__(self, model, optimizer, tc: TrainConfig, *, mesh=None,
                 dp_axes: Sequence[str] = (), param_specs=None):
        self.model = model
        self.optimizer = optimizer
        self.tc = tc
        self.mesh = mesh
        self.dp_axes = tuple(dp_axes)
        self.compressor = make_compressor(tc)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        self.plan = build_plan(
            shapes,
            bucket_bytes=tc.bucket_bytes,
            max_buckets=tc.max_buckets,
            interval=tc.interval,
        )
        self._steps: dict[int, Callable] = {}
        self.history: list[dict] = []

    @property
    def num_phases(self) -> int:
        base = self.compressor.num_phases(self.tc.interval)
        if self.tc.pod_interval > 1 and "pod" in self.dp_axes:
            import math as _m
            return _m.lcm(base, self.tc.pod_interval)
        return base

    def _phase_fn(self, phase: int) -> Callable:
        if phase not in self._steps:
            self._steps[phase] = build_train_step(
                self.model, self.optimizer, self.compressor, self.plan,
                phase=phase, mesh=self.mesh, dp_axes=self.dp_axes,
                clip_norm=self.tc.clip_norm, donate=False,
                pod_interval=self.tc.pod_interval,
            )
        return self._steps[phase]

    @property
    def hierarchical(self) -> bool:
        return self.tc.pod_interval > 1 and "pod" in self.dp_axes

    def init_state(self, key):
        state = make_train_state(self.model, self.optimizer, self.compressor,
                                 self.plan, key)
        if self.hierarchical:
            n_pods = self.mesh.shape["pod"]
            for k in ("params", "opt", "comp"):
                state[k] = jax.tree.map(
                    lambda a: jnp.broadcast_to(a[None], (n_pods,) + a.shape),
                    state[k],
                )
        return state

    def run(self, state, batches, steps: int | None = None, log=print):
        steps = steps if steps is not None else self.tc.steps
        it = iter(batches)
        t0 = time.perf_counter()
        for i in range(steps):
            batch = next(it)
            phase = state["step"] % self.num_phases
            fn = self._phase_fn(phase)
            params, opt, comp, metrics = fn(
                state["params"], state["opt"], state["comp"], batch,
                jnp.asarray(state["step"], jnp.int32),
            )
            state = {"params": params, "opt": opt, "comp": comp,
                     "step": state["step"] + 1}
            if (i + 1) % self.tc.log_every == 0 or i == 0:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = state["step"]
                m["wall_s"] = time.perf_counter() - t0
                self.history.append(m)
                if log:
                    log(
                        f"step {state['step']:>5d}  loss {m['loss']:.4f}  "
                        f"gnorm {m['grad_norm']:.3f}  t {m['wall_s']:.1f}s"
                    )
        return state
