"""Training substrate: DP step builder with COVAP phase-specialised
executables, host loop, metrics."""
from .trainer import (
    TrainConfig,
    Trainer,
    build_overlapped_step,
    build_train_step,
    make_train_state,
    restore_pod_block,
    strip_pod_block,
)

__all__ = [
    "TrainConfig",
    "Trainer",
    "build_overlapped_step",
    "build_train_step",
    "make_train_state",
    "restore_pod_block",
    "strip_pod_block",
]
