"""Training substrate: DP step builder with COVAP phase-specialised
executables, host loop, metrics."""
from .trainer import TrainConfig, Trainer, build_train_step, make_train_state

__all__ = ["TrainConfig", "Trainer", "build_train_step", "make_train_state"]
