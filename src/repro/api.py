"""Top-level facade: ``repro.api.fit / tune / plan_report``.

One import, three verbs, all built on the plan/execute split
(DESIGN.md SS6):

* :func:`fit` — train an architecture with any registered GC scheme.
  ``interval="auto"`` resolves the paper's adaptive rule
  ``I = ceil(analytic_ccr)`` (SS III.B) before a single step is traced.
* :func:`plan_report` — the full static story of a run: resolved interval,
  per-phase ``CommSchedule`` summaries, analytic step times and the
  residual (post-compression) CCR — **no compilation, no tracing**.
* :func:`tune` — rank candidate compressors for a workload with the
  schedule-driven overlap timeline (eq (6) with real planned volumes).

    import repro.api as api
    result = api.fit("gpt2-paper", reduced=True, interval="auto", steps=20)
    print(result.interval, result.ccr)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax

from repro.configs import get_config, get_reduced
from repro.core import build_plan, get_compressor
from repro.core.ccr import (
    HardwareSpec,
    analytic_ccr,
    analytic_times,
    compressed_ccr,
    select_interval,
)
from repro.core.perfmodel import (
    cycle_speedup,
    overlap_fraction,
    pack_overhead_s,
    simulate_schedule,
)
from repro.core.schedule import CommSchedule, mean_bytes_per_step, plan_all_phases
from repro.data import DataConfig, make_loader
from repro.models import build_model, count_params
from repro.optim import adamw, cosine_warmup, sgd
from repro.train.trainer import TrainConfig, Trainer


@dataclasses.dataclass(frozen=True)
class IntervalChoice:
    """How ``interval="auto"`` was resolved."""

    interval: int
    ccr: float | None          # None when the interval was given explicitly
    auto: bool
    dp_world: int
    grad_bytes: int
    step_flops_per_chip: float


def resolve_interval(
    interval,
    cfg,
    *,
    global_batch: int,
    seq_len: int,
    dp_world: int,
    hw: HardwareSpec | None = None,
) -> IntervalChoice:
    """The paper's adaptive compression ratio, as a library call: with
    ``interval="auto"`` pick ``I = ceil(analytic_ccr)``.  The default
    hardware model is the paper's environment (V100 + 30 Gbps Ethernet) so
    CPU-local runs reproduce the paper's interval choices.

    ``interval="adaptive"`` resolves the same way — the analytic pick is
    the *initial* interval, which the online runtime then re-plans from
    measured CCR (``repro.runtime``)."""
    hw = hw or HardwareSpec.cloud_v100_30gbps()
    n_active = count_params(cfg, active_only=True)
    tokens = global_batch * seq_len
    flops = 6.0 * n_active * tokens / max(dp_world, 1)
    grad_bytes = count_params(cfg) * 4
    if interval not in ("auto", "adaptive"):
        return IntervalChoice(
            int(interval), None, False, dp_world, grad_bytes, flops
        )
    ccr = analytic_ccr(
        step_flops_per_chip=flops,
        grad_bytes=grad_bytes,
        dp_world=max(dp_world, 1),
        hw=hw,
    )
    return IntervalChoice(
        select_interval(ccr), ccr, True, dp_world, grad_bytes, flops
    )


def _config(arch: str, *, reduced: bool, vocab_size: int | None = None):
    cfg = get_reduced(arch) if reduced else get_config(arch)
    if vocab_size is not None:
        cfg = cfg.with_(vocab_size=vocab_size)
    return cfg


def _compressor_opts(name: str, opts: dict | None, interval: int) -> dict:
    opts = dict(opts or {})
    if name == "covap":
        opts.setdefault("interval", interval)
    return opts


def _static_setup(
    arch: str,
    *,
    reduced: bool,
    interval,
    seq_len: int,
    global_batch: int,
    dp_workers: int,
    bucket_bytes: int,
    max_buckets: int,
    hw: HardwareSpec,
):
    """Shared no-tracing-needed setup of plan_report/tune: config, interval
    resolution, bucket plan and analytic step times."""
    cfg = _config(arch, reduced=reduced)
    model = build_model(cfg)
    choice = resolve_interval(
        interval, cfg, global_batch=global_batch, seq_len=seq_len,
        dp_world=dp_workers, hw=hw,
    )
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    plan = build_plan(
        shapes, bucket_bytes=bucket_bytes, max_buckets=max_buckets,
        interval=choice.interval,
    )
    times = analytic_times(
        step_flops_per_chip=choice.step_flops_per_chip,
        grad_bytes=choice.grad_bytes,
        dp_world=max(dp_workers, 1),
        hw=hw,
    )
    return cfg, choice, plan, times


def _optimizer(name: str, lr: float, steps: int):
    if name == "adam":
        return adamw(cosine_warmup(lr, steps // 10 + 1, steps))
    if name == "sgd":
        return sgd(lr, momentum=0.9)
    raise ValueError(f"unknown optimizer {name!r}")


@dataclasses.dataclass
class FitResult:
    trainer: Trainer
    state: Any
    history: list[dict]
    interval: int
    ccr: float | None
    schedules: list[CommSchedule]
    autotune: dict | None = None   # AdaptiveRuntime summary (adaptive mode)
    telemetry: Any = None          # repro.obs.Telemetry when armed
    resilience: dict | None = None  # ResilienceRuntime summary (guards mode)

    @property
    def final_interval(self) -> int:
        """The interval after any online re-planning (== ``interval`` when
        the run was static)."""
        return self.trainer.tc.interval

    @property
    def final_loss(self) -> float | None:
        if not self.history:
            return None
        m = self.history[-1]
        return m.get("loss", m.get("total_loss"))


def fit(
    arch: str = "gpt2-paper",
    *,
    reduced: bool = True,
    compressor: str = "covap",
    compressor_options: dict | None = None,
    interval: int | str = "auto",
    steps: int = 20,
    seq_len: int = 32,
    global_batch: int = 8,
    dp_workers: int = 8,
    optimizer: str = "adam",
    lr: float = 1.5e-4,
    bucket_bytes: int = 1 << 14,
    max_buckets: int = 32,
    vocab_size: int | None = None,
    hw: HardwareSpec | None = None,
    mesh=None,
    dp_axes: Sequence[str] = (),
    seed: int = 0,
    log=None,
    log_every: int = 10,
    batches=None,
    autotune=None,
    overlap: str = "post",
    arena: bool = False,
    sync: str = "allreduce",
    telemetry=None,
    guards=None,
    faults=None,
) -> FitResult:
    """Train ``arch`` with a GC scheme; ``interval="auto"`` applies the
    paper's ``I = ceil(CCR)`` from the analytic profiler end-to-end.

    ``interval="adaptive"`` starts from the analytic pick and arms the
    adaptive runtime (``repro.runtime``): measured CCR drives online
    re-planning of the interval, with EF residuals carried across each
    switch.  ``autotune`` passes an ``AutotuneConfig`` (or True) to tune
    the policy; it may also be given with a numeric ``interval``.

    ``dp_workers`` is the modelled DP world size for CCR selection on
    single-process runs; with a real ``mesh`` the mesh's DP extent wins.
    ``batches`` overrides the synthetic data loader.

    ``overlap="fused"`` runs the overlap execution engine: each bucket's
    collective is issued inside the backward pass by gradient-ready hooks
    (bit-for-bit equal to the default ``"post"`` path; segmented bucket
    compressors only — covap/none/fp16).

    ``arena=True`` turns on the zero-copy gradient arena (DESIGN.md §12):
    bucket payloads become static-offset views of statically-planned flat
    buffers, packed once per step by the fused pack/EF/cast pass —
    bitwise-equal results with the per-bucket gather/scatter copies gone;
    composes with both overlap modes.

    ``sync="sharded"`` swaps each selected bucket's all-reduce for a
    reduce-scatter + deferred param all-gather (DESIGN.md §13): the
    optimizer's meaningful updates land on the local 1/W shard and the
    gather of updated params rides the NEXT step's forward pass, halving
    the communication exposed behind the backward pass.  Segmented bucket
    compressors only (covap/none/fp16); composes with both overlap modes
    and the arena; parity with ``"allreduce"`` is pinned bit-for-bit
    (tests/test_sharded_sync.py).

    ``telemetry`` (None | directory path | ``repro.obs.Telemetry``) arms
    the unified telemetry subsystem (DESIGN.md §15); the live bundle is
    handed back as ``FitResult.telemetry`` for inspection or ``save()``.

    ``guards`` (None | True | ``repro.resilience.GuardConfig`` | dict)
    arms the resilience runtime (DESIGN.md §16): numeric guardrails on
    every step plus the skip-step → EF-flush → checkpoint-rewind auto-
    recovery ladder; ``faults`` (None | spec string like
    ``"grad_nan@10,ef_blowup@20"`` | ``FaultPlan``) injects a
    deterministic chaos schedule.  The ladder's summary lands in
    ``FitResult.resilience``."""
    cfg = _config(arch, reduced=reduced, vocab_size=vocab_size)
    model = build_model(cfg)
    dp_world = dp_workers
    if mesh is not None and dp_axes:
        dp_world = 1
        for a in dp_axes:
            dp_world *= mesh.shape[a]
    choice = resolve_interval(
        interval, cfg, global_batch=global_batch, seq_len=seq_len,
        dp_world=dp_world, hw=hw,
    )
    tc = TrainConfig(
        compressor=compressor,
        compressor_options=dict(compressor_options or {}),
        interval=choice.interval,
        bucket_bytes=bucket_bytes,
        max_buckets=max_buckets,
        steps=steps,
        log_every=log_every,
        overlap=overlap,
        arena=arena,
        sync=sync,
    )
    tr = Trainer(
        model, _optimizer(optimizer, lr, steps), tc,
        mesh=mesh, dp_axes=dp_axes,
    )
    state = tr.init_state(jax.random.PRNGKey(seed))
    if batches is None:
        dc = DataConfig(
            vocab_size=cfg.vocab_size, seq_len=seq_len,
            global_batch=global_batch,
        )
        batches = make_loader(dc)
    if interval == "adaptive" and autotune is None:
        autotune = True
    from repro.obs import as_telemetry

    tel = as_telemetry(telemetry)
    state = tr.run(state, iter(batches), steps=steps, log=log,
                   autotune=autotune, telemetry=tel, guards=guards,
                   faults=faults)
    return FitResult(
        trainer=tr,
        state=state,
        history=tr.history,
        interval=choice.interval,
        ccr=choice.ccr,
        schedules=tr.schedules(),
        autotune=tr.runtime.summary() if tr.runtime is not None else None,
        telemetry=tel if tel.enabled else None,
        resilience=(
            tr.resilience.summary() if tr.resilience is not None else None
        ),
    )


def plan_report(
    arch: str = "gpt2-paper",
    *,
    reduced: bool = True,
    compressor: str = "covap",
    compressor_options: dict | None = None,
    interval: int | str = "auto",
    seq_len: int = 32,
    global_batch: int = 8,
    dp_workers: int = 8,
    bucket_bytes: int = 1 << 14,
    max_buckets: int = 32,
    hw: HardwareSpec | None = None,
    sync: str = "allreduce",
) -> dict:
    """Everything static about a run — interval resolution, per-phase
    ``CommSchedule``s, analytic step times, residual CCR — computed without
    tracing or compiling anything.  ``sync="sharded"`` reports the
    reduce-scatter decomposition's exposed/deferred byte split per phase."""
    hw = hw or HardwareSpec.cloud_v100_30gbps()
    cfg, choice, plan, times = _static_setup(
        arch, reduced=reduced, interval=interval, seq_len=seq_len,
        global_batch=global_batch, dp_workers=dp_workers,
        bucket_bytes=bucket_bytes, max_buckets=max_buckets, hw=hw,
    )
    opts = _compressor_opts(compressor, compressor_options, choice.interval)
    if sync != "allreduce":
        opts.setdefault("sync", sync)
    comp = get_compressor(compressor, **opts)
    schedules = plan_all_phases(comp, plan, world=dp_workers)
    return {
        "arch": cfg.name,
        "compressor": compressor,
        "interval": choice.interval,
        "interval_auto": choice.auto,
        "analytic_ccr": choice.ccr if choice.auto else times["ccr"],
        "dense_ccr": times["ccr"],
        "residual_ccr": compressed_ccr(
            schedules, t_comp=times["t_comp"], world=dp_workers, hw=hw,
            link_bw=hw.ici_bw,
        ),
        "t_before": times["t_before"],
        "t_comp": times["t_comp"],
        "t_comm_dense": times["t_comm"],
        "num_buckets": plan.num_buckets,
        "phases": [s.summary() for s in schedules],
    }


_TUNE_CANDIDATES = (
    ("covap", {}),
    ("none", {}),
    ("fp16", {}),
    ("topk", {"ratio": 0.01}),
    ("randomk", {"ratio": 0.01}),
    ("efsignsgd", {}),
    ("powersgd", {"rank": 2}),
    ("oktopk", {"ratio": 0.01}),
    ("fp8wire", {}),
)


def tune(
    arch: str = "gpt2-paper",
    *,
    reduced: bool = True,
    candidates: Sequence[tuple[str, dict]] = _TUNE_CANDIDATES,
    interval: int | str = "auto",
    seq_len: int = 32,
    global_batch: int = 8,
    dp_workers: int = 8,
    bucket_bytes: int = 1 << 14,
    max_buckets: int = 32,
    hw: HardwareSpec | None = None,
    measured: bool = False,
    measure_steps: int = 2,
    arena: bool = False,
    telemetry=None,
) -> list[dict]:
    """Rank GC schemes for a workload by the schedule-driven overlap
    timeline (eq (6) with each scheme's real planned volumes).  Data-
    dependent exchanges (all-to-all based) lose their overlap, as in the
    paper's Fig. 1(e).

    ``arena=True`` models the arena execution path: the pack pass
    (``perfmodel.pack_overhead_s``) rides the compute lane of the
    timeline, mirroring ``fit(arena=True)``.  The ``pack_overhead_us``
    column is reported either way; with ``arena=False`` (default) the
    timeline matches the legacy execute path so ``overlap_frac_modeled``
    stays comparable with ``overlap_frac_achieved`` on default runs.

    ``measured=True`` additionally runs the online profiler
    (``repro.runtime.measure_workload_ccr``) on the dense workload — a few
    real steps, sub-program timing — and reports the measured CCR next to
    the analytic one in every row (``measured_ccr`` / the interval it
    implies).  On a single process the honest measured comm time is ~0;
    the column earns its keep on a real mesh."""
    hw = hw or HardwareSpec.cloud_v100_30gbps()
    cfg, choice, plan, times = _static_setup(
        arch, reduced=reduced, interval=interval, seq_len=seq_len,
        global_batch=global_batch, dp_workers=dp_workers,
        bucket_bytes=bucket_bytes, max_buckets=max_buckets, hw=hw,
    )
    measured_row = None
    if measured:
        measured_row = _measured_workload_ccr(
            cfg, seq_len=seq_len, global_batch=global_batch,
            bucket_bytes=bucket_bytes, max_buckets=max_buckets,
            steps=measure_steps,
        )
    rows = []
    for name, opts in candidates:
        opts = _compressor_opts(name, opts, choice.interval)
        comp = get_compressor(name, **opts)
        schedules = plan_all_phases(comp, plan, world=dp_workers)
        data_dep = any(
            c.op == "all_to_all" for s in schedules for c in s.calls
        )
        speedup = cycle_speedup(
            dp_workers, times["t_before"], times["t_comp"], schedules,
            world=dp_workers, link_bw=hw.ici_bw, data_dependency=data_dep,
        )
        mean_bytes = mean_bytes_per_step(schedules)
        # arena pack pass (one streaming HBM sweep per phase): priced into
        # the timeline below and kept as an explicit column so "near-zero
        # compression overhead" stays a measured claim, not an assumption
        ef_on = getattr(comp, "ef", None) is not None
        packs = [
            pack_overhead_s(s, hbm_bw=hw.hbm_bw, ef=ef_on)
            for s in schedules
        ]
        pack_us = sum(packs) / max(len(packs), 1) * 1e6
        # predicted overlap fraction: the eq-(6) timeline in the overlap
        # engine's real issue order (ReadyOrder) — the headroom the fused
        # path is built to recover
        sims = [
            simulate_schedule(
                times["t_before"], times["t_comp"], s,
                world=dp_workers, link_bw=hw.ici_bw,
                t_pack=t_pack if arena else 0.0,
                data_dependency=data_dep, ready_order=True,
            )
            for s, t_pack in zip(schedules, packs)
        ]
        predicted_overlap = sum(overlap_fraction(s) for s in sims) / max(
            len(sims), 1
        )
        row = {
            "compressor": name,
            "options": opts,
            "speedup": speedup,
            "efficiency": speedup / max(dp_workers, 1),
            "mean_bytes_per_step": mean_bytes,
            "volume_ratio": schedules[0].dense_bytes / max(mean_bytes, 1),
            "data_dependency": data_dep,
            "num_phases": len(schedules),
            "analytic_ccr": times["ccr"],
            "overlap_frac_modeled": predicted_overlap,
            "pack_overhead_us": pack_us,
        }
        if measured_row is not None:
            row["measured_ccr"] = measured_row["ccr"]
            row["measured_interval"] = measured_row["interval"]
            # achieved overlap of the executed (dense) workload — what the
            # engine actually hid, next to the model's prediction
            row["overlap_frac_achieved"] = measured_row.get(
                "achieved_overlap"
            )
        rows.append(row)
    rows.sort(key=lambda r: -r["speedup"])
    from repro.obs import as_telemetry

    tel = as_telemetry(telemetry)
    if tel.enabled:
        for row in rows:
            tel.events.emit("tune_row", compressor=row["compressor"], row=row)
            tel.registry.gauge(
                "tune_speedup", "modeled cycle speedup",
                compressor=row["compressor"],
            ).set(row["speedup"])
            tel.registry.gauge(
                "tune_overlap_frac_modeled", "predicted overlap fraction",
                compressor=row["compressor"],
            ).set(row["overlap_frac_modeled"])
    return rows


def _measured_workload_ccr(
    cfg, *, seq_len: int, global_batch: int, bucket_bytes: int,
    max_buckets: int, steps: int,
) -> dict:
    """A few real dense steps through the measured profiler: what the
    hardware actually delivers for this workload, as a CCR + interval."""
    from repro.runtime import measure_workload_ccr

    model = build_model(cfg)
    tc = TrainConfig(
        compressor="none", interval=1, bucket_bytes=bucket_bytes,
        max_buckets=max_buckets, log_every=10 ** 9,
    )
    tr = Trainer(model, sgd(1e-3), tc)
    state = tr.init_state(jax.random.PRNGKey(0))
    dc = DataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq_len, global_batch=global_batch
    )
    batches = iter(make_loader(dc))
    batch = next(batches)
    state = tr.run(state, iter([batch] * max(steps, 1)), steps=max(steps, 1),
                   log=None)
    out = measure_workload_ccr(tr, state, batch)
    out["interval"] = select_interval(out["ccr"])
    return out


__all__ = [
    "FitResult",
    "IntervalChoice",
    "fit",
    "plan_report",
    "resolve_interval",
    "tune",
]
