"""Cheap numeric guardrails over the training step (DESIGN.md §16).

Guards must be nearly free — the chaos gate budgets their total overhead
at ≤3% of step wall time — so every check is either (a) a scalar the step
already computes (``total_loss``, ``grad_norm``: NaN/Inf anywhere in the
gradient propagates into the global norm, so one finite-check on it has
the same detection power as a per-leaf sweep), (b) a single reduction per
packed arena plane (:func:`plane_nonfinite_counts`, used by the arena
pipeline tests), or (c) a cadenced O(params) reduction — the EF-residual
watchdog, a single cached jitted norm over the compressor state every
``residual_check_every`` steps rather than per step.

Three guards:

* **nonfinite** — loss or global gradient norm is NaN/Inf.  The step
  that produced it already applied a poisoned update, which is why
  recovery restores the *pre-step* snapshot rather than patching the
  post-step state.
* **loss_spike** — loss exceeds ``loss_spike_factor ×`` the rolling
  median of the last ``loss_window`` finite losses (armed only after
  ``loss_spike_min_steps`` samples, so init noise can't trip it).
  Catches blow-ups that stay finite.
* **residual** — EF residual norm exceeds ``residual_abs_max``.
  Residual mass is *deferred gradient*, so divergence here silently
  poisons every future flush long before the loss moves; this guard maps
  straight to the EF-flush recovery rung.

What these guards cannot see (honest limits, DESIGN.md §16): silent
numerical drift that stays finite and small (a low-mantissa bit flip is
indistinguishable from rounding), corruption in the optimizer moments,
and anything that corrupts the checkpoint itself — the digest check in
``checkpoint.store`` covers at-rest corruption, but a correct checkpoint
of an already-wrong state is unrecoverable by this subsystem.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import jax.numpy as jnp
import numpy as np

GUARD_KINDS = ("nonfinite", "loss_spike", "residual")

# Module-level so the jitted executable is cached by a STABLE function
# identity: a per-Guards-instance jit would recompile (~250 ms) on every
# trainer run, which is the entire 3% overhead budget many times over.
_residual_norm_jit = None


def _residual_norm_leaves(leaves):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def _get_residual_norm_jit():
    global _residual_norm_jit
    if _residual_norm_jit is None:
        import jax

        _residual_norm_jit = jax.jit(_residual_norm_leaves)
    return _residual_norm_jit


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """Knobs for the guard battery and (consumed by ``recovery.py``) the
    escalation ladder bounds."""

    check_every: int = 1            # host-side metric check cadence (steps)
    sync_every: int = 4             # materialise deferred checks in batches
    #   of this many steps: one host<->device wake per batch instead of
    #   per step (each blocking wake costs ~0.5 ms of scheduler latency
    #   on a saturated box, which alone blows the 3% budget on a small
    #   step).  EVERY step is still checked — detection *latency* grows
    #   to at most check_every*sync_every steps, detection *power* does
    #   not change.  1 = the strict lag-one pipeline (tests that assert
    #   step-exact recovery arithmetic pin this).
    loss_window: int = 32           # rolling-median window for spikes
    loss_spike_factor: float = 100.0
    loss_spike_min_steps: int = 8   # samples before the spike guard arms
    residual_check_every: int = 8   # EF-norm watchdog cadence (0 = off)
    residual_abs_max: float = 1e12
    # --- escalation ladder bounds (recovery.py) ---
    max_skips: int = 2              # skip-step rungs per incident
    max_flushes: int = 1            # EF-flush rungs per incident
    max_rewinds: int = 2            # checkpoint rewinds per RUN (never reset)
    retry_backoff_s: float = 0.0    # sleep between escalations
    # --- guard-owned checkpointing (rewind target) ---
    ckpt_dir: str | None = None
    ckpt_every: int = 0             # 0 = never save; rewind needs a dir + cadence

    def __post_init__(self):
        if self.check_every < 1:
            raise ValueError("check_every must be >= 1")
        if self.sync_every < 1:
            raise ValueError("sync_every must be >= 1")
        if self.loss_window < 2:
            raise ValueError("loss_window must be >= 2")


def as_guard_config(obj) -> GuardConfig | None:
    """Coerce the user-facing ``guards=`` argument: None passes through,
    True means defaults, a dict is keyword overrides."""
    if obj is None or isinstance(obj, GuardConfig):
        return obj
    if obj is True:
        return GuardConfig()
    if obj is False:
        return None
    if isinstance(obj, dict):
        return GuardConfig(**obj)
    raise TypeError(
        f"guards must be None/True/False, a GuardConfig or a dict of "
        f"overrides; got {type(obj).__name__}"
    )


@dataclasses.dataclass(frozen=True)
class GuardTrip:
    """One guard firing.  ``value``/``threshold`` are the observed
    statistic and the limit it crossed (NaN value for non-finite trips)."""

    step: int
    guard: str
    reason: str
    value: float = float("nan")
    threshold: float = float("nan")


def plane_nonfinite_counts(planes: Sequence[jnp.ndarray]) -> list[int]:
    """Non-finite element count per packed arena plane — exactly one
    ``sum(~isfinite)`` reduction per plane, the cheapest whole-gradient
    scan the arena layout admits (planes are already flat and contiguous,
    so there is no per-bucket gather)."""
    return [int(jnp.sum(~jnp.isfinite(p))) for p in planes]


class Guards:
    """The guard battery.  ``check(step, metrics, comp_state)`` is called
    by the resilience runtime on its host-side cadence with the step's
    already-materialised scalar metrics; it returns the list of trips
    (empty on a clean step).  The battery is stateful only through the
    rolling loss window."""

    def __init__(self, config: GuardConfig | None = None):
        self.config = config or GuardConfig()
        self._losses: list[float] = []
        self.trips: list[GuardTrip] = []

    # -- individual guards --------------------------------------------------
    def _check_nonfinite(self, step: int, loss: float,
                         gnorm: float | None) -> GuardTrip | None:
        if not math.isfinite(loss):
            return GuardTrip(step, "nonfinite", f"loss={loss}", value=loss)
        if gnorm is not None and not math.isfinite(gnorm):
            return GuardTrip(step, "nonfinite", f"grad_norm={gnorm}",
                             value=gnorm)
        return None

    def _check_loss_spike(self, step: int, loss: float) -> GuardTrip | None:
        cfg = self.config
        window = self._losses[-cfg.loss_window:]
        if len(window) >= cfg.loss_spike_min_steps:
            med = float(np.median(window))
            limit = cfg.loss_spike_factor * max(abs(med), 1e-8)
            if abs(loss) > limit:
                return GuardTrip(step, "loss_spike",
                                 f"|loss|={abs(loss):.3e} > "
                                 f"{cfg.loss_spike_factor:g}x median "
                                 f"{med:.3e}",
                                 value=loss, threshold=limit)
        return None

    def _check_residual(self, step: int, comp_state: Any,
                        value: float | None = None) -> GuardTrip | None:
        """``value`` is a precomputed norm from :meth:`residual_async`
        (the caller already applied the cadence); without it the cadence
        is applied here and the norm computed synchronously."""
        cfg = self.config
        if value is None:
            if cfg.residual_check_every <= 0 or comp_state is None:
                return None
            if step % cfg.residual_check_every != 0:
                return None
            value = self._residual_value(comp_state)
        norm = value
        if not math.isfinite(norm) or norm > cfg.residual_abs_max:
            return GuardTrip(step, "residual",
                             f"EF residual norm {norm:.3e} exceeds "
                             f"{cfg.residual_abs_max:.1e}",
                             value=norm, threshold=cfg.residual_abs_max)
        return None

    def _residual_value(self, comp_state: Any) -> float:
        """EF residual L2 norm via one cached jitted reduction.  The eager
        ``transitions.residual_norm`` dispatches per-leaf ops and costs
        tens of milliseconds on a reduced model — fine at replan
        boundaries, fatal inside the 3%-budget watchdog cadence.  The jit
        cache keys on leaf shapes, which are fixed for a run."""
        import jax

        if isinstance(comp_state, dict) and "residual" in comp_state:
            comp_state = comp_state["residual"]
        leaves = [
            l for l in jax.tree_util.tree_leaves(comp_state)
            if hasattr(l, "dtype") and jnp.issubdtype(l.dtype, jnp.floating)
        ]
        if not leaves:
            return 0.0
        return float(_get_residual_norm_jit()(leaves))

    def residual_async(self, step: int, comp_state: Any):
        """Dispatch the residual-norm reduction WITHOUT materialising it —
        returns a device scalar (or None when the cadence/state says no
        check is due).  The resilience runtime calls this at enqueue time
        so that by the batched flush the scalar is already computed and
        ``float()`` costs microseconds instead of a pipeline stall."""
        import jax

        cfg = self.config
        if cfg.residual_check_every <= 0 or comp_state is None:
            return None
        if step % cfg.residual_check_every != 0:
            return None
        if isinstance(comp_state, dict) and "residual" in comp_state:
            comp_state = comp_state["residual"]
        leaves = [
            l for l in jax.tree_util.tree_leaves(comp_state)
            if hasattr(l, "dtype") and jnp.issubdtype(l.dtype, jnp.floating)
        ]
        if not leaves:
            return None
        return _get_residual_norm_jit()(leaves)

    # -- the battery --------------------------------------------------------
    def check(self, step: int, metrics: dict, comp_state: Any = None,
              residual_value: float | None = None) -> list[GuardTrip]:
        """Run every guard against one step's host-side metrics.  The
        loss window only learns from clean steps — a tripped step's loss
        must not drag the median toward the blow-up."""
        loss = float(metrics.get("loss", metrics.get("total_loss", 0.0)))
        gnorm = metrics.get("grad_norm")
        gnorm = None if gnorm is None else float(gnorm)

        trips = []
        t = self._check_nonfinite(step, loss, gnorm)
        if t is not None:
            trips.append(t)
        else:
            t = self._check_loss_spike(step, loss)
            if t is not None:
                trips.append(t)
        rt = self._check_residual(step, comp_state, value=residual_value)
        if rt is not None:
            trips.append(rt)
        if not trips:
            self._losses.append(loss)
            if len(self._losses) > 4 * self.config.loss_window:
                del self._losses[: -2 * self.config.loss_window]
        self.trips.extend(trips)
        return trips

    def reset_window(self) -> None:
        """Drop the loss history — called after a checkpoint rewind, where
        the pre-rewind window no longer describes the trajectory."""
        self._losses.clear()


__all__ = [
    "GUARD_KINDS",
    "GuardConfig",
    "GuardTrip",
    "Guards",
    "as_guard_config",
    "plane_nonfinite_counts",
]
