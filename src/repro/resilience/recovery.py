"""Escalating auto-recovery around the trainer's host loop (DESIGN.md §16).

The :class:`ResilienceRuntime` brackets each step:

* ``pre_step`` — pin the clean incoming state as a rollback point when a
  new batch window opens (a *reference*, not a copy: JAX arrays are
  immutable), write the guard-owned checkpoint on its cadence, then let
  the fault injector corrupt the step's inputs.  Snapshot-before-inject
  is load-bearing: skip-step must restore the state as it was before the
  fault, not a faithfully-corrupted copy.  At most TWO states are ever
  pinned (current + previous window): pinning every step's state keeps
  the allocator from recycling step buffers and makes the training step
  itself ~40% slower — the dominant guard cost, ahead of any host sync.
* ``post_step`` — run the guard battery on the step's host-side metrics
  (on ``check_every`` cadence) and, on a trip, climb the escalation
  ladder.  Checks are **deferred and batched**: step ``N``'s device
  scalars are enqueued at its own ``post_step`` and materialised —
  together with up to ``sync_every - 1`` neighbours, in step order —
  once per batch, by which time the async queue has computed them.  One
  blocking host↔device wake per batch instead of per step is what keeps
  the guard overhead inside the ≤3% budget (``benchmarks/chaos_check.py``;
  a per-step wake costs ~0.5 ms of scheduler latency on a saturated box).
  Every step is still checked; the price is detection *latency* — up to
  ``check_every * sync_every`` steps of in-flight work are discarded on
  a trip — and the adaptive runtime can see that many poisoned steps
  before the guards do: a probe or re-plan landing in the window rides
  corrupted numbers for one decision cycle.  The residual watchdog's
  norm is dispatched asynchronously at enqueue time
  (``Guards.residual_async``) so the batched flush finds it already
  computed.  ``finalize`` drains the pending batch when the loop ends:

  1. **skip-step** — discard the poisoned update by restoring the batch
     window's start snapshot (equivalent to zeroed updates: params,
     optimizer moments and EF residual all revert; the batches are
     consumed).  With ``sync_every=1`` this is exactly the tripped
     step's pre-state; larger windows also discard up to
     ``sync_every - 1`` clean neighbour steps.  Heals transient
     corruption.
  2. **EF flush** — restore the snapshot AND zero the error-feedback
     residual via ``runtime.transitions`` (policy ``"flush"``, through
     ``Trainer.flush_sync`` so sharded runs settle deferred gathers
     first).  Deferred gradient mass is dropped — the report records the
     norm lost — but a diverging residual cannot be skipped away: it
     re-poisons every future flush.  Residual-watchdog trips enter the
     ladder HERE: restoring the snapshot alone would also restore the
     blown-up residual and loop forever under a persistent fault.
  3. **checkpoint rewind** — restore the last guard-owned checkpoint
     (``checkpoint.restore_train_state``; digest-verified since this PR)
     and replay from there.  Loses up to ``ckpt_every`` steps; heals
     anything the snapshot itself has absorbed (e.g. slow loss-spike
     drift older than one step).

  Skip/flush budgets are **per incident** — they reset on the first
  clean check — while the rewind budget is **per run**: a workload that
  needs a third rewind is not converging, and looping the ladder forever
  would just burn the cluster.  Exhausting the ladder raises
  :class:`RecoveryError` with the trip history attached.

Honest limits: recovery is only as good as its rollback points.  A fault
the guards cannot see (silent small-magnitude corruption) gets
checkpointed as if clean; a corrupted/lost checkpoint directory fails the
digest check and ends the run (by design — restoring garbage is worse).
Mid-run process death is NOT handled here: that is the operator-restart
path (``launch/train.py --resume``), exercised by the chaos gate's
``kill`` fault.
"""
from __future__ import annotations

import time
from typing import Any

from .faults import FaultInjector, FaultPlan, as_fault_plan
from .guards import GuardConfig, Guards, GuardTrip, as_guard_config

ACTIONS = ("skip_step", "ef_flush", "rewind")


class RecoveryError(RuntimeError):
    """The escalation ladder is exhausted (or has no rung left to climb:
    no checkpoint directory configured / no checkpoint written yet)."""

    def __init__(self, msg: str, trips: list[GuardTrip] | None = None):
        super().__init__(msg)
        self.trips = list(trips or [])


class ResilienceRuntime:
    """One per ``Trainer.run`` invocation chain (like ``AdaptiveRuntime``,
    it survives chunked runs).  Built by the trainer from
    ``run(guards=..., faults=...)``; either side may be None — guards
    without faults is the production config, faults without guards is the
    negative-control config the chaos gate uses to prove the faults are
    real."""

    def __init__(self, trainer, guards: GuardConfig | None = None,
                 faults: FaultPlan | FaultInjector | None = None,
                 telemetry=None):
        from repro.obs import as_telemetry

        self.trainer = trainer
        self.config = as_guard_config(guards)
        self.guards = Guards(self.config) if self.config is not None else None
        faults = as_fault_plan(faults)
        if isinstance(faults, FaultInjector):
            self.injector = faults
        elif faults is not None:
            self.injector = FaultInjector(faults)
        else:
            self.injector = None
        self.telemetry = as_telemetry(telemetry)
        if self.injector is not None:
            self.injector.attach_telemetry(self.telemetry)
        # rollback points: at most TWO pinned states — the current batch
        # window's start and the previous (still-unflushed) window's.
        # Pinning one state per step (the obvious design) makes the
        # TRAINING STEP itself ~40% slower: every live snapshot blocks the
        # allocator from recycling the step's buffers, so each step pays
        # fresh cold-page allocations.  (step, pre-step state) | None:
        self._win: tuple[int, dict] | None = None
        self._prev_win: tuple[int, dict] | None = None
        # deferred checks, flushed in batches of sync_every:
        # (ran, device metrics, async residual norm | None) — NO state
        # reference (same allocator argument as above)
        self._pending: list[tuple[int, dict, Any]] = []
        self._last_saved_step: int | None = None
        # ladder bookkeeping
        self._skips_used = 0       # per incident
        self._flushes_used = 0     # per incident
        self._rewinds_used = 0     # per RUN — never resets
        self.actions: list[dict] = []

    # ------------------------------------------------------------------
    def attach_telemetry(self, telemetry) -> None:
        from repro.obs import as_telemetry

        self.telemetry = as_telemetry(telemetry)
        if self.injector is not None:
            self.injector.attach_telemetry(self.telemetry)

    @property
    def _cfg(self) -> GuardConfig:
        return self.config if self.config is not None else GuardConfig()

    # ------------------------------------------------------------------
    # step bracket
    # ------------------------------------------------------------------
    def pre_step(self, state: dict, batch: Any):
        """Snapshot → guard-owned checkpoint → inject.  Returns the
        (possibly corrupted) ``(state, batch)`` the step should consume."""
        cfg = self._cfg
        step = int(state["step"])
        if (
            cfg.ckpt_dir and cfg.ckpt_every > 0
            and step % cfg.ckpt_every == 0
            and step != self._last_saved_step
        ):
            state = self._save_checkpoint(state)
        # a new batch window opens when the queue is empty (run start /
        # just recovered) or full (this post_step will flush it): pin this
        # step's pre-state as the window's rollback point (a reference,
        # not a copy — JAX arrays are immutable)
        if not self._pending or len(self._pending) >= cfg.sync_every:
            self._prev_win = self._win
            self._win = (step, state)
        if self.injector is not None:
            from .faults import InjectedCrash

            try:
                state, batch = self.injector.pre_step(state, batch, step)
            except InjectedCrash:
                # an in-process "crash": the in-flight deferred checks
                # reference a trajectory the restart will not continue
                self._pending = []
                raise
        return state, batch

    def post_step(self, state: dict, metrics: dict) -> dict:
        """Guard check + recovery, **deferred & batched**: step ``N``'s
        device scalars (and, on its cadence, an async-dispatched residual
        norm) are enqueued here; the queue is materialised in step order
        once it holds ``sync_every`` entries, by which time the async
        dispatch queue has computed them all — one blocking wake per
        batch instead of per step.  A synchronous per-step check would
        serialise host loop and device work and cost >15% of the step
        wall.  The trainer drains the final partial batch via
        :meth:`finalize`."""
        if self.guards is None:
            return state
        # flush BEFORE enqueueing the step that just dispatched: the
        # oldest batch entries are long computed, so the single blocking
        # wake waits only on the batch tail, and the in-flight step keeps
        # the device busy across it
        if len(self._pending) >= self._cfg.sync_every:
            healed = self._flush_pending(state)
            if healed is not state:
                # recovery rewound past the in-flight step too
                return healed
        # state["step"] is already advanced; guards see the step that ran
        ran = int(state["step"]) - 1
        if ran % self._cfg.check_every == 0:
            rnorm = self.guards.residual_async(ran, state.get("comp"))
            self._pending.append((ran, metrics, rnorm))
        return state

    def finalize(self, state: dict) -> dict:
        """Drain the deferred checks at the end of a run (the batched
        pipeline always leaves up to ``sync_every`` checked steps in
        flight).  May recover — the returned state can sit a few steps
        behind the loop's nominal target, but it is guarded."""
        if self.guards is None:
            self._pending = []
            return state
        return self._flush_pending(state)

    def _flush_pending(self, state: dict) -> dict:
        """Materialise the queued checks oldest-first.  On a trip the
        younger queue entries are discarded unchecked: they were computed
        from the poisoned state the trip just condemned, and recovery
        rewinds past them anyway."""
        pending, self._pending = self._pending, []
        for ran, metrics, rnorm in pending:
            host = {
                k: float(v) for k, v in metrics.items()
                if k in ("total_loss", "loss", "grad_norm")
            }
            trips = self.guards.check(
                ran, host,
                residual_value=None if rnorm is None else float(rnorm),
            )
            if not trips:
                # first clean check closes the incident: the next fault
                # gets the full skip/flush budget again (rewinds stay
                # spent)
                self._skips_used = 0
                self._flushes_used = 0
                continue
            for t in trips:
                self._emit_trip(t)
            return self._recover(ran, trips)
        return state

    # ------------------------------------------------------------------
    # the ladder
    # ------------------------------------------------------------------
    def _recover(self, step: int, trips: list[GuardTrip]) -> dict:
        cfg = self._cfg
        residual_trip = any(t.guard == "residual" for t in trips)
        if cfg.retry_backoff_s > 0.0:
            time.sleep(cfg.retry_backoff_s)

        # residual trips enter at the flush rung (skip would restore the
        # blown-up residual along with everything else)
        if not residual_trip and self._skips_used < cfg.max_skips:
            self._skips_used += 1
            return self._act("skip_step", step, self._skip(step),
                             attempt=self._skips_used,
                             detail=trips[0].reason)
        if self._flushes_used < cfg.max_flushes:
            self._flushes_used += 1
            return self._act("ef_flush", step, self._flush(step),
                             attempt=self._flushes_used,
                             detail=trips[0].reason)
        if self._rewinds_used < cfg.max_rewinds:
            restored, rewind_to = self._rewind(step, trips)
            self._rewinds_used += 1
            # a rewind opens a fresh incident at the restored step
            self._skips_used = 0
            self._flushes_used = 0
            self.guards.reset_window()
            return self._act("rewind", step, restored,
                             attempt=self._rewinds_used,
                             detail=trips[0].reason, rewind_to=rewind_to)
        raise RecoveryError(
            f"recovery ladder exhausted at step {step}: "
            f"{self._skips_used} skip(s), {self._flushes_used} flush(es), "
            f"{self._rewinds_used} rewind(s) "
            f"(last trip: {trips[0].guard}: {trips[0].reason})",
            trips=self.guards.trips,
        )

    def _skip(self, step: int) -> dict:
        """Roll back to the tightest window snapshot at or before the
        tripped step: exactly its pre-step state when ``sync_every=1``,
        else the start of the batch window it ran in (discarding up to
        ``sync_every - 1`` clean neighbours — the price of pinning only
        two rollback states, see ``__init__``)."""
        best = None
        for w in (self._prev_win, self._win):
            if w is not None and w[0] <= step:
                if best is None or w[0] > best[0]:
                    best = w
        if best is None:
            raise RecoveryError("no pre-step snapshot to skip back to")
        return best[1]

    def _flush(self, step: int) -> dict:
        from repro.runtime.transitions import carry_comp_state

        tr = self.trainer
        state = tr.flush_sync(self._skip(step))
        interval = tr.tc.interval
        comp, report = carry_comp_state(
            state["comp"], new_compressor=tr.compressor, new_plan=tr.plan,
            params_like=state["params"], step=step,
            old_interval=interval, new_interval=interval, policy="flush",
        )
        tr.transitions.append(report)
        return {**state, "comp": comp}

    def _rewind(self, step: int, trips: list[GuardTrip]) -> tuple[dict, int]:
        from repro import checkpoint

        cfg = self._cfg
        if not cfg.ckpt_dir:
            raise RecoveryError(
                f"guard trip at step {step} needs a checkpoint rewind but "
                f"GuardConfig.ckpt_dir is not set",
                trips=trips,
            )
        last = checkpoint.latest_step(cfg.ckpt_dir)
        if last is None:
            raise RecoveryError(
                f"guard trip at step {step} needs a checkpoint rewind but "
                f"{cfg.ckpt_dir!r} holds no checkpoint yet",
                trips=trips,
            )
        like = (
            self._win[1] if self._win is not None
            else self._prev_win[1] if self._prev_win is not None
            else {}
        )
        state, _extra = checkpoint.restore_train_state(cfg.ckpt_dir, like)
        return state, int(last)

    def _save_checkpoint(self, state: dict) -> dict:
        from repro import checkpoint

        cfg = self._cfg
        tr = self.trainer
        state = tr.flush_sync(state)     # sharded: persist fresh params
        path = checkpoint.save_train_state(
            cfg.ckpt_dir, state, interval=tr.tc.interval,
            extra={"guard_owned": True},
        )
        self._last_saved_step = int(state["step"])
        if self.telemetry.enabled:
            self.telemetry.events.emit(
                "checkpoint", step=int(state["step"]), path=path,
            )
        return state

    # ------------------------------------------------------------------
    # telemetry
    # ------------------------------------------------------------------
    def _emit_trip(self, t: GuardTrip) -> None:
        tel = self.telemetry
        if not tel.enabled:
            return
        import math

        tel.events.emit(
            "guard_trip", step=int(t.step), guard=t.guard, reason=t.reason,
            value=None if not math.isfinite(t.value) else float(t.value),
            threshold=(
                None if not math.isfinite(t.threshold) else float(t.threshold)
            ),
        )
        tel.registry.counter(
            "guard_trips_total", "numeric guard trips, by guard",
            guard=t.guard,
        ).inc()

    def _act(self, action: str, step: int, state: dict, *, attempt: int,
             detail: str, rewind_to: int | None = None) -> dict:
        rec = {"step": step, "action": action, "attempt": attempt,
               "detail": detail}
        if rewind_to is not None:
            rec["rewind_to"] = rewind_to
        self.actions.append(rec)
        tel = self.telemetry
        if tel.enabled:
            kw = {} if rewind_to is None else {"rewind_to": int(rewind_to)}
            tel.events.emit(
                "recovery", step=step, action=action, ok=True,
                attempt=attempt, detail=detail, **kw,
            )
            tel.registry.counter(
                "recovery_actions_total", "recovery ladder actions, by rung",
                action=action,
            ).inc()
        return state

    # ------------------------------------------------------------------
    def summary(self) -> dict:
        out = {
            "trips": len(self.guards.trips) if self.guards else 0,
            "trips_by_guard": {},
            "actions": len(self.actions),
            "actions_by_rung": {},
            "rewinds_used": self._rewinds_used,
        }
        if self.guards:
            for t in self.guards.trips:
                out["trips_by_guard"][t.guard] = (
                    out["trips_by_guard"].get(t.guard, 0) + 1
                )
        for a in self.actions:
            out["actions_by_rung"][a["action"]] = (
                out["actions_by_rung"].get(a["action"], 0) + 1
            )
        if self.injector is not None:
            out["faults"] = self.injector.summary()
        return out


__all__ = ["ACTIONS", "RecoveryError", "ResilienceRuntime"]
