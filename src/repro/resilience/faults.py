"""Seeded, deterministic fault injection (DESIGN.md §16).

A chaos run is only useful if it is *reproducible*: the same
:class:`FaultPlan` against the same seed must corrupt the same elements of
the same leaves at the same steps, so a recovery bug found in CI replays
locally.  Every corruption site is drawn from
``numpy.random.default_rng([seed, step, event_index])`` — nothing depends
on wall clock, dict order, or device layout.

Fault taxonomy (the failure classes that dominate real DP runs):

* ``grad_nan`` / ``grad_inf`` / ``grad_bitflip`` — numeric corruption of
  the values feeding the gradient computation.  The injector poisons
  ``count`` elements of the parameter tree at the step boundary; every
  gradient plane built from a poisoned operand is non-finite (NaN/Inf
  propagate through the backward pass and the packed arena planes), which
  is exactly the signal ``guards.py`` watches.  ``corrupt_planes`` applies
  the same corruption directly to packed arena planes for pipeline-level
  tests.
* ``ef_blowup`` — scales the error-feedback residual by ``scale``
  (default 1e20), modelling residual-energy divergence under a broken
  compression schedule (the failure mode GraVAC's convergence gating
  exists to prevent).
* ``ccr_skew`` — wraps the adaptive runtime's probe and adds a synthetic
  straggler delay to the measured comm time for ``times`` probes: the
  measured CCR spikes the way it does when one worker is slow, which is
  what the :class:`ReplanController`'s hysteresis + circuit breaker must
  absorb without thrashing.
* ``page_starve`` — grabs pages from a serve :class:`PagePool` and holds
  them, starving admission (``starve_pages`` / ``release_pages``).
* ``kill`` — raises :class:`InjectedCrash` at the step boundary: the
  mid-run crash that loses unflushed sharded state.  The *resume* side is
  the caller's job (``checkpoint.restore_train_state``), mirroring a real
  operator restart.

Each event fires ``times`` times total, matched by exact step number —
so a recovery that rewinds *through* a fault step replays it only while
firings remain, and a skip-step retry of the same step re-encounters the
fault until it is exhausted.  That models transient faults (fire once,
retry succeeds) and persistent ones (fire N times, forcing the recovery
ladder to escalate) with one knob.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

GRAD_FAULTS = ("grad_nan", "grad_inf", "grad_bitflip")
FAULT_KINDS = GRAD_FAULTS + ("ef_blowup", "ccr_skew", "page_starve", "kill")


class InjectedCrash(RuntimeError):
    """Raised by a ``kill`` fault: simulates the process dying mid-run."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.  ``step`` is the train-state step count the
    event matches (exactly — a rewound run re-encounters it only while
    ``times`` firings remain).  ``scale`` is the ``ef_blowup`` factor or
    the ``ccr_skew`` straggler delay in seconds; ``count`` is how many
    elements to corrupt (grad faults) or pages to hold (page_starve)."""

    step: int
    kind: str
    times: int = 1
    scale: float = 1e20
    count: int = 1

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; known: {FAULT_KINDS}"
            )


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A reproducible chaos schedule: events + the seed corruption sites
    are drawn from."""

    events: tuple[FaultEvent, ...] = ()
    seed: int = 0

    @property
    def kinds(self) -> tuple[str, ...]:
        return tuple(sorted({e.kind for e in self.events}))


def parse_fault_spec(spec: str, *, seed: int = 0) -> FaultPlan:
    """Parse the CLI fault grammar: ``kind@step[xTIMES][*SCALE]`` items,
    comma-separated — e.g. ``grad_nan@10,grad_inf@18x4,ef_blowup@14*1e12``.
    """
    events = []
    for item in spec.split(","):
        item = item.strip()
        if not item:
            continue
        if "@" not in item:
            raise ValueError(
                f"bad fault spec {item!r}: expected kind@step[xN][*SCALE]"
            )
        kind, rest = item.split("@", 1)
        scale = 1e20
        times = 1
        if "*" in rest:
            rest, s = rest.split("*", 1)
            scale = float(s)
        if "x" in rest:
            rest, t = rest.split("x", 1)
            times = int(t)
        events.append(
            FaultEvent(step=int(rest), kind=kind.strip(), times=times,
                       scale=scale)
        )
    return FaultPlan(events=tuple(events), seed=seed)


def as_fault_plan(obj) -> FaultPlan | None:
    """Coerce the user-facing ``faults=`` argument: None passes through,
    a spec string parses, a plan or live injector is used as-is."""
    if obj is None or isinstance(obj, (FaultPlan, FaultInjector)):
        return obj
    if isinstance(obj, str):
        return parse_fault_spec(obj)
    if isinstance(obj, FaultEvent):
        return FaultPlan(events=(obj,))
    if isinstance(obj, (list, tuple)) and all(
        isinstance(e, FaultEvent) for e in obj
    ):
        return FaultPlan(events=tuple(obj))
    raise TypeError(
        f"faults must be None, a spec string, FaultEvent(s), a FaultPlan "
        f"or a FaultInjector; got {type(obj).__name__}"
    )


# ---------------------------------------------------------------------------
# corruption primitives (deterministic site selection)
# ---------------------------------------------------------------------------

def _rng(seed: int, step: int, idx: int) -> np.random.Generator:
    return np.random.default_rng([int(seed) & 0x7FFFFFFF, int(step), int(idx)])


def _poison_value(kind: str, x: jax.Array, flat_idx: int,
                  rng: np.random.Generator) -> jax.Array:
    flat = x.reshape(-1)
    if kind == "grad_nan":
        v = jnp.asarray(np.nan, flat.dtype)
    elif kind == "grad_inf":
        v = jnp.asarray(np.inf, flat.dtype)
    elif kind == "grad_bitflip":
        # flip one bit of the element's binary representation — a high
        # exponent bit, so the flip is a blow-up rather than a rounding
        # wiggle (low-mantissa flips are invisible to any cheap guard and
        # are absorbed by EF like ordinary noise)
        itemsize = flat.dtype.itemsize
        uint = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}[itemsize]
        bits = np.asarray(flat[flat_idx]).view(uint)
        bit = uint(1) << uint(itemsize * 8 - 2 - int(rng.integers(0, 3)))
        v = (bits ^ bit).view(flat.dtype)
        v = jnp.asarray(v)
    else:
        raise ValueError(f"not a value-corruption kind: {kind!r}")
    return flat.at[flat_idx].set(v).reshape(x.shape)


def corrupt_tree(tree: Any, kind: str, *, seed: int, step: int,
                 count: int = 1, event_index: int = 0) -> tuple[Any, list]:
    """Corrupt ``count`` elements of a pytree's floating leaves, sites
    drawn deterministically from (seed, step, event_index).  Returns
    ``(corrupted_tree, sites)`` where each site is
    ``(leaf_index, flat_index)``."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    float_ids = [
        i for i, leaf in enumerate(leaves)
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating)
        and leaf.size > 0
    ]
    if not float_ids:
        return tree, []
    rng = _rng(seed, step, event_index)
    sizes = np.array([leaves[i].size for i in float_ids], np.float64)
    sites = []
    for _ in range(max(int(count), 1)):
        li = float_ids[int(rng.choice(len(float_ids), p=sizes / sizes.sum()))]
        fi = int(rng.integers(0, leaves[li].size))
        leaves[li] = _poison_value(kind, leaves[li], fi, rng)
        sites.append((li, fi))
    return jax.tree_util.tree_unflatten(treedef, leaves), sites


def corrupt_planes(planes: Sequence[jax.Array], kind: str, *, seed: int,
                   step: int, count: int = 1) -> tuple[list[jax.Array], list]:
    """The same corruption applied directly to packed gradient arena
    planes (``core.arena.ArenaLayout.assemble`` output) — the unit-level
    form the plane guards are tested against."""
    planes = list(planes)
    out, sites = corrupt_tree(planes, kind, seed=seed, step=step, count=count)
    return list(out), sites


def blowup_residual(comp_state: Any, scale: float) -> Any:
    """Scale every floating leaf of a compressor state (the EF residual)
    by ``scale`` — the residual-energy divergence fault."""
    return jax.tree.map(
        lambda r: (r.astype(jnp.float32) * jnp.float32(scale)).astype(r.dtype)
        if hasattr(r, "dtype") and jnp.issubdtype(r.dtype, jnp.floating)
        else r,
        comp_state,
    )


# ---------------------------------------------------------------------------
# serve-side starvation
# ---------------------------------------------------------------------------

def starve_pages(pool, n: int | None = None) -> list[int]:
    """Allocate-and-hold ``n`` pages (default: all available) from a serve
    :class:`~repro.serve.kv_arena.PagePool`.  Returns the held page ids —
    pass them to :func:`release_pages` to end the fault."""
    n = pool.available if n is None else min(int(n), pool.available)
    held = pool.alloc(n) if n > 0 else []
    return held or []


def release_pages(pool, held: list[int]) -> None:
    if held:
        pool.free(held)


# ---------------------------------------------------------------------------
# the injector
# ---------------------------------------------------------------------------

class FaultInjector:
    """Applies a :class:`FaultPlan` at step boundaries.

    ``pre_step(state, batch, step)`` fires every event whose ``step``
    matches and whose firing budget remains, returning the (possibly
    corrupted) state/batch; ``kill`` events raise :class:`InjectedCrash`
    instead.  ``wrap_probe`` decorates an adaptive-runtime probe so
    ``ccr_skew`` events inflate its measured comm time.  All telemetry
    goes through the bundle handed in by the resilience runtime."""

    def __init__(self, plan: FaultPlan, telemetry=None):
        from repro.obs import as_telemetry

        self.plan = plan
        self.telemetry = as_telemetry(telemetry)
        self.fired = [0] * len(plan.events)
        self.log: list[dict] = []

    def attach_telemetry(self, telemetry) -> None:
        from repro.obs import as_telemetry

        self.telemetry = as_telemetry(telemetry)

    def _record(self, step: int, event: FaultEvent, detail: dict) -> None:
        rec = {"step": int(step), "fault": event.kind, **detail}
        self.log.append(rec)
        tel = self.telemetry
        if tel.enabled:
            tel.events.emit(
                "fault_injected", step=int(step), fault=event.kind,
                detail=detail,
            )
            tel.registry.counter(
                "faults_injected_total", "chaos faults fired, by kind",
                kind=event.kind,
            ).inc()

    def pre_step(self, state: dict, batch: Any, step: int):
        """Fire every due event against this step's inputs.  Must be
        called AFTER the caller snapshots its clean pre-step state — the
        whole point of skip-step recovery is that the snapshot predates
        the corruption."""
        for i, ev in enumerate(self.plan.events):
            if ev.step != int(step) or self.fired[i] >= ev.times:
                continue
            if ev.kind == "ccr_skew":
                continue        # consumed by wrap_probe, not the step path
            self.fired[i] += 1
            if ev.kind == "kill":
                self._record(step, ev, {"firing": self.fired[i]})
                raise InjectedCrash(f"injected kill at step {step}")
            if ev.kind in GRAD_FAULTS:
                params, sites = corrupt_tree(
                    state["params"], ev.kind, seed=self.plan.seed,
                    step=step, count=ev.count, event_index=i,
                )
                state = {**state, "params": params}
                self._record(step, ev, {
                    "firing": self.fired[i],
                    "sites": [[li, fi] for li, fi in sites],
                })
            elif ev.kind == "ef_blowup":
                state = {**state, "comp": blowup_residual(state["comp"],
                                                          ev.scale)}
                self._record(step, ev, {
                    "firing": self.fired[i], "scale": ev.scale,
                })
        return state, batch

    # ---- probe skew -------------------------------------------------------
    def wrap_probe(self, probe: Callable) -> Callable:
        """Decorate a ``probe(state, batch, phase) -> PhaseSample`` so due
        ``ccr_skew`` events add their synthetic straggler delay to the
        sample's comm time (the slow-worker tail every collective waits
        on).  Each event fires on ``times`` consecutive probes starting at
        its ``step``th probe call (probe calls are the natural clock here
        — the probe cadence, not the step cadence, is what the controller
        sees)."""
        calls = [0]

        def skewed(state, batch, phase):
            sample = probe(state, batch, phase)
            n = calls[0]
            calls[0] += 1
            delay = 0.0
            for i, ev in enumerate(self.plan.events):
                if ev.kind != "ccr_skew":
                    continue
                if ev.step <= n and self.fired[i] < ev.times:
                    self.fired[i] += 1
                    delay += float(ev.scale)
                    self._record(n, ev, {
                        "firing": self.fired[i], "delay_s": float(ev.scale),
                    })
            if delay > 0.0:
                sample = dataclasses.replace(
                    sample, t_comm=sample.t_comm + delay,
                    t_full=(sample.t_full + delay
                            if sample.t_full > 0.0 else sample.t_full),
                )
            return sample

        return skewed

    def summary(self) -> dict:
        return {
            "events": len(self.plan.events),
            "fired": int(sum(self.fired)),
            "by_kind": {
                k: sum(
                    f for f, e in zip(self.fired, self.plan.events)
                    if e.kind == k
                )
                for k in self.plan.kinds
            },
        }


__all__ = [
    "FAULT_KINDS",
    "FaultEvent",
    "FaultInjector",
    "FaultPlan",
    "GRAD_FAULTS",
    "InjectedCrash",
    "as_fault_plan",
    "blowup_residual",
    "corrupt_planes",
    "corrupt_tree",
    "parse_fault_spec",
    "release_pages",
    "starve_pages",
]
