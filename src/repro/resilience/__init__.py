"""Resilience subsystem (DESIGN.md §16): deterministic fault injection,
cheap numeric guardrails, and an escalating auto-recovery ladder wired
through ``Trainer.run(guards=..., faults=...)`` and ``api.fit``.

Production entry point::

    from repro.resilience import GuardConfig
    tr.run(state, batches, guards=GuardConfig(ckpt_dir="ckpt", ckpt_every=50))

Chaos entry point (reproducible — same plan + seed, same corruption)::

    tr.run(state, batches, guards=True, faults="grad_nan@10,ef_blowup@20")
"""
from .faults import (
    FAULT_KINDS,
    FaultEvent,
    FaultInjector,
    FaultPlan,
    GRAD_FAULTS,
    InjectedCrash,
    as_fault_plan,
    blowup_residual,
    corrupt_planes,
    corrupt_tree,
    parse_fault_spec,
    release_pages,
    starve_pages,
)
from .guards import (
    GUARD_KINDS,
    GuardConfig,
    GuardTrip,
    Guards,
    as_guard_config,
    plane_nonfinite_counts,
)
from .recovery import ACTIONS, RecoveryError, ResilienceRuntime

__all__ = [
    "ACTIONS",
    "FAULT_KINDS",
    "FaultEvent",
    "FaultInjector",
    "FaultPlan",
    "GRAD_FAULTS",
    "GUARD_KINDS",
    "GuardConfig",
    "GuardTrip",
    "Guards",
    "InjectedCrash",
    "RecoveryError",
    "ResilienceRuntime",
    "as_fault_plan",
    "as_guard_config",
    "blowup_residual",
    "corrupt_planes",
    "corrupt_tree",
    "parse_fault_spec",
    "plane_nonfinite_counts",
    "release_pages",
    "starve_pages",
]
