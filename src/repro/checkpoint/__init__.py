"""Checkpointing: pytree <-> npz + JSON manifest, sharding-aware on restore.

``save_train_state`` / ``restore_train_state`` round-trip the full trainer
state including compressor (error-feedback) residuals.
"""
from .store import (
    latest_step,
    load_extra,
    restore,
    restore_train_state,
    save,
    save_train_state,
)

__all__ = [
    "save",
    "restore",
    "latest_step",
    "load_extra",
    "save_train_state",
    "restore_train_state",
]
