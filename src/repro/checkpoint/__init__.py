"""Checkpointing: pytree <-> npz + JSON manifest, sharding-aware on restore.

``save_train_state`` / ``restore_train_state`` round-trip the full trainer
state including compressor (error-feedback) residuals.  Saves are atomic
(temp dir + rename) and digest-verified on restore — a corrupted or
partial checkpoint raises :class:`CheckpointCorruptError` instead of
deserializing garbage.
"""
from .store import (
    CheckpointCorruptError,
    latest_step,
    load_extra,
    restore,
    restore_train_state,
    save,
    save_train_state,
    verify,
)

__all__ = [
    "CheckpointCorruptError",
    "save",
    "restore",
    "verify",
    "latest_step",
    "load_extra",
    "save_train_state",
    "restore_train_state",
]
