"""Checkpointing: pytree <-> npz + JSON manifest, sharding-aware on restore."""
from .store import latest_step, restore, save

__all__ = ["save", "restore", "latest_step"]
