"""Pytree checkpointing without external deps.

Layout: ``<dir>/step_<N>/arrays.npz`` + ``manifest.json`` (treedef as
keypath strings, dtypes, shapes).  Arrays are gathered to host on save; on
restore they are placed back with the caller's shardings (pass
``shardings=`` a matching pytree of NamedSharding, or None for host).
bf16 is round-tripped through a uint16 view (npz has no bfloat16).

``save_train_state`` / ``restore_train_state`` round-trip the trainer's
full state dict — params, optimizer state **and compressor state** (the
error-feedback residual is deferred gradient mass; dropping it at a
restart silently loses the paper's accuracy guarantee).  The manifest's
``extra`` dict records the interval the residual was accumulated under,
so a restart into a re-planned interval can route through
``runtime.transitions`` instead of assuming the cadence matched.

Crash safety (DESIGN.md §16): a checkpoint is the recovery ladder's last
rung, so a half-written one is worse than none.  ``save`` therefore
writes into a dot-prefixed sibling directory (invisible to
``latest_step``'s ``step_(\\d+)`` scan) and publishes it with one atomic
``os.replace`` — a crash mid-save leaves either the previous checkpoint
or a stray temp dir, never a readable-but-partial ``step_<N>``.  The
manifest records a SHA-256 digest of ``arrays.npz``; ``restore`` verifies
it before deserializing and raises :class:`CheckpointCorruptError` —
deliberately NOT a ``ValueError``, so ``restore_train_state``'s
comp-structure-drift fallback cannot swallow at-rest corruption — on any
mismatch, truncation, or missing payload.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "::"


class CheckpointCorruptError(RuntimeError):
    """The checkpoint on disk fails integrity checks (digest mismatch,
    truncated/missing array payload).  Restoring it would deserialize
    garbage into live training state — callers should treat the
    checkpoint as lost, not retry."""


def _digest_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return "sha256:" + h.hexdigest()


def _flatten(tree: Any) -> dict[str, jax.Array]:
    out = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        out[jax.tree_util.keystr(path)] = leaf
    return out


def save(directory: str, step: int, tree: Any, extra: dict | None = None) -> str:
    d = os.path.join(directory, f"step_{step:08d}")
    # stage into a dot-prefixed sibling (latest_step's regex skips it),
    # publish with one atomic rename: a crash mid-save can never leave a
    # readable-but-partial step_<N> for the recovery ladder to trust
    tmp = os.path.join(directory, f".tmp_step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    arrays, manifest = {}, {}
    for i, (key, leaf) in enumerate(sorted(flat.items())):
        arr = np.asarray(jax.device_get(leaf))
        name = f"a{i}"
        if arr.dtype == jnp.bfloat16:
            arrays[name] = arr.view(np.uint16)
            manifest[key] = {"name": name, "dtype": "bfloat16", "shape": arr.shape}
        else:
            arrays[name] = arr
            manifest[key] = {
                "name": name,
                "dtype": str(arr.dtype),
                "shape": arr.shape,
            }
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    digest = _digest_file(os.path.join(tmp, "arrays.npz"))
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(
            {"step": step, "leaves": manifest, "digest": digest,
             "extra": dict(extra or {})}, f
        )
    # os.replace needs the target gone (non-empty dirs don't replace);
    # removing a complete old copy before the rename keeps the invariant:
    # step_<N> is either absent or whole
    if os.path.exists(d):
        shutil.rmtree(d)
    os.replace(tmp, d)
    return d


def load_extra(directory: str, step: int) -> dict:
    """The ``extra`` metadata dict stored alongside a checkpoint."""
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        return json.load(f).get("extra", {})


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(m.group(1))
        for m in (re.match(r"step_(\d+)$", n) for n in os.listdir(directory))
        if m
    ]
    return max(steps) if steps else None


def verify(directory: str, step: int) -> str | None:
    """Integrity-check one checkpoint's array payload against the digest
    in its manifest.  Returns the digest (None for pre-digest checkpoints,
    which carry nothing to verify); raises :class:`CheckpointCorruptError`
    on mismatch or a missing payload."""
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        recorded = json.load(f).get("digest")
    npz = os.path.join(d, "arrays.npz")
    if not os.path.exists(npz):
        raise CheckpointCorruptError(
            f"checkpoint {d} has a manifest but no arrays.npz — partial "
            f"write or deleted payload; treat this checkpoint as lost"
        )
    if recorded is None:
        return None
    actual = _digest_file(npz)
    if actual != recorded:
        raise CheckpointCorruptError(
            f"checkpoint {d} is corrupted: arrays.npz digest {actual} does "
            f"not match the manifest's {recorded}; refusing to deserialize"
        )
    return recorded


def restore(directory: str, step: int, like: Any, shardings: Any = None) -> Any:
    d = os.path.join(directory, f"step_{step:08d}")
    verify(directory, step)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)["leaves"]
    data = np.load(os.path.join(d, "arrays.npz"))

    paths_and_leaves = jax.tree_util.tree_leaves_with_path(like)
    treedef = jax.tree_util.tree_structure(like)
    shard_leaves = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None else None
    )
    out = []
    for i, (path, leaf) in enumerate(paths_and_leaves):
        key = jax.tree_util.keystr(path)
        if key not in manifest:
            raise KeyError(f"checkpoint missing leaf {key}")
        meta = manifest[key]
        arr = data[meta["name"]]
        if meta["dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs model {leaf.shape}"
            )
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# full train-state round trip (params + opt + compressor/EF state)
# ---------------------------------------------------------------------------

_STATE_KEYS = ("params", "opt", "comp")


def save_train_state(
    directory: str, state: dict, *, interval: int | None = None,
    extra: dict | None = None,
) -> str:
    """Persist a trainer state dict (``params``/``opt``/``comp``/``step``).

    The compressor state — the EF residual for COVAP-family schemes — is a
    first-class part of the checkpoint: it is exactly the gradient mass the
    filter has deferred, so a restart that drops it replays the paper's
    no-EF ablation for one interval.  ``interval`` (and anything in
    ``extra``) lands in the manifest for restart-time validation."""
    meta = dict(extra or {})
    if interval is not None:
        meta["interval"] = int(interval)
    meta["has_comp_state"] = bool(
        jax.tree_util.tree_leaves(state.get("comp", ()))
    )
    tree = {k: state[k] for k in _STATE_KEYS if k in state}
    return save(directory, int(state["step"]), tree, extra=meta)


def restore_train_state(
    directory: str, like_state: dict, *, step: int | None = None,
) -> tuple[dict, dict]:
    """Restore a trainer state dict saved by :func:`save_train_state`.

    ``like_state`` is a freshly-initialised ``Trainer.init_state(...)``
    providing structure/shapes (including the compressor state — so EF
    residuals restore to real values, not zeros).  Returns
    ``(state, extra)``; ``extra`` carries the saved interval so callers can
    re-plan (``runtime.transitions``) when the restart config drifted.

    The compressor state is restored **leaf-compatibly**: when the saved
    and current structures differ (EF on one side of an ``I = 1`` restart
    only, or a different state family), params/opt still restore and the
    compressor state keeps its fresh initialisation —
    ``extra["comp_restored"]`` is False so callers can warn about the
    dropped residual instead of crashing on a manifest mismatch."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory!r}")
    like = {k: like_state[k] for k in _STATE_KEYS if k in like_state}
    extra = load_extra(directory, step)
    try:
        tree = restore(directory, step, like)
        # restore() validates key-by-key, but a saved residual restored
        # into a like-state with NO comp leaves succeeds trivially — catch
        # that silent-drop direction via the save-time marker (absent for
        # checkpoints not written by save_train_state: assume compatible)
        like_has = bool(jax.tree_util.tree_leaves(like.get("comp", ())))
        comp_restored = like_has == bool(
            extra.get("has_comp_state", like_has)
        )
    except (KeyError, ValueError):
        # comp structure drifted (EF on/off, different state family):
        # params/opt still restore, the compressor state stays fresh
        tree = restore(
            directory, step, {k: v for k, v in like.items() if k != "comp"}
        )
        comp_restored = False
    state = dict(like_state)
    state.update(tree)
    state["step"] = int(step)
    extra["comp_restored"] = comp_restored or "comp" not in like_state
    return state, extra
