"""Pytree checkpointing without external deps.

Layout: ``<dir>/step_<N>/arrays.npz`` + ``manifest.json`` (treedef as
keypath strings, dtypes, shapes).  Arrays are gathered to host on save; on
restore they are placed back with the caller's shardings (pass
``shardings=`` a matching pytree of NamedSharding, or None for host).
bf16 is round-tripped through a uint16 view (npz has no bfloat16).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "::"


def _flatten(tree: Any) -> dict[str, jax.Array]:
    out = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        out[jax.tree_util.keystr(path)] = leaf
    return out


def save(directory: str, step: int, tree: Any) -> str:
    d = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    flat = _flatten(tree)
    arrays, manifest = {}, {}
    for i, (key, leaf) in enumerate(sorted(flat.items())):
        arr = np.asarray(jax.device_get(leaf))
        name = f"a{i}"
        if arr.dtype == jnp.bfloat16:
            arrays[name] = arr.view(np.uint16)
            manifest[key] = {"name": name, "dtype": "bfloat16", "shape": arr.shape}
        else:
            arrays[name] = arr
            manifest[key] = {
                "name": name,
                "dtype": str(arr.dtype),
                "shape": arr.shape,
            }
    np.savez(os.path.join(d, "arrays.npz"), **arrays)
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump({"step": step, "leaves": manifest}, f)
    return d


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(m.group(1))
        for m in (re.match(r"step_(\d+)$", n) for n in os.listdir(directory))
        if m
    ]
    return max(steps) if steps else None


def restore(directory: str, step: int, like: Any, shardings: Any = None) -> Any:
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)["leaves"]
    data = np.load(os.path.join(d, "arrays.npz"))

    paths_and_leaves = jax.tree_util.tree_leaves_with_path(like)
    treedef = jax.tree_util.tree_structure(like)
    shard_leaves = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None else None
    )
    out = []
    for i, (path, leaf) in enumerate(paths_and_leaves):
        key = jax.tree_util.keystr(path)
        if key not in manifest:
            raise KeyError(f"checkpoint missing leaf {key}")
        meta = manifest[key]
        arr = data[meta["name"]]
        if meta["dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs model {leaf.shape}"
            )
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
