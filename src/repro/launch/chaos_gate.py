"""The chaos-recovery gate: a multi-worker train run under injected
faults must heal itself through every rung of the recovery ladder
(DESIGN.md §16).

Shared harness for the ``benchmarks.run --smoke`` "chaos" gate and ad-hoc
runs — execute it in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the CPU backend
has a real 8-worker mesh for the compressor's collectives:

    python -m repro.launch.chaos_gate

The scenario (reduced gpt2-paper, covap ``I=2``):

* ``grad_nan@6`` — transient NaN in the params: nonfinite guard trips,
  **skip-step** restores the pre-corruption snapshot;
* ``ef_blowup@10`` — the EF residual scaled past the watchdog limit:
  residual guard trips and enters the ladder at **ef-flush** (skip would
  restore the blown residual along with everything else);
* ``grad_inf@14x3`` — a persistent fault that survives three
  re-encounters: the per-incident skip and flush budgets drain, forcing a
  **checkpoint rewind**;
* ``kill@17`` — an injected crash: the driver catches
  :class:`~repro.resilience.InjectedCrash`, restores the latest
  guard-owned checkpoint, and resumes with the SAME
  :class:`~repro.resilience.ResilienceRuntime` (so spent fault budgets
  persist and the kill does not re-fire on replay).

Prints one ``CHAOS ...`` line and exits non-zero unless the healed run
ends with a finite loss, all three rungs were exercised, and every
trip/action/firing is visible in telemetry (events 1:1 with counters).
"""
from __future__ import annotations

import json
import math
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

FAULT_SPEC = "grad_nan@6,ef_blowup@10,grad_inf@14x3,kill@17"
TOTAL_STEPS = 20


def run_chaos(td: str) -> dict:
    """Run the kill+resume chaos scenario; returns the summary dict the
    gate asserts over.  ``td`` holds the checkpoint dir and telemetry."""
    from jax.sharding import Mesh

    from repro import checkpoint
    from repro.configs import get_reduced
    from repro.data import DataConfig, make_loader
    from repro.models import build_model
    from repro.obs import Telemetry, validate_event
    from repro.optim import adamw
    from repro.resilience import GuardConfig, InjectedCrash
    from repro.train.trainer import TrainConfig, Trainer

    devs = jax.devices()
    mesh = Mesh(np.array(devs[: min(8, len(devs))]), ("data",))
    cfg = get_reduced("gpt2-paper").with_(vocab_size=256)
    model = build_model(cfg)
    tc = TrainConfig(compressor="covap", interval=2, bucket_bytes=1 << 14,
                     max_buckets=16, log_every=1000)
    tr = Trainer(model, adamw(3e-3), tc, mesh=mesh, dp_axes=("data",))
    state = tr.init_state(jax.random.PRNGKey(0))
    dc = DataConfig(vocab_size=256, seq_len=16,
                    global_batch=mesh.shape["data"], corpus_tokens=1 << 12)
    loader = iter(make_loader(dc))

    tel = Telemetry(os.path.join(td, "tel"))
    ck = os.path.join(td, "ck")
    g = GuardConfig(ckpt_dir=ck, ckpt_every=6, residual_check_every=2,
                    max_skips=1, max_flushes=1,
                    sync_every=1)   # strict lag-one: the FAULT_SPEC /
    #   TOTAL_STEPS schedule below is step-exact (kill@17 must be reached
    #   inside the budget); batched-sync semantics are covered by
    #   tests/test_resilience.py::test_batched_sync_detection_and_recovery

    # run-until-target: ``steps`` counts loop iterations and every
    # recovery rung consumes one without advancing the step counter, so a
    # single run call would fall short of the kill step.  Each pass tops
    # the budget back up; fault budgets (``times``) bound the loop.
    resumed_from = -1
    while int(state["step"]) < TOTAL_STEPS:
        try:
            state = tr.run(
                state, loader, steps=TOTAL_STEPS - int(state["step"]),
                log=None, telemetry=tel,
                guards=tr.resilience if tr.resilience is not None else g,
                faults=None if tr.resilience is not None else FAULT_SPEC,
            )
        except InjectedCrash:
            # the driver half of kill-fault recovery: restore the latest
            # guard-owned checkpoint and resume with the same runtime
            # (its injector remembers the kill already fired)
            like = tr.init_state(jax.random.PRNGKey(1))
            state, _extra = checkpoint.restore_train_state(ck, like)
            resumed_from = int(state["step"])

    # finite loss through the trainer's own compiled executable
    fn = tr._phase_fn(int(state["step"]) % tr.num_phases)
    _, _, _, m = fn(state["params"], state["opt"], state["comp"],
                    next(loader), jnp.asarray(state["step"], jnp.int32))
    loss = float(m["total_loss"])

    summary = tr.resilience.summary()
    tel.save()
    tel.close()

    by_kind: dict[str, int] = {}
    with open(os.path.join(td, "tel", "events.jsonl")) as f:
        for lineno, line in enumerate(f, 1):
            ev = json.loads(line)
            errs = validate_event(ev)
            if errs:
                raise AssertionError(
                    f"chaos gate: events.jsonl:{lineno} invalid "
                    f"{ev.get('kind')!r} event: {errs}"
                )
            by_kind[ev["kind"]] = by_kind.get(ev["kind"], 0) + 1
    snap = tel.registry.snapshot()

    def counted(prefix: str) -> int:
        return int(sum(v for k, v in snap.items() if k.startswith(prefix)))

    return {
        "loss": loss,
        "resumed_from": resumed_from,
        "final_step": int(state["step"]),
        "summary": summary,
        "events": by_kind,
        "counters": {
            "guard_trips_total": counted("guard_trips_total"),
            "recovery_actions_total": counted("recovery_actions_total"),
            "faults_injected_total": counted("faults_injected_total"),
        },
    }


def main() -> None:
    with tempfile.TemporaryDirectory() as td:
        out = run_chaos(td)
    s = out["summary"]
    rungs = s["actions_by_rung"]
    ok = (
        math.isfinite(out["loss"])
        and out["resumed_from"] >= 0                      # kill+resume ran
        and s["faults"]["by_kind"].get("kill", 0) == 1
        and out["final_step"] == TOTAL_STEPS
        and set(rungs) == {"skip_step", "ef_flush", "rewind"}
        and out["events"].get("guard_trip", 0)
        == out["counters"]["guard_trips_total"] == s["trips"]
        and out["events"].get("recovery", 0)
        == out["counters"]["recovery_actions_total"] == s["actions"]
        and out["events"].get("fault_injected", 0)
        == out["counters"]["faults_injected_total"] == s["faults"]["fired"]
    )
    print(
        "CHAOS loss=%.4f resumed_from=%d trips=%d actions=%d "
        "rungs=%s faults_fired=%d events_ok=%d"
        % (out["loss"], out["resumed_from"], s["trips"], s["actions"],
           ",".join(f"{k}:{v}" for k, v in sorted(rungs.items())),
           s["faults"]["fired"], int(ok))
    )
    if not ok:
        raise SystemExit(f"chaos gate failed: {out}")


if __name__ == "__main__":
    main()
