"""Aggregate dry-run JSONs into the SSRoofline table (markdown + CSV).

    python -m repro.launch.roofline_report --dir experiments/dryrun \
        --mesh pod1 --md experiments/roofline.md
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dir_: str) -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def fmt_ms(s):
    return f"{s*1e3:.2f}"


def one_liner(rec: dict) -> str:
    """'What would move the dominant term down' — rule-based suggestion."""
    r = rec.get("roofline", {})
    dom = r.get("dominant")
    kind = rec.get("kind")
    if dom == "collective":
        if kind == "train":
            return "raise COVAP interval / larger buckets to cut sync volume"
        return "reshard weights to cut per-step weight gathers"
    if dom == "memory":
        if kind == "decode":
            return "shrink KV reads: wider GQA sharding or quantized cache"
        return "fuse elementwise chains; bf16 activations to cut HBM traffic"
    return "MXU-align matmul tiles; raise arithmetic intensity per pass"


def table(recs: list[dict], mesh: str) -> str:
    rows = [r for r in recs if r.get("mesh") == mesh and r.get("status") == "ok"]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = [
        "| arch | shape | dom | compute ms | memory ms | collective ms | "
        "useful_flops | note |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        rf = r["roofline"]
        ratio = rf.get("useful_flops_ratio")
        out.append(
            f"| {r['arch']} | {r['shape']} | {rf['dominant'][:4]} | "
            f"{fmt_ms(rf['compute_s'])} | {fmt_ms(rf['memory_s'])} | "
            f"{fmt_ms(rf['collective_s'])} | "
            f"{ratio:.2f} | {one_liner(r)} |"
            if ratio is not None else
            f"| {r['arch']} | {r['shape']} | {rf['dominant'][:4]} | "
            f"{fmt_ms(rf['compute_s'])} | {fmt_ms(rf['memory_s'])} | "
            f"{fmt_ms(rf['collective_s'])} | n/a | {one_liner(r)} |"
        )
    return "\n".join(out)


def pick_hillclimb(recs: list[dict], mesh: str = "16x16") -> dict:
    """worst roofline fraction, most collective-bound, most COVAP-representative."""
    rows = [r for r in recs if r.get("mesh") == mesh and r.get("status") == "ok"]

    def frac(r):
        rf = r["roofline"]
        bound = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        return rf["compute_s"] / bound if bound else 0.0

    worst = min(rows, key=frac)
    coll = max(rows, key=lambda r: r["roofline"]["collective_s"] /
               max(r["roofline"]["compute_s"] + r["roofline"]["memory_s"], 1e-12))
    train = [r for r in rows if r["kind"] == "train"]
    rep = max(train, key=lambda r: r["roofline"]["collective_s"]) if train else None
    return {
        "worst_roofline_fraction": f"{worst['arch']}/{worst['shape']}",
        "most_collective_bound": f"{coll['arch']}/{coll['shape']}",
        "most_representative": f"{rep['arch']}/{rep['shape']}" if rep else None,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--md", default="")
    args = ap.parse_args()
    recs = load(args.dir)
    parts = []
    for mesh in ("16x16", "2x16x16"):
        parts.append(f"### Mesh {mesh}\n\n" + table(recs, mesh) + "\n")
    parts.append("### Hillclimb candidates (single-pod)\n")
    parts.append("```json\n" + json.dumps(pick_hillclimb(recs), indent=1) + "\n```")
    text = "\n".join(parts)
    if args.md:
        with open(args.md, "w") as f:
            f.write(text)
    print(text)


if __name__ == "__main__":
    main()
