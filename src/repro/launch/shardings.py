"""Sharding assignment for the dry-run / production launchers.

Training:  params/opt/EF-residuals sharded over 'model' (TP), replicated
over the DP axes (the COVAP psums run there).  Batch over DP axes.

Serving:   no gradients -> weights are sharded over ('model','data') [+
'pod' for batch-1 long-context] so the full fleet's HBM holds them; KV
caches shard batch over the DP axes and kv-heads/head-dim over 'model';
batch-1 long-context shards the cache's *sequence* axis over 'data'.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import build_param_specs


def as_named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _axis_size(mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    n = 1
    for a in names:
        n *= mesh.shape[a]
    return n


def train_param_specs(model, mesh):
    return build_param_specs(
        model.cfg, model.init, _axis_size(mesh, "model"), "model"
    )


def serve_param_specs(model, mesh, *, include_pod_in_weights: bool = False):
    axes = ("model", "data", "pod") if include_pod_in_weights else ("model", "data")
    axes = tuple(a for a in axes if a in mesh.shape)
    sizes = tuple(mesh.shape[a] for a in axes)
    return build_param_specs(
        model.cfg, model.init, _axis_size(mesh, axes), axes, axis_sizes=sizes
    )


def opt_state_specs(opt_state_shapes: dict, param_specs) -> dict:
    """Optimizer moments mirror the parameter shardings."""
    out = {}
    for k, v in opt_state_shapes.items():
        if k == "step" or v == ():
            out[k] = P() if k == "step" else ()
        else:
            out[k] = param_specs
    return out


def comp_state_specs(comp_state_shapes, param_shapes, param_specs):
    """EF residuals mirror params; anything else is replicated."""
    if comp_state_shapes == ():
        return ()
    same = jax.tree_util.tree_structure(
        comp_state_shapes
    ) == jax.tree_util.tree_structure(param_shapes)
    if same:
        return param_specs
    return jax.tree.map(lambda _: P(), comp_state_shapes)


def batch_specs(batch_sds: dict, mesh, dp_axes: Sequence[str]) -> dict:
    dp = tuple(dp_axes)
    world = _axis_size(mesh, dp)

    def one(sds):
        if sds.shape and sds.shape[0] % world == 0 and world > 1:
            return P(dp)
        # try pod-only for small batches on the multi-pod mesh
        if (
            "pod" in mesh.shape
            and sds.shape
            and sds.shape[0] % mesh.shape["pod"] == 0
        ):
            return P(("pod",))
        return P()

    return jax.tree.map(one, batch_sds)


def cache_specs_tree(cache_sds, cfg, mesh, dp_axes: Sequence[str], batch: int):
    """Heuristic KV/state cache shardings (see module docstring)."""
    dp = tuple(a for a in dp_axes if a in mesh.shape)
    dp_world = _axis_size(mesh, dp)
    model_world = mesh.shape.get("model", 1)
    kv = cfg.num_kv_heads
    hd = cfg.head_dim
    heads = cfg.num_heads

    def one(sds):
        shape = sds.shape
        spec = [None] * len(shape)
        batch_done = False
        for ax, dim in enumerate(shape):
            if ax == 0:
                continue  # stacked layer axis
            if not batch_done and dim == batch:
                if batch % dp_world == 0 and dp_world > 1:
                    spec[ax] = dp
                elif "pod" in mesh.shape and batch % mesh.shape["pod"] == 0 and mesh.shape["pod"] > 1:
                    spec[ax] = ("pod",)
                batch_done = True
                continue
        # shard kv-heads (or head_dim) over 'model'
        for ax in range(len(shape) - 1, 0, -1):
            if spec[ax] is None and shape[ax] in (kv, heads) and shape[ax] % model_world == 0:
                spec[ax] = "model"
                break
        else:
            for ax in range(len(shape) - 1, 0, -1):
                if spec[ax] is None and shape[ax] == hd and hd % model_world == 0:
                    spec[ax] = "model"
                    break
        # batch-1 long context: shard the longest (sequence) axis over 'data'
        if batch == 1 and "data" in mesh.shape:
            seq_ax = max(
                (ax for ax in range(1, len(shape)) if spec[ax] is None),
                key=lambda ax: shape[ax],
                default=None,
            )
            if (
                seq_ax is not None
                and shape[seq_ax] >= 4096
                and shape[seq_ax] % mesh.shape["data"] == 0
            ):
                spec[seq_ax] = "data"
        return P(*spec)

    return jax.tree.map(one, cache_sds)
