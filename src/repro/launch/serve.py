"""Serving driver: batched requests through the slot engine.

    python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
        --requests 16 --slots 4 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.models import build_model
from repro.serve import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-paper")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    eng = Engine(
        model, params,
        ServeConfig(batch_slots=args.slots, max_len=args.max_len,
                    max_new_tokens=args.max_new,
                    temperature=args.temperature),
    )
    rng = np.random.default_rng(args.seed)
    rids = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=rng.integers(2, args.prompt_len + 1)).tolist()
        rids.append((eng.submit(prompt), prompt))

    t0 = time.perf_counter()
    steps = 0
    while eng.busy:
        eng.step()
        steps += 1
    wall = time.perf_counter() - t0
    total_new = sum(len(eng.results[r]) for r, _ in rids)
    print(f"[serve] {args.requests} requests, {steps} engine steps, "
          f"{wall:.2f}s, {total_new/wall:.1f} tok/s")
    for rid, prompt in rids[:4]:
        print(f"  req {rid}: prompt={prompt[:6]}... -> {eng.results[rid][:8]}")


if __name__ == "__main__":
    main()
