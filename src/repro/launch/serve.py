"""Serving driver: continuous batching over the paged KV arena.

Batch mode (submit everything, drain, print stage metrics):

    python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
        --requests 16 --slots 4 --max-new 16

Traffic mode (Poisson arrivals at --qps, latency percentiles):

    python -m repro.launch.serve --arch qwen1.5-0.5b --reduced --qps 16

Sweep mode (arrival-rate sweep -> saturation table):

    python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
        --sweep 2,8,32,128
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.models import build_model
from repro.serve import Engine, ServeConfig, TrafficConfig, run_traffic, sweep


def _print_report(rep) -> None:
    print(f"[serve] qps={rep.qps:<7g} n={rep.num_requests:<4d} "
          f"p50={rep.p50_ms:8.1f}ms p99={rep.p99_ms:8.1f}ms "
          f"ttft_p50={rep.ttft_p50_ms:7.1f}ms "
          f"tok/s={rep.tokens_per_s:7.1f} reasons={rep.finish_reasons}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-paper")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="page pool size (0 = every slot can run full-length)")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="prompt tokens per compiled prefill call")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--qps", type=float, default=0.0,
                    help="Poisson arrival rate; 0 = batch mode")
    ap.add_argument("--sweep", default="",
                    help="comma-separated qps list, e.g. 2,8,32,128")
    ap.add_argument("--telemetry-dir", default="",
                    help="arm the unified telemetry subsystem (repro.obs): "
                         "per-request spans, stage histograms and queue/"
                         "page-pool series into this directory")
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    telemetry = None
    if args.telemetry_dir:
        from repro.obs import Telemetry

        telemetry = Telemetry(args.telemetry_dir)
        telemetry.manifest_once(
            role="serve", config=vars(args), plan={}, world=1,
        )
    eng = Engine(
        model, params,
        ServeConfig(batch_slots=args.slots, max_len=args.max_len,
                    max_new_tokens=args.max_new,
                    temperature=args.temperature,
                    page_size=args.page_size, num_pages=args.num_pages,
                    prefill_chunk=args.prefill_chunk),
        telemetry=telemetry,
    )
    print(f"[serve] arena: {eng.arena.num_pages} pages x "
          f"{eng.layout.page_bytes()} B "
          f"({eng.arena.nbytes() / 1e6:.1f} MB), page_size={args.page_size}, "
          f"planes={list(eng.layout.plane_dtypes)}")

    base = TrafficConfig(num_requests=args.requests,
                         prompt_len=(2, max(2, args.prompt_len)),
                         vocab_size=cfg.vocab_size, seed=args.seed)

    def _save_telemetry() -> None:
        if telemetry is None:
            return
        paths = telemetry.save()
        telemetry.close()
        print(f"[telemetry] {paths['snapshot']}  {paths['trace']} "
              f"(open in Perfetto)")

    if args.sweep:
        rates = [float(r) for r in args.sweep.split(",") if r]
        for rep in sweep(eng, rates, base):
            _print_report(rep)
        _save_telemetry()
        return
    if args.qps > 0:
        _print_report(run_traffic(eng, TrafficConfig(
            qps=args.qps, num_requests=args.requests,
            prompt_len=base.prompt_len, vocab_size=cfg.vocab_size,
            seed=args.seed)))
        m = eng.metrics()
        print(f"[serve] prefill={m['prefill_tok_us']:.0f}us/tok "
              f"generate={m['generate_tok_us']:.0f}us/tok "
              f"insert={m['insert_us']:.0f}us")
        _save_telemetry()
        return

    rng = np.random.default_rng(args.seed)
    rids = []
    for _ in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=rng.integers(2, args.prompt_len + 1)).tolist()
        rids.append((eng.submit(prompt), prompt))

    t0 = time.perf_counter()
    steps = 0
    while eng.busy:
        eng.step()
        steps += 1
    wall = time.perf_counter() - t0
    total_new = sum(len(eng.results[r].tokens) for r, _ in rids)
    m = eng.metrics()
    print(f"[serve] {args.requests} requests, {steps} engine steps, "
          f"{wall:.2f}s, {total_new/wall:.1f} tok/s")
    print(f"[serve] prefill={m['prefill_tok_us']:.0f}us/tok "
          f"generate={m['generate_tok_us']:.0f}us/tok "
          f"insert={m['insert_us']:.0f}us")
    for rid, prompt in rids[:4]:
        c = eng.results[rid]
        print(f"  req {rid}: prompt={prompt[:6]}... -> {c.tokens[:8]} "
              f"[{c.finish_reason}]")
    _save_telemetry()


if __name__ == "__main__":
    main()
