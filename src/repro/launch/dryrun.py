import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape x mesh) combination:
``jit(step).lower(**ShapeDtypeStructs).compile()`` against the production
mesh — 16x16 (one pod, 256 chips) and 2x16x16 (two pods, 512 chips) — then
record ``memory_analysis()``, ``cost_analysis()`` and the parsed collective
schedule into a JSON report consumed by EXPERIMENTS.md SSDry-run/SSRoofline.

No arrays are ever materialised: inputs are ShapeDtypeStructs; compilation
alone proves the sharding config is coherent (sharding mismatches, OOM at
compile and unsupported collectives all fail here).

Usage:
  python -m repro.launch.dryrun --arch gemma-2b --shape train_4k --mesh pod1
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.core import build_plan, get_compressor
from repro.core.ccr import (
    HardwareSpec,
    analytic_ccr,
    select_interval,
)
from repro.launch import analytic_costs, hlo_analysis, shardings as sh
from repro.launch.mesh import (
    dp_axes as dp_axes_fn,
    make_production_mesh,
    make_slice_mesh,
)
from repro.models import build_model, count_params, long_context_variant, model_flops
from repro.optim import adamw
from repro.train.trainer import build_train_step

HW = HardwareSpec.v5e()


def auto_interval(cfg, mesh, dp) -> int:
    """COVAP's adaptive I = ceil(CCR) from the analytic profiler (SS III.B).

    Same rule as ``repro.api``'s ``interval='auto'``; the multi-pod mesh
    splits the sync into the two-level decomposition (DESIGN.md §17):
    a ring all-reduce of the shard inside the pod over the ICI, plus a
    cross-pod exchange over the DCN of only the 1/W_intra slice the intra
    ring already reduced — priced through per-link ``CollectiveCall``
    wire models, so this stays consistent with the trainer's static
    ``CommSchedule`` accounting.  The intra-pod DP world is derived from
    the dp axes themselves (any axis but 'pod'), not a hardcoded axis
    name.
    """
    from repro.core.schedule import CollectiveCall

    n_chips = 1
    for a in mesh.shape:
        n_chips *= mesh.shape[a]
    dp_world = 1
    for a in dp:
        dp_world *= mesh.shape[a]
    tokens = INPUT_SHAPES["train_4k"].global_batch * INPUT_SHAPES["train_4k"].seq_len
    n_active = count_params(cfg, active_only=True)
    flops_per_chip = 6.0 * n_active * tokens / n_chips
    grad_bytes = count_params(cfg) * jnp.dtype(cfg.param_dtype).itemsize
    # gradient sync happens per model-shard: each DP group syncs its shard
    model_world = n_chips // dp_world
    shard = grad_bytes / model_world
    t_comp = (2.0 / 3.0) * flops_per_chip / (HW.peak_flops * HW.mfu)
    if "pod" in dp:
        w_intra = 1
        for a in dp:
            if a != "pod":
                w_intra *= mesh.shape[a]
        calls = (
            CollectiveCall(
                "grad-shard", "all_reduce", cfg.param_dtype, int(shard),
                link="ici", world=w_intra,
            ),
            # the DCN carries only the 1/W_intra slice each worker owns
            # after the intra ring reduced it
            CollectiveCall(
                "pod-shard", "all_reduce", cfg.param_dtype,
                int(shard) // max(w_intra, 1),
                link="dcn", world=mesh.shape["pod"],
            ),
        )
        bw = {"ici": HW.ici_bw, "dcn": HW.dcn_bw}
        t_comm = sum(c.wire_bytes(0) / bw[c.link] for c in calls)
        return select_interval(t_comm / max(t_comp, 1e-12))
    return select_interval(analytic_ccr(
        step_flops_per_chip=flops_per_chip,
        grad_bytes=shard,
        dp_world=dp_world,
        hw=HW,
    ))


def _spec_shapes(model):
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def lower_train(model, mesh, dp, compressor_name: str, interval: int, phase: int,
                pod_interval: int = 1, sync: str = "allreduce"):
    cfg = model.cfg
    params_sds = _spec_shapes(model)
    plan = build_plan(params_sds, interval=interval,
                      param_specs=sh.train_param_specs(model, mesh))
    opts = {"interval": interval} if compressor_name == "covap" else {}
    if sync != "allreduce":
        opts["sync"] = sync
    compressor = get_compressor(compressor_name, **opts)
    moment_dtype = "bfloat16" if cfg.param_dtype == "bfloat16" else None
    optimizer = adamw(1e-4, moment_dtype=moment_dtype)

    p_specs = sh.train_param_specs(model, mesh)
    opt_sds = jax.eval_shape(optimizer.init, params_sds)
    comp_sds = jax.eval_shape(
        lambda p: compressor.init_state(p, plan), params_sds
    )
    shape = INPUT_SHAPES["train_4k"]
    batch_sds = model.input_specs(shape)

    hier = pod_interval > 1 and "pod" in mesh.shape
    if hier:
        n_pods = mesh.shape["pod"]

        def podded(tree):
            return jax.tree.map(
                lambda a: jax.ShapeDtypeStruct((n_pods,) + a.shape, a.dtype),
                tree,
            )

        def pod_spec(tree):
            return jax.tree.map(
                lambda s: P(*(("pod",) + tuple(s))),
                tree,
                is_leaf=lambda x: isinstance(x, P),
            )

        params_sds, opt_sds, comp_sds = map(podded, (params_sds, opt_sds, comp_sds))
        p_specs_in = pod_spec(p_specs)
        opt_specs_in = pod_spec(sh.opt_state_specs(
            jax.eval_shape(optimizer.init, _spec_shapes(model)), p_specs))
        comp_specs_in = pod_spec(sh.comp_state_specs(
            jax.eval_shape(
                lambda p: compressor.init_state(p, plan), _spec_shapes(model)
            ),
            _spec_shapes(model), p_specs))
    else:
        p_specs_in = p_specs
        opt_specs_in = sh.opt_state_specs(opt_sds, p_specs)
        comp_specs_in = sh.comp_state_specs(comp_sds, params_sds, p_specs)

    step_jit = build_train_step(
        model, optimizer, compressor, plan,
        phase=phase, mesh=mesh, dp_axes=dp,
        param_shardings={
            "params": p_specs_in,
            "opt": opt_specs_in,
            "comp": comp_specs_in,
            "batch": jax.tree.map(lambda _: P(tuple(dp)), batch_sds),
        },
        donate=False,
        pod_interval=pod_interval,
    )
    step_sds = jax.ShapeDtypeStruct((), jnp.int32)
    lowered = step_jit.lower(params_sds, opt_sds, comp_sds, batch_sds, step_sds)
    # the static plan of this phase, exactly as compiled: build_train_step
    # attaches the CommSchedule it planned (with the correct sync world —
    # pod excluded in hierarchical mode), so the recorded bytes are the
    # ones the HLO below must agree with
    sched = step_jit.comm_schedule
    # per-link injected bytes of everything the compiled step body runs:
    # the grad-sync collectives (exposed), the head all-gather freshening
    # last step's deferred shards (sharded sync re-plans the same gather
    # every phase, so this schedule's deferred bytes equal the prev one's),
    # and the cross-pod reconcile if this phase selects pod buckets
    planned_by_link: dict[str, float] = {}

    def _acc(d):
        for l, v in d.items():
            planned_by_link[l] = planned_by_link.get(l, 0.0) + v

    _acc(sched.exposed_bytes_by_link())
    _acc(sched.deferred_bytes_by_link())
    pod_sched = getattr(step_jit, "pod_schedule", None)
    if pod_sched is not None:
        _acc(pod_sched.exposed_bytes_by_link())
    if not hier and "pod" in mesh.shape and "pod" in tuple(dp):
        # flat sync over a multislice mesh: every grad collective's replica
        # group spans the pod boundary, which the HLO classifier (and the
        # physical network) counts as DCN traffic — relabel the record to
        # match; the schedule itself keeps its link labels since flat plans
        # are priced against a single-bandwidth model elsewhere
        planned_by_link = {"dcn": sum(planned_by_link.values())}
    meta = {
        "plan_buckets": plan.num_buckets,
        "interval": interval,
        "phase": phase,
        "compressor": compressor_name,
        "sync": sync,
        "pod_interval": pod_interval,
        "comm_schedule": sched.summary(),
        "pod_schedule": pod_sched.summary() if pod_sched is not None else None,
        "planned_bytes_per_worker": sched.bytes_per_worker,
        "planned_bytes_by_link": planned_by_link,
    }
    return lowered, meta


def _pick_serve_specs(model, mesh, *, include_pod: bool, strategy: str):
    """Serve weight sharding strategy (SSPerf lever).

    'model_data' shards weights over every non-batch axis (max HBM headroom,
    but each matmul re-gathers its weights); 'model' keeps TP-only sharding
    (weights resident per data row — no weight gathers); 'auto' picks
    'model' when the TP shard fits comfortably (< 6 GB/chip)."""
    if strategy == "auto":
        p_bytes = count_params(model.cfg) * jnp.dtype(model.cfg.param_dtype).itemsize
        strategy = "model" if p_bytes / mesh.shape["model"] < 6e9 else "model_data"
    if strategy == "model":
        return sh.train_param_specs(model, mesh), strategy
    return (
        sh.serve_param_specs(model, mesh, include_pod_in_weights=include_pod),
        strategy,
    )


def lower_prefill(model, mesh, dp, shape, *, serve_weights: str = "auto"):
    params_sds = _spec_shapes(model)
    p_specs, strategy = _pick_serve_specs(
        model, mesh, include_pod=False, strategy=serve_weights
    )
    batch_sds = model.input_specs(shape)
    b_specs = sh.batch_specs(batch_sds, mesh, dp)
    fn = jax.jit(
        model.prefill,
        in_shardings=(sh.as_named(mesh, p_specs), sh.as_named(mesh, b_specs)),
    )
    return fn.lower(params_sds, batch_sds), {"serve_weights": strategy}


def lower_decode(model, mesh, dp, shape, *, serve_weights: str = "auto"):
    cfg = model.cfg
    B, S = shape.global_batch, shape.seq_len
    params_sds = _spec_shapes(model)
    include_pod = B == 1 and "pod" in mesh.shape
    p_specs, strategy = _pick_serve_specs(
        model, mesh, include_pod=include_pod, strategy=serve_weights
    )
    cache_sds = model.cache_specs(B, S)
    c_specs = sh.cache_specs_tree(cache_sds, cfg, mesh, dp, B)
    batch_sds = model.input_specs(shape)
    b_specs = sh.batch_specs(batch_sds, mesh, dp)
    fn = jax.jit(
        model.decode_step,
        in_shardings=(
            sh.as_named(mesh, p_specs),
            sh.as_named(mesh, c_specs),
            sh.as_named(mesh, b_specs),
        ),
    )
    return fn.lower(params_sds, cache_sds, batch_sds), {"serve_weights": strategy}


def _memory_analysis(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # backend may not support it
        return {"error": str(e)}
    if ma is None:
        return {}
    out = {}
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
        "peak_memory_in_bytes",
    ):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if not out:
        out["repr"] = str(ma)[:500]
    return out


def _cost_analysis(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    keep = {}
    for k, v in dict(ca).items():
        if k in ("flops", "transcendentals", "bytes accessed") or k.startswith(
            "bytes accessed"
        ):
            keep[k] = float(v)
    return keep


def run_one(arch: str, shape_name: str, multi_pod: bool, *,
            compressor: str = "covap", interval: int | None = None,
            phase: int = 0, serve_weights: str = "auto",
            kv_cache_dtype: str = "", pod_interval: int = 1,
            sync: str = "allreduce", n_slices: int = 0) -> dict:
    shape = INPUT_SHAPES[shape_name]
    cfg = get_config(arch)
    variant = "exact"
    if shape_name == "long_500k":
        new_cfg = long_context_variant(cfg)
        variant = "native" if new_cfg is cfg else "sliding_window"
        cfg = new_cfg
    if kv_cache_dtype:
        cfg = cfg.with_(kv_cache_dtype=kv_cache_dtype)
    model = build_model(cfg)
    if n_slices:
        # compile-only N-slice sweep (MaxText-multislice style): each slice
        # is one pod behind a DCN crossing; smaller per-slice grid so the
        # sweep fits the 512 fake-device budget
        mesh = make_slice_mesh(n_slices)
        dp = ("pod", "data") if n_slices > 1 else ("data",)
        mesh_desc = "x".join(str(mesh.shape[a]) for a in mesh.shape)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
        dp = dp_axes_fn(multi_pod=multi_pod)
        mesh_desc = "2x16x16" if multi_pod else "16x16"
    n_devices = 1
    for a in mesh.shape:
        n_devices *= mesh.shape[a]

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_desc,
        "n_devices": n_devices,
        "kind": shape.kind,
        "variant": variant,
        "status": "ok",
    }
    t0 = time.perf_counter()
    try:
        if shape.kind == "train":
            if interval is None and compressor == "covap":
                interval = auto_interval(cfg, mesh, dp)
            lowered, meta = lower_train(
                model, mesh, dp, compressor, interval or 1, phase,
                pod_interval=pod_interval, sync=sync,
            )
        elif shape.kind == "prefill":
            lowered, meta = lower_prefill(
                model, mesh, dp, shape, serve_weights=serve_weights
            )
        else:
            lowered, meta = lower_decode(
                model, mesh, dp, shape, serve_weights=serve_weights
            )
        if kv_cache_dtype:
            rec["kv_cache_dtype"] = kv_cache_dtype
        rec.update(meta)
        rec["lower_s"] = round(time.perf_counter() - t0, 2)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.perf_counter() - t1, 2)
        rec["memory_analysis"] = _memory_analysis(compiled)
        rec["cost_analysis_hlo"] = _cost_analysis(compiled)
        hlo = compiled.as_text()
        rec["collectives"] = hlo_analysis.collective_summary(hlo, trip_aware=True)
        rec["collectives_raw"] = hlo_analysis.collective_summary(
            hlo, trip_aware=False
        )

        # per-link cross-check (DESIGN.md §17): the statically planned
        # CommSchedule bytes vs the bytes the compiled HLO actually moves
        # over each link.  Plan numels are global while the HLO operates on
        # per-model-shard buffers, so the HLO side is scaled back up by the
        # model world before comparing.  Recorded, not asserted — the hard
        # gate is launch.hier_gate on an unsharded-model mesh.
        planned = rec.get("planned_bytes_by_link")
        if shape.kind == "train" and planned:
            n_pods_mesh = mesh.shape.get("pod", 1)
            hlo_by_link = hlo_analysis.collective_bytes_by_link(
                hlo,
                intra_world=n_devices // n_pods_mesh,
                min_bytes=2048,
                world=n_devices,
            )
            mw = mesh.shape.get("model", 1)
            scaled = {l: v * mw for l, v in hlo_by_link.items()}
            rel = {}
            for l in set(planned) | set(scaled):
                p, h = planned.get(l, 0.0), scaled.get(l, 0.0)
                # None, not inf: HLO traffic on a link with zero planned
                # bytes (e.g. model-TP activation collectives on ici under
                # flat-over-pods sync) — keeps the record strict JSON
                rel[l] = abs(h - p) / p if p else (0.0 if h == 0.0 else None)
            rec["bytes_by_link_check"] = {
                "schedule": planned,
                "hlo": hlo_by_link,
                "hlo_model_scaled": scaled,
                "rel_err": rel,
            }

        # roofline terms (per device).  compute/memory terms are ANALYTIC
        # (XLA cost_analysis counts scan bodies once — see analytic_costs);
        # the collective term is HLO-parsed with while-trip multiplication.
        dp_world = 1
        for a in dp:
            dp_world *= mesh.shape[a]
        model_world = mesh.shape.get("model", 1)
        flops_global = analytic_costs.step_flops(cfg, shape)
        flops = flops_global / n_devices
        extra = 1
        if shape.kind != "train" and shape.global_batch == 1 and "pod" in mesh.shape:
            extra = mesh.shape["pod"]
        hbm = analytic_costs.step_hbm_bytes(
            cfg, shape,
            model_shard=model_world,
            data_shard=dp_world,
            weight_shard_extra=extra,
        )
        wire = rec["collectives"]["wire_bytes_est"]
        terms = hlo_analysis.roofline_terms(
            flops_per_device=flops,
            hbm_bytes_per_device=hbm,
            wire_bytes_per_device=wire,
            peak_flops=HW.peak_flops, hbm_bw=HW.hbm_bw, ici_bw=HW.ici_bw,
        )
        tokens = (
            shape.global_batch
            if shape.kind == "decode"
            else shape.global_batch * shape.seq_len
        )
        mf = model_flops(cfg, tokens, "train" if shape.kind == "train" else "serve")
        rec["roofline"] = {
            "compute_s": terms.compute_s,
            "memory_s": terms.memory_s,
            "collective_s": terms.collective_s,
            "dominant": terms.dominant,
            "flops_per_device": flops,
            "hbm_bytes_per_device": hbm,
            "wire_bytes_per_device": wire,
            "model_flops_global": mf,
            "model_flops_per_device": mf / n_devices,
            "useful_flops_ratio": mf / flops_global if flops_global else None,
        }
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2", "both"])
    ap.add_argument("--compressor", default="covap")
    ap.add_argument("--interval", type=int, default=None)
    ap.add_argument("--phase", type=int, default=0)
    ap.add_argument("--serve-weights", default="auto",
                    choices=["auto", "model", "model_data"])
    ap.add_argument("--kv-cache-dtype", default="")
    ap.add_argument("--pod-interval", type=int, default=1)
    ap.add_argument("--sync", default="allreduce", choices=["allreduce", "sharded"])
    ap.add_argument("--slices", default="",
                    help="comma list of slice counts for the multislice sweep "
                         "(e.g. 1,2,4); overrides --mesh with N-slice "
                         "(pod, 8, 8) compile-only meshes")
    ap.add_argument("--tag", default="", help="suffix for the output JSON")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list_archs(assigned_only=True) if args.arch == "all" else args.arch.split(",")
    shapes = list(INPUT_SHAPES) if args.shape == "all" else args.shape.split(",")
    if args.slices:
        # the multislice sweep reuses the mesh loop: one entry per N
        meshes = [int(s) for s in args.slices.split(",")]
        mesh_tags = [f"slice{n}" for n in meshes]
        slice_mode = True
    else:
        meshes = {"pod1": [False], "pod2": [True], "both": [False, True]}[args.mesh]
        mesh_tags = ["pod2" if m else "pod1" for m in meshes]
        slice_mode = False

    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        for shape in shapes:
            for mesh_sel, mesh_tag in zip(meshes, mesh_tags):
                tag = f"{arch}__{shape}__{mesh_tag}__{args.compressor}"
                if args.sync != "allreduce":
                    tag += f"__{args.sync}"
                if args.tag:
                    tag += f"__{args.tag}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"skip {tag}")
                    continue
                rec = run_one(
                    arch, shape,
                    mesh_sel if not slice_mode else False,
                    compressor=args.compressor,
                    interval=args.interval, phase=args.phase,
                    serve_weights=args.serve_weights,
                    kv_cache_dtype=args.kv_cache_dtype,
                    pod_interval=args.pod_interval,
                    sync=args.sync,
                    n_slices=mesh_sel if slice_mode else 0,
                )
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if rec["status"] == "ok":
                    r = rec["roofline"]
                    print(
                        f"OK   {tag:60s} compile={rec['compile_s']:7.1f}s "
                        f"dom={r['dominant']:10s} "
                        f"comp={r['compute_s']*1e3:8.2f}ms "
                        f"mem={r['memory_s']*1e3:8.2f}ms "
                        f"coll={r['collective_s']*1e3:8.2f}ms"
                    )
                else:
                    print(f"FAIL {tag:60s} {rec['error'][:120]}")


if __name__ == "__main__":
    main()
