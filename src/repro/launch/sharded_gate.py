"""The sharded-sync placement gate: compile one sharded step and check its
HLO schedule (DESIGN.md §13).

Shared harness for the ``benchmarks.run --smoke`` "sharded" gate and
``tests/test_sharded_sync.py`` — run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the CPU backend
has a real 8-worker mesh to emit collectives on:

    python -m repro.launch.sharded_gate

prints one ``SHARDED ...`` line and exits non-zero unless the compiled
module (a) reduce-scatters gradient buckets before the final
gradient-producing fusion (the RS half rides the backward pass) and
(b) schedules the deferred param all-gathers at the step's HEAD, before
the first reduce-scatter (they overlap the forward pass of the step whose
head they sit at).  It additionally cross-checks the schedule-level
exposed-bytes claim: under ``sync="sharded"`` at W=8 the ring-amplified
exposed wire bytes per worker must be at most 0.6x the all-reduce path's.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import (
    ShardedPlacementReport,
    check_sharded_placement,
)


def build_trainer(
    *,
    arch: str = "gpt2-paper",
    vocab_size: int = 256,
    seq_len: int = 32,
    global_batch: int = 8,
    interval: int = 4,
    overlap: str = "fused",
):
    from jax.sharding import Mesh

    from repro.configs import get_reduced
    from repro.data import DataConfig, make_loader
    from repro.models import build_model
    from repro.optim import adamw
    from repro.train.trainer import TrainConfig, Trainer

    mesh = Mesh(np.array(jax.devices()), ("data",))
    cfg = get_reduced(arch).with_(vocab_size=vocab_size)
    model = build_model(cfg)
    tc = TrainConfig(
        compressor="covap", interval=interval, bucket_bytes=1 << 14,
        max_buckets=32, log_every=10 ** 9, overlap=overlap, sync="sharded",
    )
    trainer = Trainer(model, adamw(1e-3), tc, mesh=mesh, dp_axes=("data",))
    state = trainer.init_state(jax.random.PRNGKey(0))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                    global_batch=global_batch)
    batch = next(iter(make_loader(dc)))
    return trainer, state, batch


def compile_and_check(
    trainer=None, state=None, batch=None, *, phase: int = 0,
    min_bytes: int = 1024, **kw,
) -> ShardedPlacementReport:
    """Compile ``trainer``'s sharded phase executable (or build a small
    sharded COVAP trainer on a mesh over all local devices) and run
    :func:`~repro.launch.hlo_analysis.check_sharded_placement` on the
    optimized HLO."""
    if trainer is None:
        trainer, state, batch = build_trainer(**kw)
    fn = trainer._phase_fn(phase)
    hlo = fn.lower(
        state["params"], state["opt"], state["comp"], batch, jnp.int32(0)
    ).compile().as_text()
    return check_sharded_placement(
        hlo, min_bytes=min_bytes, world=trainer.dp_world
    )


def exposed_ratio(trainer, *, world: int | None = None) -> float:
    """Schedule-level acceptance number: mean exposed wire bytes per worker
    of the sharded plan over one phase cycle, divided by the same
    compressor's all-reduce plan.  The RS half moves (W-1)/W of each
    buffer where the all-reduce moves 2(W-1)/W, so the ratio sits at ~0.5
    (padding adds epsilon); the gate requires <= 0.6."""
    from repro.train.trainer import make_compressor
    import dataclasses

    w = trainer.dp_world if world is None else world
    sharded = trainer.schedules()
    ar_comp = make_compressor(
        dataclasses.replace(trainer.tc, sync="allreduce")
    )
    exposed = sum(s.exposed_wire_bytes(w) for s in sharded)
    dense = sum(
        ar_comp.plan_phase(trainer.plan, p, world=w).exposed_wire_bytes(w)
        for p in range(len(sharded))
    )
    return exposed / dense if dense else 1.0


def main() -> None:
    trainer, state, batch = build_trainer()
    r = compile_and_check(trainer, state, batch)
    ratio = exposed_ratio(trainer)
    print(
        f"SHARDED num_reduce_scatter={r.num_reduce_scatter} "
        f"num_all_gather={r.num_all_gather} "
        f"rs_before_final_grad={r.rs_before_final_grad} "
        f"ag_before_first_rs={r.ag_before_first_rs} "
        f"placed={r.placed} exposed_ratio={ratio:.3f}"
    )
    if not r.placed:
        raise SystemExit(
            "sharded step's compiled HLO does not place reduce-scatters "
            "inside the backward pass with the param all-gathers at the "
            "step head"
        )
    if ratio > 0.6:
        raise SystemExit(
            f"sharded exposed wire bytes {ratio:.3f}x all-reduce path "
            "(acceptance gate: <= 0.6x)"
        )


if __name__ == "__main__":
    main()
