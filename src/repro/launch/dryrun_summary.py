"""SSDry-run evidence table: memory fit + collective schedule per combo.

    python -m repro.launch.dryrun_summary --dir experiments/dryrun_v2 \
        --md experiments/dryrun_summary.md
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def gb(x):
    return f"{x/1e9:.2f}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun_v2")
    ap.add_argument("--md", default="")
    args = ap.parse_args()

    recs = []
    for f in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))

    lines = [
        "| arch | shape | mesh | peak GB/dev | args GB | AR ops/GB | "
        "AG ops/GB | A2A ops/GB | compile s |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(
        recs, key=lambda r: (r.get("arch", ""), r.get("shape", ""), r.get("mesh", ""))
    ):
        if r.get("status") != "ok":
            lines.append(
                f"| {r.get('arch')} | {r.get('shape')} | {r.get('mesh')} | "
                f"{r.get('status').upper()} | | | | | |"
            )
            continue
        ma = r.get("memory_analysis", {})
        coll = r.get("collectives", {}).get("by_kind", {})

        def cell(kind):
            d = coll.get(kind)
            return f"{d['count']}/{gb(d['bytes'])}" if d else "-"

        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{gb(ma.get('peak_memory_in_bytes', 0))} | "
            f"{gb(ma.get('argument_size_in_bytes', 0))} | "
            f"{cell('all-reduce')} | {cell('all-gather')} | "
            f"{cell('all-to-all')} | {r.get('compile_s', '')} |"
        )
    text = "\n".join(lines)
    if args.md:
        with open(args.md, "w") as f:
            f.write(text)
    print(text)


if __name__ == "__main__":
    main()
